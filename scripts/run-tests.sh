#!/usr/bin/env bash
# Tier-1 verify (`cargo build --release && cargo test -q`), toolchain-gated
# the same way scripts/check-docs.sh gates its cargo half:
#
#   - no rust toolchain on PATH             -> skip with a notice
#   - no rust/Cargo.toml (the vendored xla  -> skip with a notice
#     crate set lives in the build image,
#     not in every checkout)
#   - CHECK_TESTS_SKIP_CARGO=1              -> skip (CI escape hatch)
#
# Hosted CI runners ship a toolchain but not the vendor set, so the gate
# keeps .github/workflows/tests.yml green there while still running the
# full suite anywhere the build image is available.
set -euo pipefail
cd "$(dirname "$0")/.."

# Python suite collection check — toolchain-free, so it runs BEFORE the
# cargo gates. The property files guard their hypothesis import with
# pytest.importorskip, so collection must succeed (zero errors) whether
# or not hypothesis is installed; the count floor catches a suite that
# silently stopped being collected.
if command -v python3 >/dev/null 2>&1 && python3 -c 'import pytest' >/dev/null 2>&1; then
    echo "run-tests: pytest --collect-only python/tests"
    collect="$(python3 -m pytest --collect-only -q python/tests 2>&1 | tail -2)" || {
        echo "run-tests: FAIL — python test collection errored:" >&2
        printf '%s\n' "${collect}" >&2
        exit 1
    }
    if grep -qi 'error' <<< "${collect}"; then
        echo "run-tests: FAIL — python test collection reports errors:" >&2
        printf '%s\n' "${collect}" >&2
        exit 1
    fi
    n_tests="$(sed -n 's/^\([0-9][0-9]*\) tests collected.*/\1/p' <<< "${collect}")"
    if [ -z "${n_tests}" ] || [ "${n_tests}" -lt 25 ]; then
        echo "run-tests: FAIL — expected >= 25 collectable python tests, got '${n_tests:-none}':" >&2
        printf '%s\n' "${collect}" >&2
        exit 1
    fi
    echo "run-tests: python collection OK (${n_tests} tests, 0 errors)"
else
    echo "run-tests: NOTE — python3/pytest not available, skipping python collection check" >&2
fi

if [ "${CHECK_TESTS_SKIP_CARGO:-0}" = "1" ]; then
    echo "run-tests: NOTE — CHECK_TESTS_SKIP_CARGO=1, skipping cargo build/test" >&2
    exit 0
fi
if ! command -v cargo >/dev/null 2>&1; then
    echo "run-tests: NOTE — cargo not on PATH, skipping cargo build/test" >&2
    exit 0
fi
if [ ! -f rust/Cargo.toml ]; then
    echo "run-tests: NOTE — rust/Cargo.toml absent (vendored crate set not in this checkout), skipping cargo build/test" >&2
    exit 0
fi

cd rust
echo "run-tests: cargo build --release"
cargo build --release
echo "run-tests: cargo test -q"
cargo test -q

# Kernel backend for the smokes below (DESIGN.md §13). Default is the
# bit-exact reference path; the tests.yml cargo-test-simd leg re-runs
# the smokes with RSQ_SMOKE_BACKEND=simd, which resolves back to
# reference on hosts without AVX2+FMA — so it is safe everywhere.
backend="${RSQ_SMOKE_BACKEND:-reference}"
echo "run-tests: smoke backend = ${backend}"

# Serve smoke (DESIGN.md §11): greedy-decode the golden fixture artifact
# — a tiny, committed, byte-reproducible packed artifact — through `rsq
# generate` and assert the token output is non-empty and identical
# across two runs (the serving layer's determinism contract). Fully
# host-side: needs no AOT artifact set and no PJRT.
echo "run-tests: serve smoke (rsq generate on tests/data/artifact_ok)"
smoke_log="$(mktemp)"
smoke() {
    cargo run --release --quiet -- generate \
        --artifact tests/data/artifact_ok --prompt 1,2 --max-new 5 \
        --backend "${backend}" 2>"${smoke_log}"
}
# || disarms set -e so a decode failure prints its captured stderr
# instead of silently killing the script at the assignment
out1="$(smoke)" || {
    echo "run-tests: FAIL — serve smoke (rsq generate) exited non-zero:" >&2
    cat "${smoke_log}" >&2
    exit 1
}
out2="$(smoke)" || {
    echo "run-tests: FAIL — serve smoke second run exited non-zero:" >&2
    cat "${smoke_log}" >&2
    exit 1
}
rm -f "${smoke_log}"
if [ -z "${out1}" ]; then
    echo "run-tests: FAIL — serve smoke produced no output" >&2
    exit 1
fi
# herestring, not printf|grep: under pipefail an early grep -q match can
# SIGPIPE the printf and flake a passing check (see check-docs.sh)
if ! grep -q '^generated' <<< "${out1}"; then
    echo "run-tests: FAIL — serve smoke output has no 'generated' line:" >&2
    printf '%s\n' "${out1}" >&2
    exit 1
fi
if [ "${out1}" != "${out2}" ]; then
    echo "run-tests: FAIL — serve smoke output is not deterministic across runs" >&2
    printf 'run 1:\n%s\nrun 2:\n%s\n' "${out1}" "${out2}" >&2
    exit 1
fi
echo "run-tests: serve smoke OK"

# Quantized-KV smoke (DESIGN.md §12): the same golden-fixture decode at
# --kv-bits 8 must be non-empty, deterministic, AND token-identical to
# the f32 run — the acceptance bar that 8-bit KV divergence is 0 on the
# smoke prompt.
echo "run-tests: kv smoke (rsq generate --kv-bits 8)"
kv_log="$(mktemp)"
kv_smoke() {
    cargo run --release --quiet -- generate \
        --artifact tests/data/artifact_ok --prompt 1,2 --max-new 5 \
        --kv-bits 8 --backend "${backend}" 2>"${kv_log}"
}
kv1="$(kv_smoke)" || {
    echo "run-tests: FAIL — kv smoke (--kv-bits 8) exited non-zero:" >&2
    cat "${kv_log}" >&2
    exit 1
}
kv2="$(kv_smoke)" || {
    echo "run-tests: FAIL — kv smoke second run exited non-zero:" >&2
    cat "${kv_log}" >&2
    exit 1
}
rm -f "${kv_log}"
if [ -z "${kv1}" ]; then
    echo "run-tests: FAIL — kv smoke produced no output" >&2
    exit 1
fi
if [ "${kv1}" != "${kv2}" ]; then
    echo "run-tests: FAIL — kv smoke output is not deterministic across runs" >&2
    printf 'run 1:\n%s\nrun 2:\n%s\n' "${kv1}" "${kv2}" >&2
    exit 1
fi
gen_f32="$(grep '^generated' <<< "${out1}")"
gen_kv8="$(grep '^generated' <<< "${kv1}")"
if [ -z "${gen_kv8}" ]; then
    echo "run-tests: FAIL — kv smoke output has no 'generated' line:" >&2
    printf '%s\n' "${kv1}" >&2
    exit 1
fi
if [ "${gen_kv8}" != "${gen_f32}" ]; then
    echo "run-tests: FAIL — 8-bit KV diverged from f32 on the smoke prompt:" >&2
    printf 'f32 : %s\nkv8 : %s\n' "${gen_f32}" "${gen_kv8}" >&2
    exit 1
fi
echo "run-tests: kv smoke OK (8-bit KV divergence 0)"

# Backend smoke (DESIGN.md §13): a run with no --backend flag must be
# byte-identical on stdout to an explicit --backend reference run (the
# default is the bit-exact path), and --backend simd — which silently
# resolves to reference on hosts without AVX2+FMA — must be
# deterministic across two runs. simd-vs-reference greedy token
# divergence is REPORTED, not fatal: simd is tolerance-pinned, and a
# greedy argmax can legitimately flip on a near-tie.
echo "run-tests: backend smoke (rsq generate, default vs reference vs simd)"
be_log="$(mktemp)"
be_smoke() {
    cargo run --release --quiet -- generate \
        --artifact tests/data/artifact_ok --prompt 1,2 --max-new 5 \
        --backend "$1" 2>"${be_log}"
}
be_noflag="$(cargo run --release --quiet -- generate \
    --artifact tests/data/artifact_ok --prompt 1,2 --max-new 5 2>"${be_log}")" || {
    echo "run-tests: FAIL — backend smoke (no flag) exited non-zero:" >&2
    cat "${be_log}" >&2
    exit 1
}
be_ref="$(be_smoke reference)" || {
    echo "run-tests: FAIL — backend smoke (--backend reference) exited non-zero:" >&2
    cat "${be_log}" >&2
    exit 1
}
if [ "${be_noflag}" != "${be_ref}" ]; then
    echo "run-tests: FAIL — default stdout differs from --backend reference:" >&2
    printf 'default  :\n%s\nreference:\n%s\n' "${be_noflag}" "${be_ref}" >&2
    exit 1
fi
be_simd1="$(be_smoke simd)" || {
    echo "run-tests: FAIL — backend smoke (--backend simd) exited non-zero:" >&2
    cat "${be_log}" >&2
    exit 1
}
be_simd2="$(be_smoke simd)" || {
    echo "run-tests: FAIL — backend smoke simd second run exited non-zero:" >&2
    cat "${be_log}" >&2
    exit 1
}
rm -f "${be_log}"
if [ "${be_simd1}" != "${be_simd2}" ]; then
    echo "run-tests: FAIL — --backend simd output is not deterministic across runs" >&2
    printf 'run 1:\n%s\nrun 2:\n%s\n' "${be_simd1}" "${be_simd2}" >&2
    exit 1
fi
gen_ref_be="$(grep '^generated' <<< "${be_ref}")"
gen_simd_be="$(grep '^generated' <<< "${be_simd1}")"
if [ "${gen_simd_be}" = "${gen_ref_be}" ]; then
    echo "run-tests: backend smoke OK (simd greedy-token divergence 0)"
else
    echo "run-tests: backend smoke OK (NOTE — simd greedy tokens diverge from reference:)"
    printf 'reference: %s\nsimd     : %s\n' "${gen_ref_be}" "${gen_simd_be}"
fi

# Prefix-cache smoke (DESIGN.md §15): serve the golden fixture through
# the batching path (--prompts 2, one slot, 2-position pages so the
# 3-token prompt crosses a page boundary). The warm (--prefix-cache)
# run must be byte-identical on stdout to the cold run — prefix hits
# change ZERO tokens — and its stderr must report a non-zero hit count
# (prefill forwards actually eliminated).
echo "run-tests: prefix smoke (rsq generate --prompts 2 --prefix-cache)"
px_log="$(mktemp)"
px_smoke() {
    cargo run --release --quiet -- generate \
        --artifact tests/data/artifact_ok --prompt 1,2,5 --max-new 5 \
        --prompts 2 --max-batch 1 --kv-page 2 \
        --backend "${backend}" "$@" 2>"${px_log}"
}
px_cold="$(px_smoke)" || {
    echo "run-tests: FAIL — prefix smoke cold run exited non-zero:" >&2
    cat "${px_log}" >&2
    exit 1
}
px_warm="$(px_smoke --prefix-cache)" || {
    echo "run-tests: FAIL — prefix smoke warm run exited non-zero:" >&2
    cat "${px_log}" >&2
    exit 1
}
if [ -z "${px_cold}" ] || ! grep -q '^generated' <<< "${px_cold}"; then
    echo "run-tests: FAIL — prefix smoke cold run produced no generated lines:" >&2
    printf '%s\n' "${px_cold}" >&2
    exit 1
fi
if [ "${px_cold}" != "${px_warm}" ]; then
    echo "run-tests: FAIL — prefix-cache hits changed the served tokens:" >&2
    printf 'cold:\n%s\nwarm:\n%s\n' "${px_cold}" "${px_warm}" >&2
    exit 1
fi
px_hits="$(sed -n 's/.*prefix cache: \([0-9][0-9]*\)\/.*/\1/p' "${px_log}")"
if [ -z "${px_hits}" ] || [ "${px_hits}" -eq 0 ]; then
    echo "run-tests: FAIL — warm run reported no prefix-cache hits:" >&2
    cat "${px_log}" >&2
    exit 1
fi
rm -f "${px_log}"
echo "run-tests: prefix smoke OK (${px_hits} hit(s), stdout identical to cold)"

# Trace smoke (DESIGN.md §16): the same golden-fixture decode with
# --trace/--metrics must keep stdout BYTE-IDENTICAL to the untraced run
# — the binding contract that observability changes zero output bits —
# and the exported files must pass the toolchain-free validator,
# required span names included.
echo "run-tests: trace smoke (rsq generate --trace/--metrics)"
tr_log="$(mktemp)"
tr_tmp="$(mktemp -d)"
tr_smoke() {
    cargo run --release --quiet -- generate \
        --artifact tests/data/artifact_ok --prompt 1,2 --max-new 5 \
        --jobs 2 --backend "${backend}" "$@" 2>"${tr_log}"
}
tr_plain="$(tr_smoke)" || {
    echo "run-tests: FAIL — trace smoke untraced run exited non-zero:" >&2
    cat "${tr_log}" >&2
    exit 1
}
tr_on="$(tr_smoke --trace "${tr_tmp}/trace.json" --metrics "${tr_tmp}/metrics.json")" || {
    echo "run-tests: FAIL — trace smoke traced run exited non-zero:" >&2
    cat "${tr_log}" >&2
    exit 1
}
rm -f "${tr_log}"
if [ "${tr_plain}" != "${tr_on}" ]; then
    echo "run-tests: FAIL — --trace/--metrics changed stdout:" >&2
    printf 'untraced:\n%s\ntraced:\n%s\n' "${tr_plain}" "${tr_on}" >&2
    exit 1
fi
if [ ! -s "${tr_tmp}/trace.json" ] || [ ! -s "${tr_tmp}/metrics.json" ]; then
    echo "run-tests: FAIL — traced run wrote no trace/metrics files" >&2
    exit 1
fi
if command -v python3 >/dev/null 2>&1; then
    python3 ../scripts/validate_trace.py \
        --trace "${tr_tmp}/trace.json" --metrics "${tr_tmp}/metrics.json" \
        --require serve.prefill --require serve.decode --require pool.task || {
        echo "run-tests: FAIL — trace/metrics files failed validation" >&2
        exit 1
    }
else
    echo "run-tests: NOTE — python3 not available, skipping trace validation" >&2
fi
rm -rf "${tr_tmp}"
echo "run-tests: trace smoke OK (stdout identical, files validated)"

# Mixed-precision smoke (DESIGN.md §14): quantize the tiny config under
# --avg-bits 3.0, assert the achieved average respects the budget, and
# assert `rsq eval --artifact` on the resulting mixed-width artifact is
# deterministic across two runs. Quantization needs the AOT artifact set
# (`make artifacts`), so this leg gates on the tiny directory the same
# way the cargo half gates on the toolchain.
tiny_dir="${RSQ_ARTIFACTS:-artifacts}/tiny"
if [ -d "${tiny_dir}" ]; then
    echo "run-tests: mixed-precision smoke (rsq quantize --avg-bits 3.0)"
    mp_log="$(mktemp)"
    mp_tmp="$(mktemp -d)"
    mp_dir="${mp_tmp}/mixed-artifact"
    mp_out="$(cargo run --release --quiet -- quantize \
        --config tiny --avg-bits 3.0 --calib-n 4 --calib-t 64 \
        --hess-cache off --save "${mp_dir}" \
        --backend "${backend}" 2>"${mp_log}")" || {
        echo "run-tests: FAIL — mixed-precision quantize exited non-zero:" >&2
        cat "${mp_log}" >&2
        exit 1
    }
    avg="$(sed -n 's/^mixed bits   : avg \([0-9.]*\).*/\1/p' <<< "${mp_out}")"
    if [ -z "${avg}" ]; then
        echo "run-tests: FAIL — quantize printed no 'mixed bits' line:" >&2
        printf '%s\n' "${mp_out}" >&2
        exit 1
    fi
    if ! awk -v a="${avg}" 'BEGIN { exit !(a <= 3.0) }'; then
        echo "run-tests: FAIL — achieved avg bits ${avg} exceeds the 3.0 budget" >&2
        exit 1
    fi
    mp_eval() {
        cargo run --release --quiet -- eval --artifact "${mp_dir}" \
            --backend "${backend}" 2>"${mp_log}"
    }
    ev1="$(mp_eval)" || {
        echo "run-tests: FAIL — eval --artifact on the mixed artifact exited non-zero:" >&2
        cat "${mp_log}" >&2
        exit 1
    }
    ev2="$(mp_eval)" || {
        echo "run-tests: FAIL — mixed-precision eval second run exited non-zero:" >&2
        cat "${mp_log}" >&2
        exit 1
    }
    rm -f "${mp_log}"
    if ! grep -q '^mixed bits' <<< "${ev1}"; then
        echo "run-tests: FAIL — eval output has no 'mixed bits' provenance line:" >&2
        printf '%s\n' "${ev1}" >&2
        exit 1
    fi
    if [ "${ev1}" != "${ev2}" ]; then
        echo "run-tests: FAIL — mixed-precision eval is not deterministic across runs" >&2
        printf 'run 1:\n%s\nrun 2:\n%s\n' "${ev1}" "${ev2}" >&2
        exit 1
    fi
    rm -rf "${mp_tmp}"
    echo "run-tests: mixed-precision smoke OK (avg ${avg} <= 3.0, eval deterministic)"
else
    echo "run-tests: NOTE — ${tiny_dir} absent (run \`make artifacts\`), skipping mixed-precision smoke" >&2
fi

# Quantize trace smoke (DESIGN.md §16): a full tiny quantization under
# --trace/--metrics must cover the scheduler phases, and its stdout must
# match an untraced run once the wall-timing line (nondeterministic
# across ANY two runs) is filtered out. Gated on the AOT artifact set
# like the mixed-precision smoke above.
if [ -d "${tiny_dir}" ]; then
    echo "run-tests: quantize trace smoke (rsq quantize --trace/--metrics)"
    qt_log="$(mktemp)"
    qt_tmp="$(mktemp -d)"
    qt_smoke() {
        cargo run --release --quiet -- quantize \
            --config tiny --calib-n 4 --calib-t 64 --jobs 2 \
            --hess-cache off --backend "${backend}" "$@" 2>"${qt_log}"
    }
    qt_plain="$(qt_smoke)" || {
        echo "run-tests: FAIL — quantize trace smoke untraced run exited non-zero:" >&2
        cat "${qt_log}" >&2
        exit 1
    }
    qt_on="$(qt_smoke --trace "${qt_tmp}/trace.json" --metrics "${qt_tmp}/metrics.json")" || {
        echo "run-tests: FAIL — quantize trace smoke traced run exited non-zero:" >&2
        cat "${qt_log}" >&2
        exit 1
    }
    rm -f "${qt_log}"
    if [ "$(grep -v '^wall' <<< "${qt_plain}")" != "$(grep -v '^wall' <<< "${qt_on}")" ]; then
        echo "run-tests: FAIL — --trace/--metrics changed quantize stdout:" >&2
        printf 'untraced:\n%s\ntraced:\n%s\n' "${qt_plain}" "${qt_on}" >&2
        exit 1
    fi
    if command -v python3 >/dev/null 2>&1; then
        python3 ../scripts/validate_trace.py \
            --trace "${qt_tmp}/trace.json" --metrics "${qt_tmp}/metrics.json" \
            --require sched.solve_module --require quant.rotate --require pool.task || {
            echo "run-tests: FAIL — quantize trace/metrics files failed validation" >&2
            exit 1
        }
    fi
    rm -rf "${qt_tmp}"
    echo "run-tests: quantize trace smoke OK (stdout identical, scheduler spans present)"
else
    echo "run-tests: NOTE — ${tiny_dir} absent, skipping quantize trace smoke" >&2
fi
echo "run-tests: OK"
