#!/usr/bin/env bash
# Tier-1 verify (`cargo build --release && cargo test -q`), toolchain-gated
# the same way scripts/check-docs.sh gates its cargo half:
#
#   - no rust toolchain on PATH             -> skip with a notice
#   - no rust/Cargo.toml (the vendored xla  -> skip with a notice
#     crate set lives in the build image,
#     not in every checkout)
#   - CHECK_TESTS_SKIP_CARGO=1              -> skip (CI escape hatch)
#
# Hosted CI runners ship a toolchain but not the vendor set, so the gate
# keeps .github/workflows/tests.yml green there while still running the
# full suite anywhere the build image is available.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${CHECK_TESTS_SKIP_CARGO:-0}" = "1" ]; then
    echo "run-tests: NOTE — CHECK_TESTS_SKIP_CARGO=1, skipping cargo build/test" >&2
    exit 0
fi
if ! command -v cargo >/dev/null 2>&1; then
    echo "run-tests: NOTE — cargo not on PATH, skipping cargo build/test" >&2
    exit 0
fi
if [ ! -f rust/Cargo.toml ]; then
    echo "run-tests: NOTE — rust/Cargo.toml absent (vendored crate set not in this checkout), skipping cargo build/test" >&2
    exit 0
fi

cd rust
echo "run-tests: cargo build --release"
cargo build --release
echo "run-tests: cargo test -q"
cargo test -q

# Serve smoke (DESIGN.md §11): greedy-decode the golden fixture artifact
# — a tiny, committed, byte-reproducible packed artifact — through `rsq
# generate` and assert the token output is non-empty and identical
# across two runs (the serving layer's determinism contract). Fully
# host-side: needs no AOT artifact set and no PJRT.
echo "run-tests: serve smoke (rsq generate on tests/data/artifact_ok)"
smoke_log="$(mktemp)"
smoke() {
    cargo run --release --quiet -- generate \
        --artifact tests/data/artifact_ok --prompt 1,2 --max-new 5 2>"${smoke_log}"
}
# || disarms set -e so a decode failure prints its captured stderr
# instead of silently killing the script at the assignment
out1="$(smoke)" || {
    echo "run-tests: FAIL — serve smoke (rsq generate) exited non-zero:" >&2
    cat "${smoke_log}" >&2
    exit 1
}
out2="$(smoke)" || {
    echo "run-tests: FAIL — serve smoke second run exited non-zero:" >&2
    cat "${smoke_log}" >&2
    exit 1
}
rm -f "${smoke_log}"
if [ -z "${out1}" ]; then
    echo "run-tests: FAIL — serve smoke produced no output" >&2
    exit 1
fi
# herestring, not printf|grep: under pipefail an early grep -q match can
# SIGPIPE the printf and flake a passing check (see check-docs.sh)
if ! grep -q '^generated' <<< "${out1}"; then
    echo "run-tests: FAIL — serve smoke output has no 'generated' line:" >&2
    printf '%s\n' "${out1}" >&2
    exit 1
fi
if [ "${out1}" != "${out2}" ]; then
    echo "run-tests: FAIL — serve smoke output is not deterministic across runs" >&2
    printf 'run 1:\n%s\nrun 2:\n%s\n' "${out1}" "${out2}" >&2
    exit 1
fi
echo "run-tests: serve smoke OK"
echo "run-tests: OK"
