#!/usr/bin/env bash
# Tier-1 verify (`cargo build --release && cargo test -q`), toolchain-gated
# the same way scripts/check-docs.sh gates its cargo half:
#
#   - no rust toolchain on PATH             -> skip with a notice
#   - no rust/Cargo.toml (the vendored xla  -> skip with a notice
#     crate set lives in the build image,
#     not in every checkout)
#   - CHECK_TESTS_SKIP_CARGO=1              -> skip (CI escape hatch)
#
# Hosted CI runners ship a toolchain but not the vendor set, so the gate
# keeps .github/workflows/tests.yml green there while still running the
# full suite anywhere the build image is available.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${CHECK_TESTS_SKIP_CARGO:-0}" = "1" ]; then
    echo "run-tests: NOTE — CHECK_TESTS_SKIP_CARGO=1, skipping cargo build/test" >&2
    exit 0
fi
if ! command -v cargo >/dev/null 2>&1; then
    echo "run-tests: NOTE — cargo not on PATH, skipping cargo build/test" >&2
    exit 0
fi
if [ ! -f rust/Cargo.toml ]; then
    echo "run-tests: NOTE — rust/Cargo.toml absent (vendored crate set not in this checkout), skipping cargo build/test" >&2
    exit 0
fi

cd rust
echo "run-tests: cargo build --release"
cargo build --release
echo "run-tests: cargo test -q"
cargo test -q
echo "run-tests: OK"
