#!/usr/bin/env bash
# Docs gate, wired into the verify flow next to tier-1
# (`cargo build --release && cargo test -q`):
#
#   1. every "DESIGN.md §<section>" reference in the sources resolves to a
#      real DESIGN.md heading (no toolchain needed);
#   2. the numbered DESIGN.md sections the sources lean on exist, and the
#      scheduler-refactor docs track the code (quant/sched + the windowed
#      Pool primitives must be documented in §5);
#   3. rustdoc builds clean with warnings denied;
#   4. the tree is rustfmt-clean.
#
# Steps 3-4 are skipped with a notice when no rust toolchain is on PATH
# (the toolchain lives in the build image, not every checkout), or when
# CHECK_DOCS_SKIP_CARGO=1 — hosted CI runners ship a toolchain but not the
# vendored xla crate set, so only the toolchain-free checks can run there.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0

# --- 1. DESIGN.md existence + section references ---------------------------
if [ ! -f DESIGN.md ]; then
    echo "check-docs: FAIL — sources reference DESIGN.md but it does not exist" >&2
    exit 1
fi

# Collect §Name / §N tokens that appear next to a DESIGN.md mention.
refs=$(grep -rhoE 'DESIGN\.md[^a-zA-Z0-9§]*§[A-Za-z0-9-]+' \
        rust/src rust/benches rust/tests python examples 2>/dev/null \
        | grep -oE '§[A-Za-z0-9-]+' | sort -u || true)
for ref in $refs; do
    sec="${ref#§}"
    if ! grep -qiE "^## .*${sec}" DESIGN.md; then
        echo "check-docs: FAIL — source reference \"DESIGN.md ${ref}\" has no matching '## … ${sec}' heading" >&2
        fail=1
    fi
done

# Quoted-section spelling: see DESIGN.md "Substitutions"
quoted=$(grep -rhoE 'DESIGN\.md "[A-Za-z-]+"' \
        rust/src rust/benches rust/tests python examples 2>/dev/null \
        | grep -oE '"[A-Za-z-]+"' | tr -d '"' | sort -u || true)
for sec in $quoted; do
    if ! grep -qiE "^## .*${sec}" DESIGN.md; then
        echo "check-docs: FAIL — source reference 'DESIGN.md \"${sec}\"' has no matching heading" >&2
        fail=1
    fi
done

[ "$fail" -eq 0 ] && echo "check-docs: DESIGN.md section references OK"

# --- 2. required sections + scheduler-doc consistency ----------------------
# The stable section numbers the source tree points at (1-8). A renumbering
# that orphans one of these breaks every "DESIGN.md §N" comment at once.
for sec in 1 2 3 4 5 6 7 8; do
    if ! grep -qE "^## ${sec}\." DESIGN.md; then
        echo "check-docs: FAIL — DESIGN.md is missing required section '## ${sec}.'" >&2
        fail=1
    fi
done

# The staged-scheduler refactor: if the quant/sched subsystem exists, §5
# must document it and the Pool windowed-dispatch primitives it rests on.
if [ -d rust/src/quant/sched ]; then
    for needle in "quant/sched" "run_windowed" "update_windowed" "pipelined"; do
        if ! grep -q "${needle}" DESIGN.md; then
            echo "check-docs: FAIL — rust/src/quant/sched exists but DESIGN.md never mentions \"${needle}\"" >&2
            fail=1
        fi
    done
fi

# The artifact subsystem: if quant/artifact exists, §9 must document the
# on-disk format (naming its version), the subsystem path, and every
# Hessian-cache key field — the key derivation IS the cache contract, so
# the docs and the code must not drift apart. Needles are grepped inside
# the §9 body only: words like "strategy" and "corpus" appear all over
# the rest of DESIGN.md, and a whole-file grep would never notice them
# being dropped from the section this gate protects.
if [ -d rust/src/quant/artifact ]; then
    if ! grep -qE "^## 9\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/quant/artifact exists but DESIGN.md has no '## 9.' section" >&2
        fail=1
    fi
    sec9=$(awk '/^## 9\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    # herestrings, not printf|grep: under pipefail, `grep -q` exiting at
    # an early match can SIGPIPE the printf and fail a passing check
    for needle in "quant/artifact" "artifact format version 1" "hess-cache" \
                  "rot_seed" "strategy" "corpus" "model parameters" \
                  "bit-packed" "artifact.txt" "weights.bin"; do
        if ! grep -q "${needle}" <<< "${sec9}"; then
            echo "check-docs: FAIL — DESIGN.md §9 never mentions \"${needle}\" (artifact/cache contract drift)" >&2
            fail=1
        fi
    done
fi

# The host kernel layer: if tensor/kernels exists, §10 must document it —
# the tiling scheme, the fused-transpose entry points, and the row-block
# determinism argument are the contract every refactored call site leans
# on, so the docs must name them.
if [ -d rust/src/tensor/kernels ]; then
    if ! grep -qE "^## 10\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/tensor/kernels exists but DESIGN.md has no '## 10.' section" >&2
        fail=1
    fi
    sec10=$(awk '/^## 10\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    for needle in "tensor/kernels" "gemm_at" "gemm_bt" "syrk" "row block" \
                  "cholesky_lower" "tri_inv_lower" "zero-skip" "reference kernel"; do
        if ! grep -qi "${needle}" <<< "${sec10}"; then
            echo "check-docs: FAIL — DESIGN.md §10 never mentions \"${needle}\" (host-kernel contract drift)" >&2
            fail=1
        fi
    done
fi

# The serving layer: if rust/src/serve exists, §11 must document the
# fused dequantize kernels, the KV-cache layout, the continuous-batching
# semantics, and the determinism guarantee — the contract `rsq generate`
# / `rsq serve-bench` and the serve tests lean on. Needles are grepped
# inside the §11 body only, same scoping rationale as §9.
if [ -d rust/src/serve ]; then
    if ! grep -qE "^## 11\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/serve exists but DESIGN.md has no '## 11.' section" >&2
        fail=1
    fi
    sec11=$(awk '/^## 11\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    for needle in "tensor/kernels/gemv" "deq_gemm_bt" "deq_gemv" "KV cache" \
                  "continuous-batching" "paged" "padded-free" "deadline" \
                  "token-identical" "rsq generate" "serve-bench" "tokens/s"; do
        if ! grep -qi "${needle}" <<< "${sec11}"; then
            echo "check-docs: FAIL — DESIGN.md §11 never mentions \"${needle}\" (serving-layer contract drift)" >&2
            fail=1
        fi
    done
fi

# The quantized KV cache: if serve/kvq.rs exists, §12 must document the
# codec formats, the packed page layout with its per-row scale state, the
# fused decode path, the exactness-oracle policy behind --kv-bits 32, and
# the divergence metric the serve-bench kv axis reports. Needles are
# grepped inside the §12 body only, same scoping rationale as §9.
if [ -f rust/src/serve/kvq.rs ]; then
    if ! grep -qE "^## 12\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/serve/kvq.rs exists but DESIGN.md has no '## 12.' section" >&2
        fail=1
    fi
    sec12=$(awk '/^## 12\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    for needle in "serve/kvq" "kv-bits" "Linear8" "8-bit linear" \
                  "log-distributed" "quantize-on-write" "scale state" \
                  "attn_row" "exactness oracle" "token_divergence" \
                  "resident-bytes"; do
        if ! grep -qi "${needle}" <<< "${sec12}"; then
            echo "check-docs: FAIL — DESIGN.md §12 never mentions \"${needle}\" (KV-codec contract drift)" >&2
            fail=1
        fi
    done
fi

# The kernel backend dispatch: if tensor/kernels/backend.rs exists, §13
# must document the backend trait, the --backend flag, runtime feature
# detection, and the tolerance policy that separates the simd path from
# the bit-exact reference oracle. Needles are grepped inside the §13
# body only, same scoping rationale as §9; `grep -q --` so needles that
# begin with a dash (--backend) are not parsed as grep options.
if [ -f rust/src/tensor/kernels/backend.rs ]; then
    if ! grep -qE "^## 13\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/tensor/kernels/backend.rs exists but DESIGN.md has no '## 13.' section" >&2
        fail=1
    fi
    sec13=$(awk '/^## 13\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    for needle in "kernels/backend" "--backend" "is_x86_feature_detected" \
                  "AVX2" "FMA" "tolerance" "ULP" "bit-exact" \
                  "reassociat" "par_rows_into" "POOL_MIN_WORK" \
                  "zero-skip" "prop_kernels"; do
        if ! grep -qi -- "${needle}" <<< "${sec13}"; then
            echo "check-docs: FAIL — DESIGN.md §13 never mentions \"${needle}\" (backend-dispatch contract drift)" >&2
            fail=1
        fi
    done
fi

# The mixed-precision allocator: if quant/alloc.rs exists, §14 must
# document the budget flags, the two-phase proxy flow, the greedy solve
# with its determinism tie-break, and the artifact provenance keys —
# the contract integration_alloc.rs and the frontier sweep lean on.
# Needles are grepped inside the §14 body only, same scoping rationale
# as §9; `grep -qi --` so dash-leading needles are not parsed as options.
if [ -f rust/src/quant/alloc.rs ]; then
    if ! grep -qE "^## 14\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/quant/alloc.rs exists but DESIGN.md has no '## 14.' section" >&2
        fail=1
    fi
    sec14=$(awk '/^## 14\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    for needle in "quant/alloc" "--avg-bits" "--budget-bytes" "PACK_BITS" \
                  "proxy pass" "greedy" "gptq_with_factor" "tie" \
                  "Hessian-cache key" "avg_bits" "frontier" \
                  "expected_len" "non-canonical" "Args::conflict"; do
        if ! grep -qi -- "${needle}" <<< "${sec14}"; then
            echo "check-docs: FAIL — DESIGN.md §14 never mentions \"${needle}\" (mixed-precision contract drift)" >&2
            fail=1
        fi
    done
fi

# The prefix cache + speculative decoding layer: if serve/prefix.rs
# exists, §15 must document the content-addressed keying, the
# donate/adopt/refcount/pressure lifecycle, the step_many verify path
# with its row-exactness gate, and the reporting surface — the contract
# the prefix smoke, bench_serve §15 section, and prop_serve pins lean
# on. Needles are grepped inside the §15 body only, same scoping
# rationale as §9; `grep -qi --` so dash-leading needles are not parsed
# as options.
if [ -f rust/src/serve/prefix.rs ]; then
    if ! grep -qE "^## 15\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/serve/prefix.rs exists but DESIGN.md has no '## 15.' section" >&2
        fail=1
    fi
    sec15=$(awk '/^## 15\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    for needle in "serve/prefix" "--prefix-cache" "content_key" "FNV" \
                  "share_prefix" "try_adopt" "prefill_skipped" \
                  "oldest-first" "--spec-k" "--draft-artifact" \
                  "step_many" "fused_rows_exact" "draft_accepted" \
                  "token-identical" "prop_serve"; do
        if ! grep -qi -- "${needle}" <<< "${sec15}"; then
            echo "check-docs: FAIL — DESIGN.md §15 never mentions \"${needle}\" (prefix/speculation contract drift)" >&2
            fail=1
        fi
    done
fi

# The observability subsystem: if rust/src/obs exists, §16 must document
# the span tracer and its Chrome trace-event export, the metrics
# registry with its log2 histograms, the CLI flags, the log facade, the
# validator, and the zero-bit-drift contract the trace smokes pin.
# Needles are grepped inside the §16 body only, same scoping rationale
# as §9; `grep -qi --` so dash-leading needles are not parsed as options.
if [ -d rust/src/obs ]; then
    if ! grep -qE "^## 16\." DESIGN.md; then
        echo "check-docs: FAIL — rust/src/obs exists but DESIGN.md has no '## 16.' section" >&2
        fail=1
    fi
    sec16=$(awk '/^## 16\./{f=1; print; next} /^## /{f=0} f' DESIGN.md)
    for needle in "obs/trace" "obs/metrics" "--trace" "--metrics" \
                  "Chrome trace-event" "thread_name" "tid" "thread_local" \
                  "log2" "percentile" "byte-identical" "obs_info" \
                  "obs_debug" "validate_trace" "deadline_missed"; do
        if ! grep -qi -- "${needle}" <<< "${sec16}"; then
            echo "check-docs: FAIL — DESIGN.md §16 never mentions \"${needle}\" (observability contract drift)" >&2
            fail=1
        fi
    done
fi

[ "$fail" -eq 0 ] && echo "check-docs: required sections + scheduler/artifact/kernel/serve/backend/alloc/prefix/obs docs OK"

# --- 3+4. rustdoc + rustfmt ------------------------------------------------
if [ "${CHECK_DOCS_SKIP_CARGO:-0}" = "1" ]; then
    echo "check-docs: NOTE — CHECK_DOCS_SKIP_CARGO=1, skipping rustdoc/fmt checks" >&2
elif command -v cargo >/dev/null 2>&1; then
    echo "check-docs: cargo doc --no-deps (warnings denied)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet || fail=1
    echo "check-docs: cargo fmt --check"
    cargo fmt --check || fail=1
else
    echo "check-docs: NOTE — cargo not on PATH, skipping rustdoc/fmt checks" >&2
fi

if [ "$fail" -ne 0 ]; then
    echo "check-docs: FAILED" >&2
    exit 1
fi
echo "check-docs: OK"
