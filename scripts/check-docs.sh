#!/usr/bin/env bash
# Docs gate, wired into the verify flow next to tier-1
# (`cargo build --release && cargo test -q`):
#
#   1. every "DESIGN.md §<section>" reference in the sources resolves to a
#      real DESIGN.md heading (no toolchain needed);
#   2. rustdoc builds clean with warnings denied;
#   3. the tree is rustfmt-clean.
#
# Steps 2-3 are skipped with a notice when no rust toolchain is on PATH
# (the toolchain lives in the build image, not every checkout).
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0

# --- 1. DESIGN.md existence + section references ---------------------------
if [ ! -f DESIGN.md ]; then
    echo "check-docs: FAIL — sources reference DESIGN.md but it does not exist" >&2
    exit 1
fi

# Collect §Name / §N tokens that appear next to a DESIGN.md mention.
refs=$(grep -rhoE 'DESIGN\.md[^a-zA-Z0-9§]*§[A-Za-z0-9-]+' \
        rust/src rust/benches rust/tests python examples 2>/dev/null \
        | grep -oE '§[A-Za-z0-9-]+' | sort -u || true)
for ref in $refs; do
    sec="${ref#§}"
    if ! grep -qiE "^## .*${sec}" DESIGN.md; then
        echo "check-docs: FAIL — source reference \"DESIGN.md ${ref}\" has no matching '## … ${sec}' heading" >&2
        fail=1
    fi
done

# Quoted-section spelling: see DESIGN.md "Substitutions"
quoted=$(grep -rhoE 'DESIGN\.md "[A-Za-z-]+"' \
        rust/src rust/benches rust/tests python examples 2>/dev/null \
        | grep -oE '"[A-Za-z-]+"' | tr -d '"' | sort -u || true)
for sec in $quoted; do
    if ! grep -qiE "^## .*${sec}" DESIGN.md; then
        echo "check-docs: FAIL — source reference 'DESIGN.md \"${sec}\"' has no matching heading" >&2
        fail=1
    fi
done

[ "$fail" -eq 0 ] && echo "check-docs: DESIGN.md section references OK"

# --- 2+3. rustdoc + rustfmt ------------------------------------------------
if command -v cargo >/dev/null 2>&1; then
    echo "check-docs: cargo doc --no-deps (warnings denied)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet || fail=1
    echo "check-docs: cargo fmt --check"
    cargo fmt --check || fail=1
else
    echo "check-docs: NOTE — cargo not on PATH, skipping rustdoc/fmt checks" >&2
fi

if [ "$fail" -ne 0 ]; then
    echo "check-docs: FAILED" >&2
    exit 1
fi
echo "check-docs: OK"
