#!/usr/bin/env bash
# fmt + clippy gate, toolchain-gated the same way scripts/run-tests.sh
# gates tier-1:
#
#   - no rust toolchain on PATH             -> skip with a notice
#   - no rust/Cargo.toml (the vendored xla  -> skip with a notice
#     crate set lives in the build image,
#     not in every checkout — even
#     `cargo fmt` needs the manifest)
#   - CHECK_LINT_SKIP_CARGO=1               -> skip (CI escape hatch)
#
# Wherever the build image's toolchain + vendor set are present this
# enforces `cargo fmt --check` and `cargo clippy --all-targets
# -- -D warnings`; hosted CI runners skip with a notice.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${CHECK_LINT_SKIP_CARGO:-0}" = "1" ]; then
    echo "lint: NOTE — CHECK_LINT_SKIP_CARGO=1, skipping fmt/clippy" >&2
    exit 0
fi
if ! command -v cargo >/dev/null 2>&1; then
    echo "lint: NOTE — cargo not on PATH, skipping fmt/clippy" >&2
    exit 0
fi
if [ ! -f rust/Cargo.toml ]; then
    echo "lint: NOTE — rust/Cargo.toml absent (vendored crate set not in this checkout), skipping fmt/clippy" >&2
    exit 0
fi

cd rust
echo "lint: cargo fmt --check"
cargo fmt --check
echo "lint: cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings
echo "lint: OK"
