#!/usr/bin/env python3
"""Validate `rsq --trace` / `rsq --metrics` output (DESIGN.md §16).

Stdlib-only on purpose: CI's trace smoke (scripts/run-tests.sh) runs this
on the files a traced `rsq generate` run writes, so it must work on any
host with a bare python3 — no rust toolchain, no third-party packages.

Trace files are Chrome trace-event JSON: a root object whose
``traceEvents`` array holds complete spans (``ph: "X"``), instants
(``ph: "i"``) and ``thread_name`` metadata rows (``ph: "M"``), all under
``pid`` 1. The exporter sorts events by ``(tid, ts)``, so timestamps are
checked monotone **per tid**. Metrics files are the run record
``{cmd, counters, gauges, hists}`` with per-histogram summaries whose
percentiles must be ordered.

Usage:
    validate_trace.py --trace t.json [--require sched.pass_a ...]
    validate_trace.py --metrics m.json
    validate_trace.py --trace t.json --metrics m.json

Exit status 0 when every check passes, 1 otherwise (problems on stderr).
"""

import argparse
import json
import sys

#: phases the exporter emits; anything else is a malformed row
KNOWN_PHASES = ("X", "i", "M")

#: per-histogram summary fields the metrics record must carry
HIST_FIELDS = ("count", "min", "max", "mean", "p50", "p90", "p95", "p99")


def _num(v):
    """True for a JSON number (bool is int in python — excluded)."""
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_trace(doc, require=()):
    """Return a list of problems with a parsed Chrome trace document."""
    errs = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["trace root must be an object with a 'traceEvents' array"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not an array"]
    names = set()
    event_tids = set()
    named_tids = set()
    last_ts = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            errs.append(f"event {i}: unexpected ph {ph!r} (want one of {KNOWN_PHASES})")
            continue
        if e.get("pid") != 1:
            errs.append(f"event {i}: pid {e.get('pid')!r} != 1")
        tid = e.get("tid")
        if not isinstance(tid, int) or isinstance(tid, bool) or tid < 0:
            errs.append(f"event {i}: tid {tid!r} is not a non-negative integer")
            continue
        if ph == "M":
            if e.get("name") == "thread_name":
                named_tids.add(tid)
            continue
        name = e.get("name")
        if not isinstance(name, str) or not name:
            errs.append(f"event {i}: missing span name")
        else:
            names.add(name)
        event_tids.add(tid)
        ts = e.get("ts")
        if not _num(ts) or ts < 0:
            errs.append(f"event {i}: ts {ts!r} is not a non-negative number")
            continue
        if ph == "X":
            dur = e.get("dur")
            if not _num(dur) or dur < 0:
                errs.append(f"event {i}: dur {dur!r} is not a non-negative number")
        if ts < last_ts.get(tid, 0):
            errs.append(
                f"event {i}: ts {ts} goes backwards on tid {tid} "
                f"(previous {last_ts[tid]})"
            )
        last_ts[tid] = ts
    for tid in sorted(event_tids - named_tids):
        errs.append(f"tid {tid} has events but no thread_name metadata row")
    for want in require:
        if want not in names:
            errs.append(f"required span {want!r} missing (have {len(names)} names)")
    return errs


def validate_metrics(doc):
    """Return a list of problems with a parsed metrics run record."""
    errs = []
    if not isinstance(doc, dict):
        return ["metrics root is not an object"]
    for key in ("cmd", "counters", "gauges", "hists"):
        if key not in doc:
            errs.append(f"metrics record missing {key!r}")
    for key in ("counters", "gauges"):
        sec = doc.get(key, {})
        if not isinstance(sec, dict):
            errs.append(f"{key!r} is not an object")
            continue
        for k, v in sec.items():
            if not _num(v):
                errs.append(f"{key}[{k!r}]: value {v!r} is not a number")
    hists = doc.get("hists", {})
    if not isinstance(hists, dict):
        errs.append("'hists' is not an object")
        return errs
    for k, h in hists.items():
        if not isinstance(h, dict):
            errs.append(f"hists[{k!r}]: not an object")
            continue
        bad = [f for f in HIST_FIELDS if not _num(h.get(f))]
        if bad:
            errs.append(f"hists[{k!r}]: missing/non-numeric fields {bad}")
            continue
        if not (h["p50"] <= h["p90"] <= h["p95"] <= h["p99"]):
            errs.append(f"hists[{k!r}]: percentiles out of order: {h}")
        if h["min"] > h["max"]:
            errs.append(f"hists[{k!r}]: min {h['min']} > max {h['max']}")
        if h["count"] > 0 and not (h["min"] <= h["p50"] and h["p99"] <= h["max"]):
            errs.append(f"hists[{k!r}]: percentiles outside [min, max]: {h}")
    return errs


def _load(path):
    with open(path) as f:
        return json.load(f)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", help="Chrome trace-event JSON to validate")
    ap.add_argument("--metrics", help="metrics run record JSON to validate")
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="NAME",
        help="span name that must appear in the trace (repeatable)",
    )
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to validate: pass --trace and/or --metrics")
    problems = []
    if args.trace:
        try:
            problems += [f"trace: {p}" for p in validate_trace(_load(args.trace), args.require)]
        except (OSError, ValueError) as e:
            problems.append(f"trace: cannot load {args.trace}: {e}")
    if args.metrics:
        try:
            problems += [f"metrics: {p}" for p in validate_metrics(_load(args.metrics))]
        except (OSError, ValueError) as e:
            problems.append(f"metrics: cannot load {args.metrics}: {e}")
    if problems:
        for p in problems:
            print(f"validate_trace: {p}", file=sys.stderr)
        return 1
    checked = [p for p in (args.trace, args.metrics) if p]
    print(f"validate_trace: OK ({', '.join(checked)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
