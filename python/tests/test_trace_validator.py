"""Tests for the toolchain-free trace/metrics validator
(scripts/validate_trace.py) on synthetic good and bad documents — the
same checks CI's trace smoke runs on real `rsq --trace` output."""

import importlib.util
import json
import os
import subprocess
import sys

_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "scripts", "validate_trace.py")

spec = importlib.util.spec_from_file_location("validate_trace", _PATH)
vt = importlib.util.module_from_spec(spec)
spec.loader.exec_module(vt)


def _meta(tid, name="worker"):
    return {
        "ph": "M",
        "name": "thread_name",
        "pid": 1,
        "tid": tid,
        "args": {"name": name},
    }


def _span(name, ts, dur, tid=0):
    return {"name": name, "cat": "t", "ph": "X", "ts": ts, "dur": dur, "pid": 1, "tid": tid}


def _instant(name, ts, tid=0):
    return {"name": name, "cat": "t", "ph": "i", "s": "t", "ts": ts, "pid": 1, "tid": tid}


def _good_trace():
    return {
        "traceEvents": [
            _meta(0, "main"),
            _meta(1),
            _span("sched.pass_a", 0, 100),
            _span("sched.pass_b", 100, 50),
            _instant("hess_cache.miss", 120),
            _span("pool.task", 5, 10, tid=1),
        ],
        "displayTimeUnit": "ms",
    }


def _good_metrics():
    return {
        "cmd": "quantize",
        "counters": {"hess_cache.miss": 2},
        "gauges": {"quant.layer_err.l000": 0.25},
        "hists": {
            "pool.task_wait_us": {
                "count": 4,
                "min": 1,
                "max": 90,
                "mean": 30.0,
                "p50": 16,
                "p90": 63,
                "p95": 90,
                "p99": 90,
            }
        },
    }


def test_good_trace_passes():
    assert vt.validate_trace(_good_trace()) == []


def test_required_span_names_enforced():
    assert vt.validate_trace(_good_trace(), require=["sched.pass_a"]) == []
    errs = vt.validate_trace(_good_trace(), require=["serve.decode"])
    assert any("serve.decode" in e for e in errs)


def test_trace_rejects_bad_pid_and_tid():
    doc = _good_trace()
    doc["traceEvents"][2]["pid"] = 7
    assert any("pid" in e for e in vt.validate_trace(doc))
    doc = _good_trace()
    doc["traceEvents"][2]["tid"] = -1
    assert any("tid" in e for e in vt.validate_trace(doc))


def test_trace_rejects_backwards_timestamps_per_tid():
    doc = _good_trace()
    doc["traceEvents"].append(_span("late", 10, 1))  # tid 0 was already at ts 120
    errs = vt.validate_trace(doc)
    assert any("backwards" in e for e in errs)
    # a fresh tid restarting at a small ts is fine (per-tid monotonicity);
    # it only needs its own thread_name row
    doc = _good_trace()
    doc["traceEvents"] += [_meta(2), _span("other-row", 3, 1, tid=2)]
    assert vt.validate_trace(doc) == []


def test_trace_rejects_missing_thread_name_row():
    doc = _good_trace()
    doc["traceEvents"] = [e for e in doc["traceEvents"] if e["ph"] != "M" or e["tid"] != 1]
    errs = vt.validate_trace(doc)
    assert any("thread_name" in e for e in errs)


def test_trace_rejects_malformed_root_and_rows():
    assert vt.validate_trace([]) != []
    assert vt.validate_trace({"traceEvents": 3}) != []
    doc = _good_trace()
    doc["traceEvents"].append({"ph": "Q", "pid": 1, "tid": 0})
    assert any("ph" in e for e in vt.validate_trace(doc))
    doc = _good_trace()
    doc["traceEvents"].append(_span("nodur", 130, 1))
    del doc["traceEvents"][-1]["dur"]
    assert any("dur" in e for e in vt.validate_trace(doc))


def test_good_metrics_pass():
    assert vt.validate_metrics(_good_metrics()) == []


def test_metrics_reject_missing_sections_and_disorder():
    doc = _good_metrics()
    del doc["counters"]
    assert any("counters" in e for e in vt.validate_metrics(doc))
    doc = _good_metrics()
    doc["hists"]["pool.task_wait_us"]["p50"] = 1000  # > p90
    assert any("out of order" in e for e in vt.validate_metrics(doc))
    doc = _good_metrics()
    del doc["hists"]["pool.task_wait_us"]["p95"]
    assert any("p95" in e for e in vt.validate_metrics(doc))


def test_cli_round_trip(tmp_path):
    tr = tmp_path / "t.json"
    mt = tmp_path / "m.json"
    tr.write_text(json.dumps(_good_trace()))
    mt.write_text(json.dumps(_good_metrics()))
    ok = subprocess.run(
        [
            sys.executable,
            _PATH,
            "--trace",
            str(tr),
            "--metrics",
            str(mt),
            "--require",
            "sched.pass_a",
        ],
        capture_output=True,
        text=True,
    )
    assert ok.returncode == 0, ok.stderr
    bad = subprocess.run(
        [sys.executable, _PATH, "--trace", str(tr), "--require", "serve.decode"],
        capture_output=True,
        text=True,
    )
    assert bad.returncode == 1
    assert "serve.decode" in bad.stderr
