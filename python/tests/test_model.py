"""L2 model correctness: shapes, NLL semantics, gain fusion, and the
rotation computational-invariance property (paper Sec. 3.2) that the whole
Rotate step rests on."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.configs import CONFIGS, TINY


def _params(cfg, seed=0, gains=True):
    rng = np.random.default_rng(seed)
    flat = []
    for name in cfg.param_names():
        shape = cfg.param_shape(name)
        if len(shape) == 1:
            g = np.ones(shape, np.float32)
            if gains:
                g += 0.1 * rng.normal(size=shape).astype(np.float32)
            flat.append(jnp.asarray(g))
        else:
            scale = 0.4 / np.sqrt(shape[1])
            flat.append(jnp.asarray(
                scale * rng.normal(size=shape).astype(np.float32)))
    return flat


def _tokens(cfg, seed=0, t=None):
    rng = np.random.default_rng(seed + 1000)
    return jnp.asarray(rng.integers(
        0, cfg.vocab, size=(cfg.batch, t or cfg.max_seq)).astype(np.int32))


def _hadamard(d):
    h = np.array([[1.0]], np.float32)
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    rng = np.random.default_rng(7)
    s = rng.choice([-1.0, 1.0], size=d).astype(np.float32)
    return jnp.asarray((h / np.sqrt(d)) * s[None, :])


def test_param_ordering_contract():
    cfg = TINY
    names = cfg.param_names()
    assert names[0] == "emb" and names[1] == "pos"
    assert names[-2] == "gf" and names[-1] == "head"
    assert len(names) == 2 + 9 * cfg.layers + 2
    assert names[2] == "l0.g1" and names[10] == "l0.wdown"


def test_forward_shapes():
    cfg = TINY
    flat = _params(cfg)
    tokens = _tokens(cfg, t=32)
    h = M.forward(cfg, tokens, flat)
    assert h.shape == (cfg.batch, 32, cfg.d)
    nll = M.lm_nll(cfg, tokens, flat)
    assert nll.shape == (cfg.batch, 32)
    ll = M.logits_last(cfg, tokens, flat)
    assert ll.shape == (cfg.batch, cfg.vocab)


def test_nll_semantics():
    """nll[:, t] must be -log p(tok[t+1]); last column zero-padded."""
    cfg = TINY
    flat = _params(cfg)
    tokens = _tokens(cfg, t=16)
    nll = np.asarray(M.lm_nll(cfg, tokens, flat))
    assert (nll[:, :-1] > 0).all()
    np.testing.assert_array_equal(nll[:, -1], 0.0)
    # uniform-ish at random init: mean nll close to log(V)
    assert abs(nll[:, :-1].mean() - np.log(cfg.vocab)) < 1.5


def test_logits_last_is_log_softmax():
    cfg = TINY
    flat = _params(cfg)
    ll = np.asarray(M.logits_last(cfg, _tokens(cfg, t=16), flat))
    np.testing.assert_allclose(np.exp(ll).sum(axis=1), 1.0, rtol=1e-4)


def test_layer_fwd_capture_outputs():
    cfg = TINY
    flat = _params(cfg)
    tokens = _tokens(cfg, t=32)
    z = M.embed(cfg, tokens, flat[0], flat[1])
    lp = M.split_layer_params(cfg, flat, 0)
    outs = M.layer_fwd(cfg, z, lp, capture=True)
    z2, xa, xo, xf, xd, attn_con, act_norm, act_diff, token_sim = outs
    b, t, d, ff = cfg.batch, 32, cfg.d, cfg.ff
    assert z2.shape == (b, t, d) and xd.shape == (b, t, ff)
    for s in (attn_con, act_norm, act_diff, token_sim):
        assert s.shape == (b, t)
    # capture=False must produce the identical hidden state
    z2b = M.layer_fwd(cfg, z, lp, capture=False)
    np.testing.assert_allclose(z2, z2b, rtol=1e-5, atol=1e-5)
    # score sanity: attn mass sums to heads*T per sample
    np.testing.assert_allclose(
        np.asarray(attn_con).sum(axis=1), cfg.heads * t, rtol=1e-4)
    assert (np.asarray(act_norm) > 0).all()
    assert (np.asarray(act_diff) <= 0).all()


def test_gain_fusion_preserves_function():
    cfg = TINY
    flat = _params(cfg, gains=True)
    tokens = _tokens(cfg, t=32)
    fused = M.fuse_gains(cfg, flat)
    for l in range(cfg.layers):
        base = 2 + l * 9
        np.testing.assert_array_equal(np.asarray(fused[base]), 1.0)
        np.testing.assert_array_equal(np.asarray(fused[base + 5]), 1.0)
    np.testing.assert_array_equal(np.asarray(fused[-2]), 1.0)
    a = M.lm_nll(cfg, tokens, flat)
    b = M.lm_nll(cfg, tokens, fused)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_rotation_invariance():
    """The core QuaRot property: rotated params compute the same function."""
    cfg = TINY
    flat = M.fuse_gains(cfg, _params(cfg, gains=True))
    tokens = _tokens(cfg, t=32)
    qmat = _hadamard(cfg.d)
    np.testing.assert_allclose(
        np.asarray(qmat @ qmat.T), np.eye(cfg.d), atol=1e-5)
    rot = M.rotate_params(cfg, flat, qmat)
    a = np.asarray(M.lm_nll(cfg, tokens, flat))
    b = np.asarray(M.lm_nll(cfg, tokens, rot))
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_rotation_without_fusion_breaks():
    """Sanity counter-test: with non-trivial gains, rotation is NOT
    function-preserving — this is exactly why the paper fuses LayerNorm."""
    cfg = TINY
    flat = _params(cfg, gains=True)  # not fused
    tokens = _tokens(cfg, t=32)
    rot = M.rotate_params(cfg, flat, _hadamard(cfg.d))
    a = np.asarray(M.lm_nll(cfg, tokens, flat))
    b = np.asarray(M.lm_nll(cfg, tokens, rot))
    assert np.abs(a[:, :-1] - b[:, :-1]).max() > 1e-3


def test_rotation_gaussianizes_outliers():
    """Rotation shrinks per-row max/rms kurtosis of an outlier-injected
    weight — the mechanism that makes QuaRot/RSQ beat plain GPTQ."""
    cfg = TINY
    rng = np.random.default_rng(3)
    w = rng.normal(size=(cfg.d, cfg.d)).astype(np.float32)
    idx = rng.integers(0, w.size, size=20)
    w.flat[idx] += rng.choice([-8.0, 8.0], size=20).astype(np.float32)
    q = np.asarray(_hadamard(cfg.d))
    wr = w @ q
    ratio = lambda m: (np.abs(m).max(axis=1) / np.sqrt((m**2).mean(axis=1))).mean()
    assert ratio(wr) < ratio(w)


def test_train_step_reduces_loss():
    cfg = TINY
    flat = _params(cfg, seed=2)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]
    tokens = _tokens(cfg, seed=2, t=cfg.max_seq)
    losses = []
    for step in range(8):
        flat, m, v, loss = M.train_step(
            cfg, flat, m, v, tokens, jnp.float32(step), lr=3e-3)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", ["tiny", "small", "s1", "s2", "s3"])
def test_config_registry_consistency(name):
    cfg = CONFIGS[name]
    assert cfg.d % cfg.heads == 0
    assert cfg.d & (cfg.d - 1) == 0
    for n in cfg.param_names():
        assert len(cfg.param_shape(n)) in (1, 2)
