"""AOT pipeline smoke tests: manifest completeness and HLO-text hygiene
(no LAPACK/custom-call ops that the rust xla_extension 0.5.1 runtime cannot
resolve)."""

import os

import pytest

from compile import aot
from compile.configs import CONFIGS, TINY


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts") / "tiny"
    aot.build_config(TINY, str(out))
    return str(out)


def _manifest(path):
    with open(os.path.join(path, "manifest.txt")) as f:
        return f.read().splitlines()


def test_manifest_header(tiny_artifacts):
    lines = _manifest(tiny_artifacts)
    kv = dict(l.split("=", 1) for l in lines if "|" not in l)
    assert kv["config"] == "tiny"
    assert int(kv["d"]) == TINY.d
    assert int(kv["layers"]) == TINY.layers
    assert kv["seq_lens"] == ",".join(str(t) for t in TINY.seq_lens)


def test_manifest_params_match_config(tiny_artifacts):
    lines = [l for l in _manifest(tiny_artifacts) if l.startswith("param=")]
    names = [l.split("|")[0].split("=")[1] for l in lines]
    assert names == TINY.param_names()
    for l, n in zip(lines, names):
        shape = l.split("shape=")[1]
        want = "x".join(str(d) for d in TINY.param_shape(n))
        assert shape == want


def test_all_modules_emitted(tiny_artifacts):
    lines = [l for l in _manifest(tiny_artifacts) if l.startswith("module=")]
    names = {l.split("|")[0].split("=")[1] for l in lines}
    for t in TINY.seq_lens:
        for stem in ("embed", "layer_fwd", "hess_d", "hess_ff", "lm_nll",
                     "logits_last"):
            assert f"{stem}_t{t}" in names
    d, ff = TINY.d, TINY.ff
    for (o, i) in {(d, d), (ff, d), (d, ff)}:
        for stem in ("gptq", "rtn", "ldlq"):
            assert f"{stem}_{o}x{i}" in names
    assert "train_step" in names
    # every module's HLO file exists and is non-trivial
    for l in lines:
        fname = [p for p in l.split("|") if p.startswith("file=")][0][5:]
        p = os.path.join(tiny_artifacts, fname)
        assert os.path.getsize(p) > 500


def test_no_custom_calls(tiny_artifacts):
    """custom-call targets (LAPACK etc.) would crash the rust runtime."""
    for f in os.listdir(tiny_artifacts):
        if not f.endswith(".hlo.txt"):
            continue
        with open(os.path.join(tiny_artifacts, f)) as fh:
            text = fh.read()
        assert "custom-call" not in text, f
        assert "ENTRY" in text, f


def test_module_arity_recorded(tiny_artifacts):
    lines = [l for l in _manifest(tiny_artifacts) if l.startswith("module=")]
    by_name = {l.split("|")[0].split("=")[1]: l for l in lines}
    n = len(TINY.param_names())
    layer = by_name[f"layer_fwd_t{TINY.seq_lens[0]}"]
    assert "nout=9" in layer
    train = by_name["train_step"]
    assert f"nout={3 * n + 1}" in train
    ins = [p for p in train.split("|") if p.startswith("in=")][0]
    assert len(ins.split(";")) == 3 * n + 2


def test_all_registered_configs_are_valid():
    for cfg in CONFIGS.values():
        assert cfg.seq_lens, cfg.name
        assert cfg.batch >= 1
