"""GPTQ / LDLQ solver correctness: hand-rolled linear algebra vs numpy, and
the optimality/ordering properties the paper's quantization step relies on."""

import numpy as np
import jax.numpy as jnp
import pytest

# property sweeps need hypothesis (python/requirements.txt); skip — not
# error — collection on images that ship without it, so the suite's
# collectable-test count stays honest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import quantizer as Q
from compile.kernels import ref

SET = dict(deadline=None, max_examples=10)


def _spd(d, seed, cond=None):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(d, d)).astype(np.float32)
    return a @ a.T + d * np.eye(d, dtype=np.float32)


def _hess(din, n, seed, rscale=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, din)).astype(np.float32)
    if rscale is not None:
        x = x * rscale[:, None]
    return 2.0 * x.T @ x


# --- linear algebra ----------------------------------------------------------

@settings(**SET)
@given(d=st.sampled_from([4, 16, 33]), seed=st.integers(0, 2**31))
def test_cholesky_matches_numpy(d, seed):
    a = _spd(d, seed)
    l = np.asarray(Q.cholesky_lower(jnp.asarray(a)))
    np.testing.assert_allclose(l, np.linalg.cholesky(a), rtol=2e-3, atol=2e-3)


@settings(**SET)
@given(d=st.sampled_from([4, 16, 33]), seed=st.integers(0, 2**31))
def test_tri_inv_lower(d, seed):
    a = _spd(d, seed)
    l = jnp.asarray(np.linalg.cholesky(a))
    li = np.asarray(Q.tri_inv_lower(l))
    np.testing.assert_allclose(li @ np.asarray(l), np.eye(d), atol=1e-4)
    assert np.allclose(np.triu(li, 1), 0.0)


def test_hinv_cholesky_upper_identity():
    d = 16
    h = _spd(d, 3)
    u = np.asarray(Q.hinv_cholesky_upper(jnp.asarray(h), jnp.float32(0.01)))
    hd = h + 0.01 * np.mean(np.diag(h)) * np.eye(d, dtype=np.float32)
    np.testing.assert_allclose(u.T @ u, np.linalg.inv(hd), atol=1e-4)
    assert np.allclose(np.tril(u, -1), 0.0)


def test_hinv_cholesky_degenerate_hessian():
    """H ~ 0 (dead layer input) must still return a finite factor."""
    u = np.asarray(Q.hinv_cholesky_upper(
        jnp.zeros((8, 8), jnp.float32), jnp.float32(0.01)))
    assert np.isfinite(u).all()


# --- GPTQ --------------------------------------------------------------------

def test_gptq_high_bits_lossless():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)).astype(np.float32))
    h = jnp.asarray(_hess(16, 100, 0))
    q, err = Q.gptq_quantize(w, h, jnp.float32(2.0**20), jnp.float32(0.01))
    np.testing.assert_allclose(q, w, atol=1e-3)
    assert float(err) < 1e-2


@settings(**SET)
@given(seed=st.integers(0, 2**31))
def test_gptq_beats_rtn_in_hessian_metric(seed):
    """The whole point of OBC/GPTQ: error feedback lowers tr(E H E^T) vs RTN."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
    h = jnp.asarray(_hess(24, 150, seed))
    q, err = Q.gptq_quantize(w, h, jnp.float32(7.0), jnp.float32(0.01))
    rtn = np.asarray(ref.rtn_quant_ref(w, jnp.float32(7.0)))
    d = rtn - np.asarray(w)
    rtn_err = float(np.sum((d @ np.asarray(h)) * d))
    assert float(err) <= rtn_err * 1.001


def test_gptq_error_monotone_in_bits():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32))
    h = jnp.asarray(_hess(24, 150, 5))
    errs = [
        float(Q.gptq_quantize(w, h, jnp.float32(2.0**b - 1), jnp.float32(0.01))[1])
        for b in (2, 3, 4, 8)
    ]
    assert errs[0] >= errs[1] >= errs[2] >= errs[3]


def test_gptq_grid_levels():
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    h = jnp.asarray(_hess(16, 100, 6))
    q, _ = Q.gptq_quantize(w, h, jnp.float32(7.0), jnp.float32(0.01))
    for row in np.asarray(q):
        assert len(np.unique(row)) <= 8


def test_gptq_err_matches_direct_computation():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    h = jnp.asarray(_hess(16, 100, 7))
    q, err = Q.gptq_quantize(w, h, jnp.float32(3.0), jnp.float32(0.01))
    d = np.asarray(q) - np.asarray(w)
    np.testing.assert_allclose(
        float(err), float(np.sum((d @ np.asarray(h)) * d)), rtol=1e-3)


def test_gptq_token_scaling_shifts_error():
    """RSQ's claim in miniature: scaling up some tokens' importance reduces
    the reconstruction error measured on exactly those tokens."""
    rng = np.random.default_rng(8)
    din, n = 16, 256
    x = rng.normal(size=(n, din)).astype(np.float32)
    w = jnp.asarray(rng.normal(size=(8, din)).astype(np.float32))
    important = np.zeros(n, np.float32)
    important[: n // 4] = 1.0   # "first chunk" of tokens
    r_uniform = np.ones(n, np.float32)
    r_rsq = 0.01 + 0.99 * important    # Eq. 4 with r_min=0.01

    def quant(r):
        h = jnp.asarray(2.0 * (x * (r**2)[:, None]).T @ x)
        q, _ = Q.gptq_quantize(w, h, jnp.float32(3.0), jnp.float32(0.01))
        return np.asarray(q)

    def chunk_err(q):
        e = (x[: n // 4] @ (q - np.asarray(w)).T)
        return float(np.sum(e * e))

    assert chunk_err(quant(r_rsq)) < chunk_err(quant(r_uniform))


# --- LDLQ vector quantization ------------------------------------------------

def _codebook(k=256, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(k, 8)).astype(np.float32))


def test_ldlq_shapes_and_finite():
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    h = jnp.asarray(_hess(32, 200, 9))
    q, err = Q.ldlq_vq_quantize(w, h, _codebook(), jnp.float32(0.01))
    assert q.shape == w.shape
    assert np.isfinite(np.asarray(q)).all() and np.isfinite(float(err))


def test_ldlq_codeword_structure():
    """Every 8-wide block of every output row must be s * some codeword."""
    rng = np.random.default_rng(10)
    w = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    h = jnp.asarray(_hess(16, 100, 10))
    cb = _codebook(64)
    q = np.asarray(Q.ldlq_vq_quantize(w, h, cb, jnp.float32(0.01))[0])
    s = np.sqrt(np.mean(np.asarray(w)**2, axis=1, keepdims=True)) + 1e-8
    cbn = np.asarray(cb)
    for r in range(4):
        for b in range(2):
            blk = q[r, b * 8:(b + 1) * 8] / s[r]
            dmin = np.min(np.linalg.norm(cbn - blk[None, :], axis=1))
            assert dmin < 1e-4, (r, b, dmin)


def test_ldlq_richer_codebook_not_worse():
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    h = jnp.asarray(_hess(32, 200, 11))
    e_small = float(Q.ldlq_vq_quantize(w, h, _codebook(16, 1), jnp.float32(0.01))[1])
    e_big = float(Q.ldlq_vq_quantize(w, h, _codebook(1024, 1), jnp.float32(0.01))[1])
    assert e_big <= e_small


def test_ldlq_feedback_beats_no_feedback():
    """Error feedback through U must not hurt the Hessian-weighted error."""
    rng = np.random.default_rng(12)
    w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    h = jnp.asarray(_hess(32, 200, 12))
    cb = _codebook(256, 2)
    _, err_fb = Q.ldlq_vq_quantize(w, h, cb, jnp.float32(0.01))
    # no-feedback VQ: independent nearest-codeword per block
    s = np.sqrt(np.mean(np.asarray(w)**2, axis=1, keepdims=True)) + 1e-8
    wn, cbn = np.asarray(w), np.asarray(cb)
    qn = np.zeros_like(wn)
    for b in range(4):
        blk = wn[:, b * 8:(b + 1) * 8] / s
        d2 = ((blk[:, None, :] - cbn[None]) ** 2).sum(-1)
        qn[:, b * 8:(b + 1) * 8] = s * cbn[np.argmin(d2, axis=1)]
    dn = qn - wn
    err_nofb = float(np.sum((dn @ np.asarray(h)) * dn))
    assert float(err_fb) <= err_nofb * 1.05
