"""Tests for the HLO inspector (compile/inspect_hlo.py)."""

import jax
import jax.numpy as jnp

from compile import inspect_hlo
from compile.aot import to_hlo_text


def _hlo_of(fn, *specs):
    return to_hlo_text(jax.jit(fn).lower(*specs))


def test_analyze_counts_dots():
    spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    text = _hlo_of(lambda a, b: (a @ b,), spec, spec)
    info = inspect_hlo.analyze(text)
    assert info["n_dot"] >= 1
    assert info["dot_output_elems"] >= 64
    assert not info["has_custom_call"]


def test_analyze_counts_while_loops():
    spec = jax.ShapeDtypeStruct((4,), jnp.float32)

    def loopy(x):
        return (jax.lax.fori_loop(0, 5, lambda i, a: a + 1.0, x),)

    info = inspect_hlo.analyze(_hlo_of(loopy, spec))
    assert info["n_while"] >= 1


def test_flags_custom_calls():
    fake = 'ENTRY main { ROOT c = f32[2]{0} custom-call(), custom_call_target="lapack_spotrf" }'
    info = inspect_hlo.analyze(fake)
    assert info["has_custom_call"]
    issues = inspect_hlo.check_module("m", info, 0, 0)
    assert issues and "custom-call" in issues[0]


def test_clean_module_has_no_issues():
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    info = inspect_hlo.analyze(_hlo_of(lambda a: (a * 2.0,), spec))
    assert inspect_hlo.check_module("m", info, 0, 0) == []
