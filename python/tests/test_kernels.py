"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; kernels run under
interpret=True (the CPU-PJRT-compatible mode the artifacts ship with).
"""

import numpy as np
import jax.numpy as jnp
import pytest

# property sweeps need hypothesis (python/requirements.txt); skip — not
# error — collection on images that ship without it, so the suite's
# collectable-test count stays honest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    attn_concentration, hessian_scaled, ref, rtn_quant, vq_assign,
)

SET = dict(deadline=None, max_examples=15)


def _rng(seed):
    return np.random.default_rng(seed)


# --- hessian_scaled ----------------------------------------------------------

@settings(**SET)
@given(
    b=st.integers(1, 3), t=st.integers(4, 48), k=st.sampled_from([8, 16, 33]),
    block=st.sampled_from([8, 16, 64]), seed=st.integers(0, 2**31),
    scale=st.sampled_from([1.0, 10.0, 1e-3]),
)
def test_hessian_matches_ref(b, t, k, block, seed, scale):
    rng = _rng(seed)
    x = jnp.asarray(scale * rng.normal(size=(b, t, k)).astype(np.float32))
    r = jnp.asarray(rng.uniform(0, 1, size=(b, t)).astype(np.float32))
    got = hessian_scaled(x, r, block_t=block)
    want = ref.hessian_scaled_ref(x, r)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale**2)


def test_hessian_zero_importance_is_zero():
    rng = _rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 8)).astype(np.float32))
    r = jnp.zeros((2, 16), jnp.float32)
    assert float(jnp.abs(hessian_scaled(x, r)).max()) == 0.0


def test_hessian_uniform_importance_is_plain_gram():
    """R = 1 must reduce RSQ's Hessian to GPTQ's 2XX^T (QuaRot equivalence)."""
    rng = _rng(1)
    x = jnp.asarray(rng.normal(size=(2, 16, 8)).astype(np.float32))
    r = jnp.ones((2, 16), jnp.float32)
    flat = np.asarray(x).reshape(-1, 8)
    np.testing.assert_allclose(
        hessian_scaled(x, r), 2.0 * flat.T @ flat, rtol=1e-4, atol=1e-4)


def test_hessian_psd():
    rng = _rng(2)
    x = jnp.asarray(rng.normal(size=(1, 32, 12)).astype(np.float32))
    r = jnp.asarray(rng.uniform(0, 1, size=(1, 32)).astype(np.float32))
    evals = np.linalg.eigvalsh(np.asarray(hessian_scaled(x, r)))
    assert evals.min() > -1e-3


def test_hessian_token_padding_is_noop():
    """n % block_t != 0 exercises the zero-pad path; padding must not leak."""
    rng = _rng(3)
    x = jnp.asarray(rng.normal(size=(1, 17, 8)).astype(np.float32))
    r = jnp.asarray(rng.uniform(0, 1, size=(1, 17)).astype(np.float32))
    np.testing.assert_allclose(
        hessian_scaled(x, r, block_t=8), ref.hessian_scaled_ref(x, r),
        rtol=1e-4, atol=1e-4)


# --- attn_concentration ------------------------------------------------------

@settings(**SET)
@given(
    b=st.integers(1, 2), m=st.integers(1, 3), t=st.sampled_from([8, 16, 32]),
    hd=st.sampled_from([4, 8]), block=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31),
)
def test_attn_con_matches_ref(b, m, t, hd, block, seed):
    if t % block != 0:
        block = t
    rng = _rng(seed)
    q = jnp.asarray(rng.normal(size=(b, m, t, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, m, t, hd)).astype(np.float32))
    got = attn_concentration(q, k, block_q=block)
    want = ref.attn_concentration_ref(q, k)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attn_con_total_mass():
    """Column sums over all keys must total M*T (each query row sums to 1)."""
    rng = _rng(4)
    b, m, t, hd = 2, 3, 16, 8
    q = jnp.asarray(rng.normal(size=(b, m, t, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, m, t, hd)).astype(np.float32))
    s = attn_concentration(q, k)
    np.testing.assert_allclose(np.asarray(s).sum(axis=1), m * t, rtol=1e-4)


def test_attn_con_causality():
    """Token T-1 can only receive attention from query T-1: score <= M."""
    rng = _rng(5)
    q = jnp.asarray(rng.normal(size=(1, 2, 16, 4)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 16, 4)).astype(np.float32))
    s = np.asarray(attn_concentration(q, k))
    assert s[0, -1] <= 2.0 + 1e-5
    # token 0 is attended by every query in a sink-free random model too
    assert s[0, 0] > 0.0


# --- rtn_quant ---------------------------------------------------------------

@settings(**SET)
@given(
    o=st.sampled_from([8, 16, 64]), i=st.integers(4, 64),
    bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2**31),
    scale=st.sampled_from([1.0, 100.0, 1e-4]),
)
def test_rtn_matches_ref(o, i, bits, seed, scale):
    rng = _rng(seed)
    w = jnp.asarray(scale * rng.normal(size=(o, i)).astype(np.float32))
    maxq = jnp.float32(2**bits - 1)
    got = rtn_quant(w, maxq, block_o=8)
    want = ref.rtn_quant_ref(w, maxq)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6 * scale)


def test_rtn_level_count():
    """Dequantized values must take at most 2^bits distinct levels per row."""
    rng = _rng(6)
    w = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    q = np.asarray(rtn_quant(w, jnp.float32(7.0), block_o=8))
    for row in q:
        assert len(np.unique(row)) <= 8


def test_rtn_high_bits_near_lossless():
    rng = _rng(7)
    w = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    q = rtn_quant(w, jnp.float32(float(2**16 - 1)), block_o=8)
    np.testing.assert_allclose(q, w, atol=1e-3)


def test_rtn_constant_row_stable():
    w = jnp.ones((8, 16), jnp.float32) * 3.25
    q = np.asarray(rtn_quant(w, jnp.float32(7.0), block_o=8))
    assert np.isfinite(q).all()
    np.testing.assert_allclose(q, w, atol=0.5)


# --- vq_assign ---------------------------------------------------------------

@settings(**SET)
@given(
    n=st.sampled_from([16, 64]), g=st.sampled_from([4, 8]),
    kk=st.sampled_from([16, 128]), seed=st.integers(0, 2**31),
)
def test_vq_matches_ref(n, g, kk, seed):
    rng = _rng(seed)
    groups = jnp.asarray(rng.normal(size=(n, g)).astype(np.float32))
    cb = jnp.asarray(rng.normal(size=(kk, g)).astype(np.float32))
    got = vq_assign(groups, cb, block_n=16)
    want = ref.vq_assign_ref(groups, cb)
    # ties can differ between argmin orders; verify distances instead
    gd = np.linalg.norm(np.asarray(groups) - np.asarray(cb)[np.asarray(got)], axis=1)
    wd = np.linalg.norm(np.asarray(groups) - np.asarray(cb)[np.asarray(want)], axis=1)
    np.testing.assert_allclose(gd, wd, rtol=1e-5, atol=1e-5)


def test_vq_exact_match_recovers_index():
    rng = _rng(8)
    cb = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    idx = np.asarray([3, 17, 0, 31] * 4, dtype=np.int64)
    groups = jnp.asarray(np.asarray(cb)[idx])
    got = np.asarray(vq_assign(groups, cb, block_n=16))
    np.testing.assert_array_equal(got, idx)
