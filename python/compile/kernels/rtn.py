"""Pallas kernel: per-row asymmetric grid quantize-dequantize (RTN).

The round-to-nearest baseline and the grid primitive shared by GPTQ: each
output row gets an asymmetric min-max grid with maxq = 2^bits - 1 levels.
maxq arrives as a runtime (1,1) scalar so one compiled artifact serves
2/3/4-bit sweeps (paper Tab. 5) without recompilation.

Grid/BlockSpec: one row-tile [BLOCK_O, I] per step; the reduction (row
min/max), the rounding, and the dequantize are all VPU elementwise work on
the resident tile, so the kernel is purely bandwidth-bound — one read and
one write of W, the roofline for this op.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rtn_kernel(w_ref, maxq_ref, o_ref):
    w = w_ref[...]
    maxq = maxq_ref[0, 0]
    lo = jnp.minimum(jnp.min(w, axis=1, keepdims=True), 0.0)
    hi = jnp.maximum(jnp.max(w, axis=1, keepdims=True), 0.0)
    scale = jnp.maximum((hi - lo) / maxq, 1e-8)
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(w / scale) + zero, 0.0, maxq)
    o_ref[...] = scale * (q - zero)


@functools.partial(jax.jit, static_argnames=("block_o", "interpret"))
def rtn_quant(w: jnp.ndarray, maxq: jnp.ndarray, *, block_o: int = 64,
              interpret: bool = True) -> jnp.ndarray:
    """Per-row grid quantize-dequantize. w: [O, I], maxq: scalar -> [O, I]."""
    o, i = w.shape
    block_o = min(block_o, o)
    assert o % block_o == 0, "O must be a multiple of the row tile"
    maxq2 = jnp.reshape(maxq.astype(jnp.float32), (1, 1))
    return pl.pallas_call(
        _rtn_kernel,
        grid=(o // block_o,),
        in_specs=[
            pl.BlockSpec((block_o, i), lambda bi: (bi, 0)),
            pl.BlockSpec((1, 1), lambda bi: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_o, i), lambda bi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((o, i), jnp.float32),
        interpret=interpret,
    )(w, maxq2)
