"""L1 Pallas kernels for the RSQ compute hot-spots.

Every kernel has a pure-jnp oracle in ref.py; pytest + hypothesis verify
them under interpret=True (the only mode runnable on CPU PJRT — real TPU
lowering emits Mosaic custom-calls the CPU plugin cannot execute).
"""

from .hessian import hessian_scaled
from .attn_scores import attn_concentration
from .rtn import rtn_quant
from .vq import vq_assign
from . import ref

__all__ = ["hessian_scaled", "attn_concentration", "rtn_quant", "vq_assign", "ref"]
