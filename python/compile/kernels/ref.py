"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (pytest +
hypothesis in python/tests/test_kernels.py). They are also what the L2
model would use if the Pallas path were disabled, so they double as
documentation of each kernel's semantics.
"""

import jax.numpy as jnp


def hessian_scaled_ref(x: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """H = 2 * sum_{b,t} r[b,t]^2 * x[b,t,:] x[b,t,:]^T   (paper Eq. 3->H_RSQ).

    x: [B, T, K] token features feeding one weight matrix.
    r: [B, T]    token importance (diagonal of R).
    returns [K, K] float32.
    """
    xr = x * r[..., None]
    flat = xr.reshape(-1, x.shape[-1])
    return 2.0 * (flat.T @ flat)


def attn_concentration_ref(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """AttnCon scores (paper Sec. 4.3): R_j = sum_{m,i} A[m,i,j].

    q, k: [B, M, T, Hd] query/key tensors (unscaled; the kernel applies
    1/sqrt(Hd)). Causal mask: A[m,i,j]=0 for j>i.
    returns [B, T] column sums of the softmax attention probability map,
    summed over heads and query positions.
    """
    hd = q.shape[-1]
    logits = jnp.einsum("bmth,bmsh->bmts", q, k) / jnp.sqrt(jnp.float32(hd))
    t = q.shape[2]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.sum(probs, axis=(1, 2))


def rtn_quant_ref(w: jnp.ndarray, maxq: jnp.ndarray) -> jnp.ndarray:
    """Per-row asymmetric min-max grid quantize-dequantize (RTN baseline and
    the grid used inside GPTQ).

    w: [O, I]; maxq: scalar f32 (= 2^bits - 1).
    """
    lo = jnp.minimum(jnp.min(w, axis=1, keepdims=True), 0.0)
    hi = jnp.maximum(jnp.max(w, axis=1, keepdims=True), 0.0)
    scale = jnp.maximum((hi - lo) / maxq, 1e-8)
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(w / scale) + zero, 0.0, maxq)
    return scale * (q - zero)


def quant_grid_ref(w, scale, zero, maxq):
    """Quantize-dequantize values with a fixed per-row grid."""
    q = jnp.clip(jnp.round(w / scale) + zero, 0.0, maxq)
    return scale * (q - zero)


def vq_assign_ref(groups: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Nearest-codeword assignment for vector quantization (paper Tab. 6).

    groups:   [N, G] weight groups (rows already scaled).
    codebook: [K, G].
    returns   [N] int32 index of the nearest codeword (L2).
    """
    # |g - c|^2 = |g|^2 - 2 g.c + |c|^2 ; |g|^2 is constant per row for argmin.
    dots = groups @ codebook.T
    c2 = jnp.sum(codebook * codebook, axis=1)
    return jnp.argmin(c2[None, :] - 2.0 * dots, axis=1).astype(jnp.int32)
