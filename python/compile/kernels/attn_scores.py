"""Pallas kernel: Attention-Concentration token scores (paper Sec. 4.3).

AttnCon assigns token j the total attention it receives:
    R_j = sum_{m,i} A[m,i,j],  A = causal-softmax(q k^T / sqrt(Hd)).

On GPU the paper reads attention maps off an eager forward pass. On TPU we
never materialize the [T, T] probability map in HBM: the kernel streams
query tiles (grid axis 2), keeps the key block VMEM-resident, computes the
[BLOCK_Q, T] logit tile on the MXU, applies the causal mask with iota
comparisons, row-softmaxes in-register (exact — each query row sees all of
its keys because keys are fully resident), and accumulates per-key column
sums into a [1, T] VMEM accumulator shared across (head, query-tile) grid
steps. Only the [B, T] score matrix ever returns to HBM.

VMEM footprint: BLOCK_Q*T logits + T*Hd keys + BLOCK_Q*Hd queries + T accum.
At paper scale (T=4096, Hd=128, BLOCK_Q=256): 4.2 MB + 2 MB + 0.13 MB — fits
a single TensorCore's VMEM; for longer T a second streaming pass over key
tiles with online-softmax renormalization would replace the resident keys.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_con_kernel(q_ref, k_ref, o_ref, *, block_q: int, t: int):
    m = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when((m == 0) & (qi == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, 0]                      # [BLOCK_Q, Hd]
    k = k_ref[0, 0]                      # [T, Hd]
    hd = q.shape[-1]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(hd))
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_q, t), 1)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(cols <= rows, logits, neg)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[...] += jnp.sum(probs, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def attn_concentration(q: jnp.ndarray, k: jnp.ndarray, *, block_q: int = 64,
                       interpret: bool = True) -> jnp.ndarray:
    """AttnCon scores. q, k: [B, M, T, Hd] -> [B, T]."""
    b, m, t, hd = q.shape
    block_q = min(block_q, t)
    assert t % block_q == 0, "T must be a multiple of the query tile"
    grid = (b, m, t // block_q)
    kernel = functools.partial(_attn_con_kernel, block_q=block_q, t=t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda bi, mi, qi: (bi, mi, qi, 0)),
            pl.BlockSpec((1, 1, t, hd), lambda bi, mi, qi: (bi, mi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda bi, mi, qi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t), jnp.float32),
        interpret=interpret,
    )(q, k)
