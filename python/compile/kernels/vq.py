"""Pallas kernel: nearest-codeword search for vector quantization (Tab. 6).

RSQ+VQ replaces the scalar integer grid with an E8-lattice-derived codebook
(paper: 2-bit-comparable E8P from QuIP#). The hot loop of vector quantization
is the [N, G] x [K, G] nearest-neighbour search; the kernel tiles the weight
groups (grid over N) while keeping the codebook VMEM-resident and expands
||g - c||^2 = ||g||^2 - 2 g.c + ||c||^2 so the dominant term is a single
[BLOCK_N, G] x [G, K] MXU matmul (||g||^2 is row-constant so dropped from the
argmin). On GPU this is the classic "codebook in shared memory" pattern; on
TPU the BlockSpec keeps the codebook in VMEM across all grid steps.

VMEM footprint: BLOCK_N*G + K*G + BLOCK_N*K floats — at K=4096, G=8,
BLOCK_N=512: 0.13 MB codebook + 8 MB distance tile, comfortably resident.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vq_kernel(g_ref, c_ref, o_ref):
    g = g_ref[...]                       # [BLOCK_N, G]
    c = c_ref[...]                       # [K, G]
    dots = jnp.dot(g, c.T, preferred_element_type=jnp.float32)
    c2 = jnp.sum(c * c, axis=1)
    dist = c2[None, :] - 2.0 * dots      # [BLOCK_N, K] (+||g||^2, constant)
    o_ref[...] = jnp.argmin(dist, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def vq_assign(groups: jnp.ndarray, codebook: jnp.ndarray, *,
              block_n: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Nearest codeword per group. groups: [N, G], codebook: [K, G] -> [N] i32."""
    n, g = groups.shape
    k, g2 = codebook.shape
    assert g == g2
    block_n = min(block_n, n)
    assert n % block_n == 0, "N must be a multiple of the group tile"
    return pl.pallas_call(
        _vq_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, g), lambda i: (i, 0)),
            pl.BlockSpec((k, g), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(groups, codebook)
