"""Pallas kernel: importance-scaled Hessian accumulation (the RSQ hot spot).

Computes the modified GPTQ second-order statistic of paper Sec. 4.2:

    H_RSQ = 2 * X R^2 X^T = 2 * sum_{b,t} r[b,t]^2 x[b,t] x[b,t]^T

This is the bandwidth-bound core of layer-wise quantization: X is the
[B*T, K] stream of token features feeding one weight matrix, read exactly
once per layer. The TPU schedule (DESIGN.md §Hardware-Adaptation):

  * grid over token tiles (BLOCK_T rows of X at a time),
  * each step loads an [BLOCK_T, K] tile of X and a [BLOCK_T, 1] tile of r
    into VMEM (BlockSpec below expresses the HBM->VMEM pipeline),
  * the rank-BLOCK_T update X_b^T diag(r^2) X_b is one [K,BLOCK_T]x[BLOCK_T,K]
    MXU matmul,
  * the [K, K] accumulator lives in the output VMEM block, revisited by
    every grid step (output index map is constant) — the standard Pallas
    reduction idiom; TPU grid execution is sequential so this is safe.

VMEM footprint: BLOCK_T*K + BLOCK_T + K*K floats. For the paper-scale
K=4096, BLOCK_T=256: 4.2 MB + 64 MB accumulator — the accumulator dominates,
so for K > 1024 a production TPU kernel would tile K as well; at this repo's
scales (K <= 512) everything fits in one VMEM block comfortably.

CPU note: lowered with interpret=True (Mosaic custom-calls cannot run on the
CPU PJRT plugin); numerics are identical to the TPU path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hessian_kernel(x_ref, r_ref, o_ref):
    """One grid step: o += 2 * (r*x)^T (r*x) over a BLOCK_T token tile."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xr = x_ref[...] * r_ref[...]          # [BLOCK_T, K] * [BLOCK_T, 1]
    # MXU contraction in f32 (quantization error feedback needs f32 accum).
    o_ref[...] += 2.0 * jnp.dot(
        xr.T, xr, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def hessian_scaled(x: jnp.ndarray, r: jnp.ndarray, *, block_t: int = 64,
                   interpret: bool = True) -> jnp.ndarray:
    """H = 2 * X R^2 X^T over token-tiles. x: [B,T,K], r: [B,T] -> [K,K]."""
    b, t, k = x.shape
    n = b * t
    xf = x.reshape(n, k)
    rf = r.reshape(n, 1)
    block_t = min(block_t, n)
    if n % block_t != 0:  # pad token axis; r=0 rows contribute nothing
        pad = block_t - n % block_t
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        rf = jnp.pad(rf, ((0, pad), (0, 0)))
        n += pad
    grid = (n // block_t,)
    return pl.pallas_call(
        _hessian_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=interpret,
    )(xf, rf)
