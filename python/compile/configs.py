"""Model configurations for the RSQ reproduction.

Each config fully determines the AOT artifact set: every HLO module is
lowered with shapes baked from these numbers (PJRT executables are
shape-monomorphic). `d` is always a power of two so the randomized Hadamard
rotation (paper Sec. 3.2) exists without block tricks.

The paper quantizes 7B-22B models on A100s; this box is a single CPU core,
so the configs are scaled down (see DESIGN.md "Substitutions"). The three
"model families" of paper Tab. 2 (LLaMA3-8B / Mistral-NeMo-12B / Qwen2.5-7B)
map to s1/s2/s3: same architecture family, different width/depth/head
layout, exactly as the paper varies families rather than hyperparameters of
one model.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d: int            # residual stream width (power of 2)
    layers: int
    heads: int
    ff: int           # FFN hidden width
    vocab: int
    max_seq: int      # positional-embedding table length
    batch: int        # calibration/eval batch baked into artifacts
    # sequence lengths for which embed/layer_fwd/hess/lm_nll variants are
    # emitted (Tab. 3 uses three N-samples x seq-len calibration configs,
    # Fig. 8 evaluates PPL at three context lengths).
    seq_lens: Tuple[int, ...] = ()

    def __post_init__(self):
        assert self.d % self.heads == 0, "d must divide heads"
        assert self.d & (self.d - 1) == 0, "d must be a power of 2 (Hadamard)"
        assert all(t <= self.max_seq for t in self.seq_lens)

    @property
    def head_dim(self) -> int:
        return self.d // self.heads

    def param_names(self) -> List[str]:
        """Canonical parameter ordering shared with the rust side.

        rust/src/model/params.rs mirrors this list; any change must be made
        in both places (the manifest also records it for cross-checking).
        """
        names = ["emb", "pos"]
        for l in range(self.layers):
            for w in ("g1", "wq", "wk", "wv", "wo", "g2", "wup", "wgate", "wdown"):
                names.append(f"l{l}.{w}")
        names += ["gf", "head"]
        return names

    def param_shape(self, name: str) -> Tuple[int, ...]:
        d, ff, v = self.d, self.ff, self.vocab
        if name == "emb":
            return (v, d)
        if name == "pos":
            return (self.max_seq, d)
        if name == "gf":
            return (d,)
        if name == "head":
            return (v, d)
        key = name.split(".")[1]
        return {
            "g1": (d,), "g2": (d,),
            "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "wup": (ff, d), "wgate": (ff, d), "wdown": (d, ff),
        }[key]

    def num_params(self) -> int:
        return sum(
            int.__mul__(*(list(self.param_shape(n)) + [1])[:2]) if len(self.param_shape(n)) == 2
            else self.param_shape(n)[0]
            for n in self.param_names()
        )


# --- the config registry -------------------------------------------------

CONFIGS = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


# unit/integration tests + pytest goldens: small enough that every HLO
# module compiles + runs in milliseconds.
TINY = _reg(ModelConfig("tiny", d=64, layers=2, heads=2, ff=128, vocab=256,
                        max_seq=64, batch=4, seq_lens=(32, 64)))

# default config for the table/figure drivers.
SMALL = _reg(ModelConfig("small", d=128, layers=2, heads=4, ff=256, vocab=512,
                         max_seq=256, batch=4, seq_lens=(64, 128, 256)))

# paper Tab. 2 "three model families" (different width/depth/heads/ff ratio,
# like LLaMA vs Mistral vs Qwen differ).
S1 = _reg(ModelConfig("s1", d=128, layers=3, heads=4, ff=256, vocab=512,
                      max_seq=128, batch=4, seq_lens=(128,)))
S2 = _reg(ModelConfig("s2", d=256, layers=2, heads=8, ff=384, vocab=512,
                      max_seq=128, batch=4, seq_lens=(128,)))
S3 = _reg(ModelConfig("s3", d=128, layers=4, heads=2, ff=512, vocab=512,
                      max_seq=128, batch=4, seq_lens=(128,)))

# model-size ablation (paper Fig. 5/6: 7B/12B/22B): three sizes of one family.
MS1 = _reg(ModelConfig("ms1", d=64, layers=2, heads=2, ff=128, vocab=512,
                       max_seq=128, batch=4, seq_lens=(128,)))
MS2 = _reg(ModelConfig("ms2", d=128, layers=3, heads=4, ff=256, vocab=512,
                       max_seq=128, batch=4, seq_lens=(128,)))
MS3 = _reg(ModelConfig("ms3", d=256, layers=4, heads=8, ff=512, vocab=512,
                       max_seq=128, batch=4, seq_lens=(128,)))

# end-to-end example: trained for a few hundred steps then quantized.
E2E = _reg(ModelConfig("e2e", d=256, layers=4, heads=4, ff=512, vocab=2048,
                       max_seq=128, batch=8, seq_lens=(128,)))

# GPTQ weight shapes that need a dedicated artifact: (out, in) pairs are
# derived per config in aot.py: (d,d), (ff,d), (d,ff).
