"""L2: GPTQ / LDLQ solvers (JAX, build-time only; lowered to HLO by aot.py).

GPTQ (paper Sec. 3.3, Frantar et al. 2023): quantize weight columns one at a
time against the (RSQ-modified) Hessian H = 2 X R^2 X^T, propagating each
column's quantization error into the not-yet-quantized columns through the
Cholesky factor of H^{-1} (OBC formula, paper Eq. 2).

LDLQ + vector quantization (paper Tab. 6, QuIP#-style): same error-feedback
recurrence, but 8-wide column blocks are quantized jointly against an
E8-derived codebook (the codebook is a runtime input built by
rust/src/quant/vq.rs).

All linear algebra is hand-rolled from fori_loop + masked matmuls: on CPU,
jnp.linalg lowers to LAPACK custom-calls that the rust xla_extension 0.5.1
runtime cannot resolve (see model.py header). Each helper is tested against
numpy in python/tests/test_quantizer.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax


# --- linear algebra ---------------------------------------------------------

def cholesky_lower(a):
    """Lower Cholesky of SPD a via column-by-column fori_loop.

    Progressive-fill trick: columns >= j of L are still zero when column j is
    computed, so the full matvec L @ L[j] only sums the k < j terms.
    """
    d = a.shape[0]
    diag_a = jnp.diagonal(a)

    def body(j, l):
        row_j = jnp.take(l, j, axis=0)
        s = l @ row_j
        ljj = jnp.sqrt(jnp.maximum(diag_a[j] - s[j], 1e-12))
        col = (jnp.take(a, j, axis=1) - s) / ljj
        idx = jnp.arange(d)
        col = jnp.where(idx > j, col, 0.0)
        col = col.at[j].set(ljj)
        return l.at[:, j].set(col)

    return lax.fori_loop(0, d, body, jnp.zeros_like(a))


def tri_inv_lower(l):
    """Inverse of a lower-triangular matrix by forward substitution rows."""
    d = l.shape[0]

    def body(i, x):
        row_l = jnp.take(l, i, axis=0)
        s = row_l @ x                       # rows >= i of x are still zero
        e = jax.nn.one_hot(i, d, dtype=x.dtype)
        row = (e - s) / jnp.take(row_l, i)
        return x.at[i, :].set(row)

    return lax.fori_loop(0, d, body, jnp.zeros_like(l))


def hinv_cholesky_upper(h, damp):
    """U upper-triangular with U^T U = (H + damp*mean(diag)*I)^{-1}.

    This is the factor GPTQ's recurrence consumes: err_i = (w_i - q_i)/U_ii,
    update_j = err_i * U_ij for j > i.
    """
    d = h.shape[0]
    dmean = jnp.mean(jnp.diagonal(h))
    # fully-dead inputs (H ~ 0) still need a usable factor
    dmean = jnp.maximum(dmean, 1e-8)
    hd = h + damp * dmean * jnp.eye(d, dtype=h.dtype)
    l = cholesky_lower(hd)
    linv = tri_inv_lower(l)
    hinv = linv.T @ linv
    return cholesky_lower(hinv).T


# --- scalar GPTQ -------------------------------------------------------------

def row_grid(w, maxq):
    """Per-row asymmetric min-max grid (always includes 0)."""
    lo = jnp.minimum(jnp.min(w, axis=1, keepdims=True), 0.0)
    hi = jnp.maximum(jnp.max(w, axis=1, keepdims=True), 0.0)
    scale = jnp.maximum((hi - lo) / maxq, 1e-8)
    zero = jnp.round(-lo / scale)
    return scale[:, 0], zero[:, 0]


def gptq_quantize(w, h, maxq, damp):
    """GPTQ with the (scaled-token) Hessian.

    w: [O, I] weight; h: [I, I] Hessian (2 X R^2 X^T); maxq, damp: scalars.
    Returns (q, err) — q is the dequantized weight, err the Hessian-weighted
    reconstruction loss tr((W-Q) H (W-Q)^T) (the paper's layer objective).
    """
    o, din = w.shape
    u = hinv_cholesky_upper(h, damp)
    scale, zero = row_grid(w, maxq)

    def body(i, carry):
        wc, qc = carry
        urow = jnp.take(u, i, axis=0)
        uii = jnp.take(urow, i)
        wcol = jnp.take(wc, i, axis=1)
        qq = jnp.clip(jnp.round(wcol / scale) + zero, 0.0, maxq)
        deq = scale * (qq - zero)
        err = (wcol - deq) / uii
        mask = (jnp.arange(din) > i).astype(w.dtype)
        wc = wc - jnp.outer(err, urow * mask)
        qc = qc.at[:, i].set(deq)
        return wc, qc

    _, q = lax.fori_loop(0, din, body, (w, jnp.zeros_like(w)))
    diff = q - w
    err = jnp.sum((diff @ h) * diff)
    return q, err


# --- LDLQ vector quantization (Tab. 6) --------------------------------------

def _tri_inv_upper_small(u):
    return tri_inv_lower(u.T).T


def ldlq_vq_quantize(w, h, codebook, damp, *, gdim=8):
    """Blocked LDLQ with codebook (vector) quantization.

    Each row is scaled to unit RMS; 8-wide column blocks are assigned to the
    nearest codeword (same argmin as kernels/vq.assign — inlined jnp here so
    it fuses into the fori body), and the block's error is propagated to
    later columns through the Cholesky factor, exactly the GPTQ recurrence
    generalized to blocks:  E = (W_B - Q_B) U_BB^{-1};  W_later -= E U_B,later.
    """
    o, din = w.shape
    assert din % gdim == 0
    nblk = din // gdim
    u = hinv_cholesky_upper(h, damp)
    s = jnp.sqrt(jnp.mean(w * w, axis=1, keepdims=True)) + 1e-8   # [O,1]
    c2 = jnp.sum(codebook * codebook, axis=1)

    def body(b, carry):
        wc, qc = carry
        c0 = b * gdim
        blk = lax.dynamic_slice(wc, (0, c0), (o, gdim)) / s
        dots = blk @ codebook.T
        idx = jnp.argmin(c2[None, :] - 2.0 * dots, axis=1)
        deq = s * jnp.take(codebook, idx, axis=0)
        ubb = lax.dynamic_slice(u, (c0, c0), (gdim, gdim))
        e = (s * blk - deq) @ _tri_inv_upper_small(ubb)
        urows = lax.dynamic_slice(u, (c0, 0), (gdim, din))
        mask = (jnp.arange(din) >= c0 + gdim).astype(w.dtype)
        wc = wc - e @ (urows * mask[None, :])
        qc = lax.dynamic_update_slice(qc, deq, (0, c0))
        return wc, qc

    _, q = lax.fori_loop(0, nblk, body, (w, jnp.zeros_like(w)))
    diff = q - w
    err = jnp.sum((diff @ h) * diff)
    return q, err
