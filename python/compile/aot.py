"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the rust runtime.

Usage:  cd python && python -m compile.aot --config tiny --out ../artifacts/tiny

Emits one `<module>.hlo.txt` per compute graph plus `manifest.txt`
describing the config, the canonical parameter list, and every module's
signature. The rust side (rust/src/runtime/manifest.rs) parses the manifest,
compiles each module once on the PJRT CPU client, and never touches python
again.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import quantizer as Q
from .configs import CONFIGS

LDLQ_K = 1024     # codebook entries for the VQ artifacts (Tab. 6); 8-dim
LDLQ_G = 8        # group (vector) dimension


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _fmt_shape(s):
    return "x".join(str(d) for d in s) if s else "scalar"


class Emitter:
    def __init__(self, cfg, out_dir):
        self.cfg = cfg
        self.out = out_dir
        self.lines = []

    def emit(self, name, fn, in_specs, n_out, note=""):
        """Lower fn at in_specs, write HLO text, record a manifest line."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        ins = ";".join(
            f"{s.dtype}:{_fmt_shape(s.shape)}" for s in in_specs
        )
        self.lines.append(f"module={name}|file={fname}|in={ins}|nout={n_out}|note={note}")
        print(f"  {name}: {len(text)} chars, {len(in_specs)} inputs, {n_out} outputs")

    def param_specs(self):
        cfg = self.cfg
        return [_spec(cfg.param_shape(n)) for n in cfg.param_names()]

    def write_manifest(self):
        cfg = self.cfg
        hdr = [
            f"config={cfg.name}", f"d={cfg.d}", f"layers={cfg.layers}",
            f"heads={cfg.heads}", f"ff={cfg.ff}", f"vocab={cfg.vocab}",
            f"max_seq={cfg.max_seq}", f"batch={cfg.batch}",
            f"seq_lens={','.join(str(t) for t in cfg.seq_lens)}",
            f"ldlq_k={LDLQ_K}", f"ldlq_g={LDLQ_G}",
        ]
        hdr += [
            f"param={n}|shape={_fmt_shape(cfg.param_shape(n))}"
            for n in cfg.param_names()
        ]
        with open(os.path.join(self.out, "manifest.txt"), "w") as f:
            f.write("\n".join(hdr + self.lines) + "\n")


def build_config(cfg, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    em = Emitter(cfg, out_dir)
    b, d, ff, v = cfg.batch, cfg.d, cfg.ff, cfg.vocab
    pspecs = em.param_specs()

    for t in cfg.seq_lens:
        tok = _spec((b, t), jnp.int32)
        em.emit(
            f"embed_t{t}",
            lambda tokens, emb, pos: (M.embed(cfg, tokens, emb, pos),),
            [tok, _spec((v, d)), _spec((cfg.max_seq, d))], 1,
            note="tokens->Z0",
        )

        def layer_fn(z, g1, wq, wk, wv, wo, g2, wup, wgate, wdown):
            lp = dict(g1=g1, wq=wq, wk=wk, wv=wv, wo=wo, g2=g2,
                      wup=wup, wgate=wgate, wdown=wdown)
            return M.layer_fwd(cfg, z, lp, capture=True)

        em.emit(
            f"layer_fwd_t{t}", layer_fn,
            [_spec((b, t, d)), _spec((d,)), _spec((d, d)), _spec((d, d)),
             _spec((d, d)), _spec((d, d)), _spec((d,)), _spec((ff, d)),
             _spec((ff, d)), _spec((d, ff))], 9,
            note="z->z2,xa,xo,xf,xd,attn_con,act_norm,act_diff,token_sim",
        )

        from .kernels import hessian_scaled
        for kdim, tag in ((d, "d"), (ff, "ff")):
            em.emit(
                f"hess_{tag}_t{t}",
                lambda x, r: (hessian_scaled(x, r),),
                [_spec((b, t, kdim)), _spec((b, t))], 1,
                note="H=2*X R^2 X^T (pallas)",
            )

        em.emit(
            f"lm_nll_t{t}",
            lambda tokens, *flat: (M.lm_nll(cfg, tokens, list(flat)),),
            [tok] + pspecs, 1, note="per-position next-token NLL",
        )
        em.emit(
            f"logits_last_t{t}",
            lambda tokens, *flat: (M.logits_last(cfg, tokens, list(flat)),),
            [tok] + pspecs, 1, note="log-softmax logits at last position",
        )

    from .kernels import rtn_quant
    for (o, i) in {(d, d), (ff, d), (d, ff)}:
        em.emit(
            f"gptq_{o}x{i}",
            lambda w, h, maxq, damp: Q.gptq_quantize(w, h, maxq, damp),
            [_spec((o, i)), _spec((i, i)), _spec(()), _spec(())], 2,
            note="GPTQ column solve -> (Q, hessian-weighted err)",
        )
        em.emit(
            f"rtn_{o}x{i}",
            lambda w, maxq: (rtn_quant(w, maxq),),
            [_spec((o, i)), _spec(())], 1, note="RTN baseline (pallas)",
        )
        em.emit(
            f"ldlq_{o}x{i}",
            lambda w, h, cb, damp: Q.ldlq_vq_quantize(w, h, cb, damp, gdim=LDLQ_G),
            [_spec((o, i)), _spec((i, i)), _spec((LDLQ_K, LDLQ_G)), _spec(())],
            2, note="LDLQ vector quantization (Tab. 6)",
        )

    t_train = max(cfg.seq_lens)
    n = len(pspecs)

    def train_fn(*args):
        flat = list(args[:n])
        m = list(args[n:2 * n])
        vv = list(args[2 * n:3 * n])
        tokens, step = args[3 * n], args[3 * n + 1]
        nf, nm, nv, loss = M.train_step(cfg, flat, m, vv, tokens, step)
        return tuple(nf + nm + nv + [loss])

    em.emit(
        "train_step", train_fn,
        pspecs + pspecs + pspecs + [_spec((b, t_train), jnp.int32), _spec(())],
        3 * n + 1, note="Adam step; outputs params,m,v,loss",
    )

    em.write_manifest()
    print(f"[{cfg.name}] wrote {len(em.lines)} modules -> {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", required=True, help="config name or 'all'")
    ap.add_argument("--out", required=True, help="output directory")
    args = ap.parse_args()
    names = list(CONFIGS) if args.config == "all" else [args.config]
    for name in names:
        cfg = CONFIGS[name]
        out = args.out if len(names) == 1 else os.path.join(args.out, name)
        build_config(cfg, out)


if __name__ == "__main__":
    main()
