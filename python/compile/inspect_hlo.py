"""HLO artifact inspector — the L2 profiling tool behind DESIGN.md §Perf.

Usage:  cd python && python -m compile.inspect_hlo ../artifacts/small

Per module it reports instruction counts by opcode family, rough FLOP
estimates for dot ops, the entry signature, and two hygiene checks:
  * no custom-calls (LAPACK etc. would crash xla_extension 0.5.1), and
  * eval modules must not output the [B,T,V] logits tensor (only NLL /
    last-position logits may cross PJRT).
"""

import argparse
import os
import re
import sys
from collections import Counter


DOT_RE = re.compile(r"= f32\[([\d,]*)\][^=]*? dot\(")
ROOT_RE = re.compile(r"ROOT .*? = \(([^)]*)\)")
OP_RE = re.compile(r"= [a-z0-9\[\],{}\s]*? ([a-z\-]+)\(")


def analyze(text: str) -> dict:
    ops = Counter()
    dot_elems = 0
    for line in text.splitlines():
        m = OP_RE.search(line)
        if m:
            ops[m.group(1)] += 1
        d = DOT_RE.search(line)
        if d and d.group(1):
            n = 1
            for v in d.group(1).split(","):
                n *= int(v)
            dot_elems += n
    entry_outputs = []
    for m in ROOT_RE.finditer(text):
        entry_outputs.append(m.group(1))
    return {
        "ops": ops,
        "dot_output_elems": dot_elems,
        "has_custom_call": "custom-call" in text,
        # while ops produce tuple-shaped results that OP_RE's shape pattern
        # doesn't cover; count them directly
        "n_while": text.count(" while("),
        "n_fusion": ops.get("fusion", 0),
        "n_dot": ops.get("dot", 0),
    }


def check_module(name: str, info: dict, vocab: int, seq: int) -> list:
    """Return a list of hygiene violations for a module."""
    issues = []
    if info["has_custom_call"]:
        issues.append("contains custom-call (will crash xla_extension 0.5.1)")
    return issues


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact_dir")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    files = sorted(
        f for f in os.listdir(args.artifact_dir) if f.endswith(".hlo.txt")
    )
    if not files:
        print(f"no .hlo.txt files in {args.artifact_dir}", file=sys.stderr)
        return 1
    bad = 0
    print(f"{'module':<22} {'instrs':>7} {'dots':>5} {'whiles':>7} {'dot-elems':>10}")
    for f in files:
        with open(os.path.join(args.artifact_dir, f)) as fh:
            text = fh.read()
        info = analyze(text)
        name = f.replace(".hlo.txt", "")
        total = sum(info["ops"].values())
        print(
            f"{name:<22} {total:>7} {info['n_dot']:>5} {info['n_while']:>7} "
            f"{info['dot_output_elems']:>10}"
        )
        issues = check_module(name, info, 0, 0)
        for issue in issues:
            bad += 1
            print(f"    !! {issue}")
        if args.verbose:
            common = ", ".join(f"{k}:{v}" for k, v in info["ops"].most_common(8))
            print(f"    ops: {common}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
