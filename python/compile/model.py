"""L2: the transformer compute graph (JAX, build-time only).

A LLaMA-like decoder: RMSNorm (with learnable gains — fused to 1 before
rotation, paper Sec. 4.2 "Rotate"), multi-head causal attention, SwiGLU FFN,
learned absolute positional embeddings, untied LM head.

Everything here is lowered ONCE by aot.py to HLO text and executed from the
rust coordinator; no function in this file runs at request time.

Parameter ordering contract (must match rust/src/model/params.rs):
    [emb, pos] + [g1, wq, wk, wv, wo, g2, wup, wgate, wdown] * layers
              + [gf, head]
Weights are [out, in]; activations are row-vectors; y = x @ W.T.

Rotation conventions (checked by tests/test_model.py::test_rotation_invariance):
  residual stream z -> z Q  implies
    in-dim  rotated:  W' = W @ Q    for wq, wk, wv, wup, wgate, head
    out-dim rotated:  W' = Q.T @ W  for wo, wdown
    tables:           emb' = emb @ Q, pos' = pos @ Q
  valid only after the RMSNorm gains are fused (g == 1), since
  rmsnorm(zQ) = rmsnorm(z) Q holds for the gain-free norm.

NOTE on linear algebra: no jnp.linalg anywhere in lowered code — on CPU,
jax lowers linalg to LAPACK custom-calls that xla_extension 0.5.1 (the rust
runtime) cannot resolve. quantizer.py carries hand-rolled Cholesky and
triangular inverses built from fori_loop + masked matmuls instead.
"""

import jax
import jax.numpy as jnp

from .kernels import attn_concentration

EPS = 1e-6


def rmsnorm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def split_layer_params(cfg, flat, layer):
    """Slice one layer's 9 tensors out of the flat parameter list."""
    base = 2 + layer * 9
    keys = ("g1", "wq", "wk", "wv", "wo", "g2", "wup", "wgate", "wdown")
    return dict(zip(keys, flat[base:base + 9]))


def embed(cfg, tokens, emb, pos):
    """tokens i32[B,T] -> Z0 [B,T,d]. pos is the full [max_seq, d] table."""
    t = tokens.shape[1]
    return jnp.take(emb, tokens, axis=0) + pos[None, :t, :]


def _heads(cfg, x):
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _unheads(x):
    b, m, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, m * hd)


def layer_fwd(cfg, z, lp, *, capture=False, interpret=True):
    """One transformer layer.

    Returns z_next, and when capture=True also the per-weight input streams
    (Xa -> wq/wk/wv, Xo -> wo, Xf -> wup/wgate, Xd -> wdown) plus the four
    dynamic token-importance scores of paper Sec. 4.3 computed from this
    layer (AttnCon via the L1 Pallas kernel; ActNorm / ActDiff / TokenSim
    as masked jnp reductions). TokenFreq is corpus-side (rust).
    """
    xa = rmsnorm(z) * lp["g1"]
    q = _heads(cfg, xa @ lp["wq"].T)
    k = _heads(cfg, xa @ lp["wk"].T)
    v = _heads(cfg, xa @ lp["wv"].T)

    hd = cfg.head_dim
    t = z.shape[1]
    logits = jnp.einsum("bmth,bmsh->bmts", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask[None, None], logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    xo = _unheads(probs @ v)
    z1 = z + xo @ lp["wo"].T

    xf = rmsnorm(z1) * lp["g2"]
    xd = jax.nn.silu(xf @ lp["wgate"].T) * (xf @ lp["wup"].T)
    z2 = z1 + xd @ lp["wdown"].T

    if not capture:
        return z2

    # --- dynamic importance scores (paper Sec. 4.3) ---
    # AttnCon: R_j = sum_{m,i} A[m,i,j] — streaming Pallas kernel, never
    # materializes the [T,T] map in HBM on TPU (here probs exist for the
    # forward anyway; the kernel is the artifact-path implementation).
    attn_con = attn_concentration(q, k, interpret=interpret)
    # ActNorm: ||z_i||
    act_norm = jnp.sqrt(jnp.sum(z * z, axis=-1))
    # ActDiff: -||Layer(z_i) - z_i|| (steadier tokens matter more)
    diff = z2 - z
    act_diff = -jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    # TokenSim: R_i = sum_j ||z_i - z_j|| (rarer tokens matter more)
    zz = jnp.sum(z * z, axis=-1)
    dots = jnp.einsum("btd,bsd->bts", z, z)
    d2 = jnp.maximum(zz[:, :, None] + zz[:, None, :] - 2.0 * dots, 0.0)
    token_sim = jnp.sum(jnp.sqrt(d2), axis=-1)

    return z2, xa, xo, xf, xd, attn_con, act_norm, act_diff, token_sim


def forward(cfg, tokens, flat, *, ctx=None):
    """Full forward -> final hidden states [B, Tc, d]."""
    tc = ctx or tokens.shape[1]
    tok = tokens[:, :tc]
    z = embed(cfg, tok, flat[0], flat[1])
    for l in range(cfg.layers):
        z = layer_fwd(cfg, z, split_layer_params(cfg, flat, l), capture=False)
    gf, _ = flat[-2], flat[-1]
    return rmsnorm(z) * gf


def lm_nll(cfg, tokens, flat, *, ctx=None):
    """Per-position next-token negative log-likelihood.

    Returns nll [B, Tc] where nll[:, t] = -log p(tokens[t+1] | tokens[..t])
    for t < Tc-1 and 0 at the last position. The [B,T,V] logits never leave
    the device — only the NLL crosses PJRT (DESIGN.md §Perf / L2).
    """
    h = forward(cfg, tokens, flat, ctx=ctx)
    head = flat[-1]
    logits = h @ head.T
    logp = jax.nn.log_softmax(logits, axis=-1)
    tc = h.shape[1]
    tgt = tokens[:, 1:tc]
    picked = jnp.take_along_axis(logp[:, :-1, :], tgt[..., None], axis=-1)[..., 0]
    nll = -picked
    return jnp.pad(nll, ((0, 0), (0, 1)))


def logits_last(cfg, tokens, flat, *, ctx=None):
    """Log-probabilities of the next token after the last position [B, V]."""
    h = forward(cfg, tokens, flat, ctx=ctx)
    head = flat[-1]
    return jax.nn.log_softmax(h[:, -1, :] @ head.T, axis=-1)


def loss_fn(cfg, flat, tokens):
    nll = lm_nll(cfg, tokens, flat)
    t = tokens.shape[1]
    return jnp.sum(nll) / (tokens.shape[0] * (t - 1))


def train_step(cfg, flat, m, v, tokens, step, *, lr=1e-3, b1=0.9, b2=0.999,
               eps=1e-8):
    """One Adam step. All of flat/m/v are positional lists (device-resident
    buffers on the rust side; outputs feed the next call without host copies).
    """
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens))(list(flat))
    new_flat, new_m, new_v = [], [], []
    t_ = step + 1.0
    for p, g, mi, vi in zip(flat, grads, m, v):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * g * g
        mhat = mi / (1.0 - b1 ** t_)
        vhat = vi / (1.0 - b2 ** t_)
        new_flat.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_flat, new_m, new_v, loss


# --- rotation / fusion helpers (mirrored in rust/src/model/rotate.rs; the
# python versions exist for the invariance tests and as the executable
# specification) -----------------------------------------------------------

def fuse_gains(cfg, flat):
    """Fold RMSNorm gains into the adjacent in-dim weights; set gains to 1.

    g1 -> wq/wk/wv columns, g2 -> wup/wgate columns, gf -> head columns.
    Function-preserving; prerequisite for rotation (paper Sec. 4.2 Rotate).
    """
    out = list(flat)
    for l in range(cfg.layers):
        base = 2 + l * 9
        g1 = out[base]
        for j in (base + 1, base + 2, base + 3):       # wq wk wv
            out[j] = out[j] * g1[None, :]
        out[base] = jnp.ones_like(g1)
        g2 = out[base + 5]
        for j in (base + 6, base + 7):                 # wup wgate
            out[j] = out[j] * g2[None, :]
        out[base + 5] = jnp.ones_like(g2)
    gf = out[-2]
    out[-1] = out[-1] * gf[None, :]
    out[-2] = jnp.ones_like(gf)
    return out


def rotate_params(cfg, flat, qmat):
    """Apply the orthogonal transform Q to all weights (paper Sec. 3.2).

    Requires fused gains. rmsnorm(zQ) = rmsnorm(z) Q makes this exactly
    function-preserving (up to fp error).
    """
    out = list(flat)
    out[0] = out[0] @ qmat                              # emb
    out[1] = out[1] @ qmat                              # pos
    for l in range(cfg.layers):
        base = 2 + l * 9
        for j in (base + 1, base + 2, base + 3):        # wq wk wv: in-dim
            out[j] = out[j] @ qmat
        out[base + 4] = qmat.T @ out[base + 4]          # wo: out-dim
        for j in (base + 6, base + 7):                  # wup wgate: in-dim
            out[j] = out[j] @ qmat
        out[base + 8] = qmat.T @ out[base + 8]          # wdown: out-dim
    out[-1] = out[-1] @ qmat                            # head: in-dim
    return out


def init_params(cfg, key):
    """Reference initializer (tests only; the trained-model path inits in rust)."""
    flat = []
    for name in cfg.param_names():
        shape = cfg.param_shape(name)
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            flat.append(jnp.ones(shape, jnp.float32))
        else:
            scale = 0.4 / jnp.sqrt(jnp.float32(shape[1]))
            flat.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return flat
