//! End-to-end driver (the repo's headline run; DESIGN.md §Perf): train a ~4M-param
//! transformer from scratch on the synthetic corpus for a few hundred steps
//! (loss curve logged), inject outliers, quantize it to 3-bit with every
//! method, and report perplexity + downstream accuracy — proving all three
//! layers (rust coordinator -> HLO model graph -> Pallas kernels) compose.
//!
//!     cargo run --release --example e2e_train_quantize -- --steps 300
//!
//! Python is NOT running during any of this: training, quantization and
//! evaluation all execute AOT artifacts through PJRT.

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::eval::tasks::mean_accuracy;
use rsq::eval::{longctx_suite, perplexity, probe_suite};
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::model::ParamSet;
use rsq::quant::{quantize, Method, QuantOptions};
use rsq::runtime::Engine;
use rsq::train::{train, TrainOptions};
use rsq::util::{json::Json, Args};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.str_or("config", "e2e");
    let steps = args.usize_or("steps", 300);
    let engine = Engine::load(&config)?;
    let cfg = engine.config().clone();
    let t = *cfg.seq_lens.iter().max().unwrap();
    println!(
        "=== end-to-end: train + quantize + evaluate ===\n\
         model: {config} (d={} L={} heads={} ff={} vocab={} -> {} params)",
        cfg.d, cfg.layers, cfg.heads, cfg.ff, cfg.vocab, cfg.num_params()
    );

    // --- 1. train from scratch, logging the loss curve ---
    let mut params = ParamSet::init(&cfg, 7);
    let report = train(
        &engine,
        &mut params,
        &TrainOptions { steps, seed: 7, log_every: 10, verbose: true, ..Default::default() },
    )?;
    println!(
        "loss: {:.3} -> {:.3} over {steps} steps ({:.1}s, {:.1} tok/s)",
        report.loss_curve.first().map(|x| x.1).unwrap_or(f32::NAN),
        report.final_loss,
        report.wall_seconds,
        (steps * cfg.batch * t) as f64 / report.wall_seconds,
    );

    // --- 2. outlier injection (DESIGN.md §Substitutions) ---
    inject_outliers(&mut params, OutlierSpec::default(), 7);

    // --- 3. quantize with every method, evaluate PPL + probes + long-ctx ---
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 16, t, 7, 1);
    let eval = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 32, t, 7, 2);
    let full_ppl = perplexity(&engine, &params, &eval, t)?;
    let full_probes = probe_suite(&engine, &params, t, 3, 32)?;
    println!("\n{:<10} {:>10} {:>8} {:>10}", "method", "PPL", "acc(%)", "quant(s)");
    println!(
        "{:<10} {:>10.3} {:>8.1} {:>10}",
        "full", full_ppl, 100.0 * mean_accuracy(&full_probes), "-"
    );
    let mut rows = vec![Json::obj()
        .set("method", "full")
        .set("ppl", full_ppl)
        .set("acc", mean_accuracy(&full_probes))];
    for method in [Method::Rtn, Method::Gptq, Method::QuaRot, Method::Sq, Method::Rsq] {
        let opts = QuantOptions::new(method, args.usize_or("bits", 3) as u32, t);
        let (q, r) = quantize(&engine, &params, &calib, &opts)?;
        let ppl = perplexity(&engine, &q, &eval, t)?;
        let probes = probe_suite(&engine, &q, t, 3, 32)?;
        let acc = mean_accuracy(&probes);
        println!(
            "{:<10} {:>10.3} {:>8.1} {:>10.2}",
            method.name(), ppl, 100.0 * acc, r.wall_seconds
        );
        rows.push(
            Json::obj()
                .set("method", method.name())
                .set("ppl", ppl)
                .set("acc", acc)
                .set("quant_seconds", r.wall_seconds),
        );
    }

    // --- 4. long-context spot check on the best method ---
    let (q_rsq, _) =
        quantize(&engine, &params, &calib, &QuantOptions::new(Method::Rsq, 3, t))?;
    println!("\nlong-context (RSQ 3-bit):");
    for r in longctx_suite(&engine, &q_rsq, t, 3, 24)? {
        println!("  {:<24} {:.1}%", r.name, 100.0 * r.score);
    }

    std::fs::create_dir_all("results").ok();
    let record = Json::obj()
        .set("config", config)
        .set("steps", steps)
        .set(
            "loss_curve",
            Json::Arr(
                report
                    .loss_curve
                    .iter()
                    .map(|&(s, l)| Json::Arr(vec![Json::from(s), Json::from(l)]))
                    .collect(),
            ),
        )
        .set("rows", Json::Arr(rows));
    std::fs::write("results/e2e.json", record.to_string())?;
    println!("\n[record] wrote results/e2e.json");
    Ok(())
}
