//! Importance-strategy sweep (paper Figs. 2-3 in one program): compare all
//! nine strategies at their defaults, plus an r_min mini-sweep for AttnCon.
//!
//!     cargo run --release --example strategy_sweep -- --config small

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::eval::perplexity;
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::quant::{quantize, Method, QuantOptions, Strategy};
use rsq::runtime::Engine;
use rsq::train::train_or_load;
use rsq::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.str_or("config", "small");
    let engine = Engine::load(&config)?;
    let cfg = engine.config().clone();
    let t = args.usize_or("calib-t", 128);
    let bits = args.usize_or("bits", 3) as u32;

    let (mut params, _) = train_or_load(&engine, 7, args.usize_or("steps", 400), true)?;
    inject_outliers(&mut params, OutlierSpec::default(), 7);
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 16, t, 7, 1);
    let eval = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 32, t, 7, 2);
    println!("full PPL: {:.3}\n", perplexity(&engine, &params, &eval, t)?);

    let strategies = [
        Strategy::Uniform,
        Strategy::FirstN(t / 8),
        Strategy::FirstLastN(t / 8),
        Strategy::Chunk { index: 1, of: 4 },
        Strategy::TokenFreq { r_min: 0.05 },
        Strategy::ActNorm { r_min: 0.005 },
        Strategy::ActDiff { r_min: 0.05 },
        Strategy::TokenSim { r_min: 0.005 },
        Strategy::AttnCon { r_min: 0.05 },
    ];
    println!("{:<20} {:>10}", "strategy (RSQ)", "PPL");
    for strat in strategies {
        let mut opts = QuantOptions::new(Method::Rsq, bits, t);
        opts.strategy = strat;
        let (q, _) = quantize(&engine, &params, &calib, &opts)?;
        println!("{:<20} {:>10.3}", strat.name(), perplexity(&engine, &q, &eval, t)?);
    }

    println!("\nAttnCon r_min sweep:");
    for r_min in [0.005f32, 0.01, 0.05, 0.1, 0.3] {
        let mut opts = QuantOptions::new(Method::Rsq, bits, t);
        opts.strategy = Strategy::AttnCon { r_min };
        let (q, _) = quantize(&engine, &params, &calib, &opts)?;
        println!("  r_min={r_min:<6} PPL {:.3}", perplexity(&engine, &q, &eval, t)?);
    }
    Ok(())
}
