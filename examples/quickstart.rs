//! Quickstart: quantize a trained model with RTN / GPTQ / QuaRot / RSQ and
//! compare perplexity + downstream accuracy.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Flags: --config small --bits 3 --steps 400 --calib-n 16

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::eval::tasks::mean_accuracy;
use rsq::eval::{perplexity, probe_suite};
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::quant::{quantize, Method, QuantOptions};
use rsq::runtime::Engine;
use rsq::train::train_or_load;
use rsq::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.str_or("config", "small");
    let bits = args.usize_or("bits", 3) as u32;

    // 1. load the AOT artifact set (compiled once by `make artifacts`)
    let engine = Engine::load(&config)?;
    let cfg = engine.config().clone();
    let t = *cfg.seq_lens.iter().max().unwrap().min(&128);
    println!("model {config}: d={} layers={} params={}", cfg.d, cfg.layers, cfg.num_params());

    // 2. obtain a trained checkpoint (cached under artifacts/<config>/)
    let (mut params, _) = train_or_load(&engine, 7, args.usize_or("steps", 400), true)?;
    // give the Rotate step real work: sparse outlier injection (DESIGN.md)
    inject_outliers(&mut params, OutlierSpec::default(), 7);

    // 3. calibration + held-out eval data from the synthetic corpus
    let calib =
        CalibSet::generate(cfg.vocab, CorpusKind::Wiki, args.usize_or("calib-n", 16), t, 7, 1);
    let eval = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 32, t, 7, 2);

    let full_ppl = perplexity(&engine, &params, &eval, t)?;
    let full_acc = mean_accuracy(&probe_suite(&engine, &params, t, 3, 32)?);
    println!("\n{:<10} {:>10} {:>10} {:>12}", "method", "PPL", "acc(%)", "quant time");
    println!("{:<10} {:>10.3} {:>10.1} {:>12}", "full", full_ppl, 100.0 * full_acc, "-");

    // 4. quantize with each method and evaluate
    for method in [Method::Rtn, Method::Gptq, Method::QuaRot, Method::Rsq] {
        let opts = QuantOptions::new(method, bits, t);
        let (q, report) = quantize(&engine, &params, &calib, &opts)?;
        let ppl = perplexity(&engine, &q, &eval, t)?;
        let acc = mean_accuracy(&probe_suite(&engine, &q, t, 3, 32)?);
        println!(
            "{:<10} {:>10.3} {:>10.1} {:>11.2}s",
            method.name(), ppl, 100.0 * acc, report.wall_seconds
        );
    }
    Ok(())
}
