//! Long-context evaluation demo (paper Sec. 5.3): quantize with QuaRot and
//! RSQ, then run the long-context probe battery (KV retrieval, needle
//! position, in-context classification, code-pattern completion).
//!
//!     cargo run --release --example longcontext_eval -- --config small

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::eval::longctx_suite;
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::quant::{quantize, Method, QuantOptions};
use rsq::runtime::Engine;
use rsq::train::train_or_load;
use rsq::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.str_or("config", "small");
    let engine = Engine::load(&config)?;
    let cfg = engine.config().clone();
    let eval_t = *cfg.seq_lens.iter().max().unwrap();
    let calib_t = args.usize_or("calib-t", 128);
    let n = args.usize_or("lc-n", 24);

    let (mut params, _) = train_or_load(&engine, 7, args.usize_or("steps", 400), true)?;
    inject_outliers(&mut params, OutlierSpec::default(), 7);
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 16, calib_t, 7, 1);

    let full = longctx_suite(&engine, &params, eval_t, 3, n)?;
    println!("{:<24} {:>8} {:>8} {:>8}", "task", "full", "quarot", "rsq");
    let (quarot, _) =
        quantize(&engine, &params, &calib, &QuantOptions::new(Method::QuaRot, 3, calib_t))?;
    let (rsq, _) =
        quantize(&engine, &params, &calib, &QuantOptions::new(Method::Rsq, 3, calib_t))?;
    let rq = longctx_suite(&engine, &quarot, eval_t, 3, n)?;
    let rr = longctx_suite(&engine, &rsq, eval_t, 3, n)?;
    for ((f, q), r) in full.iter().zip(&rq).zip(&rr) {
        println!(
            "{:<24} {:>7.1}% {:>7.1}% {:>7.1}%",
            f.name,
            100.0 * f.score,
            100.0 * q.score,
            100.0 * r.score
        );
    }
    let avg = |v: &[rsq::eval::LongCtxResult]| {
        100.0 * v.iter().map(|r| r.score).sum::<f64>() / v.len() as f64
    };
    println!(
        "{:<24} {:>7.1}% {:>7.1}% {:>7.1}%",
        "AVG", avg(&full), avg(&rq), avg(&rr)
    );
    Ok(())
}
