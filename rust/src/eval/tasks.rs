//! The ten downstream probe tasks — Tab. 2's task battery, rebuilt as
//! synthetic probes over the corpus token space (DESIGN.md §Substitutions).
//!
//! Each probe is scored exactly like lm-eval scores its real counterpart:
//! top-1 argmax for cloze-style tasks, log-prob comparison for
//! multiple-choice. Paper-task mapping:
//!   bigram_cloze    -> LAMBADA_openai   (next-word prediction)
//!   lambada_topic   -> LAMBADA_std      (long-range last word)
//!   topic_choice2   -> WinoGrande       (binary choice)
//!   choice4_pattern -> ARC-Challenge    (4-way choice)
//!   induction_copy  -> ARC-Easy         (pattern completion)
//!   freq_discrim    -> HellaSwag        (plausible continuation)
//!   eos_sense       -> PIQA             (structural plausibility)
//!   topic_classify  -> MMLU             (topic knowledge, 8-way)
//!   arith_mod       -> GSM8k            (arithmetic)
//!   rare_recall     -> TruthfulQA       (resist frequent-token prior)

use anyhow::Result;

use super::{argmax, logits_last_batched, nll_batched};
use crate::corpus::generator::{CONTENT0, D0, EOS, OP};
use crate::corpus::{CorpusKind, Generator};
use crate::model::ParamSet;
use crate::runtime::Engine;
use crate::util::Pcg;

#[derive(Clone, Debug)]
pub struct ProbeResult {
    pub name: &'static str,
    pub accuracy: f64,
    pub n: usize,
}

/// One instance: a prompt plus how to judge the model's output.
enum Check {
    /// argmax over full vocab must equal token
    #[allow(dead_code)]
    Top1(i32),
    /// logprob[first] must beat logprob of every other candidate
    Choice(Vec<i32>),
    /// argmax over full vocab must land in this topic
    TopicTop1(usize),
}

struct TaskSet {
    name: &'static str,
    prompts: Vec<Vec<i32>>,
    checks: Vec<Check>,
}

/// Build + score all ten probes. `n` = instances per task.
pub fn probe_suite(
    engine: &Engine,
    params: &ParamSet,
    t: usize,
    seed: u64,
    n: usize,
) -> Result<Vec<ProbeResult>> {
    let cfg = engine.config().clone();
    let vocab = cfg.vocab;
    let mut gen = Generator::new(vocab, CorpusKind::Wiki, seed, 31);
    let mut rng = Pcg::with_stream(seed, 41);
    let mut results = Vec::new();

    let mut logit_tasks: Vec<TaskSet> = Vec::new();

    // -- 1. bigram_cloze ------------------------------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        for _ in 0..n {
            let mut p = gen.sample(t);
            // force a content cue token at the end
            let cue = random_content(&gen, &mut rng);
            p[t - 1] = cue;
            let ans = gen.space.successor_of(cue);
            checks.push(Check::Choice(with_distractors(ans, 8, &gen, &mut rng)));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "bigram_cloze", prompts, checks });
    }

    // -- 2. induction_copy ----------------------------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        for _ in 0..n {
            let mut p = gen.sample(t);
            let a = random_content(&gen, &mut rng);
            let b = random_content(&gen, &mut rng);
            let pos = t / 4 + rng.below(t / 2);
            p[pos] = a;
            p[pos + 1] = b;
            // scrub other occurrences of `a` so the cue is unambiguous
            for (i, v) in p.iter_mut().enumerate() {
                if *v == a && i != pos {
                    *v = EOS;
                }
            }
            p[t - 1] = a;
            checks.push(Check::Choice(with_distractors(b, 8, &gen, &mut rng)));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "induction_copy", prompts, checks });
    }

    // -- 3. rare_recall --------------------------------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        for _ in 0..n {
            let mut p = gen.sample(t);
            let r = random_content(&gen, &mut rng);
            let pos = 2 + rng.below(t / 2);
            p[pos] = OP;
            p[pos + 1] = r;
            for (i, v) in p.iter_mut().enumerate() {
                if *v == OP && i != pos && i != t - 1 {
                    *v = EOS;
                }
            }
            p[t - 1] = OP;
            checks.push(Check::Choice(with_distractors(r, 8, &gen, &mut rng)));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "rare_recall", prompts, checks });
    }

    // -- 4. arith_mod ----------------------------------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        for _ in 0..n {
            let mut p = gen.sample(t);
            let a = rng.below(10) as i32;
            let b = rng.below(10) as i32;
            p[t - 4] = D0 + a;
            p[t - 3] = OP;
            p[t - 2] = D0 + b;
            p[t - 1] = EQ_TOKEN;
            // label set = the ten digits (GSM8k-style exact-answer scoring)
            let ans = D0 + (a + b) % 10;
            let mut cands = vec![ans];
            cands.extend((0..10).map(|k| D0 + k).filter(|&d| d != ans));
            checks.push(Check::Choice(cands));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "arith_mod", prompts, checks });
    }

    // -- 5. topic_choice2 (WinoGrande analog) ----------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        let n_topics = gen.space.profile.n_topics;
        for _ in 0..n {
            let ta = rng.below(n_topics);
            let tb = (ta + 1 + rng.below(n_topics - 1)) % n_topics;
            let p = topic_prompt(&gen, ta, t, &mut rng);
            let good = pick_topic_token(&gen, ta, &mut rng);
            let bad = pick_topic_token(&gen, tb, &mut rng);
            checks.push(Check::Choice(vec![good, bad]));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "topic_choice2", prompts, checks });
    }

    // -- 6. choice4_pattern (ARC-Challenge analog) ------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        for _ in 0..n {
            let topic = rng.below(gen.space.profile.n_topics);
            let cyc: Vec<i32> = (0..4).map(|_| pick_topic_token(&gen, topic, &mut rng)).collect();
            let mut p = gen.sample(t);
            let tail = t / 2;
            for i in 0..tail {
                p[t - tail + i] = cyc[i % 4];
            }
            let answer = cyc[tail % 4];
            let mut cands = vec![answer];
            while cands.len() < 4 {
                let c = pick_topic_token(&gen, topic, &mut rng);
                if !cands.contains(&c) && !cyc.contains(&c) {
                    cands.push(c);
                }
            }
            checks.push(Check::Choice(cands));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "choice4_pattern", prompts, checks });
    }

    // -- 9. topic_classify (MMLU analog) ----------------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        let n_topics = gen.space.profile.n_topics;
        for _ in 0..n {
            let ta = rng.below(n_topics);
            let p = topic_prompt(&gen, ta, t, &mut rng);
            let mut cands: Vec<i32> =
                (0..n_topics).map(|k| gen.space.topic_tokens[k][0]).collect();
            // rotate so the correct answer is first (Choice contract)
            cands.rotate_left(ta);
            checks.push(Check::Choice(cands));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "topic_classify", prompts, checks });
    }

    // -- 10. lambada_topic -------------------------------------------------
    {
        let mut prompts = Vec::new();
        let mut checks = Vec::new();
        let n_topics = gen.space.profile.n_topics;
        for _ in 0..n {
            let ta = rng.below(n_topics);
            let p = topic_prompt(&gen, ta, t, &mut rng);
            checks.push(Check::TopicTop1(ta));
            prompts.push(p);
        }
        logit_tasks.push(TaskSet { name: "lambada_topic", prompts, checks });
    }

    // score all logits-based tasks
    for task in logit_tasks {
        let logits = logits_last_batched(engine, params, &task.prompts, t)?;
        let mut correct = 0usize;
        for (row, check) in logits.iter().zip(&task.checks) {
            let ok = match check {
                Check::Top1(ans) => argmax(row) as i32 == *ans,
                Check::Choice(cands) => {
                    let best = cands
                        .iter()
                        .max_by(|&&a, &&b| row[a as usize].total_cmp(&row[b as usize]))
                        .unwrap();
                    *best == cands[0]
                }
                Check::TopicTop1(topic) => {
                    let am = argmax(row) as i32;
                    gen.space.topic_of_token(am) == Some(*topic)
                }
            };
            correct += ok as usize;
        }
        results.push(ProbeResult {
            name: task.name,
            accuracy: correct as f64 / task.checks.len() as f64,
            n: task.checks.len(),
        });
    }

    // -- 7. eos_sense (paired logits) --------------------------------------
    {
        let mut prompts = Vec::new();
        for _ in 0..n {
            // long-sentence prompt (EOS strongly expected soon)
            let mut long_p = gen.sample(t);
            for v in long_p[t - 20..].iter_mut() {
                if *v == EOS {
                    *v = random_content(&gen, &mut rng);
                }
            }
            // short-sentence prompt: EOS 2 tokens ago
            let mut short_p = long_p.clone();
            short_p[t - 3] = EOS;
            prompts.push(long_p);
            prompts.push(short_p);
        }
        let logits = logits_last_batched(engine, params, &prompts, t)?;
        let mut correct = 0usize;
        for pair in logits.chunks(2) {
            correct += (pair[0][EOS as usize] > pair[1][EOS as usize]) as usize;
        }
        results.push(ProbeResult {
            name: "eos_sense",
            accuracy: correct as f64 / n as f64,
            n,
        });
    }

    // -- 8. freq_discrim (NLL-scored continuation choice) -------------------
    {
        let mut seqs = Vec::new();
        for _ in 0..n {
            let real = gen.sample(t);
            let mut fake = real.clone();
            // corrupt the 4-token continuation: shuffle it
            let tail: &mut [i32] = &mut fake[t - 4..];
            rng.shuffle(tail);
            if fake == real {
                fake[t - 1] = random_content(&gen, &mut rng);
            }
            seqs.push(real);
            seqs.push(fake);
        }
        let nll = nll_batched(engine, params, &seqs, t)?;
        let mut correct = 0usize;
        for pair in nll.chunks(2) {
            let score = |row: &[f32]| -> f32 { row[t - 5..t - 1].iter().sum() };
            correct += (score(&pair[0]) < score(&pair[1])) as usize;
        }
        results.push(ProbeResult {
            name: "freq_discrim",
            accuracy: correct as f64 / n as f64,
            n,
        });
    }

    results.sort_by_key(|r| r.name);
    Ok(results)
}

/// Mean accuracy across a probe battery (the paper's "Avg" column).
pub fn mean_accuracy(results: &[ProbeResult]) -> f64 {
    results.iter().map(|r| r.accuracy).sum::<f64>() / results.len() as f64
}

const EQ_TOKEN: i32 = crate::corpus::generator::EQ;

fn random_content(gen: &Generator, rng: &mut Pcg) -> i32 {
    (CONTENT0 + rng.below(gen.space.n_content)) as i32
}

/// answer-first candidate list with `n-1` distinct content distractors
fn with_distractors(ans: i32, n: usize, gen: &Generator, rng: &mut Pcg) -> Vec<i32> {
    let mut cands = vec![ans];
    while cands.len() < n {
        let c = random_content(gen, rng);
        if !cands.contains(&c) {
            cands.push(c);
        }
    }
    cands
}

fn pick_topic_token(gen: &Generator, topic: usize, rng: &mut Pcg) -> i32 {
    let toks = &gen.space.topic_tokens[topic];
    toks[rng.below(toks.len())]
}

/// A prompt dominated by one topic's tokens (bigram-chained for realism).
fn topic_prompt(gen: &Generator, topic: usize, t: usize, rng: &mut Pcg) -> Vec<i32> {
    let mut p = Vec::with_capacity(t);
    p.push(crate::corpus::generator::BOS);
    let mut cur = pick_topic_token(gen, topic, rng);
    while p.len() < t {
        p.push(cur);
        cur = if rng.f32() < 0.5 {
            gen.space.successor_of(cur)
        } else {
            pick_topic_token(gen, topic, rng)
        };
    }
    p
}

#[cfg(test)]
mod tests {
    // probe construction is deterministic; engine-dependent scoring is
    // covered by rust/tests/integration_eval.rs
    use super::*;

    #[test]
    fn topic_prompt_is_on_topic() {
        let gen = Generator::new(256, CorpusKind::Wiki, 1, 31);
        let mut rng = Pcg::new(2);
        let p = topic_prompt(&gen, 3, 64, &mut rng);
        assert_eq!(p.len(), 64);
        let on_topic = p
            .iter()
            .filter(|&&tk| gen.space.topic_of_token(tk) == Some(3))
            .count();
        assert!(on_topic > 48, "{on_topic}");
    }
}
