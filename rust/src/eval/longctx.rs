//! Long-context probe families (paper Sec. 5.3, Tab. 3 + Tab. 7 analogs).
//!
//! Four families mirroring the benchmarks' task *shapes*:
//!   kv_retrieval  — LongEval: L key-value lines, query one key at the end;
//!                   the L sweep is the context-pressure axis.
//!   needle_pos    — Lost-in-the-Middle: a needle ("OP r") at position
//!                   fraction P of the context, recalled at the end.
//!   icl_classify  — LongICLBench (Banking77/TecRED): many-class in-context
//!                   classification from few-shot examples.
//!   code_pattern  — LongCodeArena: complete a long periodic "function"
//!                   using project-wide (whole-prompt) context; scored as a
//!                   [0,1] pattern-match rate (the ChrF analog).

use anyhow::Result;

use super::{argmax, logits_last_batched};
use crate::corpus::generator::{BOS, CONTENT0, D0, OP};
use crate::corpus::{CorpusKind, Generator};
use crate::model::ParamSet;
use crate::runtime::Engine;
use crate::util::Pcg;

#[derive(Clone, Debug)]
pub struct LongCtxResult {
    pub name: String,
    pub score: f64,
    pub n: usize,
}

/// KV retrieval with `n_pairs` key-value lines inside context length `t`.
pub fn kv_retrieval(
    engine: &Engine,
    params: &ParamSet,
    t: usize,
    n_pairs: usize,
    seed: u64,
    n: usize,
) -> Result<LongCtxResult> {
    let cfg = engine.config();
    let gen = Generator::new(cfg.vocab, CorpusKind::Wiki, seed, 51);
    let mut rng = Pcg::with_stream(seed, 52);
    assert!(2 * n_pairs + 2 <= t, "too many pairs for context {t}");
    let mut prompts = Vec::new();
    let mut answers = Vec::new();
    for _ in 0..n {
        // distinct keys
        let mut keys = Vec::with_capacity(n_pairs);
        while keys.len() < n_pairs {
            let k = (CONTENT0 + rng.below(gen.space.n_content)) as i32;
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
        let vals: Vec<i32> = (0..n_pairs).map(|_| D0 + rng.below(10) as i32).collect();
        let qi = rng.below(n_pairs);
        let mut p = vec![BOS];
        for (k, v) in keys.iter().zip(&vals) {
            p.push(*k);
            p.push(*v);
        }
        while p.len() < t - 1 {
            p.push(crate::corpus::generator::EOS);
        }
        p.push(keys[qi]);
        prompts.push(p);
        answers.push(vals[qi]);
    }
    let logits = logits_last_batched(engine, params, &prompts, t)?;
    let correct = logits
        .iter()
        .zip(&answers)
        .filter(|(row, &a)| {
            // restricted argmax over the 10 value tokens (the task's label set)
            let best = (0..10)
                .max_by(|&x, &y| row[(D0 + x) as usize].total_cmp(&row[(D0 + y) as usize]))
                .unwrap();
            D0 + best == a
        })
        .count();
    Ok(LongCtxResult {
        name: format!("kv_retrieval:L{n_pairs}"),
        score: correct as f64 / n as f64,
        n,
    })
}

/// Needle at position fraction `frac` of the context (LITM P analog).
pub fn needle_pos(
    engine: &Engine,
    params: &ParamSet,
    t: usize,
    frac: f64,
    seed: u64,
    n: usize,
) -> Result<LongCtxResult> {
    let cfg = engine.config();
    let mut gen = Generator::new(cfg.vocab, CorpusKind::Wiki, seed, 53);
    let mut rng = Pcg::with_stream(seed, 54);
    let mut prompts = Vec::new();
    let mut answers = Vec::new();
    for _ in 0..n {
        let mut p = gen.sample(t);
        let r = (CONTENT0 + rng.below(gen.space.n_content)) as i32;
        let pos = 1 + ((t - 8) as f64 * frac) as usize;
        p[pos] = OP;
        p[pos + 1] = r;
        for (i, v) in p.iter_mut().enumerate() {
            if *v == OP && i != pos && i != t - 1 {
                *v = crate::corpus::generator::EOS;
            }
        }
        p[t - 1] = OP;
        prompts.push(p);
        answers.push(r);
    }
    let logits = logits_last_batched(engine, params, &prompts, t)?;
    let correct = logits
        .iter()
        .zip(&answers)
        .filter(|(row, &a)| argmax(row) as i32 == a)
        .count();
    Ok(LongCtxResult {
        name: format!("needle:P{:.0}", frac * 100.0),
        score: correct as f64 / n as f64,
        n,
    })
}

/// Few-shot in-context classification over `n_classes` topics with digit
/// labels (LongICLBench analog).
pub fn icl_classify(
    engine: &Engine,
    params: &ParamSet,
    t: usize,
    n_classes: usize,
    seed: u64,
    n: usize,
) -> Result<LongCtxResult> {
    let cfg = engine.config();
    let gen = Generator::new(cfg.vocab, CorpusKind::Wiki, seed, 55);
    let mut rng = Pcg::with_stream(seed, 56);
    let n_classes = n_classes.min(gen.space.profile.n_topics).min(10);
    let mut prompts = Vec::new();
    let mut answers = Vec::new();
    for _ in 0..n {
        let mut p = vec![BOS];
        // few-shot blocks: "<topic token> <digit label>" until the context
        // is full, covering every class round-robin
        let mut c = 0usize;
        while p.len() < t - 2 {
            let topic = c % n_classes;
            let tok = gen.space.topic_tokens[topic][rng.below(gen.space.topic_tokens[topic].len())];
            p.push(tok);
            p.push(D0 + topic as i32);
            c += 1;
        }
        while p.len() < t - 1 {
            p.push(crate::corpus::generator::EOS);
        }
        let q = rng.below(n_classes);
        let qtok = gen.space.topic_tokens[q][rng.below(gen.space.topic_tokens[q].len())];
        p.push(qtok);
        p.truncate(t);
        prompts.push(p);
        answers.push(D0 + q as i32);
    }
    let logits = logits_last_batched(engine, params, &prompts, t)?;
    let correct = logits
        .iter()
        .zip(&answers)
        .filter(|(row, &a)| {
            let best = (0..n_classes)
                .max_by(|&x, &y| {
                    row[(D0 + x as i32) as usize].total_cmp(&row[(D0 + y as i32) as usize])
                })
                .unwrap();
            D0 + best as i32 == a
        })
        .count();
    Ok(LongCtxResult {
        name: format!("icl_classify:{n_classes}way"),
        score: correct as f64 / n as f64,
        n,
    })
}

/// Complete a long periodic "code" pattern; score = next-token match rate
/// across phases (ChrF analog in [0,1]).
pub fn code_pattern(
    engine: &Engine,
    params: &ParamSet,
    t: usize,
    seed: u64,
    n: usize,
) -> Result<LongCtxResult> {
    let cfg = engine.config();
    let gen = Generator::new(cfg.vocab, CorpusKind::Wiki, seed, 57);
    let mut rng = Pcg::with_stream(seed, 58);
    let mut prompts = Vec::new();
    let mut answers = Vec::new();
    for _ in 0..n {
        let topic = rng.below(gen.space.profile.n_topics);
        let period = 4 + rng.below(4);
        let pat: Vec<i32> = (0..period)
            .map(|_| gen.space.topic_tokens[topic][rng.below(gen.space.topic_tokens[topic].len())])
            .collect();
        let mut p = vec![BOS];
        let mut i = 0usize;
        while p.len() < t {
            p.push(pat[i % period]);
            i += 1;
        }
        p.truncate(t);
        // answer: the continuation of the pattern after the last token
        answers.push(pat[(t - 1) % period]);
        prompts.push(p);
    }
    let logits = logits_last_batched(engine, params, &prompts, t)?;
    let correct = logits
        .iter()
        .zip(&answers)
        .filter(|(row, &a)| argmax(row) as i32 == a)
        .count();
    Ok(LongCtxResult {
        name: "code_pattern".to_string(),
        score: correct as f64 / n as f64,
        n,
    })
}

/// The full Tab. 3-analog battery at context length `t`.
pub fn longctx_suite(
    engine: &Engine,
    params: &ParamSet,
    t: usize,
    seed: u64,
    n: usize,
) -> Result<Vec<LongCtxResult>> {
    let kv_levels = [t / 4 / 2, t * 3 / 8 / 2, (t - 4) / 2];
    let mut out = Vec::new();
    for pairs in kv_levels {
        out.push(kv_retrieval(engine, params, t, pairs.max(2), seed, n)?);
    }
    for frac in [0.0, 0.5, 1.0] {
        out.push(needle_pos(engine, params, t, frac, seed, n)?);
    }
    out.push(icl_classify(engine, params, t, 8, seed, n)?); // Banking77 analog
    out.push(icl_classify(engine, params, t, 4, seed, n)?); // TecRED analog
    out.push(code_pattern(engine, params, t, seed, n)?);
    Ok(out)
}
