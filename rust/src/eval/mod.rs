//! Evaluation harnesses: perplexity, the ten downstream probe tasks
//! (Tab. 2 analogs), and the long-context probe families (Tab. 3/7
//! analogs). All scoring goes through the `lm_nll_t*` / `logits_last_t*`
//! artifacts — logits never cross PJRT except at the final position.

pub mod longctx;
pub mod ppl;
pub mod tasks;

pub use longctx::{longctx_suite, LongCtxResult};
pub use ppl::perplexity;
pub use tasks::{probe_suite, ProbeResult};

use crate::corpus::CalibSet;
use crate::model::ParamSet;
use crate::runtime::{self, Engine};
use anyhow::Result;

/// The shared scoring block of `rsq quantize` and `rsq eval`: perplexity
/// plus the downstream probe battery at one context length. Works the
/// same whether `params` came from the in-memory pipeline, a checkpoint,
/// or a packed artifact — which is exactly what makes `rsq eval
/// --artifact` comparable bit-for-bit with the pipeline that saved it.
#[derive(Clone, Debug)]
pub struct ScoreCard {
    pub ppl: f64,
    pub probes: Vec<ProbeResult>,
    pub mean_acc: f64,
}

/// Score `params` on `eval_set` at context `t` with `probe_n` instances
/// per probe task.
pub fn score_model(
    engine: &Engine,
    params: &ParamSet,
    eval_set: &CalibSet,
    t: usize,
    probe_n: usize,
) -> Result<ScoreCard> {
    let ppl = perplexity(engine, params, eval_set, t)?;
    let probes = probe_suite(engine, params, t, 3, probe_n)?;
    let mean_acc = tasks::mean_accuracy(&probes);
    Ok(ScoreCard { ppl, probes, mean_acc })
}

/// Batched last-position log-probs for a set of equal-length prompts.
/// Pads the final batch by repeating the last prompt; callers slice.
pub fn logits_last_batched(
    engine: &Engine,
    params: &ParamSet,
    prompts: &[Vec<i32>],
    t: usize,
) -> Result<Vec<Vec<f32>>> {
    let cfg = engine.config();
    let module = format!("logits_last_t{t}");
    let p_lits: Vec<xla::Literal> = params
        .tensors
        .iter()
        .map(runtime::tensor_literal)
        .collect::<Result<_>>()?;
    let mut out = Vec::with_capacity(prompts.len());
    let mut i = 0;
    while i < prompts.len() {
        let mut batch: Vec<Vec<i32>> = Vec::with_capacity(cfg.batch);
        for k in 0..cfg.batch {
            let idx = (i + k).min(prompts.len() - 1);
            batch.push(prompts[idx].clone());
        }
        let tok_lit = runtime::tokens_literal(&batch, t)?;
        let mut ins: Vec<&xla::Literal> = vec![&tok_lit];
        ins.extend(p_lits.iter());
        let outs = engine.exec_ref(&module, &ins)?;
        let lt = runtime::literal_tensor(&outs[0])?;
        let v = cfg.vocab;
        let take = cfg.batch.min(prompts.len() - i);
        for b in 0..take {
            out.push(lt.data[b * v..(b + 1) * v].to_vec());
        }
        i += cfg.batch;
    }
    Ok(out)
}

/// Batched per-position NLL for a set of equal-length sequences.
pub fn nll_batched(
    engine: &Engine,
    params: &ParamSet,
    seqs: &[Vec<i32>],
    t: usize,
) -> Result<Vec<Vec<f32>>> {
    let cfg = engine.config();
    let module = format!("lm_nll_t{t}");
    let p_lits: Vec<xla::Literal> = params
        .tensors
        .iter()
        .map(runtime::tensor_literal)
        .collect::<Result<_>>()?;
    let mut out = Vec::with_capacity(seqs.len());
    let mut i = 0;
    while i < seqs.len() {
        let mut batch: Vec<Vec<i32>> = Vec::with_capacity(cfg.batch);
        for k in 0..cfg.batch {
            let idx = (i + k).min(seqs.len() - 1);
            batch.push(seqs[idx].clone());
        }
        let tok_lit = runtime::tokens_literal(&batch, t)?;
        let mut ins: Vec<&xla::Literal> = vec![&tok_lit];
        ins.extend(p_lits.iter());
        let outs = engine.exec_ref(&module, &ins)?;
        let nt = runtime::literal_tensor(&outs[0])?;
        let take = cfg.batch.min(seqs.len() - i);
        for b in 0..take {
            out.push(nt.data[b * t..(b + 1) * t].to_vec());
        }
        i += cfg.batch;
    }
    Ok(out)
}

/// argmax helper over a log-prob row.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    #[test]
    fn argmax_basic() {
        assert_eq!(super::argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(super::argmax(&[2.0]), 0);
    }
}
