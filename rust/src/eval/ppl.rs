//! Perplexity evaluation (the paper's primary metric).
//!
//! PPL = exp(Σ nll / N) over next-token positions of a held-out set at a
//! given context length (paper App. C.4 shows context length matters —
//! Fig. 8's driver sweeps it via the lm_nll_t* artifact variants).

use anyhow::Result;

use super::nll_batched;
use crate::corpus::CalibSet;
use crate::model::ParamSet;
use crate::runtime::Engine;

/// Perplexity of `params` on `eval_set` at context length `t`.
pub fn perplexity(
    engine: &Engine,
    params: &ParamSet,
    eval_set: &CalibSet,
    t: usize,
) -> Result<f64> {
    assert!(eval_set.seq_len >= t, "eval samples shorter than context");
    let seqs: Vec<Vec<i32>> = eval_set
        .samples
        .iter()
        .map(|s| s[..t].to_vec())
        .collect();
    let nll = nll_batched(engine, params, &seqs, t)?;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for row in &nll {
        // last position predicts nothing (zero-padded by the artifact)
        for &v in &row[..t - 1] {
            total += v as f64;
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    // exercised end-to-end by rust/tests/integration_eval.rs (needs artifacts)
}
