//! The paper's contribution: token-importance-aware layer-wise quantization.
//!
//! - [`strategy`] — the importance strategies of Sec. 4.3 (heuristic:
//!   First-N, First&Last-N, Chunk; dynamic: TokenFreq, ActNorm, ActDiff,
//!   TokenSim, AttnCon) plus the Eq. 4 normalization.
//! - [`pipeline`] — the layer-by-layer coordinator implementing RTN, GPTQ,
//!   QuaRot, SQ (scale w/o rotate), RSQ (rotate+scale) and the VQ variants,
//!   with streaming Hessian accumulation and dataset expansion. Work fans
//!   out over a `util::Pool` of worker threads (`--jobs`), with a
//!   fixed-order reduction that keeps output bit-identical to the serial
//!   path (DESIGN.md §Threading).
//! - [`vq`] — E8-derived codebook construction for Tab. 6.

pub mod pipeline;
pub mod strategy;
pub mod vq;

pub use pipeline::{quantize, Method, QuantOptions, QuantReport};
pub use strategy::Strategy;
