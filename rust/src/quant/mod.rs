//! The paper's contribution: token-importance-aware layer-wise quantization.
//!
//! - [`strategy`] — the importance strategies of Sec. 4.3 (heuristic:
//!   First-N, First&Last-N, Chunk; dynamic: TokenFreq, ActNorm, ActDiff,
//!   TokenSim, AttnCon) plus the Eq. 4 normalization.
//! - [`pipeline`] — the thin coordinator implementing RTN, GPTQ, QuaRot,
//!   SQ (scale w/o rotate), RSQ (rotate+scale) and the VQ variants, with
//!   streaming Hessian accumulation and dataset expansion.
//! - [`sched`] — the staged scheduler the coordinator delegates to: pass
//!   A / solve / pass B stages dispatched over a `util::Pool` (`--jobs`)
//!   in staged or cross-layer-pipelined order (`--sched`), with
//!   fixed-order reductions that keep every combination bit-identical to
//!   the serial path (DESIGN.md §Threading).
//! - [`artifact`] — quantization output as a deployment artifact: the
//!   packed on-disk format behind `rsq quantize --save` / `rsq eval
//!   --artifact`, and the content-addressed Hessian cache that lets
//!   repeat runs skip pass A entirely (DESIGN.md §9).
//! - [`alloc`] — layer-adaptive mixed-precision bit allocation: per-module
//!   widths from `PACK_BITS` solved under `--avg-bits` / `--budget-bytes`
//!   by a deterministic greedy marginal-gain allocator over the pass-A
//!   Hessian sensitivities (DESIGN.md §14).
//! - [`vq`] — E8-derived codebook construction for Tab. 6.

pub mod alloc;
pub mod artifact;
pub mod pipeline;
pub mod sched;
pub mod strategy;
pub mod vq;

pub use alloc::{Allocation, BitBudget};
pub use pipeline::{quantize, LayerTiming, Method, QuantOptions, QuantReport};
pub use sched::SchedMode;
pub use strategy::Strategy;
