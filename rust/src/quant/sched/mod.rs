//! The staged quantization scheduler: how the per-layer phases of
//! DESIGN.md §2 are ordered and dispatched over the [`Pool`].
//!
//! `pipeline::quantize` owns *what* each phase computes (method, strategy,
//! options); this module owns *when and where* it runs. The stages are:
//!
//! - `passes` — the per-batch work: embedding, pass-A capture + partial
//!   Hessians, pass-B re-forwarding, and the fused pass-B/pass-A step;
//! - `solve` — the per-weight work: the seven-module solve fan-out and
//!   the data-free RTN grid.
//!
//! Every stage dispatches through [`Pool::run`], [`Pool::run_windowed`]
//! or [`Pool::update_windowed`], with all floating-point reductions in the
//! ordered consumer callbacks — the determinism contract of DESIGN.md §5
//! lives in those three call sites, not in per-stage loops.
//!
//! Two executors order the stages across layers ([`SchedMode`]):
//!
//! ```text
//! staged:     A₀ ‖ S₀ ‖ B₀ ‖ A₁ ‖ S₁ ‖ B₁ ‖ A₂ ‖ …      (‖ = pool barrier)
//! pipelined:  A₀ ‖ S₀ ‖ (B₀+A₁) ‖ S₁ ‖ (B₁+A₂) ‖ …
//! ```
//!
//! The pipelined executor fuses pass B of layer *l* with pass A of layer
//! *l+1* into one per-batch task: the re-forwarded hidden state feeds the
//! next layer's capture inside the task, eliminating one barrier and one
//! coordinator round-trip per batch per layer. Only the solve needs the
//! fully-reduced Hessians, so this is the only barrier the dataflow
//! actually requires — and because the fused task computes the *same*
//! per-batch values in the *same* reduction order, both modes (at any
//! `--jobs`) are bit-identical to the serial staged path.

pub(crate) mod passes;
pub(crate) mod solve;

use std::time::Instant;

use anyhow::Result;

use crate::model::config::ModelConfig;
use crate::model::ParamSet;
use crate::obs::{metrics, trace};
use crate::runtime::{Engine, SharedLiteral};
use crate::tensor::pack::RowGrid;
use crate::util::json::Json;
use crate::util::Pool;

use super::artifact::cache::LayerHessians;
use super::pipeline::{LayerTiming, QuantOptions, QuantReport};

/// How the per-layer phases are ordered across layers (`--sched`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Pass A, solve, pass B each run to completion per layer, with a
    /// full pool barrier at every phase edge.
    Staged,
    /// Pass B of layer *l* and pass A of layer *l+1* fuse into one
    /// per-batch task — one barrier and one hidden-state round-trip fewer
    /// per layer. Bit-identical to [`SchedMode::Staged`] (DESIGN.md §5).
    Pipelined,
}

impl SchedMode {
    /// Parse a CLI spelling; case-insensitive. Inverse of [`SchedMode::name`].
    pub fn parse(s: &str) -> Option<SchedMode> {
        match s.to_ascii_lowercase().as_str() {
            "staged" => Some(SchedMode::Staged),
            "pipelined" | "pipeline" => Some(SchedMode::Pipelined),
            _ => None,
        }
    }

    /// Canonical CLI spelling; `SchedMode::parse(m.name()) == Some(m)`.
    pub fn name(&self) -> &'static str {
        match self {
            SchedMode::Staged => "staged",
            SchedMode::Pipelined => "pipelined",
        }
    }
}

/// Borrowed per-run state every stage of one `quantize` call shares.
pub(crate) struct SchedCtx<'a> {
    pub engine: &'a Engine,
    pub cfg: &'a ModelConfig,
    pub opts: &'a QuantOptions,
    pub pool: &'a Pool,
    /// calibration batches (post expansion + padding); their index order
    /// is the reduction order of every per-batch phase
    pub batches: &'a [&'a [Vec<i32>]],
    /// corpus token-frequency table (TokenFreq strategy)
    pub freq: &'a [u32],
    /// `layer_fwd_t{t}` module name
    pub lname: String,
    /// `hess_d_t{t}` / `hess_ff_t{t}` module names
    pub hess_d: String,
    pub hess_ff: String,
    /// shared E8 codebook literal (VQ methods only)
    pub codebook: Option<SharedLiteral>,
    /// a partial module mask (Fig. 7) needs a second, uniform-weighted
    /// Hessian accumulator next to the scaled one
    pub needs_uniform: bool,
    /// keep each layer's reduced Hessians after its solve so the run can
    /// populate the content-addressed cache (DESIGN.md §9); off when the
    /// cache is disabled to avoid holding every layer's Hessians at once
    pub collect_hessians: bool,
    /// per-(layer, `Module::ALL`) solve widths from the mixed-precision
    /// allocator (DESIGN.md §14), indexed `l * 7 + mi`; None = every
    /// solve at the single global `opts.bits`
    pub widths: Option<Vec<u32>>,
}

impl SchedCtx<'_> {
    /// The bit width module `mi` of layer `l` solves at.
    pub(crate) fn width(&self, l: usize, mi: usize) -> u32 {
        match &self.widths {
            Some(w) => w[l * crate::model::config::Module::ALL.len() + mi],
            None => self.opts.bits,
        }
    }

    /// Largest quantization level for that width (per-solve-task
    /// counterpart of `QuantOptions::maxq`).
    pub(crate) fn maxq(&self, l: usize, mi: usize) -> f32 {
        ((1u64 << self.width(l, mi)) - 1) as f32
    }
}

/// Drive every layer through pass A → solve → pass B in the configured
/// [`SchedMode`], recording per-layer phase timings into the report.
/// Entered with the (possibly rotated) full-precision params; returns
/// with `p` fully quantized, plus the per-layer reduced Hessians when
/// `ctx.collect_hessians` asked for them (empty otherwise).
pub(crate) fn run_layers(
    ctx: &SchedCtx,
    p: &mut ParamSet,
    report: &mut QuantReport,
) -> Result<Vec<LayerHessians>> {
    // initial hidden states: embed every batch once (fans out per batch)
    let mut z = passes::embed(ctx, p)?;
    match ctx.opts.sched {
        SchedMode::Staged => staged(ctx, p, &mut z, report),
        SchedMode::Pipelined => pipelined(ctx, p, &mut z, report),
    }
}

/// The warm path: every layer's Hessians came from the content-addressed
/// cache, so pass A, pass B, and the embedding sweep are skipped entirely
/// and the run is solve-only. The solve consumes bit-identical Hessians
/// in the same order, so the quantized output is byte-identical to the
/// cold run that populated the cache.
pub(crate) fn run_layers_cached(
    ctx: &SchedCtx,
    p: &mut ParamSet,
    report: &mut QuantReport,
    hessians: Vec<LayerHessians>,
) -> Result<()> {
    assert_eq!(hessians.len(), ctx.cfg.layers, "cache entry layer count");
    for (l, lh) in hessians.into_iter().enumerate() {
        let acc = passes::HessAccum::from_layer_hessians(lh);
        let ts = Instant::now();
        let _sp = trace::span_with("quant", "sched.solve", || Json::obj().set("layer", l));
        let (errsum, grids) = solve::solve_layer(ctx, p, l, &acc)?;
        drop(_sp);
        report.layer_timings.push(LayerTiming {
            solve_seconds: ts.elapsed().as_secs_f64(),
            ..Default::default()
        });
        finish_layer(ctx, report, l, errsum, grids);
    }
    Ok(())
}

/// The barrier-per-phase executor (PR 1 behavior, kept as the reference
/// ordering the pipelined mode is tested against).
fn staged(
    ctx: &SchedCtx,
    p: &mut ParamSet,
    z: &mut [SharedLiteral],
    report: &mut QuantReport,
) -> Result<Vec<LayerHessians>> {
    let mut saved = Vec::new();
    for l in 0..ctx.cfg.layers {
        let mut lt = LayerTiming::default();

        let ta = Instant::now();
        let sp_a = trace::span_with("quant", "sched.pass_a", || Json::obj().set("layer", l));
        let lp = passes::layer_literals(p, l)?;
        let acc = passes::pass_a(ctx, z, &lp)?;
        drop(sp_a);
        lt.pass_a_seconds = ta.elapsed().as_secs_f64();
        drop(lp);

        let ts = Instant::now();
        let sp_s = trace::span_with("quant", "sched.solve", || Json::obj().set("layer", l));
        let (errsum, grids) = solve::solve_layer(ctx, p, l, &acc)?;
        drop(sp_s);
        lt.solve_seconds = ts.elapsed().as_secs_f64();
        finish_layer(ctx, report, l, errsum, grids);
        if ctx.collect_hessians {
            saved.push(acc.into_layer_hessians());
        }

        // pass B is skipped for the last layer: its outputs feed nothing
        // (saves 1/L of the re-forward cost; DESIGN.md §7)
        if l + 1 < ctx.cfg.layers {
            let tb = Instant::now();
            let sp_b = trace::span_with("quant", "sched.pass_b", || Json::obj().set("layer", l));
            let lp_q = passes::layer_literals(p, l)?;
            passes::pass_b(ctx, z, &lp_q)?;
            drop(sp_b);
            lt.pass_b_seconds = tb.elapsed().as_secs_f64();
        }
        report.layer_timings.push(lt);
    }
    Ok(saved)
}

/// The cross-layer pipelined executor: after each solve, pass B of the
/// just-quantized layer and pass A of the next run as one fused per-batch
/// sweep. Layer 0's pass A has no preceding pass B and runs standalone.
fn pipelined(
    ctx: &SchedCtx,
    p: &mut ParamSet,
    z: &mut [SharedLiteral],
    report: &mut QuantReport,
) -> Result<Vec<LayerHessians>> {
    let layers = ctx.cfg.layers;
    let mut timings = vec![LayerTiming::default(); layers];
    let mut saved = Vec::new();

    let ta = Instant::now();
    let sp_a = trace::span_with("quant", "sched.pass_a", || Json::obj().set("layer", 0usize));
    let lp0 = passes::layer_literals(p, 0)?;
    let mut acc = passes::pass_a(ctx, z, &lp0)?;
    drop(sp_a);
    drop(lp0);
    timings[0].pass_a_seconds = ta.elapsed().as_secs_f64();

    for l in 0..layers {
        let ts = Instant::now();
        let sp_s = trace::span_with("quant", "sched.solve", || Json::obj().set("layer", l));
        let (errsum, grids) = solve::solve_layer(ctx, p, l, &acc)?;
        drop(sp_s);
        timings[l].solve_seconds = ts.elapsed().as_secs_f64();
        finish_layer(ctx, report, l, errsum, grids);

        if l + 1 < layers {
            let tf = Instant::now();
            let sp_f =
                trace::span_with("quant", "sched.fused_b_a", || Json::obj().set("layer", l));
            let lp_q = passes::layer_literals(p, l)?;
            let lp_next = passes::layer_literals(p, l + 1)?;
            let next = passes::fused_b_a(ctx, z, &lp_q, &lp_next)?;
            drop(sp_f);
            timings[l].fused_seconds = tf.elapsed().as_secs_f64();
            let prev = std::mem::replace(&mut acc, next);
            if ctx.collect_hessians {
                saved.push(prev.into_layer_hessians());
            }
        } else if ctx.collect_hessians {
            saved.push(std::mem::take(&mut acc).into_layer_hessians());
        }
    }
    report.layer_timings.extend(timings);
    Ok(saved)
}

/// Record one layer's solve result (shared by every executor — staged,
/// pipelined, and the cached solve-only path — so the report and the
/// verbose trace are mode-independent).
fn finish_layer(
    ctx: &SchedCtx,
    report: &mut QuantReport,
    l: usize,
    errsum: f32,
    grids: Vec<Option<RowGrid>>,
) {
    report.layer_err.push(errsum);
    report.grids.extend(grids);
    // the per-layer reconstruction error the metrics record carries —
    // what layer-adaptive allocation consumes (LSAQ; DESIGN.md §16)
    if metrics::on() {
        metrics::gauge(&format!("quant.layer_err.l{l:03}"), errsum as f64);
    }
    if ctx.opts.verbose {
        crate::obs_info!(
            "[quant:{}] layer {l}: hessian-weighted err {errsum:.3}",
            ctx.opts.method.name()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sched_mode_parse_round_trip() {
        for m in [SchedMode::Staged, SchedMode::Pipelined] {
            assert_eq!(SchedMode::parse(m.name()), Some(m));
        }
        assert_eq!(SchedMode::parse("PIPELINED"), Some(SchedMode::Pipelined));
        assert_eq!(SchedMode::parse("pipeline"), Some(SchedMode::Pipelined), "alias");
        assert_eq!(SchedMode::parse("Staged"), Some(SchedMode::Staged));
        assert_eq!(SchedMode::parse(""), None);
        assert_eq!(SchedMode::parse("fused"), None);
    }
}
