//! Per-weight stages of the scheduler: the seven-module solve fan-out
//! (GPTQ / LDLQ-VQ) and the data-free RTN grid (DESIGN.md §2, §5).
//!
//! Host-side dense math inside these stages routes through the
//! `tensor::kernels` layer (DESIGN.md §10); the per-task work here —
//! `quantref::row_grid` capture and literal plumbing — is O(rows·cols)
//! with no dense product, so it stays serial *within* a task while the
//! seven tasks themselves fan out over the pool. Kernel-level pool
//! threading inside a task would oversubscribe the same workers.

use anyhow::Result;

use crate::model::config::{ModelConfig, Module};
use crate::model::ParamSet;
use crate::obs::trace;
use crate::quantref;
use crate::runtime::{self, Engine};
use crate::tensor::pack::RowGrid;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::Pool;

use crate::quant::pipeline::QuantOptions;

use super::passes::HessAccum;
use super::SchedCtx;

/// Solve one layer: the seven per-module quantizations fan out across the
/// pool; results are applied to `p` (and errors summed) in `Module::ALL`
/// order on the coordinator. Returns the layer's Hessian-weighted
/// reconstruction error Σ tr((W−Q)H(W−Q)ᵀ) plus each module's
/// quantization grid (None for the gridless VQ solve) — the grids are
/// what lets `quant::artifact` bit-pack the output (DESIGN.md §9).
pub(crate) fn solve_layer(
    ctx: &SchedCtx,
    p: &mut ParamSet,
    l: usize,
    acc: &HessAccum,
) -> Result<(f32, Vec<Option<RowGrid>>)> {
    let opts = ctx.opts;
    let solved = ctx.pool.run(Module::ALL.len(), |mi| -> Result<(Tensor, f32, Option<RowGrid>)> {
        let m = Module::ALL[mi];
        let _sp = trace::span_with("quant", "sched.solve_module", || {
            Json::obj().set("layer", l).set("module", format!("{m:?}"))
        });
        let scaled = match &opts.module_mask {
            Some(mask) => opts.method.scales() && mask.contains(&m),
            None => opts.method.scales(),
        };
        let h = acc.hessian(m.input_stream(), scaled, ctx.needs_uniform);
        let (o, i) = ctx.cfg.weight_shape(m);
        let w = p.weight(l, m);
        // the HLO solver fixes its grid from the pre-quant weight — mirror
        // it host-side so the artifact writer can recover exact codes
        // the width is per solve task (mixed-precision allocation,
        // DESIGN.md §14): maxq reaches the HLO solver as a runtime
        // scalar, so a per-module width needs no extra kernels
        let maxq = ctx.maxq(l, mi);
        let grid = if opts.method.vector_quant() {
            None
        } else {
            let (scale, zero) = quantref::row_grid(w, maxq);
            Some(RowGrid { scale, zero })
        };
        let w_lit = runtime::tensor_literal(w)?;
        let h_lit = runtime::tensor_literal(h)?;
        let damp_lit = runtime::scalar_literal(opts.damp);
        let maxq_lit = runtime::scalar_literal(maxq);
        let outs = if opts.method.vector_quant() {
            ctx.engine.exec_ref(
                &format!("ldlq_{o}x{i}"),
                &[&w_lit, &h_lit, ctx.codebook.as_ref().unwrap().get(), &damp_lit],
            )?
        } else {
            ctx.engine.exec_ref(
                &format!("gptq_{o}x{i}"),
                &[&w_lit, &h_lit, &maxq_lit, &damp_lit],
            )?
        };
        Ok((runtime::literal_tensor(&outs[0])?, runtime::literal_scalar(&outs[1])?, grid))
    });
    let mut errsum = 0.0f32;
    let mut grids = Vec::with_capacity(Module::ALL.len());
    for (m, s) in Module::ALL.into_iter().zip(solved) {
        let (q, err, grid) = s?;
        errsum += err;
        grids.push(grid);
        p.set_weight(l, m, q);
    }
    Ok((errsum, grids))
}

/// The RTN short-circuit: data-free, so every (layer, module) solve is
/// independent and the `layers × 7` weight grid sweeps through
/// `Pool::update_windowed` in one windowed dispatch — peak memory stays
/// O(jobs) quantized tensors. The weights are *moved* out of the
/// ParamSet for the sweep (gains/embeddings are untouched by RTN, and a
/// move avoids cloning anything) and spliced back quantized. Returns the
/// per-layer error sums (accumulated in `Module::ALL` order within each
/// layer exactly like the solve phase) and the per-weight grids for the
/// artifact writer.
pub(crate) fn rtn_grid(
    engine: &Engine,
    cfg: &ModelConfig,
    opts: &QuantOptions,
    pool: &Pool,
    p: &mut ParamSet,
) -> Result<(Vec<f32>, Vec<Option<RowGrid>>)> {
    let nmod = Module::ALL.len();
    let idxs: Vec<usize> = (0..cfg.layers)
        .flat_map(|l| Module::ALL.into_iter().map(move |m| cfg.param_index(l, m)))
        .collect();
    let mut weights: Vec<Tensor> = idxs
        .iter()
        .map(|&i| std::mem::replace(&mut p.tensors[i], Tensor::zeros(&[0])))
        .collect();
    let mut layer_err = Vec::with_capacity(cfg.layers);
    let mut grids = Vec::with_capacity(idxs.len());
    let mut errsum = 0.0f32;
    pool.update_windowed(
        &mut weights,
        |k, w: &Tensor| -> Result<(Tensor, (f32, Option<RowGrid>))> {
            let m = Module::ALL[k % nmod];
            let (o, i) = cfg.weight_shape(m);
            let (scale, zero) = quantref::row_grid(w, opts.maxq());
            let outs = engine.exec_ref(
                &format!("rtn_{o}x{i}"),
                &[&runtime::tensor_literal(w)?, &runtime::scalar_literal(opts.maxq())],
            )?;
            let q = runtime::literal_tensor(&outs[0])?;
            let err = q.sub(w).frob_norm().powi(2);
            Ok((q, (err, Some(RowGrid { scale, zero }))))
        },
        |k, (err, grid)| {
            errsum += err;
            grids.push(grid);
            if k % nmod == nmod - 1 {
                layer_err.push(errsum);
                errsum = 0.0;
            }
            Ok(())
        },
    )?;
    // on success every slot holds its quantized weight; on error the run
    // aborts and the gutted ParamSet is dropped with it. The slots hold
    // empty placeholders here (so set_weight's slot-shape assertion can't
    // apply) — check each spliced-back tensor against the config instead.
    let mut quantized = weights.into_iter();
    for l in 0..cfg.layers {
        for m in Module::ALL {
            let q = quantized.next().unwrap();
            let (o, i) = cfg.weight_shape(m);
            assert_eq!(q.shape, [o, i], "rtn output shape mismatch at layer {l} {m:?}");
            p.tensors[cfg.param_index(l, m)] = q;
        }
    }
    Ok((layer_err, grids))
}
