//! Per-batch stages of the scheduler: embedding, pass-A capture, pass-B
//! re-forwarding, and the fused pass-B/pass-A step of the pipelined
//! executor (DESIGN.md §2, §5).
//!
//! Every function here follows the same shape: workers compute
//! *independent* per-batch values through [`Pool::run`]-family dispatch,
//! and the coordinator folds them in batch order via [`HessAccum`] — the
//! fixed association order that makes every `--jobs` and [`SchedMode`]
//! combination bit-identical to the serial staged path.
//!
//! [`Pool::run`]: crate::util::Pool::run
//! [`SchedMode`]: super::SchedMode

use anyhow::Result;

use crate::model::config::InputStream;
use crate::model::ParamSet;
use crate::obs::{metrics, trace};
use crate::runtime::{self, SharedLiteral};
use crate::tensor::Tensor;
use crate::util::json::Json;

use crate::quant::artifact::cache::LayerHessians;
use crate::quant::strategy::{LayerScores, Strategy};

use super::SchedCtx;

/// Per-batch pass-A output: one partial Hessian per input stream, in
/// [`InputStream`] order, plus the uniform-weighted set when a partial
/// module mask needs both (Fig. 7).
pub(crate) struct BatchHessians {
    scaled: Vec<Tensor>,
    uniform: Option<Vec<Tensor>>,
}

/// Coordinator-side Hessian accumulator for one layer: the reduction of
/// every batch's [`BatchHessians`], folded strictly in batch order.
#[derive(Default)]
pub(crate) struct HessAccum {
    scaled: [Option<Tensor>; 4],
    uniform: [Option<Tensor>; 4],
}

impl HessAccum {
    /// Fold one batch's partial Hessians in. Callers must invoke this in
    /// batch order — the ordered consumer of a windowed dispatch — so the
    /// floating-point sum associates exactly like the serial loop.
    fn absorb(&mut self, bh: BatchHessians) {
        for (si, h) in bh.scaled.into_iter().enumerate() {
            accumulate(&mut self.scaled[si], h);
        }
        if let Some(us) = bh.uniform {
            for (si, h) in us.into_iter().enumerate() {
                accumulate(&mut self.uniform[si], h);
            }
        }
    }

    /// Freeze the fully-reduced accumulators into the cacheable form
    /// (`quant::artifact::cache`). Call only after pass A ran over at
    /// least one batch.
    pub fn into_layer_hessians(self) -> LayerHessians {
        let take = |slots: [Option<Tensor>; 4]| -> Vec<Tensor> {
            slots
                .into_iter()
                .map(|s| s.expect("pass A accumulated no Hessian for this stream"))
                .collect()
        };
        let uniform = self.uniform.iter().all(Option::is_some);
        LayerHessians {
            scaled: take(self.scaled),
            uniform: if uniform { Some(take(self.uniform)) } else { None },
        }
    }

    /// Rehydrate from a cache entry — the warm path's stand-in for pass A
    /// (`sched::run_layers_cached`).
    pub fn from_layer_hessians(lh: LayerHessians) -> HessAccum {
        assert_eq!(lh.scaled.len(), 4, "cache entry stream count");
        let mut acc = HessAccum::default();
        for (si, t) in lh.scaled.into_iter().enumerate() {
            acc.scaled[si] = Some(t);
        }
        if let Some(us) = lh.uniform {
            assert_eq!(us.len(), 4, "cache entry uniform stream count");
            for (si, t) in us.into_iter().enumerate() {
                acc.uniform[si] = Some(t);
            }
        }
        acc
    }

    /// The Hessian a module's solve should quantize against: the scaled
    /// accumulator when the module is importance-weighted, the uniform
    /// one when a partial mask left it unscaled (Fig. 7). When the method
    /// doesn't scale at all the "scaled" accumulator already holds the
    /// uniform sum (`Strategy::Uniform`), so it serves both.
    pub fn hessian(&self, stream: InputStream, scaled: bool, needs_uniform: bool) -> &Tensor {
        let si = stream_index(stream);
        let slot = if !scaled && needs_uniform { &self.uniform[si] } else { &self.scaled[si] };
        slot.as_ref().expect("pass A accumulated no Hessian for this stream")
    }
}

/// Index of an input stream inside the pass-A Hessian accumulators.
fn stream_index(s: InputStream) -> usize {
    match s {
        InputStream::Xa => 0,
        InputStream::Xo => 1,
        InputStream::Xf => 2,
        InputStream::Xd => 3,
    }
}

fn accumulate(acc: &mut Option<Tensor>, h: Tensor) {
    match acc {
        Some(a) => a.add_in_place(&h),
        None => *acc = Some(h),
    }
}

fn rows_of(t: &Tensor) -> Vec<Vec<f32>> {
    let (r, c) = (t.shape[0], t.shape[1]);
    (0..r).map(|i| t.data[i * c..(i + 1) * c].to_vec()).collect()
}

/// The nine tensors of layer `l` as shareable literals, in parameter
/// order (g1, wq, wk, wv, wo, g2, wup, wgate, wdown).
pub(crate) fn layer_literals(p: &ParamSet, l: usize) -> Result<Vec<SharedLiteral>> {
    let base = 2 + l * 9;
    (0..9).map(|k| runtime::shared_literal(&p.tensors[base + k])).collect()
}

/// One batch through `layer_fwd` with the given layer params; returns all
/// nine outputs (z2, the four capture streams, the four score streams).
fn layer_fwd(ctx: &SchedCtx, z: &xla::Literal, lp: &[SharedLiteral]) -> Result<Vec<xla::Literal>> {
    let mut ins: Vec<&xla::Literal> = Vec::with_capacity(10);
    ins.push(z);
    ins.extend(lp.iter().map(SharedLiteral::get));
    ctx.engine.exec_ref(&ctx.lname, &ins)
}

/// Turn one batch's `layer_fwd` outputs into its partial Hessians: score
/// streams → importance R (Sec. 4.3 + Eq. 4) → `H = 2·X·R²·Xᵀ` per
/// capture stream via the L1 Pallas kernel. Runs inside a worker task.
fn batch_hessians(ctx: &SchedCtx, bi: usize, outs: &[xla::Literal]) -> Result<BatchHessians> {
    let t = ctx.opts.seq_len;
    let _sp = trace::span_with("quant", "sched.batch_hessians", || Json::obj().set("batch", bi));
    // outs: z2, xa, xo, xf, xd, attn_con, act_norm, act_diff, token_sim
    let scores = LayerScores {
        attn_con: rows_of(&runtime::literal_tensor(&outs[5])?),
        act_norm: rows_of(&runtime::literal_tensor(&outs[6])?),
        act_diff: rows_of(&runtime::literal_tensor(&outs[7])?),
        token_sim: rows_of(&runtime::literal_tensor(&outs[8])?),
    };
    // the paper's per-token attention-concentration measurement (RSQ
    // §3), summarized into the metrics record as a ×1e6 fixed-point
    // distribution instead of being computed and thrown away
    if metrics::on() {
        metrics::hist_many(
            "quant.attn_con_x1e6",
            scores.attn_con.iter().flatten().map(|&x| (f64::from(x.max(0.0)) * 1e6) as u64),
        );
    }
    let strategy = if ctx.opts.method.scales() { ctx.opts.strategy } else { Strategy::Uniform };
    let batch = ctx.batches[bi];
    let r = strategy.importance(
        ctx.cfg, t, batch.len(), Some(&scores), Some(batch), Some(ctx.freq));
    let r_lit = runtime::tensor_literal(&Tensor::from_vec(
        &[batch.len(), t],
        r.iter().flatten().cloned().collect(),
    ))?;
    let uni_lit = if ctx.needs_uniform {
        Some(runtime::tensor_literal(&Tensor::ones(&[batch.len(), t]))?)
    } else {
        None
    };
    let mut scaled = Vec::with_capacity(4);
    let mut uniform = uni_lit.as_ref().map(|_| Vec::with_capacity(4));
    for (si, xout) in [(0usize, 1usize), (1, 2), (2, 3), (3, 4)] {
        let hess_mod = if si == 3 { &ctx.hess_ff } else { &ctx.hess_d };
        let h = ctx.engine.exec_ref(hess_mod, &[&outs[xout], &r_lit])?;
        scaled.push(runtime::literal_tensor(&h[0])?);
        if let (Some(u), Some(ul)) = (uniform.as_mut(), uni_lit.as_ref()) {
            let hu = ctx.engine.exec_ref(hess_mod, &[&outs[xout], ul])?;
            u.push(runtime::literal_tensor(&hu[0])?);
        }
    }
    Ok(BatchHessians { scaled, uniform })
}

/// Initial hidden states: embed every calibration batch (one task per
/// batch; the results are the scheduler's per-batch state from here on).
pub(crate) fn embed(ctx: &SchedCtx, p: &ParamSet) -> Result<Vec<SharedLiteral>> {
    let t = ctx.opts.seq_len;
    let ename = format!("embed_t{t}");
    let emb_lit = runtime::shared_literal(&p.tensors[0])?;
    let pos_lit = runtime::shared_literal(&p.tensors[1])?;
    ctx.pool
        .run(ctx.batches.len(), |bi| -> Result<SharedLiteral> {
            let tl = runtime::tokens_literal(ctx.batches[bi], t)?;
            let z = ctx.engine.exec_ref(&ename, &[&tl, emb_lit.get(), pos_lit.get()])?;
            Ok(z.into_iter().next().unwrap().into())
        })
        .into_iter()
        .collect()
}

/// Pass A for one layer: capture + per-batch partial Hessians fan out in
/// windows; the coordinator folds them in batch order.
pub(crate) fn pass_a(
    ctx: &SchedCtx,
    z: &[SharedLiteral],
    lp: &[SharedLiteral],
) -> Result<HessAccum> {
    let mut acc = HessAccum::default();
    ctx.pool.run_windowed(
        z.len(),
        |bi| -> Result<BatchHessians> {
            let outs = layer_fwd(ctx, z[bi].get(), lp)?;
            batch_hessians(ctx, bi, &outs)
        },
        |_, bh: Result<BatchHessians>| -> Result<()> {
            acc.absorb(bh?);
            Ok(())
        },
    )?;
    Ok(acc)
}

/// Pass B for one layer: re-forward every batch's hidden state through
/// the now-quantized layer, replacing each slot in place per window.
pub(crate) fn pass_b(ctx: &SchedCtx, z: &mut [SharedLiteral], lp_q: &[SharedLiteral]) -> Result<()> {
    ctx.pool.update_windowed(
        z,
        |_, zi| -> Result<(SharedLiteral, ())> {
            let outs = layer_fwd(ctx, zi.get(), lp_q)?;
            Ok((outs.into_iter().next().unwrap().into(), ()))
        },
        |_, ()| Ok(()),
    )
}

/// The pipelined executor's fused step: pass B of layer *l* and pass A of
/// layer *l+1* as **one** per-batch task. The freshly re-forwarded hidden
/// state feeds the next layer's capture inside the task — no coordinator
/// round-trip, no barrier between the two phases. Arithmetic and
/// reduction order are exactly those of `pass_b` followed by `pass_a`,
/// so the fusion is invisible in the output bits (DESIGN.md §5).
pub(crate) fn fused_b_a(
    ctx: &SchedCtx,
    z: &mut [SharedLiteral],
    lp_q: &[SharedLiteral],
    lp_next: &[SharedLiteral],
) -> Result<HessAccum> {
    let mut acc = HessAccum::default();
    ctx.pool.update_windowed(
        z,
        |bi, zi| -> Result<(SharedLiteral, BatchHessians)> {
            let z2: SharedLiteral =
                layer_fwd(ctx, zi.get(), lp_q)?.into_iter().next().unwrap().into();
            let outs = layer_fwd(ctx, z2.get(), lp_next)?;
            let bh = batch_hessians(ctx, bi, &outs)?;
            Ok((z2, bh))
        },
        |_, bh| {
            acc.absorb(bh);
            Ok(())
        },
    )?;
    Ok(acc)
}
