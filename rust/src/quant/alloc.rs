//! Layer-adaptive mixed-precision bit allocation (DESIGN.md §14).
//!
//! After pass A every (layer, module) slot has a damped Hessian; this
//! module scores each slot's reconstruction sensitivity at every packable
//! width (`PACK_BITS`) with the host GPTQ oracle — one Cholesky factor
//! per slot, reused across widths — and solves for per-module widths
//! under a byte or average-bit budget (`--budget-bytes` / `--avg-bits`)
//! with a deterministic greedy marginal-gain allocator: every slot starts
//! at the 2-bit floor and the upgrade with the largest error reduction
//! per extra budget unit is applied first, tie-broken on (layer, module)
//! order. Nothing here depends on `--jobs` or `--sched`: scoring fans out
//! over the pool but lands in slot order, and the greedy solve is pure
//! host arithmetic — so the allocation (and therefore the quantized
//! output) is bit-invariant across every scheduler configuration, and
//! across warm-vs-cold Hessian cache (cached Hessians are exact f32).
//!
//! `pipeline::quantize` drives the two-phase flow: a proxy pass at the
//! single reference width `opts.bits` collects the Hessians (or a cache
//! hit supplies them), the allocator picks widths, and a solve-only sweep
//! re-quantizes the kept rotated full-precision params at those widths.

use anyhow::{bail, Result};

use crate::model::config::Module;
use crate::model::ParamSet;
use crate::quantref;
use crate::tensor::linalg::hinv_cholesky_upper;
use crate::tensor::pack::{row_bytes, PACK_BITS};
use crate::util::Pool;

use super::artifact::cache::LayerHessians;
use super::pipeline::QuantOptions;
use super::sched::passes::HessAccum;

/// The resource budget a mixed-precision run allocates under
/// (`--avg-bits` / `--budget-bytes`, mutually exclusive with each other
/// and with a plain global `--bits`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BitBudget {
    /// `--avg-bits X`: numel-weighted average width over the packed layer
    /// weights must not exceed X (budget in bit units: Σ numel·width ≤
    /// X·Σ numel).
    AvgBits(f32),
    /// `--budget-bytes N`: total packed weight bytes — codes plus the
    /// 8-byte-per-row f32 grid — must not exceed N.
    Bytes(u64),
}

impl BitBudget {
    /// Provenance spelling recorded in `QuantReport` and the artifact
    /// manifest (`budget=` key).
    pub fn spec(&self) -> String {
        match self {
            BitBudget::AvgBits(x) => format!("avg-bits:{x}"),
            BitBudget::Bytes(n) => format!("budget-bytes:{n}"),
        }
    }
}

/// The allocator's output: one width per (layer, `Module::ALL`) slot, in
/// `QuantReport::grids` order, plus the achieved budget accounting.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    /// chosen width per slot, each from [`PACK_BITS`]
    pub widths: Vec<u32>,
    /// achieved numel-weighted average width
    pub avg_bits: f32,
    /// total packed bytes under this allocation (codes + per-row grids)
    pub packed_bytes: u64,
    /// the budget spec that drove the solve ([`BitBudget::spec`])
    pub budget: String,
}

/// Packed on-disk/resident bytes of one (rows × cols) weight at `bits`:
/// the per-row f32 scale+zero grid plus the LSB-first code stream —
/// exactly `tensor::pack`'s layout, which `artifact::save` writes.
pub fn packed_weight_bytes(rows: usize, cols: usize, bits: u32) -> u64 {
    rows as u64 * 8 + rows as u64 * row_bytes(cols, bits) as u64
}

/// Per-slot (rows, cols) for every (layer, `Module::ALL`) slot.
fn slot_dims(p: &ParamSet) -> Vec<(usize, usize)> {
    let cfg = &p.cfg;
    (0..cfg.layers)
        .flat_map(|_| Module::ALL.into_iter().map(|m| cfg.weight_shape(m)))
        .collect()
}

/// Score every slot's Hessian-weighted reconstruction error at every
/// packable width. Fans out over the pool — one task per slot, results
/// landed in slot order — with the Cholesky factor of the damped inverse
/// Hessian computed once per slot and reused across the widths (the
/// width only changes the grid, not the factor). The Hessian each slot
/// scores against mirrors `sched::solve::solve_layer`'s selection
/// (scaled vs uniform under a partial module mask) exactly, so the
/// scores rank the same objective the final solve minimizes.
pub(crate) fn score(
    p: &ParamSet,
    hessians: &[LayerHessians],
    opts: &QuantOptions,
    needs_uniform: bool,
    pool: &Pool,
) -> Vec<[f32; PACK_BITS.len()]> {
    let nmod = Module::ALL.len();
    let accs: Vec<HessAccum> =
        hessians.iter().map(|lh| HessAccum::from_layer_hessians(lh.clone())).collect();
    pool.run(accs.len() * nmod, |k| {
        let (l, mi) = (k / nmod, k % nmod);
        let m = Module::ALL[mi];
        let scaled = match &opts.module_mask {
            Some(mask) => opts.method.scales() && mask.contains(&m),
            None => opts.method.scales(),
        };
        let h = accs[l].hessian(m.input_stream(), scaled, needs_uniform);
        let w = p.weight(l, m);
        let u = hinv_cholesky_upper(h, opts.damp, None);
        let mut errs = [0.0f32; PACK_BITS.len()];
        for (bi, &b) in PACK_BITS.iter().enumerate() {
            let maxq = ((1u64 << b) - 1) as f32;
            errs[bi] = quantref::gptq_with_factor(w, h, &u, maxq).1;
        }
        errs
    })
}

/// The deterministic greedy marginal-gain solve, pure host arithmetic.
/// Every slot starts at `PACK_BITS[0]`; while budget remains, the ladder
/// upgrade (2→3→4→8) with the largest error reduction per extra budget
/// unit is applied, tie-broken on the smallest slot index — i.e. fixed
/// (layer, module) order — so the result is a pure function of the
/// scores, dims, and budget. Errors when even the all-2-bit floor does
/// not fit.
pub fn solve_widths(
    errs: &[[f32; PACK_BITS.len()]],
    dims: &[(usize, usize)],
    budget: &BitBudget,
) -> Result<Vec<u32>> {
    assert_eq!(errs.len(), dims.len(), "one score row per slot");
    let cost = |s: usize, bi: usize| -> u64 {
        let (rows, cols) = dims[s];
        match budget {
            BitBudget::AvgBits(_) => rows as u64 * cols as u64 * PACK_BITS[bi] as u64,
            BitBudget::Bytes(_) => packed_weight_bytes(rows, cols, PACK_BITS[bi]),
        }
    };
    let total_numel: u64 = dims.iter().map(|&(r, c)| r as u64 * c as u64).sum();
    let total_budget: u64 = match budget {
        BitBudget::AvgBits(x) => {
            if !x.is_finite() || *x <= 0.0 {
                bail!("--avg-bits {x} is not a positive width");
            }
            (*x as f64 * total_numel as f64).floor() as u64
        }
        BitBudget::Bytes(n) => *n,
    };
    let mut level = vec![0usize; errs.len()];
    let mut spent: u64 = (0..errs.len()).map(|s| cost(s, 0)).sum();
    if spent > total_budget {
        let floor = PACK_BITS[0];
        match budget {
            BitBudget::AvgBits(x) => bail!(
                "--avg-bits {x} is below the {floor}-bit floor — the packed formats \
                 support widths {PACK_BITS:?}, so the average cannot go under {floor}"
            ),
            BitBudget::Bytes(n) => {
                let floor_bytes: u64 =
                    dims.iter().map(|&(r, c)| packed_weight_bytes(r, c, floor)).sum();
                bail!(
                    "--budget-bytes {n} is below the all-{floor}-bit floor of {floor_bytes} \
                     bytes for this model — pass at least {floor_bytes}"
                );
            }
        }
    }
    loop {
        // the upgrade with the best error-reduction per extra budget
        // unit that still fits; strict `>` keeps the smallest slot on a
        // ratio tie, making the pick order total and jobs-independent
        let mut best: Option<(f64, usize)> = None;
        for s in 0..errs.len() {
            if level[s] + 1 >= PACK_BITS.len() {
                continue;
            }
            let dcost = cost(s, level[s] + 1) - cost(s, level[s]);
            if dcost > total_budget - spent {
                continue;
            }
            // clamp: the oracle's error is monotone non-increasing in
            // width up to float noise; a slightly negative gain must not
            // poison the ratio ordering
            let gain = f64::from((errs[s][level[s]] - errs[s][level[s] + 1]).max(0.0));
            let ratio = gain / dcost as f64;
            if best.map(|(r, _)| ratio > r).unwrap_or(true) {
                best = Some((ratio, s));
            }
        }
        match best {
            Some((_, s)) => {
                spent += cost(s, level[s] + 1) - cost(s, level[s]);
                level[s] += 1;
            }
            None => break,
        }
    }
    Ok(level.into_iter().map(|bi| PACK_BITS[bi]).collect())
}

/// Assemble the achieved-budget accounting for a width vector.
pub fn accounting(widths: &[u32], dims: &[(usize, usize)], budget: &BitBudget) -> Allocation {
    let mut bit_sum = 0u64;
    let mut numel_sum = 0u64;
    let mut bytes = 0u64;
    for (&b, &(r, c)) in widths.iter().zip(dims) {
        bit_sum += r as u64 * c as u64 * b as u64;
        numel_sum += r as u64 * c as u64;
        bytes += packed_weight_bytes(r, c, b);
    }
    Allocation {
        widths: widths.to_vec(),
        avg_bits: (bit_sum as f64 / numel_sum as f64) as f32,
        packed_bytes: bytes,
        budget: budget.spec(),
    }
}

/// Score + solve + account: the entry `pipeline::quantize` calls between
/// obtaining the Hessians and the final solve-only sweep.
pub(crate) fn allocate(
    p: &ParamSet,
    hessians: &[LayerHessians],
    opts: &QuantOptions,
    needs_uniform: bool,
    pool: &Pool,
    budget: &BitBudget,
) -> Result<Allocation> {
    let errs = score(p, hessians, opts, needs_uniform, pool);
    let dims = slot_dims(p);
    let widths = solve_widths(&errs, &dims, budget)?;
    Ok(accounting(&widths, &dims, budget))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Pcg;

    /// A synthetic scoring instance: per-slot errors that decay with
    /// width at slot-dependent rates, so the allocator has real choices.
    fn instance(n: usize, seed: u64) -> (Vec<[f32; PACK_BITS.len()]>, Vec<(usize, usize)>) {
        let mut rng = Pcg::new(seed);
        let errs = (0..n)
            .map(|_| {
                let base = 1.0 + 10.0 * rng.f32();
                let decay = 0.2 + 0.6 * rng.f32();
                let mut e = [0.0f32; PACK_BITS.len()];
                for (bi, slot) in e.iter_mut().enumerate() {
                    *slot = base * decay.powi(bi as i32);
                }
                e
            })
            .collect();
        let dims = (0..n)
            .map(|k| if k % 2 == 0 { (16, 32) } else { (32, 16) })
            .collect();
        (errs, dims)
    }

    fn avg_bits(widths: &[u32], dims: &[(usize, usize)]) -> f64 {
        let bits: u64 =
            widths.iter().zip(dims).map(|(&b, &(r, c))| b as u64 * (r * c) as u64).sum();
        let numel: u64 = dims.iter().map(|&(r, c)| (r * c) as u64).sum();
        bits as f64 / numel as f64
    }

    #[test]
    fn budget_is_never_exceeded() {
        let (errs, dims) = instance(14, 1);
        for avg in [2.0f32, 2.25, 2.5, 3.0, 3.5, 4.0, 5.0, 8.0, 11.0] {
            let w = solve_widths(&errs, &dims, &BitBudget::AvgBits(avg)).unwrap();
            assert!(
                avg_bits(&w, &dims) <= avg as f64 + 1e-9,
                "avg {avg}: achieved {}",
                avg_bits(&w, &dims)
            );
            assert!(w.iter().all(|b| PACK_BITS.contains(b)));
        }
        let floor: u64 = dims.iter().map(|&(r, c)| packed_weight_bytes(r, c, 2)).sum();
        let ceil: u64 = dims.iter().map(|&(r, c)| packed_weight_bytes(r, c, 8)).sum();
        let mut bytes = floor;
        while bytes <= ceil + 64 {
            let w = solve_widths(&errs, &dims, &BitBudget::Bytes(bytes)).unwrap();
            let a = accounting(&w, &dims, &BitBudget::Bytes(bytes));
            assert!(a.packed_bytes <= bytes, "budget {bytes}: used {}", a.packed_bytes);
            bytes += (ceil - floor) / 7 + 1;
        }
    }

    #[test]
    fn achieved_avg_bits_monotone_in_budget() {
        let (errs, dims) = instance(14, 2);
        let mut prev = 0.0f64;
        for avg in [2.0f32, 2.2, 2.5, 2.8, 3.0, 3.3, 3.7, 4.0, 5.0, 6.5, 8.0] {
            let w = solve_widths(&errs, &dims, &BitBudget::AvgBits(avg)).unwrap();
            let got = avg_bits(&w, &dims);
            assert!(got >= prev - 1e-9, "avg {avg}: achieved {got} < previous {prev}");
            prev = got;
        }
        assert_eq!(prev, 8.0, "an 8-bit average budget saturates every slot");
    }

    #[test]
    fn total_error_monotone_non_increasing_in_budget() {
        let (errs, dims) = instance(14, 3);
        let total = |w: &[u32]| -> f64 {
            w.iter()
                .enumerate()
                .map(|(s, &b)| {
                    let bi = PACK_BITS.iter().position(|&x| x == b).unwrap();
                    f64::from(errs[s][bi])
                })
                .sum()
        };
        let mut prev = f64::INFINITY;
        for avg in [2.0f32, 2.5, 3.0, 3.5, 4.0, 6.0, 8.0] {
            let w = solve_widths(&errs, &dims, &BitBudget::AvgBits(avg)).unwrap();
            let e = total(&w);
            assert!(e <= prev + 1e-9, "avg {avg}: error {e} > previous {prev}");
            prev = e;
        }
    }

    #[test]
    fn greedy_prefers_the_most_sensitive_slot() {
        // slot 0 gains hugely from width, slot 1 barely: a budget with
        // room for exactly one upgrade must spend it on slot 0
        let errs = vec![[100.0f32, 1.0, 0.5, 0.25], [1.0, 0.9, 0.8, 0.7]];
        let dims = vec![(4, 8), (4, 8)];
        // floor = 2 bits avg; one slot to 3 bits = 2.5 avg
        let w = solve_widths(&errs, &dims, &BitBudget::AvgBits(2.5)).unwrap();
        assert_eq!(w, vec![3, 2]);
    }

    #[test]
    fn ratio_ties_break_on_slot_order() {
        // identical slots: the earlier (layer, module) slot upgrades first
        let errs = vec![[4.0f32, 2.0, 1.0, 0.5]; 3];
        let dims = vec![(4, 8); 3];
        let w = solve_widths(&errs, &dims, &BitBudget::AvgBits(2.34)).unwrap();
        assert_eq!(w, vec![3, 2, 2], "tie must go to the smallest slot index");
    }

    #[test]
    fn infeasible_budgets_error_actionably() {
        let (errs, dims) = instance(4, 4);
        let err = solve_widths(&errs, &dims, &BitBudget::AvgBits(1.5))
            .unwrap_err()
            .to_string();
        assert!(err.contains("2-bit floor"), "{err}");
        let err = solve_widths(&errs, &dims, &BitBudget::Bytes(16)).unwrap_err().to_string();
        assert!(err.contains("pass at least"), "{err}");
        assert!(
            solve_widths(&errs, &dims, &BitBudget::AvgBits(f32::NAN)).is_err(),
            "NaN budget must be rejected"
        );
    }

    #[test]
    fn allocation_identical_across_pool_sizes() {
        // the scoring fan-out lands results in slot order, so the widths
        // must be bit-identical at every jobs count — the allocator's
        // share of the --jobs invariance contract. Exercised through
        // score() itself with synthetic weights + Hessians.
        use crate::model::config::ModelConfig;
        use crate::quant::pipeline::Method;
        let cfg = ModelConfig {
            name: "tiny".into(),
            d: 8,
            layers: 2,
            heads: 2,
            ff: 16,
            vocab: 32,
            max_seq: 16,
            batch: 2,
            seq_lens: vec![16],
            ldlq_k: 16,
            ldlq_g: 2,
        };
        let p = ParamSet::init(&cfg, 7);
        let mut rng = Pcg::new(11);
        let hess = |k: usize| -> Tensor {
            let x: Vec<Vec<f32>> =
                (0..3 * k).map(|_| (0..k).map(|_| rng.normal()).collect()).collect();
            quantref::hessian_scaled(&x, &vec![1.0; 3 * k])
        };
        let hessians: Vec<LayerHessians> = (0..cfg.layers)
            .map(|_| LayerHessians {
                scaled: vec![hess(cfg.d), hess(cfg.d), hess(cfg.d), hess(cfg.ff)],
                uniform: None,
            })
            .collect();
        let opts = QuantOptions::new(Method::Rsq, 3, 16);
        let dims = slot_dims(&p);
        let mut reference: Option<(Vec<[f32; PACK_BITS.len()]>, Vec<u32>)> = None;
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            let errs = score(&p, &hessians, &opts, false, &pool);
            let w = solve_widths(&errs, &dims, &BitBudget::AvgBits(3.0)).unwrap();
            match &reference {
                None => reference = Some((errs, w)),
                Some((e0, w0)) => {
                    for (a, b) in errs.iter().flatten().zip(e0.iter().flatten()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "jobs={jobs} score drift");
                    }
                    assert_eq!(&w, w0, "jobs={jobs} allocation drift");
                }
            }
        }
    }

    #[test]
    fn budget_spec_spelling() {
        assert_eq!(BitBudget::AvgBits(3.0).spec(), "avg-bits:3");
        assert_eq!(BitBudget::Bytes(4096).spec(), "budget-bytes:4096");
    }

    #[test]
    fn packed_bytes_match_pack_layout() {
        // rows*8 grid bytes + rows*ceil(cols*bits/8) code bytes — pinned
        // against tensor::pack's row_bytes so the budget accounting and
        // the artifact writer can never drift
        assert_eq!(packed_weight_bytes(2, 3, 2), 2 * 8 + 2); // 1 code byte/row
        assert_eq!(packed_weight_bytes(4, 64, 3), 4 * 8 + 4 * 24);
        assert_eq!(packed_weight_bytes(1, 1, 8), 8 + 1);
    }
}
