//! E8-derived codebook construction for RSQ+VQ (paper Tab. 6).
//!
//! QuIP#'s E8P codebook is built from the E8 lattice (all-integer or
//! all-half-integer 8-vectors with even coordinate sum), whose packing
//! optimality makes it the right shape for 8-dim weight groups. The paper
//! swaps GPTQ's scalar grid for this codebook and the quantizer for LDLQ.
//!
//! Offline substitute (DESIGN.md): we enumerate low-norm E8 lattice points,
//! scale them to unit RMS, and keep the `k` lowest-norm ones (ties broken
//! deterministically), padding with seeded Gaussian-projected lattice points
//! if the shell enumeration runs short. K is the artifact-baked `ldlq_k`.

use crate::tensor::Tensor;
use crate::util::Pcg;

/// Build a [k, 8] codebook of E8 lattice points scaled so typical
/// unit-RMS weight groups are covered. Memoized per (k, seed): the shell
/// enumeration costs ~150 ms and every VQ quantization run needs the same
/// book (DESIGN.md §Perf).
pub fn e8_codebook(k: usize, seed: u64) -> Tensor {
    use std::sync::Mutex;
    static CACHE: Mutex<Vec<((usize, u64), Tensor)>> = Mutex::new(Vec::new());
    let mut cache = CACHE.lock().unwrap();
    if let Some((_, t)) = cache.iter().find(|(key, _)| *key == (k, seed)) {
        return t.clone();
    }
    let t = e8_codebook_uncached(k, seed);
    cache.push(((k, seed), t.clone()));
    t
}

fn e8_codebook_uncached(k: usize, seed: u64) -> Tensor {
    let mut points = enumerate_e8(3); // integer coords in [-3, 3]
    // sort by norm, then lexicographically for determinism
    points.sort_by(|a, b| {
        let na: i32 = a.iter().map(|v| v * v).sum();
        let nb: i32 = b.iter().map(|v| v * v).sum();
        na.cmp(&nb).then_with(|| a.cmp(b))
    });
    let mut data: Vec<f32> = Vec::with_capacity(k * 8);
    let mut rng = Pcg::with_stream(seed, 0xE8);
    let mut used = 0usize;
    for p in &points {
        if used >= k {
            break;
        }
        data.extend(p.iter().map(|&v| v as f32 * 0.5));
        used += 1;
    }
    while used < k {
        // top-up beyond the enumerated shells: random even-sum integer vecs
        let mut v: Vec<i32> = (0..8).map(|_| rng.below(9) as i32 - 4).collect();
        let s: i32 = v.iter().sum();
        if s % 2 != 0 {
            v[7] += 1;
        }
        data.extend(v.iter().map(|&x| x as f32 * 0.5));
        used += 1;
    }
    // scale the whole book so codeword RMS ~ 1 (weights are row-RMS-normalized
    // before assignment in the LDLQ artifact)
    let rms = (data.iter().map(|v| v * v).sum::<f32>() / data.len() as f32)
        .sqrt()
        .max(1e-6);
    for v in &mut data {
        *v /= rms;
    }
    Tensor::from_vec(&[k, 8], data)
}

/// Enumerate E8 points with integer representation c in [-r, r]^8 where the
/// lattice point is c/2 and sum(c) ≡ 0 (mod 2) — covers both the integer
/// and half-integer cosets when c has uniform parity.
fn enumerate_e8(r: i32) -> Vec<Vec<i32>> {
    // D8 coset (all even-parity "doubled" coordinates): c all even, sum/2 even
    // plus the half-integer coset: c all odd. Keep it simple: generate all c
    // with uniform parity and even sum, bounded norm.
    let mut out = Vec::new();
    let max_norm = 24; // keeps enumeration tractable and low-shell only
    let vals: Vec<i32> = (-r..=r).collect();
    let mut stack = vec![(Vec::<i32>::new(), 0i32, 0i32)];
    while let Some((prefix, norm, sum)) = stack.pop() {
        if prefix.len() == 8 {
            if sum % 2 == 0 {
                let parities: Vec<i32> = prefix.iter().map(|v| v.rem_euclid(2)).collect();
                if parities.iter().all(|&p| p == parities[0]) {
                    out.push(prefix);
                }
            }
            continue;
        }
        for &v in &vals {
            let n2 = norm + v * v;
            if n2 <= max_norm {
                let mut p = prefix.clone();
                p.push(v);
                stack.push((p, n2, sum + v));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_shape_and_determinism() {
        let a = e8_codebook(256, 0);
        let b = e8_codebook(256, 0);
        assert_eq!(a.shape, vec![256, 8]);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn codebook_has_distinct_rows() {
        let cb = e8_codebook(128, 0);
        let mut rows: Vec<Vec<u32>> = (0..128)
            .map(|i| cb.row(i).iter().map(|v| v.to_bits()).collect())
            .collect();
        rows.sort();
        let before = rows.len();
        rows.dedup();
        assert_eq!(rows.len(), before, "duplicate codewords");
    }

    #[test]
    fn codebook_rms_is_one() {
        let cb = e8_codebook(512, 1);
        let rms =
            (cb.data.iter().map(|v| v * v).sum::<f32>() / cb.data.len() as f32).sqrt();
        assert!((rms - 1.0).abs() < 1e-4, "{rms}");
    }

    #[test]
    fn contains_zero_and_symmetric_low_shells() {
        let cb = e8_codebook(64, 0);
        // first codeword after norm-sort is the origin
        assert!(cb.row(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn e8_parity_invariant() {
        for p in enumerate_e8(2) {
            let s: i32 = p.iter().sum();
            assert_eq!(s % 2, 0);
            let par: Vec<i32> = p.iter().map(|v| v.rem_euclid(2)).collect();
            assert!(par.iter().all(|&x| x == par[0]));
        }
    }
}
