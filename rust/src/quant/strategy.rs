//! Token-importance strategies (paper Sec. 4.3) and the Eq. 4 min-max
//! normalization into [r_min, 1].
//!
//! Heuristic strategies (First-N, First&Last-N, Chunk) produce {0,1} masks
//! from positions alone. Dynamic strategies consume the per-layer score
//! streams the `layer_fwd` artifact emits (AttnCon/ActNorm/ActDiff/TokenSim)
//! or the corpus frequency table (TokenFreq). Importance is computed per
//! layer and per sample, and is shared by all seven weights of the layer
//! (the paper found per-weight importance worse).

use crate::model::ModelConfig;

/// Raw per-token score streams captured from one layer forward pass
/// ([B, T] row-major, one row per sample in the batch).
#[derive(Clone, Debug)]
pub struct LayerScores {
    /// attention each token receives, summed over queries and heads
    pub attn_con: Vec<Vec<f32>>,
    /// L2 norm of each token's activation
    pub act_norm: Vec<Vec<f32>>,
    /// negated ‖Layer(z) − z‖ (steadier tokens score higher)
    pub act_diff: Vec<Vec<f32>>,
    /// negated mean cosine similarity to the other tokens
    pub token_sim: Vec<Vec<f32>>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    /// Conventional layer-wise quantization: every token weighs 1.
    Uniform,
    /// r_i = 1 for i < n, else 0 (Sec. 4.3 First-N).
    FirstN(usize),
    /// r_i = 1 for i < n/2 or i >= T - n/2 (Sec. 4.3 First&Last-N).
    FirstLastN(usize),
    /// Tab. 1: only the k-th of `of` equal chunks is weighted.
    Chunk { index: usize, of: usize },
    /// Rarer tokens matter more (corpus frequency table).
    TokenFreq { r_min: f32 },
    /// Larger-norm activations matter more.
    ActNorm { r_min: f32 },
    /// Steadier tokens (small ||Layer(z)-z||) matter more.
    ActDiff { r_min: f32 },
    /// Tokens less similar to the rest matter more.
    TokenSim { r_min: f32 },
    /// Tokens receiving more attention matter more (the paper's pick).
    AttnCon { r_min: f32 },
}

impl Strategy {
    /// Parse "attncon:0.01", "firstn:256", "chunk:1/4", "uniform", ...
    pub fn parse(s: &str) -> Option<Strategy> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        // r_min must be a finite fraction in (0, 1]: Eq. 4 maps scores into
        // [r_min, 1], so 0 would zero tokens out entirely, values > 1 would
        // invert the map, and NaN/±inf (which *do* parse as f32, e.g.
        // "attncon:NaN") would poison every importance weight. Out-of-range
        // values are rejected like the degenerate chunk specs; an omitted
        // or unparsable arg keeps the 0.01 default (pinned by
        // `parse_defaults_and_malformed_args`).
        let rmin = || -> Option<f32> {
            match arg {
                None => Some(0.01),
                Some(a) => match a.parse::<f32>() {
                    Ok(v) if v > 0.0 && v <= 1.0 => Some(v),
                    Ok(_) => None,
                    Err(_) => Some(0.01),
                },
            }
        };
        match name.to_ascii_lowercase().as_str() {
            "uniform" => Some(Strategy::Uniform),
            "firstn" => Some(Strategy::FirstN(arg?.parse().ok()?)),
            "firstlastn" => Some(Strategy::FirstLastN(arg?.parse().ok()?)),
            "chunk" => {
                let (i, of) = arg?.split_once('/')?;
                let (index, of): (usize, usize) = (i.parse().ok()?, of.parse().ok()?);
                // chunks are 1-based: `chunk:0/4` would underflow the
                // `(index - 1) * chunk` offset and `chunk:1/0` divide by
                // zero in `importance()`
                if index == 0 || of == 0 || index > of {
                    return None;
                }
                Some(Strategy::Chunk { index, of })
            }
            "tokenfreq" => Some(Strategy::TokenFreq { r_min: rmin()? }),
            "actnorm" => Some(Strategy::ActNorm { r_min: rmin()? }),
            "actdiff" => Some(Strategy::ActDiff { r_min: rmin()? }),
            "tokensim" => Some(Strategy::TokenSim { r_min: rmin()? }),
            "attncon" => Some(Strategy::AttnCon { r_min: rmin()? }),
            _ => None,
        }
    }

    /// Canonical CLI spelling; `Strategy::parse(&s.name()) == Some(s)`.
    pub fn name(&self) -> String {
        match self {
            Strategy::Uniform => "uniform".into(),
            Strategy::FirstN(n) => format!("firstn:{n}"),
            Strategy::FirstLastN(n) => format!("firstlastn:{n}"),
            Strategy::Chunk { index, of } => format!("chunk:{index}/{of}"),
            Strategy::TokenFreq { r_min } => format!("tokenfreq:{r_min}"),
            Strategy::ActNorm { r_min } => format!("actnorm:{r_min}"),
            Strategy::ActDiff { r_min } => format!("actdiff:{r_min}"),
            Strategy::TokenSim { r_min } => format!("tokensim:{r_min}"),
            Strategy::AttnCon { r_min } => format!("attncon:{r_min}"),
        }
    }

    /// True for strategies that need per-layer score streams or the corpus
    /// frequency table; heuristic masks (First-N, Chunk, …) are static.
    pub fn is_dynamic(&self) -> bool {
        matches!(
            self,
            Strategy::TokenFreq { .. }
                | Strategy::ActNorm { .. }
                | Strategy::ActDiff { .. }
                | Strategy::TokenSim { .. }
                | Strategy::AttnCon { .. }
        )
    }

    /// Compute the importance matrix R [B, T] for one layer of one batch.
    ///
    /// `tokens` and `freq` are only used by TokenFreq; `scores` only by the
    /// other dynamic strategies.
    pub fn importance(
        &self,
        _cfg: &ModelConfig,
        t: usize,
        batch: usize,
        scores: Option<&LayerScores>,
        tokens: Option<&[Vec<i32>]>,
        freq: Option<&[u32]>,
    ) -> Vec<Vec<f32>> {
        match self {
            Strategy::Uniform => vec![vec![1.0; t]; batch],
            Strategy::FirstN(n) => {
                let row: Vec<f32> =
                    (0..t).map(|i| if i < *n { 1.0 } else { 0.0 }).collect();
                vec![row; batch]
            }
            Strategy::FirstLastN(n) => {
                let half = n / 2;
                let row: Vec<f32> = (0..t)
                    .map(|i| if i < half || i >= t.saturating_sub(half) { 1.0 } else { 0.0 })
                    .collect();
                vec![row; batch]
            }
            Strategy::Chunk { index, of } => {
                let chunk = t / of;
                let lo = (index - 1) * chunk;
                let hi = if *index == *of { t } else { index * chunk };
                let row: Vec<f32> = (0..t)
                    .map(|i| if i >= lo && i < hi { 1.0 } else { 0.0 })
                    .collect();
                vec![row; batch]
            }
            Strategy::TokenFreq { r_min } => {
                let tokens = tokens.expect("TokenFreq needs tokens");
                let freq = freq.expect("TokenFreq needs the frequency table");
                tokens
                    .iter()
                    .map(|row| {
                        let raw: Vec<f32> =
                            row.iter().map(|&tk| -(freq[tk as usize] as f32)).collect();
                        normalize_eq4(&raw, *r_min)
                    })
                    .collect()
            }
            Strategy::ActNorm { r_min } => dyn_scores(&scores.unwrap().act_norm, *r_min),
            Strategy::ActDiff { r_min } => dyn_scores(&scores.unwrap().act_diff, *r_min),
            Strategy::TokenSim { r_min } => dyn_scores(&scores.unwrap().token_sim, *r_min),
            Strategy::AttnCon { r_min } => dyn_scores(&scores.unwrap().attn_con, *r_min),
        }
    }
}

fn dyn_scores(raw: &[Vec<f32>], r_min: f32) -> Vec<Vec<f32>> {
    raw.iter().map(|row| normalize_eq4(row, r_min)).collect()
}

/// Eq. 4: linearly map scores into [r_min, r_max=1]. Constant rows map to 1
/// (no preference expressible -> uniform). Non-finite entries (a NaN/inf
/// leaking out of a score stream) are excluded from the min/max and map to
/// `r_min`, so they can never poison the importance weights — and through
/// them the Hessians — with NaN.
pub fn normalize_eq4(raw: &[f32], r_min: f32) -> Vec<f32> {
    let finite = || raw.iter().cloned().filter(|v| v.is_finite());
    let lo = finite().fold(f32::INFINITY, f32::min);
    let hi = finite().fold(f32::NEG_INFINITY, f32::max);
    if !(hi - lo).is_finite() || hi - lo <= 1e-12 {
        return raw.iter().map(|&r| if r.is_finite() { 1.0 } else { r_min }).collect();
    }
    raw.iter()
        .map(|&r| {
            if r.is_finite() {
                r_min + (r - lo) / (hi - lo) * (1.0 - r_min)
            } else {
                r_min
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d: 64, layers: 1, heads: 2, ff: 128, vocab: 64,
            max_seq: 16, batch: 2, seq_lens: vec![16],
            ldlq_k: 16, ldlq_g: 8,
        }
    }

    #[test]
    fn parse_round_trip() {
        for s in [
            "uniform", "firstn:256", "firstlastn:128", "chunk:2/4",
            "tokenfreq:0.05", "actnorm:0.005", "actdiff:0.01",
            "tokensim:0.02", "attncon:0.01",
        ] {
            let st = Strategy::parse(s).unwrap();
            assert_eq!(Strategy::parse(&st.name()), Some(st), "{s}");
        }
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn parse_defaults_and_malformed_args() {
        // dynamic strategies default r_min to 0.01 when the arg is omitted
        // or unparsable; their name() then round-trips through parse()
        for s in ["attncon", "actnorm:xyz", "tokenfreq"] {
            let st = Strategy::parse(s).unwrap();
            match st {
                Strategy::AttnCon { r_min }
                | Strategy::ActNorm { r_min }
                | Strategy::TokenFreq { r_min } => assert_eq!(r_min, 0.01, "{s}"),
                other => panic!("{s} parsed to {other:?}"),
            }
            assert_eq!(Strategy::parse(&st.name()), Some(st), "{s}");
        }
        // heuristic strategies require a well-formed arg
        assert_eq!(Strategy::parse("firstn"), None);
        assert_eq!(Strategy::parse("firstn:abc"), None);
        assert_eq!(Strategy::parse("chunk:3"), None, "chunk needs k/m");
        assert_eq!(Strategy::parse("chunk:a/b"), None);
        // case-insensitive names
        assert_eq!(Strategy::parse("UNIFORM"), Some(Strategy::Uniform));
        assert_eq!(
            Strategy::parse("AttnCon:0.05"),
            Some(Strategy::AttnCon { r_min: 0.05 })
        );
    }

    #[test]
    fn parse_rejects_out_of_range_r_min() {
        // "NaN"/"inf" parse as f32, and nothing outside (0, 1] is a valid
        // Eq. 4 floor — all rejected with the same None-handling as the
        // degenerate chunk specs
        for s in [
            "attncon:NaN", "attncon:nan", "attncon:inf", "attncon:Infinity",
            "attncon:-inf", "attncon:2.0", "attncon:0", "attncon:0.0",
            "attncon:-0.5", "actnorm:1.0001", "actdiff:-1", "tokensim:inf",
            "tokenfreq:0",
        ] {
            assert_eq!(Strategy::parse(s), None, "{s}");
        }
        // the boundaries stay valid and round-trip through name()
        for s in ["attncon:1", "actnorm:0.0001", "tokenfreq:1.0", "actdiff:0.5"] {
            let st = Strategy::parse(s).unwrap_or_else(|| panic!("{s} must parse"));
            assert_eq!(Strategy::parse(&st.name()), Some(st), "{s}");
        }
    }

    #[test]
    fn parse_rejects_degenerate_chunk_specs() {
        // chunk:0/4 would underflow `(index - 1) * chunk` in importance()
        assert_eq!(Strategy::parse("chunk:0/4"), None, "chunks are 1-based");
        // chunk:1/0 would divide by zero in `t / of`
        assert_eq!(Strategy::parse("chunk:1/0"), None, "zero chunk count");
        assert_eq!(Strategy::parse("chunk:0/0"), None);
        // an index past the last chunk selects nothing meaningful
        assert_eq!(Strategy::parse("chunk:5/4"), None, "index out of range");
        // the boundary cases stay valid
        assert_eq!(Strategy::parse("chunk:1/1"), Some(Strategy::Chunk { index: 1, of: 1 }));
        assert_eq!(Strategy::parse("chunk:4/4"), Some(Strategy::Chunk { index: 4, of: 4 }));
    }

    #[test]
    fn eq4_normalization() {
        let r = normalize_eq4(&[0.0, 5.0, 10.0], 0.01);
        assert!((r[0] - 0.01).abs() < 1e-6);
        assert!((r[1] - 0.505).abs() < 1e-3);
        assert!((r[2] - 1.0).abs() < 1e-6);
        // constant input -> all ones
        assert_eq!(normalize_eq4(&[3.0, 3.0], 0.01), vec![1.0, 1.0]);
    }

    #[test]
    fn eq4_guards_non_finite_scores() {
        // a NaN in a score stream must not poison the importance weights
        // (they feed straight into the Hessian scaling): it maps to r_min
        // and is excluded from the min/max of the finite entries
        let r = normalize_eq4(&[0.0, f32::NAN, 10.0], 0.1);
        assert!(r.iter().all(|v| v.is_finite()), "{r:?}");
        assert!((r[0] - 0.1).abs() < 1e-6);
        assert!((r[1] - 0.1).abs() < 1e-6, "NaN maps to r_min");
        assert!((r[2] - 1.0).abs() < 1e-6);
        // infinities are non-finite too and must not stretch the range
        let r = normalize_eq4(&[0.0, f32::INFINITY, 10.0, f32::NEG_INFINITY], 0.1);
        assert!(r.iter().all(|v| v.is_finite()), "{r:?}");
        assert!((r[1] - 0.1).abs() < 1e-6);
        assert!((r[3] - 0.1).abs() < 1e-6);
        assert!((r[2] - 1.0).abs() < 1e-6, "finite max still maps to 1");
        // an all-NaN row expresses no preference beyond "untrustworthy"
        let r = normalize_eq4(&[f32::NAN, f32::NAN], 0.1);
        assert_eq!(r, vec![0.1, 0.1]);
        // constant-finite rows with a NaN: finite entries stay uniform
        let r = normalize_eq4(&[3.0, f32::NAN, 3.0], 0.1);
        assert_eq!(r, vec![1.0, 0.1, 1.0]);
    }

    #[test]
    fn firstn_mask() {
        let r = Strategy::FirstN(4).importance(&cfg(), 16, 2, None, None, None);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0].iter().sum::<f32>(), 4.0);
        assert_eq!(&r[0][..4], &[1.0; 4]);
    }

    #[test]
    fn firstlastn_mask() {
        let r = Strategy::FirstLastN(4).importance(&cfg(), 16, 1, None, None, None);
        assert_eq!(r[0].iter().sum::<f32>(), 4.0);
        assert_eq!(r[0][0], 1.0);
        assert_eq!(r[0][1], 1.0);
        assert_eq!(r[0][14], 1.0);
        assert_eq!(r[0][15], 1.0);
        assert_eq!(r[0][7], 0.0);
    }

    #[test]
    fn chunk_masks_partition() {
        let mut seen = vec![0.0f32; 16];
        for k in 1..=4 {
            let r = Strategy::Chunk { index: k, of: 4 }.importance(&cfg(), 16, 1, None, None, None);
            for (s, v) in seen.iter_mut().zip(&r[0]) {
                *s += v;
            }
        }
        assert_eq!(seen, vec![1.0; 16]); // chunks tile the sequence exactly
    }

    #[test]
    fn attncon_uses_scores() {
        let scores = LayerScores {
            attn_con: vec![vec![10.0, 0.0, 5.0, 0.0]],
            act_norm: vec![vec![0.0; 4]],
            act_diff: vec![vec![0.0; 4]],
            token_sim: vec![vec![0.0; 4]],
        };
        let r = Strategy::AttnCon { r_min: 0.01 }.importance(
            &cfg(), 4, 1, Some(&scores), None, None);
        assert!((r[0][0] - 1.0).abs() < 1e-6);
        assert!((r[0][1] - 0.01).abs() < 1e-6);
        assert!(r[0][2] > r[0][1] && r[0][2] < r[0][0]);
    }

    #[test]
    fn tokenfreq_prefers_rare() {
        let tokens = vec![vec![0, 1, 2]];
        let freq = vec![100u32, 10, 1];
        let r = Strategy::TokenFreq { r_min: 0.1 }.importance(
            &cfg(), 3, 1, None, Some(&tokens), Some(&freq));
        assert!(r[0][2] > r[0][1] && r[0][1] > r[0][0]);
        assert!((r[0][2] - 1.0).abs() < 1e-6);
        assert!((r[0][0] - 0.1).abs() < 1e-6);
    }
}
