//! On-disk format of a quantized artifact (DESIGN.md §9).
//!
//! A saved artifact is a directory of exactly two files:
//!
//! ```text
//! DIR/
//!   artifact.txt   line-oriented manifest: format tag, version, the full
//!                  ModelConfig block (runtime::manifest key=value style),
//!                  run provenance (method/strategy/bits/damp/rot_seed/
//!                  seq_len/expansion/module_mask/hess_key), then one
//!                  tensor= line per parameter with codec, shape, byte
//!                  span into weights.bin, and a CRC-32
//!   weights.bin    the blobs, concatenated in parameter order:
//!                    raw    — f32 little-endian, numel*4 bytes
//!                    packed — scale f32[rows] ++ zero f32[rows] ++
//!                             bit-packed codes (tensor::pack layout)
//! ```
//!
//! Every parse error is actionable and total: truncated blobs, checksum
//! mismatches, and unknown versions are rejected with messages that say
//! what to do — malformed input can never panic or decode to garbage.
//! rust/tests/golden_artifact.rs pins this behavior against committed
//! fixture files under rust/tests/data/.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::model::config::ModelConfig;
use crate::runtime::manifest::{config_from_kv, config_to_kv, parse_shape};
use crate::tensor::pack::{PackedRows, RowGrid, PACK_BITS};
use crate::tensor::Tensor;

/// Bump on any incompatible layout change; readers reject other versions.
pub const ARTIFACT_VERSION: u32 = 1;
pub const MANIFEST_FILE: &str = "artifact.txt";
pub const BLOBS_FILE: &str = "weights.bin";
const FORMAT_TAG: &str = "rsq-artifact";

/// How one tensor is encoded in `weights.bin`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// plain f32 little-endian
    Raw,
    /// per-row grid + bit-packed codes (`tensor::pack`)
    Packed { bits: u32 },
}

impl Codec {
    fn render(&self) -> String {
        match self {
            Codec::Raw => "raw".to_string(),
            Codec::Packed { bits } => format!("packed{bits}"),
        }
    }

    fn parse(s: &str) -> Result<Codec> {
        if s == "raw" {
            return Ok(Codec::Raw);
        }
        if let Some(b) = s.strip_prefix("packed") {
            let bits: u32 = b.parse().with_context(|| format!("bad codec {s:?}"))?;
            // strict render/parse inverse: u32::from_str tolerates leading
            // zeros and an explicit '+' ("packed03", "packed+3"), which
            // would make two spellings of one codec — reject anything that
            // does not round-trip through render()
            if format!("packed{bits}") != s {
                bail!("non-canonical codec spelling {s:?} (expected \"packed{bits}\")");
            }
            if !PACK_BITS.contains(&bits) {
                bail!("codec {s:?}: unsupported pack width (supported: {PACK_BITS:?})");
            }
            return Ok(Codec::Packed { bits });
        }
        bail!("unknown codec {s:?} (expected raw or packed<bits>)")
    }
}

/// One `tensor=` manifest line: where a parameter lives in `weights.bin`.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorEntry {
    pub name: String,
    pub codec: Codec,
    pub shape: Vec<usize>,
    pub offset: u64,
    pub len: u64,
    pub crc: u32,
}

impl TensorEntry {
    /// Expected blob length for this entry's codec + shape. `None` when
    /// the dims are implausible enough to overflow — or when a packed
    /// codec is claimed for a non-matrix shape — the manifest is
    /// untrusted input, so size arithmetic must be checked, not panicking
    /// (the module contract: malformed input never panics).
    pub fn expected_len(&self) -> Option<u64> {
        let numel = self.shape.iter().try_fold(1u64, |a, &d| a.checked_mul(d as u64))?;
        match self.codec {
            Codec::Raw => numel.checked_mul(4),
            Codec::Packed { bits } => {
                // packed layout is strictly per-row over a 2-D matrix; a
                // 1-D (or 3-D) shape has no row/col split to pack under
                if self.shape.len() != 2 {
                    return None;
                }
                let (rows, cols) = (self.shape[0] as u64, self.shape[1] as u64);
                let row_bits = cols.checked_mul(bits as u64)?;
                let rb = row_bits.checked_add(7)? / 8;
                rows.checked_mul(8)?.checked_add(rows.checked_mul(rb)?)
            }
        }
    }
}

/// Parsed `artifact.txt`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub version: u32,
    pub config: ModelConfig,
    pub method: String,
    pub strategy: String,
    pub bits: u32,
    pub damp: f32,
    pub rot_seed: u64,
    pub seq_len: usize,
    pub expansion: usize,
    /// sorted module names, or None for "all"
    pub module_mask: Option<Vec<String>>,
    /// content address of the Hessians the solve consumed (hex), "-" for
    /// data-free RTN provenance
    pub hess_key: String,
    /// mixed-precision provenance: the budget spec that drove the
    /// allocator (`avg-bits:3` / `budget-bytes:4096`), absent for a
    /// single global `--bits` run. Rendered only when present; parse
    /// ignores unknown keys, so old readers and old artifacts both work.
    pub budget: Option<String>,
    /// achieved packed-weight weighted average width in bits (mixed-
    /// precision runs only)
    pub avg_bits: Option<f32>,
    pub tensors: Vec<TensorEntry>,
    /// exact size of weights.bin — read back first, so truncation is
    /// caught before any blob is touched
    pub total_len: u64,
}

impl ArtifactManifest {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("format={FORMAT_TAG}\n"));
        out.push_str(&format!("version={}\n", self.version));
        out.push_str(&config_to_kv(&self.config));
        out.push_str(&format!("method={}\n", self.method));
        out.push_str(&format!("strategy={}\n", self.strategy));
        out.push_str(&format!("bits={}\n", self.bits));
        out.push_str(&format!("damp={}\n", self.damp));
        out.push_str(&format!("rot_seed={}\n", self.rot_seed));
        out.push_str(&format!("seq_len={}\n", self.seq_len));
        out.push_str(&format!("expansion={}\n", self.expansion));
        match &self.module_mask {
            None => out.push_str("module_mask=all\n"),
            Some(names) => out.push_str(&format!("module_mask={}\n", names.join(","))),
        }
        out.push_str(&format!("hess_key={}\n", self.hess_key));
        if let Some(b) = &self.budget {
            out.push_str(&format!("budget={b}\n"));
        }
        if let Some(a) = self.avg_bits {
            out.push_str(&format!("avg_bits={a}\n"));
        }
        for t in &self.tensors {
            let shape: Vec<String> = t.shape.iter().map(|d| d.to_string()).collect();
            out.push_str(&format!(
                "tensor={}|codec={}|shape={}|offset={}|len={}|crc={:08x}\n",
                t.name,
                t.codec.render(),
                if shape.is_empty() { "scalar".to_string() } else { shape.join("x") },
                t.offset,
                t.len,
                t.crc,
            ));
        }
        out.push_str(&format!("total_len={}\n", self.total_len));
        out
    }

    pub fn parse(text: &str) -> Result<ArtifactManifest> {
        let mut kv = BTreeMap::new();
        let mut tensors: Vec<TensorEntry> = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("tensor=") {
                tensors.push(parse_tensor_line(rest)?);
            } else if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
            } else {
                bail!("unparseable manifest line {line:?}");
            }
        }
        let get = |k: &str| -> Result<String> {
            kv.get(k).cloned().with_context(|| format!("artifact manifest missing key {k}"))
        };
        if kv.get("format").map(String::as_str) != Some(FORMAT_TAG) {
            bail!(
                "not a quantized-artifact manifest (format={:?}, expected {FORMAT_TAG:?}) — \
                 point --artifact at a directory written by `rsq quantize --save`",
                kv.get("format")
            );
        }
        let version: u32 = get("version")?.parse().context("bad version")?;
        if version != ARTIFACT_VERSION {
            bail!(
                "unsupported artifact version {version} (this build reads version \
                 {ARTIFACT_VERSION}) — re-save with this build's `rsq quantize --save`"
            );
        }
        let config = config_from_kv(&kv)?;
        let module_mask = match get("module_mask")?.as_str() {
            "all" => None,
            names => Some(names.split(',').map(str::to_string).collect()),
        };
        let m = ArtifactManifest {
            version,
            config,
            method: get("method")?,
            strategy: get("strategy")?,
            bits: get("bits")?.parse().context("bad bits")?,
            damp: get("damp")?.parse().context("bad damp")?,
            rot_seed: get("rot_seed")?.parse().context("bad rot_seed")?,
            seq_len: get("seq_len")?.parse().context("bad seq_len")?,
            expansion: get("expansion")?.parse().context("bad expansion")?,
            module_mask,
            hess_key: get("hess_key")?,
            budget: kv.get("budget").cloned(),
            avg_bits: match kv.get("avg_bits") {
                None => None,
                Some(v) => Some(v.parse().context("bad avg_bits")?),
            },
            tensors,
            total_len: get("total_len")?.parse().context("bad total_len")?,
        };
        m.check()?;
        Ok(m)
    }

    /// Cross-validate entries against the embedded config: names and
    /// order must equal `param_names()`, shapes must match, byte spans
    /// must be contiguous from 0 to `total_len` with codec-consistent
    /// lengths. Any drift means the artifact cannot be trusted.
    pub fn check(&self) -> Result<()> {
        let names = self.config.param_names();
        if names.len() != self.tensors.len() {
            bail!(
                "artifact has {} tensors but config {} expects {} — artifact corrupt \
                 or from an incompatible build",
                self.tensors.len(),
                self.config.name,
                names.len()
            );
        }
        let mut cursor = 0u64;
        for (want, t) in names.iter().zip(&self.tensors) {
            if want != &t.name {
                bail!("tensor order mismatch: expected {want}, manifest has {}", t.name);
            }
            let want_shape = self.config.param_shape(want);
            if want_shape != t.shape {
                bail!("tensor {want}: shape {:?} vs config {want_shape:?}", t.shape);
            }
            if t.offset != cursor {
                bail!("tensor {want}: offset {} but previous blob ends at {cursor}", t.offset);
            }
            // before expected_len(), which indexes shape[0]/shape[1] for
            // the packed codec
            if matches!(t.codec, Codec::Packed { .. }) && t.shape.len() != 2 {
                bail!("tensor {want}: packed codec on non-matrix shape {:?}", t.shape);
            }
            let want_len = t
                .expected_len()
                .with_context(|| format!("tensor {want}: implausible shape {:?}", t.shape))?;
            if t.len != want_len {
                bail!(
                    "tensor {want}: blob length {} does not match codec {} for shape {:?} \
                     (expected {want_len})",
                    t.len,
                    t.codec.render(),
                    t.shape,
                );
            }
            cursor = cursor.checked_add(t.len).with_context(|| {
                format!("tensor {want}: blob spans overflow the address space")
            })?;
        }
        if cursor != self.total_len {
            bail!(
                "manifest total_len {} does not equal the sum of blob lengths {cursor}",
                self.total_len
            );
        }
        Ok(())
    }
}

fn parse_tensor_line(rest: &str) -> Result<TensorEntry> {
    let mut parts = rest.split('|');
    let name = parts.next().unwrap_or_default().to_string();
    if name.is_empty() {
        bail!("tensor line with empty name");
    }
    let (mut codec, mut shape, mut offset, mut len, mut crc) = (None, None, None, None, None);
    for part in parts {
        if let Some(v) = part.strip_prefix("codec=") {
            codec = Some(Codec::parse(v)?);
        } else if let Some(v) = part.strip_prefix("shape=") {
            shape = Some(parse_shape(v)?);
        } else if let Some(v) = part.strip_prefix("offset=") {
            offset = Some(v.parse::<u64>().with_context(|| format!("bad offset in {rest:?}"))?);
        } else if let Some(v) = part.strip_prefix("len=") {
            len = Some(v.parse::<u64>().with_context(|| format!("bad len in {rest:?}"))?);
        } else if let Some(v) = part.strip_prefix("crc=") {
            crc = Some(
                u32::from_str_radix(v, 16).with_context(|| format!("bad crc in {rest:?}"))?,
            );
        } else {
            bail!("unknown field {part:?} in tensor line {rest:?}");
        }
    }
    let missing = |f: &str| format!("tensor {name}: missing {f}");
    Ok(TensorEntry {
        codec: codec.with_context(|| missing("codec"))?,
        shape: shape.with_context(|| missing("shape"))?,
        offset: offset.with_context(|| missing("offset"))?,
        len: len.with_context(|| missing("len"))?,
        crc: crc.with_context(|| missing("crc"))?,
        name,
    })
}

/// Encode one tensor blob. Packed layout: scale row f32s, zero row f32s,
/// then the code bitstream.
pub fn encode_blob(t: &Tensor, packed: Option<&PackedRows>) -> Vec<u8> {
    match packed {
        None => t.data.iter().flat_map(|v| v.to_le_bytes()).collect(),
        Some(p) => {
            let mut out = Vec::with_capacity(p.rows * 8 + p.data.len());
            for &s in &p.grid.scale {
                out.extend_from_slice(&s.to_le_bytes());
            }
            for &z in &p.grid.zero {
                out.extend_from_slice(&z.to_le_bytes());
            }
            out.extend_from_slice(&p.data);
            out
        }
    }
}

/// A decoded blob in its **storage domain**: packed weights stay packed.
/// The serving layer (`serve::PackedModel`, DESIGN.md §11) consumes this
/// directly so decode-time memory matches the on-disk packing; the
/// `ParamSet` loader unpacks each `Packed` arm on the way out.
#[derive(Clone, Debug)]
pub enum Blob {
    Raw(Tensor),
    Packed(PackedRows),
}

/// Decode one blob without leaving the storage domain (packed weights are
/// validated but **not** dequantized). `entry.check()`-validated lengths
/// are re-checked here so a decoder on untrusted bytes stays total.
pub fn decode_blob_any(entry: &TensorEntry, bytes: &[u8]) -> Result<Blob> {
    let want = entry.expected_len().with_context(|| {
        format!(
            "tensor {}: shape {:?} is implausible or not packable under codec {} — \
             artifact corrupt; re-save with `rsq quantize --save`",
            entry.name,
            entry.shape,
            entry.codec.render(),
        )
    })?;
    if bytes.len() as u64 != want {
        bail!(
            "tensor {}: blob is {} bytes, expected {want} — weights.bin truncated or corrupt",
            entry.name,
            bytes.len(),
        );
    }
    let f32s = |b: &[u8]| -> Vec<f32> {
        b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
    };
    match entry.codec {
        Codec::Raw => Ok(Blob::Raw(Tensor::from_vec(&entry.shape, f32s(bytes)))),
        Codec::Packed { bits } => {
            let (rows, cols) = (entry.shape[0], entry.shape[1]);
            let scale = f32s(&bytes[..rows * 4]);
            let zero = f32s(&bytes[rows * 4..rows * 8]);
            if let Some(r) = (0..rows)
                .find(|&r| !scale[r].is_finite() || scale[r] <= 0.0 || !zero[r].is_finite())
            {
                bail!(
                    "tensor {}: row {r} has a non-finite or non-positive grid — artifact \
                     corrupt; re-run `rsq quantize --save`",
                    entry.name
                );
            }
            Ok(Blob::Packed(PackedRows {
                bits,
                rows,
                cols,
                grid: RowGrid { scale, zero },
                data: bytes[rows * 8..].to_vec(),
            }))
        }
    }
}

/// Decode one blob back to its f32 tensor, optionally pool-parallel over
/// packed rows (bit-identical at every jobs count — `PackedRows::unpack`).
pub fn decode_blob(
    entry: &TensorEntry,
    bytes: &[u8],
    pool: Option<&crate::util::Pool>,
) -> Result<Tensor> {
    Ok(match decode_blob_any(entry, bytes)? {
        Blob::Raw(t) => t,
        Blob::Packed(p) => p.unpack(pool),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "golden".into(),
            d: 4,
            layers: 1,
            heads: 1,
            ff: 8,
            vocab: 16,
            max_seq: 8,
            batch: 2,
            seq_lens: vec![8],
            ldlq_k: 16,
            ldlq_g: 2,
        }
    }

    fn sample_manifest() -> ArtifactManifest {
        let c = cfg();
        let mut tensors = Vec::new();
        let mut cursor = 0u64;
        for name in c.param_names() {
            let shape = c.param_shape(&name);
            let mut e = TensorEntry {
                name,
                codec: Codec::Raw,
                shape,
                offset: cursor,
                len: 0,
                crc: 0xDEADBEEF,
            };
            e.len = e.expected_len().unwrap();
            cursor += e.len;
            tensors.push(e);
        }
        ArtifactManifest {
            version: ARTIFACT_VERSION,
            config: c,
            method: "rsq".into(),
            strategy: "attncon:0.05".into(),
            bits: 3,
            damp: 0.01,
            rot_seed: 20823,
            seq_len: 8,
            expansion: 1,
            module_mask: None,
            hess_key: "00".repeat(16),
            budget: None,
            avg_bits: None,
            tensors,
            total_len: cursor,
        }
    }

    #[test]
    fn render_parse_round_trip() {
        let m = sample_manifest();
        let m2 = ArtifactManifest::parse(&m.render()).unwrap();
        assert_eq!(m2.config, m.config);
        assert_eq!(m2.tensors, m.tensors);
        assert_eq!(m2.total_len, m.total_len);
        assert_eq!(m2.strategy, m.strategy);
        assert_eq!(m2.hess_key, m.hess_key);
    }

    #[test]
    fn rejects_unknown_version() {
        let text = sample_manifest().render().replace("version=1", "version=99");
        let err = ArtifactManifest::parse(&text).unwrap_err().to_string();
        assert!(err.contains("unsupported artifact version 99"), "{err}");
        assert!(err.contains("re-save"), "error must be actionable: {err}");
    }

    #[test]
    fn rejects_wrong_format_tag() {
        let text = sample_manifest().render().replace("format=rsq-artifact", "format=tarball");
        let err = ArtifactManifest::parse(&text).unwrap_err().to_string();
        assert!(err.contains("not a quantized-artifact manifest"), "{err}");
    }

    #[test]
    fn rejects_tensor_drift() {
        let m = sample_manifest();
        let text = m.render().replace("tensor=l0.wq", "tensor=l0.xx");
        assert!(ArtifactManifest::parse(&text).is_err());
        // gap in the byte spans
        let mut m2 = m.clone();
        m2.tensors[3].offset += 4;
        assert!(m2.check().is_err());
        // total_len drift
        let mut m3 = m;
        m3.total_len += 1;
        assert!(m3.check().is_err());
    }

    #[test]
    fn implausible_dims_error_instead_of_overflowing() {
        // corrupt manifests are untrusted: 2^33-sized dims must produce a
        // parse error, not a multiply-with-overflow panic
        let text = sample_manifest().render().replace("\nd=4\n", "\nd=8589934592\n");
        let err = ArtifactManifest::parse(&text).unwrap_err().to_string();
        assert!(!err.is_empty());
        let huge = TensorEntry {
            name: "x".into(),
            codec: Codec::Raw,
            shape: vec![usize::MAX, usize::MAX],
            offset: 0,
            len: 0,
            crc: 0,
        };
        assert_eq!(huge.expected_len(), None);
    }

    #[test]
    fn packed_codec_on_non_matrix_shape_is_total() {
        // the headline regression: a hostile manifest claiming a packed
        // codec for a 1-D tensor must not index shape[1] — expected_len
        // returns None and both decoders turn that into an actionable
        // error instead of a panic
        for shape in [vec![4], vec![], vec![2, 2, 2]] {
            let e = TensorEntry {
                name: "l0.g1".into(),
                codec: Codec::Packed { bits: 3 },
                shape,
                offset: 0,
                len: 0,
                crc: 0,
            };
            assert_eq!(e.expected_len(), None, "shape {:?}", e.shape);
            let err = decode_blob_any(&e, &[0u8; 16]).unwrap_err().to_string();
            assert!(err.contains("not packable"), "{err}");
            assert!(err.contains("rsq quantize --save"), "error must be actionable: {err}");
        }
    }

    #[test]
    fn codec_parse_is_strict_inverse_of_render() {
        for bits in PACK_BITS {
            let c = Codec::Packed { bits };
            assert_eq!(Codec::parse(&c.render()).unwrap(), c);
        }
        assert_eq!(Codec::parse("raw").unwrap(), Codec::Raw);
        // non-canonical spellings that u32::from_str would happily accept
        for s in ["packed03", "packed+3", "packed 3", "packed0x3"] {
            let err = Codec::parse(s).unwrap_err().to_string();
            assert!(!err.is_empty(), "{s:?} must be rejected");
        }
        assert!(Codec::parse("packed03").unwrap_err().to_string().contains("non-canonical"));
        // out-of-set widths name the supported set
        let err = Codec::parse("packed5").unwrap_err().to_string();
        assert!(err.contains("unsupported pack width"), "{err}");
        assert!(err.contains('2') && err.contains('8'), "must name PACK_BITS: {err}");
    }

    #[test]
    fn budget_provenance_round_trip_and_optional() {
        // absent on a plain --bits manifest (and absent from render)
        let m = sample_manifest();
        assert!(!m.render().contains("budget="));
        let m2 = ArtifactManifest::parse(&m.render()).unwrap();
        assert_eq!(m2.budget, None);
        assert_eq!(m2.avg_bits, None);
        // present round-trips exactly
        let mut m3 = sample_manifest();
        m3.budget = Some("avg-bits:3".into());
        m3.avg_bits = Some(2.875);
        let m4 = ArtifactManifest::parse(&m3.render()).unwrap();
        assert_eq!(m4.budget.as_deref(), Some("avg-bits:3"));
        assert_eq!(m4.avg_bits, Some(2.875));
    }

    #[test]
    fn module_mask_round_trip() {
        let mut m = sample_manifest();
        m.module_mask = Some(vec!["wq".into(), "wv".into()]);
        let m2 = ArtifactManifest::parse(&m.render()).unwrap();
        assert_eq!(m2.module_mask, m.module_mask);
    }

    #[test]
    fn blob_round_trip_raw_and_packed() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, -2.5, 0.0, 3.25, -0.75, 8.0]);
        let entry = TensorEntry {
            name: "x".into(),
            codec: Codec::Raw,
            shape: vec![2, 3],
            offset: 0,
            len: 24,
            crc: 0,
        };
        let bytes = encode_blob(&t, None);
        assert_eq!(decode_blob(&entry, &bytes, None).unwrap().data, t.data);

        let grid = RowGrid { scale: vec![0.5, 0.25], zero: vec![2.0, 0.0] };
        let q = Tensor::from_vec(&[2, 3], vec![0.5, -1.0, 0.0, 0.25, 0.0, 0.75]);
        let p = PackedRows::pack(&q, 2, &grid).unwrap();
        let entry = TensorEntry {
            name: "q".into(),
            codec: Codec::Packed { bits: 2 },
            shape: vec![2, 3],
            offset: 0,
            len: 18,
            crc: 0,
        };
        assert_eq!(entry.expected_len(), Some(18)); // 2 rows * (8 grid + 1 data)
        let bytes = encode_blob(&q, Some(&p));
        let back = decode_blob(&entry, &bytes, None).unwrap();
        for (a, b) in back.data.iter().zip(&q.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // the storage-domain decoder hands the packed rows back verbatim
        match decode_blob_any(&entry, &bytes).unwrap() {
            Blob::Packed(p2) => {
                assert_eq!(p2, p);
                for (a, b) in p2.unpack(None).data.iter().zip(&q.data) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            Blob::Raw(_) => panic!("packed entry decoded to a raw blob"),
        }
    }

    #[test]
    fn decode_rejects_truncated_blob() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0; 4]);
        let entry = TensorEntry {
            name: "x".into(),
            codec: Codec::Raw,
            shape: vec![2, 2],
            offset: 0,
            len: 16,
            crc: 0,
        };
        let bytes = encode_blob(&t, None);
        let err = decode_blob(&entry, &bytes[..10], None).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }
}
