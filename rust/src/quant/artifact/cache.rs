//! Content-addressed Hessian cache (DESIGN.md §9).
//!
//! Pass A — calibration capture + scaled Hessian accumulation — is the
//! dominant cost of every non-RTN quantization run, and it is a pure
//! function of inputs the sweep drivers repeat constantly: the model
//! parameters, the calibration set, the rotation seed, the importance
//! strategy, and (because pass B re-forwards through the *quantized*
//! layer, propagating solve error into the next layer's statistics) the
//! solve configuration itself. [`cache_key`] hashes exactly that
//! determining set; `--jobs` and `--sched` are deliberately **excluded**
//! because the scheduler's fixed-order reductions make the accumulated
//! Hessians bit-identical across every jobs/sched combination (DESIGN.md
//! §5) — a cache entry written at `--jobs 1 --sched staged` is byte-valid
//! for `--jobs 8 --sched pipelined`.
//!
//! Content addressing means there is no invalidation protocol: any change
//! to a key field produces a different key, and an entry is immutable once
//! written. A corrupt or truncated entry is detected by its CRC and
//! treated as a miss (recompute + rewrite), never an error. The same
//! property makes **eviction** always safe — deleting an entry only costs
//! a future recompute — which is what `rsq cache ls`/`rsq cache gc`
//! (wrapping [`HessCache::entries`]/[`HessCache::gc`]) rely on to keep
//! the directory bounded by age and total size.
//!
//! On a key hit the scheduler skips pass A, pass B, and the embedding
//! sweep entirely and runs solve-only (`sched::run_layers_cached`) —
//! `QuantReport::hess_cache_hits` and `rsq perf` surface the elimination.

use std::path::PathBuf;
use std::time::SystemTime;

use anyhow::{Context, Result};

use crate::corpus::CalibSet;
use crate::model::config::ModelConfig;
use crate::model::ParamSet;
use crate::runtime::manifest::config_to_kv;
use crate::tensor::Tensor;
use crate::util::hash::{crc32, Fnv1a64, FNV_BASIS};

use crate::quant::pipeline::QuantOptions;

/// Bump when the key derivation or the entry format changes — old entries
/// simply stop being addressed.
const CACHE_VERSION: u32 = 1;

const MAGIC: &[u8; 8] = b"RSQHESC1";

/// One layer's fully-reduced pass-A output: the four per-stream scaled
/// Hessians (Xa/Xo/Xf/Xd order), plus the uniform-weighted set when a
/// partial module mask needs both (Fig. 7).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerHessians {
    pub scaled: Vec<Tensor>,
    pub uniform: Option<Vec<Tensor>>,
}

/// 128-bit content address (two independent FNV-1a 64 streams).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheKey(pub [u8; 16]);

impl CacheKey {
    pub fn hex(&self) -> String {
        self.0.iter().map(|b| format!("{b:02x}")).collect()
    }
}

/// Two independent FNV streams fed in one traversal (model tensors can be
/// megabytes — walking them once, not twice, matters now that caching is
/// the driver default).
struct KeyHasher {
    a: Fnv1a64,
    b: Fnv1a64,
}

impl KeyHasher {
    fn new() -> Self {
        KeyHasher {
            a: Fnv1a64::new(),
            b: Fnv1a64::with_basis(FNV_BASIS ^ 0x9E37_79B9_7F4A_7C15),
        }
    }

    fn str(&mut self, s: &str) {
        self.a.write_str(s);
        self.b.write_str(s);
    }

    fn u32(&mut self, v: u32) {
        self.a.write_u32(v);
        self.b.write_u32(v);
    }

    fn u64(&mut self, v: u64) {
        self.a.write_u64(v);
        self.b.write_u64(v);
    }

    fn usize(&mut self, v: usize) {
        self.a.write_usize(v);
        self.b.write_usize(v);
    }

    fn f32(&mut self, v: f32) {
        self.a.write_f32(v);
        self.b.write_f32(v);
    }

    fn f32s(&mut self, vs: &[f32]) {
        self.a.write_f32s(vs);
        self.b.write_f32s(vs);
    }

    fn i32s(&mut self, vs: &[i32]) {
        self.a.write_i32s(vs);
        self.b.write_i32s(vs);
    }

    fn finish(self) -> CacheKey {
        let mut out = [0u8; 16];
        out[..8].copy_from_slice(&self.a.finish().to_le_bytes());
        out[8..].copy_from_slice(&self.b.finish().to_le_bytes());
        CacheKey(out)
    }
}

/// Derive the content address of one run's Hessians. The field list below
/// IS the cache contract — everything that can change a Hessian bit must
/// be hashed, and nothing that cannot (jobs, sched, verbose) may be.
pub fn cache_key(
    cfg: &ModelConfig,
    params: &ParamSet,
    calib: &CalibSet,
    opts: &QuantOptions,
) -> CacheKey {
    let mut h = KeyHasher::new();
    h.u32(CACHE_VERSION);
    // model: config + every parameter bit (pre-rotation; the rotation
    // is determined by rot_seed + method below)
    h.str(&config_to_kv(cfg));
    h.usize(params.tensors.len());
    for t in &params.tensors {
        h.usize(t.shape.len());
        for &d in &t.shape {
            h.usize(d);
        }
        h.f32s(&t.data);
    }
    // corpus spec: kind + the pre-expansion token content itself
    h.str(calib.kind.name());
    h.usize(calib.seq_len);
    h.usize(calib.samples.len());
    for s in &calib.samples {
        h.i32s(s);
    }
    // run options that reach the Hessians (directly, or through the
    // quantized pass-B propagation)
    h.str(opts.method.name());
    h.str(&opts.strategy.name());
    h.u32(opts.bits);
    h.f32(opts.damp);
    h.usize(opts.seq_len);
    h.usize(opts.expansion);
    h.u64(opts.rot_seed);
    match &opts.module_mask {
        None => h.str("mask=all"),
        Some(mask) => {
            let mut names: Vec<&str> = mask.iter().map(|m| m.name()).collect();
            names.sort_unstable();
            h.str(&format!("mask={}", names.join(",")));
        }
    }
    // The simd backend's rotate GEMMs reassociate reductions, so the
    // rotated params — and therefore the Hessians pass A accumulates —
    // can differ from the reference run's. Hash the backend only when it
    // is not Reference: every pre-§13 entry stays addressed by its
    // original key.
    if opts.backend != crate::tensor::kernels::Backend::Reference {
        h.str(&format!("backend={}", opts.backend.name()));
    }
    h.finish()
}

/// One cache entry as seen by `ls`/`gc` — metadata only, the payload is
/// never read for maintenance.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    pub path: PathBuf,
    /// content address (hex), recovered from the file name
    pub key_hex: String,
    pub bytes: u64,
    /// seconds since the entry was written
    pub age_s: f64,
}

/// What one [`HessCache::gc`] sweep did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    pub scanned: usize,
    pub deleted: usize,
    pub deleted_bytes: u64,
    pub kept: usize,
    pub kept_bytes: u64,
    /// orphaned `*.tmp.*` files from crashed writers, swept by age —
    /// they are invisible to `ls`/the byte budget, so without this they
    /// would leak forever
    pub stale_tmp_deleted: usize,
}

/// A `*.tmp.*` file older than this is an orphan from a crashed writer
/// (a live [`HessCache::store`] renames within the same call), safe for
/// gc to delete.
const STALE_TMP_S: f64 = 3600.0;

/// On-disk store: one immutable `<key>.hess` file per content address.
pub struct HessCache {
    dir: PathBuf,
}

impl HessCache {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        HessCache { dir: dir.into() }
    }

    pub fn entry_path(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(format!("{}.hess", key.hex()))
    }

    /// Fetch an entry. `None` on absent, corrupt, or shape-incompatible
    /// entries (the caller recomputes); corruption warns on stderr.
    pub fn load(
        &self,
        key: &CacheKey,
        layers: usize,
        needs_uniform: bool,
    ) -> Option<Vec<LayerHessians>> {
        let path = self.entry_path(key);
        let bytes = std::fs::read(&path).ok()?;
        match decode_entry(&bytes, key, layers, needs_uniform) {
            Ok(hs) => Some(hs),
            Err(e) => {
                eprintln!("[hess-cache] ignoring corrupt entry {path:?}: {e}");
                None
            }
        }
    }

    /// List the cache's entries (`*.hess` files), oldest first. A missing
    /// cache directory is an empty cache, not an error; non-entry files
    /// (stray names, in-flight `*.tmp.*`) are skipped.
    pub fn entries(&self) -> Result<Vec<CacheEntry>> {
        let mut out = Vec::new();
        let rd = match std::fs::read_dir(&self.dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
            Err(e) => return Err(e).with_context(|| format!("read cache dir {:?}", self.dir)),
        };
        for dent in rd {
            let dent = dent.with_context(|| format!("read cache dir {:?}", self.dir))?;
            let path = dent.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            let Some(key_hex) = name.strip_suffix(".hess") else { continue };
            let meta = dent.metadata().with_context(|| format!("stat {path:?}"))?;
            let modified = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
            let age_s = SystemTime::now()
                .duration_since(modified)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0);
            out.push(CacheEntry {
                key_hex: key_hex.to_string(),
                bytes: meta.len(),
                age_s,
                path,
            });
        }
        // oldest first; path as the tie-break so the order is total
        out.sort_by(|a, b| b.age_s.total_cmp(&a.age_s).then_with(|| a.path.cmp(&b.path)));
        Ok(out)
    }

    /// Evict entries: everything older than `max_age_s`, then — oldest
    /// first — whatever it takes to bring the directory under
    /// `max_bytes`. Content addressing makes eviction always safe: a
    /// deleted entry is simply a future miss, recomputed and rewritten
    /// (DESIGN.md §9); `rsq cache gc` is the CLI face.
    pub fn gc(&self, max_age_s: Option<f64>, max_bytes: Option<u64>) -> Result<GcReport> {
        let entries = self.entries()?;
        let mut kept_bytes: u64 = entries.iter().map(|e| e.bytes).sum();
        let mut report = GcReport {
            scanned: entries.len(),
            deleted: 0,
            deleted_bytes: 0,
            kept: 0,
            kept_bytes: 0,
            stale_tmp_deleted: self.sweep_stale_tmps()?,
        };
        for e in &entries {
            let too_old = max_age_s.is_some_and(|max| e.age_s >= max);
            let too_big = max_bytes.is_some_and(|max| kept_bytes > max);
            if too_old || too_big {
                std::fs::remove_file(&e.path).with_context(|| format!("evict {:?}", e.path))?;
                kept_bytes -= e.bytes;
                report.deleted += 1;
                report.deleted_bytes += e.bytes;
            } else {
                report.kept += 1;
                report.kept_bytes += e.bytes;
            }
        }
        Ok(report)
    }

    /// Delete `*.tmp.*` orphans older than [`STALE_TMP_S`] (a writer
    /// that crashed between write and rename); young tmps are left alone
    /// in case a live `store` is mid-rename.
    fn sweep_stale_tmps(&self) -> Result<usize> {
        let mut swept = 0;
        let rd = match std::fs::read_dir(&self.dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e).with_context(|| format!("read cache dir {:?}", self.dir)),
        };
        for dent in rd {
            let dent = dent.with_context(|| format!("read cache dir {:?}", self.dir))?;
            let path = dent.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            if !name.contains(".tmp.") {
                continue;
            }
            let meta = dent.metadata().with_context(|| format!("stat {path:?}"))?;
            let modified = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
            let age_s = SystemTime::now()
                .duration_since(modified)
                .map(|d| d.as_secs_f64())
                .unwrap_or(0.0);
            if age_s >= STALE_TMP_S {
                std::fs::remove_file(&path).with_context(|| format!("sweep {path:?}"))?;
                swept += 1;
            }
        }
        Ok(swept)
    }

    /// Write an entry atomically (tmp + rename) so a concurrent reader —
    /// `rsq all` runs drivers as subprocesses over one cache dir — never
    /// observes a half-written file.
    pub fn store(&self, key: &CacheKey, layers: &[LayerHessians]) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("create hessian cache dir {:?}", self.dir))?;
        let bytes = encode_entry(key, layers);
        let path = self.entry_path(key);
        let tmp = self.dir.join(format!("{}.tmp.{}", key.hex(), std::process::id()));
        std::fs::write(&tmp, &bytes).with_context(|| format!("write {tmp:?}"))?;
        std::fs::rename(&tmp, &path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
        Ok(())
    }
}

fn encode_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for v in &t.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn encode_entry(key: &CacheKey, layers: &[LayerHessians]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&CACHE_VERSION.to_le_bytes());
    out.extend_from_slice(&key.0);
    out.extend_from_slice(&(layers.len() as u32).to_le_bytes());
    for lh in layers {
        out.push(lh.uniform.is_some() as u8);
        out.extend_from_slice(&(lh.scaled.len() as u32).to_le_bytes());
        for t in &lh.scaled {
            encode_tensor(&mut out, t);
        }
        if let Some(us) = &lh.uniform {
            for t in us {
                encode_tensor(&mut out, t);
            }
        }
    }
    let crc = crc32(&out[MAGIC.len()..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Bounds-checked little-endian reader over an entry's payload.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.bytes.len().saturating_sub(self.pos) {
            anyhow::bail!("truncated at byte {}", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let ndim = self.u32()? as usize;
        if ndim > 4 {
            anyhow::bail!("implausible tensor rank {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let n = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .and_then(|n| n.checked_mul(4))
            .with_context(|| format!("implausible tensor shape {shape:?}"))?;
        let bytes = self.take(n)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor::from_vec(&shape, data))
    }
}

fn decode_entry(
    bytes: &[u8],
    key: &CacheKey,
    layers: usize,
    needs_uniform: bool,
) -> Result<Vec<LayerHessians>> {
    if bytes.len() < MAGIC.len() + 4 || &bytes[..MAGIC.len()] != MAGIC {
        anyhow::bail!("bad magic");
    }
    let payload = &bytes[MAGIC.len()..bytes.len() - 4];
    let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    if crc32(payload) != stored_crc {
        anyhow::bail!("checksum mismatch");
    }
    let mut r = Reader { bytes: payload, pos: 0 };
    let version = r.u32()?;
    if version != CACHE_VERSION {
        anyhow::bail!("entry version {version}, this build writes {CACHE_VERSION}");
    }
    if r.take(16)? != key.0 {
        anyhow::bail!("key echo mismatch (hash collision or misplaced file)");
    }
    let nlayers = r.u32()? as usize;
    if nlayers != layers {
        anyhow::bail!("entry has {nlayers} layers, run expects {layers}");
    }
    let mut out = Vec::with_capacity(nlayers);
    for _ in 0..nlayers {
        let has_uniform = r.u8()? != 0;
        if has_uniform != needs_uniform {
            anyhow::bail!(
                "entry uniform-hessian presence ({has_uniform}) does not match run ({needs_uniform})"
            );
        }
        let nscaled = r.u32()? as usize;
        if nscaled != 4 {
            anyhow::bail!("entry has {nscaled} streams per layer, expected 4");
        }
        let scaled: Vec<Tensor> =
            (0..nscaled).map(|_| r.tensor()).collect::<Result<_>>()?;
        let uniform = if has_uniform {
            Some((0..nscaled).map(|_| r.tensor()).collect::<Result<_>>()?)
        } else {
            None
        };
        out.push(LayerHessians { scaled, uniform });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lh(seed: f32, uniform: bool) -> LayerHessians {
        let t = |k: f32| Tensor::from_vec(&[2, 2], vec![k, k + 1.0, k + 2.0, k + 3.0]);
        LayerHessians {
            scaled: (0..4).map(|i| t(seed + i as f32)).collect(),
            uniform: uniform.then(|| (0..4).map(|i| t(seed + 10.0 + i as f32)).collect()),
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("rsq_hesscache_{name}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn store_load_round_trip() {
        let dir = tmpdir("rt");
        let cache = HessCache::new(&dir);
        let key = CacheKey([7u8; 16]);
        let layers = vec![lh(0.0, false), lh(100.0, false)];
        cache.store(&key, &layers).unwrap();
        let got = cache.load(&key, 2, false).unwrap();
        assert_eq!(got, layers);
        // wrong expectations -> miss, not garbage
        assert!(cache.load(&key, 3, false).is_none(), "layer-count mismatch");
        assert!(cache.load(&key, 2, true).is_none(), "uniform mismatch");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn uniform_round_trip() {
        let dir = tmpdir("uni");
        let cache = HessCache::new(&dir);
        let key = CacheKey([9u8; 16]);
        let layers = vec![lh(0.5, true)];
        cache.store(&key, &layers).unwrap();
        assert_eq!(cache.load(&key, 1, true).unwrap(), layers);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_entry_is_a_miss() {
        let dir = tmpdir("corrupt");
        let cache = HessCache::new(&dir);
        let key = CacheKey([3u8; 16]);
        cache.store(&key, &[lh(1.0, false)]).unwrap();
        let path = cache.entry_path(&key);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(cache.load(&key, 1, false).is_none(), "flipped byte must fail CRC");
        // truncation likewise
        cache.store(&key, &[lh(1.0, false)]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(cache.load(&key, 1, false).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn absent_entry_is_a_quiet_miss() {
        let cache = HessCache::new(tmpdir("absent"));
        assert!(cache.load(&CacheKey([1u8; 16]), 2, false).is_none());
    }

    #[test]
    fn entries_lists_only_hess_files_missing_dir_is_empty() {
        let missing = HessCache::new(std::env::temp_dir().join("rsq_hesscache_no_such_dir"));
        assert!(missing.entries().unwrap().is_empty());

        let dir = tmpdir("ls");
        let cache = HessCache::new(&dir);
        cache.store(&CacheKey([1u8; 16]), &[lh(0.0, false)]).unwrap();
        cache.store(&CacheKey([2u8; 16]), &[lh(1.0, false)]).unwrap();
        // stray files and in-flight tmps are not entries
        std::fs::write(dir.join("README"), b"x").unwrap();
        std::fs::write(dir.join(format!("{}.tmp.999", "03".repeat(16))), b"half").unwrap();
        let es = cache.entries().unwrap();
        assert_eq!(es.len(), 2);
        for e in &es {
            assert_eq!(e.key_hex.len(), 32);
            assert!(e.bytes > 0);
            assert!(e.age_s >= 0.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_by_age_and_by_bytes() {
        let dir = tmpdir("gc");
        let cache = HessCache::new(&dir);
        for b in 1u8..=3 {
            cache.store(&CacheKey([b; 16]), &[lh(b as f32, false)]).unwrap();
        }
        let total: u64 = cache.entries().unwrap().iter().map(|e| e.bytes).sum();
        let one = total / 3;

        // byte budget of two entries: the oldest is evicted
        let rep = cache.gc(None, Some(2 * one)).unwrap();
        assert_eq!((rep.scanned, rep.deleted, rep.kept), (3, 1, 2));
        assert_eq!(rep.deleted_bytes, one);
        assert!(rep.kept_bytes <= 2 * one);
        assert_eq!(cache.entries().unwrap().len(), 2);

        // age 0 evicts everything that remains
        let rep = cache.gc(Some(0.0), None).unwrap();
        assert_eq!((rep.deleted, rep.kept), (2, 0));
        assert!(cache.entries().unwrap().is_empty());

        // gc of an empty cache is a no-op report
        assert_eq!(cache.gc(Some(0.0), Some(0)).unwrap(), GcReport { ..Default::default() });

        // an evicted entry is simply a future miss
        assert!(cache.load(&CacheKey([1u8; 16]), 1, false).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_sweeps_stale_tmp_orphans_but_not_live_writers() {
        let dir = tmpdir("tmps");
        let cache = HessCache::new(&dir);
        cache.store(&CacheKey([5u8; 16]), &[lh(0.0, false)]).unwrap();
        let fresh = dir.join(format!("{}.tmp.123", "0a".repeat(16)));
        let stale = dir.join(format!("{}.tmp.456", "0b".repeat(16)));
        std::fs::write(&fresh, b"half").unwrap();
        std::fs::write(&stale, b"half").unwrap();
        let old = SystemTime::now() - std::time::Duration::from_secs(2 * 3600);
        let f = std::fs::File::options().write(true).open(&stale).unwrap();
        f.set_times(std::fs::FileTimes::new().set_modified(old)).unwrap();
        let rep = cache.gc(None, Some(u64::MAX)).unwrap();
        assert_eq!(rep.stale_tmp_deleted, 1);
        assert!(!stale.exists(), "crashed-writer orphan swept");
        assert!(fresh.exists(), "young tmp left for its (possibly live) writer");
        assert_eq!((rep.kept, rep.deleted), (1, 0), "entries untouched");
        std::fs::remove_dir_all(&dir).ok();
    }
}
