//! The quantized-artifact subsystem (DESIGN.md §9): quantization output as
//! a shippable, loadable deployment artifact instead of a transient.
//!
//! - [`format`] — the versioned on-disk layout (`artifact.txt` manifest +
//!   `weights.bin` blobs) with per-blob CRCs and total-length checking.
//! - [`cache`] — the content-addressed Hessian cache that lets a repeat
//!   run skip pass A entirely (`sched::run_layers_cached`).
//! - [`save`] / [`load`] here — the directory-level API `rsq quantize
//!   --save DIR` and `rsq eval --artifact DIR` speak.
//!
//! Saving is **bit-faithful**: layer weights whose solve produced an
//! affine grid are stored bit-packed (2/3/4/8-bit codes + per-row f32
//! grid, `tensor::pack`), and the packer verifies exact reconstruction of
//! every element at pack time — any tensor that is not exactly
//! representable (the VQ codebook methods, or any grid drift) falls back
//! to raw f32 storage. Loading therefore always reproduces the in-memory
//! `ParamSet` bit-for-bit, so `rsq eval --artifact` scores are
//! bit-identical to the pipeline that produced the artifact.
//!
//! The writer is deterministic — same quantized weights in, same bytes
//! out — which is what makes "warm Hessian-cache runs produce
//! byte-identical artifacts" testable (rust/tests/integration_artifact.rs).

pub mod cache;
pub mod format;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::Module;
use crate::model::ParamSet;
use crate::tensor::pack::{PackedRows, RowGrid};
use crate::util::hash::crc32;

use super::pipeline::{QuantOptions, QuantReport};

pub use format::{
    ArtifactManifest, Blob, Codec, TensorEntry, ARTIFACT_VERSION, BLOBS_FILE, MANIFEST_FILE,
};

/// Write the quantized `ParamSet` as an artifact directory. `report`
/// supplies the per-weight grids captured by the solve phase (and the
/// Hessian content-address for provenance); weights without a grid are
/// stored raw.
pub fn save(
    dir: &Path,
    q: &ParamSet,
    report: &QuantReport,
    opts: &QuantOptions,
) -> Result<ArtifactManifest> {
    // same contract as the CLI's pre-run check: the leaf directory is
    // created, a missing parent is the caller's typo (never silently
    // mkdir -p an arbitrary tree)
    validate_save_dir(dir)?;
    let cfg = &q.cfg;
    // tensor index -> solve grid (and allocator width, when the
    // mixed-precision path chose per-module widths — DESIGN.md §14),
    // from the report's (layer, module) order
    let mut grid_of: Vec<Option<&RowGrid>> = vec![None; q.tensors.len()];
    let mut width_of: Vec<u32> = vec![opts.bits; q.tensors.len()];
    if report.grids.len() == cfg.layers * Module::ALL.len() {
        for l in 0..cfg.layers {
            for (mi, m) in Module::ALL.into_iter().enumerate() {
                let slot = l * Module::ALL.len() + mi;
                grid_of[cfg.param_index(l, m)] = report.grids[slot].as_ref();
                if let Some(&w) = report.widths.get(slot) {
                    width_of[cfg.param_index(l, m)] = w;
                }
            }
        }
    }

    let names = cfg.param_names();
    let mut blobs: Vec<u8> = Vec::new();
    let mut tensors = Vec::with_capacity(q.tensors.len());
    for (i, t) in q.tensors.iter().enumerate() {
        let packed = grid_of[i].and_then(|g| match PackedRows::pack(t, width_of[i], g) {
            Ok(p) => Some(p),
            Err(e) => {
                if opts.verbose {
                    eprintln!("[artifact] {}: storing raw ({e})", names[i]);
                }
                None
            }
        });
        let codec = match &packed {
            Some(p) => Codec::Packed { bits: p.bits },
            None => Codec::Raw,
        };
        let bytes = format::encode_blob(t, packed.as_ref());
        tensors.push(TensorEntry {
            name: names[i].clone(),
            codec,
            shape: t.shape.clone(),
            offset: blobs.len() as u64,
            len: bytes.len() as u64,
            crc: crc32(&bytes),
        });
        blobs.extend_from_slice(&bytes);
    }

    let module_mask = opts.module_mask.as_ref().map(|mask| {
        let mut names: Vec<String> = mask.iter().map(|m| m.name().to_string()).collect();
        names.sort_unstable();
        names
    });
    let manifest = ArtifactManifest {
        version: ARTIFACT_VERSION,
        config: cfg.clone(),
        method: opts.method.name().to_string(),
        strategy: opts.strategy.name(),
        bits: opts.bits,
        damp: opts.damp,
        rot_seed: opts.rot_seed,
        seq_len: opts.seq_len,
        expansion: opts.expansion,
        module_mask,
        hess_key: if report.hess_key.is_empty() {
            "-".to_string()
        } else {
            report.hess_key.clone()
        },
        budget: report.budget.clone(),
        avg_bits: report.avg_bits,
        total_len: blobs.len() as u64,
        tensors,
    };
    manifest.check()?;

    if !dir.exists() {
        std::fs::create_dir(dir).with_context(|| format!("create artifact dir {dir:?}"))?;
    }
    let blob_path = dir.join(BLOBS_FILE);
    std::fs::write(&blob_path, &blobs).with_context(|| format!("write {blob_path:?}"))?;
    let man_path = dir.join(MANIFEST_FILE);
    std::fs::write(&man_path, manifest.render()).with_context(|| format!("write {man_path:?}"))?;
    Ok(manifest)
}

/// Read + parse the manifest and verify `weights.bin` against it (total
/// length now, per-blob CRCs as the caller walks the entries).
fn read_verified(dir: &Path) -> Result<(ArtifactManifest, Vec<u8>)> {
    let man_path = dir.join(MANIFEST_FILE);
    let text = std::fs::read_to_string(&man_path).with_context(|| {
        format!(
            "no artifact manifest at {man_path:?} — expected a directory written by \
             `rsq quantize --save DIR`"
        )
    })?;
    let manifest = ArtifactManifest::parse(&text)
        .with_context(|| format!("parse {man_path:?}"))?;
    let blob_path = dir.join(BLOBS_FILE);
    let blobs = std::fs::read(&blob_path).with_context(|| format!("read {blob_path:?}"))?;
    if blobs.len() as u64 != manifest.total_len {
        bail!(
            "{blob_path:?} is {} bytes but the manifest records {} — artifact truncated or \
             corrupt; re-run `rsq quantize --save`",
            blobs.len(),
            manifest.total_len
        );
    }
    Ok((manifest, blobs))
}

fn verified_span<'b>(entry: &TensorEntry, blobs: &'b [u8]) -> Result<&'b [u8]> {
    let span = &blobs[entry.offset as usize..(entry.offset + entry.len) as usize];
    if crc32(span) != entry.crc {
        bail!(
            "checksum mismatch in tensor {} — artifact corrupt; re-run \
             `rsq quantize --save`",
            entry.name
        );
    }
    Ok(span)
}

/// Load an artifact directory back into a `ParamSet`, verifying total
/// length and every per-blob CRC. Errors are actionable; corrupt input
/// can never produce a silently-wrong model.
pub fn load(dir: &Path) -> Result<(ParamSet, ArtifactManifest)> {
    load_with(dir, None)
}

/// [`load`] with a worker pool: each packed tensor unpacks pool-parallel
/// over its row blocks (bit-identical to the serial decode at every jobs
/// count — `PackedRows::unpack`), so a multi-layer artifact no longer
/// dequantizes one tensor row at a time on one thread.
pub fn load_with(
    dir: &Path,
    pool: Option<&crate::util::Pool>,
) -> Result<(ParamSet, ArtifactManifest)> {
    let (manifest, blobs) = read_verified(dir)?;
    let mut tensors = Vec::with_capacity(manifest.tensors.len());
    for entry in &manifest.tensors {
        tensors.push(format::decode_blob(entry, verified_span(entry, &blobs)?, pool)?);
    }
    Ok((ParamSet { cfg: manifest.config.clone(), tensors }, manifest))
}

/// Load an artifact **without leaving the storage domain**: packed layer
/// weights come back as [`tensor::pack::PackedRows`] for the serving
/// layer's fused dequantize kernels (DESIGN.md §11), raw tensors as f32.
/// Same verification (total length + per-blob CRCs) and parameter order
/// as [`load`]; `serve::PackedModel::load` is the consumer.
///
/// [`tensor::pack::PackedRows`]: crate::tensor::pack::PackedRows
pub fn load_packed(dir: &Path) -> Result<(Vec<format::Blob>, ArtifactManifest)> {
    let (manifest, blobs) = read_verified(dir)?;
    let mut out = Vec::with_capacity(manifest.tensors.len());
    for entry in &manifest.tensors {
        out.push(format::decode_blob_any(entry, verified_span(entry, &blobs)?)?);
    }
    Ok((out, manifest))
}

/// Fail-fast check for `rsq quantize --save DIR`, run **before** training
/// and calibration start: an unwritable or orphaned save target must not
/// cost the user a full quantization run to discover.
pub fn validate_save_dir(dir: &Path) -> Result<()> {
    let probe_in = |d: &Path| -> Result<()> {
        let probe = d.join(format!(".rsq-write-probe-{}", std::process::id()));
        std::fs::write(&probe, b"probe")
            .with_context(|| format!("cannot write artifact to {dir:?}: {d:?} is not writable"))?;
        std::fs::remove_file(&probe).ok();
        Ok(())
    };
    if dir.exists() {
        if !dir.is_dir() {
            bail!("cannot write artifact to {dir:?}: path exists and is not a directory");
        }
        return probe_in(dir);
    }
    let parent = match dir.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    if !parent.exists() {
        bail!(
            "cannot write artifact to {dir:?}: parent directory {parent:?} does not exist — \
             create it first"
        );
    }
    if !parent.is_dir() {
        bail!("cannot write artifact to {dir:?}: parent {parent:?} is not a directory");
    }
    probe_in(&parent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::quant::pipeline::{Method, QuantOptions};
    use crate::quantref;
    use crate::tensor::pack::RowGrid;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d: 64,
            layers: 2,
            heads: 2,
            ff: 128,
            vocab: 256,
            max_seq: 64,
            batch: 4,
            seq_lens: vec![32, 64],
            ldlq_k: 1024,
            ldlq_g: 8,
        }
    }

    /// RTN-quantize every layer weight host-side, producing a ParamSet +
    /// report grids exactly like a real run would.
    fn quantized_fixture(bits: u32) -> (ParamSet, QuantReport, QuantOptions) {
        let c = cfg();
        let mut p = ParamSet::init(&c, 3);
        let mut report = QuantReport::default();
        report.hess_key = "ab".repeat(16);
        let maxq = ((1u64 << bits) - 1) as f32;
        for l in 0..c.layers {
            for m in Module::ALL {
                let w = p.weight(l, m).clone();
                let q = quantref::rtn(&w, maxq);
                let (scale, zero) = quantref::row_grid(&w, maxq);
                report.grids.push(Some(RowGrid { scale, zero }));
                p.set_weight(l, m, q);
            }
        }
        let mut opts = QuantOptions::new(Method::Rtn, bits, 64);
        opts.strategy = crate::quant::Strategy::Uniform;
        (p, report, opts)
    }

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("rsq_artifact_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn save_load_bit_identical() {
        for bits in [2u32, 3, 4, 8] {
            let (p, report, opts) = quantized_fixture(bits);
            let dir = tmpdir(&format!("rt{bits}"));
            let manifest = save(&dir, &p, &report, &opts).unwrap();
            // all 14 layer weights packed, the rest raw
            let packed = manifest
                .tensors
                .iter()
                .filter(|t| matches!(t.codec, Codec::Packed { .. }))
                .count();
            assert_eq!(packed, 14, "bits={bits}");
            let (q, m2) = load(&dir).unwrap();
            assert_eq!(m2.bits, bits);
            assert_eq!(q.tensors.len(), p.tensors.len());
            for (a, b) in q.tensors.iter().zip(&p.tensors) {
                assert_eq!(a.shape, b.shape);
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "bits={bits}");
                }
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn mixed_width_save_load_bit_identical() {
        // per-module widths (report.widths, DESIGN.md §14): every layer
        // weight packs at its own slot width, the manifest records the
        // codec per tensor plus the budget provenance, and the load is
        // bit-identical — the serve/eval paths already decode per-tensor
        // widths, so this pins the writer side of the contract
        let c = cfg();
        let mut p = ParamSet::init(&c, 5);
        let mut report = QuantReport::default();
        report.hess_key = "cd".repeat(16);
        let widths: Vec<u32> = vec![2, 3, 4, 8, 2, 3, 4, 8, 4, 3, 2, 8, 3, 2];
        assert_eq!(widths.len(), c.layers * Module::ALL.len());
        let mut k = 0;
        for l in 0..c.layers {
            for m in Module::ALL {
                let bits = widths[k];
                k += 1;
                let maxq = ((1u64 << bits) - 1) as f32;
                let w = p.weight(l, m).clone();
                let q = quantref::rtn(&w, maxq);
                let (scale, zero) = quantref::row_grid(&w, maxq);
                report.grids.push(Some(RowGrid { scale, zero }));
                p.set_weight(l, m, q);
            }
        }
        report.widths = widths.clone();
        report.avg_bits = Some(3.625);
        report.budget = Some("avg-bits:4".into());
        let mut opts = QuantOptions::new(Method::Rtn, 3, 64);
        opts.strategy = crate::quant::Strategy::Uniform;

        let dir = tmpdir("mixed");
        let manifest = save(&dir, &p, &report, &opts).unwrap();
        assert_eq!(manifest.budget.as_deref(), Some("avg-bits:4"));
        assert_eq!(manifest.avg_bits, Some(3.625));
        // each layer weight's codec carries its slot width
        let mut k = 0;
        for l in 0..c.layers {
            for m in Module::ALL {
                let entry = &manifest.tensors[c.param_index(l, m)];
                assert_eq!(
                    entry.codec,
                    Codec::Packed { bits: widths[k] },
                    "layer {l} {m:?}"
                );
                k += 1;
            }
        }
        let (q, m2) = load(&dir).unwrap();
        assert_eq!(m2.avg_bits, Some(3.625));
        for (a, b) in q.tensors.iter().zip(&p.tensors) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // storage domain (the serve loader): per-blob widths survive
        let (blobs, _) = load_packed(&dir).unwrap();
        let mut k = 0;
        for l in 0..c.layers {
            for m in Module::ALL {
                match &blobs[c.param_index(l, m)] {
                    Blob::Packed(pr) => assert_eq!(pr.bits, widths[k], "layer {l} {m:?}"),
                    Blob::Raw(_) => panic!("layer {l} {m:?} lost its packing"),
                }
                k += 1;
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_with_pool_and_load_packed_are_bit_identical() {
        let (p, report, opts) = quantized_fixture(3);
        let dir = tmpdir("pool");
        save(&dir, &p, &report, &opts).unwrap();
        let (serial, _) = load(&dir).unwrap();
        let pool = crate::util::Pool::new(4);
        let (pooled, _) = load_with(&dir, Some(&pool)).unwrap();
        for (a, b) in serial.tensors.iter().zip(&pooled.tensors) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // storage-domain load: 14 packed layer weights whose unpack equals
        // the ParamSet load bitwise, everything else raw
        let (blobs, manifest) = load_packed(&dir).unwrap();
        let packed = blobs.iter().filter(|b| matches!(b, Blob::Packed(_))).count();
        assert_eq!(packed, 14);
        assert_eq!(blobs.len(), manifest.tensors.len());
        for (blob, t) in blobs.iter().zip(&serial.tensors) {
            let dense = match blob {
                Blob::Raw(t) => t.clone(),
                Blob::Packed(p) => p.unpack(None),
            };
            assert_eq!(dense.shape, t.shape);
            for (x, y) in dense.data.iter().zip(&t.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_deterministic() {
        let (p, report, opts) = quantized_fixture(3);
        let (d1, d2) = (tmpdir("det1"), tmpdir("det2"));
        save(&d1, &p, &report, &opts).unwrap();
        save(&d2, &p, &report, &opts).unwrap();
        for f in [MANIFEST_FILE, BLOBS_FILE] {
            assert_eq!(
                std::fs::read(d1.join(f)).unwrap(),
                std::fs::read(d2.join(f)).unwrap(),
                "{f} must be byte-identical across saves"
            );
        }
        std::fs::remove_dir_all(&d1).ok();
        std::fs::remove_dir_all(&d2).ok();
    }

    #[test]
    fn missing_grids_fall_back_to_raw() {
        let (p, mut report, opts) = quantized_fixture(3);
        report.grids.clear();
        let dir = tmpdir("rawfb");
        let manifest = save(&dir, &p, &report, &opts).unwrap();
        assert!(manifest.tensors.iter().all(|t| t.codec == Codec::Raw));
        let (q, _) = load(&dir).unwrap();
        for (a, b) in q.tensors.iter().zip(&p.tensors) {
            assert_eq!(a.data, b.data);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_truncation_and_corruption() {
        let (p, report, opts) = quantized_fixture(3);
        let dir = tmpdir("corrupt");
        save(&dir, &p, &report, &opts).unwrap();
        let blob_path = dir.join(BLOBS_FILE);
        let good = std::fs::read(&blob_path).unwrap();

        std::fs::write(&blob_path, &good[..good.len() - 7]).unwrap();
        let err = load(&dir).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");

        let mut bad = good.clone();
        bad[good.len() / 3] ^= 0x40;
        std::fs::write(&blob_path, &bad).unwrap();
        let err = load(&dir).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_save_dir_fails_fast() {
        // nonexistent parent
        let orphan = std::env::temp_dir().join("rsq_no_such_parent_xyz/child");
        let err = validate_save_dir(&orphan).unwrap_err().to_string();
        assert!(err.contains("does not exist"), "{err}");

        // parent exists but is a file
        let file = std::env::temp_dir().join(format!("rsq_probe_file_{}", std::process::id()));
        std::fs::write(&file, b"x").unwrap();
        let err = validate_save_dir(&file.join("sub")).unwrap_err().to_string();
        assert!(err.contains("not a directory"), "{err}");
        let err = validate_save_dir(&file).unwrap_err().to_string();
        assert!(err.contains("not a directory"), "{err}");
        std::fs::remove_file(&file).ok();

        // happy paths: existing dir, and a fresh child of an existing dir
        validate_save_dir(&std::env::temp_dir()).unwrap();
        validate_save_dir(&std::env::temp_dir().join("rsq_fresh_child")).unwrap();
    }
}
