//! The RSQ layer-by-layer quantization coordinator (paper Sec. 4.2),
//! parallelized over a [`Pool`] of worker threads (DESIGN.md §5).
//!
//! For each transformer layer:
//!   pass A  — stream every calibration batch through the (not yet
//!             quantized) layer, capture the four weight-input streams and
//!             the dynamic token scores, turn scores into the importance
//!             matrix R (Sec. 4.3 + Eq. 4), and accumulate the scaled
//!             Hessians H = 2·X·R²·Xᵀ via the L1 Pallas kernel. Batches
//!             are sharded across the workers in bounded windows (peak
//!             memory stays O(jobs) partial Hessians); each worker returns
//!             its per-batch partial Hessians and the coordinator reduces
//!             them **in batch order**, so the sum is bit-identical to the
//!             serial path no matter how many workers ran;
//!   solve   — quantize the seven weights against their stream's Hessian
//!             (GPTQ / LDLQ-VQ HLO modules, or RTN which needs no data).
//!             The seven solves are independent and dispatch to the pool
//!             concurrently; results are applied in `Module::ALL` order;
//!   pass B  — recompute the layer outputs with the *quantized* weights so
//!             the next layer calibrates on what it will actually see at
//!             inference (standard GPTQ practice). Each batch's hidden
//!             state updates independently, so this also fans out.
//!
//! Modes: RTN, GPTQ (no rotate, uniform), QuaRot (rotate, uniform), SQ
//! (scale only), RSQ (rotate + scale), and the VQ variants of
//! QuaRot/RSQ (Tab. 6). Fig. 7's per-module ablation is `module_mask`.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::corpus::{expand_dataset, CalibSet};
use crate::model::config::{InputStream, Module};
use crate::model::fuse::fuse_gains;
use crate::model::outliers::kurtosis_ratio;
use crate::model::rotate::{rotate_params, rotation_matrix};
use crate::model::ParamSet;
use crate::runtime::{self, Engine, SharedLiteral};
use crate::tensor::Tensor;
use crate::util::Pool;

use super::strategy::{LayerScores, Strategy};
use super::vq::e8_codebook;

/// Which quantizer family to run (the paper's baselines + RSQ + VQ rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Round-to-nearest (data-free baseline).
    Rtn,
    /// GPTQ: uniform token weighting, no rotation (paper baseline).
    Gptq,
    /// QuaRot: rotation + GPTQ with uniform weighting (paper baseline).
    QuaRot,
    /// SQ: token scaling without rotation (paper Fig. 9 ablation).
    Sq,
    /// RSQ: rotate, scale, then quantize (the paper's method).
    Rsq,
    /// QuaRot with the E8 codebook + LDLQ (Tab. 6 baseline).
    QuaRotVq,
    /// RSQ with the E8 codebook + LDLQ (Tab. 6).
    RsqVq,
}

impl Method {
    /// Parse a CLI spelling (`rsq`, `quarot-vq`, …); case-insensitive.
    /// Inverse of [`Method::name`].
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Some(Method::Rtn),
            "gptq" => Some(Method::Gptq),
            "quarot" => Some(Method::QuaRot),
            "sq" => Some(Method::Sq),
            "rsq" => Some(Method::Rsq),
            "quarot-vq" | "quarotvq" => Some(Method::QuaRotVq),
            "rsq-vq" | "rsqvq" => Some(Method::RsqVq),
            _ => None,
        }
    }

    /// Canonical CLI spelling; `Method::parse(m.name()) == Some(m)`.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "rtn",
            Method::Gptq => "gptq",
            Method::QuaRot => "quarot",
            Method::Sq => "sq",
            Method::Rsq => "rsq",
            Method::QuaRotVq => "quarot-vq",
            Method::RsqVq => "rsq-vq",
        }
    }

    /// Does this method apply the randomized-Hadamard rotation first?
    pub fn rotates(&self) -> bool {
        matches!(self, Method::QuaRot | Method::Rsq | Method::QuaRotVq | Method::RsqVq)
    }

    /// Does this method weight tokens by importance (scaled Hessians)?
    pub fn scales(&self) -> bool {
        matches!(self, Method::Sq | Method::Rsq | Method::RsqVq)
    }

    /// Does this method use the E8 codebook + LDLQ instead of GPTQ's grid?
    pub fn vector_quant(&self) -> bool {
        matches!(self, Method::QuaRotVq | Method::RsqVq)
    }
}

/// Everything one `quantize` run needs beyond the model + data.
#[derive(Clone, Debug)]
pub struct QuantOptions {
    /// quantizer family (see [`Method`])
    pub method: Method,
    /// importance strategy used when `method.scales()`
    pub strategy: Strategy,
    /// quantization bit width (paper Tab. 5 sweeps 2-4)
    pub bits: u32,
    /// Hessian dampening fraction added to the diagonal (GPTQ's λ)
    pub damp: f32,
    /// calibration sequence length (must be one of cfg.seq_lens)
    pub seq_len: usize,
    /// dataset-expansion factor M (paper Sec. 4.4); 1 = off
    pub expansion: usize,
    /// Fig. 7: scale only these modules (None = all seven)
    pub module_mask: Option<HashSet<Module>>,
    /// seed for the randomized-Hadamard rotation (varied across runs)
    pub rot_seed: u64,
    /// scheduler worker threads (`--jobs`): 1 = serial, 0 = one per
    /// hardware thread. Any value produces bit-identical output.
    pub jobs: usize,
    /// log per-layer reconstruction error to stderr
    pub verbose: bool,
}

impl QuantOptions {
    /// Defaults matching the paper's main configuration (AttnCon r_min
    /// 0.05, damp 0.01, no expansion, serial scheduler).
    pub fn new(method: Method, bits: u32, seq_len: usize) -> Self {
        QuantOptions {
            method,
            strategy: Strategy::AttnCon { r_min: 0.05 },
            bits,
            damp: 0.01,
            seq_len,
            expansion: 1,
            module_mask: None,
            rot_seed: 0x5157, // "QW"
            jobs: 1,
            verbose: false,
        }
    }

    /// Largest quantization level for the configured bit width.
    pub fn maxq(&self) -> f32 {
        ((1u64 << self.bits) - 1) as f32
    }
}

/// Per-run accounting returned next to the quantized parameters.
#[derive(Clone, Debug, Default)]
pub struct QuantReport {
    /// Σ over weights of tr((W-Q)H(W-Q)ᵀ), per layer
    pub layer_err: Vec<f32>,
    /// weight kurtosis ratio before the rotate step
    pub kurtosis_before: f32,
    /// weight kurtosis ratio after the rotate step (lower ⇒ fewer outliers)
    pub kurtosis_after: f32,
    /// end-to-end wall time of the whole `quantize` call
    pub wall_seconds: f64,
    /// calibration batches consumed (after padding/expansion)
    pub batches: usize,
    /// worker threads the scheduler actually used
    pub jobs: usize,
    /// total seconds in pass A (capture + Hessian accumulation), all layers
    pub pass_a_seconds: f64,
    /// total seconds in the solve phase (GPTQ/LDLQ/RTN), all layers
    pub solve_seconds: f64,
    /// total seconds in pass B (quantized re-forward), all layers
    pub pass_b_seconds: f64,
}

/// Per-batch pass-A output: one partial Hessian per input stream, in
/// [`InputStream`] order, plus the uniform-weighted set when a partial
/// module mask needs both (Fig. 7).
struct BatchHessians {
    scaled: Vec<Tensor>,
    uniform: Option<Vec<Tensor>>,
}

/// Quantize `params` with the given options; returns the quantized set and
/// a report. `params` is cloned — the caller keeps the full-precision model.
///
/// Work is dispatched over `opts.jobs` worker threads sharing `engine`.
/// The output is **bit-identical for every jobs value**: workers only
/// compute independent per-batch / per-module values, and every
/// floating-point reduction (Hessian sums, layer error sums) happens on
/// the coordinator thread in the serial path's order (DESIGN.md §5).
pub fn quantize(
    engine: &Engine,
    params: &ParamSet,
    calib: &CalibSet,
    opts: &QuantOptions,
) -> Result<(ParamSet, QuantReport)> {
    let t0 = Instant::now();
    let cfg = engine.config().clone();
    if !cfg.seq_lens.contains(&opts.seq_len) {
        bail!("seq_len {} not in artifact set {:?}", opts.seq_len, cfg.seq_lens);
    }
    let pool = Pool::new(opts.jobs);
    let mut p = params.clone();
    let mut report = QuantReport {
        kurtosis_before: kurtosis_ratio(&p),
        jobs: pool.jobs(),
        ..Default::default()
    };

    // --- Rotate (paper Sec. 4.2 step 1) ---
    if opts.method.rotates() {
        fuse_gains(&mut p);
        let q = rotation_matrix(cfg.d, opts.rot_seed);
        rotate_params(&mut p, &q);
    }
    report.kurtosis_after = kurtosis_ratio(&p);

    // --- RTN short-circuit: data-free, so every (layer, module) solve is
    // independent; the layers×7 grid fans out in windows so peak memory
    // stays O(jobs) quantized tensors, applied in grid order ---
    if opts.method == Method::Rtn {
        let ts = Instant::now();
        let nmod = Module::ALL.len();
        let total = cfg.layers * nmod;
        let window = pool.jobs() * 2;
        let mut errsum = 0.0f32;
        for start in (0..total).step_by(window) {
            let n = window.min(total - start);
            let solved = pool.run(n, |off| -> Result<(Tensor, f32)> {
                let k = start + off;
                let (l, m) = (k / nmod, Module::ALL[k % nmod]);
                let (o, i) = cfg.weight_shape(m);
                let w = p.weight(l, m);
                let outs = engine.exec_ref(
                    &format!("rtn_{o}x{i}"),
                    &[&runtime::tensor_literal(w)?, &runtime::scalar_literal(opts.maxq())],
                )?;
                let q = runtime::literal_tensor(&outs[0])?;
                let err = q.sub(w).frob_norm().powi(2);
                Ok((q, err))
            });
            for (off, solved) in solved.into_iter().enumerate() {
                let k = start + off;
                let (l, m) = (k / nmod, Module::ALL[k % nmod]);
                let (q, err) = solved?;
                errsum += err;
                p.set_weight(l, m, q);
                if k % nmod == nmod - 1 {
                    report.layer_err.push(errsum);
                    errsum = 0.0;
                }
            }
        }
        report.solve_seconds = ts.elapsed().as_secs_f64();
        report.wall_seconds = t0.elapsed().as_secs_f64();
        return Ok((p, report));
    }

    // --- calibration data (Sec. 4.4 expansion) ---
    let mut calib = if opts.expansion > 1 {
        expand_dataset(calib, opts.expansion)
    } else {
        calib.clone()
    };
    calib.pad_to_batch(cfg.batch);
    let t = opts.seq_len;
    let batches: Vec<&[Vec<i32>]> = calib.samples.chunks(cfg.batch).collect();
    report.batches = batches.len();
    let freq = calib.token_frequencies(cfg.vocab);

    let lname = format!("layer_fwd_t{t}");
    let hess_d = format!("hess_d_t{t}");
    let hess_ff = format!("hess_ff_t{t}");
    let codebook_lit: Option<SharedLiteral> = if opts.method.vector_quant() {
        Some(runtime::shared_literal(&e8_codebook(cfg.ldlq_k, opts.rot_seed))?)
    } else {
        None
    };

    // initial hidden states: embed every batch once (fans out per batch)
    let emb_lit = runtime::shared_literal(&p.tensors[0])?;
    let pos_lit = runtime::shared_literal(&p.tensors[1])?;
    let mut z_lits: Vec<SharedLiteral> = pool
        .run(batches.len(), |bi| -> Result<SharedLiteral> {
            let tl = runtime::tokens_literal(batches[bi], t)?;
            let z = engine.exec_ref(&format!("embed_t{t}"), &[&tl, emb_lit.get(), pos_lit.get()])?;
            Ok(z.into_iter().next().unwrap().into())
        })
        .into_iter()
        .collect::<Result<_>>()?;

    // A partial module mask (Fig. 7) needs BOTH Hessians per stream: the
    // masked modules use the scaled one, the rest the uniform one. When the
    // method doesn't scale at all, the "scaled" accumulator already holds
    // the uniform Hessian (Strategy::Uniform), so no second pass is needed.
    let needs_uniform = opts.method.scales()
        && opts
            .module_mask
            .as_ref()
            .map(|m| m.len() < Module::ALL.len())
            .unwrap_or(false);

    // Fan-out window for the per-batch phases: a few tasks per worker keeps
    // the pool busy while bounding in-flight results to O(jobs), not
    // O(batches); windows are processed in order, so reductions and
    // in-place updates keep the serial path's exact order.
    let window = pool.jobs() * 2;

    for l in 0..cfg.layers {
        // layer params as literals, once per layer
        let base = 2 + l * 9;
        let lp: Vec<SharedLiteral> = (0..9)
            .map(|k| runtime::shared_literal(&p.tensors[base + k]))
            .collect::<Result<_>>()?;

        // --- pass A: captures + scores -> per-batch partial Hessians,
        // computed across the pool in windows, reduced here in batch
        // order ---
        let ta = Instant::now();
        let mut h_scaled: [Option<Tensor>; 4] = [None, None, None, None];
        let mut h_uniform: [Option<Tensor>; 4] = [None, None, None, None];
        for start in (0..batches.len()).step_by(window) {
            let n = window.min(batches.len() - start);
            let partials = pool.run(n, |off| -> Result<BatchHessians> {
                let bi = start + off;
                let mut ins: Vec<&xla::Literal> = Vec::with_capacity(10);
                ins.push(z_lits[bi].get());
                ins.extend(lp.iter().map(SharedLiteral::get));
                // outs: z2, xa, xo, xf, xd, attn_con, act_norm, act_diff, token_sim
                let outs = engine.exec_ref(&lname, &ins)?;
                let scores = LayerScores {
                    attn_con: rows_of(&runtime::literal_tensor(&outs[5])?),
                    act_norm: rows_of(&runtime::literal_tensor(&outs[6])?),
                    act_diff: rows_of(&runtime::literal_tensor(&outs[7])?),
                    token_sim: rows_of(&runtime::literal_tensor(&outs[8])?),
                };
                let strategy = if opts.method.scales() { opts.strategy } else { Strategy::Uniform };
                let batch = batches[bi];
                let r = strategy.importance(
                    &cfg, t, batch.len(), Some(&scores), Some(batch), Some(&freq));
                let r_lit = runtime::tensor_literal(&Tensor::from_vec(
                    &[batch.len(), t],
                    r.iter().flatten().cloned().collect(),
                ))?;
                let uni_lit = if needs_uniform {
                    Some(runtime::tensor_literal(&Tensor::ones(&[batch.len(), t]))?)
                } else {
                    None
                };
                let mut scaled = Vec::with_capacity(4);
                let mut uniform = uni_lit.as_ref().map(|_| Vec::with_capacity(4));
                for (si, xout) in [(0usize, 1usize), (1, 2), (2, 3), (3, 4)] {
                    let hess_mod = if si == 3 { &hess_ff } else { &hess_d };
                    let h = engine.exec_ref(hess_mod, &[&outs[xout], &r_lit])?;
                    scaled.push(runtime::literal_tensor(&h[0])?);
                    if let (Some(u), Some(ul)) = (uniform.as_mut(), uni_lit.as_ref()) {
                        let hu = engine.exec_ref(hess_mod, &[&outs[xout], ul])?;
                        u.push(runtime::literal_tensor(&hu[0])?);
                    }
                }
                Ok(BatchHessians { scaled, uniform })
            });
            for part in partials {
                let part = part?;
                for (si, h) in part.scaled.into_iter().enumerate() {
                    accumulate(&mut h_scaled[si], h);
                }
                if let Some(us) = part.uniform {
                    for (si, h) in us.into_iter().enumerate() {
                        accumulate(&mut h_uniform[si], h);
                    }
                }
            }
        }
        report.pass_a_seconds += ta.elapsed().as_secs_f64();

        // --- solve: the seven per-module quantizations fan out; results
        // are applied (and errors summed) in Module::ALL order ---
        let ts = Instant::now();
        let solved = pool.run(Module::ALL.len(), |mi| -> Result<(Tensor, f32)> {
            let m = Module::ALL[mi];
            let scaled = match &opts.module_mask {
                Some(mask) => opts.method.scales() && mask.contains(&m),
                None => opts.method.scales(),
            };
            let stream = stream_index(m.input_stream());
            let h = if scaled {
                h_scaled[stream].as_ref().unwrap()
            } else if needs_uniform {
                h_uniform[stream].as_ref().unwrap()
            } else {
                h_scaled[stream].as_ref().unwrap() // uniform strategy ⇒ same
            };
            let (o, i) = cfg.weight_shape(m);
            let w_lit = runtime::tensor_literal(p.weight(l, m))?;
            let h_lit = runtime::tensor_literal(h)?;
            let damp_lit = runtime::scalar_literal(opts.damp);
            let maxq_lit = runtime::scalar_literal(opts.maxq());
            let outs = if opts.method.vector_quant() {
                engine.exec_ref(
                    &format!("ldlq_{o}x{i}"),
                    &[&w_lit, &h_lit, codebook_lit.as_ref().unwrap().get(), &damp_lit],
                )?
            } else {
                engine.exec_ref(
                    &format!("gptq_{o}x{i}"),
                    &[&w_lit, &h_lit, &maxq_lit, &damp_lit],
                )?
            };
            Ok((runtime::literal_tensor(&outs[0])?, runtime::literal_scalar(&outs[1])?))
        });
        let mut errsum = 0.0f32;
        for (m, solved) in Module::ALL.into_iter().zip(solved) {
            let (q, err) = solved?;
            errsum += err;
            p.set_weight(l, m, q);
        }
        report.solve_seconds += ts.elapsed().as_secs_f64();
        report.layer_err.push(errsum);
        if opts.verbose {
            eprintln!("[quant:{}] layer {l}: hessian-weighted err {errsum:.3}", opts.method.name());
        }

        // --- pass B: propagate through the quantized layer; every batch's
        // hidden state updates independently, so this fans out too.
        // (skipped for the last layer: its outputs feed nothing — saves
        //  1/L of the pass-B forward cost; DESIGN.md §7)
        if l + 1 < cfg.layers {
            let tb = Instant::now();
            let lp_q: Vec<SharedLiteral> = (0..9)
                .map(|k| runtime::shared_literal(&p.tensors[base + k]))
                .collect::<Result<_>>()?;
            // windowed like pass A: old hidden states are replaced in
            // place per window, so peak memory is batches + O(jobs)
            // literals, not 2x batches
            for start in (0..batches.len()).step_by(window) {
                let n = window.min(batches.len() - start);
                let next_z = pool.run(n, |off| -> Result<SharedLiteral> {
                    let mut ins: Vec<&xla::Literal> = Vec::with_capacity(10);
                    ins.push(z_lits[start + off].get());
                    ins.extend(lp_q.iter().map(SharedLiteral::get));
                    let outs = engine.exec_ref(&lname, &ins)?;
                    Ok(outs.into_iter().next().unwrap().into())
                });
                for (off, z) in next_z.into_iter().enumerate() {
                    z_lits[start + off] = z?;
                }
            }
            report.pass_b_seconds += tb.elapsed().as_secs_f64();
        }
    }

    report.wall_seconds = t0.elapsed().as_secs_f64();
    Ok((p, report))
}

/// Index of an input stream inside the pass-A Hessian accumulators.
fn stream_index(s: InputStream) -> usize {
    match s {
        InputStream::Xa => 0,
        InputStream::Xo => 1,
        InputStream::Xf => 2,
        InputStream::Xd => 3,
    }
}

fn accumulate(acc: &mut Option<Tensor>, h: Tensor) {
    match acc {
        Some(a) => a.add_in_place(&h),
        None => *acc = Some(h),
    }
}

fn rows_of(t: &Tensor) -> Vec<Vec<f32>> {
    let (r, c) = (t.shape[0], t.shape[1]);
    (0..r).map(|i| t.data[i * c..(i + 1) * c].to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for m in [
            Method::Rtn, Method::Gptq, Method::QuaRot, Method::Sq,
            Method::Rsq, Method::QuaRotVq, Method::RsqVq,
        ] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn method_parse_aliases_and_case() {
        assert_eq!(Method::parse("RSQ"), Some(Method::Rsq));
        assert_eq!(Method::parse("QuaRot"), Some(Method::QuaRot));
        assert_eq!(Method::parse("rsqvq"), Some(Method::RsqVq));
        assert_eq!(Method::parse("quarotvq"), Some(Method::QuaRotVq));
        assert_eq!(Method::parse("rsq-vq"), Some(Method::RsqVq));
        assert_eq!(Method::parse(""), None);
        assert_eq!(Method::parse("rsq "), None, "no trimming — CLI passes exact tokens");
    }

    #[test]
    fn method_semantics() {
        assert!(Method::Rsq.rotates() && Method::Rsq.scales());
        assert!(Method::QuaRot.rotates() && !Method::QuaRot.scales());
        assert!(!Method::Sq.rotates() && Method::Sq.scales());
        assert!(!Method::Gptq.rotates() && !Method::Gptq.scales());
        assert!(Method::RsqVq.vector_quant() && Method::RsqVq.scales());
    }

    #[test]
    fn maxq_from_bits() {
        assert_eq!(QuantOptions::new(Method::Rsq, 2, 64).maxq(), 3.0);
        assert_eq!(QuantOptions::new(Method::Rsq, 3, 64).maxq(), 7.0);
        assert_eq!(QuantOptions::new(Method::Rsq, 4, 64).maxq(), 15.0);
    }

    #[test]
    fn default_options_are_serial() {
        let o = QuantOptions::new(Method::Rsq, 3, 64);
        assert_eq!(o.jobs, 1, "parallelism is opt-in via --jobs");
        assert_eq!(o.expansion, 1);
        assert!(o.module_mask.is_none());
    }
}
