//! The RSQ layer-by-layer quantization coordinator (paper Sec. 4.2).
//!
//! For each transformer layer:
//!   pass A  — stream every calibration batch through the (not yet
//!             quantized) layer, capture the four weight-input streams and
//!             the dynamic token scores, turn scores into the importance
//!             matrix R (Sec. 4.3 + Eq. 4), and accumulate the scaled
//!             Hessians H = 2·X·R²·Xᵀ via the L1 Pallas kernel;
//!   solve   — quantize the seven weights against their stream's Hessian
//!             (GPTQ / LDLQ-VQ HLO modules, or RTN which needs no data);
//!   pass B  — recompute the layer outputs with the *quantized* weights so
//!             the next layer calibrates on what it will actually see at
//!             inference (standard GPTQ practice).
//!
//! Modes: RTN, GPTQ (no rotate, uniform), QuaRot (rotate, uniform), SQ
//! (scale only), RSQ (rotate + scale), and the VQ variants of
//! QuaRot/RSQ (Tab. 6). Fig. 7's per-module ablation is `module_mask`.

use std::collections::HashSet;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::corpus::{expand_dataset, CalibSet};
use crate::model::config::{InputStream, Module};
use crate::model::fuse::fuse_gains;
use crate::model::outliers::kurtosis_ratio;
use crate::model::rotate::{rotate_params, rotation_matrix};
use crate::model::ParamSet;
use crate::runtime::{self, Engine};
use crate::tensor::Tensor;

use super::strategy::{LayerScores, Strategy};
use super::vq::e8_codebook;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Round-to-nearest (data-free baseline).
    Rtn,
    /// GPTQ: uniform token weighting, no rotation (paper baseline).
    Gptq,
    /// QuaRot: rotation + GPTQ with uniform weighting (paper baseline).
    QuaRot,
    /// SQ: token scaling without rotation (paper Fig. 9 ablation).
    Sq,
    /// RSQ: rotate, scale, then quantize (the paper's method).
    Rsq,
    /// QuaRot with the E8 codebook + LDLQ (Tab. 6 baseline).
    QuaRotVq,
    /// RSQ with the E8 codebook + LDLQ (Tab. 6).
    RsqVq,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Some(Method::Rtn),
            "gptq" => Some(Method::Gptq),
            "quarot" => Some(Method::QuaRot),
            "sq" => Some(Method::Sq),
            "rsq" => Some(Method::Rsq),
            "quarot-vq" | "quarotvq" => Some(Method::QuaRotVq),
            "rsq-vq" | "rsqvq" => Some(Method::RsqVq),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "rtn",
            Method::Gptq => "gptq",
            Method::QuaRot => "quarot",
            Method::Sq => "sq",
            Method::Rsq => "rsq",
            Method::QuaRotVq => "quarot-vq",
            Method::RsqVq => "rsq-vq",
        }
    }

    pub fn rotates(&self) -> bool {
        matches!(self, Method::QuaRot | Method::Rsq | Method::QuaRotVq | Method::RsqVq)
    }

    pub fn scales(&self) -> bool {
        matches!(self, Method::Sq | Method::Rsq | Method::RsqVq)
    }

    pub fn vector_quant(&self) -> bool {
        matches!(self, Method::QuaRotVq | Method::RsqVq)
    }
}

#[derive(Clone, Debug)]
pub struct QuantOptions {
    pub method: Method,
    /// importance strategy used when `method.scales()`
    pub strategy: Strategy,
    pub bits: u32,
    pub damp: f32,
    /// calibration sequence length (must be one of cfg.seq_lens)
    pub seq_len: usize,
    /// dataset-expansion factor M (paper Sec. 4.4); 1 = off
    pub expansion: usize,
    /// Fig. 7: scale only these modules (None = all seven)
    pub module_mask: Option<HashSet<Module>>,
    pub rot_seed: u64,
    pub verbose: bool,
}

impl QuantOptions {
    pub fn new(method: Method, bits: u32, seq_len: usize) -> Self {
        QuantOptions {
            method,
            strategy: Strategy::AttnCon { r_min: 0.05 },
            bits,
            damp: 0.01,
            seq_len,
            expansion: 1,
            module_mask: None,
            rot_seed: 0x5157, // "QW"
            verbose: false,
        }
    }

    pub fn maxq(&self) -> f32 {
        ((1u64 << self.bits) - 1) as f32
    }
}

#[derive(Clone, Debug, Default)]
pub struct QuantReport {
    /// Σ over weights of tr((W-Q)H(W-Q)ᵀ), per layer
    pub layer_err: Vec<f32>,
    pub kurtosis_before: f32,
    pub kurtosis_after: f32,
    pub wall_seconds: f64,
    pub batches: usize,
}

/// Quantize `params` with the given options; returns the quantized set and
/// a report. `params` is cloned — the caller keeps the full-precision model.
pub fn quantize(
    engine: &Engine,
    params: &ParamSet,
    calib: &CalibSet,
    opts: &QuantOptions,
) -> Result<(ParamSet, QuantReport)> {
    let t0 = Instant::now();
    let cfg = engine.config().clone();
    if !cfg.seq_lens.contains(&opts.seq_len) {
        bail!("seq_len {} not in artifact set {:?}", opts.seq_len, cfg.seq_lens);
    }
    let mut p = params.clone();
    let mut report = QuantReport {
        kurtosis_before: kurtosis_ratio(&p),
        ..Default::default()
    };

    // --- Rotate (paper Sec. 4.2 step 1) ---
    if opts.method.rotates() {
        fuse_gains(&mut p);
        let q = rotation_matrix(cfg.d, opts.rot_seed);
        rotate_params(&mut p, &q);
    }
    report.kurtosis_after = kurtosis_ratio(&p);

    // --- RTN short-circuit: data-free ---
    if opts.method == Method::Rtn {
        for l in 0..cfg.layers {
            let mut errsum = 0.0;
            for m in Module::ALL {
                let (o, i) = cfg.weight_shape(m);
                let w = p.weight(l, m).clone();
                let outs = engine.exec(
                    &format!("rtn_{o}x{i}"),
                    &[runtime::tensor_literal(&w)?, runtime::scalar_literal(opts.maxq())],
                )?;
                let q = runtime::literal_tensor(&outs[0])?;
                errsum += q.sub(&w).frob_norm().powi(2);
                p.set_weight(l, m, q);
            }
            report.layer_err.push(errsum);
        }
        report.wall_seconds = t0.elapsed().as_secs_f64();
        return Ok((p, report));
    }

    // --- calibration data (Sec. 4.4 expansion) ---
    let mut calib = if opts.expansion > 1 {
        expand_dataset(calib, opts.expansion)
    } else {
        calib.clone()
    };
    calib.pad_to_batch(cfg.batch);
    let t = opts.seq_len;
    let batches: Vec<&[Vec<i32>]> = calib.samples.chunks(cfg.batch).collect();
    report.batches = batches.len();
    let freq = calib.token_frequencies(cfg.vocab);

    let lname = format!("layer_fwd_t{t}");
    let hess_d = format!("hess_d_t{t}");
    let hess_ff = format!("hess_ff_t{t}");
    let codebook_lit = if opts.method.vector_quant() {
        Some(runtime::tensor_literal(&e8_codebook(cfg.ldlq_k, opts.rot_seed))?)
    } else {
        None
    };

    // initial hidden states: embed every batch once
    let emb_lit = runtime::tensor_literal(&p.tensors[0])?;
    let pos_lit = runtime::tensor_literal(&p.tensors[1])?;
    let mut z_lits = Vec::with_capacity(batches.len());
    let mut tok_lits = Vec::with_capacity(batches.len());
    for b in &batches {
        let tl = runtime::tokens_literal(b, t)?;
        let z = engine.exec_ref(&format!("embed_t{t}"), &[&tl, &emb_lit, &pos_lit])?;
        tok_lits.push(tl);
        z_lits.push(z.into_iter().next().unwrap());
    }

    // A partial module mask (Fig. 7) needs BOTH Hessians per stream: the
    // masked modules use the scaled one, the rest the uniform one. When the
    // method doesn't scale at all, the "scaled" accumulator already holds
    // the uniform Hessian (Strategy::Uniform), so no second pass is needed.
    let needs_uniform = opts.method.scales()
        && opts
            .module_mask
            .as_ref()
            .map(|m| m.len() < Module::ALL.len())
            .unwrap_or(false);

    for l in 0..cfg.layers {
        // layer params as literals, once per layer
        let base = 2 + l * 9;
        let lp: Vec<xla::Literal> = (0..9)
            .map(|k| runtime::tensor_literal(&p.tensors[base + k]))
            .collect::<Result<_>>()?;

        // --- pass A: captures + scores -> scaled Hessians ---
        let mut h_scaled: [Option<Tensor>; 4] = [None, None, None, None];
        let mut h_uniform: [Option<Tensor>; 4] = [None, None, None, None];
        for (bi, batch) in batches.iter().enumerate() {
            let mut ins: Vec<&xla::Literal> = vec![&z_lits[bi]];
            ins.extend(lp.iter());
            let outs = engine.exec_ref(&lname, &ins)?;
            // outs: z2, xa, xo, xf, xd, attn_con, act_norm, act_diff, token_sim
            let scores = LayerScores {
                attn_con: rows_of(&runtime::literal_tensor(&outs[5])?),
                act_norm: rows_of(&runtime::literal_tensor(&outs[6])?),
                act_diff: rows_of(&runtime::literal_tensor(&outs[7])?),
                token_sim: rows_of(&runtime::literal_tensor(&outs[8])?),
            };
            let strategy = if opts.method.scales() { opts.strategy } else { Strategy::Uniform };
            let r = strategy.importance(
                &cfg, t, batch.len(), Some(&scores), Some(batch), Some(&freq));
            let r_lit = runtime::tensor_literal(&Tensor::from_vec(
                &[batch.len(), t],
                r.iter().flatten().cloned().collect(),
            ))?;
            let uni_lit = runtime::tensor_literal(&Tensor::ones(&[batch.len(), t]))?;
            for (si, xout) in [(0usize, 1usize), (1, 2), (2, 3), (3, 4)] {
                let hess_mod = if si == 3 { &hess_ff } else { &hess_d };
                let h = engine.exec_ref(hess_mod, &[&outs[xout], &r_lit])?;
                accumulate(&mut h_scaled[si], runtime::literal_tensor(&h[0])?);
                if needs_uniform {
                    let hu = engine.exec_ref(hess_mod, &[&outs[xout], &uni_lit])?;
                    accumulate(&mut h_uniform[si], runtime::literal_tensor(&hu[0])?);
                }
            }
        }

        // --- solve: quantize the seven weights ---
        let mut errsum = 0.0f32;
        for m in Module::ALL {
            let scaled = match &opts.module_mask {
                Some(mask) => opts.method.scales() && mask.contains(&m),
                None => opts.method.scales(),
            };
            let stream = stream_index(m.input_stream());
            let h = if scaled {
                h_scaled[stream].as_ref().unwrap()
            } else if needs_uniform {
                h_uniform[stream].as_ref().unwrap()
            } else {
                h_scaled[stream].as_ref().unwrap() // uniform strategy ⇒ same
            };
            let (o, i) = cfg.weight_shape(m);
            let w_lit = runtime::tensor_literal(p.weight(l, m))?;
            let h_lit = runtime::tensor_literal(h)?;
            let damp_lit = runtime::scalar_literal(opts.damp);
            let maxq_lit = runtime::scalar_literal(opts.maxq());
            let outs = if opts.method.vector_quant() {
                engine.exec_ref(
                    &format!("ldlq_{o}x{i}"),
                    &[&w_lit, &h_lit, codebook_lit.as_ref().unwrap(), &damp_lit],
                )?
            } else {
                engine.exec_ref(
                    &format!("gptq_{o}x{i}"),
                    &[&w_lit, &h_lit, &maxq_lit, &damp_lit],
                )?
            };
            errsum += runtime::literal_scalar(&outs[1])?;
            p.set_weight(l, m, runtime::literal_tensor(&outs[0])?);
        }
        report.layer_err.push(errsum);
        if opts.verbose {
            eprintln!("[quant:{}] layer {l}: hessian-weighted err {errsum:.3}", opts.method.name());
        }

        // --- pass B: propagate through the quantized layer ---
        // (skipped for the last layer: its outputs feed nothing — saves
        //  1/L of the pass-B forward cost; EXPERIMENTS.md §Perf)
        if l + 1 < cfg.layers {
            let lp_q: Vec<xla::Literal> = (0..9)
                .map(|k| runtime::tensor_literal(&p.tensors[base + k]))
                .collect::<Result<_>>()?;
            for z in z_lits.iter_mut() {
                let mut ins: Vec<&xla::Literal> = vec![z];
                ins.extend(lp_q.iter());
                let outs = engine.exec_ref(&lname, &ins)?;
                *z = outs.into_iter().next().unwrap();
            }
        }
    }

    report.wall_seconds = t0.elapsed().as_secs_f64();
    Ok((p, report))
}

fn stream_index(s: InputStream) -> usize {
    match s {
        InputStream::Xa => 0,
        InputStream::Xo => 1,
        InputStream::Xf => 2,
        InputStream::Xd => 3,
    }
}

fn accumulate(acc: &mut Option<Tensor>, h: Tensor) {
    match acc {
        Some(a) => a.add_in_place(&h),
        None => *acc = Some(h),
    }
}

fn rows_of(t: &Tensor) -> Vec<Vec<f32>> {
    let (r, c) = (t.shape[0], t.shape[1]);
    (0..r).map(|i| t.data[i * c..(i + 1) * c].to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for m in [
            Method::Rtn, Method::Gptq, Method::QuaRot, Method::Sq,
            Method::Rsq, Method::QuaRotVq, Method::RsqVq,
        ] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn method_semantics() {
        assert!(Method::Rsq.rotates() && Method::Rsq.scales());
        assert!(Method::QuaRot.rotates() && !Method::QuaRot.scales());
        assert!(!Method::Sq.rotates() && Method::Sq.scales());
        assert!(!Method::Gptq.rotates() && !Method::Gptq.scales());
        assert!(Method::RsqVq.vector_quant() && Method::RsqVq.scales());
    }

    #[test]
    fn maxq_from_bits() {
        assert_eq!(QuantOptions::new(Method::Rsq, 2, 64).maxq(), 3.0);
        assert_eq!(QuantOptions::new(Method::Rsq, 3, 64).maxq(), 7.0);
        assert_eq!(QuantOptions::new(Method::Rsq, 4, 64).maxq(), 15.0);
    }
}
