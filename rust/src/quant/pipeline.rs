//! The RSQ layer-by-layer quantization coordinator (paper Sec. 4.2).
//!
//! This module owns the *what* of a quantization run — [`Method`],
//! [`QuantOptions`], [`QuantReport`], the rotate step, calibration-data
//! preparation — and hands the *how* to the staged scheduler in
//! [`super::sched`]: pass A (capture + scaled Hessians), the per-module
//! solve, and pass B (quantized re-forward) dispatch over a [`Pool`] of
//! worker threads, in staged or cross-layer-pipelined order
//! ([`SchedMode`]), with every floating-point reduction kept in the
//! serial path's order so any `--jobs`/`--sched` combination is
//! bit-identical to `--jobs 1` (DESIGN.md §5).
//!
//! Modes: RTN, GPTQ (no rotate, uniform), QuaRot (rotate, uniform), SQ
//! (scale only), RSQ (rotate + scale), and the VQ variants of
//! QuaRot/RSQ (Tab. 6). Fig. 7's per-module ablation is `module_mask`.
//!
//! [`Pool`]: crate::util::Pool
//! [`SchedMode`]: super::sched::SchedMode

use std::collections::HashSet;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::corpus::{expand_dataset, CalibSet};
use crate::model::config::Module;
use crate::model::fuse::fuse_gains;
use crate::model::outliers::kurtosis_ratio;
use crate::model::rotate::{rotate_params_with, rotation_matrix};
use crate::model::ParamSet;
use crate::obs::{metrics, trace};
use crate::runtime::{self, Engine};
use crate::tensor::kernels::Backend;
use crate::tensor::pack::RowGrid;
use crate::util::Pool;

use super::artifact::cache::{cache_key, HessCache};
use super::sched::{self, SchedMode};
use super::strategy::Strategy;
use super::vq::e8_codebook;

/// Which quantizer family to run (the paper's baselines + RSQ + VQ rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Round-to-nearest (data-free baseline).
    Rtn,
    /// GPTQ: uniform token weighting, no rotation (paper baseline).
    Gptq,
    /// QuaRot: rotation + GPTQ with uniform weighting (paper baseline).
    QuaRot,
    /// SQ: token scaling without rotation (paper Fig. 9 ablation).
    Sq,
    /// RSQ: rotate, scale, then quantize (the paper's method).
    Rsq,
    /// QuaRot with the E8 codebook + LDLQ (Tab. 6 baseline).
    QuaRotVq,
    /// RSQ with the E8 codebook + LDLQ (Tab. 6).
    RsqVq,
}

impl Method {
    /// Parse a CLI spelling (`rsq`, `quarot-vq`, …); case-insensitive.
    /// Inverse of [`Method::name`].
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Some(Method::Rtn),
            "gptq" => Some(Method::Gptq),
            "quarot" => Some(Method::QuaRot),
            "sq" => Some(Method::Sq),
            "rsq" => Some(Method::Rsq),
            "quarot-vq" | "quarotvq" => Some(Method::QuaRotVq),
            "rsq-vq" | "rsqvq" => Some(Method::RsqVq),
            _ => None,
        }
    }

    /// Canonical CLI spelling; `Method::parse(m.name()) == Some(m)`.
    pub fn name(&self) -> &'static str {
        match self {
            Method::Rtn => "rtn",
            Method::Gptq => "gptq",
            Method::QuaRot => "quarot",
            Method::Sq => "sq",
            Method::Rsq => "rsq",
            Method::QuaRotVq => "quarot-vq",
            Method::RsqVq => "rsq-vq",
        }
    }

    /// Does this method apply the randomized-Hadamard rotation first?
    pub fn rotates(&self) -> bool {
        matches!(self, Method::QuaRot | Method::Rsq | Method::QuaRotVq | Method::RsqVq)
    }

    /// Does this method weight tokens by importance (scaled Hessians)?
    pub fn scales(&self) -> bool {
        matches!(self, Method::Sq | Method::Rsq | Method::RsqVq)
    }

    /// Does this method use the E8 codebook + LDLQ instead of GPTQ's grid?
    pub fn vector_quant(&self) -> bool {
        matches!(self, Method::QuaRotVq | Method::RsqVq)
    }
}

/// Everything one `quantize` run needs beyond the model + data.
#[derive(Clone, Debug)]
pub struct QuantOptions {
    /// quantizer family (see [`Method`])
    pub method: Method,
    /// importance strategy used when `method.scales()`
    pub strategy: Strategy,
    /// quantization bit width (paper Tab. 5 sweeps 2-4)
    pub bits: u32,
    /// Hessian dampening fraction added to the diagonal (GPTQ's λ)
    pub damp: f32,
    /// calibration sequence length (must be one of cfg.seq_lens)
    pub seq_len: usize,
    /// dataset-expansion factor M (paper Sec. 4.4); 1 = off
    pub expansion: usize,
    /// Fig. 7: scale only these modules (None = all seven)
    pub module_mask: Option<HashSet<Module>>,
    /// seed for the randomized-Hadamard rotation (varied across runs)
    pub rot_seed: u64,
    /// scheduler worker threads (`--jobs`): 1 = serial, 0 = one per
    /// hardware thread. Any value produces bit-identical output.
    pub jobs: usize,
    /// cross-layer phase ordering (`--sched`); both modes are
    /// bit-identical, pipelined saves one barrier per layer (DESIGN.md §5)
    pub sched: SchedMode,
    /// content-addressed Hessian cache directory (`--hess-cache`); None
    /// disables caching. A key hit skips pass A entirely while keeping the
    /// output byte-identical (DESIGN.md §9).
    pub hess_cache: Option<PathBuf>,
    /// kernel backend for the host-side rotate GEMMs (`--backend`);
    /// `Backend::Reference` (the default) is bit-exact, `Backend::Simd`
    /// is tolerance-pinned (DESIGN.md §13)
    pub backend: Backend,
    /// mixed-precision bit budget (`--avg-bits` / `--budget-bytes`,
    /// DESIGN.md §14): per-module widths are allocated from the pass-A
    /// Hessians and `bits` only sets the proxy/scoring width. None =
    /// every module solves at the single global `bits`.
    pub alloc: Option<super::alloc::BitBudget>,
    /// log per-layer reconstruction error to stderr
    pub verbose: bool,
}

impl QuantOptions {
    /// Defaults matching the paper's main configuration (AttnCon r_min
    /// 0.05, damp 0.01, no expansion, serial pipelined scheduler).
    pub fn new(method: Method, bits: u32, seq_len: usize) -> Self {
        QuantOptions {
            method,
            strategy: Strategy::AttnCon { r_min: 0.05 },
            bits,
            damp: 0.01,
            seq_len,
            expansion: 1,
            module_mask: None,
            rot_seed: 0x5157, // "QW"
            jobs: 1,
            sched: SchedMode::Pipelined,
            hess_cache: None,
            backend: Backend::Reference,
            alloc: None,
            verbose: false,
        }
    }

    /// Largest quantization level for the configured bit width.
    pub fn maxq(&self) -> f32 {
        ((1u64 << self.bits) - 1) as f32
    }
}

/// Wall-clock seconds one layer spent in each scheduler phase. In
/// pipelined mode, pass B of this layer and pass A of the next run as one
/// fused sweep recorded in `fused_seconds` (attributed to this layer);
/// only layer 0 then has a standalone `pass_a_seconds`.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerTiming {
    /// standalone pass A (capture + Hessian accumulation)
    pub pass_a_seconds: f64,
    /// the seven-module solve phase (GPTQ/LDLQ)
    pub solve_seconds: f64,
    /// standalone pass B (quantized re-forward; staged mode only)
    pub pass_b_seconds: f64,
    /// fused pass B + next layer's pass A (pipelined mode only)
    pub fused_seconds: f64,
}

/// Per-run accounting returned next to the quantized parameters.
#[derive(Clone, Debug, Default)]
pub struct QuantReport {
    /// Σ over weights of tr((W-Q)H(W-Q)ᵀ), per layer
    pub layer_err: Vec<f32>,
    /// weight kurtosis ratio before the rotate step
    pub kurtosis_before: f32,
    /// weight kurtosis ratio after the rotate step (lower ⇒ fewer outliers)
    pub kurtosis_after: f32,
    /// end-to-end wall time of the whole `quantize` call
    pub wall_seconds: f64,
    /// calibration batches consumed (after padding/expansion)
    pub batches: usize,
    /// worker threads the scheduler actually used
    pub jobs: usize,
    /// scheduler mode the run executed with (`SchedMode::name`)
    pub sched: String,
    /// kernel backend the host-side rotate ran on (`Backend::name`:
    /// "reference" or "simd", DESIGN.md §13)
    pub backend: String,
    /// per-layer phase timings (empty for RTN: its windowed grid crosses
    /// layer boundaries, so only `solve_seconds` is meaningful there)
    pub layer_timings: Vec<LayerTiming>,
    /// seconds in the host-side rotate step — the pool-parallel
    /// `tensor::kernels` GEMMs over every weight (0 for non-rotating
    /// methods; DESIGN.md §10)
    pub rotate_seconds: f64,
    /// total seconds in standalone pass A, all layers
    pub pass_a_seconds: f64,
    /// total seconds in the solve phase (GPTQ/LDLQ/RTN), all layers
    pub solve_seconds: f64,
    /// total seconds in standalone pass B, all layers (staged mode)
    pub pass_b_seconds: f64,
    /// total seconds in fused pass-B/pass-A sweeps (pipelined mode)
    pub fused_seconds: f64,
    /// per-(layer, module) solve grids in (layer, `Module::ALL`) order;
    /// None per VQ solve (codebook output has no affine grid). What lets
    /// `quant::artifact::save` bit-pack the weights (DESIGN.md §9).
    pub grids: Vec<Option<RowGrid>>,
    /// content address of this run's Hessians (hex; empty for data-free
    /// RTN, which accumulates none)
    pub hess_key: String,
    /// layers whose Hessians were served from the cache (pass A skipped)
    pub hess_cache_hits: usize,
    /// layers whose Hessians were computed, then stored in the cache
    pub hess_cache_misses: usize,
    /// layers whose Hessians were computed with caching disabled
    pub hess_cache_skips: usize,
    /// per-(layer, `Module::ALL`) widths chosen by the mixed-precision
    /// allocator, in `grids` order (DESIGN.md §14); empty for a
    /// global-width run. `artifact::save` packs each weight at its slot's
    /// width.
    pub widths: Vec<u32>,
    /// achieved numel-weighted average width (allocator runs only)
    pub avg_bits: Option<f32>,
    /// the budget spec that drove the allocator (`BitBudget::spec`)
    pub budget: Option<String>,
    /// total packed weight bytes under the allocation, per-row grids
    /// included (allocator runs only)
    pub packed_bytes: Option<u64>,
}

/// Record a Hessian-cache outcome (`hess_cache.hit` / `.miss` / `.skip`)
/// as a trace instant plus a metrics counter of affected layers — pure
/// observation next to the `QuantReport` counters (DESIGN.md §16).
fn note_hess_cache(outcome: &'static str, layers: usize) {
    trace::instant("quant", outcome);
    metrics::add(outcome, layers as u64);
}

/// Quantize `params` with the given options; returns the quantized set and
/// a report. `params` is cloned — the caller keeps the full-precision model.
///
/// This is a thin coordinator: it validates options, applies the rotate
/// step, prepares calibration data, then delegates the per-layer phases to
/// the `quant::sched` executors. Work is dispatched over `opts.jobs`
/// worker threads sharing `engine`, and the output is **bit-identical for
/// every jobs value and scheduler mode**: workers only compute independent
/// per-batch / per-module values, and every floating-point reduction
/// (Hessian sums, layer error sums) happens on the coordinator thread in
/// the serial path's order (DESIGN.md §5).
///
/// With `opts.hess_cache` set, the run's Hessians are content-addressed
/// (`artifact::cache`): a key hit replaces pass A / pass B / embed with a
/// solve-only sweep over the cached Hessians, byte-identical to the cold
/// run — `QuantReport`'s `hess_cache_{hits,misses,skips}` record which
/// path ran (DESIGN.md §9).
pub fn quantize(
    engine: &Engine,
    params: &ParamSet,
    calib: &CalibSet,
    opts: &QuantOptions,
) -> Result<(ParamSet, QuantReport)> {
    let t0 = Instant::now();
    let cfg = engine.config().clone();
    if !cfg.seq_lens.contains(&opts.seq_len) {
        bail!("seq_len {} not in artifact set {:?}", opts.seq_len, cfg.seq_lens);
    }
    if !crate::tensor::pack::PACK_BITS.contains(&opts.bits) {
        bail!(
            "unsupported bit width {} — the packed formats support {:?}",
            opts.bits,
            crate::tensor::pack::PACK_BITS
        );
    }
    if opts.alloc.is_some() {
        if opts.method == Method::Rtn {
            bail!(
                "--avg-bits/--budget-bytes need Hessian sensitivity scores and RTN is \
                 data-free — use gptq, quarot, sq, or rsq"
            );
        }
        if opts.method.vector_quant() {
            bail!(
                "--avg-bits/--budget-bytes need the affine-grid solver and the VQ codebook \
                 methods are gridless — use gptq, quarot, sq, or rsq"
            );
        }
    }
    let pool = Pool::new(opts.jobs);
    let mut p = params.clone();
    let mut report = QuantReport {
        kurtosis_before: kurtosis_ratio(&p),
        jobs: pool.jobs(),
        sched: opts.sched.name().to_string(),
        backend: opts.backend.name().to_string(),
        ..Default::default()
    };

    // --- Rotate (paper Sec. 4.2 step 1) --- host-side GEMMs on the
    // tensor::kernels layer; the scheduler pool parallelizes them over
    // row blocks, bit-identically at every --jobs (DESIGN.md §10)
    if opts.method.rotates() {
        fuse_gains(&mut p);
        let q = rotation_matrix(cfg.d, opts.rot_seed);
        // timed from here so rotate_seconds is pure kernel time, not
        // gain fusion or Hadamard construction
        let tr = Instant::now();
        let sp = trace::span("quant", "quant.rotate");
        rotate_params_with(&mut p, &q, &pool, opts.backend);
        drop(sp);
        report.rotate_seconds = tr.elapsed().as_secs_f64();
    }
    report.kurtosis_after = kurtosis_ratio(&p);

    // --- RTN short-circuit: data-free, no calibration pass needed ---
    if opts.method == Method::Rtn {
        let ts = Instant::now();
        let (layer_err, grids) = sched::solve::rtn_grid(engine, &cfg, opts, &pool, &mut p)?;
        report.layer_err = layer_err;
        report.grids = grids;
        report.solve_seconds = ts.elapsed().as_secs_f64();
        report.wall_seconds = t0.elapsed().as_secs_f64();
        return Ok((p, report));
    }

    // Content-address of this run's Hessians, over the *pre-expansion*
    // calibration set and pre-rotation params (jobs/sched excluded — the
    // fixed-order reductions make them bit-invariant; DESIGN.md §9).
    let key = cache_key(&cfg, params, calib, opts);
    report.hess_key = key.hex();
    let cache = opts.hess_cache.as_ref().map(HessCache::new);

    // A partial module mask (Fig. 7) needs BOTH Hessians per stream: the
    // masked modules use the scaled one, the rest the uniform one. When the
    // method doesn't scale at all, the "scaled" accumulator already holds
    // the uniform Hessian (Strategy::Uniform), so no second pass is needed.
    let needs_uniform = opts.method.scales()
        && opts
            .module_mask
            .as_ref()
            .map(|m| m.len() < Module::ALL.len())
            .unwrap_or(false);

    // a warm cache entry must match this run's layer count and uniform-
    // accumulator needs, or it is treated as a miss
    let cached = cache.as_ref().and_then(|c| c.load(&key, cfg.layers, needs_uniform));

    // --- calibration data (Sec. 4.4 expansion) --- skipped on a warm hit:
    // the solve-only path never reads batches or token frequencies, so
    // `report.batches` is honestly 0 there (no batch was consumed)
    let t = opts.seq_len;
    let mut prepared = CalibSet { samples: Vec::new(), seq_len: t, kind: calib.kind };
    if cached.is_none() {
        prepared = if opts.expansion > 1 {
            expand_dataset(calib, opts.expansion)
        } else {
            calib.clone()
        };
        prepared.pad_to_batch(cfg.batch);
    }
    let batches: Vec<&[Vec<i32>]> = prepared.samples.chunks(cfg.batch).collect();
    report.batches = batches.len();
    let freq = prepared.token_frequencies(cfg.vocab);

    let mut ctx = sched::SchedCtx {
        engine,
        cfg: &cfg,
        opts,
        pool: &pool,
        batches: &batches,
        freq: &freq,
        lname: format!("layer_fwd_t{t}"),
        hess_d: format!("hess_d_t{t}"),
        hess_ff: format!("hess_ff_t{t}"),
        codebook: if opts.method.vector_quant() {
            Some(runtime::shared_literal(&e8_codebook(cfg.ldlq_k, opts.rot_seed))?)
        } else {
            None
        },
        needs_uniform,
        // the allocator needs every layer's Hessians in hand regardless
        // of caching (DESIGN.md §14)
        collect_hessians: opts.alloc.is_some() || (cache.is_some() && cached.is_none()),
        widths: None,
    };

    // --- mixed-precision path (--avg-bits / --budget-bytes, DESIGN.md
    // §14): obtain Hessians (warm hit, or a proxy pass at the single
    // reference width opts.bits), allocate per-module widths, then
    // re-solve the kept rotated full-precision params at those widths.
    // The allocation is a pure function of the Hessians + weights +
    // budget, so warm and cold runs — and every --jobs/--sched combo —
    // produce bit-identical widths and output.
    if let Some(budget) = opts.alloc.as_ref() {
        let mut proxy_timings: Vec<LayerTiming> = Vec::new();
        let hessians = match cached {
            Some(h) => {
                report.hess_cache_hits = cfg.layers;
                note_hess_cache("hess_cache.hit", cfg.layers);
                h
            }
            None => {
                // the proxy pass quantizes a throwaway clone exactly like
                // a plain `--bits` run would, collecting the Hessians its
                // pass A accumulates (which is why alloc does not enter
                // the cache key: the Hessians are identical)
                let mut proxy = p.clone();
                let mut scratch = QuantReport::default();
                let computed = sched::run_layers(&ctx, &mut proxy, &mut scratch)?;
                proxy_timings = scratch.layer_timings;
                match &cache {
                    Some(c) => {
                        report.hess_cache_misses = cfg.layers;
                        note_hess_cache("hess_cache.miss", cfg.layers);
                        if let Err(e) = c.store(&key, &computed) {
                            crate::obs_info!("[hess-cache] store failed (run unaffected): {e:#}");
                        }
                    }
                    None => {
                        report.hess_cache_skips = cfg.layers;
                        note_hess_cache("hess_cache.skip", cfg.layers);
                    }
                }
                computed
            }
        };
        let a = super::alloc::allocate(&p, &hessians, opts, needs_uniform, &pool, budget)?;
        if opts.verbose {
            crate::obs_info!(
                "[alloc] {}: avg {:.3} bits, {} packed bytes",
                a.budget, a.avg_bits, a.packed_bytes
            );
        }
        report.widths = a.widths.clone();
        report.avg_bits = Some(a.avg_bits);
        report.budget = Some(a.budget);
        report.packed_bytes = Some(a.packed_bytes);
        ctx.widths = Some(a.widths);
        ctx.collect_hessians = false;
        sched::run_layers_cached(&ctx, &mut p, &mut report, hessians)?;
        // fold the proxy pass's phase timings into the final solve's
        // per-layer entries so the report keeps one entry per layer
        for l in 0..proxy_timings.len().min(report.layer_timings.len()) {
            let plt = proxy_timings[l];
            let lt = &mut report.layer_timings[l];
            lt.pass_a_seconds += plt.pass_a_seconds;
            lt.pass_b_seconds += plt.pass_b_seconds;
            lt.fused_seconds += plt.fused_seconds;
            lt.solve_seconds += plt.solve_seconds;
        }
        for lt in &report.layer_timings {
            report.pass_a_seconds += lt.pass_a_seconds;
            report.solve_seconds += lt.solve_seconds;
            report.pass_b_seconds += lt.pass_b_seconds;
            report.fused_seconds += lt.fused_seconds;
        }
        report.wall_seconds = t0.elapsed().as_secs_f64();
        return Ok((p, report));
    }

    match cached {
        Some(hessians) => {
            // warm: pass A, pass B, and the embed sweep are all skipped
            report.hess_cache_hits = cfg.layers;
            note_hess_cache("hess_cache.hit", cfg.layers);
            sched::run_layers_cached(&ctx, &mut p, &mut report, hessians)?;
        }
        None => {
            let computed = sched::run_layers(&ctx, &mut p, &mut report)?;
            match &cache {
                Some(c) => {
                    report.hess_cache_misses = cfg.layers;
                    note_hess_cache("hess_cache.miss", cfg.layers);
                    if let Err(e) = c.store(&key, &computed) {
                        crate::obs_info!("[hess-cache] store failed (run unaffected): {e:#}");
                    }
                }
                None => {
                    report.hess_cache_skips = cfg.layers;
                    note_hess_cache("hess_cache.skip", cfg.layers);
                }
            }
        }
    }

    for lt in &report.layer_timings {
        report.pass_a_seconds += lt.pass_a_seconds;
        report.solve_seconds += lt.solve_seconds;
        report.pass_b_seconds += lt.pass_b_seconds;
        report.fused_seconds += lt.fused_seconds;
    }
    report.wall_seconds = t0.elapsed().as_secs_f64();
    Ok((p, report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_round_trip() {
        for m in [
            Method::Rtn, Method::Gptq, Method::QuaRot, Method::Sq,
            Method::Rsq, Method::QuaRotVq, Method::RsqVq,
        ] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn method_parse_aliases_and_case() {
        assert_eq!(Method::parse("RSQ"), Some(Method::Rsq));
        assert_eq!(Method::parse("QuaRot"), Some(Method::QuaRot));
        assert_eq!(Method::parse("rsqvq"), Some(Method::RsqVq));
        assert_eq!(Method::parse("quarotvq"), Some(Method::QuaRotVq));
        assert_eq!(Method::parse("rsq-vq"), Some(Method::RsqVq));
        assert_eq!(Method::parse(""), None);
        assert_eq!(Method::parse("rsq "), None, "no trimming — CLI passes exact tokens");
    }

    #[test]
    fn method_semantics() {
        assert!(Method::Rsq.rotates() && Method::Rsq.scales());
        assert!(Method::QuaRot.rotates() && !Method::QuaRot.scales());
        assert!(!Method::Sq.rotates() && Method::Sq.scales());
        assert!(!Method::Gptq.rotates() && !Method::Gptq.scales());
        assert!(Method::RsqVq.vector_quant() && Method::RsqVq.scales());
    }

    #[test]
    fn maxq_from_bits() {
        assert_eq!(QuantOptions::new(Method::Rsq, 2, 64).maxq(), 3.0);
        assert_eq!(QuantOptions::new(Method::Rsq, 3, 64).maxq(), 7.0);
        assert_eq!(QuantOptions::new(Method::Rsq, 4, 64).maxq(), 15.0);
    }

    #[test]
    fn default_options_are_serial_pipelined() {
        let o = QuantOptions::new(Method::Rsq, 3, 64);
        assert_eq!(o.jobs, 1, "parallelism is opt-in via --jobs");
        assert_eq!(o.sched, SchedMode::Pipelined, "barrier elimination is on by default");
        assert_eq!(o.expansion, 1);
        assert!(o.module_mask.is_none());
        assert!(o.hess_cache.is_none(), "hessian caching is opt-in via --hess-cache");
        assert_eq!(o.backend, Backend::Reference, "simd is opt-in via --backend");
    }
}
