//! # RSQ — Rotate, Scale, then Quantize (full-system reproduction)
//!
//! Layer-3 of the three-layer stack (see DESIGN.md): the rust coordinator
//! that owns the quantization pipeline, the calibration corpus, training,
//! evaluation, and every table/figure driver from the paper. All heavy
//! compute executes AOT-compiled HLO (JAX/Pallas, lowered once at build
//! time) through the PJRT CPU client — python never runs at request time.
//!
//! Module map:
//! - [`util`]     — RNG, bench harness, CLI parsing, JSON writer, property
//!                  testing (offline substitutes for rand/criterion/clap/
//!                  proptest, which are not in the vendored crate set).
//! - [`tensor`]   — minimal row-major f32 tensor + the randomized Hadamard
//!                  construction used by the Rotate step.
//! - [`corpus`]   — synthetic corpus generators (WikiText-2/C4/PTB/RedPajama
//!                  stand-ins), calibration sampling, dataset expansion
//!                  (paper Sec. 4.4).
//! - [`model`]    — model configs, parameter store, RMSNorm-gain fusion,
//!                  rotation, outlier injection.
//! - [`runtime`]  — PJRT engine: manifest parsing, HLO compile cache,
//!                  literal/buffer plumbing.
//! - [`quant`]    — the paper's contribution: importance strategies
//!                  (Sec. 4.3), the scaled-Hessian GPTQ driver (Sec. 4.2),
//!                  the layer-by-layer pipeline, RTN / GPTQ / QuaRot / SQ /
//!                  RSQ / VQ modes, plus the quantized-artifact subsystem
//!                  (packed save/load + content-addressed Hessian cache).
//! - [`quantref`] — pure-rust RTN + GPTQ oracle for property tests against
//!                  the HLO path.
//! - [`serve`]    — the deployment path: packed-domain batched decoding
//!                  (fused dequantize kernels, paged KV cache, continuous
//!                  batching) straight from a saved artifact.
//! - [`eval`]     — perplexity + 10 downstream probe tasks + long-context
//!                  probe families.
//! - [`train`]    — Adam training loop over the `train_step` artifact
//!                  (used by the end-to-end example).
//! - [`obs`]      — zero-dependency observability: span tracer + metrics
//!                  registry (`--trace`/`--metrics` Chrome-trace and run-
//!                  record exporters) and the leveled log facade.
//! - [`repro`]    — one driver per paper table/figure.

pub mod corpus;
pub mod eval;
pub mod model;
pub mod obs;
pub mod quant;
pub mod quantref;
pub mod repro;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

/// Default root for AOT artifacts, relative to the repo checkout.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifact directory for a model config, honoring the
/// `RSQ_ARTIFACTS` environment variable (used by tests and CI).
pub fn artifacts_dir(config: &str) -> std::path::PathBuf {
    let root = std::env::var("RSQ_ARTIFACTS").unwrap_or_else(|_| {
        // tests run from the crate root; binaries may run elsewhere
        let here = std::path::Path::new(ARTIFACTS_DIR);
        if here.exists() {
            ARTIFACTS_DIR.to_string()
        } else {
            format!("{}/{}", env!("CARGO_MANIFEST_DIR"), ARTIFACTS_DIR)
        }
    });
    std::path::Path::new(&root).join(config)
}
