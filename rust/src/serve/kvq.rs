//! Quantized KV-cache codecs (DESIGN.md §12).
//!
//! At long contexts and high concurrency the paged KV cache — not the
//! packed weights — dominates serving's resident bytes, capping how many
//! requests the batch scheduler can admit. This module is the KV-side
//! counterpart of the weight codec (`tensor::pack`): per-row (one
//! position of one layer's k or v projection) lossy encodings selected
//! by [`KvFormat`] and stored through the same LSB-first bitstream
//! primitives (`pack::write_code`/`pack::read_code`, `pack::row_bytes`).
//!
//! Formats (`--kv-bits {32,8,2}`):
//!
//! - [`KvFormat::F32`] — today's exact path, byte-for-byte unchanged:
//!   the oracle every lossy format is measured against;
//! - [`KvFormat::Linear8`] — 8-bit affine per-row codec: codes
//!   `round((v − lo) / step)` on the row's `[lo, hi]` span, absolute
//!   error bounded by half the per-row step (`rust/tests/prop_kvq.rs`);
//! - [`KvFormat::Log2`] — 2-bit log-distributed codec per **LogQuant**
//!   (PAPERS.md): attention activations have log-distributed magnitude
//!   profiles, so the two magnitude levels per sign sit geometrically at
//!   `{M/4, M}` of the row max-abs `M`. Sign-correct, monotone in
//!   magnitude, and idempotent (encode∘decode∘encode is a fixed point).
//!
//! **Non-finite policy.** Lossy codecs never emit garbage codes: row
//! statistics (`lo`/`hi`/`M`) are folded over *finite* elements only,
//! NaN clamps to the smallest code, ±inf to the span's matching end —
//! all deterministic, pinned by `prop_kvq.rs`.
//!
//! **Exactness-oracle policy.** F32 stays the correctness oracle: every
//! lossy path is *deterministic* (same inputs → same codes → same
//! decode, invariant to jobs/batch/page pressure) and its greedy-token
//! divergence against the F32 decode is measured, not assumed
//! ([`token_divergence`], surfaced in `ServeReport` / `rsq serve-bench`).

use crate::tensor::pack::{read_code, row_bytes, write_code};

/// Storage format of one KV cache (`--kv-bits`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvFormat {
    /// exact f32 rows — the PR-5 path and the divergence oracle
    F32,
    /// 8-bit affine per-row codec (codes 0..=255 on the row's span)
    Linear8,
    /// 2-bit log-distributed codec: sign bit + magnitude level {M/4, M}
    Log2,
}

/// KV bit widths the CLI accepts, in `--kv-bits` spelling.
pub const KV_BITS: [u32; 3] = [32, 8, 2];

impl KvFormat {
    /// Parse a `--kv-bits` value; `None` for anything outside
    /// [`KV_BITS`].
    pub fn from_bits(bits: u32) -> Option<KvFormat> {
        match bits {
            32 => Some(KvFormat::F32),
            8 => Some(KvFormat::Linear8),
            2 => Some(KvFormat::Log2),
            _ => None,
        }
    }

    /// The `--kv-bits` spelling of this format.
    pub fn bits(&self) -> u32 {
        match self {
            KvFormat::F32 => 32,
            KvFormat::Linear8 => 8,
            KvFormat::Log2 => 2,
        }
    }

    /// Whether decode reproduces written rows bit-for-bit.
    pub fn is_exact(&self) -> bool {
        matches!(self, KvFormat::F32)
    }

    /// Packed code bytes one d-length row occupies (`pack::row_bytes`
    /// layout; 0 for the f32 format, which stores no codes).
    pub fn row_code_bytes(&self, d: usize) -> usize {
        match self {
            KvFormat::F32 => 0,
            KvFormat::Linear8 => row_bytes(d, 8),
            KvFormat::Log2 => row_bytes(d, 2),
        }
    }

    /// Per-row scale-state f32s a page stores alongside the codes
    /// (Linear8: `(lo, step)`; Log2: `(M, unused)`; F32: none).
    pub fn row_state_f32s(&self) -> usize {
        if self.is_exact() {
            0
        } else {
            2
        }
    }

    /// Resident bytes of one k **or** v page half (`page` positions of
    /// d-length rows) at this format.
    pub fn half_page_bytes(&self, page: usize, d: usize) -> usize {
        match self {
            KvFormat::F32 => 4 * page * d,
            _ => page * self.row_code_bytes(d) + 4 * page * self.row_state_f32s(),
        }
    }

    /// Resident bytes of one full page (k + v halves) at this format.
    pub fn page_bytes(&self, page: usize, d: usize) -> usize {
        2 * self.half_page_bytes(page, d)
    }
}

impl std::fmt::Display for KvFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.bits())
    }
}

/// Quantize one row into `codes` (length `fmt.row_code_bytes(src.len())`,
/// cleared here — safe to re-encode a slot) and return its scale state
/// `(s0, s1)` for [`decode_row`]. Must not be called for [`KvFormat::F32`]
/// (the exact path never materializes codes).
pub fn encode_row(fmt: KvFormat, src: &[f32], codes: &mut [u8]) -> (f32, f32) {
    debug_assert_eq!(codes.len(), fmt.row_code_bytes(src.len()));
    codes.fill(0);
    match fmt {
        KvFormat::F32 => unreachable!("f32 KV rows are stored, not encoded"),
        KvFormat::Linear8 => {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in src {
                if v.is_finite() {
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
            }
            if !(lo <= hi) {
                // no finite element: every code 0, decode to exact 0.0
                return (0.0, 0.0);
            }
            // hi/255 - lo/255 (not (hi-lo)/255) so an extreme span can
            // never overflow the step to inf
            let step = hi / 255.0 - lo / 255.0;
            let step = if step.is_finite() && step > 0.0 { step } else { 0.0 };
            for (c, &v) in src.iter().enumerate() {
                let code = if step == 0.0 || v.is_nan() {
                    0 // constant row decodes to lo exactly; NaN clamps low
                } else if v >= hi {
                    255 // +inf (and the span max) clamps to the top code
                } else if v <= lo {
                    0 // -inf (and the span min) clamps to the bottom code
                } else {
                    ((v - lo) / step).round().clamp(0.0, 255.0) as u32
                };
                write_code(codes, c, 8, code);
            }
            (lo, step)
        }
        KvFormat::Log2 => {
            let mut m = 0.0f32;
            for &v in src {
                if v.is_finite() {
                    m = m.max(v.abs());
                }
            }
            if m == 0.0 {
                // all-zero (or no finite element): codes 0 decode to 0.0
                return (0.0, 0.0);
            }
            // geometric threshold between the M/4 and M levels; strict >
            // keeps encode∘decode∘encode a fixed point even where
            // subnormal scaling collapses 0.25·M and 0.5·M together
            let t = 0.5 * m;
            for (c, &v) in src.iter().enumerate() {
                let (neg, mag) =
                    if v.is_nan() { (false, 0.0) } else { (v < 0.0, v.abs().min(m)) };
                let code = ((neg as u32) << 1) | (mag > t) as u32;
                write_code(codes, c, 2, code);
            }
            (m, 0.0)
        }
    }
}

/// Dequantize one row of codes into `out` — the per-row decode primitive
/// the attention path fuses into `attn_row`'s scratch buffer the way
/// `gemv.rs` tile-decodes packed weights (no f32 page is ever rebuilt).
pub fn decode_row(fmt: KvFormat, codes: &[u8], s0: f32, s1: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), fmt.row_code_bytes(out.len()));
    match fmt {
        KvFormat::F32 => unreachable!("f32 KV rows are read in place, not decoded"),
        KvFormat::Linear8 => {
            let (lo, step) = (s0, s1);
            for (c, o) in out.iter_mut().enumerate() {
                // min(MAX): a near-f32::MAX span's top codes overflow
                // lo + step·code past MAX even though the true value
                // (≤ hi) is finite — saturate so decode never emits inf
                *o = (lo + step * read_code(codes, c, 8) as f32).min(f32::MAX);
            }
        }
        KvFormat::Log2 => {
            let m = s0;
            for (c, o) in out.iter_mut().enumerate() {
                let code = read_code(codes, c, 2);
                let mag = if code & 1 == 1 { m } else { 0.25 * m };
                *o = if code & 2 != 0 { -mag } else { mag };
            }
        }
    }
}

/// Row source for the unified attention kernel (`serve::model::attn_row`):
/// position `s`'s full d-length row, decoding into `scratch` when the
/// storage is quantized. The f32 path returns its resident slice and
/// never copies, which is what keeps `--kv-bits 32` byte-for-byte the
/// PR-5 exact path.
pub trait RowSource {
    fn row<'a>(&'a self, s: usize, scratch: &'a mut [f32]) -> &'a [f32];
}

/// Greedy-token divergence between a lossy decode and its f32 oracle:
/// the number of positions where the two token streams differ, with
/// every unpaired tail position of a length mismatch counted as a
/// divergence (DESIGN.md §12 defines the metric; `--kv-bits 32` is 0 by
/// construction and `rust/tests/prop_serve.rs` pins it).
pub fn token_divergence(oracle: &[i32], got: &[i32]) -> usize {
    let shared = oracle.len().min(got.len());
    let mut n = oracle.len().max(got.len()) - shared;
    for i in 0..shared {
        if oracle[i] != got[i] {
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(fmt: KvFormat, src: &[f32]) -> Vec<f32> {
        let mut codes = vec![0u8; fmt.row_code_bytes(src.len())];
        let (s0, s1) = encode_row(fmt, src, &mut codes);
        let mut out = vec![0.0f32; src.len()];
        decode_row(fmt, &codes, s0, s1, &mut out);
        out
    }

    #[test]
    fn parse_and_bits_round_trip() {
        for bits in KV_BITS {
            let fmt = KvFormat::from_bits(bits).unwrap();
            assert_eq!(fmt.bits(), bits);
            assert_eq!(fmt.to_string(), bits.to_string());
        }
        assert_eq!(KvFormat::from_bits(4), None);
        assert_eq!(KvFormat::from_bits(0), None);
        assert!(KvFormat::F32.is_exact());
        assert!(!KvFormat::Linear8.is_exact());
    }

    #[test]
    fn page_bytes_shrink_with_bits() {
        let (page, d) = (16usize, 64usize);
        let f32b = KvFormat::F32.page_bytes(page, d);
        let l8 = KvFormat::Linear8.page_bytes(page, d);
        let l2 = KvFormat::Log2.page_bytes(page, d);
        assert_eq!(f32b, 2 * 4 * page * d);
        assert!(l8 < f32b, "{l8} vs {f32b}");
        assert!(l2 < l8, "{l2} vs {l8}");
        // 8-bit: d code bytes + 8 state bytes per row, both halves
        assert_eq!(l8, 2 * (page * d + page * 8));
    }

    #[test]
    fn linear8_constant_row_is_exact() {
        for v in [0.0f32, -3.5, 7.25] {
            let out = roundtrip(KvFormat::Linear8, &[v; 9]);
            for o in out {
                assert_eq!(o.to_bits(), v.to_bits(), "constant row must decode exactly");
            }
        }
    }

    #[test]
    fn log2_levels_and_signs() {
        let src = [4.0f32, -4.0, 0.5, -0.5, 0.0];
        let out = roundtrip(KvFormat::Log2, &src);
        assert_eq!(out, vec![4.0, -4.0, 1.0, -1.0, 1.0]);
    }

    #[test]
    fn divergence_counts_mismatches_and_tails() {
        assert_eq!(token_divergence(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(token_divergence(&[1, 2, 3], &[1, 9, 3]), 1);
        assert_eq!(token_divergence(&[1, 2], &[1, 2, 7, 8]), 2);
        assert_eq!(token_divergence(&[], &[]), 0);
        assert_eq!(token_divergence(&[5], &[]), 1);
    }
}
