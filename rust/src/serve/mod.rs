//! The serving layer (DESIGN.md §11): packed-domain batched decoding.
//!
//! Quantization's end product is a packed artifact (§9), and this
//! subsystem is its deployment path — the ROADMAP's "serve heavy
//! traffic" north star. It decodes **directly from packed weights**
//! (`rsq generate --artifact DIR`), never materializing the f32 model:
//!
//! - [`model`] — [`PackedModel`], the host forward pass over
//!   storage-domain weights via the fused dequantize kernels
//!   (`tensor::kernels::gemv`), with [`Decoder`] (KV-cache step) and the
//!   full-context recompute reference it is tested against;
//! - [`kv`] — the preallocated paged KV cache: per-sequence page tables
//!   over a shared [`PagePool`], reserved at admission, returned at
//!   retire;
//! - [`kvq`] — the KV-page codecs behind `--kv-bits` (§12): exact f32,
//!   8-bit linear, and 2-bit log-distributed storage, quantize-on-write /
//!   decode-into-scratch-on-read, with [`token_divergence`] measuring
//!   every lossy path against the f32 oracle;
//! - [`batch`] — the continuous-batching scheduler on `util::Pool`:
//!   padded-free token-level steps, mid-flight admit/retire, per-request
//!   deadlines, all surfaced in a [`ServeReport`];
//! - [`prefix`] — the content-addressed prefix cache (§15): frozen,
//!   refcounted prompt-prefix KV pages keyed by
//!   `(model content key, kv format, page size, prefix tokens)`, so a
//!   prefix-hit admission adopts shared pages with **zero** prefill
//!   forwards (`--prefix-cache`). Speculative self-decoding
//!   (`--draft-artifact` + `--spec-k`, §15) lives in [`batch`] and
//!   [`model`]: a low-bit draft of the same artifact proposes k tokens
//!   and the serving model verifies them in one batched forward.
//!
//! Determinism contract: generated tokens are a pure function of (model,
//! prompt, max_new, kv format) — invariant to `--jobs`, batch size, page
//! size, co-scheduled requests, prefix-cache hits, and speculation
//! (greedy accept/correct reproduces plain greedy token-for-token).
//! `tests/prop_serve.rs` pins the host-side guarantees (including
//! bit-identity of the fused kernels against `unpack()` + `gemm`, of
//! `--kv-bits 32` against the full-context recompute, of prefix-hit vs
//! cold decodes, and of speculative vs plain greedy);
//! `tests/integration_serve.rs` pins greedy token-identity against the
//! XLA engine's full-context recompute.

pub mod batch;
pub mod kv;
pub mod kvq;
pub mod model;
pub mod prefix;

pub use batch::{serve, serve_with_draft, RequestStats, ServeOptions, ServeReport, ServeRequest};
pub use kv::{PagePool, SeqKv, SharedPrefix, PAGE_POSITIONS};
pub use kvq::{token_divergence, KvFormat, KV_BITS};
pub use model::{greedy_decode, greedy_decode_kv, Decoder, HostWeight, PackedModel};
pub use prefix::{PrefixCache, PrefixHit};

/// The synthetic model config `rsq serve-bench` and
/// `benches/bench_serve.rs` both build when no artifact is given — one
/// definition, so the two tokens/s grids stay comparable (they advertise
/// running "the same grid").
pub fn bench_model_config() -> crate::model::ModelConfig {
    crate::model::ModelConfig {
        name: "serve-bench".into(),
        d: 64,
        layers: 2,
        heads: 2,
        ff: 128,
        vocab: 256,
        max_seq: 128,
        batch: 4,
        seq_lens: vec![32, 64],
        ldlq_k: 1024,
        ldlq_g: 8,
    }
}
