//! Paged KV cache (DESIGN.md §11, §12, §15).
//!
//! Decoding token t attends over every previous position's per-layer
//! key/value projections. Recomputing them each step is the full-context
//! O(t²·d)-per-token recompute the eval modules do; caching them makes a
//! decode step O(t·d). Layout:
//!
//! - a [`PagePool`] preallocates a fixed number of pages up front; one
//!   page holds [`PAGE_POSITIONS`] positions of **one layer's** k and v
//!   rows (`[page, d]` row-major each), so pages are interchangeable
//!   across layers and sequences;
//! - a [`SeqKv`] is one sequence's cache: per layer, a page table
//!   reserved **at admission** for the sequence's whole worst case
//!   (prompt + max_new positions, capped at the model's `max_seq`), so a
//!   mid-flight decode step can never fail an allocation;
//! - retiring a sequence returns its pages ([`PagePool::release`]),
//!   which is what lets the batch scheduler (`serve::batch`) admit new
//!   requests mid-flight under a bounded memory budget.
//!
//! **Shared pages (prefix cache, §15).** A page-table slot is either
//! `Owned` (a page moved out of the pool, the exclusive case) or
//! `Shared` (an `Arc<KvPage>` — a read-only page whose contents are a
//! fully-written prompt prefix). The `Arc` strong count **is** the
//! per-page refcount: a donor sequence freezes its written prefix pages
//! in place ([`SeqKv::share_prefix`]), the prefix cache holds one
//! reference, and any number of later sequences adopt the same pages
//! ([`PagePool::try_adopt`]) without re-running prefill. A page returns
//! to the pool's free list exactly when its **last** reference drops
//! ([`PagePool::release`] / [`PagePool::reclaim`] unwrap the `Arc`), so
//! releases cannot double-free by construction — ownership moves, it is
//! never duplicated.
//!
//! **Copy-on-write.** Adoption is page-aligned, so the scheduler's first
//! write past an adopted prefix always lands in the sequence's own first
//! `Owned` page. Writing *into* a shared page (a non-aligned adopter)
//! forks it first: the write pops a COW spare page reserved at adoption
//! time, copies the shared page's stored bytes into it, and swaps the
//! slot to `Owned` — the donor and every other adopter keep reading the
//! original. A write into a shared page with no spare reserved panics
//! rather than corrupting a neighbour.
//!
//! **Storage format.** Every page in a pool shares one [`KvFormat`]
//! (`--kv-bits`): f32 rows stored verbatim (the exact path), or packed
//! low-bit codes plus per-position-row scale state, quantized on write
//! through `serve::kvq` and decoded row-at-a-time on read. A position is
//! written at most once per decode pass (a speculative rewind re-encodes
//! the row in place — `encode_row` clears the slot's code bytes first),
//! so a row's decoded value is independent of page size and of
//! everything written after it.
//!
//! **Determinism.** Page identity carries no information — a sequence's
//! contents are addressed purely through its own page table — so which
//! physical pages a sequence happens to receive (an artifact of admission
//! order) cannot affect any decoded value. Shared pages keep that
//! property: a frozen page stores exactly the bytes the adopter's own
//! prefill would have written (encode is a pure per-row function of the
//! same k/v rows), so a prefix-hit decode is bit-identical to a cold one.

use std::sync::{Arc, Mutex};

use super::kvq::{decode_row, encode_row, KvFormat, RowSource};
use crate::obs::{metrics, trace};
use crate::util::json::Json;

/// Positions per page: small enough that short sequences waste little
/// capacity, large enough that page tables stay tiny.
pub const PAGE_POSITIONS: usize = 16;

/// One half (k or v) of a page, in its storage domain.
#[derive(Debug)]
enum PageHalf {
    /// `[page, d]` row-major f32 — read in place, never copied
    F32(Vec<f32>),
    /// `[page, row_code_bytes(d)]` packed codes + per-row scale state
    Packed { codes: Vec<u8>, s0: Vec<f32>, s1: Vec<f32> },
}

impl PageHalf {
    fn new(fmt: KvFormat, page: usize, d: usize) -> PageHalf {
        match fmt {
            KvFormat::F32 => PageHalf::F32(vec![0.0; page * d]),
            _ => PageHalf::Packed {
                codes: vec![0u8; page * fmt.row_code_bytes(d)],
                s0: vec![0.0; page],
                s1: vec![0.0; page],
            },
        }
    }

    /// Store row `r` (quantizing when packed; `encode_row` clears the
    /// slot's code bytes first, so overwrites are safe).
    fn write(&mut self, fmt: KvFormat, r: usize, d: usize, src: &[f32]) {
        match self {
            PageHalf::F32(data) => data[r * d..(r + 1) * d].copy_from_slice(src),
            PageHalf::Packed { codes, s0, s1 } => {
                let cb = fmt.row_code_bytes(d);
                let (a, b) = encode_row(fmt, src, &mut codes[r * cb..(r + 1) * cb]);
                s0[r] = a;
                s1[r] = b;
            }
        }
    }

    /// Row `r`: the resident slice when f32, a decode into `scratch`
    /// when packed.
    fn row<'a>(&'a self, fmt: KvFormat, r: usize, d: usize, scratch: &'a mut [f32]) -> &'a [f32] {
        match self {
            PageHalf::F32(data) => &data[r * d..(r + 1) * d],
            PageHalf::Packed { codes, s0, s1 } => {
                let cb = fmt.row_code_bytes(d);
                let out = &mut scratch[..d];
                decode_row(fmt, &codes[r * cb..(r + 1) * cb], s0[r], s1[r], out);
                out
            }
        }
    }

    /// Overwrite with `src`'s stored bytes (the COW fork): storage-domain
    /// copy, so quantized pages fork without a decode/re-encode round trip.
    fn copy_from(&mut self, src: &PageHalf) {
        match (self, src) {
            (PageHalf::F32(d), PageHalf::F32(s)) => d.copy_from_slice(s),
            (
                PageHalf::Packed { codes, s0, s1 },
                PageHalf::Packed { codes: sc, s0: ss0, s1: ss1 },
            ) => {
                codes.copy_from_slice(sc);
                s0.copy_from_slice(ss0);
                s1.copy_from_slice(ss1);
            }
            _ => panic!("COW fork across page storage formats"),
        }
    }
}

/// One page: `page` positions of one layer's k and v rows.
#[derive(Debug)]
pub struct KvPage {
    k: PageHalf,
    v: PageHalf,
}

impl KvPage {
    fn new(fmt: KvFormat, page: usize, d: usize) -> KvPage {
        KvPage { k: PageHalf::new(fmt, page, d), v: PageHalf::new(fmt, page, d) }
    }

    /// Zero-capacity placeholder used only while a slot's page is being
    /// moved into an `Arc` (never read).
    fn placeholder() -> KvPage {
        KvPage { k: PageHalf::F32(Vec::new()), v: PageHalf::F32(Vec::new()) }
    }

    fn copy_from(&mut self, src: &KvPage) {
        self.k.copy_from(&src.k);
        self.v.copy_from(&src.v);
    }
}

/// One page-table slot: exclusively owned, or a refcounted read-only
/// share of a frozen prefix page (module docs).
enum SeqPage {
    Owned(KvPage),
    Shared(Arc<KvPage>),
}

/// A frozen, refcounted prompt prefix: `pages[layer][pi]` covers
/// positions `0..positions` (page-aligned), every row fully written.
/// Cloning is cheap (`Arc` bumps); the prefix cache stores one of these
/// per content key and [`PagePool::try_adopt`] splices it into new
/// sequences.
#[derive(Clone)]
pub struct SharedPrefix {
    fmt: KvFormat,
    d: usize,
    page: usize,
    positions: usize,
    pages: Vec<Vec<Arc<KvPage>>>,
}

impl SharedPrefix {
    /// Positions these pages cover (a multiple of the page size).
    pub fn positions(&self) -> usize {
        self.positions
    }

    pub fn pages_per_layer(&self) -> usize {
        self.pages.first().map_or(0, Vec::len)
    }

    /// The same prefix truncated to its first `n_pages` pages — shares
    /// the underlying `Arc`s, so boundary-granular cache entries alias
    /// the same physical pages.
    pub fn truncated(&self, n_pages: usize) -> SharedPrefix {
        assert!(n_pages >= 1 && n_pages <= self.pages_per_layer(), "truncate to {n_pages} pages");
        SharedPrefix {
            fmt: self.fmt,
            d: self.d,
            page: self.page,
            positions: n_pages * self.page,
            pages: self.pages.iter().map(|l| l[..n_pages].to_vec()).collect(),
        }
    }
}

/// Preallocated, shared page arena. Cheap to query, `Mutex`-guarded for
/// the batch scheduler's concurrent retire/admit bookkeeping.
pub struct PagePool {
    fmt: KvFormat,
    layers: usize,
    d: usize,
    page: usize,
    total: usize,
    free: Mutex<Vec<KvPage>>,
}

impl PagePool {
    /// Preallocate `pages` f32 pages for a `layers`-layer model with
    /// model dim `d`, `page` positions per page (0 = [`PAGE_POSITIONS`]).
    pub fn new(layers: usize, d: usize, page: usize, pages: usize) -> PagePool {
        Self::with_format(KvFormat::F32, layers, d, page, pages)
    }

    /// [`PagePool::new`] with an explicit KV storage format
    /// (`--kv-bits`); every page in the pool shares it.
    pub fn with_format(
        fmt: KvFormat,
        layers: usize,
        d: usize,
        page: usize,
        pages: usize,
    ) -> PagePool {
        let page = if page == 0 { PAGE_POSITIONS } else { page };
        let free = (0..pages).map(|_| KvPage::new(fmt, page, d)).collect();
        PagePool { fmt, layers, d, page, total: pages, free: Mutex::new(free) }
    }

    /// Storage format every page in this pool uses.
    pub fn format(&self) -> KvFormat {
        self.fmt
    }

    /// Positions one page holds.
    pub fn page_positions(&self) -> usize {
        self.page
    }

    /// Resident bytes of one page at this pool's format.
    pub fn page_bytes(&self) -> usize {
        self.fmt.page_bytes(self.page, self.d)
    }

    /// Resident bytes the same page would occupy at f32 — the baseline
    /// for the KV resident-bytes ratio `ServeReport` surfaces.
    pub fn page_bytes_f32(&self) -> usize {
        KvFormat::F32.page_bytes(self.page, self.d)
    }

    /// Pages a sequence of `positions` total positions reserves (its
    /// worst case, across all layers). Matches [`PagePool::try_alloc`]
    /// exactly — including the one-page floor an empty reservation pays.
    pub fn pages_for(&self, positions: usize) -> usize {
        self.layers * positions.div_ceil(self.page).max(1)
    }

    /// Pages the same reservation needs when `covered` positions
    /// (page-aligned) adopt shared prefix pages instead of owned ones.
    pub fn pages_for_adopted(&self, positions: usize, covered: usize) -> usize {
        let per_layer = positions.div_ceil(self.page).max(1);
        let shared = (covered / self.page).min(per_layer);
        self.layers * (per_layer - shared)
    }

    pub fn total_pages(&self) -> usize {
        self.total
    }

    pub fn free_pages(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Reserve a sequence's full worst case up front; `None` when the
    /// pool cannot cover it (the scheduler then defers admission until a
    /// retire returns pages).
    pub fn try_alloc(&self, positions: usize) -> Option<SeqKv> {
        let per_layer = positions.div_ceil(self.page).max(1);
        let needed = self.layers * per_layer;
        let mut free = self.free.lock().unwrap();
        if free.len() < needed {
            trace::instant_with("serve", "kv.defer", || Json::obj().set("pages", needed));
            metrics::add("kv.alloc_deferred", 1);
            return None;
        }
        let mut layers = Vec::with_capacity(self.layers);
        for _ in 0..self.layers {
            let pages = free.split_off(free.len() - per_layer);
            layers.push(pages.into_iter().map(SeqPage::Owned).collect());
        }
        trace::instant_with("serve", "kv.alloc", || Json::obj().set("pages", needed));
        metrics::add("kv.pages_allocated", needed as u64);
        Some(SeqKv { fmt: self.fmt, d: self.d, page: self.page, layers, spares: Vec::new() })
    }

    /// Reserve `positions` with the first `prefix.positions()` adopted
    /// read-only from `prefix` (zero prefill forwards for the adopter):
    /// only the remaining page slots draw owned pages from the pool,
    /// plus `cow_spares` extra pages per layer as fork targets for
    /// writes **into** the shared span. Page-aligned adopters (the batch
    /// scheduler) pass 0 — their first write past the prefix lands in an
    /// owned page. `None` when the pool cannot cover the owned part.
    pub fn try_adopt(
        &self,
        positions: usize,
        prefix: &SharedPrefix,
        cow_spares: usize,
    ) -> Option<SeqKv> {
        assert_eq!(prefix.pages.len(), self.layers, "prefix layer count");
        assert_eq!(prefix.fmt, self.fmt, "prefix storage format");
        assert_eq!(prefix.d, self.d, "prefix model dim");
        assert_eq!(prefix.page, self.page, "prefix page size");
        assert!(prefix.positions <= positions, "prefix longer than the reservation");
        let per_layer = positions.div_ceil(self.page).max(1);
        let shared = prefix.positions / self.page;
        assert!(shared <= per_layer);
        let own_per_layer = per_layer - shared;
        let needed = self.layers * own_per_layer + cow_spares * self.layers;
        let mut free = self.free.lock().unwrap();
        if free.len() < needed {
            trace::instant_with("serve", "kv.defer", || Json::obj().set("pages", needed));
            metrics::add("kv.alloc_deferred", 1);
            return None;
        }
        trace::instant_with("serve", "kv.adopt", || {
            Json::obj().set("pages", needed).set("shared", shared * self.layers)
        });
        metrics::add("kv.pages_allocated", needed as u64);
        metrics::add("kv.pages_adopted", (shared * self.layers) as u64);
        let mut layers = Vec::with_capacity(self.layers);
        for l in 0..self.layers {
            let mut slots: Vec<SeqPage> =
                prefix.pages[l].iter().map(|p| SeqPage::Shared(p.clone())).collect();
            for _ in 0..own_per_layer {
                slots.push(SeqPage::Owned(free.pop().expect("count checked above")));
            }
            layers.push(slots);
        }
        let spares = (0..cow_spares * self.layers)
            .map(|_| free.pop().expect("count checked above"))
            .collect();
        Some(SeqKv { fmt: self.fmt, d: self.d, page: self.page, layers, spares })
    }

    /// Return a retired sequence's pages to the arena. Owned pages (and
    /// unused COW spares) go straight back; a shared page goes back only
    /// if this sequence held its **last** reference — otherwise the
    /// dropped `Arc` just decrements the refcount and the final holder
    /// (another sequence, or the prefix cache via [`PagePool::reclaim`])
    /// returns it. Each physical page is pushed exactly once, ever.
    pub fn release(&self, seq: SeqKv) {
        trace::instant("serve", "kv.release");
        metrics::add("kv.releases", 1);
        let mut free = self.free.lock().unwrap();
        for slots in seq.layers {
            for slot in slots {
                match slot {
                    SeqPage::Owned(p) => free.push(p),
                    SeqPage::Shared(arc) => {
                        if let Ok(p) = Arc::try_unwrap(arc) {
                            free.push(p);
                        }
                    }
                }
            }
        }
        free.extend(seq.spares);
    }

    /// Drop the prefix cache's reference to a frozen prefix, returning
    /// any page no sequence still shares (cache eviction; see
    /// [`PagePool::release`] for the refcount rule).
    pub fn reclaim(&self, prefix: SharedPrefix) {
        trace::instant("serve", "kv.reclaim");
        metrics::add("kv.reclaims", 1);
        let mut free = self.free.lock().unwrap();
        for pages in prefix.pages {
            for arc in pages {
                if let Ok(p) = Arc::try_unwrap(arc) {
                    free.push(p);
                }
            }
        }
    }
}

/// One sequence's KV cache: a per-layer page table. Positions are written
/// once (during that position's decode step) and read by every later
/// step's attention; adopted prefix positions are never written at all.
pub struct SeqKv {
    fmt: KvFormat,
    d: usize,
    page: usize,
    layers: Vec<Vec<SeqPage>>,
    /// COW fork targets for writes into shared pages (pool-allocated at
    /// adoption; returned with the sequence)
    spares: Vec<KvPage>,
}

impl SeqKv {
    /// Pool-free f32 cache for single-sequence decoding (`rsq generate`,
    /// tests): owns exactly the pages `capacity` positions need.
    pub fn standalone(layers: usize, d: usize, capacity: usize) -> SeqKv {
        Self::standalone_fmt(KvFormat::F32, layers, d, capacity)
    }

    /// [`SeqKv::standalone`] with an explicit KV storage format.
    pub fn standalone_fmt(fmt: KvFormat, layers: usize, d: usize, capacity: usize) -> SeqKv {
        let page = PAGE_POSITIONS;
        let per_layer = capacity.div_ceil(page).max(1);
        let layers = (0..layers)
            .map(|_| (0..per_layer).map(|_| SeqPage::Owned(KvPage::new(fmt, page, d))).collect())
            .collect();
        SeqKv { fmt, d, page, layers, spares: Vec::new() }
    }

    /// Storage format of this cache's pages.
    pub fn format(&self) -> KvFormat {
        self.fmt
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Positions this cache can hold (page-granular, so it may exceed the
    /// reservation that sized it).
    pub fn capacity(&self) -> usize {
        self.layers.first().map_or(0, |pages| pages.len() * self.page)
    }

    /// COW spare pages still unused (drops by one per shared-page fork).
    pub fn cow_spares(&self) -> usize {
        self.spares.len()
    }

    /// Store position `pos`'s k and v rows for `layer` — quantizing on
    /// write when the format is lossy. A write into a **shared** page
    /// forks it first (copy-on-write): the page's stored bytes are copied
    /// into a spare reserved at adoption and the slot becomes owned, so
    /// the donor and other adopters never observe the write. Panics if no
    /// spare was reserved — page-aligned adopters never write into the
    /// shared span, so the scheduler runs spare-free.
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.capacity(), "kv write past capacity: {pos}");
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        let (pi, r) = (pos / self.page, pos % self.page);
        let slot = &mut self.layers[layer][pi];
        if let SeqPage::Shared(src) = slot {
            let mut fork = self
                .spares
                .pop()
                .expect("write into a shared prefix page with no COW spare reserved");
            fork.copy_from(src);
            *slot = SeqPage::Owned(fork);
        }
        let SeqPage::Owned(p) = slot else { unreachable!("shared slot forked above") };
        p.k.write(self.fmt, r, self.d, k);
        p.v.write(self.fmt, r, self.d, v);
    }

    /// Freeze the first `positions` (a page multiple, fully written) into
    /// a refcounted [`SharedPrefix`] — the prefix-cache donation. Owned
    /// pages are moved into `Arc`s **in place**: this sequence keeps
    /// reading them through `Shared` slots (no copy, no extra pool
    /// pages), and slots that are already shared (this sequence itself
    /// adopted them) just bump their refcount.
    pub fn share_prefix(&mut self, positions: usize) -> SharedPrefix {
        assert!(positions > 0, "share_prefix needs at least one page");
        assert_eq!(positions % self.page, 0, "share_prefix is page-granular");
        let n = positions / self.page;
        assert!(n * self.page <= self.capacity(), "share_prefix past capacity");
        let mut pages = Vec::with_capacity(self.layers.len());
        for slots in &mut self.layers {
            let mut row = Vec::with_capacity(n);
            for slot in slots.iter_mut().take(n) {
                let arc = match std::mem::replace(slot, SeqPage::Owned(KvPage::placeholder())) {
                    SeqPage::Owned(p) => Arc::new(p),
                    SeqPage::Shared(a) => a,
                };
                *slot = SeqPage::Shared(arc.clone());
                row.push(arc);
            }
            pages.push(row);
        }
        SharedPrefix { fmt: self.fmt, d: self.d, page: self.page, positions, pages }
    }

    /// `layer`'s key rows as a [`RowSource`] for `attn_row` — the f32
    /// format reads in place; lossy formats decode into the kernel's
    /// scratch row, so no f32 page is ever rebuilt.
    pub fn k_rows(&self, layer: usize) -> KvHalfRows<'_> {
        let pages = &self.layers[layer];
        KvHalfRows { fmt: self.fmt, d: self.d, page: self.page, pages, v: false }
    }

    /// `layer`'s value rows as a [`RowSource`] (see [`SeqKv::k_rows`]).
    pub fn v_rows(&self, layer: usize) -> KvHalfRows<'_> {
        let pages = &self.layers[layer];
        KvHalfRows { fmt: self.fmt, d: self.d, page: self.page, pages, v: true }
    }
}

/// [`RowSource`] view over one layer's k **or** v rows of a [`SeqKv`] —
/// owned and shared pages read identically (shared pages are just pages
/// behind an `Arc`).
pub struct KvHalfRows<'s> {
    fmt: KvFormat,
    d: usize,
    page: usize,
    pages: &'s [SeqPage],
    v: bool,
}

impl RowSource for KvHalfRows<'_> {
    fn row<'a>(&'a self, s: usize, scratch: &'a mut [f32]) -> &'a [f32] {
        let (pi, r) = (s / self.page, s % self.page);
        let page: &KvPage = match &self.pages[pi] {
            SeqPage::Owned(p) => p,
            SeqPage::Shared(a) => a,
        };
        let half = if self.v { &page.v } else { &page.k };
        half.row(self.fmt, r, self.d, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read(kv: &SeqKv, layer: usize, pos: usize, v: bool) -> Vec<f32> {
        let mut scratch = vec![0.0f32; kv.d()];
        let rows = if v { kv.v_rows(layer) } else { kv.k_rows(layer) };
        rows.row(pos, &mut scratch).to_vec()
    }

    #[test]
    fn write_read_round_trip_across_pages() {
        let mut kv = SeqKv::standalone(2, 3, 40);
        assert_eq!(kv.capacity(), 48, "page-granular capacity");
        assert_eq!(kv.num_layers(), 2);
        assert_eq!(kv.format(), KvFormat::F32);
        for pos in 0..40 {
            for layer in 0..2 {
                let base = (layer * 100 + pos) as f32;
                let k = [base, base + 1.0, base + 2.0];
                let v = [-base, -base - 1.0, -base - 2.0];
                kv.write(layer, pos, &k, &v);
            }
        }
        // reads survive later writes (incl. across the page boundary at 16)
        for pos in [0usize, 15, 16, 17, 31, 32, 39] {
            for layer in 0..2 {
                let base = (layer * 100 + pos) as f32;
                assert_eq!(read(&kv, layer, pos, false), &[base, base + 1.0, base + 2.0]);
                assert_eq!(read(&kv, layer, pos, true), &[-base, -base - 1.0, -base - 2.0]);
            }
        }
    }

    #[test]
    fn f32_rows_are_read_in_place_not_from_scratch() {
        let mut kv = SeqKv::standalone(1, 2, 4);
        kv.write(0, 0, &[5.0, 6.0], &[7.0, 8.0]);
        // poisoned scratch must not leak into an exact-format read
        let mut scratch = vec![f32::NAN; 2];
        assert_eq!(kv.k_rows(0).row(0, &mut scratch), &[5.0, 6.0]);
        assert!(scratch.iter().all(|s| s.is_nan()), "f32 path never touches scratch");
    }

    #[test]
    fn quantized_round_trip_is_bounded_and_deterministic() {
        for fmt in [KvFormat::Linear8, KvFormat::Log2] {
            let mut kv = SeqKv::standalone_fmt(fmt, 2, 4, 20);
            assert_eq!(kv.format(), fmt);
            for pos in 0..20 {
                for layer in 0..2 {
                    let base = (1 + layer * 50 + pos) as f32;
                    let k = [base, -base, 0.5 * base, 0.0];
                    kv.write(layer, pos, &k, &k);
                }
            }
            for pos in [0usize, 15, 16, 19] {
                for layer in 0..2 {
                    let base = (1 + layer * 50 + pos) as f32;
                    let got = read(&kv, layer, pos, false);
                    // per-row max-abs bounds both codecs' absolute error
                    for (g, w) in got.iter().zip([base, -base, 0.5 * base, 0.0]) {
                        assert!((g - w).abs() <= base, "fmt={fmt:?} pos={pos}: {g} vs {w}");
                    }
                    assert_eq!(got, read(&kv, layer, pos, false), "decode must be deterministic");
                    assert_eq!(got, read(&kv, layer, pos, true), "same row, same decode");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "kv write past capacity")]
    fn write_past_capacity_panics() {
        let mut kv = SeqKv::standalone(1, 2, 16);
        kv.write(0, 16, &[0.0, 0.0], &[0.0, 0.0]);
    }

    #[test]
    fn pool_reserves_and_releases() {
        // 2 layers, page = 4 positions: a 10-position sequence needs
        // ceil(10/4) = 3 pages per layer = 6 total
        let pool = PagePool::new(2, 2, 4, 10);
        assert_eq!(pool.pages_for(10), 6);
        assert_eq!(pool.free_pages(), 10);
        let a = pool.try_alloc(10).unwrap();
        assert_eq!(a.capacity(), 12);
        assert_eq!(pool.free_pages(), 4);
        // a second 10-position sequence does not fit ...
        assert!(pool.try_alloc(10).is_none());
        // ... but a 8-position one does (2 pages x 2 layers)
        let b = pool.try_alloc(8).unwrap();
        assert_eq!(pool.free_pages(), 0);
        pool.release(a);
        assert_eq!(pool.free_pages(), 6);
        pool.release(b);
        assert_eq!(pool.free_pages(), 10);
        // released pages are reusable
        assert!(pool.try_alloc(10).is_some());
    }

    #[test]
    fn pool_format_flows_into_sequences_and_page_bytes() {
        let pool = PagePool::with_format(KvFormat::Linear8, 2, 8, 4, 4);
        assert_eq!(pool.format(), KvFormat::Linear8);
        assert_eq!(pool.page_bytes(), KvFormat::Linear8.page_bytes(4, 8));
        assert_eq!(pool.page_bytes_f32(), 2 * 4 * 4 * 8);
        assert!(pool.page_bytes() < pool.page_bytes_f32());
        let kv = pool.try_alloc(4).unwrap();
        assert_eq!(kv.format(), KvFormat::Linear8);
        pool.release(kv);
    }

    #[test]
    fn zero_position_reservation_still_holds_a_page() {
        let pool = PagePool::new(2, 2, 4, 4);
        assert_eq!(pool.pages_for(0), 2, "sizing math matches try_alloc's floor");
        let kv = pool.try_alloc(0).unwrap();
        assert_eq!(kv.capacity(), 4);
        assert_eq!(pool.free_pages(), pool.total_pages() - pool.pages_for(0));
        pool.release(kv);
    }

    /// Write `positions` deterministic rows into every layer of `kv`.
    fn fill(kv: &mut SeqKv, positions: usize, tag: f32) {
        for pos in 0..positions {
            for layer in 0..kv.num_layers() {
                let base = tag + (layer * 100 + pos) as f32;
                kv.write(layer, pos, &[base, base + 1.0], &[-base, -base - 1.0]);
            }
        }
    }

    #[test]
    fn shared_prefix_adoption_reads_donor_rows_and_refcounts_release() {
        // 1 layer, page = 4: donor writes 8 positions, freezes both pages
        let pool = PagePool::new(1, 2, 4, 8);
        let mut donor = pool.try_alloc(8).unwrap();
        fill(&mut donor, 8, 0.0);
        let prefix = donor.share_prefix(8);
        assert_eq!(prefix.positions(), 8);
        assert_eq!(prefix.pages_per_layer(), 2);
        // the donor keeps reading its frozen pages
        assert_eq!(read(&donor, 0, 5, false), &[5.0, 6.0]);
        // adoption needs only the owned tail: 12 positions = 3 pages, 2 shared
        assert_eq!(pool.pages_for_adopted(12, 8), 1);
        let free_before = pool.free_pages();
        let mut adopter = pool.try_adopt(12, &prefix, 0).unwrap();
        assert_eq!(pool.free_pages(), free_before - 1, "only the tail page is drawn");
        // adopted rows are the donor's bytes
        assert_eq!(read(&adopter, 0, 0, false), &[0.0, 1.0]);
        assert_eq!(read(&adopter, 0, 7, true), &[-7.0, -8.0]);
        // the adopter writes past the prefix into its own page
        adopter.write(0, 8, &[50.0, 51.0], &[52.0, 53.0]);
        assert_eq!(read(&adopter, 0, 8, false), &[50.0, 51.0]);
        assert_eq!(read(&donor, 0, 5, false), &[5.0, 6.0], "donor unaffected");
        // release order: donor first (pages still shared by adopter+prefix)
        pool.release(donor);
        let after_donor = pool.free_pages();
        pool.release(adopter);
        assert!(pool.free_pages() <= pool.total_pages(), "never over-free");
        assert!(pool.free_pages() > after_donor, "owned tail page returned");
        // the cache reference is last: reclaim returns the shared pages
        pool.reclaim(prefix);
        assert_eq!(pool.free_pages(), pool.total_pages(), "every page home exactly once");
    }

    #[test]
    fn cow_fork_leaves_donor_and_other_adopters_untouched() {
        let pool = PagePool::new(1, 2, 4, 8);
        let mut donor = pool.try_alloc(4).unwrap();
        fill(&mut donor, 4, 0.0);
        let prefix = donor.share_prefix(4);
        // non-aligned use: the adopter reserves one COW spare per layer
        let mut a = pool.try_adopt(8, &prefix, 1).unwrap();
        let b = pool.try_adopt(8, &prefix, 0).unwrap();
        assert_eq!(a.cow_spares(), 1);
        // writing INTO the shared span forks the page copy-on-write
        a.write(0, 1, &[99.0, 98.0], &[97.0, 96.0]);
        assert_eq!(a.cow_spares(), 0, "fork consumed the spare");
        assert_eq!(read(&a, 0, 1, false), &[99.0, 98.0]);
        // untouched rows of the forked page keep the donor's bytes
        assert_eq!(read(&a, 0, 2, false), &[2.0, 3.0]);
        // donor and the other adopter still read the original
        assert_eq!(read(&donor, 0, 1, false), &[1.0, 2.0]);
        assert_eq!(read(&b, 0, 1, false), &[1.0, 2.0]);
        pool.release(donor);
        pool.release(a);
        pool.release(b);
        pool.reclaim(prefix);
        assert_eq!(pool.free_pages(), pool.total_pages());
    }

    #[test]
    #[should_panic(expected = "no COW spare reserved")]
    fn shared_write_without_spare_panics() {
        let pool = PagePool::new(1, 2, 4, 8);
        let mut donor = pool.try_alloc(4).unwrap();
        fill(&mut donor, 4, 0.0);
        let prefix = donor.share_prefix(4);
        let mut a = pool.try_adopt(8, &prefix, 0).unwrap();
        a.write(0, 0, &[1.0, 2.0], &[3.0, 4.0]);
    }

    #[test]
    fn truncated_prefix_aliases_the_same_pages() {
        let pool = PagePool::new(2, 2, 4, 12);
        let mut donor = pool.try_alloc(8).unwrap();
        fill(&mut donor, 8, 0.0);
        let full = donor.share_prefix(8);
        let short = full.truncated(1);
        assert_eq!(short.positions(), 4);
        let adopter = pool.try_adopt(8, &short, 0).unwrap();
        assert_eq!(read(&adopter, 1, 3, false), &[103.0, 104.0]);
        pool.release(donor);
        pool.release(adopter);
        // reclaiming the short alias leaves pages live for the full one
        pool.reclaim(short);
        let missing = pool.total_pages() - pool.free_pages();
        assert_eq!(missing, full.pages_per_layer() * 2, "full prefix still holds its pages");
        pool.reclaim(full);
        assert_eq!(pool.free_pages(), pool.total_pages());
    }
}
