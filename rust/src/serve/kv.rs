//! Paged KV cache (DESIGN.md §11).
//!
//! Decoding token t attends over every previous position's per-layer
//! key/value projections. Recomputing them each step is the full-context
//! O(t²·d)-per-token recompute the eval modules do; caching them makes a
//! decode step O(t·d). Layout:
//!
//! - a [`PagePool`] preallocates a fixed number of pages up front; one
//!   page holds [`PAGE_POSITIONS`] positions of **one layer's** k and v
//!   rows (`[page, d]` row-major each), so pages are interchangeable
//!   across layers and sequences;
//! - a [`SeqKv`] is one sequence's cache: per layer, a page table
//!   reserved **at admission** for the sequence's whole worst case
//!   (prompt + max_new positions, capped at the model's `max_seq`), so a
//!   mid-flight decode step can never fail an allocation;
//! - retiring a sequence returns its pages ([`PagePool::release`]),
//!   which is what lets the batch scheduler (`serve::batch`) admit new
//!   requests mid-flight under a bounded memory budget.
//!
//! **Determinism.** Page identity carries no information — a sequence's
//! contents are addressed purely through its own page table — so which
//! physical pages a sequence happens to receive (an artifact of admission
//! order) cannot affect any decoded value.

use std::sync::Mutex;

/// Positions per page: small enough that short sequences waste little
/// capacity, large enough that page tables stay tiny.
pub const PAGE_POSITIONS: usize = 16;

/// One page: `page` positions of one layer's k and v rows.
#[derive(Debug)]
struct KvPage {
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvPage {
    fn new(page: usize, d: usize) -> KvPage {
        KvPage { k: vec![0.0; page * d], v: vec![0.0; page * d] }
    }
}

/// Preallocated, shared page arena. Cheap to query, `Mutex`-guarded for
/// the batch scheduler's concurrent retire/admit bookkeeping.
pub struct PagePool {
    layers: usize,
    d: usize,
    page: usize,
    total: usize,
    free: Mutex<Vec<KvPage>>,
}

impl PagePool {
    /// Preallocate `pages` pages for a `layers`-layer model with model
    /// dim `d`, `page` positions per page (0 = [`PAGE_POSITIONS`]).
    pub fn new(layers: usize, d: usize, page: usize, pages: usize) -> PagePool {
        let page = if page == 0 { PAGE_POSITIONS } else { page };
        let free = (0..pages).map(|_| KvPage::new(page, d)).collect();
        PagePool { layers, d, page, total: pages, free: Mutex::new(free) }
    }

    /// Positions one page holds.
    pub fn page_positions(&self) -> usize {
        self.page
    }

    /// Pages a sequence of `positions` total positions reserves (its
    /// worst case, across all layers). Matches [`PagePool::try_alloc`]
    /// exactly — including the one-page floor an empty reservation pays.
    pub fn pages_for(&self, positions: usize) -> usize {
        self.layers * positions.div_ceil(self.page).max(1)
    }

    pub fn total_pages(&self) -> usize {
        self.total
    }

    pub fn free_pages(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Reserve a sequence's full worst case up front; `None` when the
    /// pool cannot cover it (the scheduler then defers admission until a
    /// retire returns pages).
    pub fn try_alloc(&self, positions: usize) -> Option<SeqKv> {
        let per_layer = positions.div_ceil(self.page).max(1);
        let needed = self.layers * per_layer;
        let mut free = self.free.lock().unwrap();
        if free.len() < needed {
            return None;
        }
        let mut layers = Vec::with_capacity(self.layers);
        for _ in 0..self.layers {
            layers.push(free.split_off(free.len() - per_layer));
        }
        Some(SeqKv { d: self.d, page: self.page, layers })
    }

    /// Return a retired sequence's pages to the arena.
    pub fn release(&self, seq: SeqKv) {
        let mut free = self.free.lock().unwrap();
        for pages in seq.layers {
            free.extend(pages);
        }
    }
}

/// One sequence's KV cache: a per-layer page table. Positions are written
/// once (during that position's decode step) and read by every later
/// step's attention.
pub struct SeqKv {
    d: usize,
    page: usize,
    layers: Vec<Vec<KvPage>>,
}

impl SeqKv {
    /// Pool-free cache for single-sequence decoding (`rsq generate`,
    /// tests): owns exactly the pages `capacity` positions need.
    pub fn standalone(layers: usize, d: usize, capacity: usize) -> SeqKv {
        let page = PAGE_POSITIONS;
        let per_layer = capacity.div_ceil(page).max(1);
        let layers = (0..layers)
            .map(|_| (0..per_layer).map(|_| KvPage::new(page, d)).collect())
            .collect();
        SeqKv { d, page, layers }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Positions this cache can hold (page-granular, so it may exceed the
    /// reservation that sized it).
    pub fn capacity(&self) -> usize {
        self.layers.first().map_or(0, |pages| pages.len() * self.page)
    }

    /// Store position `pos`'s k and v rows for `layer`.
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.capacity(), "kv write past capacity: {pos}");
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        let (pi, off) = (pos / self.page, (pos % self.page) * self.d);
        let p = &mut self.layers[layer][pi];
        p.k[off..off + self.d].copy_from_slice(k);
        p.v[off..off + self.d].copy_from_slice(v);
    }

    /// Position `pos`'s key row for `layer`.
    pub fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
        let (pi, off) = (pos / self.page, (pos % self.page) * self.d);
        &self.layers[layer][pi].k[off..off + self.d]
    }

    /// Position `pos`'s value row for `layer`.
    pub fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
        let (pi, off) = (pos / self.page, (pos % self.page) * self.d);
        &self.layers[layer][pi].v[off..off + self.d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_round_trip_across_pages() {
        let mut kv = SeqKv::standalone(2, 3, 40);
        assert_eq!(kv.capacity(), 48, "page-granular capacity");
        assert_eq!(kv.num_layers(), 2);
        for pos in 0..40 {
            for layer in 0..2 {
                let base = (layer * 100 + pos) as f32;
                let k = [base, base + 1.0, base + 2.0];
                let v = [-base, -base - 1.0, -base - 2.0];
                kv.write(layer, pos, &k, &v);
            }
        }
        // reads survive later writes (incl. across the page boundary at 16)
        for pos in [0usize, 15, 16, 17, 31, 32, 39] {
            for layer in 0..2 {
                let base = (layer * 100 + pos) as f32;
                assert_eq!(kv.k_at(layer, pos), &[base, base + 1.0, base + 2.0]);
                assert_eq!(kv.v_at(layer, pos), &[-base, -base - 1.0, -base - 2.0]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "kv write past capacity")]
    fn write_past_capacity_panics() {
        let mut kv = SeqKv::standalone(1, 2, 16);
        kv.write(0, 16, &[0.0, 0.0], &[0.0, 0.0]);
    }

    #[test]
    fn pool_reserves_and_releases() {
        // 2 layers, page = 4 positions: a 10-position sequence needs
        // ceil(10/4) = 3 pages per layer = 6 total
        let pool = PagePool::new(2, 2, 4, 10);
        assert_eq!(pool.pages_for(10), 6);
        assert_eq!(pool.free_pages(), 10);
        let a = pool.try_alloc(10).unwrap();
        assert_eq!(a.capacity(), 12);
        assert_eq!(pool.free_pages(), 4);
        // a second 10-position sequence does not fit ...
        assert!(pool.try_alloc(10).is_none());
        // ... but a 8-position one does (2 pages x 2 layers)
        let b = pool.try_alloc(8).unwrap();
        assert_eq!(pool.free_pages(), 0);
        pool.release(a);
        assert_eq!(pool.free_pages(), 6);
        pool.release(b);
        assert_eq!(pool.free_pages(), 10);
        // released pages are reusable
        assert!(pool.try_alloc(10).is_some());
    }

    #[test]
    fn zero_position_reservation_still_holds_a_page() {
        let pool = PagePool::new(2, 2, 4, 4);
        assert_eq!(pool.pages_for(0), 2, "sizing math matches try_alloc's floor");
        let kv = pool.try_alloc(0).unwrap();
        assert_eq!(kv.capacity(), 4);
        assert_eq!(pool.free_pages(), pool.total_pages() - pool.pages_for(0));
        pool.release(kv);
    }
}
