//! Paged KV cache (DESIGN.md §11, §12).
//!
//! Decoding token t attends over every previous position's per-layer
//! key/value projections. Recomputing them each step is the full-context
//! O(t²·d)-per-token recompute the eval modules do; caching them makes a
//! decode step O(t·d). Layout:
//!
//! - a [`PagePool`] preallocates a fixed number of pages up front; one
//!   page holds [`PAGE_POSITIONS`] positions of **one layer's** k and v
//!   rows (`[page, d]` row-major each), so pages are interchangeable
//!   across layers and sequences;
//! - a [`SeqKv`] is one sequence's cache: per layer, a page table
//!   reserved **at admission** for the sequence's whole worst case
//!   (prompt + max_new positions, capped at the model's `max_seq`), so a
//!   mid-flight decode step can never fail an allocation;
//! - retiring a sequence returns its pages ([`PagePool::release`]),
//!   which is what lets the batch scheduler (`serve::batch`) admit new
//!   requests mid-flight under a bounded memory budget.
//!
//! **Storage format.** Every page in a pool shares one [`KvFormat`]
//! (`--kv-bits`): f32 rows stored verbatim (the exact path), or packed
//! low-bit codes plus per-position-row scale state, quantized on write
//! through `serve::kvq` and decoded row-at-a-time on read. A position is
//! written exactly once (its own decode step), so per-row scale state
//! never has to be revised by later writes, and a row's decoded value is
//! independent of page size and of everything written after it.
//!
//! **Determinism.** Page identity carries no information — a sequence's
//! contents are addressed purely through its own page table — so which
//! physical pages a sequence happens to receive (an artifact of admission
//! order) cannot affect any decoded value. Quantized rows keep that
//! property: encode and decode are pure per-row functions.

use std::sync::Mutex;

use super::kvq::{decode_row, encode_row, KvFormat, RowSource};

/// Positions per page: small enough that short sequences waste little
/// capacity, large enough that page tables stay tiny.
pub const PAGE_POSITIONS: usize = 16;

/// One half (k or v) of a page, in its storage domain.
#[derive(Debug)]
enum PageHalf {
    /// `[page, d]` row-major f32 — read in place, never copied
    F32(Vec<f32>),
    /// `[page, row_code_bytes(d)]` packed codes + per-row scale state
    Packed { codes: Vec<u8>, s0: Vec<f32>, s1: Vec<f32> },
}

impl PageHalf {
    fn new(fmt: KvFormat, page: usize, d: usize) -> PageHalf {
        match fmt {
            KvFormat::F32 => PageHalf::F32(vec![0.0; page * d]),
            _ => PageHalf::Packed {
                codes: vec![0u8; page * fmt.row_code_bytes(d)],
                s0: vec![0.0; page],
                s1: vec![0.0; page],
            },
        }
    }

    /// Store row `r` (quantizing when packed; `encode_row` clears the
    /// slot's code bytes first, so overwrites are safe).
    fn write(&mut self, fmt: KvFormat, r: usize, d: usize, src: &[f32]) {
        match self {
            PageHalf::F32(data) => data[r * d..(r + 1) * d].copy_from_slice(src),
            PageHalf::Packed { codes, s0, s1 } => {
                let cb = fmt.row_code_bytes(d);
                let (a, b) = encode_row(fmt, src, &mut codes[r * cb..(r + 1) * cb]);
                s0[r] = a;
                s1[r] = b;
            }
        }
    }

    /// Row `r`: the resident slice when f32, a decode into `scratch`
    /// when packed.
    fn row<'a>(&'a self, fmt: KvFormat, r: usize, d: usize, scratch: &'a mut [f32]) -> &'a [f32] {
        match self {
            PageHalf::F32(data) => &data[r * d..(r + 1) * d],
            PageHalf::Packed { codes, s0, s1 } => {
                let cb = fmt.row_code_bytes(d);
                let out = &mut scratch[..d];
                decode_row(fmt, &codes[r * cb..(r + 1) * cb], s0[r], s1[r], out);
                out
            }
        }
    }
}

/// One page: `page` positions of one layer's k and v rows.
#[derive(Debug)]
struct KvPage {
    k: PageHalf,
    v: PageHalf,
}

impl KvPage {
    fn new(fmt: KvFormat, page: usize, d: usize) -> KvPage {
        KvPage { k: PageHalf::new(fmt, page, d), v: PageHalf::new(fmt, page, d) }
    }
}

/// Preallocated, shared page arena. Cheap to query, `Mutex`-guarded for
/// the batch scheduler's concurrent retire/admit bookkeeping.
pub struct PagePool {
    fmt: KvFormat,
    layers: usize,
    d: usize,
    page: usize,
    total: usize,
    free: Mutex<Vec<KvPage>>,
}

impl PagePool {
    /// Preallocate `pages` f32 pages for a `layers`-layer model with
    /// model dim `d`, `page` positions per page (0 = [`PAGE_POSITIONS`]).
    pub fn new(layers: usize, d: usize, page: usize, pages: usize) -> PagePool {
        Self::with_format(KvFormat::F32, layers, d, page, pages)
    }

    /// [`PagePool::new`] with an explicit KV storage format
    /// (`--kv-bits`); every page in the pool shares it.
    pub fn with_format(
        fmt: KvFormat,
        layers: usize,
        d: usize,
        page: usize,
        pages: usize,
    ) -> PagePool {
        let page = if page == 0 { PAGE_POSITIONS } else { page };
        let free = (0..pages).map(|_| KvPage::new(fmt, page, d)).collect();
        PagePool { fmt, layers, d, page, total: pages, free: Mutex::new(free) }
    }

    /// Storage format every page in this pool uses.
    pub fn format(&self) -> KvFormat {
        self.fmt
    }

    /// Positions one page holds.
    pub fn page_positions(&self) -> usize {
        self.page
    }

    /// Resident bytes of one page at this pool's format.
    pub fn page_bytes(&self) -> usize {
        self.fmt.page_bytes(self.page, self.d)
    }

    /// Resident bytes the same page would occupy at f32 — the baseline
    /// for the KV resident-bytes ratio `ServeReport` surfaces.
    pub fn page_bytes_f32(&self) -> usize {
        KvFormat::F32.page_bytes(self.page, self.d)
    }

    /// Pages a sequence of `positions` total positions reserves (its
    /// worst case, across all layers). Matches [`PagePool::try_alloc`]
    /// exactly — including the one-page floor an empty reservation pays.
    pub fn pages_for(&self, positions: usize) -> usize {
        self.layers * positions.div_ceil(self.page).max(1)
    }

    pub fn total_pages(&self) -> usize {
        self.total
    }

    pub fn free_pages(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Reserve a sequence's full worst case up front; `None` when the
    /// pool cannot cover it (the scheduler then defers admission until a
    /// retire returns pages).
    pub fn try_alloc(&self, positions: usize) -> Option<SeqKv> {
        let per_layer = positions.div_ceil(self.page).max(1);
        let needed = self.layers * per_layer;
        let mut free = self.free.lock().unwrap();
        if free.len() < needed {
            return None;
        }
        let mut layers = Vec::with_capacity(self.layers);
        for _ in 0..self.layers {
            layers.push(free.split_off(free.len() - per_layer));
        }
        Some(SeqKv { fmt: self.fmt, d: self.d, page: self.page, layers })
    }

    /// Return a retired sequence's pages to the arena.
    pub fn release(&self, seq: SeqKv) {
        let mut free = self.free.lock().unwrap();
        for pages in seq.layers {
            free.extend(pages);
        }
    }
}

/// One sequence's KV cache: a per-layer page table. Positions are written
/// once (during that position's decode step) and read by every later
/// step's attention.
pub struct SeqKv {
    fmt: KvFormat,
    d: usize,
    page: usize,
    layers: Vec<Vec<KvPage>>,
}

impl SeqKv {
    /// Pool-free f32 cache for single-sequence decoding (`rsq generate`,
    /// tests): owns exactly the pages `capacity` positions need.
    pub fn standalone(layers: usize, d: usize, capacity: usize) -> SeqKv {
        Self::standalone_fmt(KvFormat::F32, layers, d, capacity)
    }

    /// [`SeqKv::standalone`] with an explicit KV storage format.
    pub fn standalone_fmt(fmt: KvFormat, layers: usize, d: usize, capacity: usize) -> SeqKv {
        let page = PAGE_POSITIONS;
        let per_layer = capacity.div_ceil(page).max(1);
        let layers = (0..layers)
            .map(|_| (0..per_layer).map(|_| KvPage::new(fmt, page, d)).collect())
            .collect();
        SeqKv { fmt, d, page, layers }
    }

    /// Storage format of this cache's pages.
    pub fn format(&self) -> KvFormat {
        self.fmt
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Positions this cache can hold (page-granular, so it may exceed the
    /// reservation that sized it).
    pub fn capacity(&self) -> usize {
        self.layers.first().map_or(0, |pages| pages.len() * self.page)
    }

    /// Store position `pos`'s k and v rows for `layer` — quantizing on
    /// write when the format is lossy.
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.capacity(), "kv write past capacity: {pos}");
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        let (pi, r) = (pos / self.page, pos % self.page);
        let p = &mut self.layers[layer][pi];
        p.k.write(self.fmt, r, self.d, k);
        p.v.write(self.fmt, r, self.d, v);
    }

    /// `layer`'s key rows as a [`RowSource`] for `attn_row` — the f32
    /// format reads in place; lossy formats decode into the kernel's
    /// scratch row, so no f32 page is ever rebuilt.
    pub fn k_rows(&self, layer: usize) -> KvHalfRows<'_> {
        let pages = &self.layers[layer];
        KvHalfRows { fmt: self.fmt, d: self.d, page: self.page, pages, v: false }
    }

    /// `layer`'s value rows as a [`RowSource`] (see [`SeqKv::k_rows`]).
    pub fn v_rows(&self, layer: usize) -> KvHalfRows<'_> {
        let pages = &self.layers[layer];
        KvHalfRows { fmt: self.fmt, d: self.d, page: self.page, pages, v: true }
    }
}

/// [`RowSource`] view over one layer's k **or** v rows of a [`SeqKv`].
pub struct KvHalfRows<'s> {
    fmt: KvFormat,
    d: usize,
    page: usize,
    pages: &'s [KvPage],
    v: bool,
}

impl RowSource for KvHalfRows<'_> {
    fn row<'a>(&'a self, s: usize, scratch: &'a mut [f32]) -> &'a [f32] {
        let (pi, r) = (s / self.page, s % self.page);
        let half = if self.v { &self.pages[pi].v } else { &self.pages[pi].k };
        half.row(self.fmt, r, self.d, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read(kv: &SeqKv, layer: usize, pos: usize, v: bool) -> Vec<f32> {
        let mut scratch = vec![0.0f32; kv.d()];
        let rows = if v { kv.v_rows(layer) } else { kv.k_rows(layer) };
        rows.row(pos, &mut scratch).to_vec()
    }

    #[test]
    fn write_read_round_trip_across_pages() {
        let mut kv = SeqKv::standalone(2, 3, 40);
        assert_eq!(kv.capacity(), 48, "page-granular capacity");
        assert_eq!(kv.num_layers(), 2);
        assert_eq!(kv.format(), KvFormat::F32);
        for pos in 0..40 {
            for layer in 0..2 {
                let base = (layer * 100 + pos) as f32;
                let k = [base, base + 1.0, base + 2.0];
                let v = [-base, -base - 1.0, -base - 2.0];
                kv.write(layer, pos, &k, &v);
            }
        }
        // reads survive later writes (incl. across the page boundary at 16)
        for pos in [0usize, 15, 16, 17, 31, 32, 39] {
            for layer in 0..2 {
                let base = (layer * 100 + pos) as f32;
                assert_eq!(read(&kv, layer, pos, false), &[base, base + 1.0, base + 2.0]);
                assert_eq!(read(&kv, layer, pos, true), &[-base, -base - 1.0, -base - 2.0]);
            }
        }
    }

    #[test]
    fn f32_rows_are_read_in_place_not_from_scratch() {
        let mut kv = SeqKv::standalone(1, 2, 4);
        kv.write(0, 0, &[5.0, 6.0], &[7.0, 8.0]);
        // poisoned scratch must not leak into an exact-format read
        let mut scratch = vec![f32::NAN; 2];
        assert_eq!(kv.k_rows(0).row(0, &mut scratch), &[5.0, 6.0]);
        assert!(scratch.iter().all(|s| s.is_nan()), "f32 path never touches scratch");
    }

    #[test]
    fn quantized_round_trip_is_bounded_and_deterministic() {
        for fmt in [KvFormat::Linear8, KvFormat::Log2] {
            let mut kv = SeqKv::standalone_fmt(fmt, 2, 4, 20);
            assert_eq!(kv.format(), fmt);
            for pos in 0..20 {
                for layer in 0..2 {
                    let base = (1 + layer * 50 + pos) as f32;
                    let k = [base, -base, 0.5 * base, 0.0];
                    kv.write(layer, pos, &k, &k);
                }
            }
            for pos in [0usize, 15, 16, 19] {
                for layer in 0..2 {
                    let base = (1 + layer * 50 + pos) as f32;
                    let got = read(&kv, layer, pos, false);
                    // per-row max-abs bounds both codecs' absolute error
                    for (g, w) in got.iter().zip([base, -base, 0.5 * base, 0.0]) {
                        assert!((g - w).abs() <= base, "fmt={fmt:?} pos={pos}: {g} vs {w}");
                    }
                    assert_eq!(got, read(&kv, layer, pos, false), "decode must be deterministic");
                    assert_eq!(got, read(&kv, layer, pos, true), "same row, same decode");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "kv write past capacity")]
    fn write_past_capacity_panics() {
        let mut kv = SeqKv::standalone(1, 2, 16);
        kv.write(0, 16, &[0.0, 0.0], &[0.0, 0.0]);
    }

    #[test]
    fn pool_reserves_and_releases() {
        // 2 layers, page = 4 positions: a 10-position sequence needs
        // ceil(10/4) = 3 pages per layer = 6 total
        let pool = PagePool::new(2, 2, 4, 10);
        assert_eq!(pool.pages_for(10), 6);
        assert_eq!(pool.free_pages(), 10);
        let a = pool.try_alloc(10).unwrap();
        assert_eq!(a.capacity(), 12);
        assert_eq!(pool.free_pages(), 4);
        // a second 10-position sequence does not fit ...
        assert!(pool.try_alloc(10).is_none());
        // ... but a 8-position one does (2 pages x 2 layers)
        let b = pool.try_alloc(8).unwrap();
        assert_eq!(pool.free_pages(), 0);
        pool.release(a);
        assert_eq!(pool.free_pages(), 6);
        pool.release(b);
        assert_eq!(pool.free_pages(), 10);
        // released pages are reusable
        assert!(pool.try_alloc(10).is_some());
    }

    #[test]
    fn pool_format_flows_into_sequences_and_page_bytes() {
        let pool = PagePool::with_format(KvFormat::Linear8, 2, 8, 4, 4);
        assert_eq!(pool.format(), KvFormat::Linear8);
        assert_eq!(pool.page_bytes(), KvFormat::Linear8.page_bytes(4, 8));
        assert_eq!(pool.page_bytes_f32(), 2 * 4 * 4 * 8);
        assert!(pool.page_bytes() < pool.page_bytes_f32());
        let kv = pool.try_alloc(4).unwrap();
        assert_eq!(kv.format(), KvFormat::Linear8);
        pool.release(kv);
    }

    #[test]
    fn zero_position_reservation_still_holds_a_page() {
        let pool = PagePool::new(2, 2, 4, 4);
        assert_eq!(pool.pages_for(0), 2, "sizing math matches try_alloc's floor");
        let kv = pool.try_alloc(0).unwrap();
        assert_eq!(kv.capacity(), 4);
        assert_eq!(pool.free_pages(), pool.total_pages() - pool.pages_for(0));
        pool.release(kv);
    }
}
