//! Continuous-batching request scheduler (DESIGN.md §11).
//!
//! The serving loop advances every in-flight sequence by **one position
//! per engine step** — a sequence still consuming its prompt and one
//! already generating ride the same step, and no sequence ever computes a
//! padding position (padded-free batching). Sequences are admitted the
//! moment a batch slot *and* their full KV-cache reservation are
//! available, and retired (pages returned to the [`PagePool`]) the moment
//! they finish, so new requests join mid-flight instead of waiting for
//! the whole batch to drain.
//!
//! Per-step work fans out over `util::Pool`, one task per active
//! sequence; sequences are fully independent (own decoder, own KV pages),
//! so the generated tokens are **deterministic** — invariant to `--jobs`,
//! to `max_batch`, and to which other requests happen to be in flight
//! ([`serve`]'s output equals per-request solo [`greedy_decode`];
//! `tests/prop_serve.rs` pins it). Only the wall-clock fields of
//! [`ServeReport`] vary between runs.
//!
//! Deadlines are best-effort admission-relative wall-clock budgets: a
//! sequence past its deadline stops generating at its next step and is
//! retired with `deadline_missed` set, surfaced per request in the
//! report.
//!
//! [`PagePool`]: super::kv::PagePool
//! [`greedy_decode`]: super::model::greedy_decode

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use super::kv::PagePool;
use super::kvq::KvFormat;
use super::model::{Decoder, PackedModel};
use crate::eval::argmax;
use crate::util::Pool;

/// One generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    /// caller-chosen id, echoed in [`RequestStats`]
    pub id: u64,
    pub prompt: Vec<i32>,
    /// tokens to generate (greedy argmax)
    pub max_new: usize,
    /// optional wall-clock budget in seconds, measured from admission
    pub deadline_s: Option<f64>,
}

impl ServeRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> ServeRequest {
        ServeRequest { id, prompt, max_new, deadline_s: None }
    }
}

/// Scheduler knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// sequences decoded concurrently (batch slots)
    pub max_batch: usize,
    /// KV page size in positions (0 = `kv::PAGE_POSITIONS`)
    pub page: usize,
    /// KV page-pool capacity in pages (0 = auto: enough for `max_batch`
    /// worst-case sequences, unless `pool_bytes` sizes it instead)
    pub pages: usize,
    /// KV page-pool capacity as a **byte** budget (0 = off). Converted
    /// to pages at the chosen `kv` format's page size — the admission
    /// accounting where lower `--kv-bits` buys more pages, more
    /// concurrent reservations, and higher peak occupancy under the same
    /// memory budget. Ignored when `pages` is set explicitly.
    pub pool_bytes: usize,
    /// KV storage format (`--kv-bits`; default f32 = the exact path)
    pub kv: KvFormat,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 4, page: 0, pages: 0, pool_bytes: 0, kv: KvFormat::F32 }
    }
}

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestStats {
    pub id: u64,
    pub prompt_len: usize,
    /// greedy-decoded tokens (deterministic; may be short of `max_new`
    /// on a missed deadline or the model's context limit)
    pub generated: Vec<i32>,
    pub deadline_missed: bool,
    /// engine step at which the request entered / left the batch
    pub admitted_step: usize,
    pub finished_step: usize,
    /// admission → first generated token, seconds
    pub ttft_s: Option<f64>,
    /// admission → retire, seconds
    pub wall_s: f64,
}

/// Aggregate serving outcome.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// per-request stats, sorted by request id
    pub requests: Vec<RequestStats>,
    /// engine steps executed (each advances every active sequence once)
    pub steps: usize,
    pub peak_active: usize,
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    /// KV storage width served at (`--kv-bits`: 32, 8, or 2)
    pub kv_bits: u32,
    /// most pages simultaneously reserved from the pool
    pub kv_peak_pages: usize,
    /// peak KV bytes resident at `kv_bits` (`kv_peak_pages` × page size)
    pub kv_resident_bytes: usize,
    /// bytes the same peak page count would occupy at f32 — the
    /// denominator of the KV resident-bytes ratio
    pub kv_resident_f32_bytes: usize,
    /// kernel backend the forward passes ran on (`--backend` after
    /// resolution: "reference" or "simd", DESIGN.md §13)
    pub backend: String,
}

/// One in-flight sequence.
struct Active<'m> {
    req: ServeRequest,
    decoder: Decoder<'m>,
    consumed: usize,
    generated: Vec<i32>,
    admitted_at: Instant,
    admitted_step: usize,
    ttft_s: Option<f64>,
    deadline_missed: bool,
    done: bool,
}

impl<'m> Active<'m> {
    /// Advance one position: consume the next prompt token or the last
    /// generated one, and (once past the prompt) greedily emit the next
    /// token. Deadline is checked before spending any compute.
    fn advance(&mut self, pool: Option<&Pool>) {
        if self.done {
            return;
        }
        if let Some(deadline) = self.req.deadline_s {
            if self.admitted_at.elapsed().as_secs_f64() > deadline {
                self.deadline_missed = true;
                self.done = true;
                return;
            }
        }
        let tok = if self.consumed < self.req.prompt.len() {
            self.req.prompt[self.consumed]
        } else {
            *self.generated.last().expect("past the prompt, so a token was generated")
        };
        // logits are only needed once this position's output token will
        // actually be kept; earlier prompt positions prefill the KV
        // cache without paying the head projection
        let wants_token = self.consumed + 1 >= self.req.prompt.len()
            && self.generated.len() < self.req.max_new;
        if wants_token {
            let logp = self.decoder.step(tok, pool);
            let next = argmax(&logp) as i32;
            self.generated.push(next);
            if self.ttft_s.is_none() {
                self.ttft_s = Some(self.admitted_at.elapsed().as_secs_f64());
            }
        } else {
            self.decoder.prefill(tok, pool);
        }
        self.consumed += 1;
        if self.generated.len() >= self.req.max_new
            || self.decoder.positions() >= self.decoder.capacity()
        {
            self.done = true;
        }
    }

    fn finish(self, finished_step: usize) -> (RequestStats, Decoder<'m>) {
        let stats = RequestStats {
            id: self.req.id,
            prompt_len: self.req.prompt.len(),
            generated: self.generated,
            deadline_missed: self.deadline_missed,
            admitted_step: self.admitted_step,
            finished_step,
            ttft_s: self.ttft_s,
            wall_s: self.admitted_at.elapsed().as_secs_f64(),
        };
        (stats, self.decoder)
    }
}

/// Run `requests` to completion through the continuous-batching loop.
/// Requests are admitted in the given order (FIFO) as slots and KV pages
/// free up.
pub fn serve(
    model: &PackedModel,
    pool: &Pool,
    requests: Vec<ServeRequest>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let cfg = &model.cfg;
    if opts.max_batch == 0 {
        bail!("serve needs max_batch >= 1");
    }
    for r in &requests {
        if r.prompt.is_empty() {
            bail!("request {}: empty prompt", r.id);
        }
        if r.prompt.len() > cfg.max_seq {
            bail!(
                "request {}: prompt length {} exceeds max_seq {}",
                r.id,
                r.prompt.len(),
                cfg.max_seq
            );
        }
        if let Some(&t) = r.prompt.iter().find(|&&t| !(0..cfg.vocab as i32).contains(&t)) {
            bail!("request {}: token {t} outside vocab {}", r.id, cfg.vocab);
        }
    }
    // positions a request reserves for its whole lifetime
    let worst = |r: &ServeRequest| (r.prompt.len() + r.max_new).min(cfg.max_seq);
    let probe = PagePool::with_format(opts.kv, cfg.layers, cfg.d, opts.page, 0);
    let max_pages = requests.iter().map(|r| probe.pages_for(worst(r))).max().unwrap_or(0);
    // explicit pages > byte budget > auto; a byte budget buys more pages
    // (= more concurrent admissions) the narrower the KV format is
    let pages = if opts.pages != 0 {
        opts.pages
    } else if opts.pool_bytes != 0 {
        opts.pool_bytes / probe.page_bytes().max(1)
    } else {
        opts.max_batch * max_pages
    };
    if pages < max_pages {
        bail!(
            "page pool of {pages} pages cannot fit the largest request ({max_pages} pages) — \
             raise ServeOptions::pages or pool_bytes"
        );
    }
    let page_pool = PagePool::with_format(opts.kv, cfg.layers, cfg.d, opts.page, pages);

    let t0 = Instant::now();
    let mut pending: VecDeque<ServeRequest> = requests.into();
    let mut active: Vec<Mutex<Active>> = Vec::new();
    let mut done: Vec<RequestStats> = Vec::new();
    let mut steps = 0usize;
    let mut peak_active = 0usize;
    let mut kv_peak_pages = 0usize;
    while !pending.is_empty() || !active.is_empty() {
        // admit while a slot and a full KV reservation are available
        while active.len() < opts.max_batch {
            let Some(front) = pending.front() else { break };
            let Some(kv) = page_pool.try_alloc(worst(front)) else { break };
            let req = pending.pop_front().expect("front() was Some");
            active.push(Mutex::new(Active {
                decoder: Decoder::new(model, kv),
                consumed: 0,
                generated: Vec::with_capacity(req.max_new),
                admitted_at: Instant::now(),
                admitted_step: steps,
                ttft_s: None,
                deadline_missed: false,
                done: false,
                req,
            }));
        }
        peak_active = peak_active.max(active.len());
        kv_peak_pages = kv_peak_pages.max(page_pool.total_pages() - page_pool.free_pages());
        // one position per active sequence; the pool fans out across
        // sequences — with a single sequence it accelerates the
        // projections inside the step instead
        if active.len() > 1 {
            pool.run(active.len(), |i| active[i].lock().unwrap().advance(None));
        } else if let Some(only) = active.first() {
            only.lock().unwrap().advance(Some(pool));
        }
        steps += 1;
        // retire finished sequences, returning their pages
        let mut i = 0;
        while i < active.len() {
            if active[i].get_mut().unwrap().done {
                let a = active.swap_remove(i).into_inner().unwrap();
                let (stats, decoder) = a.finish(steps);
                page_pool.release(decoder.into_kv());
                done.push(stats);
            } else {
                i += 1;
            }
        }
    }
    done.sort_by_key(|r| r.id);
    let wall_s = t0.elapsed().as_secs_f64();
    let generated_tokens: usize = done.iter().map(|r| r.generated.len()).sum();
    Ok(ServeReport {
        steps,
        peak_active,
        generated_tokens,
        wall_s,
        tokens_per_s: generated_tokens as f64 / wall_s.max(1e-12),
        kv_bits: opts.kv.bits(),
        kv_peak_pages,
        kv_resident_bytes: kv_peak_pages * page_pool.page_bytes(),
        kv_resident_f32_bytes: kv_peak_pages * page_pool.page_bytes_f32(),
        backend: model.backend().name().to_string(),
        requests: done,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::ParamSet;
    use crate::serve::model::greedy_decode;
    use crate::serve::PackedModel;

    fn model() -> PackedModel {
        let cfg = ModelConfig {
            name: "serve-batch-test".into(),
            d: 16,
            layers: 2,
            heads: 2,
            ff: 32,
            vocab: 32,
            max_seq: 32,
            batch: 2,
            seq_lens: vec![8, 32],
            ldlq_k: 64,
            ldlq_g: 4,
        };
        PackedModel::from_paramset_rtn(&ParamSet::init(&cfg, 13), 4).unwrap()
    }

    fn reqs(n: u64) -> Vec<ServeRequest> {
        (0..n)
            .map(|i| ServeRequest::new(i, vec![(i as i32) % 8 + 1, 2, 5], 6 + (i as usize % 3)))
            .collect()
    }

    #[test]
    fn batched_output_equals_solo_decode() {
        let m = model();
        let solo: Vec<Vec<i32>> = reqs(5)
            .into_iter()
            .map(|r| greedy_decode(&m, &r.prompt, r.max_new, None).unwrap())
            .collect();
        for max_batch in [1usize, 2, 4] {
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let opts = ServeOptions { max_batch, ..Default::default() };
                let rep = serve(&m, &pool, reqs(5), &opts).unwrap();
                assert_eq!(rep.requests.len(), 5);
                assert!(rep.peak_active <= max_batch);
                for (r, want) in rep.requests.iter().zip(&solo) {
                    assert_eq!(&r.generated, want, "id={} batch={max_batch} jobs={jobs}", r.id);
                    assert!(!r.deadline_missed);
                    assert!(r.finished_step > r.admitted_step);
                }
                assert_eq!(
                    rep.generated_tokens,
                    solo.iter().map(Vec::len).sum::<usize>(),
                    "batch={max_batch}"
                );
                assert_eq!(rep.kv_bits, 32);
                assert!(rep.kv_peak_pages > 0);
                assert_eq!(rep.kv_resident_bytes, rep.kv_resident_f32_bytes, "f32 ratio is 1");
                assert_eq!(rep.backend, "reference", "default backend in the report");
            }
        }
    }

    #[test]
    fn simd_backend_batch_equals_its_own_solo_decode() {
        // the scheduler must not add divergence on top of the simd
        // backend's: batched output equals per-request solo decode on the
        // same backend, and the report records which backend ran
        let mut m = model();
        m.set_backend(crate::tensor::kernels::Backend::Simd);
        let solo: Vec<Vec<i32>> = reqs(4)
            .into_iter()
            .map(|r| greedy_decode(&m, &r.prompt, r.max_new, None).unwrap())
            .collect();
        for max_batch in [1usize, 3] {
            let pool = Pool::new(2);
            let opts = ServeOptions { max_batch, ..Default::default() };
            let rep = serve(&m, &pool, reqs(4), &opts).unwrap();
            for (r, want) in rep.requests.iter().zip(&solo) {
                assert_eq!(&r.generated, want, "id={} batch={max_batch}", r.id);
            }
            assert_eq!(rep.backend, "simd");
        }
    }

    #[test]
    fn tiny_page_pool_still_completes_all_requests() {
        let m = model();
        let pool = Pool::new(2);
        // pool sized for exactly one worst-case request: sequences must
        // admit one at a time as pages are returned
        let probe = super::PagePool::new(m.cfg.layers, m.cfg.d, 0, 0);
        let pages = probe.pages_for(3 + 8);
        let opts = ServeOptions { max_batch: 4, pages, ..Default::default() };
        let rep = serve(&m, &pool, reqs(4), &opts).unwrap();
        assert_eq!(rep.requests.len(), 4);
        assert_eq!(rep.peak_active, 1, "one reservation at a time");
        let solo = greedy_decode(&m, &[1, 2, 5], 6, None).unwrap();
        assert_eq!(rep.requests[0].generated, solo);
    }

    #[test]
    fn quantized_batch_equals_quantized_solo_and_shrinks_resident_bytes() {
        let m = model();
        for kv in [KvFormat::Linear8, KvFormat::Log2] {
            // the oracle for a lossy format is its own solo decode — the
            // scheduler must not add any divergence of its own
            let solo: Vec<Vec<i32>> = reqs(4)
                .into_iter()
                .map(|r| {
                    crate::serve::model::greedy_decode_kv(&m, &r.prompt, r.max_new, kv, None)
                        .unwrap()
                })
                .collect();
            for max_batch in [1usize, 3] {
                let pool = Pool::new(2);
                let opts = ServeOptions { max_batch, kv, ..Default::default() };
                let rep = serve(&m, &pool, reqs(4), &opts).unwrap();
                for (r, want) in rep.requests.iter().zip(&solo) {
                    assert_eq!(&r.generated, want, "kv={kv:?} id={} batch={max_batch}", r.id);
                }
                assert_eq!(rep.kv_bits, kv.bits());
                assert!(
                    rep.kv_resident_bytes < rep.kv_resident_f32_bytes,
                    "kv={kv:?}: quantized pages must be smaller"
                );
            }
        }
    }

    #[test]
    fn byte_budget_admits_more_sequences_at_lower_kv_bits() {
        let m = model();
        let pool = Pool::new(2);
        // one f32 worst-case reservation is 2 pages x 2048 B = 4096 B, so
        // this budget serializes f32 admissions but fits two 8-bit ones
        let budget = 4096usize;
        let f32_opts =
            ServeOptions { max_batch: 4, pool_bytes: budget, ..Default::default() };
        let f32_rep = serve(&m, &pool, reqs(4), &f32_opts).unwrap();
        assert_eq!(f32_rep.peak_active, 1, "budget admits one f32 sequence at a time");
        let q_opts = ServeOptions {
            max_batch: 4,
            pool_bytes: budget,
            kv: KvFormat::Linear8,
            ..Default::default()
        };
        let q_rep = serve(&m, &pool, reqs(4), &q_opts).unwrap();
        assert!(
            q_rep.peak_active > f32_rep.peak_active,
            "same byte budget must admit more 8-bit sequences ({} vs {})",
            q_rep.peak_active,
            f32_rep.peak_active
        );
        // explicit pages wins over the byte budget
        let probe = super::PagePool::new(m.cfg.layers, m.cfg.d, 0, 0);
        let both = ServeOptions {
            pages: probe.pages_for(3 + 8),
            pool_bytes: 1,
            ..Default::default()
        };
        assert!(serve(&m, &pool, reqs(1), &both).is_ok());
    }

    #[test]
    fn zero_deadline_is_missed_without_generating() {
        let m = model();
        let pool = Pool::new(1);
        let mut r = ServeRequest::new(7, vec![1, 2], 5);
        r.deadline_s = Some(0.0);
        let rep = serve(&m, &pool, vec![r], &ServeOptions::default()).unwrap();
        assert!(rep.requests[0].deadline_missed);
        assert!(rep.requests[0].generated.is_empty());
        assert_eq!(rep.requests[0].ttft_s, None);
    }

    #[test]
    fn invalid_requests_fail_fast() {
        let m = model();
        let pool = Pool::new(1);
        let empty = ServeRequest::new(0, vec![], 4);
        assert!(serve(&m, &pool, vec![empty], &ServeOptions::default()).is_err());
        let oov = ServeRequest::new(1, vec![999], 4);
        let err = serve(&m, &pool, vec![oov], &ServeOptions::default()).unwrap_err().to_string();
        assert!(err.contains("outside vocab"), "{err}");
        let long = ServeRequest::new(2, vec![1; 33], 1);
        assert!(serve(&m, &pool, vec![long], &ServeOptions::default()).is_err());
        let starved = ServeOptions { pages: 1, ..Default::default() };
        let err = serve(&m, &pool, reqs(1), &starved).unwrap_err().to_string();
        assert!(err.contains("page pool"), "{err}");
    }

    #[test]
    fn max_new_zero_retires_immediately() {
        let m = model();
        let pool = Pool::new(1);
        let reqs = vec![ServeRequest::new(0, vec![1, 2, 3], 0)];
        let rep = serve(&m, &pool, reqs, &ServeOptions::default()).unwrap();
        assert!(rep.requests[0].generated.is_empty());
        assert!(!rep.requests[0].deadline_missed);
        assert_eq!(rep.steps, 1, "a zero-token request retires on its first step");
    }
}
