//! Continuous-batching request scheduler (DESIGN.md §11).
//!
//! The serving loop advances every in-flight sequence by **one position
//! per engine step** — a sequence still consuming its prompt and one
//! already generating ride the same step, and no sequence ever computes a
//! padding position (padded-free batching). Sequences are admitted the
//! moment a batch slot *and* their full KV-cache reservation are
//! available, and retired (pages returned to the [`PagePool`]) the moment
//! they finish, so new requests join mid-flight instead of waiting for
//! the whole batch to drain.
//!
//! Per-step work fans out over `util::Pool`, one task per active
//! sequence; sequences are fully independent (own decoder, own KV pages),
//! so the generated tokens are **deterministic** — invariant to `--jobs`,
//! to `max_batch`, and to which other requests happen to be in flight
//! ([`serve`]'s output equals per-request solo [`greedy_decode`];
//! `tests/prop_serve.rs` pins it). Only the wall-clock fields of
//! [`ServeReport`] vary between runs.
//!
//! Deadlines are best-effort admission-relative wall-clock budgets: a
//! sequence past its deadline stops generating at its next step and is
//! retired with `deadline_missed` set, surfaced per request in the
//! report.
//!
//! **Prefix cache (`--prefix-cache`, DESIGN.md §15).** With the cache on,
//! every admission probes a content-addressed [`PrefixCache`] keyed by
//! `(model content key, kv format, page size, prompt-prefix tokens)`. A
//! hit adopts the cached read-only pages ([`PagePool::try_adopt`]) and
//! starts the decoder **past** the adopted span — those prompt positions
//! cost zero prefill forwards. Every request that fully consumes its
//! prompt donates its page-aligned prefix back (in place, no copy), and
//! admission pressure evicts cache entries oldest-first, so the cache can
//! never wedge the scheduler. Adoption changes which physical pages back
//! a sequence, never their decoded bytes, so generated tokens stay
//! identical to the cold path bit for bit.
//!
//! **Speculative self-decoding (`--spec-k` + a draft model, §15).** Past
//! its prompt, a sequence lets a low-bit draft of the same artifact
//! propose `spec_k - 1` tokens, then verifies the whole window in **one**
//! batched target forward ([`Decoder::step_many`]) instead of `spec_k`
//! sequential steps. The accept rule emits target argmaxes while they
//! agree with the draft's proposals and stops at the first disagreement
//! (whose target argmax is the correction), then rewinds both decoders to
//! the canonical consumed length — greedy output is **token-identical**
//! to the non-speculative path by construction, because every verified
//! row is bit-equal to the sequential step's logits. The draft runs in
//! lockstep from its own page pool; acceptance rate is surfaced per
//! request and in aggregate.
//!
//! [`PagePool`]: super::kv::PagePool
//! [`PagePool::try_adopt`]: super::kv::PagePool::try_adopt
//! [`PrefixCache`]: super::prefix::PrefixCache
//! [`Decoder::step_many`]: super::model::Decoder::step_many
//! [`greedy_decode`]: super::model::greedy_decode

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use super::kv::PagePool;
use super::kvq::KvFormat;
use super::model::{Decoder, PackedModel};
use super::prefix::PrefixCache;
use crate::eval::argmax;
use crate::obs::metrics::{self, Hist};
use crate::obs::trace;
use crate::util::json::Json;
use crate::util::Pool;

/// One generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    /// caller-chosen id, echoed in [`RequestStats`]
    pub id: u64,
    pub prompt: Vec<i32>,
    /// tokens to generate (greedy argmax)
    pub max_new: usize,
    /// optional wall-clock budget in seconds, measured from admission
    pub deadline_s: Option<f64>,
}

impl ServeRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> ServeRequest {
        ServeRequest { id, prompt, max_new, deadline_s: None }
    }
}

/// Scheduler knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// sequences decoded concurrently (batch slots)
    pub max_batch: usize,
    /// KV page size in positions (0 = `kv::PAGE_POSITIONS`)
    pub page: usize,
    /// KV page-pool capacity in pages (0 = auto: enough for `max_batch`
    /// worst-case sequences, unless `pool_bytes` sizes it instead)
    pub pages: usize,
    /// KV page-pool capacity as a **byte** budget (0 = off). Converted
    /// to pages at the chosen `kv` format's page size — the admission
    /// accounting where lower `--kv-bits` buys more pages, more
    /// concurrent reservations, and higher peak occupancy under the same
    /// memory budget. Ignored when `pages` is set explicitly.
    pub pool_bytes: usize,
    /// KV storage format (`--kv-bits`; default f32 = the exact path)
    pub kv: KvFormat,
    /// content-addressed prompt-prefix cache (`--prefix-cache`): admit
    /// prefix-hit requests with zero prefill forwards over the hit span
    pub prefix_cache: bool,
    /// speculative window: tokens verified per scheduler step once a
    /// sequence is past its prompt (`--spec-k`; 0 = off). Requires a
    /// draft model ([`serve_with_draft`]).
    pub spec_k: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_batch: 4,
            page: 0,
            pages: 0,
            pool_bytes: 0,
            kv: KvFormat::F32,
            prefix_cache: false,
            spec_k: 0,
        }
    }
}

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestStats {
    pub id: u64,
    pub prompt_len: usize,
    /// greedy-decoded tokens (deterministic; may be short of `max_new`
    /// on a missed deadline or the model's context limit)
    pub generated: Vec<i32>,
    pub deadline_missed: bool,
    /// engine step at which the request entered / left the batch
    pub admitted_step: usize,
    pub finished_step: usize,
    /// admission → first generated token, seconds
    pub ttft_s: Option<f64>,
    /// admission → retire, seconds
    pub wall_s: f64,
    /// prompt positions adopted from the prefix cache (0 = cold)
    pub prefix_adopted: usize,
    /// draft tokens proposed for this request (0 without speculation)
    pub draft_proposed: usize,
    /// proposed tokens the target verified and accepted
    pub draft_accepted: usize,
}

/// Aggregate serving outcome.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// per-request stats, sorted by request id
    pub requests: Vec<RequestStats>,
    /// engine steps executed (each advances every active sequence once)
    pub steps: usize,
    pub peak_active: usize,
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    /// KV storage width served at (`--kv-bits`: 32, 8, or 2)
    pub kv_bits: u32,
    /// most pages simultaneously reserved from the pool
    pub kv_peak_pages: usize,
    /// peak KV bytes resident at `kv_bits` (`kv_peak_pages` × page size)
    pub kv_resident_bytes: usize,
    /// bytes the same peak page count would occupy at f32 — the
    /// denominator of the KV resident-bytes ratio
    pub kv_resident_f32_bytes: usize,
    /// kernel backend the forward passes ran on (`--backend` after
    /// resolution: "reference" or "simd", DESIGN.md §13)
    pub backend: String,
    /// prefix-cache probes at admission (0 with the cache off)
    pub prefix_lookups: usize,
    /// admissions that adopted a cached prefix
    pub prefix_hits: usize,
    /// `prefix_hits / prefix_lookups` (0 when nothing was probed)
    pub prefix_hit_rate: f64,
    /// prompt positions adopted across all requests — prefill forwards
    /// eliminated by the cache
    pub prefill_skipped: usize,
    /// speculative window served with (`--spec-k`; 0 = off)
    pub spec_k: usize,
    /// draft tokens proposed across all requests
    pub draft_proposed: usize,
    /// proposed tokens accepted by the target's verify forwards
    pub draft_accepted: usize,
    /// `draft_accepted / draft_proposed` (0 when nothing was proposed)
    pub draft_accept_rate: f64,
    /// requests retired past their wall-clock budget — the aggregate of
    /// the per-request [`RequestStats::deadline_missed`] flags
    pub deadline_missed: usize,
    /// admission → first-token latency percentiles over every request
    /// that produced a token, seconds (0 when none did)
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    pub ttft_p99_s: f64,
    /// per-token inter-arrival percentiles, seconds: each ≥2-token
    /// request contributes its mean `(wall − ttft) / (generated − 1)`
    /// (0 when no request generated a second token)
    pub itl_p50_s: f64,
    pub itl_p95_s: f64,
    pub itl_p99_s: f64,
}

/// One in-flight sequence.
struct Active<'m> {
    req: ServeRequest,
    decoder: Decoder<'m>,
    /// draft-model decoder in lockstep with `decoder` (speculation only)
    draft: Option<Decoder<'m>>,
    /// speculative window (0 = plain one-token steps)
    spec_k: usize,
    consumed: usize,
    generated: Vec<i32>,
    admitted_at: Instant,
    admitted_step: usize,
    ttft_s: Option<f64>,
    deadline_missed: bool,
    done: bool,
    /// prompt positions adopted from the prefix cache at admission
    adopted: usize,
    /// whether this sequence has donated its prefix to the cache yet
    inserted: bool,
    draft_proposed: usize,
    draft_accepted: usize,
}

impl<'m> Active<'m> {
    /// Advance one scheduler step: consume the next prompt token, or
    /// (once past the prompt) greedily emit — one token per step on the
    /// plain path, up to `spec_k` on the speculative path. Deadline is
    /// checked before spending any compute.
    fn advance(&mut self, pool: Option<&Pool>) {
        if self.done {
            return;
        }
        if let Some(deadline) = self.req.deadline_s {
            if self.admitted_at.elapsed().as_secs_f64() > deadline {
                self.deadline_missed = true;
                self.done = true;
                trace::instant("serve", "serve.deadline_missed");
                metrics::add("serve.deadline_missed", 1);
                return;
            }
        }
        if self.consumed < self.req.prompt.len() {
            // prompt phase; the draft prefills the same token in
            // lockstep so speculation can start the moment the prompt
            // ends. Logits are only needed once this position's output
            // token will actually be kept (last prompt position).
            let tok = self.req.prompt[self.consumed];
            if let Some(d) = self.draft.as_mut() {
                d.prefill(tok, pool);
            }
            let wants_token = self.consumed + 1 >= self.req.prompt.len()
                && self.generated.len() < self.req.max_new;
            if wants_token {
                let logp = self.decoder.step(tok, pool);
                let next = argmax(&logp) as i32;
                self.generated.push(next);
                if self.ttft_s.is_none() {
                    self.ttft_s = Some(self.admitted_at.elapsed().as_secs_f64());
                }
            } else {
                self.decoder.prefill(tok, pool);
            }
            self.consumed += 1;
        } else if self.spec_k > 0 && self.draft.is_some() {
            self.spec_step(pool);
        } else {
            let tok = *self.generated.last().expect("past the prompt, so a token was generated");
            let logp = self.decoder.step(tok, pool);
            self.generated.push(argmax(&logp) as i32);
            self.consumed += 1;
        }
        if self.generated.len() >= self.req.max_new
            || self.decoder.positions() >= self.decoder.capacity()
        {
            self.done = true;
        }
    }

    /// One speculative window: the draft proposes up to `spec_k - 1`
    /// tokens past the pending one, the target verifies the whole window
    /// in one batched forward, and the longest agreeing run is emitted
    /// (the first disagreement's target argmax is the correction). Both
    /// decoders are rewound to the canonical consumed length, so the
    /// emitted tokens equal plain greedy's exactly (module docs).
    fn spec_step(&mut self, pool: Option<&Pool>) {
        let draft = self.draft.as_mut().expect("spec_step requires a draft");
        // lockstep catch-up: after a fully-accepted window the draft sits
        // one canonical token behind the target
        while draft.positions() < self.decoder.positions() {
            let pos = draft.positions();
            let tok = if pos < self.req.prompt.len() {
                self.req.prompt[pos]
            } else {
                self.generated[pos - self.req.prompt.len()]
            };
            draft.prefill(tok, pool);
        }
        let t = self.decoder.positions();
        let remaining = self.req.max_new - self.generated.len();
        let cap = self.decoder.capacity() - t;
        let k = self.spec_k.min(remaining).min(cap).max(1);
        // window = the pending token + the draft's k-1 proposals
        let mut inputs = Vec::with_capacity(k);
        inputs.push(*self.generated.last().expect("generation phase"));
        for i in 1..k {
            let lp = draft.step(inputs[i - 1], pool);
            inputs.push(argmax(&lp) as i32);
        }
        self.draft_proposed += k - 1;
        // one batched verify forward over all k window positions; row i
        // is bit-identical to the i-th sequential step's logits
        let logits = self.decoder.step_many(&inputs, pool);
        let mut emitted = 0usize;
        for i in 0..k {
            let g = argmax(logits.row(i)) as i32;
            self.generated.push(g);
            emitted += 1;
            if i + 1 < k && g != inputs[i + 1] {
                break; // g corrects the rejected proposal
            }
        }
        // every emitted token after the first certified one proposal
        self.draft_accepted += emitted - 1;
        trace::instant_with("serve", "spec.window", || {
            Json::obj().set("proposed", k - 1).set("accepted", emitted - 1)
        });
        metrics::add("spec.proposed", (k - 1) as u64);
        metrics::add("spec.accepted", (emitted - 1) as u64);
        // rewind to the canonical consumed length; rejected positions'
        // KV rows are overwritten by later writes
        self.decoder.truncate(t + emitted);
        let draft = self.draft.as_mut().expect("borrow ended above");
        if draft.positions() > t + emitted {
            draft.truncate(t + emitted);
        }
        self.consumed = t + emitted;
    }

    fn finish(self, finished_step: usize) -> (RequestStats, Decoder<'m>, Option<Decoder<'m>>) {
        let stats = RequestStats {
            id: self.req.id,
            prompt_len: self.req.prompt.len(),
            generated: self.generated,
            deadline_missed: self.deadline_missed,
            admitted_step: self.admitted_step,
            finished_step,
            ttft_s: self.ttft_s,
            wall_s: self.admitted_at.elapsed().as_secs_f64(),
            prefix_adopted: self.adopted,
            draft_proposed: self.draft_proposed,
            draft_accepted: self.draft_accepted,
        };
        (stats, self.decoder, self.draft)
    }
}

/// Run `requests` to completion through the continuous-batching loop.
/// Requests are admitted in the given order (FIFO) as slots and KV pages
/// free up. Plain serving — no draft model; [`ServeOptions::spec_k`]
/// must be 0 (use [`serve_with_draft`] for speculation).
pub fn serve(
    model: &PackedModel,
    pool: &Pool,
    requests: Vec<ServeRequest>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    serve_with_draft(model, None, pool, requests, opts)
}

/// [`serve`] with an optional draft model for speculative self-decoding
/// (`--draft-artifact` + `--spec-k`): a low-bit packing of the same
/// artifact proposes tokens that the serving-width `model` verifies in
/// batched forwards (module docs). The draft is ignored when
/// `opts.spec_k == 0`; `spec_k > 0` without a draft is an error.
pub fn serve_with_draft(
    model: &PackedModel,
    draft: Option<&PackedModel>,
    pool: &Pool,
    requests: Vec<ServeRequest>,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let cfg = &model.cfg;
    if opts.max_batch == 0 {
        bail!("serve needs max_batch >= 1");
    }
    if opts.spec_k > 0 && draft.is_none() {
        bail!("spec_k = {} needs a draft model (--draft-artifact)", opts.spec_k);
    }
    // a draft without a speculative window would only burn prefills
    let draft = if opts.spec_k > 0 { draft } else { None };
    if let Some(d) = draft {
        if d.cfg.vocab != cfg.vocab || d.cfg.max_seq != cfg.max_seq {
            bail!(
                "draft model must share the target's vocab and max_seq \
                 (vocab {} vs {}, max_seq {} vs {})",
                d.cfg.vocab,
                cfg.vocab,
                d.cfg.max_seq,
                cfg.max_seq
            );
        }
    }
    for r in &requests {
        if r.prompt.is_empty() {
            bail!("request {}: empty prompt", r.id);
        }
        if r.prompt.len() > cfg.max_seq {
            bail!(
                "request {}: prompt length {} exceeds max_seq {}",
                r.id,
                r.prompt.len(),
                cfg.max_seq
            );
        }
        if let Some(&t) = r.prompt.iter().find(|&&t| !(0..cfg.vocab as i32).contains(&t)) {
            bail!("request {}: token {t} outside vocab {}", r.id, cfg.vocab);
        }
    }
    // positions a request reserves for its whole lifetime
    let worst = |r: &ServeRequest| (r.prompt.len() + r.max_new).min(cfg.max_seq);
    let probe = PagePool::with_format(opts.kv, cfg.layers, cfg.d, opts.page, 0);
    let max_pages = requests.iter().map(|r| probe.pages_for(worst(r))).max().unwrap_or(0);
    // explicit pages > byte budget > auto; a byte budget buys more pages
    // (= more concurrent admissions) the narrower the KV format is
    let pages = if opts.pages != 0 {
        opts.pages
    } else if opts.pool_bytes != 0 {
        opts.pool_bytes / probe.page_bytes().max(1)
    } else {
        opts.max_batch * max_pages
    };
    if pages < max_pages {
        bail!(
            "page pool of {pages} pages cannot fit the largest request ({max_pages} pages) — \
             raise ServeOptions::pages or pool_bytes"
        );
    }
    let page_pool = PagePool::with_format(opts.kv, cfg.layers, cfg.d, opts.page, pages);
    // the draft decodes from its own pool, auto-sized for max_batch
    // worst-case reservations (the cache-eviction path below keeps it
    // live even when draft prefix entries hold pages)
    let draft_pool = draft.map(|d| {
        let dprobe = PagePool::with_format(opts.kv, d.cfg.layers, d.cfg.d, opts.page, 0);
        let dmax = requests.iter().map(|r| dprobe.pages_for(worst(r))).max().unwrap_or(0);
        PagePool::with_format(opts.kv, d.cfg.layers, d.cfg.d, opts.page, opts.max_batch * dmax)
    });
    let ppos = page_pool.page_positions();
    // content-addressed prefix caches; target and draft pages live in
    // different pools (and differ in content), so they key and evict
    // independently — a hit requires both sides to cover the same span
    let mut tcache =
        opts.prefix_cache.then(|| PrefixCache::new(model.content_key(), opts.kv.bits(), ppos));
    let mut dcache = match (opts.prefix_cache, draft) {
        (true, Some(d)) => Some(PrefixCache::new(d.content_key(), opts.kv.bits(), ppos)),
        _ => None,
    };

    let t0 = Instant::now();
    let mut pending: VecDeque<ServeRequest> = requests.into();
    let mut active: Vec<Mutex<Active>> = Vec::new();
    let mut done: Vec<RequestStats> = Vec::new();
    let mut steps = 0usize;
    let mut peak_active = 0usize;
    let mut kv_peak_pages = 0usize;
    while !pending.is_empty() || !active.is_empty() {
        let _step_sp = trace::span_with("serve", "serve.step", || {
            Json::obj().set("step", steps).set("active", active.len())
        });
        // admit while a slot and a full KV reservation are available;
        // admission pressure evicts prefix-cache entries oldest-first
        // before giving up, so cached pages can never starve admissions
        while active.len() < opts.max_batch {
            let Some(front) = pending.front() else { break };
            let need = worst(front);
            let t_hit = tcache.as_ref().and_then(|c| c.lookup(&front.prompt));
            let d_hit = dcache.as_ref().and_then(|c| c.lookup(&front.prompt));
            let covered = match (dcache.is_some(), &t_hit, &d_hit) {
                (false, Some(t), _) => t.covered,
                (true, Some(t), Some(d)) => t.covered.min(d.covered),
                _ => 0,
            };
            let kv = loop {
                let got = if covered > 0 {
                    let p = t_hit.as_ref().expect("covered > 0").prefix.truncated(covered / ppos);
                    page_pool.try_adopt(need, &p, 0)
                } else {
                    page_pool.try_alloc(need)
                };
                if got.is_some() {
                    break got;
                }
                if !tcache.as_mut().is_some_and(|c| c.evict_oldest(&page_pool)) {
                    break None;
                }
            };
            let Some(kv) = kv else { break };
            let dkv = match (draft, &draft_pool) {
                (Some(_), Some(dp)) => {
                    let got = loop {
                        let got = if covered > 0 {
                            let p = d_hit
                                .as_ref()
                                .expect("covered > 0 implies a draft hit")
                                .prefix
                                .truncated(covered / ppos);
                            dp.try_adopt(need, &p, 0)
                        } else {
                            dp.try_alloc(need)
                        };
                        if got.is_some() {
                            break got;
                        }
                        if !dcache.as_mut().is_some_and(|c| c.evict_oldest(dp)) {
                            break None;
                        }
                    };
                    match got {
                        Some(k) => Some(k),
                        None => {
                            // target pages go back; this admission waits
                            // for a retire to free draft pages
                            page_pool.release(kv);
                            break;
                        }
                    }
                }
                _ => None,
            };
            if let Some(c) = tcache.as_mut() {
                c.record((covered > 0).then_some(covered));
                if covered > 0 {
                    trace::instant_with("serve", "prefix.hit", || {
                        Json::obj().set("covered", covered)
                    });
                    metrics::add("prefix.hits", 1);
                } else {
                    trace::instant("serve", "prefix.miss");
                    metrics::add("prefix.misses", 1);
                }
            }
            let req = pending.pop_front().expect("front() was Some");
            active.push(Mutex::new(Active {
                decoder: Decoder::resume(model, kv, covered),
                draft: draft.map(|d| {
                    Decoder::resume(d, dkv.expect("draft kv reserved above"), covered)
                }),
                spec_k: opts.spec_k,
                consumed: covered,
                generated: Vec::with_capacity(req.max_new),
                admitted_at: Instant::now(),
                admitted_step: steps,
                ttft_s: None,
                deadline_missed: false,
                done: false,
                adopted: covered,
                inserted: false,
                draft_proposed: 0,
                draft_accepted: 0,
                req,
            }));
        }
        peak_active = peak_active.max(active.len());
        kv_peak_pages = kv_peak_pages.max(page_pool.total_pages() - page_pool.free_pages());
        // one scheduler step per active sequence; the pool fans out
        // across sequences — with a single sequence it accelerates the
        // projections inside the step instead
        if active.len() > 1 {
            pool.run(active.len(), |i| active[i].lock().unwrap().advance(None));
        } else if let Some(only) = active.first() {
            only.lock().unwrap().advance(Some(pool));
        }
        steps += 1;
        // donate freshly completed prompt prefixes (in place — the donor
        // keeps reading the same pages), then retire finished sequences
        let mut i = 0;
        while i < active.len() {
            let finished = {
                let a = active[i].get_mut().unwrap();
                if tcache.is_some() && !a.inserted && a.consumed >= a.req.prompt.len() {
                    a.inserted = true;
                    let full = a.req.prompt.len() / ppos;
                    if full >= 1 {
                        if let Some(c) = tcache.as_mut() {
                            c.insert(&a.req.prompt, &a.decoder.share_prefix(full * ppos));
                        }
                        if let (Some(c), Some(d)) = (dcache.as_mut(), a.draft.as_mut()) {
                            c.insert(&a.req.prompt, &d.share_prefix(full * ppos));
                        }
                    }
                }
                a.done
            };
            if finished {
                let a = active.swap_remove(i).into_inner().unwrap();
                let (stats, decoder, dft) = a.finish(steps);
                page_pool.release(decoder.into_kv());
                if let (Some(dp), Some(d)) = (&draft_pool, dft) {
                    dp.release(d.into_kv());
                }
                done.push(stats);
            } else {
                i += 1;
            }
        }
    }
    // drop the caches' page references; with no live sequences every
    // page must come home exactly once (the §15 refcount invariant)
    if let Some(c) = tcache.as_mut() {
        c.drain(&page_pool);
    }
    if let (Some(c), Some(dp)) = (dcache.as_mut(), &draft_pool) {
        c.drain(dp);
    }
    debug_assert_eq!(page_pool.free_pages(), page_pool.total_pages());
    if let Some(dp) = &draft_pool {
        debug_assert_eq!(dp.free_pages(), dp.total_pages());
    }
    done.sort_by_key(|r| r.id);
    let wall_s = t0.elapsed().as_secs_f64();
    let generated_tokens: usize = done.iter().map(|r| r.generated.len()).sum();
    let (lookups, hits, skipped) =
        tcache.as_ref().map_or((0, 0, 0), |c| (c.lookups(), c.hits(), c.hit_positions()));
    let hit_rate = tcache.as_ref().map_or(0.0, |c| c.hit_rate());
    let draft_proposed: usize = done.iter().map(|r| r.draft_proposed).sum();
    let draft_accepted: usize = done.iter().map(|r| r.draft_accepted).sum();
    let deadline_missed = done.iter().filter(|r| r.deadline_missed).count();
    // latency percentiles from the per-request stats, through the log2
    // histogram at µs resolution (DESIGN.md §16)
    let mut ttft_h = Hist::new();
    let mut itl_h = Hist::new();
    for r in &done {
        if let Some(t) = r.ttft_s {
            ttft_h.record((t * 1e6) as u64);
            if r.generated.len() > 1 {
                let per_tok = (r.wall_s - t).max(0.0) / (r.generated.len() - 1) as f64;
                itl_h.record((per_tok * 1e6) as u64);
            }
        }
    }
    let secs = |h: &Hist, p: f64| h.percentile(p) as f64 / 1e6;
    Ok(ServeReport {
        steps,
        peak_active,
        generated_tokens,
        wall_s,
        tokens_per_s: generated_tokens as f64 / wall_s.max(1e-12),
        kv_bits: opts.kv.bits(),
        kv_peak_pages,
        kv_resident_bytes: kv_peak_pages * page_pool.page_bytes(),
        kv_resident_f32_bytes: kv_peak_pages * page_pool.page_bytes_f32(),
        backend: model.backend().name().to_string(),
        prefix_lookups: lookups,
        prefix_hits: hits,
        prefix_hit_rate: hit_rate,
        prefill_skipped: skipped,
        spec_k: opts.spec_k,
        draft_proposed,
        draft_accepted,
        draft_accept_rate: if draft_proposed == 0 {
            0.0
        } else {
            draft_accepted as f64 / draft_proposed as f64
        },
        deadline_missed,
        ttft_p50_s: secs(&ttft_h, 50.0),
        ttft_p95_s: secs(&ttft_h, 95.0),
        ttft_p99_s: secs(&ttft_h, 99.0),
        itl_p50_s: secs(&itl_h, 50.0),
        itl_p95_s: secs(&itl_h, 95.0),
        itl_p99_s: secs(&itl_h, 99.0),
        requests: done,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::ParamSet;
    use crate::serve::model::greedy_decode;
    use crate::serve::PackedModel;

    fn model_bits(bits: u32) -> PackedModel {
        let cfg = ModelConfig {
            name: "serve-batch-test".into(),
            d: 16,
            layers: 2,
            heads: 2,
            ff: 32,
            vocab: 32,
            max_seq: 32,
            batch: 2,
            seq_lens: vec![8, 32],
            ldlq_k: 64,
            ldlq_g: 4,
        };
        PackedModel::from_paramset_rtn(&ParamSet::init(&cfg, 13), bits).unwrap()
    }

    fn model() -> PackedModel {
        model_bits(4)
    }

    fn reqs(n: u64) -> Vec<ServeRequest> {
        (0..n)
            .map(|i| ServeRequest::new(i, vec![(i as i32) % 8 + 1, 2, 5], 6 + (i as usize % 3)))
            .collect()
    }

    #[test]
    fn batched_output_equals_solo_decode() {
        let m = model();
        let solo: Vec<Vec<i32>> = reqs(5)
            .into_iter()
            .map(|r| greedy_decode(&m, &r.prompt, r.max_new, None).unwrap())
            .collect();
        for max_batch in [1usize, 2, 4] {
            for jobs in [1usize, 4] {
                let pool = Pool::new(jobs);
                let opts = ServeOptions { max_batch, ..Default::default() };
                let rep = serve(&m, &pool, reqs(5), &opts).unwrap();
                assert_eq!(rep.requests.len(), 5);
                assert!(rep.peak_active <= max_batch);
                for (r, want) in rep.requests.iter().zip(&solo) {
                    assert_eq!(&r.generated, want, "id={} batch={max_batch} jobs={jobs}", r.id);
                    assert!(!r.deadline_missed);
                    assert!(r.finished_step > r.admitted_step);
                }
                assert_eq!(
                    rep.generated_tokens,
                    solo.iter().map(Vec::len).sum::<usize>(),
                    "batch={max_batch}"
                );
                assert_eq!(rep.kv_bits, 32);
                assert!(rep.kv_peak_pages > 0);
                assert_eq!(rep.kv_resident_bytes, rep.kv_resident_f32_bytes, "f32 ratio is 1");
                assert_eq!(rep.backend, "reference", "default backend in the report");
            }
        }
    }

    #[test]
    fn simd_backend_batch_equals_its_own_solo_decode() {
        // the scheduler must not add divergence on top of the simd
        // backend's: batched output equals per-request solo decode on the
        // same backend, and the report records which backend ran
        let mut m = model();
        m.set_backend(crate::tensor::kernels::Backend::Simd);
        let solo: Vec<Vec<i32>> = reqs(4)
            .into_iter()
            .map(|r| greedy_decode(&m, &r.prompt, r.max_new, None).unwrap())
            .collect();
        for max_batch in [1usize, 3] {
            let pool = Pool::new(2);
            let opts = ServeOptions { max_batch, ..Default::default() };
            let rep = serve(&m, &pool, reqs(4), &opts).unwrap();
            for (r, want) in rep.requests.iter().zip(&solo) {
                assert_eq!(&r.generated, want, "id={} batch={max_batch}", r.id);
            }
            assert_eq!(rep.backend, "simd");
        }
    }

    #[test]
    fn tiny_page_pool_still_completes_all_requests() {
        let m = model();
        let pool = Pool::new(2);
        // pool sized for exactly one worst-case request: sequences must
        // admit one at a time as pages are returned
        let probe = super::PagePool::new(m.cfg.layers, m.cfg.d, 0, 0);
        let pages = probe.pages_for(3 + 8);
        let opts = ServeOptions { max_batch: 4, pages, ..Default::default() };
        let rep = serve(&m, &pool, reqs(4), &opts).unwrap();
        assert_eq!(rep.requests.len(), 4);
        assert_eq!(rep.peak_active, 1, "one reservation at a time");
        let solo = greedy_decode(&m, &[1, 2, 5], 6, None).unwrap();
        assert_eq!(rep.requests[0].generated, solo);
    }

    #[test]
    fn quantized_batch_equals_quantized_solo_and_shrinks_resident_bytes() {
        let m = model();
        for kv in [KvFormat::Linear8, KvFormat::Log2] {
            // the oracle for a lossy format is its own solo decode — the
            // scheduler must not add any divergence of its own
            let solo: Vec<Vec<i32>> = reqs(4)
                .into_iter()
                .map(|r| {
                    crate::serve::model::greedy_decode_kv(&m, &r.prompt, r.max_new, kv, None)
                        .unwrap()
                })
                .collect();
            for max_batch in [1usize, 3] {
                let pool = Pool::new(2);
                let opts = ServeOptions { max_batch, kv, ..Default::default() };
                let rep = serve(&m, &pool, reqs(4), &opts).unwrap();
                for (r, want) in rep.requests.iter().zip(&solo) {
                    assert_eq!(&r.generated, want, "kv={kv:?} id={} batch={max_batch}", r.id);
                }
                assert_eq!(rep.kv_bits, kv.bits());
                assert!(
                    rep.kv_resident_bytes < rep.kv_resident_f32_bytes,
                    "kv={kv:?}: quantized pages must be smaller"
                );
            }
        }
    }

    #[test]
    fn byte_budget_admits_more_sequences_at_lower_kv_bits() {
        let m = model();
        let pool = Pool::new(2);
        // one f32 worst-case reservation is 2 pages x 2048 B = 4096 B, so
        // this budget serializes f32 admissions but fits two 8-bit ones
        let budget = 4096usize;
        let f32_opts =
            ServeOptions { max_batch: 4, pool_bytes: budget, ..Default::default() };
        let f32_rep = serve(&m, &pool, reqs(4), &f32_opts).unwrap();
        assert_eq!(f32_rep.peak_active, 1, "budget admits one f32 sequence at a time");
        let q_opts = ServeOptions {
            max_batch: 4,
            pool_bytes: budget,
            kv: KvFormat::Linear8,
            ..Default::default()
        };
        let q_rep = serve(&m, &pool, reqs(4), &q_opts).unwrap();
        assert!(
            q_rep.peak_active > f32_rep.peak_active,
            "same byte budget must admit more 8-bit sequences ({} vs {})",
            q_rep.peak_active,
            f32_rep.peak_active
        );
        // explicit pages wins over the byte budget
        let probe = super::PagePool::new(m.cfg.layers, m.cfg.d, 0, 0);
        let both = ServeOptions {
            pages: probe.pages_for(3 + 8),
            pool_bytes: 1,
            ..Default::default()
        };
        assert!(serve(&m, &pool, reqs(1), &both).is_ok());
    }

    #[test]
    fn zero_deadline_is_missed_without_generating() {
        let m = model();
        let pool = Pool::new(1);
        let mut r = ServeRequest::new(7, vec![1, 2], 5);
        r.deadline_s = Some(0.0);
        let rep = serve(&m, &pool, vec![r], &ServeOptions::default()).unwrap();
        assert!(rep.requests[0].deadline_missed);
        assert!(rep.requests[0].generated.is_empty());
        assert_eq!(rep.requests[0].ttft_s, None);
        assert_eq!(rep.deadline_missed, 1, "aggregate mirrors the per-request flag");
        assert_eq!(rep.ttft_p99_s, 0.0, "no first token, no TTFT sample");
    }

    #[test]
    fn report_aggregates_deadlines_and_latency_percentiles() {
        let m = model();
        let pool = Pool::new(2);
        let rep = serve(&m, &pool, reqs(5), &ServeOptions::default()).unwrap();
        assert_eq!(rep.deadline_missed, 0);
        // percentile order is a Hist invariant; absolute values are
        // wall-clock and stay unasserted
        assert!(rep.ttft_p50_s <= rep.ttft_p95_s && rep.ttft_p95_s <= rep.ttft_p99_s);
        assert!(rep.itl_p50_s <= rep.itl_p95_s && rep.itl_p95_s <= rep.itl_p99_s);
        assert!(rep.ttft_p50_s >= 0.0 && rep.itl_p50_s >= 0.0);
    }

    #[test]
    fn tracing_on_never_changes_served_tokens() {
        // the §16 binding contract, serve side: enabling the tracer and
        // the metrics registry must not change one generated token, at
        // batch {1, 4} × kv-bits {32, 8}
        let m = model();
        let pool = Pool::new(2);
        let combos =
            [(1usize, KvFormat::F32), (4, KvFormat::F32), (1, KvFormat::Linear8), (4, KvFormat::Linear8)];
        let run = |mb: usize, kv: KvFormat| -> Vec<Vec<i32>> {
            let opts = ServeOptions { max_batch: mb, kv, ..Default::default() };
            let rep = serve(&m, &pool, reqs(4), &opts).unwrap();
            rep.requests.into_iter().map(|r| r.generated).collect()
        };
        let baseline: Vec<_> = combos.iter().map(|&(mb, kv)| run(mb, kv)).collect();
        crate::obs::trace::enable();
        metrics::enable();
        for (&(mb, kv), want) in combos.iter().zip(&baseline) {
            assert_eq!(&run(mb, kv), want, "batch={mb} kv={kv:?}: tracing flipped a token");
        }
    }

    #[test]
    fn invalid_requests_fail_fast() {
        let m = model();
        let pool = Pool::new(1);
        let empty = ServeRequest::new(0, vec![], 4);
        assert!(serve(&m, &pool, vec![empty], &ServeOptions::default()).is_err());
        let oov = ServeRequest::new(1, vec![999], 4);
        let err = serve(&m, &pool, vec![oov], &ServeOptions::default()).unwrap_err().to_string();
        assert!(err.contains("outside vocab"), "{err}");
        let long = ServeRequest::new(2, vec![1; 33], 1);
        assert!(serve(&m, &pool, vec![long], &ServeOptions::default()).is_err());
        let starved = ServeOptions { pages: 1, ..Default::default() };
        let err = serve(&m, &pool, reqs(1), &starved).unwrap_err().to_string();
        assert!(err.contains("page pool"), "{err}");
    }

    #[test]
    fn prefix_hits_skip_prefill_and_keep_tokens_identical() {
        let m = model();
        let pool = Pool::new(2);
        // shared 6-token prompt, page = 4 → hits adopt 4 positions;
        // max_batch 1 so the donor retires before the next admission
        let prompt = vec![1i32, 2, 5, 7, 3, 4];
        let shared: Vec<ServeRequest> =
            (0..3).map(|i| ServeRequest::new(i, prompt.clone(), 5)).collect();
        let base = ServeOptions { max_batch: 1, page: 4, ..Default::default() };
        let cold = serve(&m, &pool, shared.clone(), &base).unwrap();
        assert_eq!((cold.prefix_lookups, cold.prefix_hits, cold.prefill_skipped), (0, 0, 0));
        let warm_opts = ServeOptions { prefix_cache: true, ..base.clone() };
        let warm = serve(&m, &pool, shared, &warm_opts).unwrap();
        for (c, w) in cold.requests.iter().zip(&warm.requests) {
            assert_eq!(c.generated, w.generated, "id={}: warm must equal cold", c.id);
        }
        assert_eq!(warm.prefix_lookups, 3);
        assert_eq!(warm.prefix_hits, 2, "first admission is cold, the rest hit");
        assert_eq!(warm.prefill_skipped, 2 * 4, "one adopted page per hit");
        assert!((warm.prefix_hit_rate - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(warm.requests[0].prefix_adopted, 0);
        assert_eq!(warm.requests[2].prefix_adopted, 4);
        // a diverging prompt misses but still decodes correctly
        let other = vec![ServeRequest::new(9, vec![8, 8, 8, 8, 8, 8], 4)];
        let rep = serve(&m, &pool, other, &warm_opts).unwrap();
        assert_eq!(rep.prefix_hits, 0);
        assert_eq!(rep.requests[0].generated, greedy_decode(&m, &[8; 6], 4, None).unwrap());
    }

    #[test]
    fn prefix_cache_matches_cold_at_quantized_kv_too() {
        let m = model();
        let pool = Pool::new(2);
        let prompt = vec![3i32, 1, 4, 1, 5, 9, 2, 6];
        let reqs: Vec<ServeRequest> =
            (0..3).map(|i| ServeRequest::new(i, prompt.clone(), 6)).collect();
        let base =
            ServeOptions { max_batch: 1, page: 4, kv: KvFormat::Linear8, ..Default::default() };
        let cold = serve(&m, &pool, reqs.clone(), &base).unwrap();
        let warm = serve(&m, &pool, reqs, &ServeOptions { prefix_cache: true, ..base }).unwrap();
        assert!(warm.prefix_hits > 0);
        for (c, w) in cold.requests.iter().zip(&warm.requests) {
            assert_eq!(c.generated, w.generated, "id={}: quantized warm must equal cold", c.id);
        }
    }

    #[test]
    fn speculative_serve_is_token_identical_and_reports_acceptance() {
        let m = model();
        let draft = model_bits(2);
        let pool = Pool::new(2);
        let plain = serve(&m, &pool, reqs(4), &ServeOptions::default()).unwrap();
        for spec_k in [1usize, 2, 4] {
            for max_batch in [1usize, 3] {
                let opts = ServeOptions { spec_k, max_batch, ..Default::default() };
                let rep = serve_with_draft(&m, Some(&draft), &pool, reqs(4), &opts).unwrap();
                for (p, s) in plain.requests.iter().zip(&rep.requests) {
                    assert_eq!(
                        p.generated,
                        s.generated,
                        "id={}: spec_k={spec_k} batch={max_batch} must match plain greedy",
                        p.id
                    );
                }
                assert_eq!(rep.spec_k, spec_k);
                assert!(rep.draft_accepted <= rep.draft_proposed);
                if spec_k >= 2 {
                    assert!(rep.draft_proposed > 0, "spec_k={spec_k} must propose");
                }
                assert!((0.0..=1.0).contains(&rep.draft_accept_rate));
            }
        }
        // self-drafting (draft == target) must accept every proposal —
        // the determinism oracle for the accept rule
        let opts = ServeOptions { spec_k: 4, ..Default::default() };
        let rep = serve_with_draft(&m, Some(&m), &pool, reqs(4), &opts).unwrap();
        assert!(rep.draft_proposed > 0);
        assert_eq!(rep.draft_accepted, rep.draft_proposed, "self-draft accepts everything");
        assert_eq!(rep.draft_accept_rate, 1.0);
        for (p, s) in plain.requests.iter().zip(&rep.requests) {
            assert_eq!(p.generated, s.generated, "id={}", p.id);
        }
    }

    #[test]
    fn prefix_cache_and_speculation_compose() {
        let m = model();
        let draft = model_bits(2);
        let pool = Pool::new(2);
        let prompt = vec![1i32, 2, 5, 7, 3, 4];
        let reqs: Vec<ServeRequest> =
            (0..3).map(|i| ServeRequest::new(i, prompt.clone(), 6)).collect();
        let base = ServeOptions { max_batch: 1, page: 4, ..Default::default() };
        let cold = serve(&m, &pool, reqs.clone(), &base).unwrap();
        let opts = ServeOptions { prefix_cache: true, spec_k: 3, ..base };
        let rep = serve_with_draft(&m, Some(&draft), &pool, reqs, &opts).unwrap();
        for (c, w) in cold.requests.iter().zip(&rep.requests) {
            assert_eq!(c.generated, w.generated, "id={}: both features on must stay exact", c.id);
        }
        assert!(rep.prefix_hits > 0, "draft-side cache must not block target hits");
        assert!(rep.draft_proposed > 0);
    }

    #[test]
    fn cache_pressure_evicts_instead_of_wedging() {
        let m = model();
        let pool = Pool::new(2);
        // pool sized for exactly one worst-case request: the cached
        // prefix must be evicted to admit the diverging third request
        let probe = super::PagePool::new(m.cfg.layers, m.cfg.d, 4, 0);
        let pages = probe.pages_for(6 + 4);
        let shared = vec![1i32, 2, 5, 7, 3, 4];
        let other = vec![8i32, 8, 8, 8, 8, 8];
        let reqs = vec![
            ServeRequest::new(0, shared.clone(), 4),
            ServeRequest::new(1, shared.clone(), 4),
            ServeRequest::new(2, other.clone(), 4),
        ];
        let mut opts = ServeOptions { max_batch: 1, page: 4, pages, ..Default::default() };
        opts.prefix_cache = true;
        let rep = serve(&m, &pool, reqs, &opts).unwrap();
        assert_eq!(rep.requests.len(), 3, "eviction must unblock the cold admission");
        assert_eq!(rep.prefix_hits, 1, "second shared request hits before the eviction");
        assert_eq!(rep.requests[2].generated, greedy_decode(&m, &other, 4, None).unwrap());
    }

    #[test]
    fn spec_k_without_draft_fails_fast() {
        let m = model();
        let pool = Pool::new(1);
        let opts = ServeOptions { spec_k: 2, ..Default::default() };
        let err = serve(&m, &pool, reqs(1), &opts).unwrap_err().to_string();
        assert!(err.contains("draft"), "{err}");
    }

    #[test]
    fn max_new_zero_retires_immediately() {
        let m = model();
        let pool = Pool::new(1);
        let reqs = vec![ServeRequest::new(0, vec![1, 2, 3], 0)];
        let rep = serve(&m, &pool, reqs, &ServeOptions::default()).unwrap();
        assert!(rep.requests[0].generated.is_empty());
        assert!(!rep.requests[0].deadline_missed);
        assert_eq!(rep.steps, 1, "a zero-token request retires on its first step");
    }
}
