//! Content-addressed prefix cache (DESIGN.md §15).
//!
//! Thousands of serving requests share a prompt prefix (a system prompt,
//! a few-shot template); re-running prefill for each one is pure waste —
//! the KV rows a prefix produces are a deterministic function of
//! `(model, kernel backend, kv format, prefix tokens)`. This cache keys
//! frozen prefix pages ([`SharedPrefix`], `serve::kv`) by exactly that
//! determining set, with the same keying discipline as the Hessian cache
//! (`quant::artifact::cache`): two independent FNV-1a 64 streams over a
//! versioned field list → a 128-bit content address. Jobs, batch shape,
//! and scheduling order are deliberately **not** hashed — they cannot
//! change a KV bit (DESIGN.md §11).
//!
//! **Granularity.** Entries live at page-aligned prompt boundaries: a
//! donor whose prompt spans n full pages inserts n entries (1 page, 2
//! pages, …, n pages), all aliasing the same refcounted physical pages.
//! A lookup probes its own prompt's boundaries longest-first and takes
//! the deepest hit, capped at `prompt_len - 1` positions — the last
//! prompt position must still run in the adopter to produce the
//! first-token logits. The stored token slice is compared on every hit
//! (cheap; prompts are short), so even a 128-bit collision cannot serve
//! wrong pages.
//!
//! **Memory.** Cached pages stay charged to the [`PagePool`] they came
//! from. Under admission pressure the scheduler evicts oldest-first
//! ([`PrefixCache::evict_oldest`]): eviction drops the cache's
//! references, and each physical page returns to the pool's free list
//! when its last reference (cache alias or live sequence) is dropped —
//! the per-page refcount rule of `serve::kv`.

use std::collections::{HashMap, VecDeque};

use super::kv::{PagePool, SharedPrefix};
use crate::obs::{metrics, trace};
use crate::util::hash::{Fnv1a64, FNV_BASIS};

/// Bump when the key derivation below changes shape.
const PREFIX_KEY_VERSION: u32 = 1;

/// Second-stream basis — the same derivation as the Hessian cache's
/// `KeyHasher`, giving 128 collision-safe bits from one traversal.
const FNV_BASIS_B: u64 = FNV_BASIS ^ 0x9E37_79B9_7F4A_7C15;

struct Entry {
    /// exact tokens the pages cover — verified on hit (collision guard)
    tokens: Vec<i32>,
    prefix: SharedPrefix,
}

/// Outcome of a [`PrefixCache::lookup`] probe.
pub struct PrefixHit {
    /// frozen pages to adopt via [`PagePool::try_adopt`]
    pub prefix: SharedPrefix,
    /// prompt positions the adopter skips (= prefill forwards eliminated)
    pub covered: usize,
}

/// In-process, content-addressed cache of frozen prompt-prefix KV pages.
/// One cache serves one `(model, backend, kv format, page size)` tuple —
/// the model's 128-bit content key is baked into every entry key, so two
/// caches can never alias each other's pages even if their maps merged.
pub struct PrefixCache {
    model_key: [u8; 16],
    kv_bits: u32,
    page: usize,
    entries: HashMap<[u8; 16], Entry>,
    /// insertion order, oldest first (pressure-eviction order)
    order: VecDeque<[u8; 16]>,
    lookups: usize,
    hits: usize,
    hit_positions: usize,
}

impl PrefixCache {
    /// `model_key` is the serving model's content address
    /// (`PackedModel::content_key` — config + backend + weight bytes);
    /// `kv_bits` and `page` pin the storage format and page geometry the
    /// frozen pages were written at.
    pub fn new(model_key: [u8; 16], kv_bits: u32, page: usize) -> PrefixCache {
        assert!(page > 0, "page size must be positive");
        PrefixCache {
            model_key,
            kv_bits,
            page,
            entries: HashMap::new(),
            order: VecDeque::new(),
            lookups: 0,
            hits: 0,
            hit_positions: 0,
        }
    }

    /// 128-bit keys for every page-aligned prefix of `tokens` up to
    /// `max_pages` boundaries — one incremental pass over the tokens,
    /// snapshotting both FNV streams at each boundary (the same
    /// two-stream discipline as the Hessian cache key).
    fn boundary_keys(&self, tokens: &[i32], max_pages: usize) -> Vec<[u8; 16]> {
        let mut a = Fnv1a64::with_basis(FNV_BASIS);
        let mut b = Fnv1a64::with_basis(FNV_BASIS_B);
        for s in [&mut a, &mut b] {
            s.write_u32(PREFIX_KEY_VERSION);
            s.write(&self.model_key);
            s.write_u32(self.kv_bits);
            s.write_usize(self.page);
        }
        let mut keys = Vec::with_capacity(max_pages);
        for (i, &t) in tokens.iter().take(max_pages * self.page).enumerate() {
            a.write(&t.to_le_bytes());
            b.write(&t.to_le_bytes());
            if (i + 1) % self.page == 0 {
                // finalize a snapshot with the length suffix so the key
                // commits to how many tokens it covers
                let (mut fa, mut fb) = (a.clone(), b.clone());
                fa.write_usize(i + 1);
                fb.write_usize(i + 1);
                let mut key = [0u8; 16];
                key[..8].copy_from_slice(&fa.finish().to_le_bytes());
                key[8..].copy_from_slice(&fb.finish().to_le_bytes());
                keys.push(key);
            }
        }
        keys
    }

    /// Probe for the deepest cached page-aligned prefix of `prompt`,
    /// capped at `prompt.len() - 1` positions (the adopter must run the
    /// last prompt position itself for its first logits). Pure — stats
    /// are recorded by [`PrefixCache::record`] at actual admission, so a
    /// deferred admission retried later is not double-counted.
    pub fn lookup(&self, prompt: &[i32]) -> Option<PrefixHit> {
        let max_pages = prompt.len().saturating_sub(1) / self.page;
        let keys = self.boundary_keys(prompt, max_pages);
        for n in (1..=keys.len()).rev() {
            if let Some(e) = self.entries.get(&keys[n - 1]) {
                if e.tokens == prompt[..n * self.page] {
                    return Some(PrefixHit { prefix: e.prefix.clone(), covered: n * self.page });
                }
            }
        }
        None
    }

    /// Record one admission outcome: `covered` is `Some(positions)` for a
    /// prefix-hit admission, `None` for a cold one.
    pub fn record(&mut self, covered: Option<usize>) {
        self.lookups += 1;
        if let Some(c) = covered {
            self.hits += 1;
            self.hit_positions += c;
        }
    }

    /// Insert entries for every page boundary `prefix` covers, keyed by
    /// the corresponding token prefix of `tokens`. Boundary entries alias
    /// the same refcounted pages ([`SharedPrefix::truncated`]); already
    /// present boundaries are left untouched.
    pub fn insert(&mut self, tokens: &[i32], prefix: &SharedPrefix) {
        let n = prefix.pages_per_layer();
        assert!(tokens.len() >= n * self.page, "token slice shorter than the frozen pages");
        let keys = self.boundary_keys(tokens, n);
        for (b, key) in keys.iter().enumerate() {
            if self.entries.contains_key(key) {
                continue;
            }
            let pages = b + 1;
            self.entries.insert(
                *key,
                Entry {
                    tokens: tokens[..pages * self.page].to_vec(),
                    prefix: prefix.truncated(pages),
                },
            );
            self.order.push_back(*key);
        }
    }

    /// Evict the oldest entry, handing its page references back to
    /// `pool` (pages still shared by live sequences or deeper aliases
    /// return later, when their last reference drops). Returns false
    /// when the cache is already empty.
    pub fn evict_oldest(&mut self, pool: &PagePool) -> bool {
        let Some(key) = self.order.pop_front() else { return false };
        let e = self.entries.remove(&key).expect("order and entries stay in sync");
        trace::instant("serve", "prefix.evict");
        metrics::add("prefix.evictions", 1);
        pool.reclaim(e.prefix);
        true
    }

    /// Evict everything (end-of-serve teardown / tests).
    pub fn drain(&mut self, pool: &PagePool) {
        while self.evict_oldest(pool) {}
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Admissions probed while the cache was on.
    pub fn lookups(&self) -> usize {
        self.lookups
    }

    /// Admissions that adopted a cached prefix.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Total prompt positions adopted — prefill forwards eliminated.
    pub fn hit_positions(&self) -> usize {
        self.hit_positions
    }

    /// `hits / lookups` (0 when nothing was probed).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::kvq::KvFormat;

    /// Donor pool + frozen 2-page prefix over `tokens[..8]` (page = 4).
    fn frozen(pool: &PagePool, tokens: &[i32]) -> SharedPrefix {
        let mut kv = pool.try_alloc(tokens.len()).unwrap();
        for (pos, _) in tokens.iter().enumerate() {
            kv.write(0, pos, &[pos as f32, 0.0], &[0.0, pos as f32]);
        }
        let p = kv.share_prefix(8);
        pool.release(kv);
        p
    }

    #[test]
    fn deepest_boundary_wins_and_stats_track_admissions() {
        let pool = PagePool::new(1, 2, 4, 16);
        let toks: Vec<i32> = (1..=10).collect();
        let prefix = frozen(&pool, &toks);
        let mut cache = PrefixCache::new([7u8; 16], 32, 4);
        assert!(cache.is_empty());
        cache.insert(&toks, &prefix);
        assert_eq!(cache.len(), 2, "one entry per page boundary");
        // same first 8 tokens, different tail → deepest boundary (8)
        let hit = cache.lookup(&[1, 2, 3, 4, 5, 6, 7, 8, 99, 98]).unwrap();
        assert_eq!(hit.covered, 8);
        // 8-token prompt: capped at len-1 = 7 → only the 4-boundary fits
        let hit = cache.lookup(&toks[..8]).unwrap();
        assert_eq!(hit.covered, 4, "last prompt position must stay uncached");
        // diverging within the first page → miss
        assert!(cache.lookup(&[9, 2, 3, 4, 5, 6]).is_none());
        // too short for any boundary → miss
        assert!(cache.lookup(&[1, 2, 3]).is_none());
        cache.record(Some(8));
        cache.record(None);
        assert_eq!((cache.lookups(), cache.hits(), cache.hit_positions()), (2, 1, 8));
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
        cache.drain(&pool);
        pool.reclaim(prefix);
        assert_eq!(pool.free_pages(), pool.total_pages());
    }

    #[test]
    fn key_is_sensitive_to_model_format_and_page() {
        let pool = PagePool::new(1, 2, 4, 16);
        let toks: Vec<i32> = (1..=9).collect();
        let prefix = frozen(&pool, &toks);
        let mut base = PrefixCache::new([1u8; 16], 32, 4);
        base.insert(&toks, &prefix);
        assert!(base.lookup(&toks).is_some());
        // a different model key, kv width, or page size must never hit
        for mut other in [PrefixCache::new([2u8; 16], 32, 4), PrefixCache::new([1u8; 16], 8, 4)] {
            other.insert(&toks, &prefix);
            let k_base = base.boundary_keys(&toks, 2);
            let k_other = other.boundary_keys(&toks, 2);
            assert_ne!(k_base, k_other, "keys must differ across caches");
            other.drain(&pool);
        }
        base.drain(&pool);
        pool.reclaim(prefix);
        assert_eq!(pool.free_pages(), pool.total_pages());
    }

    #[test]
    fn eviction_is_oldest_first_and_returns_pages() {
        let pool = PagePool::with_format(KvFormat::F32, 1, 2, 4, 16);
        let t1: Vec<i32> = (1..=9).collect();
        let t2: Vec<i32> = (21..=29).collect();
        let p1 = frozen(&pool, &t1);
        let p2 = frozen(&pool, &t2);
        let mut cache = PrefixCache::new([3u8; 16], 32, 4);
        cache.insert(&t1, &p1);
        cache.insert(&t2, &p2);
        drop(p1);
        drop(p2);
        let free_before = pool.free_pages();
        assert!(cache.evict_oldest(&pool), "evicts t1's 1-page boundary");
        assert!(pool.free_pages() >= free_before, "never loses pages");
        // t1's 2-page boundary still aliases page 0, so lookups still hit
        assert!(cache.lookup(&t1).is_some() || cache.lookup(&t2).is_some());
        cache.drain(&pool);
        assert!(!cache.evict_oldest(&pool), "empty cache has nothing to evict");
        assert_eq!(pool.free_pages(), pool.total_pages(), "all pages home after drain");
    }
}
