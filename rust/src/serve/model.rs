//! Host decode forward pass over a packed artifact (DESIGN.md §11).
//!
//! The first forward-pass implementation outside XLA: [`PackedModel`]
//! holds layer weights **in the storage domain** ([`PackedRows`] for
//! `packedN` artifact blobs, f32 for `raw` ones) and projects through the
//! fused dequantize kernels (`tensor::kernels::gemv`), so serving memory
//! tracks the artifact's packed size, not the f32 model
//! ([`PackedModel::resident_bytes`]).
//!
//! Two entry points compute the same function:
//!
//! - [`Decoder::step`] — one token against the paged KV cache
//!   (`serve::kv`): O(t·d) attention per step, the serving path;
//! - [`PackedModel::logits_full`] — the full-context matrix recompute
//!   (masked softmax over the whole [T, T] score matrix), mirroring the
//!   lowered `logits_last_t*` modules position by position.
//!
//! **Determinism.** Both paths share every per-row scalar helper
//! (`rmsnorm_gain`, `attn_row`, `swiglu_row`, `log_softmax_in_place`) and
//! their projections run the same k-ascending, zero-skipping dot products
//! (`deq_gemm_bt`/`gemm_bt`), so KV-cache decode is **bit-identical** to
//! the full-context recompute at every position — a masked score
//! contributes an exact `+0.0` to the softmax denominator and is skipped
//! in the value reduction, exactly like the §10 zero-skip contract.
//! `tests/prop_serve.rs` asserts greedy token-identity and exact logit
//! equality; `tests/integration_serve.rs` pins greedy token-identity
//! against the XLA engine's full-context recompute.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::kv::{SeqKv, SharedPrefix};
use super::kvq::{KvFormat, RowSource};
use crate::eval::argmax;
use crate::model::config::ModelConfig;
use crate::model::ParamSet;
use crate::obs::trace;
use crate::quant::artifact::{self, ArtifactManifest, Blob};
use crate::quantref;
use crate::runtime::manifest::config_to_kv;
use crate::tensor::kernels::Backend;
use crate::tensor::pack::{PackedRows, RowGrid, PACK_BITS};
use crate::tensor::Tensor;
use crate::util::hash::{Fnv1a64, FNV_BASIS};
use crate::util::json::Json;
use crate::util::Pool;

/// RMSNorm epsilon — must match python/compile/model.py.
const EPS: f32 = 1e-6;

/// One projection weight in its storage domain.
pub enum HostWeight {
    /// bit-packed codes + per-row grid, dequantized on the fly
    Packed(PackedRows),
    /// plain f32 (raw artifact blobs, VQ fallbacks, checkpoints)
    Dense(Tensor),
}

impl HostWeight {
    pub fn is_packed(&self) -> bool {
        matches!(self, HostWeight::Packed(_))
    }

    pub fn out_dim(&self) -> usize {
        match self {
            HostWeight::Packed(p) => p.rows,
            HostWeight::Dense(t) => t.rows(),
        }
    }

    pub fn in_dim(&self) -> usize {
        match self {
            HostWeight::Packed(p) => p.cols,
            HostWeight::Dense(t) => t.cols(),
        }
    }

    /// `y = a · Wᵀ` on the given kernel backend — fused dequantization
    /// when packed; on `Backend::Reference` the element-wise operation
    /// sequence is identical either way (DESIGN.md §11, §13).
    pub fn matmul_bt(&self, a: &Tensor, pool: Option<&Pool>, backend: Backend) -> Tensor {
        match self {
            HostWeight::Packed(p) => backend.deq_gemm_bt(a, p, pool),
            HostWeight::Dense(w) => backend.gemm_bt(a, w, pool),
        }
    }

    /// `y = a · Wᵀ` with every output row **bit-identical** to the
    /// single-row [`HostWeight::matvec`] path on the same backend — the
    /// projection the speculative verify forward ([`Decoder::step_many`])
    /// uses so its logits match sequential [`Decoder::step`] calls
    /// exactly. Dispatches to the batched kernel when the backend is
    /// row-exact ([`Backend::fused_rows_exact`]); otherwise (AVX2 simd,
    /// whose batched kernels reduce column-major) it loops the GEMV
    /// kernel per row — same results, less decode amortization.
    pub fn matmul_bt_rowexact(&self, a: &Tensor, pool: Option<&Pool>, backend: Backend) -> Tensor {
        if backend.fused_rows_exact() {
            return self.matmul_bt(a, pool, backend);
        }
        let mut out = Tensor::zeros(&[a.rows(), self.out_dim()]);
        for i in 0..a.rows() {
            out.row_mut(i).copy_from_slice(&self.matvec(a.row(i), pool, backend));
        }
        out
    }

    /// Single-row `y = x · Wᵀ` (the per-token decode path).
    pub fn matvec(&self, x: &[f32], pool: Option<&Pool>, backend: Backend) -> Vec<f32> {
        match self {
            HostWeight::Packed(p) => backend.deq_gemv(x, p, pool),
            HostWeight::Dense(w) => {
                backend.gemm_bt(&Tensor::from_vec(&[1, x.len()], x.to_vec()), w, pool).data
            }
        }
    }

    /// Bytes this weight keeps resident at serve time.
    pub fn resident_bytes(&self) -> usize {
        match self {
            // codes + per-row (scale, zero) f32 pair
            HostWeight::Packed(p) => p.data.len() + 8 * p.rows,
            HostWeight::Dense(t) => 4 * t.numel(),
        }
    }

    /// Bytes the dequantized f32 equivalent would keep resident.
    pub fn dense_bytes(&self) -> usize {
        4 * self.out_dim() * self.in_dim()
    }

    /// Feed this weight's full storage-domain identity into a key stream
    /// (see [`PackedModel::content_key`]).
    fn hash_into(&self, h: &mut Fnv1a64) {
        match self {
            HostWeight::Packed(p) => {
                h.write_str("packed");
                h.write_u32(p.bits);
                h.write_usize(p.rows);
                h.write_usize(p.cols);
                h.write_f32s(&p.grid.scale);
                h.write_f32s(&p.grid.zero);
                h.write_usize(p.data.len());
                h.write(&p.data);
            }
            HostWeight::Dense(t) => {
                h.write_str("dense");
                h.write_usize(t.rows());
                h.write_usize(t.cols());
                h.write_f32s(&t.data);
            }
        }
    }
}

/// One transformer layer's serving weights (gains stay f32 vectors).
struct HostLayer {
    g1: Vec<f32>,
    wq: HostWeight,
    wk: HostWeight,
    wv: HostWeight,
    wo: HostWeight,
    g2: Vec<f32>,
    wup: HostWeight,
    wgate: HostWeight,
    wdown: HostWeight,
}

/// A model loaded for serving: packed layer weights + f32 tables, plus
/// the kernel backend every forward pass dispatches through (`--backend`,
/// DESIGN.md §13). Defaults to the bit-exact `Backend::Reference`.
pub struct PackedModel {
    pub cfg: ModelConfig,
    emb: Tensor,
    pos: Tensor,
    layers: Vec<HostLayer>,
    gf: Vec<f32>,
    head: HostWeight,
    backend: Backend,
}

fn gain(blob: Blob, name: &str, d: usize) -> Result<Vec<f32>> {
    match blob {
        Blob::Raw(t) if t.shape == vec![d] => Ok(t.data),
        Blob::Raw(t) => bail!("tensor {name}: expected gain shape [{d}], got {:?}", t.shape),
        Blob::Packed(_) => bail!("tensor {name}: gain unexpectedly bit-packed"),
    }
}

fn weight(blob: Blob) -> HostWeight {
    match blob {
        Blob::Raw(t) => HostWeight::Dense(t),
        Blob::Packed(p) => HostWeight::Packed(p),
    }
}

fn raw(blob: Blob, name: &str) -> Result<Tensor> {
    match blob {
        Blob::Raw(t) => Ok(t),
        Blob::Packed(_) => bail!("tensor {name}: table unexpectedly bit-packed"),
    }
}

impl PackedModel {
    /// Load an artifact directory for serving, keeping packed weights
    /// packed (`artifact::load_packed`).
    pub fn load(dir: &Path) -> Result<(PackedModel, ArtifactManifest)> {
        let (blobs, manifest) = artifact::load_packed(dir)?;
        let model = PackedModel::from_blobs(manifest.config.clone(), blobs)
            .with_context(|| format!("assemble serving model from artifact {dir:?}"))?;
        Ok((model, manifest))
    }

    /// Assemble from artifact blobs in `param_names()` order.
    pub fn from_blobs(cfg: ModelConfig, blobs: Vec<Blob>) -> Result<PackedModel> {
        let names = cfg.param_names();
        if blobs.len() != names.len() {
            bail!("artifact has {} tensors, config expects {}", blobs.len(), names.len());
        }
        let mut it = blobs.into_iter().zip(names);
        let mut next = || it.next().expect("length checked above");
        let (emb, _) = next();
        let emb = raw(emb, "emb")?;
        let (pos, _) = next();
        let pos = raw(pos, "pos")?;
        let mut layers = Vec::with_capacity(cfg.layers);
        for _ in 0..cfg.layers {
            let (g1b, g1n) = next();
            let g1 = gain(g1b, &g1n, cfg.d)?;
            let wq = weight(next().0);
            let wk = weight(next().0);
            let wv = weight(next().0);
            let wo = weight(next().0);
            let (g2b, g2n) = next();
            let g2 = gain(g2b, &g2n, cfg.d)?;
            let wup = weight(next().0);
            let wgate = weight(next().0);
            let wdown = weight(next().0);
            layers.push(HostLayer { g1, wq, wk, wv, wo, g2, wup, wgate, wdown });
        }
        let (gfb, gfn) = next();
        let gf = gain(gfb, &gfn, cfg.d)?;
        let head = weight(next().0);
        Ok(PackedModel { cfg, emb, pos, layers, gf, head, backend: Backend::Reference })
    }

    /// Host-side RTN quantize-and-pack of a full-precision `ParamSet` at
    /// `bits` — the artifact-free fixture path for benches, `rsq perf`,
    /// and tests (mirrors the pipeline's grid: `quantref::row_grid` on
    /// the pre-quant weight). Weights that fail the exact-pack check fall
    /// back to dense, like `artifact::save`.
    pub fn from_paramset_rtn(p: &ParamSet, bits: u32) -> Result<PackedModel> {
        if !PACK_BITS.contains(&bits) {
            bail!("unsupported pack width {bits} (supported: {PACK_BITS:?})");
        }
        let maxq = ((1u64 << bits) - 1) as f32;
        let pack = |w: &Tensor| -> HostWeight {
            let q = quantref::rtn(w, maxq);
            let (scale, zero) = quantref::row_grid(w, maxq);
            match PackedRows::pack(&q, bits, &RowGrid { scale, zero }) {
                Ok(pk) => HostWeight::Packed(pk),
                Err(_) => HostWeight::Dense(q),
            }
        };
        Self::assemble(p, pack)
    }

    /// Serve a full-precision checkpoint as-is (the `rsq generate
    /// --model` path): every weight dense, nothing quantized.
    pub fn from_paramset_dense(p: &ParamSet) -> Result<PackedModel> {
        Self::assemble(p, |w| HostWeight::Dense(w.clone()))
    }

    fn assemble(p: &ParamSet, mut wrap: impl FnMut(&Tensor) -> HostWeight) -> Result<PackedModel> {
        let cfg = p.cfg.clone();
        let t = |i: usize| p.tensors[i].clone();
        let g = |i: usize| -> Result<Vec<f32>> {
            if p.tensors[i].shape != vec![cfg.d] {
                bail!("tensor {i}: expected gain shape [{}]", cfg.d);
            }
            Ok(p.tensors[i].data.clone())
        };
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let base = 2 + l * 9;
            layers.push(HostLayer {
                g1: g(base)?,
                wq: wrap(&p.tensors[base + 1]),
                wk: wrap(&p.tensors[base + 2]),
                wv: wrap(&p.tensors[base + 3]),
                wo: wrap(&p.tensors[base + 4]),
                g2: g(base + 5)?,
                wup: wrap(&p.tensors[base + 6]),
                wgate: wrap(&p.tensors[base + 7]),
                wdown: wrap(&p.tensors[base + 8]),
            });
        }
        let n = p.tensors.len();
        Ok(PackedModel {
            emb: t(0),
            pos: t(1),
            layers,
            gf: g(n - 2)?,
            head: wrap(&p.tensors[n - 1]),
            cfg,
            backend: Backend::Reference,
        })
    }

    /// Select the kernel backend all subsequent forward passes dispatch
    /// through (`--backend`, DESIGN.md §13). `Backend::Reference` (the
    /// default) is bit-identical to the historical path; `Backend::Simd`
    /// is tolerance-pinned against it and falls back to scalar code on
    /// hosts without AVX2+FMA.
    pub fn set_backend(&mut self, backend: Backend) {
        self.backend = backend;
    }

    /// The kernel backend forward passes currently run on.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// How many projection weights are actually bit-packed.
    pub fn packed_weights(&self) -> usize {
        let mut n = 0;
        for l in &self.layers {
            for w in [&l.wq, &l.wk, &l.wv, &l.wo, &l.wup, &l.wgate, &l.wdown] {
                n += w.is_packed() as usize;
            }
        }
        n + self.head.is_packed() as usize
    }

    /// (packed-domain resident bytes, f32-equivalent resident bytes) over
    /// every tensor the server keeps in memory — the deployment memory
    /// win `bench_serve`/`rsq serve-bench` report.
    pub fn resident_bytes(&self) -> (usize, usize) {
        let tables = 4 * (self.emb.numel() + self.pos.numel() + self.gf.len());
        let (mut packed, mut dense) = (tables, tables);
        let mut weights: Vec<&HostWeight> = vec![&self.head];
        for l in &self.layers {
            let gains = 4 * (l.g1.len() + l.g2.len());
            packed += gains;
            dense += gains;
            weights.extend([&l.wq, &l.wk, &l.wv, &l.wo, &l.wup, &l.wgate, &l.wdown]);
        }
        for w in weights {
            packed += w.resident_bytes();
            dense += w.dense_bytes();
        }
        (packed, dense)
    }

    /// 128-bit content address of everything that determines this
    /// model's forward-pass outputs: config, resolved kernel backend
    /// (AVX reductions are tolerance-pinned, not bit-equal, so KV bytes
    /// differ across backends), and every tensor's storage-domain bytes.
    /// Two loads of the same artifact on the same backend share a key;
    /// any weight, bit-width, or backend difference separates them. This
    /// is the `hash(artifact id, …)` component of the prefix-cache key
    /// (`serve::prefix`, DESIGN.md §15), derived with the Hessian cache's
    /// dual-stream FNV discipline (`quant::artifact::cache`).
    pub fn content_key(&self) -> [u8; 16] {
        let mut a = Fnv1a64::with_basis(FNV_BASIS);
        let mut b = Fnv1a64::with_basis(FNV_BASIS ^ 0x9E37_79B9_7F4A_7C15);
        for h in [&mut a, &mut b] {
            // the field list IS the key contract — bump the version when
            // it changes shape
            h.write_u32(1);
            h.write_str(self.backend.name());
            h.write_str(&config_to_kv(&self.cfg));
            h.write_f32s(&self.emb.data);
            h.write_f32s(&self.pos.data);
            for l in &self.layers {
                h.write_f32s(&l.g1);
                h.write_f32s(&l.g2);
                for w in [&l.wq, &l.wk, &l.wv, &l.wo, &l.wup, &l.wgate, &l.wdown] {
                    w.hash_into(&mut *h);
                }
            }
            h.write_f32s(&self.gf);
            self.head.hash_into(&mut *h);
        }
        let mut key = [0u8; 16];
        key[..8].copy_from_slice(&a.finish().to_le_bytes());
        key[8..].copy_from_slice(&b.finish().to_le_bytes());
        key
    }

    /// Embedding row for `token` at absolute position `pos`.
    fn embed_row(&self, token: i32, pos: usize) -> Vec<f32> {
        assert!(
            (0..self.cfg.vocab as i32).contains(&token),
            "token {token} outside vocab {}",
            self.cfg.vocab
        );
        assert!(pos < self.cfg.max_seq, "position {pos} past max_seq {}", self.cfg.max_seq);
        self.emb
            .row(token as usize)
            .iter()
            .zip(self.pos.row(pos))
            .map(|(e, p)| e + p)
            .collect()
    }

    /// Full-context recompute: next-token log-probabilities at **every**
    /// position of `tokens` (`[T, vocab]`), through the same fused
    /// kernels and per-row helpers as [`Decoder::step`]. Row `i` depends
    /// only on tokens `0..=i` (causal mask), so it equals a fresh
    /// prefix-only forward — the reference the KV-cache path is tested
    /// against.
    pub fn logits_full(&self, tokens: &[i32], pool: Option<&Pool>) -> Tensor {
        let tn = tokens.len();
        assert!(tn >= 1, "logits_full needs at least one token");
        assert!(tn <= self.cfg.max_seq, "context {tn} past max_seq {}", self.cfg.max_seq);
        let cfg = &self.cfg;
        let (d, heads, hd) = (cfg.d, cfg.heads, cfg.head_dim());
        let mut z = Tensor::zeros(&[tn, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            z.row_mut(i).copy_from_slice(&self.embed_row(tok, i));
        }
        let rows = |src: &Tensor, g: &[f32]| -> Tensor {
            let mut out = Tensor::zeros(&[tn, src.cols()]);
            for i in 0..tn {
                out.row_mut(i).copy_from_slice(&rmsnorm_gain(src.row(i), g));
            }
            out
        };
        let be = self.backend;
        for layer in &self.layers {
            let xa = rows(&z, &layer.g1);
            let q = layer.wq.matmul_bt(&xa, pool, be);
            let k = layer.wk.matmul_bt(&xa, pool, be);
            let v = layer.wv.matmul_bt(&xa, pool, be);
            let mut xo = Tensor::zeros(&[tn, d]);
            for i in 0..tn {
                let kr = TensorRows(&k);
                let vr = TensorRows(&v);
                let row = attn_row(q.row(i), heads, hd, (i, tn), &kr, &vr, be);
                xo.row_mut(i).copy_from_slice(&row);
            }
            z.add_in_place(&layer.wo.matmul_bt(&xo, pool, be));
            let xf = rows(&z, &layer.g2);
            let gate = layer.wgate.matmul_bt(&xf, pool, be);
            let up = layer.wup.matmul_bt(&xf, pool, be);
            let mut xd = Tensor::zeros(&[tn, cfg.ff]);
            for i in 0..tn {
                xd.row_mut(i).copy_from_slice(&swiglu_row(gate.row(i), up.row(i)));
            }
            z.add_in_place(&layer.wdown.matmul_bt(&xd, pool, be));
        }
        let h = rows(&z, &self.gf);
        let mut logits = self.head.matmul_bt(&h, pool, be);
        for i in 0..tn {
            log_softmax_in_place(logits.row_mut(i));
        }
        logits
    }
}

/// `x · rsqrt(mean(x²) + EPS) · g` — shared by both forward paths.
fn rmsnorm_gain(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    let r = 1.0 / (ss / x.len() as f32 + EPS).sqrt();
    x.iter().zip(g).map(|(v, gv)| v * r * gv).collect()
}

/// `silu(gate) · up` per element (`silu(x) = x · sigmoid(x)`).
fn swiglu_row(gate: &[f32], up: &[f32]) -> Vec<f32> {
    gate.iter()
        .zip(up)
        .map(|(&gv, &uv)| {
            let sig = 1.0 / (1.0 + (-gv).exp());
            gv * sig * uv
        })
        .collect()
}

/// In-place log-softmax over one logits row.
fn log_softmax_in_place(row: &mut [f32]) {
    let mut maxv = f32::NEG_INFINITY;
    for &v in row.iter() {
        maxv = maxv.max(v);
    }
    let mut denom = 0.0f32;
    for &v in row.iter() {
        denom += (v - maxv).exp();
    }
    let lse = denom.ln();
    for v in row.iter_mut() {
        *v = *v - maxv - lse;
    }
}

/// [`RowSource`] view over a `[T, d]` activation tensor (the
/// full-context recompute's materialized k/v projections): rows are
/// resident f32, so reads never touch the scratch.
struct TensorRows<'t>(&'t Tensor);

impl RowSource for TensorRows<'_> {
    fn row<'a>(&'a self, s: usize, _scratch: &'a mut [f32]) -> &'a [f32] {
        self.0.row(s)
    }
}

/// One position's multi-head causal attention output.
///
/// Scores run over `total_t` positions with everything past `causal_t`
/// masked to `f32::MIN` (the lowered modules' mask constant); the max
/// fold, the exp/denominator accumulation (s ascending), and the
/// zero-skipped value reduction are the **single** implementation both
/// the KV-cache decode (`total_t == causal_t + 1`, no masked tail) and
/// the full-context recompute execute — a masked score's exp is an exact
/// `+0.0`, which cannot move the denominator and is skipped in the value
/// sum, so the two paths are bit-identical (module docs).
///
/// Rows come through [`RowSource`], which is where the quantized KV
/// decode fuses in (DESIGN.md §12): position loops run s-outer so each
/// stored row is decoded into the L1 scratch **once** per call — the
/// same shape as `gemv.rs`'s tiled weight decode — and the f32 source
/// returns its resident slice untouched. Per output element the
/// accumulation order (k-ascending dots, s-ascending max/denominator/
/// value sums) is exactly the pre-§12 per-head loop's, which is what
/// keeps `--kv-bits 32` bit-identical to the PR 5 path
/// (`tests/prop_serve.rs` pins it).
///
/// `t` is `(causal_t, total_t)`. The q·k dots and the p·v AXPYs run on
/// `backend` ([`Backend::dot`]/[`Backend::axpy`]): `Reference` is exactly
/// the historical inlined loops, `Simd` vectorizes them under the §13
/// tolerance contract (the `p == 0.0` skip stays caller-side, so the
/// zero-skip contract is backend-independent here).
fn attn_row<K: RowSource, V: RowSource>(
    q: &[f32],
    heads: usize,
    hd: usize,
    t: (usize, usize),
    k_rows: &K,
    v_rows: &V,
    backend: Backend,
) -> Vec<f32> {
    let (causal_t, total_t) = t;
    let d = heads * hd;
    let mut out = vec![0.0f32; d];
    let mut scratch = vec![0.0f32; d];
    // scores[m * total_t + s]: per-head rows, s contiguous
    let mut scores = vec![0.0f32; heads * total_t];
    for s in 0..total_t {
        if s > causal_t {
            for m in 0..heads {
                scores[m * total_t + s] = f32::MIN;
            }
            continue;
        }
        let krow = k_rows.row(s, &mut scratch);
        for m in 0..heads {
            let qh = &q[m * hd..(m + 1) * hd];
            let kh = &krow[m * hd..(m + 1) * hd];
            let dot = backend.dot(qh, kh);
            scores[m * total_t + s] = dot / (hd as f32).sqrt();
        }
    }
    let mut denoms = vec![0.0f32; heads];
    for m in 0..heads {
        let sc = &mut scores[m * total_t..(m + 1) * total_t];
        let mut maxv = f32::NEG_INFINITY;
        for &v in sc.iter() {
            maxv = maxv.max(v);
        }
        let mut denom = 0.0f32;
        for v in sc.iter_mut() {
            *v = (*v - maxv).exp();
            denom += *v;
        }
        denoms[m] = denom;
    }
    for s in 0..=causal_t.min(total_t - 1) {
        let vrow = v_rows.row(s, &mut scratch);
        for m in 0..heads {
            let p = scores[m * total_t + s] / denoms[m];
            if p == 0.0 {
                continue;
            }
            let oh = &mut out[m * hd..(m + 1) * hd];
            let vh = &vrow[m * hd..(m + 1) * hd];
            backend.axpy(p, vh, oh);
        }
    }
    out
}

/// Autoregressive decode state over one sequence's KV cache.
pub struct Decoder<'m> {
    model: &'m PackedModel,
    kv: SeqKv,
    t: usize,
}

impl<'m> Decoder<'m> {
    pub fn new(model: &'m PackedModel, kv: SeqKv) -> Decoder<'m> {
        assert_eq!(kv.num_layers(), model.cfg.layers, "kv cache layer count");
        assert_eq!(kv.d(), model.cfg.d, "kv cache model dim");
        Decoder { model, kv, t: 0 }
    }

    /// [`Decoder::new`] over a cache whose first `positions` rows are
    /// already written — the prefix-cache adoption path (`serve::prefix`):
    /// the decoder starts past the adopted prefix and never re-runs its
    /// prefill forwards. The caller guarantees the rows really are the
    /// ones this model + backend + KV format would have written (the
    /// content key pins that).
    pub fn resume(model: &'m PackedModel, kv: SeqKv, positions: usize) -> Decoder<'m> {
        let mut dec = Decoder::new(model, kv);
        assert!(positions <= dec.capacity(), "resume past capacity {}", dec.capacity());
        dec.t = positions;
        dec
    }

    /// Rewind to `positions` consumed — the speculative-reject path:
    /// positions past the accepted run are simply re-written by later
    /// steps (KV writes are overwrite-safe; `serve::kv` module docs).
    /// Never rewind into an adopted shared prefix without COW spares —
    /// the scheduler only speculates past the prompt, which adoption
    /// covers page-aligned, so its rewinds always land in owned pages.
    pub fn truncate(&mut self, positions: usize) {
        assert!(positions <= self.t, "truncate only rewinds ({positions} > {})", self.t);
        self.t = positions;
    }

    /// Positions consumed so far.
    pub fn positions(&self) -> usize {
        self.t
    }

    /// Positions this decoder can consume (KV capacity ∧ `max_seq`).
    pub fn capacity(&self) -> usize {
        self.kv.capacity().min(self.model.cfg.max_seq)
    }

    /// Consume `token` at the next position and return the next-token
    /// log-probabilities — O(t) attention against the KV cache instead of
    /// a full-context recompute.
    pub fn step(&mut self, token: i32, pool: Option<&Pool>) -> Vec<f32> {
        let pos = self.t;
        let _sp = trace::span_with("serve", "serve.decode", || Json::obj().set("pos", pos));
        self.advance_pos(token, pool, true).expect("logits requested")
    }

    /// Consume `token` without producing logits: fills the KV cache but
    /// skips the final norm, the head projection (the model's largest
    /// GEMV), and the log-softmax. Prompt positions whose logits would be
    /// discarded go through here — KV state is identical to [`step`]'s,
    /// so the decode stays deterministic.
    ///
    /// [`step`]: Decoder::step
    pub fn prefill(&mut self, token: i32, pool: Option<&Pool>) {
        let pos = self.t;
        let _sp = trace::span_with("serve", "serve.prefill", || Json::obj().set("pos", pos));
        let _ = self.advance_pos(token, pool, false);
    }

    fn advance_pos(
        &mut self,
        token: i32,
        pool: Option<&Pool>,
        want_logits: bool,
    ) -> Option<Vec<f32>> {
        let t = self.t;
        assert!(t < self.capacity(), "decode past capacity {}", self.capacity());
        let model = self.model;
        let cfg = &model.cfg;
        let (heads, hd) = (cfg.heads, cfg.head_dim());
        let be = model.backend;
        let mut z = model.embed_row(token, t);
        for (l, layer) in model.layers.iter().enumerate() {
            let xa = rmsnorm_gain(&z, &layer.g1);
            let q = layer.wq.matvec(&xa, pool, be);
            let k = layer.wk.matvec(&xa, pool, be);
            let v = layer.wv.matvec(&xa, pool, be);
            self.kv.write(l, t, &k, &v);
            let (kr, vr) = (self.kv.k_rows(l), self.kv.v_rows(l));
            let xo = attn_row(&q, heads, hd, (t, t + 1), &kr, &vr, be);
            for (zv, ov) in z.iter_mut().zip(layer.wo.matvec(&xo, pool, be)) {
                *zv += ov;
            }
            let xf = rmsnorm_gain(&z, &layer.g2);
            let gate = layer.wgate.matvec(&xf, pool, be);
            let up = layer.wup.matvec(&xf, pool, be);
            let xd = swiglu_row(&gate, &up);
            for (zv, dv) in z.iter_mut().zip(layer.wdown.matvec(&xd, pool, be)) {
                *zv += dv;
            }
        }
        self.t = t + 1;
        if !want_logits {
            return None;
        }
        let h = rmsnorm_gain(&z, &model.gf);
        let mut logits = model.head.matvec(&h, pool, be);
        log_softmax_in_place(&mut logits);
        Some(logits)
    }

    /// Consume `tokens` at the next `tokens.len()` positions in **one**
    /// batched forward and return their next-token log-probabilities
    /// (`[tokens.len(), vocab]`) — the speculative verify pass: the
    /// target model scores every draft candidate in a single sweep
    /// instead of `k` sequential steps, amortizing each layer's weight
    /// decode across the candidate rows.
    ///
    /// Row `i` is **bit-identical** to what the `i`-th sequential
    /// [`Decoder::step`] call would return: every projection goes through
    /// [`HostWeight::matmul_bt_rowexact`] (per-row bit-equal to the
    /// matvec path on every backend), the per-row helpers are the shared
    /// ones, and attention at position `t+i` reads exactly rows
    /// `0..=t+i` — later candidates' KV rows are already written but
    /// masked out by `total_t`, contributing nothing. That identity is
    /// what makes greedy speculative decoding token-identical to plain
    /// greedy by construction (DESIGN.md §15); `step_many` vs sequential
    /// steps is pinned bitwise in this module's tests.
    ///
    /// On reject, [`Decoder::truncate`] rewinds past the unaccepted
    /// positions; their stale KV rows are overwritten by later writes.
    pub fn step_many(&mut self, tokens: &[i32], pool: Option<&Pool>) -> Tensor {
        let n = tokens.len();
        assert!(n >= 1, "step_many needs at least one token");
        let t0 = self.t;
        assert!(t0 + n <= self.capacity(), "decode past capacity {}", self.capacity());
        if n == 1 {
            let lp = self.step(tokens[0], pool);
            return Tensor::from_vec(&[1, lp.len()], lp);
        }
        let _sp = trace::span_with("serve", "serve.verify", || {
            Json::obj().set("pos", t0).set("n", n)
        });
        let model = self.model;
        let cfg = &model.cfg;
        let (d, heads, hd) = (cfg.d, cfg.heads, cfg.head_dim());
        let be = model.backend;
        let mut z = Tensor::zeros(&[n, d]);
        for (i, &tok) in tokens.iter().enumerate() {
            z.row_mut(i).copy_from_slice(&model.embed_row(tok, t0 + i));
        }
        let rows = |src: &Tensor, g: &[f32]| -> Tensor {
            let mut out = Tensor::zeros(&[n, src.cols()]);
            for i in 0..n {
                out.row_mut(i).copy_from_slice(&rmsnorm_gain(src.row(i), g));
            }
            out
        };
        for (l, layer) in model.layers.iter().enumerate() {
            let xa = rows(&z, &layer.g1);
            let q = layer.wq.matmul_bt_rowexact(&xa, pool, be);
            let kp = layer.wk.matmul_bt_rowexact(&xa, pool, be);
            let vp = layer.wv.matmul_bt_rowexact(&xa, pool, be);
            for i in 0..n {
                self.kv.write(l, t0 + i, kp.row(i), vp.row(i));
            }
            let mut xo = Tensor::zeros(&[n, d]);
            for i in 0..n {
                let (kr, vr) = (self.kv.k_rows(l), self.kv.v_rows(l));
                let row = attn_row(q.row(i), heads, hd, (t0 + i, t0 + i + 1), &kr, &vr, be);
                xo.row_mut(i).copy_from_slice(&row);
            }
            z.add_in_place(&layer.wo.matmul_bt_rowexact(&xo, pool, be));
            let xf = rows(&z, &layer.g2);
            let gate = layer.wgate.matmul_bt_rowexact(&xf, pool, be);
            let up = layer.wup.matmul_bt_rowexact(&xf, pool, be);
            let mut xd = Tensor::zeros(&[n, cfg.ff]);
            for i in 0..n {
                xd.row_mut(i).copy_from_slice(&swiglu_row(gate.row(i), up.row(i)));
            }
            z.add_in_place(&layer.wdown.matmul_bt_rowexact(&xd, pool, be));
        }
        self.t = t0 + n;
        let h = rows(&z, &model.gf);
        let mut logits = model.head.matmul_bt_rowexact(&h, pool, be);
        for i in 0..n {
            log_softmax_in_place(logits.row_mut(i));
        }
        logits
    }

    /// Freeze the first `positions` consumed positions into a refcounted
    /// [`SharedPrefix`] (the prefix-cache donation; `SeqKv::share_prefix`
    /// owns the page mechanics). Only already-consumed positions may be
    /// shared — their KV rows are fully written.
    pub fn share_prefix(&mut self, positions: usize) -> SharedPrefix {
        assert!(
            positions <= self.t,
            "can only share consumed positions ({positions} > {})",
            self.t
        );
        self.kv.share_prefix(positions)
    }

    /// Hand the KV cache back (the batch scheduler returns it to the
    /// page pool on retire).
    pub fn into_kv(self) -> SeqKv {
        self.kv
    }
}

/// Greedy decode helper: consume `prompt`, then generate up to `max_new`
/// tokens by argmax, stopping early at the model's context limit.
/// Returns the generated tokens only. Uses the exact f32 KV cache — the
/// divergence oracle for every lossy `--kv-bits` path.
pub fn greedy_decode(
    model: &PackedModel,
    prompt: &[i32],
    max_new: usize,
    pool: Option<&Pool>,
) -> Result<Vec<i32>> {
    greedy_decode_kv(model, prompt, max_new, KvFormat::F32, pool)
}

/// [`greedy_decode`] with an explicit KV storage format (`--kv-bits`):
/// `KvFormat::F32` is byte-for-byte the exact path; lossy formats
/// quantize each position's k/v rows on write and decode them inside
/// `attn_row`'s scratch on read.
pub fn greedy_decode_kv(
    model: &PackedModel,
    prompt: &[i32],
    max_new: usize,
    fmt: KvFormat,
    pool: Option<&Pool>,
) -> Result<Vec<i32>> {
    if prompt.is_empty() {
        bail!("empty prompt — greedy decode needs at least one token");
    }
    let cfg = &model.cfg;
    if prompt.len() > cfg.max_seq {
        bail!("prompt length {} exceeds max_seq {}", prompt.len(), cfg.max_seq);
    }
    let total = (prompt.len() + max_new).min(cfg.max_seq);
    let kv = SeqKv::standalone_fmt(fmt, cfg.layers, cfg.d, total);
    let mut dec = Decoder::new(model, kv);
    // only the last prompt position's logits are used — earlier ones
    // prefill the KV cache without paying the head projection
    for &tok in &prompt[..prompt.len() - 1] {
        dec.prefill(tok, pool);
    }
    let mut logp = dec.step(prompt[prompt.len() - 1], pool);
    let mut out = Vec::with_capacity(max_new);
    while out.len() < max_new {
        let next = argmax(&logp) as i32;
        out.push(next);
        if out.len() == max_new || dec.positions() >= dec.capacity() {
            break;
        }
        logp = dec.step(next, pool);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "serve-test".into(),
            d: 16,
            layers: 2,
            heads: 2,
            ff: 32,
            vocab: 32,
            max_seq: 24,
            batch: 2,
            seq_lens: vec![8, 24],
            ldlq_k: 64,
            ldlq_g: 4,
        }
    }

    #[test]
    fn decode_matches_full_context_bitwise() {
        let p = ParamSet::init(&cfg(), 11);
        let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        assert_eq!(model.packed_weights(), 2 * 7 + 1);
        let prompt = [3i32, 1, 4, 1, 5];
        let gen = greedy_decode(&model, &prompt, 10, None).unwrap();
        assert_eq!(gen.len(), 10);
        let mut seq = prompt.to_vec();
        seq.extend_from_slice(&gen);
        let full = model.logits_full(&seq, None);
        // every decode step's argmax equals the full-context recompute's
        // at the same position — and the last row is bit-identical
        for (i, &tok) in gen.iter().enumerate() {
            let row = full.row(prompt.len() + i - 1);
            assert_eq!(argmax(row) as i32, tok, "step {i}");
        }
        let kv = SeqKv::standalone(model.cfg.layers, model.cfg.d, seq.len());
        let mut dec = Decoder::new(&model, kv);
        let mut last = Vec::new();
        for &tok in &seq {
            last = dec.step(tok, None);
        }
        for (a, b) in last.iter().zip(full.row(seq.len() - 1)) {
            assert_eq!(a.to_bits(), b.to_bits(), "final log-probs must be bit-identical");
        }
    }

    #[test]
    fn resident_bytes_shrink_with_bits() {
        let p = ParamSet::init(&cfg(), 3);
        let (d8, dense8) = PackedModel::from_paramset_rtn(&p, 8).unwrap().resident_bytes();
        let (d2, dense2) = PackedModel::from_paramset_rtn(&p, 2).unwrap().resident_bytes();
        assert_eq!(dense8, dense2, "f32 equivalent is bits-independent");
        assert!(d2 < d8, "2-bit model must be smaller than 8-bit ({d2} vs {d8})");
        assert!(d8 < dense8, "packed must beat f32");
        let dense = PackedModel::from_paramset_dense(&p).unwrap();
        assert_eq!(dense.packed_weights(), 0);
        assert_eq!(dense.resident_bytes().0, dense.resident_bytes().1);
    }

    #[test]
    fn dense_and_packed_paths_agree_at_8_bits_tokens() {
        // 8-bit RTN is near-lossless; dense-serving the *same* quantized
        // tensors must produce identical greedy tokens (packed vs dense
        // dispatch is a storage difference, not a math difference)
        let p = ParamSet::init(&cfg(), 5);
        let packed = PackedModel::from_paramset_rtn(&p, 8).unwrap();
        // dense model over the dequantized weights
        let mut q = p.clone();
        for l in 0..q.cfg.layers {
            for m in crate::model::config::Module::ALL {
                let w = q.weight(l, m).clone();
                q.set_weight(l, m, quantref::rtn(&w, 255.0));
            }
        }
        let n = q.tensors.len();
        q.tensors[n - 1] = quantref::rtn(&q.tensors[n - 1], 255.0);
        let dense = PackedModel::from_paramset_dense(&q).unwrap();
        let prompt = [7i32, 2, 9];
        let a = greedy_decode(&packed, &prompt, 8, None).unwrap();
        let b = greedy_decode(&dense, &prompt, 8, None).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn jobs_do_not_change_tokens() {
        let p = ParamSet::init(&cfg(), 9);
        let model = PackedModel::from_paramset_rtn(&p, 3).unwrap();
        let prompt = [1i32, 2, 3];
        let serial = greedy_decode(&model, &prompt, 12, None).unwrap();
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            assert_eq!(greedy_decode(&model, &prompt, 12, Some(&pool)).unwrap(), serial);
        }
    }

    #[test]
    fn simd_backend_decode_is_deterministic_and_close_to_reference() {
        // Backend::Simd resolves to scalar fallbacks off-AVX2, so this
        // runs everywhere; on AVX2 hosts it pins the §13 contracts on the
        // serve path: logits within tolerance of reference, greedy tokens
        // jobs-invariant, and every KV format still deterministic.
        let p = ParamSet::init(&cfg(), 11);
        let mut model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        let prompt = [3i32, 1, 4, 1, 5];
        let reference = model.logits_full(&prompt, None);
        assert_eq!(model.backend(), Backend::Reference, "default backend");
        model.set_backend(Backend::Simd);
        let simd = model.logits_full(&prompt, None);
        for (a, b) in reference.data.iter().zip(&simd.data) {
            let tol = 1e-3f32.max(a.abs() * 1e-3);
            assert!((a - b).abs() <= tol, "logit drift {a} vs {b}");
        }
        let serial = greedy_decode(&model, &prompt, 8, None).unwrap();
        for jobs in [1usize, 4] {
            let pool = Pool::new(jobs);
            let got = greedy_decode(&model, &prompt, 8, Some(&pool)).unwrap();
            assert_eq!(got, serial, "simd decode must be jobs-invariant");
        }
        for fmt in [KvFormat::F32, KvFormat::Linear8, KvFormat::Log2] {
            let a = greedy_decode_kv(&model, &prompt, 8, fmt, None).unwrap();
            let b = greedy_decode_kv(&model, &prompt, 8, fmt, None).unwrap();
            assert_eq!(a, b, "{fmt:?}: simd decode must be deterministic");
        }
    }

    #[test]
    fn kv_formats_decode_deterministically_and_f32_wrapper_is_exact() {
        let p = ParamSet::init(&cfg(), 11);
        let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        let prompt = [3i32, 1, 4];
        let oracle = greedy_decode(&model, &prompt, 8, None).unwrap();
        assert_eq!(
            greedy_decode_kv(&model, &prompt, 8, KvFormat::F32, None).unwrap(),
            oracle,
            "greedy_decode must be exactly the F32-format decode"
        );
        for fmt in [KvFormat::Linear8, KvFormat::Log2] {
            let a = greedy_decode_kv(&model, &prompt, 8, fmt, None).unwrap();
            assert_eq!(a.len(), 8, "{fmt:?}");
            let b = greedy_decode_kv(&model, &prompt, 8, fmt, None).unwrap();
            assert_eq!(a, b, "{fmt:?}: lossy decode must still be deterministic");
        }
    }

    #[test]
    fn step_many_is_bitwise_identical_to_sequential_steps() {
        // the speculative verify forward must reproduce the sequential
        // decode exactly, on the row-exact path AND the simd fallback
        let p = ParamSet::init(&cfg(), 7);
        for backend in [Backend::Reference, Backend::Simd] {
            let mut model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
            model.set_backend(backend);
            let toks = [3i32, 1, 4, 1, 5, 9, 2, 6];
            let kv = SeqKv::standalone(model.cfg.layers, model.cfg.d, 16);
            let mut seq = Decoder::new(&model, kv);
            let rows: Vec<Vec<f32>> = toks.iter().map(|&tk| seq.step(tk, None)).collect();
            let kv = SeqKv::standalone(model.cfg.layers, model.cfg.d, 16);
            let mut dec = Decoder::new(&model, kv);
            for &tk in &toks[..3] {
                dec.prefill(tk, None);
            }
            let many = dec.step_many(&toks[3..], None);
            assert_eq!(dec.positions(), toks.len());
            assert_eq!(many.shape, vec![toks.len() - 3, model.cfg.vocab]);
            for i in 0..toks.len() - 3 {
                for (a, b) in many.row(i).iter().zip(&rows[3 + i]) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{backend:?} row {i}");
                }
            }
        }
    }

    #[test]
    fn truncate_rewinds_and_overwrites_cleanly() {
        // speculative reject: rewind past unaccepted positions, then
        // decode a different continuation — must match a fresh decode of
        // the same accepted sequence bit-for-bit (stale KV rows of the
        // rejected candidates are simply overwritten)
        let p = ParamSet::init(&cfg(), 13);
        let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        for fmt in [KvFormat::F32, KvFormat::Linear8] {
            let kv = SeqKv::standalone_fmt(fmt, model.cfg.layers, model.cfg.d, 16);
            let mut dec = Decoder::new(&model, kv);
            for tk in [1i32, 2, 3] {
                dec.prefill(tk, None);
            }
            let _ = dec.step_many(&[7, 8, 9], None);
            dec.truncate(4); // keep 1,2,3,7 — reject 8,9
            let got = dec.step(5, None);
            let kv = SeqKv::standalone_fmt(fmt, model.cfg.layers, model.cfg.d, 16);
            let mut fresh = Decoder::new(&model, kv);
            for tk in [1i32, 2, 3, 7] {
                fresh.prefill(tk, None);
            }
            let want = fresh.step(5, None);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?}");
            }
        }
    }

    #[test]
    fn resume_over_adopted_prefix_matches_cold_decode_bitwise() {
        use crate::serve::kv::PagePool;
        let p = ParamSet::init(&cfg(), 17);
        let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        let prompt = [3i32, 1, 4, 1, 5, 9];
        for fmt in [KvFormat::F32, KvFormat::Linear8] {
            let pool = PagePool::with_format(fmt, model.cfg.layers, model.cfg.d, 4, 16);
            // donor: cold decode of the prompt, freeze the first page
            let mut donor = Decoder::new(&model, pool.try_alloc(8).unwrap());
            for &tk in &prompt {
                donor.prefill(tk, None);
            }
            let mut donor_kv = donor.into_kv();
            let prefix = donor_kv.share_prefix(4);
            // adopter: resume past the adopted page, run only the tail
            let kv = pool.try_adopt(8, &prefix, 0).unwrap();
            let mut warm = Decoder::resume(&model, kv, 4);
            assert_eq!(warm.positions(), 4);
            warm.prefill(prompt[4], None);
            let got = warm.step(prompt[5], None);
            // cold reference over the full prompt
            let mut cold = Decoder::new(&model, pool.try_alloc(8).unwrap());
            for &tk in &prompt[..5] {
                cold.prefill(tk, None);
            }
            let want = cold.step(prompt[5], None);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?}: warm must equal cold");
            }
            pool.release(donor_kv);
            pool.release(warm.into_kv());
            pool.release(cold.into_kv());
            pool.reclaim(prefix);
            assert_eq!(pool.free_pages(), pool.total_pages());
        }
    }

    #[test]
    fn content_key_separates_everything_that_changes_outputs() {
        let p = ParamSet::init(&cfg(), 3);
        let m4 = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        assert_eq!(
            m4.content_key(),
            PackedModel::from_paramset_rtn(&p, 4).unwrap().content_key(),
            "same weights, same backend → same key"
        );
        let m2 = PackedModel::from_paramset_rtn(&p, 2).unwrap();
        assert_ne!(m4.content_key(), m2.content_key(), "bit width changes the key");
        let dense = PackedModel::from_paramset_dense(&p).unwrap();
        assert_ne!(m4.content_key(), dense.content_key(), "storage domain changes the key");
        let mut simd = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        simd.set_backend(Backend::Simd);
        assert_ne!(m4.content_key(), simd.content_key(), "kernel backend changes the key");
        let other = ParamSet::init(&cfg(), 4);
        let mo = PackedModel::from_paramset_rtn(&other, 4).unwrap();
        assert_ne!(m4.content_key(), mo.content_key(), "weights change the key");
    }

    #[test]
    fn decode_stops_at_context_limit() {
        let p = ParamSet::init(&cfg(), 2);
        let model = PackedModel::from_paramset_rtn(&p, 4).unwrap();
        // max_seq = 24: 20 generated tokens are fed back in (positions
        // 4..24), plus one final token off the last position's logits
        let gen = greedy_decode(&model, &[1, 2, 3, 4], 100, None).unwrap();
        assert_eq!(gen.len(), 24 - 4 + 1, "truncated at max_seq");
    }
}
