//! `rsq` — the L3 coordinator CLI.
//!
//! Subcommands map one-to-one to the paper's experiments (DESIGN.md §4):
//!   rsq table1..table7      regenerate paper tables
//!   rsq fig2..fig9          regenerate paper figures
//!   rsq scores              dump Figs. 10-14 score series
//!   rsq quantize            one-off quantization run
//!   rsq train               train a checkpoint
//!   rsq perf                performance profile (DESIGN.md §Perf)
//!   rsq all                 every table + figure at default scale
//!
//! `--jobs N|auto` selects the quantization scheduler's worker count and
//! `--sched staged|pipelined` its cross-layer phase ordering (DESIGN.md
//! §Threading); output is bit-identical for every combination.

use std::path::Path;

use anyhow::{bail, Result};

use rsq::corpus::CorpusKind;
use rsq::eval::{perplexity, score_model};
use rsq::quant::{artifact, quantize, Method, QuantOptions, SchedMode, Strategy};
use rsq::repro::{self, Ctx};
use rsq::train::{train, TrainOptions};
use rsq::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "table1" => repro::tables::table1(&args)?,
        "table2" => repro::tables::table2(&args)?,
        "table3" => repro::tables::table3(&args)?,
        "table4" => repro::tables::table4(&args)?,
        "table5" => repro::tables::table5(&args)?,
        "table6" => repro::tables::table6(&args)?,
        "table7" => repro::tables::table7(&args)?,
        "fig2" => repro::figs::fig2(&args)?,
        "fig3" => repro::figs::fig3(&args)?,
        "fig4" => repro::figs::fig4(&args)?,
        "fig5" | "fig6" => repro::figs::fig5(&args)?,
        "fig7" => repro::figs::fig7(&args)?,
        "fig8" => repro::figs::fig8(&args)?,
        "fig9" => repro::figs::fig9(&args)?,
        "scores" => repro::scores::dump_scores(&args)?,
        "perf" => repro::perf::perf(&args)?,
        "quantize" => cmd_quantize(&args)?,
        "eval" => cmd_eval(&args)?,
        "train" => cmd_train(&args)?,
        "all" => cmd_all(&args)?,
        "help" | "--help" | "-h" => print_help(),
        other => bail!("unknown command {other:?} — try `rsq help`"),
    }
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    // fail fast on a bad --save target BEFORE training/calibration start:
    // a typo'd path must not cost a full quantization run to discover
    if let Some(out) = args.get("save") {
        artifact::validate_save_dir(Path::new(out))?;
    }
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let cfg = ctx.engine.config().clone();
    let t = args.usize_or("calib-t", repro::default_context(&cfg));
    let method = Method::parse(&args.str_or("method", "rsq"))
        .ok_or_else(|| anyhow::anyhow!("bad --method"))?;
    let strategy = Strategy::parse(&args.str_or("strategy", "attncon:0.01"))
        .ok_or_else(|| anyhow::anyhow!("bad --strategy"))?;
    let mut opts = QuantOptions::new(method, args.usize_or("bits", 3) as u32, t);
    opts.strategy = strategy;
    opts.expansion = args.usize_or("expansion", 1);
    opts.damp = args.f32_or("damp", opts.damp);
    opts.rot_seed = args.u64_or("rot-seed", opts.rot_seed);
    opts.jobs = args.jobs();
    opts.sched = SchedMode::parse(&args.sched())
        .ok_or_else(|| anyhow::anyhow!("bad --sched (staged|pipelined)"))?;
    opts.hess_cache = args.hess_cache();
    opts.verbose = args.flag("verbose");
    let corpus = CorpusKind::parse(&args.str_or("corpus", "wiki"))
        .ok_or_else(|| anyhow::anyhow!("bad --corpus"))?;
    let calib = ctx.calib(corpus, args.usize_or("calib-n", 16), t, args.u64_or("seed", 0));

    let full_ppl = perplexity(&ctx.engine, &ctx.params, &ctx.eval, t)?;
    let (q, report) = quantize(&ctx.engine, &ctx.params, &calib, &opts)?;
    let score = score_model(&ctx.engine, &q, &ctx.eval, t, args.usize_or("probe-n", 32))?;
    println!("config       : {config} ({} params)", cfg.num_params());
    println!("method       : {} / {} / {}bit", method.name(), opts.strategy.name(), opts.bits);
    println!("full  PPL    : {full_ppl:.3}");
    println!("quant PPL    : {:.3}", score.ppl);
    println!("avg accuracy : {:.1}%", 100.0 * score.mean_acc);
    println!("kurtosis     : {:.2} -> {:.2}", report.kurtosis_before, report.kurtosis_after);
    println!("layer errs   : {:?}", report.layer_err);
    println!(
        "wall         : {:.2}s over {} batches (jobs={} sched={}; rotate {:.2}s, \
         pass A {:.2}s, solve {:.2}s, pass B {:.2}s, fused {:.2}s)",
        report.wall_seconds,
        report.batches,
        report.jobs,
        report.sched,
        report.rotate_seconds,
        report.pass_a_seconds,
        report.solve_seconds,
        report.pass_b_seconds,
        report.fused_seconds
    );
    if !report.hess_key.is_empty() {
        println!(
            "hess cache   : {} (layers hit {} / miss {} / skip {}; key {})",
            if report.hess_cache_hits > 0 { "HIT — pass A skipped" } else { "cold" },
            report.hess_cache_hits,
            report.hess_cache_misses,
            report.hess_cache_skips,
            report.hess_key,
        );
    }
    if let Some(out) = args.get("save") {
        let manifest = artifact::save(Path::new(out), &q, &report, &opts)?;
        let packed = manifest
            .tensors
            .iter()
            .filter(|t| !matches!(t.codec, artifact::Codec::Raw))
            .count();
        println!(
            "saved artifact to {out} ({} tensors, {packed} bit-packed, {} blob bytes) — \
             score it with `rsq eval --artifact {out}`",
            manifest.tensors.len(),
            manifest.total_len,
        );
    }
    Ok(())
}

/// `rsq eval` — score a saved quantized artifact (`--artifact DIR`) or a
/// raw checkpoint (`--model PATH`) without re-running quantization. The
/// artifact path reproduces the in-memory pipeline's numbers bit-for-bit
/// (rust/tests/integration_artifact.rs pins this).
fn cmd_eval(args: &Args) -> Result<()> {
    if let Err(e) = args.conflict("artifact", "model") {
        bail!("{e}");
    }
    // default_t mirrors the context the quantize-time printout scored at:
    // the artifact's recorded seq_len when loading an artifact, else
    // cmd_quantize's own default
    let (params, engine, default_t) = if let Some(dir) = args.get("artifact") {
        let (p, manifest) = artifact::load(Path::new(dir))?;
        let engine = rsq::runtime::Engine::load(&manifest.config.name)?;
        if engine.config() != &manifest.config {
            bail!(
                "artifact {dir} was saved for config {:?} but the compiled artifacts for \
                 {:?} differ — re-run `make artifacts` or re-save the artifact",
                manifest.config.name,
                engine.config().name,
            );
        }
        println!(
            "artifact     : {dir} ({} / {} / {}bit, hess key {})",
            manifest.method, manifest.strategy, manifest.bits, manifest.hess_key
        );
        let t = manifest.seq_len;
        (p, engine, t)
    } else if let Some(path) = args.get("model") {
        let config = args.str_or("config", "small");
        let engine = rsq::runtime::Engine::load(&config)?;
        let p = rsq::model::ParamSet::load(engine.config(), Path::new(path))?;
        println!("checkpoint   : {path} (config {config})");
        let t = repro::default_context(engine.config());
        (p, engine, t)
    } else {
        bail!("rsq eval needs --artifact DIR (packed artifact) or --model PATH (checkpoint)");
    };
    let cfg = engine.config().clone();
    let t = args.usize_or("eval-t", default_t);
    if !cfg.seq_lens.contains(&t) {
        bail!("--eval-t {t} not in artifact set {:?}", cfg.seq_lens);
    }
    // the one shared held-out recipe, so scores line up with the
    // quantize-time printout
    let eval = repro::heldout_eval_set(&cfg, args);
    let score = score_model(&engine, &params, &eval, t, args.usize_or("probe-n", 32))?;
    println!("PPL          : {:.3} (context {t})", score.ppl);
    println!("avg accuracy : {:.1}%", 100.0 * score.mean_acc);
    for p in &score.probes {
        println!("  {:<18} {:>5.1}%", p.name, 100.0 * p.accuracy);
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.str_or("config", "small");
    let engine = rsq::runtime::Engine::load(&config)?;
    let mut p = rsq::model::ParamSet::init(engine.config(), args.u64_or("train-seed", 7));
    let report = train(
        &engine,
        &mut p,
        &TrainOptions {
            steps: args.usize_or("steps", repro::default_steps(&config)),
            corpus: CorpusKind::parse(&args.str_or("corpus", "wiki")).unwrap(),
            seed: args.u64_or("train-seed", 7),
            log_every: args.usize_or("log-every", 20),
            verbose: true,
        },
    )?;
    println!("final loss {:.4} after {:.1}s", report.final_loss, report.wall_seconds);
    if let Some(out) = args.get("save") {
        p.save(std::path::Path::new(out))?;
        println!("saved checkpoint to {out}");
    }
    Ok(())
}

fn cmd_all(_args: &Args) -> Result<()> {
    // Each driver runs in its own subprocess: the prebuilt xla_extension
    // 0.5.1 leaks ~output-size heap per PJRT execute (upstream C bug — the
    // rust wrappers free everything they own), so a single long-lived
    // process accumulates GBs across tens of thousands of executions.
    // Process isolation bounds it per driver. See DESIGN.md §Perf.
    let exe = std::env::current_exe()?;
    let fwd: Vec<String> = std::env::args().skip(2).collect();
    for cmd in [
        "table1", "table2", "table3", "table4", "table5", "table6", "table7",
        "fig2", "fig3", "fig4", "fig5", "fig7", "fig8", "fig9", "scores",
    ] {
        eprintln!("[all] running {cmd} ...");
        let status = std::process::Command::new(&exe).arg(cmd).args(&fwd).status()?;
        if !status.success() {
            bail!("driver {cmd} failed with {status}");
        }
    }
    Ok(())
}

fn print_help() {
    println!(
        "rsq — RSQ (Rotate, Scale, then Quantize) reproduction\n\
         \n\
         usage: rsq <command> [flags]\n\
         \n\
         commands:\n\
           table1..table7   regenerate the paper's tables\n\
           fig2..fig9       regenerate the paper's figures\n\
           scores           dump Figs. 10-14 token-importance series\n\
           quantize         one-off quantization (see flags below)\n\
           eval             score a saved artifact or checkpoint\n\
                            (--artifact DIR | --model PATH; bit-identical\n\
                            to the pipeline that saved it)\n\
           train            train a checkpoint on the synthetic corpus\n\
           perf             performance profile\n\
           all              run every table + figure\n\
         \n\
         common flags:\n\
           --config NAME    model config (tiny|small|s1|s2|s3|ms1..3|e2e)\n\
           --seeds N        seeded repetitions (default 3)\n\
           --steps N        training steps for the base checkpoint\n\
           --bits B         quantization bits (default 3)\n\
           --method M       rtn|gptq|quarot|sq|rsq|quarot-vq|rsq-vq\n\
           --strategy S     uniform|firstn:N|firstlastn:N|chunk:K/M|\n\
                            tokenfreq:R|actnorm:R|actdiff:R|tokensim:R|attncon:R\n\
           --calib-n/-t     calibration samples / sequence length\n\
           --expansion M    dataset expansion factor (paper M=8)\n\
           --damp F         Hessian dampening fraction (GPTQ's lambda, default 0.01)\n\
           --rot-seed N     randomized-Hadamard rotation seed (decimal;\n\
                            default 20823)\n\
           --corpus C       wiki|c4|ptb|redpajama\n\
           --probe-n N      instances per downstream probe task\n\
           --jobs N|auto    scheduler worker threads (default 1; output is\n\
                            bit-identical for every value)\n\
           --sched M        staged|pipelined cross-layer executor (default\n\
                            pipelined; both modes bit-identical)\n\
           --hess-cache C   auto|off|DIR content-addressed Hessian cache\n\
                            (default auto = cache/hessians; a key hit\n\
                            skips pass A, output stays byte-identical)\n\
           --save DIR       quantize: write a packed artifact directory\n\
                            (load with `rsq eval --artifact DIR`);\n\
                            train: write the checkpoint file\n\
           --verbose        chatty pipeline logging"
    );
}
