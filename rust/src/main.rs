//! `rsq` — the L3 coordinator CLI.
//!
//! Subcommands map one-to-one to the paper's experiments (DESIGN.md §4),
//! plus the deployment-side commands:
//!   rsq table1..table7      regenerate paper tables
//!   rsq fig2..fig9          regenerate paper figures
//!   rsq scores              dump Figs. 10-14 score series
//!   rsq quantize            one-off quantization run
//!   rsq eval                score a saved artifact or checkpoint
//!   rsq generate            greedy decode from a packed artifact
//!   rsq serve-bench         serving throughput sweep (DESIGN.md §11)
//!   rsq cache               Hessian-cache maintenance (ls / gc)
//!   rsq train               train a checkpoint
//!   rsq perf                performance profile (DESIGN.md §Perf)
//!   rsq all                 every table + figure at default scale
//!
//! `--jobs N|auto` selects the quantization scheduler's worker count and
//! `--sched staged|pipelined` its cross-layer phase ordering (DESIGN.md
//! §Threading); output is bit-identical for every combination.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use rsq::corpus::CorpusKind;
use rsq::eval::{perplexity, score_model};
use rsq::quant::{artifact, quantize, BitBudget, Method, QuantOptions, SchedMode, Strategy};
use rsq::repro::{self, Ctx};
use rsq::serve;
use rsq::tensor::kernels::Backend;
use rsq::tensor::pack::PACK_BITS;
use rsq::train::{train, TrainOptions};
use rsq::util::cli::{parse_bytes, parse_duration_s};
use rsq::util::json::Json;
use rsq::util::{Args, Pcg, Pool};

/// Parse and resolve `--backend reference|simd|auto` (DESIGN.md §13).
/// Unknown spellings fail fast; `simd`/`auto` silently resolve to the
/// reference backend on hosts without AVX2+FMA, so scripts can pass
/// `--backend auto` unconditionally.
fn parse_backend(args: &Args) -> Result<Backend> {
    let raw = args.backend();
    Backend::parse(&raw)
        .ok_or_else(|| anyhow!("--backend: unsupported backend {raw:?} (reference|simd|auto)"))
}

fn main() -> Result<()> {
    // `--prefix-cache` is boolean (serve-side subcommands); registering
    // it at parse time keeps it from swallowing the next token as a value
    let args = Args::from_env_with_flags(&["prefix-cache"]);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    // Observability is armed before dispatch and exported after it, so
    // every subcommand records through one switch (DESIGN.md §16). The
    // export notices go to stderr: stdout — and every artifact a
    // subcommand writes — is byte-identical with tracing on or off.
    if args.get("trace").is_some() {
        rsq::obs::trace::enable();
    }
    if args.get("metrics").is_some() {
        rsq::obs::metrics::enable();
    }
    rsq::obs::log::set_verbose(args.flag("verbose"));
    match cmd {
        "table1" => repro::tables::table1(&args)?,
        "table2" => repro::tables::table2(&args)?,
        "table3" => repro::tables::table3(&args)?,
        "table4" => repro::tables::table4(&args)?,
        "table5" => repro::tables::table5(&args)?,
        "table6" => repro::tables::table6(&args)?,
        "table7" => repro::tables::table7(&args)?,
        "fig2" => repro::figs::fig2(&args)?,
        "fig3" => repro::figs::fig3(&args)?,
        "fig4" => repro::figs::fig4(&args)?,
        "fig5" | "fig6" => repro::figs::fig5(&args)?,
        "fig7" => repro::figs::fig7(&args)?,
        "fig8" => repro::figs::fig8(&args)?,
        "fig9" => repro::figs::fig9(&args)?,
        "scores" => repro::scores::dump_scores(&args)?,
        "perf" => repro::perf::perf(&args)?,
        "quantize" => cmd_quantize(&args)?,
        "eval" => cmd_eval(&args)?,
        "generate" => cmd_generate(&args)?,
        "serve-bench" => cmd_serve_bench(&args)?,
        "cache" => cmd_cache(&args)?,
        "train" => cmd_train(&args)?,
        "all" => cmd_all(&args)?,
        "help" | "--help" | "-h" => print_help(),
        other => bail!("unknown command {other:?} — try `rsq help`"),
    }
    if let Some(path) = args.get("trace") {
        rsq::obs::trace::export(path)?;
        eprintln!("[trace] wrote Chrome trace to {path} (load in Perfetto / chrome://tracing)");
    }
    if let Some(path) = args.get("metrics") {
        rsq::obs::metrics::export(path, cmd)?;
        eprintln!("[metrics] wrote run record to {path}");
    }
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    // fail fast on a bad --save target BEFORE training/calibration start:
    // a typo'd path must not cost a full quantization run to discover
    if let Some(out) = args.get("save") {
        artifact::validate_save_dir(Path::new(out))?;
    }
    // the three budget spellings are mutually exclusive: --bits pins one
    // global width, --avg-bits / --budget-bytes hand the choice to the
    // allocator (DESIGN.md §14)
    for (a, b) in [("avg-bits", "budget-bytes"), ("avg-bits", "bits"), ("budget-bytes", "bits")] {
        if let Err(e) = args.conflict(a, b) {
            bail!("{e}");
        }
    }
    // validate the width BEFORE training/calibration: an out-of-range
    // --bits must fail at parse time, not after shifting garbage into the
    // solver's maxq (the packed formats are the full supported set)
    let bits = args.usize_or("bits", 3);
    if !PACK_BITS.iter().any(|&b| b as usize == bits) {
        bail!("--bits {bits}: unsupported width (supported: {PACK_BITS:?})");
    }
    let alloc = if let Some(s) = args.get("avg-bits") {
        let avg: f32 = s
            .parse()
            .map_err(|_| anyhow!("--avg-bits expects a decimal width, got {s:?}"))?;
        Some(BitBudget::AvgBits(avg))
    } else if let Some(s) = args.get("budget-bytes") {
        Some(BitBudget::Bytes(parse_bytes(s).map_err(|e| anyhow!("--budget-bytes: {e}"))?))
    } else {
        None
    };
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let cfg = ctx.engine.config().clone();
    let t = args.usize_or("calib-t", repro::default_context(&cfg));
    let method = Method::parse(&args.str_or("method", "rsq"))
        .ok_or_else(|| anyhow::anyhow!("bad --method"))?;
    let strategy = Strategy::parse(&args.str_or("strategy", "attncon:0.01"))
        .ok_or_else(|| anyhow::anyhow!("bad --strategy"))?;
    let mut opts = QuantOptions::new(method, bits as u32, t);
    opts.strategy = strategy;
    opts.alloc = alloc;
    opts.expansion = args.usize_or("expansion", 1);
    opts.damp = args.f32_or("damp", opts.damp);
    opts.rot_seed = args.u64_or("rot-seed", opts.rot_seed);
    opts.jobs = args.jobs();
    opts.sched = SchedMode::parse(&args.sched())
        .ok_or_else(|| anyhow::anyhow!("bad --sched (staged|pipelined)"))?;
    opts.hess_cache = args.hess_cache();
    opts.backend = parse_backend(args)?;
    opts.verbose = args.flag("verbose");
    let corpus = CorpusKind::parse(&args.str_or("corpus", "wiki"))
        .ok_or_else(|| anyhow::anyhow!("bad --corpus"))?;
    let calib = ctx.calib(corpus, args.usize_or("calib-n", 16), t, args.u64_or("seed", 0));

    let full_ppl = perplexity(&ctx.engine, &ctx.params, &ctx.eval, t)?;
    let (q, report) = quantize(&ctx.engine, &ctx.params, &calib, &opts)?;
    let score = score_model(&ctx.engine, &q, &ctx.eval, t, args.usize_or("probe-n", 32))?;
    println!("config       : {config} ({} params)", cfg.num_params());
    println!("method       : {} / {} / {}bit", method.name(), opts.strategy.name(), opts.bits);
    if let (Some(avg), Some(bytes)) = (report.avg_bits, report.packed_bytes) {
        println!(
            "mixed bits   : avg {avg:.3} over {} layer weights ({bytes} packed bytes, {})",
            report.widths.len(),
            report.budget.as_deref().unwrap_or("-"),
        );
        println!("widths       : {:?}", report.widths);
    }
    println!("full  PPL    : {full_ppl:.3}");
    println!("quant PPL    : {:.3}", score.ppl);
    println!("avg accuracy : {:.1}%", 100.0 * score.mean_acc);
    println!("kurtosis     : {:.2} -> {:.2}", report.kurtosis_before, report.kurtosis_after);
    println!("layer errs   : {:?}", report.layer_err);
    println!(
        "wall         : {:.2}s over {} batches (jobs={} sched={}; rotate {:.2}s, \
         pass A {:.2}s, solve {:.2}s, pass B {:.2}s, fused {:.2}s)",
        report.wall_seconds,
        report.batches,
        report.jobs,
        report.sched,
        report.rotate_seconds,
        report.pass_a_seconds,
        report.solve_seconds,
        report.pass_b_seconds,
        report.fused_seconds
    );
    // printed only off the bit-exact default, so `--backend reference`
    // (and no flag at all) keeps the historical stdout byte-for-byte
    if opts.backend != Backend::Reference {
        println!("backend      : {} (tolerance-pinned; DESIGN.md 13)", report.backend);
    }
    if !report.hess_key.is_empty() {
        println!(
            "hess cache   : {} (layers hit {} / miss {} / skip {}; key {})",
            if report.hess_cache_hits > 0 { "HIT — pass A skipped" } else { "cold" },
            report.hess_cache_hits,
            report.hess_cache_misses,
            report.hess_cache_skips,
            report.hess_key,
        );
    }
    if let Some(out) = args.get("save") {
        let manifest = artifact::save(Path::new(out), &q, &report, &opts)?;
        let packed = manifest
            .tensors
            .iter()
            .filter(|t| !matches!(t.codec, artifact::Codec::Raw))
            .count();
        println!(
            "saved artifact to {out} ({} tensors, {packed} bit-packed, {} blob bytes) — \
             score it with `rsq eval --artifact {out}`",
            manifest.tensors.len(),
            manifest.total_len,
        );
    }
    Ok(())
}

/// `rsq eval` — score a saved quantized artifact (`--artifact DIR`) or a
/// raw checkpoint (`--model PATH`) without re-running quantization. The
/// artifact path reproduces the in-memory pipeline's numbers bit-for-bit
/// (rust/tests/integration_artifact.rs pins this).
fn cmd_eval(args: &Args) -> Result<()> {
    if let Err(e) = args.conflict("artifact", "model") {
        bail!("{e}");
    }
    // Validated for interface uniformity and fail-fast on typos; eval's
    // host-side work (packed-row unpack) is an elementwise decode that is
    // identical on every backend, and scoring runs through the XLA
    // engine, so the flag cannot change a byte of output here.
    let _backend = parse_backend(args)?;
    // default_t mirrors the context the quantize-time printout scored at:
    // the artifact's recorded seq_len when loading an artifact, else
    // cmd_quantize's own default
    let (params, engine, default_t) = if let Some(dir) = args.get("artifact") {
        // --jobs also parallelizes the artifact's packed-row unpack
        // (bit-identical at every value — PackedRows::unpack)
        let pool = Pool::new(args.jobs());
        let (p, manifest) = artifact::load_with(Path::new(dir), Some(&pool))?;
        let engine = rsq::runtime::Engine::load(&manifest.config.name)?;
        if engine.config() != &manifest.config {
            bail!(
                "artifact {dir} was saved for config {:?} but the compiled artifacts for \
                 {:?} differ — re-run `make artifacts` or re-save the artifact",
                manifest.config.name,
                engine.config().name,
            );
        }
        println!(
            "artifact     : {dir} ({} / {} / {}bit, hess key {})",
            manifest.method, manifest.strategy, manifest.bits, manifest.hess_key
        );
        if let Some(avg) = manifest.avg_bits {
            println!(
                "mixed bits   : avg {avg:.3} ({})",
                manifest.budget.as_deref().unwrap_or("-"),
            );
        }
        let t = manifest.seq_len;
        (p, engine, t)
    } else if let Some(path) = args.get("model") {
        let config = args.str_or("config", "small");
        let engine = rsq::runtime::Engine::load(&config)?;
        let p = rsq::model::ParamSet::load(engine.config(), Path::new(path))?;
        println!("checkpoint   : {path} (config {config})");
        let t = repro::default_context(engine.config());
        (p, engine, t)
    } else {
        bail!("rsq eval needs --artifact DIR (packed artifact) or --model PATH (checkpoint)");
    };
    let cfg = engine.config().clone();
    let t = args.usize_or("eval-t", default_t);
    if !cfg.seq_lens.contains(&t) {
        bail!("--eval-t {t} not in artifact set {:?}", cfg.seq_lens);
    }
    // the one shared held-out recipe, so scores line up with the
    // quantize-time printout
    let eval = repro::heldout_eval_set(&cfg, args);
    let score = score_model(&engine, &params, &eval, t, args.usize_or("probe-n", 32))?;
    println!("PPL          : {:.3} (context {t})", score.ppl);
    println!("avg accuracy : {:.1}%", 100.0 * score.mean_acc);
    for p in &score.probes {
        println!("  {:<18} {:>5.1}%", p.name, 100.0 * p.accuracy);
    }
    Ok(())
}

/// Shared fail-fast validation for the serve-side subcommands: reject
/// unknown flags AND known value-options passed without a value (the
/// parser records `--max-new --verbose` as a bare "max-new" flag, which
/// a known-names check alone would accept while the default silently
/// applied).
fn check_flags(cmd: &str, args: &Args, known: &[&str], valued: &[&str]) -> Result<()> {
    let unknown = args.unknown_keys(known);
    if !unknown.is_empty() {
        bail!(
            "rsq {cmd}: unknown flag(s) --{} (known: --{})",
            unknown.join(", --"),
            known.join(", --")
        );
    }
    let missing = args.missing_values(valued);
    if !missing.is_empty() {
        bail!("rsq {cmd}: --{} need(s) a value", missing.join(", --"));
    }
    Ok(())
}

/// `rsq generate` — greedy decode through the serving layer (DESIGN.md
/// §11): `--artifact DIR` decodes **directly from the packed artifact**
/// host-side (no XLA involved); `--model PATH` serves a full-precision
/// checkpoint dense (the AOT manifest supplies the config — parsed only,
/// never compiled). Token output is deterministic — a pure function of
/// the model and flags — which CI's serve smoke relies on; timings go to
/// stderr. Unknown flags fail fast instead of being silently ignored.
fn cmd_generate(args: &Args) -> Result<()> {
    const KNOWN: &[&str] = &[
        "artifact", "model", "config", "prompt", "prompt-len", "seed", "max-new", "kv-bits",
        "jobs", "backend", "verbose", "prompts", "max-batch", "kv-page", "prefix-cache",
        "spec-k", "draft-artifact", "trace", "metrics",
    ];
    const VALUED: &[&str] = &[
        "artifact", "model", "config", "prompt", "prompt-len", "seed", "max-new", "kv-bits",
        "jobs", "backend", "prompts", "max-batch", "kv-page", "spec-k", "draft-artifact",
        "trace", "metrics",
    ];
    check_flags("generate", args, KNOWN, VALUED)?;
    let kv = serve::KvFormat::from_bits(args.kv_bits()).ok_or_else(|| {
        anyhow!("--kv-bits: unsupported width {} (supported: 32, 8, 2)", args.kv_bits())
    })?;
    let backend = parse_backend(args)?;
    if let Err(e) = args.conflict("artifact", "model") {
        bail!("{e}");
    }
    let pool = Pool::new(args.jobs());
    let mut model = if let Some(dir) = args.get("artifact") {
        let (m, manifest) = serve::PackedModel::load(Path::new(dir))?;
        rsq::obs_info!(
            "[generate] artifact {dir}: {} / {} / {}bit, {} packed weights",
            manifest.method,
            manifest.strategy,
            manifest.bits,
            m.packed_weights()
        );
        m
    } else if let Some(path) = args.get("model") {
        let config = args.str_or("config", "small");
        let manifest = rsq::runtime::Manifest::load(&rsq::artifacts_dir(&config))?;
        let p = rsq::model::ParamSet::load(&manifest.config, Path::new(path))?;
        rsq::obs_info!("[generate] checkpoint {path} (config {config}, served dense)");
        serve::PackedModel::from_paramset_dense(&p)?
    } else {
        bail!("rsq generate needs --artifact DIR (packed artifact) or --model PATH (checkpoint)");
    };
    model.set_backend(backend);
    let cfg = model.cfg.clone();
    let prompt: Vec<i32> = match args.get("prompt") {
        Some(s) => s
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<i32>()
                    .map_err(|_| anyhow!("--prompt expects comma-separated token ids, got {t:?}"))
            })
            .collect::<Result<_>>()?,
        None => {
            let n = args.usize_or("prompt-len", 4).max(1);
            let mut rng = Pcg::new(args.u64_or("seed", 0));
            (0..n).map(|_| rng.below(cfg.vocab) as i32).collect()
        }
    };
    if prompt.is_empty() {
        bail!("--prompt is empty");
    }
    if let Some(&t) = prompt.iter().find(|&&t| !(0..cfg.vocab as i32).contains(&t)) {
        bail!("prompt token {t} outside vocab {}", cfg.vocab);
    }
    if prompt.len() >= cfg.max_seq {
        bail!(
            "prompt length {} leaves no room to generate (max_seq {})",
            prompt.len(),
            cfg.max_seq
        );
    }
    let max_new = args.usize_or("max-new", 16);
    let join = |ts: &[i32]| ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ");
    // serve mode (`--prompts N` and friends): N copies of the prompt run
    // through the continuous-batching scheduler — the CLI surface for the
    // prefix cache and speculative decoding (DESIGN.md §15). Token output
    // is identical to the single-prompt path by the determinism contract,
    // which CI's shared-prefix smoke pins byte-for-byte.
    let serve_keys = ["prompts", "max-batch", "kv-page", "spec-k", "draft-artifact"];
    let serve_mode = serve_keys.iter().any(|k| args.get(k).is_some()) || args.flag("prefix-cache");
    if serve_mode {
        let spec_k = args.usize_or("spec-k", 0);
        let draft = match args.get("draft-artifact") {
            Some(dir) => {
                if spec_k == 0 {
                    bail!("--draft-artifact needs --spec-k K >= 1 (the speculative window)");
                }
                let (mut d, manifest) = serve::PackedModel::load(Path::new(dir))?;
                d.set_backend(backend);
                rsq::obs_info!("[generate] draft artifact {dir}: {}bit", manifest.bits);
                Some(d)
            }
            None => {
                if spec_k > 0 {
                    bail!("--spec-k {spec_k} needs --draft-artifact DIR (the proposal model)");
                }
                None
            }
        };
        let n = args.usize_or("prompts", 1).max(1);
        let mut requests = Vec::with_capacity(n);
        for id in 0..n as u64 {
            requests.push(serve::ServeRequest::new(id, prompt.clone(), max_new));
        }
        let opts = serve::ServeOptions {
            max_batch: args.usize_or("max-batch", 1).max(1),
            page: args.usize_or("kv-page", 0),
            kv,
            prefix_cache: args.flag("prefix-cache"),
            spec_k,
            ..Default::default()
        };
        let rep = serve::serve_with_draft(&model, draft.as_ref(), &pool, requests, &opts)?;
        println!("prompt       : {}", join(&prompt));
        for r in &rep.requests {
            println!("generated[{:>2}]: {}", r.id, join(&r.generated));
        }
        rsq::obs_info!(
            "[generate] served {n} request(s) in {:.3}s ({:.1} tok/s, kv-bits={kv}, \
             max-batch={}, jobs={}, backend={})",
            rep.wall_s,
            rep.tokens_per_s,
            opts.max_batch,
            pool.jobs(),
            model.backend().name()
        );
        // latency distribution (DESIGN.md §16), debug level so the
        // default stderr stays as it was before percentiles existed
        rsq::obs_debug!(
            "[generate] latency: ttft p50/p95/p99 {:.4}/{:.4}/{:.4}s, \
             inter-token p50/p95/p99 {:.4}/{:.4}/{:.4}s, deadline missed {}",
            rep.ttft_p50_s,
            rep.ttft_p95_s,
            rep.ttft_p99_s,
            rep.itl_p50_s,
            rep.itl_p95_s,
            rep.itl_p99_s,
            rep.deadline_missed
        );
        if opts.prefix_cache {
            rsq::obs_info!(
                "[generate] prefix cache: {}/{} hits (hit-rate {:.2}), \
                 {} prefill forwards skipped",
                rep.prefix_hits,
                rep.prefix_lookups,
                rep.prefix_hit_rate,
                rep.prefill_skipped
            );
        }
        if spec_k > 0 {
            rsq::obs_info!(
                "[generate] speculative: spec-k={spec_k}, accepted {}/{} drafts \
                 (accept-rate {:.2})",
                rep.draft_accepted,
                rep.draft_proposed,
                rep.draft_accept_rate
            );
        }
        return Ok(());
    }
    let t0 = Instant::now();
    let gen = serve::greedy_decode_kv(&model, &prompt, max_new, kv, Some(&pool))?;
    let dt = t0.elapsed().as_secs_f64();
    println!("prompt       : {}", join(&prompt));
    println!("generated    : {}", join(&gen));
    rsq::obs_info!(
        "[generate] {} tokens in {dt:.3}s ({:.1} tok/s, kv-bits={kv}, jobs={}, backend={})",
        gen.len(),
        gen.len() as f64 / dt.max(1e-12),
        pool.jobs(),
        model.backend().name()
    );
    Ok(())
}

/// Mean time-to-first-token across a report's requests (0.0 when nothing
/// recorded one) — the latency column of the `serve-bench --json` cells.
fn mean_ttft(rep: &serve::ServeReport) -> f64 {
    let ts: Vec<f64> = rep.requests.iter().filter_map(|r| r.ttft_s).collect();
    if ts.is_empty() {
        0.0
    } else {
        ts.iter().sum::<f64>() / ts.len() as f64
    }
}

/// One machine-readable `serve-bench --json` cell record: the row behind
/// the human-readable grid line (tokens/s, TTFT, prefix-hit rate,
/// draft-acceptance rate), tagged with its sweep axis.
fn bench_cell(
    axis: &str,
    bits: u32,
    batch: usize,
    ctx: usize,
    jobs: usize,
    rep: &serve::ServeReport,
    ttft: f64,
) -> Json {
    Json::obj()
        .set("axis", axis)
        .set("bits", bits as usize)
        .set("batch", batch)
        .set("ctx", ctx)
        .set("jobs", jobs)
        .set("kv_bits", rep.kv_bits as usize)
        .set("spec_k", rep.spec_k)
        .set("tok_per_s", rep.tokens_per_s)
        .set("ttft_s", ttft)
        .set("ttft_p50_s", rep.ttft_p50_s)
        .set("ttft_p95_s", rep.ttft_p95_s)
        .set("ttft_p99_s", rep.ttft_p99_s)
        .set("itl_p50_s", rep.itl_p50_s)
        .set("itl_p95_s", rep.itl_p95_s)
        .set("itl_p99_s", rep.itl_p99_s)
        .set("deadline_missed", rep.deadline_missed)
        .set("generated_tokens", rep.generated_tokens)
        .set("peak_active", rep.peak_active)
        .set("kv_peak_pages", rep.kv_peak_pages)
        .set("prefix_lookups", rep.prefix_lookups)
        .set("prefix_hits", rep.prefix_hits)
        .set("prefix_hit_rate", rep.prefix_hit_rate)
        .set("prefill_skipped", rep.prefill_skipped)
        .set("draft_proposed", rep.draft_proposed)
        .set("draft_accepted", rep.draft_accepted)
        .set("draft_accept_rate", rep.draft_accept_rate)
}

/// `rsq serve-bench` — serving throughput sweep: batch × context × jobs
/// (× bits when no artifact pins them), printing tokens/s and the
/// packed-vs-f32 resident-bytes ratio (DESIGN.md §11), then a kv-bits
/// axis (§12): each `--kv-bits` cell re-decodes the same prompts under a
/// shared KV byte budget and reports the KV resident-bytes ratio, peak
/// occupancy / page usage, and greedy-token divergence vs the f32 solo
/// oracle. `--traffic shared` switches every cell to a shared-prefix
/// traffic pattern (all requests decode one prompt, twice as many
/// requests as slots) with the prefix cache on, reporting hit rate and
/// prefill forwards eliminated; `--spec-k A,B` adds a speculative axis
/// (§15) against a 2-bit draft of the same weights (or
/// `--draft-artifact`). `--json PATH` dumps machine-readable per-cell
/// records. Without `--artifact` it builds its own host-side RTN-packed
/// synthetic model, so it runs anywhere — no artifacts, no XLA.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    const KNOWN: &[&str] = &[
        "artifact", "bits", "batches", "contexts", "jobs-sweep", "kv-bits", "prompt-len", "seed",
        "backend", "verbose", "traffic", "spec-k", "kv-page", "json", "draft-artifact",
        "trace", "metrics",
    ];
    const VALUED: &[&str] = &[
        "artifact", "bits", "batches", "contexts", "jobs-sweep", "kv-bits", "prompt-len", "seed",
        "backend", "traffic", "spec-k", "kv-page", "json", "draft-artifact", "trace", "metrics",
    ];
    check_flags("serve-bench", args, KNOWN, VALUED)?;
    let backend = parse_backend(args)?;
    let parse_list = |key: &str, default: &[&str]| -> Result<Vec<usize>> {
        args.list_or(key, default)
            .iter()
            .map(|v| v.parse::<usize>().map_err(|_| anyhow!("--{key}: bad entry {v:?}")))
            .collect()
    };
    let batches = parse_list("batches", &["1", "4"])?;
    let contexts = parse_list("contexts", &["32", "64"])?;
    let jobs_sweep = parse_list("jobs-sweep", &["1", "4"])?;
    let kv_bits = parse_list("kv-bits", &["32", "8", "2"])?;
    let kv_formats = kv_bits
        .iter()
        .map(|&b| {
            serve::KvFormat::from_bits(b as u32)
                .ok_or_else(|| anyhow!("--kv-bits: unsupported width {b} (supported: 32, 8, 2)"))
        })
        .collect::<Result<Vec<_>>>()?;
    let prompt_len = args.usize_or("prompt-len", 4).max(1);
    let traffic = args.str_or("traffic", "unique");
    let shared = match traffic.as_str() {
        "unique" => false,
        "shared" => true,
        other => bail!("--traffic: unsupported pattern {other:?} (unique|shared)"),
    };
    let spec_ks = parse_list("spec-k", &["0"])?;
    // shared traffic needs a page boundary inside the prompt for the
    // prefix cache to key on — default the page size down to half the
    // prompt unless --kv-page pins it
    let page_default = if shared { (prompt_len / 2).max(1) } else { 0 };
    let page = args.usize_or("kv-page", page_default);

    println!("=== serve-bench: packed-domain host decode (DESIGN.md §11) ===");
    let (mut models, source, synth): (Vec<(u32, serve::PackedModel)>, String, _) =
        if let Some(dir) = args.get("artifact") {
            let (m, manifest) = serve::PackedModel::load(Path::new(dir))?;
            (vec![(manifest.bits, m)], format!("artifact {dir}"), None)
        } else {
            // shared with benches/bench_serve.rs so the grids compare
            let cfg = serve::bench_model_config();
            let p = rsq::model::ParamSet::init(&cfg, args.u64_or("seed", 3));
            let bits = parse_list("bits", &["2", "3", "4", "8"])?;
            let ms = bits
                .into_iter()
                .map(|b| Ok((b as u32, serve::PackedModel::from_paramset_rtn(&p, b as u32)?)))
                .collect::<Result<_>>()?;
            (ms, "synthetic d=64 L=2 vocab=256 (host RTN)".to_string(), Some(p))
        };
    println!("model        : {source}");
    for (_, m) in models.iter_mut() {
        m.set_backend(backend);
    }
    println!("backend      : {}", backend.name());
    println!("traffic      : {traffic}");
    // speculative axis draft: an explicit artifact, or (synthetic mode) a
    // 2-bit RTN packing of the SAME weights — the §15 self-drafting setup
    let draft: Option<(u32, serve::PackedModel)> = if spec_ks.iter().any(|&k| k > 0) {
        let (bits, mut d) = match (args.get("draft-artifact"), &synth) {
            (Some(dir), _) => {
                let (d, manifest) = serve::PackedModel::load(Path::new(dir))?;
                (manifest.bits, d)
            }
            (None, Some(p)) => (2, serve::PackedModel::from_paramset_rtn(p, 2)?),
            (None, None) => {
                bail!("--spec-k with --artifact needs --draft-artifact DIR (the proposal model)")
            }
        };
        d.set_backend(backend);
        Some((bits, d))
    } else {
        None
    };
    // per-cell request builder: re-seeded so every cell decodes identical
    // prompts (rows stay comparable along any sweep axis — the invariant
    // benches/bench_serve.rs asserts); shared traffic reuses one prompt so
    // later admissions can hit the prefix cache
    let make_requests = |vocab: usize, n: usize, max_new: usize| -> Vec<serve::ServeRequest> {
        let mut rng = Pcg::new(args.u64_or("seed", 3));
        let first: Vec<i32> = (0..prompt_len).map(|_| rng.below(vocab) as i32).collect();
        (0..n as u64)
            .map(|id| {
                let prompt = if shared || id == 0 {
                    first.clone()
                } else {
                    (0..prompt_len).map(|_| rng.below(vocab) as i32).collect()
                };
                serve::ServeRequest::new(id, prompt, max_new)
            })
            .collect()
    };
    // shared-prefix traffic oversubscribes the slots 2x, so the second
    // wave admits against prefixes the first wave donated
    let cell_n = |batch: usize| if shared { batch * 2 } else { batch };
    let mut cells: Vec<Json> = Vec::new();
    for (bits, model) in &models {
        let (packed, dense) = model.resident_bytes();
        println!(
            "bits={bits}  resident {packed} B packed vs {dense} B f32 \
             ({:.2}x smaller, {} packed weights)",
            dense as f64 / packed as f64,
            model.packed_weights()
        );
        let cfg = &model.cfg;
        for &ctx in &contexts {
            let ctx = ctx.min(cfg.max_seq);
            let max_new = ctx.saturating_sub(prompt_len).max(1);
            for &batch in &batches {
                for &jobs in &jobs_sweep {
                    let pool = Pool::new(jobs);
                    let requests = make_requests(cfg.vocab, cell_n(batch.max(1)), max_new);
                    let opts = serve::ServeOptions {
                        max_batch: batch.max(1),
                        page,
                        prefix_cache: shared,
                        ..Default::default()
                    };
                    let rep = serve::serve(model, &pool, requests, &opts)?;
                    let hit_note = if shared {
                        format!(
                            ", hits {}/{} ({} prefills skipped)",
                            rep.prefix_hits, rep.prefix_lookups, rep.prefill_skipped
                        )
                    } else {
                        String::new()
                    };
                    println!(
                        "  batch={batch:<3} ctx={ctx:<4} jobs={jobs:<3} {:>9.1} tok/s  \
                         ttft p50/p95/p99 {:.4}/{:.4}/{:.4}s  \
                         ({} tokens, {} steps, peak {}, missed {}{hit_note})",
                        rep.tokens_per_s,
                        rep.ttft_p50_s,
                        rep.ttft_p95_s,
                        rep.ttft_p99_s,
                        rep.generated_tokens,
                        rep.steps,
                        rep.peak_active,
                        rep.deadline_missed
                    );
                    let ttft = mean_ttft(&rep);
                    cells.push(bench_cell("grid", *bits, batch, ctx, jobs, &rep, ttft));
                }
            }
        }
        // kv-bits axis (DESIGN.md §12): one cell per --kv-bits width at
        // the grid's largest batch/ctx/jobs. Every cell re-seeds the
        // prompt RNG, so all kv cells decode IDENTICAL prompts (the same
        // per-cell pattern as the grid above — bench_serve.rs asserts
        // it), under one shared KV byte budget sized to two f32
        // worst-case reservations so narrower formats show their
        // admission gains as peak occupancy.
        let kv_batch = batches.iter().copied().max().unwrap_or(1).max(1);
        let ctx = contexts.iter().copied().max().unwrap_or(32).min(cfg.max_seq);
        let max_new = ctx.saturating_sub(prompt_len).max(1);
        let jobs = jobs_sweep.iter().copied().max().unwrap_or(1).max(1);
        let pool = Pool::new(jobs);
        let probe = serve::PagePool::new(cfg.layers, cfg.d, 0, 0);
        let worst = (prompt_len + max_new).min(cfg.max_seq);
        let budget = 2 * probe.pages_for(worst) * probe.page_bytes_f32();
        println!(
            "  kv-bits axis: batch={kv_batch} ctx={ctx} jobs={jobs}, KV budget {budget} B, \
             divergence vs f32 solo oracle"
        );
        for kv in &kv_formats {
            // re-seeded per kv cell: identical prompts along the axis
            let requests = make_requests(cfg.vocab, cell_n(kv_batch), max_new);
            let oracle: Vec<Vec<i32>> = requests
                .iter()
                .map(|r| serve::greedy_decode(model, &r.prompt, r.max_new, Some(&pool)))
                .collect::<Result<_>>()?;
            let opts = serve::ServeOptions {
                max_batch: kv_batch,
                pool_bytes: budget,
                kv: *kv,
                page,
                ..Default::default()
            };
            let rep = serve::serve(model, &pool, requests, &opts)?;
            let divergence: usize = rep
                .requests
                .iter()
                .zip(&oracle)
                .map(|(r, o)| serve::token_divergence(o, &r.generated))
                .sum();
            println!(
                "  kv={:<3} {:>9.1} tok/s  kv resident {:>8} B vs {:>8} B f32 ({:.2}x), \
                 peak {} seqs / {} pages, divergence {divergence}",
                rep.kv_bits,
                rep.tokens_per_s,
                rep.kv_resident_bytes,
                rep.kv_resident_f32_bytes,
                rep.kv_resident_f32_bytes as f64 / rep.kv_resident_bytes.max(1) as f64,
                rep.peak_active,
                rep.kv_peak_pages,
            );
            let ttft = mean_ttft(&rep);
            cells.push(bench_cell("kv", *bits, kv_batch, ctx, jobs, &rep, ttft));
        }
        // speculative axis (DESIGN.md §15): same cell shape, the draft
        // proposes spec-k-token windows the serving model verifies in
        // batched forwards. spec-k=0 rows are the plain baseline; output
        // is token-identical across the whole axis by construction.
        if let Some((dbits, d)) = &draft {
            println!(
                "  spec-k axis: batch={kv_batch} ctx={ctx} jobs={jobs}, {dbits}bit draft, \
                 acceptance = verified proposals"
            );
            for &k in &spec_ks {
                let requests = make_requests(cfg.vocab, cell_n(kv_batch), max_new);
                let opts = serve::ServeOptions {
                    max_batch: kv_batch,
                    page,
                    prefix_cache: shared,
                    spec_k: k,
                    ..Default::default()
                };
                let rep =
                    serve::serve_with_draft(model, (k > 0).then_some(d), &pool, requests, &opts)?;
                println!(
                    "  spec-k={k:<2} {:>9.1} tok/s  accepted {}/{} drafts (rate {:.2}), \
                     {} steps",
                    rep.tokens_per_s,
                    rep.draft_accepted,
                    rep.draft_proposed,
                    rep.draft_accept_rate,
                    rep.steps,
                );
                let ttft = mean_ttft(&rep);
                cells.push(bench_cell("spec", *bits, kv_batch, ctx, jobs, &rep, ttft));
            }
        }
    }
    if let Some(path) = args.get("json") {
        let n = cells.len();
        let doc = Json::obj()
            .set("source", source.as_str())
            .set("backend", backend.name())
            .set("traffic", traffic.as_str())
            .set("cells", Json::Arr(cells));
        std::fs::write(path, doc.to_string() + "\n")?;
        rsq::obs_info!("[serve-bench] wrote {n} cell records to {path}");
    }
    Ok(())
}

/// `rsq cache` — Hessian-cache maintenance (DESIGN.md §9): `ls` lists the
/// content-addressed entries, `gc --max-age D --max-bytes N` evicts by
/// age then by total size (oldest first). Eviction is always safe —
/// content addressing turns a deleted entry into a future recompute.
fn cmd_cache(args: &Args) -> Result<()> {
    const KNOWN: &[&str] = &["hess-cache", "max-age", "max-bytes", "verbose", "trace", "metrics"];
    check_flags("cache", args, KNOWN, &["hess-cache", "max-age", "max-bytes", "trace", "metrics"])?;
    let Some(dir) = args.hess_cache() else {
        bail!("--hess-cache off leaves no cache to manage");
    };
    let cache = artifact::cache::HessCache::new(&dir);
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("ls");
    match sub {
        "ls" => {
            let entries = cache.entries()?;
            if entries.is_empty() {
                println!("hessian cache {dir:?}: empty");
                return Ok(());
            }
            println!("hessian cache {dir:?} (oldest first):");
            let mut total = 0u64;
            for e in &entries {
                total += e.bytes;
                println!("  {}  {:>12} B  age {}", e.key_hex, e.bytes, fmt_age(e.age_s));
            }
            println!("{} entries, {total} B total — evict with `rsq cache gc`", entries.len());
        }
        "gc" => {
            let max_age = args
                .get("max-age")
                .map(parse_duration_s)
                .transpose()
                .map_err(|e| anyhow!("--max-age: {e}"))?;
            let max_bytes = args
                .get("max-bytes")
                .map(parse_bytes)
                .transpose()
                .map_err(|e| anyhow!("--max-bytes: {e}"))?;
            if max_age.is_none() && max_bytes.is_none() {
                bail!("rsq cache gc needs --max-age DURATION and/or --max-bytes SIZE");
            }
            let rep = cache.gc(max_age, max_bytes)?;
            println!(
                "gc {dir:?}: scanned {}, evicted {} ({} B), kept {} ({} B), \
                 swept {} stale tmp file(s)",
                rep.scanned,
                rep.deleted,
                rep.deleted_bytes,
                rep.kept,
                rep.kept_bytes,
                rep.stale_tmp_deleted
            );
        }
        other => bail!("unknown cache subcommand {other:?} — try `rsq cache ls` or `rsq cache gc`"),
    }
    Ok(())
}

fn fmt_age(age_s: f64) -> String {
    if age_s >= 86400.0 {
        format!("{:.1}d", age_s / 86400.0)
    } else if age_s >= 3600.0 {
        format!("{:.1}h", age_s / 3600.0)
    } else if age_s >= 60.0 {
        format!("{:.1}m", age_s / 60.0)
    } else {
        format!("{age_s:.0}s")
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.str_or("config", "small");
    let engine = rsq::runtime::Engine::load(&config)?;
    let mut p = rsq::model::ParamSet::init(engine.config(), args.u64_or("train-seed", 7));
    let report = train(
        &engine,
        &mut p,
        &TrainOptions {
            steps: args.usize_or("steps", repro::default_steps(&config)),
            corpus: CorpusKind::parse(&args.str_or("corpus", "wiki")).unwrap(),
            seed: args.u64_or("train-seed", 7),
            log_every: args.usize_or("log-every", 20),
            verbose: true,
        },
    )?;
    println!("final loss {:.4} after {:.1}s", report.final_loss, report.wall_seconds);
    if let Some(out) = args.get("save") {
        p.save(std::path::Path::new(out))?;
        println!("saved checkpoint to {out}");
    }
    Ok(())
}

fn cmd_all(_args: &Args) -> Result<()> {
    // Each driver runs in its own subprocess: the prebuilt xla_extension
    // 0.5.1 leaks ~output-size heap per PJRT execute (upstream C bug — the
    // rust wrappers free everything they own), so a single long-lived
    // process accumulates GBs across tens of thousands of executions.
    // Process isolation bounds it per driver. See DESIGN.md §Perf.
    let exe = std::env::current_exe()?;
    let fwd: Vec<String> = std::env::args().skip(2).collect();
    for cmd in [
        "table1", "table2", "table3", "table4", "table5", "table6", "table7",
        "fig2", "fig3", "fig4", "fig5", "fig7", "fig8", "fig9", "scores",
    ] {
        rsq::obs_info!("[all] running {cmd} ...");
        let status = std::process::Command::new(&exe).arg(cmd).args(&fwd).status()?;
        if !status.success() {
            bail!("driver {cmd} failed with {status}");
        }
    }
    Ok(())
}

fn print_help() {
    println!(
        "rsq — RSQ (Rotate, Scale, then Quantize) reproduction\n\
         \n\
         usage: rsq <command> [flags]\n\
         \n\
         commands:\n\
           table1..table7   regenerate the paper's tables\n\
           fig2..fig9       regenerate the paper's figures\n\
           scores           dump Figs. 10-14 token-importance series\n\
           quantize         one-off quantization (see flags below)\n\
           eval             score a saved artifact or checkpoint\n\
                            (--artifact DIR | --model PATH; bit-identical\n\
                            to the pipeline that saved it)\n\
           generate         greedy decode through the serving layer\n\
                            (--artifact DIR decodes straight from the\n\
                            packed artifact, host-side; --model PATH\n\
                            serves a checkpoint dense)\n\
           serve-bench      serving throughput sweep: batch x context x\n\
                            jobs (x bits without --artifact) plus a\n\
                            kv-bits axis; prints tokens/s, packed-vs-f32\n\
                            resident bytes, and KV divergence vs f32\n\
           cache            Hessian-cache maintenance: `rsq cache ls`,\n\
                            `rsq cache gc --max-age 30d --max-bytes 500m`\n\
           train            train a checkpoint on the synthetic corpus\n\
           perf             performance profile\n\
           all              run every table + figure\n\
         \n\
         common flags:\n\
           --config NAME    model config (tiny|small|s1|s2|s3|ms1..3|e2e)\n\
           --configs A,B    figure drivers: config list to sweep\n\
           --seeds N        seeded repetitions (default 3)\n\
           --steps N        training steps for the base checkpoint\n\
           --train-seed N   init/training RNG seed (default 7)\n\
           --bits B         quantization bits (default 3; one of 2,3,4,8)\n\
           --avg-bits X     quantize: mixed-precision budget as a target\n\
                            average width (e.g. 3.0) — a deterministic\n\
                            greedy allocator picks per-module widths from\n\
                            {{2,3,4,8}} by Hessian sensitivity; excludes\n\
                            --bits and --budget-bytes (DESIGN.md 14)\n\
           --budget-bytes S quantize: same allocator under a total\n\
                            packed-bytes budget (accepts 500k, 2m, ...)\n\
           --method M       rtn|gptq|quarot|sq|rsq|quarot-vq|rsq-vq\n\
           --strategy S     uniform|firstn:N|firstlastn:N|chunk:K/M|\n\
                            tokenfreq:R|actnorm:R|actdiff:R|tokensim:R|attncon:R\n\
           --calib-n/-t     calibration samples / sequence length\n\
           --eval-t N       eval context length (default: the artifact's\n\
                            recorded seq_len, else the config default)\n\
           --eval-n N       held-out eval samples\n\
           --expansion M    dataset expansion factor (paper M=8)\n\
           --damp F         Hessian dampening fraction (GPTQ's lambda, default 0.01)\n\
           --rot-seed N     randomized-Hadamard rotation seed (decimal;\n\
                            default 20823)\n\
           --corpus C       wiki|c4|ptb|redpajama\n\
           --probe-n N      instances per downstream probe task\n\
           --lc-n N         instances per long-context probe family\n\
           --outlier-frac/--outlier-mag  injected-outlier spec\n\
           --jobs N|auto    scheduler worker threads (default 1; output is\n\
                            bit-identical for every value; also drives\n\
                            artifact unpack + the serve decode pool)\n\
           --sched M        staged|pipelined cross-layer executor (default\n\
                            pipelined; both modes bit-identical)\n\
           --backend B      reference|simd|auto kernel backend for the\n\
                            host GEMM/decode layer (default reference =\n\
                            bit-exact; simd = AVX2+FMA, tolerance-pinned;\n\
                            auto detects at runtime and falls back to\n\
                            reference — quantize, eval, generate,\n\
                            serve-bench)\n\
           --hess-cache C   auto|off|DIR content-addressed Hessian cache\n\
                            (default auto = cache/hessians; a key hit\n\
                            skips pass A, output stays byte-identical)\n\
           --save DIR       quantize: write a packed artifact directory\n\
                            (load with `rsq eval --artifact DIR`);\n\
                            train: write the checkpoint file\n\
           --log-every N    train: loss-logging interval\n\
           --iters N        perf: warm-run repetitions per method\n\
           --bench-samples N  perf: samples per micro-bench\n\
           --samples N      scores: sequences per importance series\n\
           --verbose        chatty pipeline logging\n\
           --trace PATH     write a Chrome trace-event file (load in\n\
                            Perfetto / chrome://tracing): scheduler\n\
                            phases, pool tasks, kernel calls, and the\n\
                            serve loop's KV/prefix/speculative events;\n\
                            stdout and every artifact stay byte-identical\n\
                            with tracing on or off (DESIGN.md 16)\n\
           --metrics PATH   write a machine-readable run record from the\n\
                            same instrumentation: counters, gauges, and\n\
                            histogram summaries (p50/p90/p95/p99)\n\
         \n\
         generate flags (unknown flags fail fast):\n\
           --prompt T1,T2   explicit prompt token ids\n\
           --prompt-len N   seeded random prompt length (default 4)\n\
           --seed N         prompt RNG seed (default 0)\n\
           --max-new N      tokens to generate (default 16)\n\
           --kv-bits W      KV-cache storage width 32|8|2 (default 32 =\n\
                            exact f32; 8 = linear, 2 = log codec)\n\
           --prompts N      serve N copies of the prompt through the\n\
                            batching scheduler (token output identical\n\
                            to the single-prompt path)\n\
           --max-batch B    serve mode: concurrent slots (default 1)\n\
           --kv-page P      serve mode: KV page size in positions\n\
                            (default 16)\n\
           --prefix-cache   serve mode: content-addressed prompt-prefix\n\
                            cache — repeat prompts admit with zero\n\
                            prefill forwards (DESIGN.md 15)\n\
           --spec-k K       serve mode: speculative window — the draft\n\
                            proposes K-token windows, the serving model\n\
                            verifies them in one batched forward; greedy\n\
                            output is token-identical (DESIGN.md 15)\n\
           --draft-artifact DIR  low-bit draft of the same weights that\n\
                            proposes the speculative windows\n\
         \n\
         serve-bench flags:\n\
           --batches A,B    batch sizes to sweep (default 1,4)\n\
           --contexts A,B   total context lengths (default 32,64)\n\
           --jobs-sweep A,B worker counts (default 1,4)\n\
           --bits A,B       bit widths, synthetic model only (default 2,3,4,8)\n\
           --kv-bits A,B    KV widths for the kv axis (default 32,8,2);\n\
                            each cell reports the KV resident-bytes\n\
                            ratio + token divergence vs the f32 oracle\n\
           --traffic T      unique|shared request pattern (default\n\
                            unique); shared = every request decodes one\n\
                            prompt, 2x oversubscribed, prefix cache on —\n\
                            rows add hit rate + prefills skipped\n\
           --spec-k A,B     speculative axis (default 0 = off): window\n\
                            sizes vs a 2-bit draft of the same weights\n\
                            (or --draft-artifact DIR with --artifact)\n\
           --kv-page P      KV page size in positions (default 16)\n\
           --json PATH      write machine-readable per-cell records\n\
                            (tok/s, TTFT, hit rate, acceptance rate)\n\
         \n\
         cache gc flags:\n\
           --max-age D      evict entries older than D (90, 45m, 12h, 30d)\n\
           --max-bytes S    then trim, oldest first, to S total (500m, 2g)"
    );
}
