//! Named counter/gauge/histogram registry with a run-record exporter
//! (DESIGN.md §16).
//!
//! Counters and histograms record into a `thread_local!` registry (no
//! shared lock on the hot path); a thread's registry merges into the
//! global sink through its TLS destructor when the thread exits — pool
//! workers are scoped per `Pool::run` call, so by the time the
//! coordinator exports, every worker has already merged. Merging is
//! commutative (u64 adds, bucket-count adds, min/max), so the merged
//! totals are independent of worker scheduling. Gauges are last-write
//! values; by convention only the coordinator sets them.
//!
//! [`Hist`] is the fixed-bucket log2 histogram the ISSUE's latency and
//! shape distributions use: values 0–15 are exact, then every power-of-two
//! range splits into 16 linear sub-buckets (≤ ~6 % relative error). It is
//! `pub` because `serve::batch` computes the `ServeReport` TTFT /
//! inter-token percentiles with it directly.
//!
//! Disabled (the default), every probe is one relaxed atomic load.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Registry>> = Mutex::new(None);

/// Linear sub-buckets per power of two.
const SUB: usize = 16;
/// 16 exact values + 60 sub-bucketed exponents (2^4 … 2^63).
const BUCKETS: usize = SUB + (64 - 4) * SUB;

/// Fixed-bucket log2 histogram over `u64` values (µs, bytes, shapes …).
#[derive(Debug, Clone)]
pub struct Hist {
    counts: Vec<u64>,
    n: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist { counts: vec![0; BUCKETS], n: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    fn bucket(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let e = 63 - v.leading_zeros() as usize; // 2^e <= v < 2^(e+1), e >= 4
        let sub = ((v >> (e - 4)) & 15) as usize;
        SUB + (e - 4) * SUB + sub
    }

    /// Lower bound of bucket `idx` — the value [`Hist::percentile`]
    /// reports for ranks landing in that bucket.
    fn bucket_floor(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let e = (idx - SUB) / SUB + 4;
        let sub = (idx % SUB) as u64;
        (1u64 << e) + (sub << (e - 4))
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Hist::bucket(v)] += 1;
        self.n += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn min(&self) -> u64 {
        if self.n == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Value at percentile `p` ∈ [0, 100]: the highest value representable
    /// by the bucket holding the rank-⌈p·n/100⌉ sample (the HdrHistogram
    /// convention), clamped into `[min, max]` so degenerate distributions
    /// report exact values. 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.n == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let hi = if idx + 1 < BUCKETS {
                    Hist::bucket_floor(idx + 1) - 1
                } else {
                    u64::MAX
                };
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// `{count, min, max, mean, p50, p90, p95, p99}` for the run record.
    pub fn summary_json(&self) -> Json {
        Json::obj()
            .set("count", self.n as f64)
            .set("min", self.min() as f64)
            .set("max", self.max as f64)
            .set("mean", self.mean())
            .set("p50", self.percentile(50.0) as f64)
            .set("p90", self.percentile(90.0) as f64)
            .set("p95", self.percentile(95.0) as f64)
            .set("p99", self.percentile(99.0) as f64)
    }
}

/// A merged view of every thread's recordings, drained by [`snapshot`] /
/// [`export`].
#[derive(Debug, Default)]
pub struct Registry {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub hists: BTreeMap<String, Hist>,
}

impl Registry {
    fn merge(&mut self, other: Registry) {
        for (k, v) in other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
        for (k, v) in other.gauges {
            self.gauges.insert(k, v);
        }
        for (k, h) in other.hists {
            match self.hists.get_mut(&k) {
                Some(mine) => mine.merge(&h),
                None => {
                    self.hists.insert(k, h);
                }
            }
        }
    }
}

/// TLS wrapper whose destructor merges the thread's registry into the
/// global sink (the "merged across workers at flush" discipline).
struct TlsReg(Registry);

impl Drop for TlsReg {
    fn drop(&mut self) {
        let mine = std::mem::take(&mut self.0);
        let mut sink = SINK.lock().unwrap();
        sink.get_or_insert_with(Registry::default).merge(mine);
    }
}

thread_local! {
    static REG: RefCell<TlsReg> = RefCell::new(TlsReg(Registry::default()));
}

/// Turn the registry on (idempotent).
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Whether metrics are being recorded — the one-branch hot-path gate.
#[inline]
pub fn on() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Add `n` to counter `name`.
#[inline]
pub fn add(name: &str, n: u64) {
    if on() {
        REG.with(|r| *r.borrow_mut().0.counters.entry(name.to_string()).or_insert(0) += n);
    }
}

/// Set gauge `name` (last write wins; coordinator-thread use).
#[inline]
pub fn gauge(name: &str, v: f64) {
    if on() {
        REG.with(|r| {
            r.borrow_mut().0.gauges.insert(name.to_string(), v);
        });
    }
}

/// Record one value into histogram `name`.
#[inline]
pub fn hist(name: &str, v: u64) {
    if on() {
        REG.with(|r| r.borrow_mut().0.hists.entry(name.to_string()).or_default().record(v));
    }
}

/// Bulk-record into histogram `name` (one map lookup for the batch); the
/// iterator is consumed only while metrics are on.
#[inline]
pub fn hist_many(name: &str, vals: impl IntoIterator<Item = u64>) {
    if on() {
        REG.with(|r| {
            let mut b = r.borrow_mut();
            let h = b.0.hists.entry(name.to_string()).or_default();
            for v in vals {
                h.record(v);
            }
        });
    }
}

/// Drain and merge every recorded value: the exited-worker sink plus the
/// calling thread's live registry.
pub fn snapshot() -> Registry {
    let mut r = SINK.lock().unwrap().take().unwrap_or_default();
    REG.with(|t| r.merge(std::mem::take(&mut t.borrow_mut().0)));
    r
}

/// Write the machine-readable run record
/// `{cmd, counters, gauges, hists}` and drain the registry.
pub fn export(path: &str, cmd: &str) -> std::io::Result<()> {
    let r = snapshot();
    let mut counters = Json::obj();
    for (k, v) in &r.counters {
        counters = counters.set(k, *v as f64);
    }
    let mut gauges = Json::obj();
    for (k, v) in &r.gauges {
        gauges = gauges.set(k, *v);
    }
    let mut hists = Json::obj();
    for (k, h) in &r.hists {
        hists = hists.set(k, h.summary_json());
    }
    let root = Json::obj()
        .set("cmd", cmd)
        .set("counters", counters)
        .set("gauges", gauges)
        .set("hists", hists);
    std::fs::write(path, root.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests use unique names and never
    // assume exclusive ownership of the sink.

    #[test]
    fn hist_buckets_are_exact_then_log2() {
        for v in 0..16u64 {
            assert_eq!(Hist::bucket_floor(Hist::bucket(v)), v, "small values exact");
        }
        for v in [16u64, 17, 31, 32, 100, 1000, 1 << 20, u64::MAX] {
            let idx = Hist::bucket(v);
            let lo = Hist::bucket_floor(idx);
            assert!(lo <= v, "floor {lo} over {v}");
            // next bucket's floor bounds the relative error at ~1/16
            if idx + 1 < BUCKETS {
                let hi = Hist::bucket_floor(idx + 1);
                assert!(v < hi, "value {v} past bucket [{lo}, {hi})");
            }
        }
    }

    #[test]
    fn percentiles_order_and_clamp() {
        let mut h = Hist::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let (p50, p95, p99) = (h.percentile(50.0), h.percentile(95.0), h.percentile(99.0));
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!((450..=550).contains(&p50), "p50 ~500, got {p50}");
        assert!((900..=1000).contains(&p99), "p99 ~990, got {p99}");
        assert_eq!(h.percentile(100.0), 1000);
        assert_eq!(h.count(), 1000);
        let mut single = Hist::new();
        single.record(777);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(single.percentile(p), 777, "degenerate hist reports the value");
        }
        assert_eq!(Hist::new().percentile(50.0), 0, "empty hist");
    }

    #[test]
    fn merge_is_a_sum() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut whole = Hist::new();
        for v in 0..100u64 {
            if v % 2 == 0 { a.record(v) } else { b.record(v) }
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!((a.min(), a.max()), (whole.min(), whole.max()));
        assert_eq!(a.percentile(50.0), whole.percentile(50.0));
    }

    #[test]
    fn workers_merge_at_thread_exit() {
        enable();
        crate::util::Pool::new(3).run(9, |i| {
            add("metrics_test.tasks", 1);
            hist("metrics_test.idx", i as u64);
            i
        });
        gauge("metrics_test.done", 1.0);
        let r = snapshot();
        assert_eq!(r.counters.get("metrics_test.tasks"), Some(&9));
        assert_eq!(r.hists.get("metrics_test.idx").map(|h| h.count()), Some(9));
        assert_eq!(r.gauges.get("metrics_test.done"), Some(&1.0));
        // put unrelated concurrent state back
        SINK.lock().unwrap().get_or_insert_with(Registry::default).merge(r);
    }
}
