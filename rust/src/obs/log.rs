//! Leveled log facade for CLI diagnostics (DESIGN.md §16).
//!
//! Two levels, both to stderr (stdout is reserved for machine-consumed
//! command output — token lines, report tables):
//!
//! - [`obs_info!`](crate::obs_info) — always prints, with formatting
//!   identical to the bare `eprintln!` it replaced; the default output of
//!   every command stays byte-for-byte what it was before the facade
//!   (pinned by the `run-tests.sh` smokes).
//! - [`obs_debug!`](crate::obs_debug) — prints only when `--verbose` set
//!   the global flag via [`set_verbose`].
//!
//! The flag is a process-global relaxed atomic: the CLI sets it once at
//! startup, before any worker threads exist.

use std::sync::atomic::{AtomicBool, Ordering};

static VERBOSE: AtomicBool = AtomicBool::new(false);

/// Set the global `--verbose` flag (CLI startup, before dispatch).
pub fn set_verbose(v: bool) {
    VERBOSE.store(v, Ordering::Relaxed);
}

/// Whether `obs_debug!` lines print.
#[inline]
pub fn verbose() -> bool {
    VERBOSE.load(Ordering::Relaxed)
}

/// Always-on diagnostic line to stderr — `eprintln!` routed through the
/// facade so every CLI diagnostic shares one chokepoint.
#[macro_export]
macro_rules! obs_info {
    ($($arg:tt)*) => {
        eprintln!($($arg)*)
    };
}

/// Verbose-gated diagnostic line to stderr; prints only after
/// `obs::log::set_verbose(true)` (the `--verbose` flag).
#[macro_export]
macro_rules! obs_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::verbose() {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn verbose_flag_round_trips() {
        // process-global: restore the default so other tests see it off
        super::set_verbose(true);
        assert!(super::verbose());
        super::set_verbose(false);
        assert!(!super::verbose());
    }
}
