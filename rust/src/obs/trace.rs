//! Span tracer with Chrome trace-event export (DESIGN.md §16).
//!
//! **Recording.** A [`span`] guard snapshots the monotonic clock on
//! construction and, on drop, pushes one *complete* event (`ph: "X"`) into
//! the calling thread's buffer; [`instant`] pushes a point event
//! (`ph: "i"`). Buffers are `thread_local!`, so the hot path takes no
//! shared lock. Pool workers are scoped to each `Pool::run` call
//! (`util::pool`): when a worker exits, its buffer drains into the global
//! sink via the TLS destructor, and [`export`] (on the coordinator, after
//! the run) collects the sink plus the coordinator's own live buffer.
//!
//! **Thread rows.** Each recording thread leases the smallest free trace
//! tid and returns it on exit, so concurrently-live threads always get
//! distinct Chrome rows while the thousands of short-lived scoped workers
//! a long run spawns reuse a bounded set of rows (≈ peak concurrency).
//! Nested spans on one thread render as Chrome's stacked slices because
//! a contained span's `[ts, ts+dur]` interval nests inside its parent's.
//!
//! **Off path.** Disabled (the default), [`span`]/[`instant`] cost one
//! relaxed atomic load and a branch — no clock read, no allocation — and
//! recording never re-enables: the contract that tracing cannot perturb
//! what it measures, let alone an output bit.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();
/// Next never-used tid; leased tids recycle through [`FREE_TIDS`].
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static FREE_TIDS: Mutex<Vec<u64>> = Mutex::new(Vec::new());
/// Buffers drained from exited threads, awaiting [`export`].
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());

/// One recorded trace event (a completed span or an instant).
#[derive(Debug, Clone)]
pub struct Event {
    pub name: &'static str,
    pub cat: &'static str,
    /// start (µs since the trace epoch)
    pub ts_us: u64,
    /// span length in µs; instants record 0 and export as `ph: "i"`
    pub dur_us: u64,
    pub tid: u64,
    /// pre-rendered `args` object (built only while tracing is on)
    pub args: Option<Json>,
    instant: bool,
}

struct ThreadCtx {
    tid: u64,
    buf: Vec<Event>,
}

impl Drop for ThreadCtx {
    fn drop(&mut self) {
        if !self.buf.is_empty() {
            SINK.lock().unwrap().append(&mut self.buf);
        }
        FREE_TIDS.lock().unwrap().push(self.tid);
    }
}

thread_local! {
    static CTX: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

/// Lease the smallest tid not currently held by a live thread.
fn acquire_tid() -> u64 {
    let mut free = FREE_TIDS.lock().unwrap();
    if free.is_empty() {
        return NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    let mut at = 0;
    for i in 1..free.len() {
        if free[i] < free[at] {
            at = i;
        }
    }
    free.swap_remove(at)
}

/// Turn the tracer on (idempotent). The first call pins the trace epoch;
/// timestamps are µs since that instant.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Whether spans are being recorded — the one-branch hot-path gate.
#[inline]
pub fn on() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// µs since the trace epoch (pins the epoch on first use).
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

fn record(mut ev: Event) {
    CTX.with(|c| {
        let mut c = c.borrow_mut();
        let ctx = c.get_or_insert_with(|| ThreadCtx { tid: acquire_tid(), buf: Vec::new() });
        ev.tid = ctx.tid;
        ctx.buf.push(ev);
    });
}

/// RAII span guard: records one complete event from construction to drop.
/// Inactive (when tracing is off) it is a two-word no-op.
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start_us: u64,
    args: Option<Json>,
    live: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let end = now_us();
        record(Event {
            name: self.name,
            cat: self.cat,
            ts_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            tid: 0,
            args: self.args.take(),
            instant: false,
        });
    }
}

/// Open a span; hold the guard for the region's lifetime
/// (`let _sp = trace::span(..)` — never `let _ =`, which drops at once).
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !on() {
        return Span { name, cat, start_us: 0, args: None, live: false };
    }
    Span { name, cat, start_us: now_us(), args: None, live: true }
}

/// [`span`] with an args object; the closure runs only while tracing is
/// on, so arg construction is free on the disabled path.
#[inline]
pub fn span_with(cat: &'static str, name: &'static str, args: impl FnOnce() -> Json) -> Span {
    if !on() {
        return Span { name, cat, start_us: 0, args: None, live: false };
    }
    Span { name, cat, start_us: now_us(), args: Some(args()), live: true }
}

/// Record a point event (cache hit, page eviction, accept/reject …).
#[inline]
pub fn instant(cat: &'static str, name: &'static str) {
    if on() {
        record(Event { name, cat, ts_us: now_us(), dur_us: 0, tid: 0, args: None, instant: true });
    }
}

/// [`instant`] with an args object (closure evaluated only when on).
#[inline]
pub fn instant_with(cat: &'static str, name: &'static str, args: impl FnOnce() -> Json) {
    if on() {
        record(Event {
            name,
            cat,
            ts_us: now_us(),
            dur_us: 0,
            tid: 0,
            args: Some(args()),
            instant: true,
        });
    }
}

/// Drain every recorded event: the exited-thread sink plus the calling
/// thread's live buffer (the coordinator's — scoped workers have already
/// drained through their TLS destructors by the time the caller is back).
pub fn take_events() -> Vec<Event> {
    let mut out = std::mem::take(&mut *SINK.lock().unwrap());
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            out.append(&mut ctx.buf);
        }
    });
    out
}

/// Write the Chrome trace-event file: a `traceEvents` array of `"X"`
/// (complete) and `"i"` (instant) events plus one `thread_name` metadata
/// row per tid, all under `pid` 1. Drains the recorded events.
pub fn export(path: &str) -> std::io::Result<()> {
    let mut events = take_events();
    // stable render order: by row, then start, widest-first so a parent
    // slice precedes the children it contains
    events.sort_by_key(|e| (e.tid, e.ts_us, u64::MAX - e.dur_us));
    let my_tid = CTX.with(|c| c.borrow().as_ref().map(|ctx| ctx.tid));
    let mut rows: Vec<Json> = Vec::with_capacity(events.len() + 4);
    let mut tids: Vec<u64> = events.iter().map(|e| e.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for &tid in &tids {
        // the exporting thread is the coordinator; everything else is a
        // (recycled) pool-worker row
        let name = if Some(tid) == my_tid { "main".to_string() } else { format!("worker-{tid}") };
        rows.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "thread_name")
                .set("pid", 1usize)
                .set("tid", tid as usize)
                .set("args", Json::obj().set("name", name)),
        );
    }
    for e in events {
        let mut o = Json::obj()
            .set("name", e.name)
            .set("cat", e.cat)
            .set("ph", if e.instant { "i" } else { "X" })
            .set("ts", e.ts_us as usize)
            .set("pid", 1usize)
            .set("tid", e.tid as usize);
        if e.instant {
            o = o.set("s", "t");
        } else {
            o = o.set("dur", e.dur_us as usize);
        }
        if let Some(a) = e.args {
            o = o.set("args", a);
        }
        rows.push(o);
    }
    let root = Json::obj().set("traceEvents", Json::Arr(rows)).set("displayTimeUnit", "ms");
    std::fs::write(path, root.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The tracer is process-global and cargo test is multi-threaded, so
    // assertions filter by this test's own span names instead of assuming
    // exclusive ownership of the sink.

    #[test]
    fn disabled_spans_record_nothing_under_their_names() {
        let was_on = on();
        {
            let _sp = span("test", "trace_test_never_on");
            instant("test", "trace_test_never_on_i");
        }
        // enabling is monotonic, so "off before and after" proves the
        // tracer was off at both recording sites; a concurrent test may
        // have enabled it mid-run, in which case there is nothing to check
        let still_off = !on();
        let evs = take_events();
        if !was_on && still_off {
            assert!(
                evs.iter().all(|e| !e.name.starts_with("trace_test_never_on")),
                "disabled tracer must not record"
            );
        }
        // put unrelated concurrent events back for their own test/export
        SINK.lock().unwrap().extend(evs);
    }

    #[test]
    fn spans_nest_and_instants_mark() {
        enable();
        assert!(on());
        {
            let _outer = span_with("test", "trace_test_outer", || Json::obj().set("k", 3usize));
            {
                let _inner = span("test", "trace_test_inner");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            instant("test", "trace_test_mark");
        }
        let evs = take_events();
        let find = |n: &str| evs.iter().find(|e| e.name == n).cloned();
        let outer = find("trace_test_outer").expect("outer recorded");
        let inner = find("trace_test_inner").expect("inner recorded");
        let mark = find("trace_test_mark").expect("instant recorded");
        assert_eq!(outer.tid, inner.tid, "same thread, same row");
        assert!(outer.ts_us <= inner.ts_us, "parent starts first");
        assert!(
            inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us,
            "child interval nests inside the parent"
        );
        assert!(mark.instant && mark.dur_us == 0);
        assert!(outer.args.is_some() && inner.args.is_none());
        SINK.lock().unwrap().extend(evs);
    }

    #[test]
    fn worker_buffers_drain_on_thread_exit_with_distinct_tids() {
        enable();
        crate::util::Pool::new(3).run(6, |i| {
            let _sp = span("test", "trace_test_pool_task");
            i
        });
        let evs = take_events();
        let mine: Vec<&Event> =
            evs.iter().filter(|e| e.name == "trace_test_pool_task").collect();
        assert_eq!(mine.len(), 6, "every task span drained through the TLS destructor");
        // same-tid events must not overlap: a tid lease is exclusive
        // while its thread lives, and is only recycled after it exits
        for a in &mine {
            for b in &mine {
                if !std::ptr::eq(*a, *b) && a.tid == b.tid {
                    assert!(
                        a.ts_us + a.dur_us <= b.ts_us || b.ts_us + b.dur_us <= a.ts_us,
                        "same-row task spans overlap"
                    );
                }
            }
        }
        SINK.lock().unwrap().extend(evs);
    }

    #[test]
    fn export_writes_loadable_json() {
        enable();
        {
            let _sp = span("test", "trace_test_export");
        }
        let dir = std::env::temp_dir().join(format!("rsq_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        export(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with('{') && body.ends_with('}'));
        assert!(body.contains("\"traceEvents\""));
        assert!(body.contains("trace_test_export"));
        assert!(body.contains("thread_name"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
