//! Zero-dependency observability: span tracer, metrics registry, and a
//! leveled log facade (DESIGN.md §16).
//!
//! Three small pieces, all built on the standard library only (the vendor
//! set has no tracing/metrics crates):
//!
//! - [`trace`] — hierarchical wall-clock spans and instant events with
//!   thread-aware IDs, recorded into per-thread buffers (no shared lock on
//!   the hot path) and exported as Chrome trace-event JSON
//!   (`--trace PATH`; loads in Perfetto / `chrome://tracing`).
//! - [`metrics`] — a named counter/gauge/histogram registry. Histograms
//!   are fixed-bucket log2 (16 linear sub-buckets per power of two, ~6 %
//!   value resolution); per-thread registries merge into a global sink at
//!   thread exit and the whole registry exports as a machine-readable run
//!   record (`--metrics PATH`).
//! - [`log`] — the `obs_info!`/`obs_debug!` facade behind every CLI
//!   diagnostic `eprintln!`: info always prints (byte-identical to the
//!   pre-facade output), debug prints only under `--verbose`.
//!
//! **Zero-bit-drift contract.** Instrumentation only *observes*: span
//! guards read a monotonic clock and push into thread-local buffers, never
//! touching the data path, so tracing on or off cannot change a single
//! output bit — pinned by the trace-on-vs-off identity tests
//! (`tests/integration_obs.rs`, `serve::batch` tests). When both tracer
//! and metrics are off (the default), every probe is one relaxed atomic
//! load and a branch.

pub mod log;
pub mod metrics;
pub mod trace;
