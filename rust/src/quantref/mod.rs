//! Pure-rust reference quantizers (RTN + GPTQ).
//!
//! An independent oracle for the HLO solver: rust/tests/prop_quant.rs
//! property-tests `runtime` GPTQ results against this implementation on
//! random instances. Mirrors python/compile/quantizer.py exactly (same
//! grid, same dampening, same Cholesky route).

use crate::tensor::linalg::hinv_cholesky_upper;
use crate::tensor::{kernels, Tensor};

/// Round half-to-even, matching `jnp.round` in quantizer.py — rust's
/// `f32::round` rounds halves away from zero, which would diverge from
/// the HLO solver on exact `.5` ties (and a diverged `zero` shifts every
/// recovered pack code; see `tensor::pack`). The `(x/2).round()*2` trick
/// is exact: halving turns every half-integer tie into a quarter, which
/// `round` resolves toward the even neighbor's half.
pub fn round_ties_even(x: f32) -> f32 {
    if (x - x.trunc()).abs() == 0.5 {
        (x / 2.0).round() * 2.0
    } else {
        x.round()
    }
}

/// Per-row asymmetric min-max grid: returns (scale, zero) per row.
pub fn row_grid(w: &Tensor, maxq: f32) -> (Vec<f32>, Vec<f32>) {
    let rows = w.rows();
    let mut scale = Vec::with_capacity(rows);
    let mut zero = Vec::with_capacity(rows);
    for i in 0..rows {
        let row = w.row(i);
        let lo = row.iter().cloned().fold(0.0f32, f32::min);
        let hi = row.iter().cloned().fold(0.0f32, f32::max);
        let s = ((hi - lo) / maxq).max(1e-8);
        scale.push(s);
        zero.push(round_ties_even(-lo / s));
    }
    (scale, zero)
}

fn quant_one(v: f32, s: f32, z: f32, maxq: f32) -> f32 {
    let q = (round_ties_even(v / s) + z).clamp(0.0, maxq);
    s * (q - z)
}

/// Round-to-nearest baseline: per-row grid quantize-dequantize.
pub fn rtn(w: &Tensor, maxq: f32) -> Tensor {
    let (scale, zero) = row_grid(w, maxq);
    let mut out = w.clone();
    for i in 0..w.rows() {
        for v in out.row_mut(i) {
            *v = quant_one(*v, scale[i], zero[i], maxq);
        }
    }
    out
}

/// GPTQ: column-by-column quantization with OBC error feedback through the
/// Cholesky factor of (H + damp·mean(diag)·I)⁻¹. Returns (Q, err) with err
/// the Hessian-weighted loss tr((W-Q) H (W-Q)ᵀ), same contract as the HLO
/// `gptq_*` modules.
pub fn gptq(w: &Tensor, h: &Tensor, maxq: f32, damp: f32) -> (Tensor, f32) {
    // the oracle stays single-threaded by design (no pool): it is the
    // fixed point the pool-parallel paths are tested against
    let u = hinv_cholesky_upper(h, damp, None);
    gptq_with_factor(w, h, &u, maxq)
}

/// [`gptq`] with the Cholesky factor `u = hinv_cholesky_upper(h, damp)`
/// supplied by the caller. The factor does not depend on the bit width,
/// so multi-width scoring (`quant::alloc`) factors once per module and
/// re-solves per width; `gptq(w, h, maxq, damp)` is exactly
/// `gptq_with_factor(w, h, &hinv_cholesky_upper(h, damp, None), maxq)`.
pub fn gptq_with_factor(w: &Tensor, h: &Tensor, u: &Tensor, maxq: f32) -> (Tensor, f32) {
    let (rows, din) = (w.rows(), w.cols());
    assert_eq!(h.rows(), din);
    let (scale, zero) = row_grid(w, maxq);
    let mut wc = w.clone();
    let mut q = Tensor::zeros(&[rows, din]);
    for i in 0..din {
        let uii = u.at2(i, i);
        for r in 0..rows {
            let wv = wc.at2(r, i);
            let deq = quant_one(wv, scale[r], zero[r], maxq);
            q.set2(r, i, deq);
            let err = (wv - deq) / uii;
            // propagate into not-yet-quantized columns
            let urow = u.row(i);
            let wrow = wc.row_mut(r);
            for j in (i + 1)..din {
                wrow[j] -= err * urow[j];
            }
        }
    }
    let err = hessian_weighted_err(w, &q, h);
    (q, err)
}

/// tr((W-Q) H (W-Q)ᵀ) — the layer-reconstruction objective (paper Sec. 3.3).
pub fn hessian_weighted_err(w: &Tensor, q: &Tensor, h: &Tensor) -> f32 {
    let diff = q.sub(w);
    let dh = kernels::gemm(&diff, h, None);
    dh.data.iter().zip(&diff.data).map(|(a, b)| a * b).sum()
}

/// Assemble H = 2 Σ r² x xᵀ host-side (reference for the Pallas kernel).
pub fn hessian_scaled(x: &[Vec<f32>], r: &[f32]) -> Tensor {
    let k = x[0].len();
    let mut h = Tensor::zeros(&[k, k]);
    for (xi, &ri) in x.iter().zip(r) {
        let w = 2.0 * ri * ri;
        for a in 0..k {
            let xa = xi[a] * w;
            if xa == 0.0 {
                continue;
            }
            let row = &mut h.data[a * k..(a + 1) * k];
            for b in 0..k {
                row[b] += xa * xi[b];
            }
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    fn hess(din: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed);
        let x: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..din).map(|_| rng.normal()).collect())
            .collect();
        let r = vec![1.0f32; n];
        hessian_scaled(&x, &r)
    }

    #[test]
    fn rounding_matches_jnp_round() {
        // jnp.round is half-to-even; f32::round is half-away — the exact
        // tie cases are where they differ
        for (x, want) in [
            (0.5f32, 0.0f32),
            (1.5, 2.0),
            (2.5, 2.0),
            (3.5, 4.0),
            (-0.5, -0.0),
            (-1.5, -2.0),
            (-2.5, -2.0),
            (1.3, 1.0),
            (1.7, 2.0),
            (-1.7, -2.0),
            (7.0, 7.0),
        ] {
            assert_eq!(round_ties_even(x), want, "x={x}");
        }
    }

    #[test]
    fn rtn_levels_bounded() {
        let mut rng = Pcg::new(0);
        let w = Tensor::randn(&[8, 32], 1.0, &mut rng);
        let q = rtn(&w, 7.0);
        for i in 0..8 {
            let mut lv: Vec<f32> = q.row(i).to_vec();
            lv.sort_by(f32::total_cmp);
            lv.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
            assert!(lv.len() <= 8, "{}", lv.len());
        }
    }

    #[test]
    fn rtn_high_bits_lossless() {
        let mut rng = Pcg::new(1);
        let w = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let q = rtn(&w, 65535.0);
        assert!(q.allclose(&w, 1e-3));
    }

    #[test]
    fn gptq_beats_rtn() {
        let mut rng = Pcg::new(2);
        let w = Tensor::randn(&[16, 24], 1.0, &mut rng);
        let h = hess(24, 200, 3);
        let (_, err_gptq) = gptq(&w, &h, 7.0, 0.01);
        let q_rtn = rtn(&w, 7.0);
        let err_rtn = hessian_weighted_err(&w, &q_rtn, &h);
        assert!(err_gptq <= err_rtn * 1.001, "{err_gptq} !<= {err_rtn}");
    }

    #[test]
    fn gptq_error_monotone_in_bits() {
        let mut rng = Pcg::new(4);
        let w = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let h = hess(16, 150, 5);
        let errs: Vec<f32> = [3.0, 7.0, 15.0, 255.0]
            .iter()
            .map(|&mq| gptq(&w, &h, mq, 0.01).1)
            .collect();
        assert!(errs[0] >= errs[1] && errs[1] >= errs[2] && errs[2] >= errs[3], "{errs:?}");
    }

    #[test]
    fn gptq_high_bits_lossless() {
        let mut rng = Pcg::new(6);
        let w = Tensor::randn(&[4, 8], 1.0, &mut rng);
        let h = hess(8, 64, 7);
        let (q, err) = gptq(&w, &h, 1_048_575.0, 0.01);
        assert!(q.allclose(&w, 1e-3));
        assert!(err < 1e-2, "{err}");
    }

    #[test]
    fn hessian_scaled_matches_direct() {
        let mut rng = Pcg::new(8);
        let x: Vec<Vec<f32>> = (0..10).map(|_| (0..4).map(|_| rng.normal()).collect()).collect();
        let r: Vec<f32> = (0..10).map(|_| rng.f32()).collect();
        let h = hessian_scaled(&x, &r);
        for a in 0..4 {
            for b in 0..4 {
                let want: f32 = x
                    .iter()
                    .zip(&r)
                    .map(|(xi, &ri)| 2.0 * ri * ri * xi[a] * xi[b])
                    .sum();
                assert!((h.at2(a, b) - want).abs() < 1e-4);
            }
        }
    }
}
