//! Performance profiling driver (`rsq perf`) — the L3 side of the perf
//! deliverable. Times every stage of the RSQ pipeline, sweeps the parallel
//! scheduler's `--jobs` values, sweeps the host kernel layer (tiled GEMM
//! sizes × jobs, serial-vs-pooled speedup — DESIGN.md §10), compares the
//! reference and simd kernel backends per shape (DESIGN.md §13), measures
//! the serving layer's packed-domain decode tokens/s (DESIGN.md §11), prints
//! the engine's per-module breakdown, and reports end-to-end throughput.
//! Results feed DESIGN.md §Perf.

use std::time::Instant;

use anyhow::Result;

use crate::corpus::CorpusKind;
use crate::eval::score_model;
use crate::quant::{quantize, BitBudget, Method, QuantOptions, SchedMode};
use crate::tensor::{kernels, Tensor};
use crate::util::{json::Json, Args, Bench, Pcg, Pool};

use super::{print_header, write_record, Ctx};

pub fn perf(args: &Args) -> Result<()> {
    print_header("Performance profile", "DESIGN.md §Perf");
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let cfg = ctx.engine.config().clone();
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let calib = ctx.calib(CorpusKind::Wiki, calib_n, t, 0);
    let tokens = calib.total_tokens();

    // warm the compile cache so timings below are pure execution
    let opts = QuantOptions::new(Method::Rsq, 3, t);
    let (_, first) = quantize(&ctx.engine, &ctx.params, &calib, &opts)?;
    println!(
        "cold end-to-end RSQ quantization: {:.2}s ({} calib tokens, {} layers)",
        first.wall_seconds, tokens, cfg.layers
    );

    let mut results = Vec::new();
    for method in [Method::Rtn, Method::Gptq, Method::QuaRot, Method::Rsq, Method::RsqVq] {
        let o = QuantOptions::new(method, if method.vector_quant() { 2 } else { 3 }, t);
        let t0 = Instant::now();
        let iters = args.usize_or("iters", 3);
        for _ in 0..iters {
            quantize(&ctx.engine, &ctx.params, &calib, &o)?;
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{:<10} warm quantization: {:>8.3}s  ({:.1} ktok/s calibration throughput)",
            method.name(),
            per,
            tokens as f64 / per / 1e3
        );
        results.push(
            Json::obj()
                .set("method", method.name())
                .set("seconds", per)
                .set("ktok_per_s", tokens as f64 / per / 1e3),
        );
    }

    // scheduler scaling: the same RSQ run across worker counts AND the
    // two cross-layer executors. Every combination is bit-identical
    // (tested in integration_pipeline); only the wall clock moves. The
    // staged/pipelined ratio at equal jobs is the per-layer barrier +
    // round-trip cost the fused executor eliminates (DESIGN.md §5).
    println!("\n--- scheduler scaling (rsq, --jobs x --sched sweep) ---");
    let mut sweep = vec![1usize, 2, 4];
    sweep.push(args.jobs());
    sweep.sort_unstable();
    sweep.dedup();
    let mut jobs_results = Vec::new();
    let mut serial_s = 0.0f64;
    for jobs in sweep {
        let mut secs_by_mode = [0.0f64; 2];
        for (k, mode) in [SchedMode::Staged, SchedMode::Pipelined].into_iter().enumerate() {
            let mut o = QuantOptions::new(Method::Rsq, 3, t);
            o.jobs = jobs;
            o.sched = mode;
            let t0 = Instant::now();
            let (_, rep) = quantize(&ctx.engine, &ctx.params, &calib, &o)?;
            let secs = t0.elapsed().as_secs_f64();
            if jobs == 1 && mode == SchedMode::Staged {
                serial_s = secs;
            }
            secs_by_mode[k] = secs;
            let speedup = if secs > 0.0 && serial_s > 0.0 { serial_s / secs } else { 1.0 };
            println!(
                "sched={:<9} jobs={:<3} {:>8.3}s  speedup {:>5.2}x  \
                 [rotate {:.3}s | pass A {:.3}s | solve {:.3}s | pass B {:.3}s | fused {:.3}s]",
                rep.sched,
                rep.jobs,
                secs,
                speedup,
                rep.rotate_seconds,
                rep.pass_a_seconds,
                rep.solve_seconds,
                rep.pass_b_seconds,
                rep.fused_seconds
            );
            jobs_results.push(
                Json::obj()
                    .set("sched", rep.sched.as_str())
                    .set("jobs", rep.jobs)
                    .set("seconds", secs)
                    .set("speedup", speedup)
                    .set("rotate_s", rep.rotate_seconds)
                    .set("pass_a_s", rep.pass_a_seconds)
                    .set("solve_s", rep.solve_seconds)
                    .set("pass_b_s", rep.pass_b_seconds)
                    .set("fused_s", rep.fused_seconds),
            );
        }
        if secs_by_mode[1] > 0.0 {
            println!(
                "  barrier elimination at jobs={jobs}: pipelined {:.2}x vs staged",
                secs_by_mode[0] / secs_by_mode[1]
            );
        }
    }

    // Host kernel sweep (DESIGN.md §10): the pool-parallel tiled GEMM
    // under the rotate/solve hot paths, sizes × jobs, against its own
    // serial dispatch. Every cell is bit-identical (asserted here on the
    // fly — the §10 determinism contract); only the wall clock moves.
    println!("\n--- host kernel sweep (tensor::kernels gemm, serial vs pooled) ---");
    let mut kernel_results = Vec::new();
    let mut kjobs = vec![1usize, 2, 4];
    kjobs.push(args.jobs());
    kjobs.sort_unstable();
    kjobs.dedup();
    for d in [64usize, 128, 256] {
        let mut rng = Pcg::new(d as u64);
        let a = Tensor::randn(&[d, d], 1.0, &mut rng);
        let b = Tensor::randn(&[d, d], 1.0, &mut rng);
        let iters = (32 * 64 * 64 / (d * d)).max(2);
        let flops = 2.0 * (d * d * d) as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            kernels::gemm(&a, &b, None);
        }
        let serial = t0.elapsed().as_secs_f64() / iters as f64;
        let reference = kernels::gemm(&a, &b, None);
        let mut row = format!(
            "gemm {d:>4}x{d:<4} serial {:>9.1}us ({:>6.2} GFLOP/s) ",
            serial * 1e6,
            flops / serial / 1e9
        );
        let mut cell = Json::obj().set("size", d).set("serial_s", serial);
        for &jobs in &kjobs {
            let pool = Pool::new(jobs);
            let t0 = Instant::now();
            for _ in 0..iters {
                kernels::gemm(&a, &b, Some(&pool));
            }
            let pooled = t0.elapsed().as_secs_f64() / iters as f64;
            assert_eq!(
                kernels::gemm(&a, &b, Some(&pool)).data,
                reference.data,
                "kernel determinism violated at d={d} jobs={jobs}"
            );
            row.push_str(&format!("| j{jobs} {:>5.2}x ", serial / pooled.max(1e-12)));
            cell = cell.set(&format!("jobs{jobs}_speedup"), serial / pooled.max(1e-12));
        }
        println!("{row}");
        kernel_results.push(cell);
    }

    // Backend dispatch (DESIGN.md §13): the same hot shapes through the
    // reference kernels and the runtime-detected AVX2+FMA simd backend.
    // simd reassociates its dot reductions, so cross-backend agreement is
    // tolerance-pinned (prop_kernels owns the bounds), not bit-equality —
    // the reference sweep above remains the bit-exact oracle.
    println!("\n--- backend dispatch (tensor::kernels, reference vs simd) ---");
    let mut backend_results = Vec::new();
    if kernels::simd_available() {
        use crate::tensor::kernels::Backend;
        let pool = Pool::new(args.jobs().max(2));
        for d in [64usize, 128, 256] {
            let mut rng = Pcg::new(d as u64 ^ 0x5eed);
            let a = Tensor::randn(&[d, d], 1.0, &mut rng);
            let b = Tensor::randn(&[d, d], 1.0, &mut rng);
            let iters = (32 * 64 * 64 / (d * d)).max(2);
            let flops = 2.0 * (d * d * d) as f64;
            let time = |be: Backend| {
                let t0 = Instant::now();
                for _ in 0..iters {
                    be.gemm(&a, &b, Some(&pool));
                }
                t0.elapsed().as_secs_f64() / iters as f64
            };
            let ref_s = time(Backend::Reference);
            let simd_s = time(Backend::Simd).max(1e-12);
            println!(
                "gemm     {d:>4}x{d:<4} reference {:>9.1}us  simd {:>9.1}us  \
                 speedup {:>5.2}x ({:>6.2} GFLOP/s)",
                ref_s * 1e6,
                simd_s * 1e6,
                ref_s / simd_s,
                flops / simd_s / 1e9
            );
            backend_results.push(
                Json::obj()
                    .set("kernel", "gemm")
                    .set("size", d)
                    .set("reference_s", ref_s)
                    .set("simd_s", simd_s)
                    .set("speedup", ref_s / simd_s),
            );
        }
        // the serving fused-decode shape (DESIGN.md §11): one activation
        // row against a 3-bit packed weight matrix, the decode inner loop.
        for n in [256usize, 512] {
            let mut rng = Pcg::new(n as u64 ^ 0xdec0de);
            let w = Tensor::randn(&[n, n], 1.0, &mut rng);
            let maxq = 7.0f32;
            let q = crate::quantref::rtn(&w, maxq);
            let (scale, zero) = crate::quantref::row_grid(&w, maxq);
            let grid = crate::tensor::pack::RowGrid { scale, zero };
            let packed = crate::tensor::pack::PackedRows::pack(&q, 3, &grid)
                .expect("rtn output packs exactly");
            let x = Tensor::randn(&[1, n], 1.0, &mut rng);
            let iters = (64 * 256 * 256 / (n * n)).max(8);
            let time = |be: Backend| {
                let t0 = Instant::now();
                for _ in 0..iters {
                    be.deq_gemv(&x.data, &packed, Some(&pool));
                }
                t0.elapsed().as_secs_f64() / iters as f64
            };
            let ref_s = time(Backend::Reference);
            let simd_s = time(Backend::Simd).max(1e-12);
            println!(
                "deq_gemv {n:>4}x{n:<4} reference {:>9.1}us  simd {:>9.1}us  \
                 speedup {:>5.2}x (3-bit packed)",
                ref_s * 1e6,
                simd_s * 1e6,
                ref_s / simd_s
            );
            backend_results.push(
                Json::obj()
                    .set("kernel", "deq_gemv")
                    .set("size", n)
                    .set("reference_s", ref_s)
                    .set("simd_s", simd_s)
                    .set("speedup", ref_s / simd_s),
            );
        }
    } else {
        println!("simd backend unavailable on this host (needs x86-64 AVX2+FMA); sweep skipped");
    }

    // Hessian-cache pass-A elimination (DESIGN.md §9): the same RSQ run
    // cold (cache miss: full pass A/B + store) then warm (key hit: solve
    // only) at IDENTICAL jobs/sched, so the printed speedup measures the
    // cache alone, not worker-count scaling. A third run at different
    // jobs + sched then shows the key ignores both knobs (the counters
    // prove the hit; byte-identity is pinned by integration_artifact).
    println!("\n--- hessian cache (content-addressed pass-A elimination) ---");
    let cache_dir = std::path::Path::new("cache/perf-hessians");
    std::fs::remove_dir_all(cache_dir).ok(); // guarantee a cold first run
    let mut cache_opts = QuantOptions::new(Method::Rsq, 3, t);
    cache_opts.hess_cache = Some(cache_dir.to_path_buf());
    let t0 = Instant::now();
    let (_, cold) = quantize(&ctx.engine, &ctx.params, &calib, &cache_opts)?;
    let cold_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let (_, warm) = quantize(&ctx.engine, &ctx.params, &calib, &cache_opts)?;
    let warm_s = t0.elapsed().as_secs_f64();
    println!(
        "cold (miss): {cold_s:>8.3}s  [pass A {:.3}s | fused {:.3}s | solve {:.3}s]  \
         layers hit {} / miss {} / skip {}",
        cold.pass_a_seconds,
        cold.fused_seconds,
        cold.solve_seconds,
        cold.hess_cache_hits,
        cold.hess_cache_misses,
        cold.hess_cache_skips,
    );
    println!(
        "warm (hit):  {warm_s:>8.3}s  [solve {:.3}s; pass A+B+embed skipped]  \
         layers hit {} / miss {} / skip {}",
        warm.solve_seconds, warm.hess_cache_hits, warm.hess_cache_misses, warm.hess_cache_skips,
    );
    println!(
        "pass-A elimination speedup (equal jobs/sched): {:.2}x (key {})",
        cold_s / warm_s.max(1e-9),
        warm.hess_key,
    );
    cache_opts.jobs = args.jobs().max(2); // hit must survive a jobs change
    cache_opts.sched = SchedMode::Staged; // ... and a sched change
    let (_, cross) = quantize(&ctx.engine, &ctx.params, &calib, &cache_opts)?;
    println!(
        "cross-scheduler reuse at jobs={} sched={}: layers hit {} / miss {} (key unchanged: {})",
        cross.jobs,
        cross.sched,
        cross.hess_cache_hits,
        cross.hess_cache_misses,
        cross.hess_key == warm.hess_key,
    );
    let cache_record = Json::obj()
        .set("cold_s", cold_s)
        .set("warm_s", warm_s)
        .set("speedup", cold_s / warm_s.max(1e-9))
        .set("hits", warm.hess_cache_hits)
        .set("misses", cold.hess_cache_misses)
        .set("cross_sched_hits", cross.hess_cache_hits)
        .set("key", warm.hess_key.as_str());

    // Mixed-precision frontier (DESIGN.md §14): one quantize per budget
    // point, every point sharing ONE Hessian cache entry — the allocator's
    // proxy pass runs at the fixed reference width and the cache key
    // ignores the budget, so the first point pays pass A once and every
    // later point is score + solve only. This is the accuracy-vs-resident-
    // bytes frontier the allocator exists to trace.
    println!("\n--- mixed-precision frontier (--avg-bits sweep, one warm hess cache) ---");
    let frontier_dir = std::path::Path::new("cache/perf-frontier");
    std::fs::remove_dir_all(frontier_dir).ok(); // cold first point
    let mut frontier_cells = Vec::new();
    for avg in [2.0f32, 2.5, 3.0, 3.5, 4.0, 8.0] {
        let mut o = QuantOptions::new(Method::Rsq, 3, t);
        o.hess_cache = Some(frontier_dir.to_path_buf());
        o.alloc = Some(BitBudget::AvgBits(avg));
        let t0 = Instant::now();
        let (q, rep) = quantize(&ctx.engine, &ctx.params, &calib, &o)?;
        let secs = t0.elapsed().as_secs_f64();
        let score = score_model(&ctx.engine, &q, &ctx.eval, t, args.usize_or("probe-n", 8))?;
        println!(
            "avg-bits {avg:<4} -> achieved {:.3} bits  {:>9} packed B  PPL {:>8.3}  \
             acc {:>5.1}%  {:>7.3}s  ({})",
            rep.avg_bits.unwrap_or(f32::NAN),
            rep.packed_bytes.unwrap_or(0),
            score.ppl,
            100.0 * score.mean_acc,
            secs,
            if rep.hess_cache_hits > 0 { "warm: score+solve only" } else { "cold: pass A + store" },
        );
        frontier_cells.push(
            Json::obj()
                .set("budget_avg_bits", avg)
                .set("achieved_avg_bits", rep.avg_bits.unwrap_or(f32::NAN))
                .set("packed_bytes", rep.packed_bytes.unwrap_or(0) as usize)
                .set("ppl", score.ppl)
                .set("mean_acc", score.mean_acc)
                .set("seconds", secs)
                .set("cache_hits", rep.hess_cache_hits),
        );
    }
    std::fs::remove_dir_all(frontier_dir).ok();

    // Serving layer (DESIGN.md §11): packed-domain host decode from the
    // same trained params, RTN-packed at 3 bits host-side. Reports the
    // end-to-end tokens/s number the ROADMAP's serving goal asks for,
    // plus the packed-vs-f32 resident-bytes ratio the fused kernels
    // preserve at decode time.
    println!("\n--- serve layer (packed-domain host decode, tensor/kernels/gemv) ---");
    let serve_model = crate::serve::PackedModel::from_paramset_rtn(&ctx.params, 3)?;
    let (packed_b, dense_b) = serve_model.resident_bytes();
    println!(
        "resident bytes: {packed_b} packed vs {dense_b} f32 ({:.2}x smaller, {} packed weights)",
        dense_b as f64 / packed_b as f64,
        serve_model.packed_weights()
    );
    let mut serve_cells = Vec::new();
    let serve_ctx = serve_model.cfg.max_seq.min(32);
    let mut sjobs = vec![1usize, 4];
    sjobs.push(args.jobs());
    sjobs.sort_unstable();
    sjobs.dedup();
    for batch in [1usize, 4] {
        for &jobs in &sjobs {
            let pool = Pool::new(jobs);
            let mut prng = Pcg::new(17);
            let requests: Vec<crate::serve::ServeRequest> = (0..batch as u64)
                .map(|id| {
                    let prompt =
                        (0..4).map(|_| prng.below(serve_model.cfg.vocab) as i32).collect();
                    crate::serve::ServeRequest::new(id, prompt, serve_ctx.saturating_sub(4).max(1))
                })
                .collect();
            let opts = crate::serve::ServeOptions { max_batch: batch, ..Default::default() };
            let rep = crate::serve::serve(&serve_model, &pool, requests, &opts)?;
            println!(
                "serve batch={batch:<3} jobs={jobs:<3} ctx={serve_ctx:<4} {:>9.1} tok/s \
                 ({} tokens, {} steps)",
                rep.tokens_per_s, rep.generated_tokens, rep.steps
            );
            serve_cells.push(
                Json::obj()
                    .set("batch", batch)
                    .set("jobs", jobs)
                    .set("ctx", serve_ctx)
                    .set("tokens_per_s", rep.tokens_per_s)
                    .set("tokens", rep.generated_tokens),
            );
        }
    }
    let serve_record = Json::obj()
        .set("packed_bytes", packed_b)
        .set("dense_bytes", dense_b)
        .set("ratio", dense_b as f64 / packed_b as f64)
        .set("cells", Json::Arr(serve_cells));

    // per-stage micro benches through the engine
    println!("\n--- per-module timings (engine) ---");
    let p_lit: Vec<xla::Literal> = ctx
        .params
        .tensors
        .iter()
        .map(crate::runtime::tensor_literal)
        .collect::<Result<_>>()?;
    let batch: Vec<Vec<i32>> = calib.samples[..cfg.batch].to_vec();
    let tl = crate::runtime::tokens_literal(&batch, t)?;
    let z = ctx
        .engine
        .exec(&format!("embed_t{t}"), &[tl.clone(), p_lit[0].clone(), p_lit[1].clone()])?
        .into_iter()
        .next()
        .unwrap();

    let mut layer_ins = vec![z.clone()];
    for k in 0..9 {
        layer_ins.push(p_lit[2 + k].clone());
    }
    let flops_layer = 2.0 * (cfg.batch * t) as f64
        * (4.0 * (cfg.d * cfg.d) as f64 + 3.0 * (cfg.d * cfg.ff) as f64);
    let mean_s = Bench::new(&format!("layer_fwd_t{t} (B={} d={})", cfg.batch, cfg.d))
        .samples(args.usize_or("bench-samples", 10))
        .iter(|| ctx.engine.exec(&format!("layer_fwd_t{t}"), &layer_ins).unwrap())
        .report();
    println!("    layer_fwd ~ {:.2} GFLOP/s", flops_layer / mean_s / 1e9);

    let outs = ctx.engine.exec(&format!("layer_fwd_t{t}"), &layer_ins)?;
    let r = crate::runtime::tensor_literal(&crate::tensor::Tensor::ones(&[cfg.batch, t]))?;
    let hess_ins = vec![outs[1].clone(), r];
    let hbytes = (cfg.batch * t * cfg.d * 4 + cfg.d * cfg.d * 4) as u64;
    Bench::new(&format!("hess_d_t{t} (pallas hessian)"))
        .samples(args.usize_or("bench-samples", 10))
        .throughput_bytes(hbytes)
        .iter(|| ctx.engine.exec(&format!("hess_d_t{t}"), &hess_ins).unwrap())
        .report();

    let w = crate::tensor::Tensor::randn(
        &[cfg.d, cfg.d], 0.1, &mut crate::util::Pcg::new(0));
    let h = crate::runtime::literal_tensor(
        &ctx.engine.exec(&format!("hess_d_t{t}"), &hess_ins)?[0])?;
    let gptq_ins = vec![
        crate::runtime::tensor_literal(&w)?,
        crate::runtime::tensor_literal(&h)?,
        crate::runtime::scalar_literal(7.0),
        crate::runtime::scalar_literal(0.01),
    ];
    Bench::new(&format!("gptq_{0}x{0} (column solve)", cfg.d))
        .samples(args.usize_or("bench-samples", 10))
        .throughput_elements((cfg.d * cfg.d) as u64)
        .iter(|| ctx.engine.exec(&format!("gptq_{0}x{0}", cfg.d), &gptq_ins).unwrap())
        .report();

    ctx.engine.print_stats();
    write_record(
        "perf",
        Json::obj()
            .set("methods", Json::Arr(results))
            .set("jobs_sweep", Json::Arr(jobs_results))
            .set("kernel_sweep", Json::Arr(kernel_results))
            .set("backend_sweep", Json::Arr(backend_results))
            .set("hess_cache", cache_record)
            .set("frontier", Json::Arr(frontier_cells))
            .set("serve", serve_record),
    )
}
