//! Table drivers (paper Tabs. 1-7).

use anyhow::Result;

use crate::corpus::CorpusKind;
use crate::eval::tasks::mean_accuracy;
use crate::eval::{longctx_suite, probe_suite};
use crate::quant::{Method, QuantOptions, Strategy};
use crate::util::{json::Json, mean, Args};

use super::{
    cell, full_model_ppl, print_header, run_seeds, seeded, write_record, Ctx,
};

fn probe_avg(ctx: &Ctx, params: &crate::model::ParamSet, t: usize, n: usize) -> Result<f64> {
    Ok(mean_accuracy(&probe_suite(&ctx.engine, params, t, 3, n)?))
}

/// Tab. 1: quantize with the reconstruction loss restricted to one quarter
/// of the token positions at a time (the paper's motivating observation).
pub fn table1(args: &Args) -> Result<()> {
    print_header(
        "Table 1 — token-subset ablation (all vs. chunks 1-4)",
        "Tab. 1: 1st chunk beats all-tokens; later chunks are worse",
    );
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let probe_n = args.usize_or("probe-n", 32);
    let bits = args.usize_or("bits", 3) as u32;

    let full = full_model_ppl(&ctx, t)?;
    println!("{:<14} {:>14} {:>14}", "Used tokens", "Wiki PPL", "Avg Acc (%)");
    println!("{:<14} {:>14.3} {:>14}", "Full model", full, "-");

    let mut rows = Vec::new();
    let variants: Vec<(String, Strategy)> = std::iter::once(("All".to_string(), Strategy::Uniform))
        .chain((1..=4).map(|k| (format!("chunk {k}/4"), Strategy::Chunk { index: k, of: 4 })))
        .collect();
    for (label, strat) in &variants {
        let mut ppls = Vec::new();
        let mut accs = Vec::new();
        for s in run_seeds(args) {
            let mut opts = seeded(QuantOptions::new(Method::Rsq, bits, t), s);
            opts.strategy = *strat;
            let calib = ctx.calib(CorpusKind::Wiki, calib_n, t, s);
            let (q, ppl) = ctx.quant_ppl(&opts, &calib, t)?;
            ppls.push(ppl);
            accs.push(100.0 * probe_avg(&ctx, &q, t, probe_n)?);
        }
        println!("{:<14} {:>14} {:>14}", label, cell(&ppls, 3), cell(&accs, 1));
        rows.push(
            Json::obj()
                .set("label", label.as_str())
                .set("ppl", ppls.clone())
                .set("acc", accs.clone()),
        );
    }
    write_record(
        "table1",
        Json::obj().set("config", config).set("full_ppl", full).set("rows", rows),
    )
}

/// Tab. 2: the main battery — GPTQ vs QuaRot vs RSQ on three model
/// families, Wiki PPL + ten downstream probes.
pub fn table2(args: &Args) -> Result<()> {
    print_header(
        "Table 2 — main comparison on three model families",
        "Tab. 2: RSQ beats QuaRot beats GPTQ on PPL and avg accuracy",
    );
    let configs = args.list_or("configs", &["s1", "s2", "s3"]);
    let bits = args.usize_or("bits", 3) as u32;
    let probe_n = args.usize_or("probe-n", 32);
    let calib_n = args.usize_or("calib-n", 16);
    let mut records = Vec::new();
    for config in &configs {
        let ctx = Ctx::prepare(config, args)?;
        let t = *ctx.engine.config().seq_lens.iter().max().unwrap().min(&128);
        println!("\n--- model family {config} (d={}, L={}) ---",
            ctx.engine.config().d, ctx.engine.config().layers);
        // full model row
        let full_ppl = full_model_ppl(&ctx, t)?;
        let full_probes = probe_suite(&ctx.engine, &ctx.params, t, 3, probe_n)?;
        let names: Vec<&str> = full_probes.iter().map(|p| p.name).collect();
        println!("{:<8} {:>10} {}", "Method", "WikiPPL", names.join(" "));
        let accs: Vec<String> =
            full_probes.iter().map(|p| format!("{:.1}", 100.0 * p.accuracy)).collect();
        println!(
            "{:<8} {:>10.3} {}  | avg {:.1}",
            "Full", full_ppl, accs.join("        "),
            100.0 * mean_accuracy(&full_probes)
        );
        for method in [Method::Gptq, Method::QuaRot, Method::Rsq] {
            let mut ppls = Vec::new();
            let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); 10];
            let mut avgs = Vec::new();
            for s in run_seeds(args) {
                let opts = seeded(QuantOptions::new(method, bits, t), s);
                let calib = ctx.calib(CorpusKind::Wiki, calib_n, t, s);
                let (q, ppl) = ctx.quant_ppl(&opts, &calib, t)?;
                ppls.push(ppl);
                let probes = probe_suite(&ctx.engine, &q, t, 3, probe_n)?;
                for (i, p) in probes.iter().enumerate() {
                    per_task[i].push(100.0 * p.accuracy);
                }
                avgs.push(100.0 * mean_accuracy(&probes));
            }
            let task_cells: Vec<String> =
                per_task.iter().map(|v| cell(v, 1)).collect();
            println!(
                "{:<8} {:>10} {}  | avg {}",
                method.name(), cell(&ppls, 3), task_cells.join(" "), cell(&avgs, 1)
            );
            records.push(
                Json::obj()
                    .set("config", config.as_str())
                    .set("method", method.name())
                    .set("ppl", ppls)
                    .set("avg_acc", avgs)
                    .set("tasks", Json::Arr(names.iter().map(|&n| Json::from(n)).collect()))
                    .set(
                        "task_acc",
                        Json::Arr(per_task.iter().map(|v| Json::from(v.clone())).collect()),
                    ),
            );
        }
    }
    write_record("table2", Json::obj().set("rows", Json::Arr(records)))
}

/// Tab. 3: long-context probe battery under three calibration
/// (samples x seq-len) configurations with a fixed token budget.
pub fn table3(args: &Args) -> Result<()> {
    print_header(
        "Table 3 — long-context tasks, three calibration configurations",
        "Tab. 3: RSQ beats QuaRot on nearly all long-context benchmarks",
    );
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let eval_t = *ctx.engine.config().seq_lens.iter().max().unwrap();
    let lc_n = args.usize_or("lc-n", 24);
    let bits = args.usize_or("bits", 3) as u32;
    // fixed token budget, like the paper's 256x4096 / 512x2048 / 1024x1024
    let calib_cfgs = [(8usize, 256usize), (16, 128), (32, 64)];

    // full model row
    let full = longctx_suite(&ctx.engine, &ctx.params, eval_t, 3, lc_n)?;
    let names: Vec<String> = full.iter().map(|r| r.name.clone()).collect();
    println!("{:<10} {}", "Method", names.join(" "));
    let f: Vec<String> = full.iter().map(|r| format!("{:.1}", 100.0 * r.score)).collect();
    println!("{:<10} {}", "Full", f.join("  "));

    let mut records = Vec::new();
    for (n, t) in calib_cfgs {
        if !ctx.engine.config().seq_lens.contains(&t) {
            continue;
        }
        println!("--- calibration: {n} samples x {t} tokens ---");
        for method in [Method::QuaRot, Method::Rsq] {
            let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); names.len()];
            let mut avgs = Vec::new();
            for s in run_seeds(args) {
                let opts = seeded(QuantOptions::new(method, bits, t), s);
                let calib = ctx.calib(CorpusKind::Wiki, n, t, s);
                let (q, _) =
                    crate::quant::quantize(&ctx.engine, &ctx.params, &calib, &ctx.with_jobs(opts))?;
                let res = longctx_suite(&ctx.engine, &q, eval_t, 3, lc_n)?;
                for (i, r) in res.iter().enumerate() {
                    per_task[i].push(100.0 * r.score);
                }
                avgs.push(100.0 * mean(&res.iter().map(|r| r.score).collect::<Vec<_>>()));
            }
            let cells: Vec<String> = per_task.iter().map(|v| cell(v, 1)).collect();
            println!("{:<10} {}  | avg {}", method.name(), cells.join("  "), cell(&avgs, 1));
            records.push(
                Json::obj()
                    .set("calib_n", n)
                    .set("calib_t", t)
                    .set("method", method.name())
                    .set("tasks", Json::Arr(names.iter().map(|n| Json::from(n.as_str())).collect()))
                    .set("scores", Json::Arr(per_task.iter().map(|v| Json::from(v.clone())).collect()))
                    .set("avg", avgs),
            );
        }
    }
    write_record("table3", Json::obj().set("rows", Json::Arr(records)))
}

/// Tab. 4: calibration-corpus ablation (Wiki / RedPajama / C4 / PTB).
pub fn table4(args: &Args) -> Result<()> {
    print_header(
        "Table 4 — calibration dataset ablation",
        "Tab. 4: RSQ beats QuaRot for every calibration corpus",
    );
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let probe_n = args.usize_or("probe-n", 32);
    let bits = args.usize_or("bits", 3) as u32;
    println!("{:<10} {:<10} {:>14} {:>14}", "Corpus", "Method", "Wiki PPL", "Avg Acc (%)");
    let mut records = Vec::new();
    for kind in CorpusKind::ALL {
        for method in [Method::QuaRot, Method::Rsq] {
            let mut ppls = Vec::new();
            let mut accs = Vec::new();
            for s in run_seeds(args) {
                let opts = seeded(QuantOptions::new(method, bits, t), s);
                let calib = ctx.calib(kind, calib_n, t, s);
                let (q, ppl) = ctx.quant_ppl(&opts, &calib, t)?;
                ppls.push(ppl);
                accs.push(100.0 * probe_avg(&ctx, &q, t, probe_n)?);
            }
            println!(
                "{:<10} {:<10} {:>14} {:>14}",
                kind.name(), method.name(), cell(&ppls, 3), cell(&accs, 1)
            );
            records.push(
                Json::obj()
                    .set("corpus", kind.name())
                    .set("method", method.name())
                    .set("ppl", ppls)
                    .set("acc", accs),
            );
        }
    }
    write_record("table4", Json::obj().set("rows", Json::Arr(records)))
}

/// Tab. 5: bit-precision ablation (4 / 3 / 2 bits).
pub fn table5(args: &Args) -> Result<()> {
    print_header(
        "Table 5 — bit precision ablation",
        "Tab. 5: RSQ's margin over QuaRot grows as bits shrink",
    );
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let probe_n = args.usize_or("probe-n", 32);
    println!("{:<6} {:<10} {:>14} {:>14}", "Bits", "Method", "Wiki PPL", "Avg Acc (%)");
    let mut records = Vec::new();
    for bits in [4u32, 3, 2] {
        for method in [Method::QuaRot, Method::Rsq] {
            let mut ppls = Vec::new();
            let mut accs = Vec::new();
            for s in run_seeds(args) {
                let opts = seeded(QuantOptions::new(method, bits, t), s);
                let calib = ctx.calib(CorpusKind::Wiki, calib_n, t, s);
                let (q, ppl) = ctx.quant_ppl(&opts, &calib, t)?;
                ppls.push(ppl);
                accs.push(100.0 * probe_avg(&ctx, &q, t, probe_n)?);
            }
            println!(
                "{:<6} {:<10} {:>14} {:>14}",
                bits, method.name(), cell(&ppls, 3), cell(&accs, 1)
            );
            records.push(
                Json::obj()
                    .set("bits", bits as usize)
                    .set("method", method.name())
                    .set("ppl", ppls)
                    .set("acc", accs),
            );
        }
    }
    write_record("table5", Json::obj().set("rows", Json::Arr(records)))
}

/// Tab. 6: vector quantization (E8 codebook + LDLQ) for both methods.
pub fn table6(args: &Args) -> Result<()> {
    print_header(
        "Table 6 — RSQ + vector quantization (E8/LDLQ)",
        "Tab. 6: VQ improves both methods at 2-bit; RSQ+VQ is best overall",
    );
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let probe_n = args.usize_or("probe-n", 32);
    println!("{:<12} {:>14} {:>14}", "Method", "Wiki PPL", "Avg Acc (%)");
    let mut records = Vec::new();
    for method in [Method::QuaRot, Method::Rsq, Method::QuaRotVq, Method::RsqVq] {
        let bits = 2; // scalar baselines at 2-bit; VQ is 2-bit-comparable
        let mut ppls = Vec::new();
        let mut accs = Vec::new();
        for s in run_seeds(args) {
            let opts = seeded(QuantOptions::new(method, bits, t), s);
            let calib = ctx.calib(CorpusKind::Wiki, calib_n, t, s);
            let (q, ppl) = ctx.quant_ppl(&opts, &calib, t)?;
            ppls.push(ppl);
            accs.push(100.0 * probe_avg(&ctx, &q, t, probe_n)?);
        }
        println!("{:<12} {:>14} {:>14}", method.name(), cell(&ppls, 2), cell(&accs, 1));
        records.push(
            Json::obj()
                .set("method", method.name())
                .set("ppl", ppls)
                .set("acc", accs),
        );
    }
    write_record("table6", Json::obj().set("rows", Json::Arr(records)))
}

/// Tab. 7: LongEval (KV retrieval) at three lengths, three calib configs.
pub fn table7(args: &Args) -> Result<()> {
    print_header(
        "Table 7 — LongEval (KV retrieval) lengths",
        "Tab. 7: RSQ beats QuaRot; accuracy drops as length grows",
    );
    let config = args.str_or("config", "small");
    let ctx = Ctx::prepare(&config, args)?;
    let eval_t = *ctx.engine.config().seq_lens.iter().max().unwrap();
    let lc_n = args.usize_or("lc-n", 24);
    let bits = args.usize_or("bits", 3) as u32;
    let levels = [eval_t / 8, eval_t / 4, (eval_t - 4) / 2]; // pairs per prompt
    let calib_cfgs = [(8usize, 256usize), (16, 128), (32, 64)];

    let mut full_cells = Vec::new();
    for &l in &levels {
        let r = crate::eval::longctx::kv_retrieval(
            &ctx.engine, &ctx.params, eval_t, l, 3, lc_n)?;
        full_cells.push(format!("{:.1}", 100.0 * r.score));
    }
    println!("{:<10} L={:?}", "Full", levels);
    println!("{:<10} {}", "", full_cells.join("  "));

    let mut records = Vec::new();
    for (n, t) in calib_cfgs {
        if !ctx.engine.config().seq_lens.contains(&t) {
            continue;
        }
        println!("--- calibration: {n} x {t} ---");
        for method in [Method::QuaRot, Method::Rsq] {
            let mut per_level: Vec<Vec<f64>> = vec![Vec::new(); levels.len()];
            for s in run_seeds(args) {
                let opts = seeded(QuantOptions::new(method, bits, t), s);
                let calib = ctx.calib(CorpusKind::Wiki, n, t, s);
                let (q, _) =
                    crate::quant::quantize(&ctx.engine, &ctx.params, &calib, &ctx.with_jobs(opts))?;
                for (i, &l) in levels.iter().enumerate() {
                    let r = crate::eval::longctx::kv_retrieval(
                        &ctx.engine, &q, eval_t, l, 3, lc_n)?;
                    per_level[i].push(100.0 * r.score);
                }
            }
            let cells: Vec<String> = per_level.iter().map(|v| cell(v, 1)).collect();
            let avg: Vec<f64> = (0..run_seeds(args).len())
                .map(|si| {
                    per_level.iter().map(|v| v[si]).sum::<f64>() / levels.len() as f64
                })
                .collect();
            println!("{:<10} {}  | avg {}", method.name(), cells.join("  "), cell(&avg, 1));
            records.push(
                Json::obj()
                    .set("calib_n", n)
                    .set("calib_t", t)
                    .set("method", method.name())
                    .set(
                        "levels",
                        Json::Arr(levels.iter().map(|&l| Json::from(l)).collect()),
                    )
                    .set(
                        "scores",
                        Json::Arr(per_level.iter().map(|v| Json::from(v.clone())).collect()),
                    ),
            );
        }
    }
    write_record("table7", Json::obj().set("rows", Json::Arr(records)))
}
