//! Paper-reproduction drivers: one per table/figure of the RSQ evaluation.
//!
//! Every driver prints rows in the paper's layout (mean with std-dev
//! subscripts across seeds) and writes a machine-readable JSON record to
//! `results/`. Scales are CPU-budget defaults — override with
//! `--config/--seeds/--steps/...` (see `rsq help`).
//!
//! Paper experiment -> driver map (DESIGN.md §4 has the full index):
//!   Tab. 1  chunk ablation            -> tables::table1
//!   Tab. 2  GPTQ/QuaRot/RSQ battery   -> tables::table2
//!   Tab. 3  long-context benchmarks   -> tables::table3
//!   Tab. 4  calibration datasets      -> tables::table4
//!   Tab. 5  bit precisions            -> tables::table5
//!   Tab. 6  RSQ + VQ                  -> tables::table6
//!   Tab. 7  LongEval lengths          -> tables::table7
//!   Fig. 2  First-N sweeps            -> figs::fig2
//!   Fig. 3  dynamic-strategy sweeps   -> figs::fig3
//!   Fig. 4  dataset expansion         -> figs::fig4
//!   Fig. 5/6 model sizes              -> figs::fig5
//!   Fig. 7  per-module ablation       -> figs::fig7
//!   Fig. 8  eval context lengths      -> figs::fig8
//!   Fig. 9  SQ (scale w/o rotate)     -> figs::fig9
//!   Figs. 10-14 score visualizations  -> scores::dump_scores

pub mod figs;
pub mod perf;
pub mod scores;
pub mod tables;

use anyhow::Result;

use crate::corpus::{CalibSet, CorpusKind};
use crate::eval::perplexity;
use crate::model::config::ModelConfig;
use crate::model::outliers::{inject_outliers, OutlierSpec};
use crate::model::ParamSet;
use crate::quant::{quantize, QuantOptions, SchedMode};
use crate::runtime::Engine;
use crate::train::train_or_load;
use crate::util::{json::Json, Args};

/// Shared experiment context: engine + trained, outlier-injected model +
/// a held-out eval set.
pub struct Ctx {
    pub engine: Engine,
    pub params: ParamSet,
    pub eval: CalibSet,
    pub train_seed: u64,
    /// scheduler worker count from `--jobs`, applied to every
    /// quantization this context runs (output is jobs-invariant)
    pub jobs: usize,
    /// scheduler mode from `--sched`, likewise stamped onto every run
    /// (output is mode-invariant — DESIGN.md §5)
    pub sched: SchedMode,
    /// content-addressed Hessian cache dir from `--hess-cache`
    /// (default auto): sweep drivers re-run identical pass-A
    /// accumulations constantly — tables repeating a (method, bits,
    /// strategy, seed) cell, `rsq all` re-running drivers — and a key hit
    /// skips pass A with byte-identical output (DESIGN.md §9)
    pub hess_cache: Option<std::path::PathBuf>,
}

impl Ctx {
    /// Default preparation: train (or load the cached checkpoint), inject
    /// outliers, build a held-out eval set at the largest context length.
    pub fn prepare(config: &str, args: &Args) -> Result<Ctx> {
        let engine = Engine::load(config)?;
        let cfg = engine.config().clone();
        let steps = args.usize_or("steps", default_steps(config));
        let train_seed = args.u64_or("train-seed", 7);
        let (mut params, rep) = train_or_load(&engine, train_seed, steps, args.flag("verbose"))?;
        if let Some(r) = rep {
            eprintln!(
                "[prepare:{config}] trained {steps} steps in {:.1}s (final loss {:.3})",
                r.wall_seconds, r.final_loss
            );
        }
        inject_outliers(&mut params, outlier_spec(args), train_seed);
        let eval = heldout_eval_set(&cfg, args);
        let sched = SchedMode::parse(&args.sched())
            .ok_or_else(|| anyhow::anyhow!("bad --sched (staged|pipelined)"))?;
        Ok(Ctx {
            engine,
            params,
            eval,
            train_seed,
            jobs: args.jobs(),
            sched,
            hess_cache: args.hess_cache(),
        })
    }

    /// Fresh calibration set for one seeded run (stream decorrelated from
    /// eval and across seeds — the paper's "three different seeds").
    pub fn calib(&self, kind: CorpusKind, n: usize, t: usize, run_seed: u64) -> CalibSet {
        let cfg = self.engine.config();
        CalibSet::generate(cfg.vocab, kind, n, t, self.train_seed, 100 + run_seed)
    }

    /// Quantize + Wiki-PPL at context `eval_t` for one seeded run. The
    /// context's `--jobs` setting is applied unless the caller already
    /// raised `opts.jobs` above the serial default.
    pub fn quant_ppl(
        &self,
        opts: &QuantOptions,
        calib: &CalibSet,
        eval_t: usize,
    ) -> Result<(ParamSet, f64)> {
        let opts = self.with_jobs(opts.clone());
        let (q, _) = quantize(&self.engine, &self.params, calib, &opts)?;
        let ppl = perplexity(&self.engine, &q, &self.eval, eval_t)?;
        Ok((q, ppl))
    }

    /// Stamp this context's `--jobs` worker count, `--sched` mode, and
    /// `--hess-cache` dir onto `opts` — each a no-op when the caller
    /// already moved that knob off its default (serial / pipelined /
    /// uncached), so explicit per-run choices win.
    pub fn with_jobs(&self, mut opts: QuantOptions) -> QuantOptions {
        if opts.jobs == 1 {
            opts.jobs = self.jobs;
        }
        if opts.sched == SchedMode::Pipelined {
            opts.sched = self.sched;
        }
        if opts.hess_cache.is_none() {
            opts.hess_cache = self.hess_cache.clone();
        }
        opts
    }
}

/// Default calibration/scoring context length shared by `rsq quantize`
/// (`--calib-t` default) and `rsq eval`'s checkpoint path: the largest
/// compiled context, capped at 128 for CPU-budget runs. One definition so
/// the two printouts can't silently drift apart.
pub fn default_context(cfg: &ModelConfig) -> usize {
    *cfg.seq_lens.iter().max().unwrap().min(&128)
}

/// The held-out eval set every scoring path shares — `Ctx::prepare` and
/// `rsq eval` MUST draw the same samples, or artifact-backed scores stop
/// lining up with the quantize-time printout: Wiki at the largest context
/// length, stream 2 (decorrelated from calibration's 100+).
pub fn heldout_eval_set(cfg: &ModelConfig, args: &Args) -> CalibSet {
    let tmax = *cfg.seq_lens.iter().max().unwrap();
    CalibSet::generate(
        cfg.vocab,
        CorpusKind::Wiki,
        args.usize_or("eval-n", 32),
        tmax,
        args.u64_or("train-seed", 7),
        2,
    )
}

pub fn default_steps(config: &str) -> usize {
    match config {
        "tiny" => 150,
        "e2e" => 300,
        _ => 400,
    }
}

pub fn outlier_spec(args: &Args) -> OutlierSpec {
    OutlierSpec {
        fraction: args.f32_or("outlier-frac", 0.003),
        magnitude: args.f32_or("outlier-mag", 6.0),
    }
}

/// Per-run seeds for "--seeds N" (paper default: 3).
pub fn run_seeds(args: &Args) -> Vec<u64> {
    (0..args.usize_or("seeds", 3) as u64).collect()
}

/// paper-style cell: "9.046±0.01"
pub fn cell(vals: &[f64], prec: usize) -> String {
    crate::util::fmt_pm(vals, prec)
}

/// Write a driver's JSON record under results/.
pub fn write_record(name: &str, record: Json) -> Result<()> {
    std::fs::create_dir_all("results").ok();
    let path = format!("results/{name}.json");
    std::fs::write(&path, record.to_string())?;
    eprintln!("[record] wrote {path}");
    Ok(())
}

pub fn print_header(title: &str, paper_ref: &str) {
    println!("\n=== {title} ===");
    println!("    (paper: {paper_ref})");
}

/// seeded variant of QuantOptions: rotation seed varies per run.
pub fn seeded(mut opts: QuantOptions, run_seed: u64) -> QuantOptions {
    opts.rot_seed = 0x5157 + run_seed;
    opts
}

/// Convenience used by several drivers: method run -> (ppl per seed).
pub fn ppl_over_seeds(
    ctx: &Ctx,
    args: &Args,
    opts_for_seed: impl Fn(u64) -> QuantOptions,
    calib_for_seed: impl Fn(u64) -> CalibSet,
    eval_t: usize,
) -> Result<Vec<f64>> {
    let mut ppls = Vec::new();
    for s in run_seeds(args) {
        let opts = opts_for_seed(s);
        let calib = calib_for_seed(s);
        let (_, ppl) = ctx.quant_ppl(&opts, &calib, eval_t)?;
        ppls.push(ppl);
    }
    Ok(ppls)
}

/// Full-model rows used by several tables.
pub fn full_model_ppl(ctx: &Ctx, eval_t: usize) -> Result<f64> {
    perplexity(&ctx.engine, &ctx.params, &ctx.eval, eval_t)
}
