//! Figure drivers (paper Figs. 2-9). Design-choice evaluations use Wiki
//! PPL only, like the paper ("to avoid overfitting").

use anyhow::Result;

use crate::corpus::CorpusKind;
use crate::model::config::Module;
use crate::quant::{Method, QuantOptions, Strategy};
use crate::util::{json::Json, Args};

use super::{cell, print_header, run_seeds, seeded, write_record, Ctx};

fn sweep_ppl(
    ctx: &Ctx,
    args: &Args,
    t: usize,
    calib_n: usize,
    make_opts: impl Fn(u64) -> QuantOptions,
) -> Result<Vec<f64>> {
    let mut ppls = Vec::new();
    for s in run_seeds(args) {
        let opts = make_opts(s);
        let calib = ctx.calib(CorpusKind::Wiki, calib_n, t, s);
        let (_, ppl) = ctx.quant_ppl(&opts, &calib, t)?;
        ppls.push(ppl);
    }
    Ok(ppls)
}

/// Fig. 2: First-N and First&Last-N over the number of activated tokens.
pub fn fig2(args: &Args) -> Result<()> {
    print_header(
        "Figure 2 — heuristic strategies vs number of used tokens",
        "Fig. 2: PPL dips at N ~ 5-10% of tokens; First&Last-N <= First-N",
    );
    let ctx = Ctx::prepare(&args.str_or("config", "small"), args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let bits = args.usize_or("bits", 3) as u32;
    let ns: Vec<usize> = [t, t / 2, t / 4, t / 8, t / 16, t / 32]
        .into_iter()
        .filter(|&n| n >= 2)
        .collect();
    println!("{:<6} {:>18} {:>18}", "N", "First-N PPL", "First&Last-N PPL");
    let mut rows = Vec::new();
    for &n in &ns {
        let p_first = sweep_ppl(&ctx, args, t, calib_n, |s| {
            let mut o = seeded(QuantOptions::new(Method::Rsq, bits, t), s);
            o.strategy = Strategy::FirstN(n);
            o
        })?;
        let p_fl = sweep_ppl(&ctx, args, t, calib_n, |s| {
            let mut o = seeded(QuantOptions::new(Method::Rsq, bits, t), s);
            o.strategy = Strategy::FirstLastN(n);
            o
        })?;
        println!("{:<6} {:>18} {:>18}", n, cell(&p_first, 3), cell(&p_fl, 3));
        rows.push(
            Json::obj()
                .set("n", n)
                .set("firstn_ppl", p_first)
                .set("firstlastn_ppl", p_fl),
        );
    }
    write_record("fig2", Json::obj().set("rows", Json::Arr(rows)))
}

/// Fig. 3: the five dynamic strategies across r_min.
pub fn fig3(args: &Args) -> Result<()> {
    print_header(
        "Figure 3 — dynamic strategies vs r_min",
        "Fig. 3: AttnCon best (opt r_min=0.01); TokenFreq/ActDiff weakest",
    );
    let ctx = Ctx::prepare(&args.str_or("config", "small"), args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let bits = args.usize_or("bits", 3) as u32;
    let rmins = [0.005f32, 0.01, 0.02, 0.05, 0.1];
    let strategies: Vec<(&str, fn(f32) -> Strategy)> = vec![
        ("tokenfreq", |r| Strategy::TokenFreq { r_min: r }),
        ("actnorm", |r| Strategy::ActNorm { r_min: r }),
        ("actdiff", |r| Strategy::ActDiff { r_min: r }),
        ("tokensim", |r| Strategy::TokenSim { r_min: r }),
        ("attncon", |r| Strategy::AttnCon { r_min: r }),
    ];
    print!("{:<10}", "strategy");
    for r in rmins {
        print!(" {:>16}", format!("r_min={r}"));
    }
    println!();
    let mut rows = Vec::new();
    for (name, make) in &strategies {
        print!("{name:<10}");
        let mut per_r = Vec::new();
        for &r in &rmins {
            let ppls = sweep_ppl(&ctx, args, t, calib_n, |s| {
                let mut o = seeded(QuantOptions::new(Method::Rsq, bits, t), s);
                o.strategy = make(r);
                o
            })?;
            print!(" {:>16}", cell(&ppls, 3));
            per_r.push(Json::obj().set("r_min", r as f64).set("ppl", ppls));
        }
        println!();
        rows.push(Json::obj().set("strategy", *name).set("points", Json::Arr(per_r)));
    }
    write_record("fig3", Json::obj().set("rows", Json::Arr(rows)))
}

/// Fig. 4: dataset expansion (M=8) on/off per strategy at its best setting.
pub fn fig4(args: &Args) -> Result<()> {
    print_header(
        "Figure 4 — dataset expansion (M=8) per strategy",
        "Fig. 4: most strategies improve with expansion",
    );
    let ctx = Ctx::prepare(&args.str_or("config", "small"), args)?;
    let t = args.usize_or("calib-t", 128);
    // expansion multiplies tokens; shrink base so budgets stay comparable
    let calib_n = args.usize_or("calib-n", 8);
    let m = args.usize_or("expansion", 8);
    let bits = args.usize_or("bits", 3) as u32;
    // paper-optimal hyperparameters per strategy (from our fig2/fig3 runs)
    let variants: Vec<(&str, Strategy)> = vec![
        ("firstn", Strategy::FirstN(t / 8)),
        ("firstlastn", Strategy::FirstLastN(t / 8)),
        ("actnorm", Strategy::ActNorm { r_min: 0.005 }),
        ("tokensim", Strategy::TokenSim { r_min: 0.005 }),
        ("attncon", Strategy::AttnCon { r_min: 0.01 }),
    ];
    println!("{:<12} {:>16} {:>16}", "strategy", "no expansion", format!("expansion M={m}"));
    let mut rows = Vec::new();
    for (name, strat) in &variants {
        let base = sweep_ppl(&ctx, args, t, calib_n, |s| {
            let mut o = seeded(QuantOptions::new(Method::Rsq, bits, t), s);
            o.strategy = *strat;
            o
        })?;
        let expanded = sweep_ppl(&ctx, args, t, calib_n, |s| {
            let mut o = seeded(QuantOptions::new(Method::Rsq, bits, t), s);
            o.strategy = *strat;
            o.expansion = m;
            o
        })?;
        println!("{:<12} {:>16} {:>16}", name, cell(&base, 3), cell(&expanded, 3));
        rows.push(
            Json::obj()
                .set("strategy", *name)
                .set("base_ppl", base)
                .set("expanded_ppl", expanded),
        );
    }
    write_record("fig4", Json::obj().set("rows", Json::Arr(rows)))
}

/// Fig. 5/6: model-size ablation (three sizes of one family).
pub fn fig5(args: &Args) -> Result<()> {
    print_header(
        "Figure 5/6 — model sizes",
        "Fig. 5/6: RSQ beats QuaRot at every size",
    );
    let configs = args.list_or("configs", &["ms1", "ms2", "ms3"]);
    let calib_n = args.usize_or("calib-n", 16);
    let bits = args.usize_or("bits", 3) as u32;
    println!("{:<8} {:<10} {:>16}", "size", "method", "Wiki PPL");
    let mut rows = Vec::new();
    for config in &configs {
        let ctx = Ctx::prepare(config, args)?;
        let t = *ctx.engine.config().seq_lens.iter().max().unwrap().min(&128);
        for method in [Method::QuaRot, Method::Rsq] {
            let ppls = sweep_ppl(&ctx, args, t, calib_n, |s| {
                seeded(QuantOptions::new(method, bits, t), s)
            })?;
            println!("{:<8} {:<10} {:>16}", config, method.name(), cell(&ppls, 3));
            rows.push(
                Json::obj()
                    .set("config", config.as_str())
                    .set("params", ctx.engine.config().num_params())
                    .set("method", method.name())
                    .set("ppl", ppls),
            );
        }
    }
    write_record("fig5", Json::obj().set("rows", Json::Arr(rows)))
}

/// Fig. 7: RSQ applied to each module independently.
pub fn fig7(args: &Args) -> Result<()> {
    print_header(
        "Figure 7 — per-module RSQ ablation",
        "Fig. 7: most modules help; v_proj gains the most",
    );
    let ctx = Ctx::prepare(&args.str_or("config", "small"), args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let bits = args.usize_or("bits", 3) as u32;
    println!("{:<10} {:>16}", "scaled", "Wiki PPL");
    let mut rows = Vec::new();
    // none (uniform everywhere) + each module alone + all
    let mut variants: Vec<(String, Option<Vec<Module>>)> =
        vec![("none".into(), Some(vec![]))];
    for m in Module::ALL {
        variants.push((m.name().to_string(), Some(vec![m])));
    }
    variants.push(("all".into(), None));
    for (label, mask) in &variants {
        let ppls = sweep_ppl(&ctx, args, t, calib_n, |s| {
            let mut o = seeded(QuantOptions::new(Method::Rsq, bits, t), s);
            o.module_mask = mask.as_ref().map(|v| v.iter().cloned().collect());
            o
        })?;
        println!("{:<10} {:>16}", label, cell(&ppls, 3));
        rows.push(Json::obj().set("module", label.as_str()).set("ppl", ppls));
    }
    write_record("fig7", Json::obj().set("rows", Json::Arr(rows)))
}

/// Fig. 8: Wiki PPL at several evaluation context lengths.
pub fn fig8(args: &Args) -> Result<()> {
    print_header(
        "Figure 8 — evaluation context lengths",
        "Fig. 8: method gaps stay consistent; longer ctx -> lower PPL",
    );
    let ctx = Ctx::prepare(&args.str_or("config", "small"), args)?;
    let calib_t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let bits = args.usize_or("bits", 3) as u32;
    let ctxs: Vec<usize> = ctx.engine.config().seq_lens.clone();
    print!("{:<10}", "method");
    for &c in &ctxs {
        print!(" {:>16}", format!("ctx={c}"));
    }
    println!();
    let mut rows = Vec::new();
    // full model
    print!("{:<10}", "full");
    let mut full_cells = Vec::new();
    for &c in &ctxs {
        let ppl = super::full_model_ppl(&ctx, c)?;
        print!(" {:>16.3}", ppl);
        full_cells.push(Json::obj().set("ctx", c).set("ppl", ppl));
    }
    println!();
    rows.push(Json::obj().set("method", "full").set("points", Json::Arr(full_cells)));
    for method in [Method::Gptq, Method::QuaRot, Method::Rsq] {
        // quantize once per seed at calib_t, evaluate at each context
        let mut per_ctx: Vec<Vec<f64>> = vec![Vec::new(); ctxs.len()];
        for s in run_seeds(args) {
            let opts = seeded(QuantOptions::new(method, bits, calib_t), s);
            let calib = ctx.calib(CorpusKind::Wiki, calib_n, calib_t, s);
            let (q, _) =
                crate::quant::quantize(&ctx.engine, &ctx.params, &calib, &ctx.with_jobs(opts))?;
            for (i, &c) in ctxs.iter().enumerate() {
                per_ctx[i].push(crate::eval::perplexity(&ctx.engine, &q, &ctx.eval, c)?);
            }
        }
        print!("{:<10}", method.name());
        let mut cells = Vec::new();
        for (i, &c) in ctxs.iter().enumerate() {
            print!(" {:>16}", cell(&per_ctx[i], 3));
            cells.push(Json::obj().set("ctx", c).set("ppl", per_ctx[i].clone()));
        }
        println!();
        rows.push(Json::obj().set("method", method.name()).set("points", Json::Arr(cells)));
    }
    write_record("fig8", Json::obj().set("rows", Json::Arr(rows)))
}

/// Fig. 9: SQ (scale without rotation) across r_min, vs RSQ.
pub fn fig9(args: &Args) -> Result<()> {
    print_header(
        "Figure 9 — AttnCon scaling without rotation (SQ)",
        "Fig. 9: SQ's optimal r_min is much larger than RSQ's",
    );
    let ctx = Ctx::prepare(&args.str_or("config", "small"), args)?;
    let t = args.usize_or("calib-t", 128);
    let calib_n = args.usize_or("calib-n", 16);
    let bits = args.usize_or("bits", 3) as u32;
    let rmins = [0.005f32, 0.01, 0.05, 0.1, 0.3, 0.5];
    println!("{:<8} {}", "method", rmins.map(|r| format!("{r:>14}")).join(""));
    let mut rows = Vec::new();
    for method in [Method::Sq, Method::Rsq] {
        print!("{:<8}", method.name());
        let mut pts = Vec::new();
        for &r in &rmins {
            let ppls = sweep_ppl(&ctx, args, t, calib_n, |s| {
                let mut o = seeded(QuantOptions::new(method, bits, t), s);
                o.strategy = Strategy::AttnCon { r_min: r };
                o
            })?;
            print!("{:>14}", cell(&ppls, 3));
            pts.push(Json::obj().set("r_min", r as f64).set("ppl", ppls));
        }
        println!();
        rows.push(Json::obj().set("method", method.name()).set("points", Json::Arr(pts)));
    }
    write_record("fig9", Json::obj().set("rows", Json::Arr(rows)))
}
