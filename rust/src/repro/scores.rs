//! Figs. 10-14: token-importance score visualizations.
//!
//! Dumps the raw per-token scores of every dynamic strategy for a few
//! samples at a few layers (JSON for plotting) and prints ASCII sparklines
//! so the paper's qualitative claims are visible in the terminal:
//! AttnCon spikes at initial (and final) tokens, ActNorm mildly favors the
//! first token, TokenSim separates the first token in early layers.

use anyhow::Result;

use crate::corpus::CorpusKind;
use crate::quant::strategy::normalize_eq4;
use crate::runtime::{self};
use crate::tensor::Tensor;
use crate::util::{json::Json, Args};

use super::{print_header, write_record, Ctx};

const BARS: &[char] = &[' ', '▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

fn sparkline(xs: &[f32]) -> String {
    let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-9);
    xs.iter()
        .map(|&x| BARS[(((x - lo) / span) * 8.0).round() as usize])
        .collect()
}

pub fn dump_scores(args: &Args) -> Result<()> {
    print_header(
        "Figures 10-14 — token-importance score visualization",
        "Figs. 10-14: AttnCon concentrates on initial/final tokens, etc.",
    );
    let ctx = Ctx::prepare(&args.str_or("config", "small"), args)?;
    let cfg = ctx.engine.config().clone();
    let t = args.usize_or("calib-t", 128);
    let n_samples = args.usize_or("samples", 3);
    let calib = ctx.calib(CorpusKind::Wiki, cfg.batch.max(n_samples), t, 0);
    let freq = calib.token_frequencies(cfg.vocab);

    // embed the first batch
    let batch: Vec<Vec<i32>> = calib.samples[..cfg.batch].to_vec();
    let tl = runtime::tokens_literal(&batch, t)?;
    let emb = runtime::tensor_literal(&ctx.params.tensors[0])?;
    let pos = runtime::tensor_literal(&ctx.params.tensors[1])?;
    let mut z = ctx
        .engine
        .exec(&format!("embed_t{t}"), &[tl, emb, pos])?
        .into_iter()
        .next()
        .unwrap();

    let mut layers_json = Vec::new();
    for l in 0..cfg.layers {
        let base = 2 + l * 9;
        let mut ins = vec![z.clone()];
        for k in 0..9 {
            ins.push(runtime::tensor_literal(&ctx.params.tensors[base + k])?);
        }
        let outs = ctx.engine.exec(&format!("layer_fwd_t{t}"), &ins)?;
        let grab = |idx: usize| -> Result<Tensor> { runtime::literal_tensor(&outs[idx]) };
        let score_mats = [
            ("attn_con", grab(5)?),
            ("act_norm", grab(6)?),
            ("act_diff", grab(7)?),
            ("token_sim", grab(8)?),
        ];
        println!("\n--- layer {l} ---");
        let mut strat_json = Vec::new();
        for (name, mat) in &score_mats {
            for s in 0..n_samples.min(cfg.batch) {
                let row = &mat.data[s * t..(s + 1) * t];
                if s == 0 {
                    println!("{name:<10} |{}|", sparkline(row));
                }
                strat_json.push(
                    Json::obj()
                        .set("strategy", *name)
                        .set("sample", s)
                        .set("scores", &row[..]),
                );
            }
        }
        // TokenFreq scores come from the corpus, not the layer
        for s in 0..n_samples.min(cfg.batch) {
            let raw: Vec<f32> = batch[s].iter().map(|&tk| -(freq[tk as usize] as f32)).collect();
            let norm = normalize_eq4(&raw, 0.01);
            if s == 0 {
                println!("{:<10} |{}|", "token_freq", sparkline(&norm));
            }
            strat_json.push(
                Json::obj()
                    .set("strategy", "token_freq")
                    .set("sample", s)
                    .set("scores", &norm[..]),
            );
        }
        layers_json.push(Json::obj().set("layer", l).set("series", Json::Arr(strat_json)));
        // advance to the next layer
        z = outs.into_iter().next().unwrap();
    }
    write_record(
        "scores_fig10_14",
        Json::obj()
            .set("config", cfg.name.as_str())
            .set("layers", Json::Arr(layers_json)),
    )
}
