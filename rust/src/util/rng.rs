//! PCG-XSH-RR 64/32: a small, fast, statistically solid PRNG.
//!
//! Offline substitute for the `rand` crate (not in the vendor set). Used
//! for parameter init, corpus generation, probe-task construction, and the
//! property-test harness — everything seeded, so every experiment
//! record under results/ is reproducible bit-for-bit (DESIGN.md §Perf).

#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for the same seed (distinct `inc` ⇒ distinct
    /// sequence); used to decorrelate corpus vs. init vs. task RNGs.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire-style rejection-free for our use
    /// (n ≪ 2^32, the tiny modulo bias is irrelevant for synthesis).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Random sign ±1.
    pub fn sign(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut x = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::with_stream(42, 1);
        let mut b = Pcg::with_stream(42, 2);
        assert!((0..10).any(|_| a.next_u32() != b.next_u32()));
    }

    #[test]
    fn f32_in_range_and_uniformish() {
        let mut rng = Pcg::new(7);
        let xs: Vec<f32> = (0..10_000).map(|_| rng.f32()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((m - 0.5).abs() < 0.02, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(9);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let m = xs.iter().sum::<f32>() / xs.len() as f32;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / xs.len() as f32;
        assert!(m.abs() < 0.03, "{m}");
        assert!((v - 1.0).abs() < 0.05, "{v}");
    }

    #[test]
    fn below_bounds() {
        let mut rng = Pcg::new(1);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[rng.weighted(&[1.0, 0.0, 9.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
