//! Fixed-size `std::thread` worker pool for the layer-quantization
//! scheduler (no rayon/crossbeam in the vendor set).
//!
//! [`Pool::run`] fans an indexed task list out over scoped OS threads and
//! returns the results **in task order**, whatever order the workers finish
//! in. That ordering contract is what lets the quantization pipeline keep
//! its bit-determinism guarantee (DESIGN.md §5): workers only compute
//! independent per-task values, and every floating-point *reduction* over
//! those values happens on the calling thread in a fixed order.
//!
//! Tasks are claimed from a shared atomic counter (work stealing in its
//! simplest form), so an uneven task list — e.g. the ff×ff Hessian next to
//! three d×d ones — still load-balances.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker-pool handle. Cheap to construct; threads are scoped to each
/// [`Pool::run`] call, so an idle `Pool` holds no OS resources.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    jobs: usize,
}

/// Number of hardware threads, as reported by the OS (>= 1).
pub fn max_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Pool {
    /// Pool with `jobs` workers; `jobs == 0` means "one per hardware
    /// thread" (the `--jobs auto` spelling).
    pub fn new(jobs: usize) -> Pool {
        Pool { jobs: if jobs == 0 { max_parallelism() } else { jobs } }
    }

    /// Worker count this pool dispatches with.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run `f(0), f(1), …, f(n-1)` across the workers and return the
    /// results in index order.
    ///
    /// With `jobs == 1` (or fewer than two tasks) this degenerates to a
    /// plain serial loop on the calling thread — the serial and parallel
    /// paths are the same code executing the same per-task closures, which
    /// is what makes `--jobs N` bit-identical to `--jobs 1` for pure `f`.
    ///
    /// A panic in any task propagates to the caller after all workers
    /// have been joined.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.jobs <= 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let done = Mutex::new(Vec::with_capacity(n));
        std::thread::scope(|s| {
            for _ in 0..self.jobs.min(n) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    done.lock().unwrap().push((i, v));
                });
            }
        });
        let mut out = done.into_inner().unwrap();
        out.sort_by_key(|&(i, _)| i);
        out.into_iter().map(|(_, v)| v).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_task_order() {
        for jobs in [1, 2, 4, 9] {
            let got = Pool::new(jobs).run(17, |i| i * i);
            let want: Vec<usize> = (0..17).map(|i| i * i).collect();
            assert_eq!(got, want, "jobs={jobs}");
        }
    }

    #[test]
    fn empty_and_single_task_lists() {
        let p = Pool::new(4);
        assert_eq!(p.run(0, |i| i), Vec::<usize>::new());
        assert_eq!(p.run(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn more_workers_than_tasks() {
        assert_eq!(Pool::new(64).run(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn zero_means_auto() {
        assert!(Pool::new(0).jobs() >= 1);
        assert_eq!(Pool::new(3).jobs(), 3);
    }

    #[test]
    fn parallel_matches_serial_reduction() {
        // the pipeline's usage pattern: compute in parallel, reduce in order
        let serial: f32 = (0..100).map(|i| (i as f32).sin()).sum();
        let parts = Pool::new(4).run(100, |i| (i as f32).sin());
        let parallel: f32 = parts.into_iter().sum();
        assert_eq!(serial.to_bits(), parallel.to_bits());
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        Pool::new(4).run(8, |i| {
            if i == 5 {
                panic!("boom");
            }
            i
        });
    }
}
