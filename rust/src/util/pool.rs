//! Fixed-size `std::thread` worker pool for the layer-quantization
//! scheduler (no rayon/crossbeam in the vendor set).
//!
//! [`Pool::run`] fans an indexed task list out over scoped OS threads and
//! returns the results **in task order**, whatever order the workers finish
//! in. That ordering contract is what lets the quantization pipeline keep
//! its bit-determinism guarantee (DESIGN.md §5): workers only compute
//! independent per-task values, and every floating-point *reduction* over
//! those values happens on the calling thread in a fixed order.
//!
//! [`Pool::run_windowed`] and [`Pool::update_windowed`] layer a bounded
//! dispatch window on top of `run`: tasks are released in windows of
//! [`Pool::window`] and their results streamed to an ordered consumer
//! callback between windows, so peak in-flight memory stays O(jobs)
//! instead of O(tasks). The `quant::sched` stages are built on these two
//! primitives and never hand-roll window loops.
//!
//! Tasks are claimed from a shared atomic counter (work stealing in its
//! simplest form), so an uneven task list — e.g. the ff×ff Hessian next to
//! three d×d ones — still load-balances.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::obs::{metrics, trace};

/// Worker-pool handle. Cheap to construct; threads are scoped to each
/// [`Pool::run`] call, so an idle `Pool` holds no OS resources.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    jobs: usize,
}

/// Number of hardware threads, as reported by the OS (>= 1).
pub fn max_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl Pool {
    /// Pool with `jobs` workers; `jobs == 0` means "one per hardware
    /// thread" (the `--jobs auto` spelling).
    pub fn new(jobs: usize) -> Pool {
        Pool { jobs: if jobs == 0 { max_parallelism() } else { jobs } }
    }

    /// Worker count this pool dispatches with.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run `f(0), f(1), …, f(n-1)` across the workers and return the
    /// results in index order.
    ///
    /// With `jobs == 1` (or fewer than two tasks) this degenerates to a
    /// plain serial loop on the calling thread — the serial and parallel
    /// paths are the same code executing the same per-task closures, which
    /// is what makes `--jobs N` bit-identical to `--jobs 1` for pure `f`.
    ///
    /// A panic in any task propagates to the caller after all workers
    /// have been joined.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        // observation only: spans/metrics wrap the same `f(i)` calls in
        // the same order, so instrumented and bare paths return
        // bit-identical results (DESIGN.md §16)
        let obs_on = trace::on() || metrics::on();
        if self.jobs <= 1 || n <= 1 {
            if !obs_on {
                return (0..n).map(f).collect();
            }
            return (0..n)
                .map(|i| {
                    let _sp = trace::span("pool", "pool.task");
                    let t0 = trace::now_us();
                    let v = f(i);
                    metrics::hist("pool.task_run_us", trace::now_us().saturating_sub(t0));
                    v
                })
                .collect();
        }
        let next = AtomicUsize::new(0);
        let done = Mutex::new(Vec::with_capacity(n));
        let t_dispatch = if obs_on { trace::now_us() } else { 0 };
        std::thread::scope(|s| {
            for _ in 0..self.jobs.min(n) {
                s.spawn(|| {
                    let _worker_sp = obs_on.then(|| trace::span("pool", "pool.worker"));
                    let mut busy = 0u64;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        if obs_on {
                            let t_claim = trace::now_us();
                            metrics::hist(
                                "pool.task_wait_us",
                                t_claim.saturating_sub(t_dispatch),
                            );
                            let v = {
                                let _sp = trace::span("pool", "pool.task");
                                f(i)
                            };
                            let dt = trace::now_us().saturating_sub(t_claim);
                            busy += dt;
                            metrics::hist("pool.task_run_us", dt);
                            done.lock().unwrap().push((i, v));
                        } else {
                            let v = f(i);
                            done.lock().unwrap().push((i, v));
                        }
                    }
                    if obs_on {
                        // per-worker utilization = busy_us / alive_us,
                        // aggregated across all scoped workers
                        metrics::add("pool.worker_busy_us", busy);
                        metrics::add(
                            "pool.worker_alive_us",
                            trace::now_us().saturating_sub(t_dispatch).max(1),
                        );
                        metrics::add("pool.workers", 1);
                    }
                });
            }
        });
        let mut out = done.into_inner().unwrap();
        out.sort_by_key(|&(i, _)| i);
        out.into_iter().map(|(_, v)| v).collect()
    }

    /// Tasks dispatched per window: a couple per worker keeps the pool
    /// busy across task-length variance while bounding in-flight results
    /// to O(jobs), not O(tasks).
    pub fn window(&self) -> usize {
        self.jobs * 2
    }

    /// Run `f(0), …, f(n-1)` in windows of [`Pool::window`], streaming
    /// each result to `consume` **in index order** on the calling thread.
    ///
    /// This is `run` plus the windowed "fan out, reduce in order" idiom
    /// the quantization stages share: `consume` is where every ordered
    /// floating-point reduction lives, so the determinism contract of
    /// [`Pool::run`] carries over unchanged (DESIGN.md §5). A `consume`
    /// error stops the dispatch after the current window; later tasks of
    /// that window are discarded unconsumed. Task panics propagate as in
    /// `run`.
    pub fn run_windowed<T, E, F, C>(&self, n: usize, f: F, mut consume: C) -> Result<(), E>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
        C: FnMut(usize, T) -> Result<(), E>,
    {
        let window = self.window();
        for start in (0..n).step_by(window) {
            let w = window.min(n - start);
            for (off, v) in self.run(w, |off| f(start + off)).into_iter().enumerate() {
                consume(start + off, v)?;
            }
        }
        Ok(())
    }

    /// Windowed **in-place transform**: `slots[i]` is replaced by the
    /// first half of `f(i, &slots[i])` while the second half streams to
    /// `consume`, both in index order between windows.
    ///
    /// Built for stages that carry state across a sweep (the scheduler's
    /// hidden-state literals: pass B replaces each batch's state, the
    /// fused pipelined step replaces it *and* emits the next layer's
    /// partial Hessians). Writes happen strictly in index order and stop
    /// at the first error: on a *task* error that slot and everything
    /// after keep their old values; on a *consumer* error the failing
    /// index's slot has already been replaced (write-then-consume), only
    /// its aux value goes unabsorbed. Peak memory is the live slots plus
    /// O(jobs) in-flight replacements.
    pub fn update_windowed<Z, A, E, F, C>(
        &self,
        slots: &mut [Z],
        f: F,
        mut consume: C,
    ) -> Result<(), E>
    where
        Z: Send + Sync,
        A: Send,
        E: Send,
        F: Fn(usize, &Z) -> Result<(Z, A), E> + Sync,
        C: FnMut(usize, A) -> Result<(), E>,
    {
        let window = self.window();
        let n = slots.len();
        for start in (0..n).step_by(window) {
            let w = window.min(n - start);
            let results = self.run(w, |off| f(start + off, &slots[start + off]));
            for (off, r) in results.into_iter().enumerate() {
                let (z, a) = r?;
                slots[start + off] = z;
                consume(start + off, a)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_task_order() {
        for jobs in [1, 2, 4, 9] {
            let got = Pool::new(jobs).run(17, |i| i * i);
            let want: Vec<usize> = (0..17).map(|i| i * i).collect();
            assert_eq!(got, want, "jobs={jobs}");
        }
    }

    #[test]
    fn empty_and_single_task_lists() {
        let p = Pool::new(4);
        assert_eq!(p.run(0, |i| i), Vec::<usize>::new());
        assert_eq!(p.run(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn more_workers_than_tasks() {
        assert_eq!(Pool::new(64).run(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn zero_means_auto() {
        assert!(Pool::new(0).jobs() >= 1);
        assert_eq!(Pool::new(3).jobs(), 3);
    }

    #[test]
    fn parallel_matches_serial_reduction() {
        // the pipeline's usage pattern: compute in parallel, reduce in order
        let serial: f32 = (0..100).map(|i| (i as f32).sin()).sum();
        let parts = Pool::new(4).run(100, |i| (i as f32).sin());
        let parallel: f32 = parts.into_iter().sum();
        assert_eq!(serial.to_bits(), parallel.to_bits());
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        Pool::new(4).run(8, |i| {
            if i == 5 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn run_windowed_streams_in_index_order() {
        // more tasks than any window so several windows run, for every
        // pool size incl. serial and more-workers-than-tasks
        for jobs in [1, 2, 3, 8] {
            let mut seen = Vec::new();
            let r: Result<(), ()> = Pool::new(jobs).run_windowed(
                23,
                |i| i * 2,
                |i, v| {
                    seen.push((i, v));
                    Ok(())
                },
            );
            assert_eq!(r, Ok(()));
            let want: Vec<(usize, usize)> = (0..23).map(|i| (i, i * 2)).collect();
            assert_eq!(seen, want, "jobs={jobs}");
        }
    }

    #[test]
    fn run_windowed_empty() {
        let r: Result<(), ()> = Pool::new(4).run_windowed(0, |i| i, |_, _| Ok(()));
        assert_eq!(r, Ok(()));
    }

    #[test]
    fn run_windowed_consumer_error_stops_in_order() {
        // consume sees 0..=5 in order, errors at 5, and nothing after
        let mut consumed = Vec::new();
        let r: Result<(), &str> = Pool::new(2).run_windowed(
            100,
            |i| i,
            |i, v| {
                consumed.push(v);
                if i == 5 {
                    Err("stop")
                } else {
                    Ok(())
                }
            },
        );
        assert_eq!(r, Err("stop"));
        assert_eq!(consumed, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    #[should_panic]
    fn run_windowed_panic_propagates() {
        let _: Result<(), ()> = Pool::new(4).run_windowed(
            32,
            |i| {
                if i == 9 {
                    panic!("boom");
                }
                i
            },
            |_, _| Ok(()),
        );
    }

    #[test]
    fn update_windowed_replaces_slots_and_streams_aux() {
        for jobs in [1, 4] {
            let mut slots: Vec<usize> = (0..17).collect();
            let mut aux = Vec::new();
            let r: Result<(), ()> = Pool::new(jobs).update_windowed(
                &mut slots,
                |i, &v| Ok((v + 100, i)),
                |i, a| {
                    aux.push((i, a));
                    Ok(())
                },
            );
            assert_eq!(r, Ok(()));
            let want: Vec<usize> = (100..117).collect();
            assert_eq!(slots, want, "jobs={jobs}");
            let want_aux: Vec<(usize, usize)> = (0..17).map(|i| (i, i)).collect();
            assert_eq!(aux, want_aux, "jobs={jobs}");
        }
    }

    #[test]
    fn update_windowed_error_keeps_writes_strictly_before_failure() {
        // ordered-consume semantics: every slot before the failing index
        // holds its new value, the failing slot and everything after keep
        // their old ones — regardless of where window boundaries fall
        let mut slots = vec![0usize; 10];
        let r: Result<(), &str> = Pool::new(2).update_windowed(
            &mut slots,
            |i, _| if i == 7 { Err("x") } else { Ok((i + 1, ())) },
            |_, _| Ok(()),
        );
        assert_eq!(r, Err("x"));
        for (i, &v) in slots.iter().enumerate() {
            assert_eq!(v, if i < 7 { i + 1 } else { 0 }, "slot {i}");
        }
    }

    #[test]
    #[should_panic]
    fn update_windowed_panic_propagates() {
        let mut slots = vec![0usize; 16];
        let _: Result<(), ()> = Pool::new(4).update_windowed(
            &mut slots,
            |i, &v| {
                if i == 11 {
                    panic!("boom");
                }
                Ok((v, ()))
            },
            |_, _| Ok(()),
        );
    }

    #[test]
    fn window_scales_with_jobs() {
        assert_eq!(Pool::new(1).window(), 2);
        assert_eq!(Pool::new(4).window(), 8);
    }
}
