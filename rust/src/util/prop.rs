//! Hand-rolled property-testing helper (proptest is not in the vendor set).
//!
//! `check` runs a predicate over `cases` seeded random instances and, on
//! failure, retries with a simple linear shrink of the size parameter to
//! report the smallest failing size. Each case gets an independent PCG
//! stream derived from the base seed, so failures are reproducible from
//! the printed (seed, size).

use super::rng::Pcg;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub min_size: usize,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 32, seed: 0x5eed, min_size: 1, max_size: 64 }
    }
}

/// Run `prop(rng, size)` for `cases` random (seed, size) pairs; panic with
/// a reproducible report on the first failure, after shrinking `size`.
pub fn check(cfg: Config, name: &str, mut prop: impl FnMut(&mut Pcg, usize) -> bool) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Pcg::with_stream(case_seed, 17);
        let span = cfg.max_size - cfg.min_size + 1;
        let size = cfg.min_size + rng.below(span);
        let mut fresh = Pcg::with_stream(case_seed, 99);
        if prop(&mut fresh, size) {
            continue;
        }
        // shrink: walk size down to find the smallest failing size
        let mut smallest = size;
        let mut s = size;
        while s > cfg.min_size {
            s -= 1;
            let mut rng2 = Pcg::with_stream(case_seed, 99);
            if !prop(&mut rng2, s) {
                smallest = s;
            }
        }
        panic!(
            "property {name:?} failed: seed={case_seed:#x} size={size} \
             (smallest failing size after shrink: {smallest})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(Config::default(), "sum_commutes", |rng, size| {
            let xs: Vec<f64> = (0..size).map(|_| rng.f64()).collect();
            let fwd: f64 = xs.iter().sum();
            let rev: f64 = xs.iter().rev().sum();
            (fwd - rev).abs() < 1e-9
        });
    }

    #[test]
    #[should_panic(expected = "smallest failing size")]
    fn failing_property_shrinks() {
        check(
            Config { cases: 8, max_size: 32, ..Default::default() },
            "always_small",
            |_rng, size| size < 3,
        );
    }
}
