//! Infrastructure substrates: RNG, bench harness, CLI parsing, JSON output,
//! the worker pool, and the property-testing helper.
//!
//! These exist because the offline vendor set (see Cargo.toml) has no
//! `rand`, `criterion`, `clap`, `proptest`, or `rayon`; each submodule is a
//! small, tested, dependency-free substitute.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

pub use bench::Bench;
pub use cli::Args;
pub use pool::Pool;
pub use rng::Pcg;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Format `mean ± std` the way the paper subscripts its tables.
pub fn fmt_pm(xs: &[f64], prec: usize) -> String {
    format!("{:.p$}±{:.p$}", mean(xs), stddev(xs), p = prec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138).abs() < 1e-2, "{s}");
    }

    #[test]
    fn fmt_pm_formats() {
        assert_eq!(fmt_pm(&[1.0, 2.0], 2), "1.50±0.71");
    }
}
