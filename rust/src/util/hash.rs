//! Checksums and content-addressing hashes (no `crc`/`sha2` crates in the
//! vendor set).
//!
//! - [`crc32`] — CRC-32 (IEEE 802.3, reflected 0xEDB88320) for per-blob
//!   integrity in the quantized-artifact format and the Hessian cache
//!   (DESIGN.md §9). Bitwise, table-free: these run over megabytes once
//!   per save/load, not in any hot loop.
//! - [`Fnv1a64`] — streaming FNV-1a 64 for content-addressed cache keys.
//!   Two independent streams (distinct bases) give a 128-bit key, which is
//!   collision-safe at the scale of "every sweep cell ever run on one
//!   machine".

/// CRC-32 (IEEE) of `bytes`. `crc32(b"123456789") == 0xCBF4_3926`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// FNV-1a 64 offset basis (the standard one).
pub const FNV_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Streaming FNV-1a 64 hasher with typed little-endian write helpers so
/// key derivation reads as a field list (see `quant::artifact::cache`).
#[derive(Clone, Debug)]
pub struct Fnv1a64 {
    state: u64,
}

impl Fnv1a64 {
    pub fn new() -> Self {
        Self::with_basis(FNV_BASIS)
    }

    /// Start from a non-standard basis — used to derive a second,
    /// independent 64-bit stream over the same input.
    pub fn with_basis(basis: u64) -> Self {
        Fnv1a64 { state: basis }
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    /// Length-prefixed string write, so `("ab","c")` and `("a","bc")`
    /// hash differently.
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// Hash the *bits* of an f32 (NaN payloads and -0.0 vs 0.0 included —
    /// cache keys must distinguish everything the pipeline could).
    pub fn write_f32(&mut self, v: f32) {
        self.write_u32(v.to_bits());
    }

    pub fn write_f32s(&mut self, vs: &[f32]) {
        self.write_usize(vs.len());
        for &v in vs {
            self.write_f32(v);
        }
    }

    pub fn write_i32s(&mut self, vs: &[i32]) {
        self.write_usize(vs.len());
        for &v in vs {
            self.write(&v.to_le_bytes());
        }
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn crc32_detects_single_bit_flip() {
        let mut data = vec![0xA5u8; 256];
        let base = crc32(&data);
        data[100] ^= 0x10;
        assert_ne!(crc32(&data), base);
    }

    #[test]
    fn fnv_known_vectors() {
        assert_eq!(Fnv1a64::new().finish(), FNV_BASIS);
        let mut h = Fnv1a64::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv1a64::new();
        h.write(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn length_prefix_disambiguates() {
        let key = |a: &str, b: &str| {
            let mut h = Fnv1a64::new();
            h.write_str(a);
            h.write_str(b);
            h.finish()
        };
        assert_ne!(key("ab", "c"), key("a", "bc"));
    }

    #[test]
    fn distinct_bases_give_independent_streams() {
        let mut a = Fnv1a64::new();
        let mut b = Fnv1a64::with_basis(FNV_BASIS ^ 0x9E37_79B9_7F4A_7C15);
        a.write(b"same input");
        b.write(b"same input");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn f32_bits_distinguish_negative_zero() {
        let mut a = Fnv1a64::new();
        let mut b = Fnv1a64::new();
        a.write_f32(0.0);
        b.write_f32(-0.0);
        assert_ne!(a.finish(), b.finish());
    }
}
