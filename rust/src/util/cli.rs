//! Tiny declarative CLI argument parser (clap is not in the vendor set).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args.
//! Every driver in `repro/` and `main.rs` consumes this.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Boolean flags every subcommand shares. [`Args::parse`] must never let
/// one of these swallow the next token as a value — `rsq generate
/// --verbose PROMPT` once recorded `verbose=PROMPT`, so `flag("verbose")`
/// was false AND the positional vanished. Subcommands with extra boolean
/// flags pass them through [`Args::parse_with_flags`], the same shape as
/// `unknown_keys`/`missing_values`.
pub const BOOL_FLAGS: &[&str] = &["verbose", "dry-run"];

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        Self::parse_with_flags(argv, BOOL_FLAGS)
    }

    /// Like [`Args::parse`], with extra known boolean flag names on top
    /// of [`BOOL_FLAGS`]. A known boolean flag never consumes the next
    /// token, so `--verbose PROMPT` keeps PROMPT positional; `--flag=true`
    /// still works via the `=` form.
    pub fn parse_with_flags(
        argv: impl IntoIterator<Item = String>,
        bool_flags: &[&str],
    ) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if BOOL_FLAGS.contains(&rest) || bool_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// [`Args::from_env`] with subcommand-specific boolean flags.
    pub fn from_env_with_flags(bool_flags: &[&str]) -> Self {
        Self::parse_with_flags(std::env::args().skip(1), bool_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Scheduler worker count: `--jobs N`, or `--jobs auto` / `--jobs 0`
    /// for one worker per hardware thread. Defaults to 1 (serial) — the
    /// parallel scheduler is bit-identical but opt-in.
    pub fn jobs(&self) -> usize {
        match self.get("jobs") {
            None => 1,
            Some("auto") | Some("0") => crate::util::pool::max_parallelism(),
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--jobs expects an integer or 'auto', got {v:?}")),
        }
    }

    /// Scheduler phase ordering: `--sched staged|pipelined`. Defaults to
    /// the cross-layer pipelined executor (bit-identical to staged, one
    /// barrier fewer per layer — DESIGN.md §5). Returned as the raw
    /// spelling; `quant::SchedMode::parse` validates it.
    pub fn sched(&self) -> String {
        self.str_or("sched", "pipelined")
    }

    /// Hessian-cache location: `--hess-cache DIR|auto|off`. Defaults to
    /// `auto` (= `cache/hessians` under the working directory, next to the
    /// drivers' `results/`), so sweep drivers pay for each distinct pass-A
    /// accumulation once. `off` disables caching (DESIGN.md §9).
    pub fn hess_cache(&self) -> Option<std::path::PathBuf> {
        match self.get("hess-cache").unwrap_or("auto") {
            "off" | "none" | "0" => None,
            "auto" => Some(std::path::PathBuf::from("cache/hessians")),
            dir => Some(std::path::PathBuf::from(dir)),
        }
    }

    /// KV-cache storage width: `--kv-bits 32|8|2`. Defaults to 32 — the
    /// exact f32 path (DESIGN.md §12). Returned raw; the serving layer's
    /// `KvFormat::from_bits` validates it so the error message can name
    /// the supported set.
    pub fn kv_bits(&self) -> u32 {
        self.u64_or("kv-bits", 32) as u32
    }

    /// Kernel backend: `--backend reference|simd|auto`. Defaults to
    /// `reference` — the bit-exact path (DESIGN.md §13). Returned as the
    /// raw spelling; `tensor::kernels::Backend::parse` validates it so
    /// the error message can name the supported set.
    pub fn backend(&self) -> String {
        self.str_or("backend", "reference")
    }

    /// Reject mutually-exclusive options. Returns the offending pair's
    /// message so callers surface it however they report errors (the util
    /// layer stays anyhow-free).
    pub fn conflict(&self, a: &str, b: &str) -> Result<(), String> {
        if self.get(a).is_some() && self.get(b).is_some() {
            Err(format!("--{a} and --{b} are mutually exclusive — pass exactly one"))
        } else {
            Ok(())
        }
    }

    /// Option/flag keys this invocation carries that are **not** in
    /// `known` — lets a subcommand fail fast on typo'd flags instead of
    /// silently ignoring them (`rsq generate` does; a silently-dropped
    /// `--max-new` would otherwise just decode the default).
    pub fn unknown_keys(&self, known: &[&str]) -> Vec<String> {
        let mut out: Vec<String> = self
            .options
            .keys()
            .map(String::as_str)
            .chain(self.flags.iter().map(String::as_str))
            .filter(|k| !known.contains(k))
            .map(str::to_string)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Known **value** options that were passed without a value — the
    /// parser records `--max-new --verbose` as a bare flag "max-new",
    /// which [`Args::unknown_keys`] alone would accept; catching it here
    /// completes the fail-fast story (the option would otherwise be
    /// silently dropped and its default used).
    pub fn missing_values(&self, value_keys: &[&str]) -> Vec<String> {
        let mut out: Vec<String> = self
            .flags
            .iter()
            .filter(|f| value_keys.contains(&f.as_str()))
            .cloned()
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Parse a human duration into seconds: a plain number is seconds, the
/// suffixes `s`/`m`/`h`/`d` scale (`"30d"`, `"12h"`, `"90"`), case
/// handled like [`parse_bytes`]. Errors stay `String` — the util layer
/// is anyhow-free.
pub fn parse_duration_s(s: &str) -> Result<f64, String> {
    let lower = s.trim().to_ascii_lowercase();
    let (num, mult) = match lower.chars().last() {
        Some('s') => (&lower[..lower.len() - 1], 1.0),
        Some('m') => (&lower[..lower.len() - 1], 60.0),
        Some('h') => (&lower[..lower.len() - 1], 3600.0),
        Some('d') => (&lower[..lower.len() - 1], 86400.0),
        _ => (lower.as_str(), 1.0),
    };
    match num.trim().parse::<f64>() {
        Ok(v) if v >= 0.0 && v.is_finite() => Ok(v * mult),
        _ => Err(format!("bad duration {s:?} — expected e.g. 90, 45m, 12h, 30d")),
    }
}

/// Parse a human byte size: plain bytes, or `k`/`m`/`g` (binary) suffix
/// (`"500m"`, `"2g"`, `"1048576"`).
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let s = s.trim();
    let lower = s.to_ascii_lowercase();
    let (num, mult) = match lower.chars().last() {
        Some('k') => (&lower[..lower.len() - 1], 1u64 << 10),
        Some('m') => (&lower[..lower.len() - 1], 1u64 << 20),
        Some('g') => (&lower[..lower.len() - 1], 1u64 << 30),
        _ => (lower.as_str(), 1),
    };
    match num.trim().parse::<u64>() {
        Ok(v) => v
            .checked_mul(mult)
            .ok_or_else(|| format!("byte size {s:?} overflows")),
        _ => Err(format!("bad byte size {s:?} — expected e.g. 1048576, 500m, 2g")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_and_flags() {
        let a = parse("table2 --config small --seeds=3 --verbose --bits 3");
        assert_eq!(a.positional, vec!["table2"]);
        assert_eq!(a.str_or("config", "x"), "small");
        assert_eq!(a.usize_or("seeds", 1), 3);
        assert_eq!(a.usize_or("bits", 4), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.f32_or("rmin", 0.01), 0.01);
        assert_eq!(a.list_or("methods", &["rsq", "quarot"]), vec!["rsq", "quarot"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn boolean_flag_never_swallows_a_positional() {
        // the regression: `rsq generate --verbose PROMPT` used to record
        // verbose=PROMPT, so flag("verbose") was false AND the positional
        // vanished
        let a = parse("generate --verbose 1,2,3");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["generate", "1,2,3"]);
        assert_eq!(a.get("verbose"), None);
        // same for a mid-line --dry-run before a value option
        let b = parse("quantize --dry-run --bits 3");
        assert!(b.flag("dry-run"));
        assert_eq!(b.usize_or("bits", 0), 3);
        // the = form still reaches flag() through the option path
        let c = parse("generate --verbose=true 9");
        assert!(c.flag("verbose"));
        assert_eq!(c.positional, vec!["generate", "9"]);
    }

    #[test]
    fn parse_with_flags_extends_the_shared_set() {
        let argv = |s: &str| s.split_whitespace().map(String::from);
        let a = Args::parse_with_flags(argv("bench --warm 7"), &["warm"]);
        assert!(a.flag("warm"));
        assert_eq!(a.positional, vec!["bench", "7"]);
        // without the extra name the old value-option behavior remains
        let b = Args::parse_with_flags(argv("bench --warm 7"), &[]);
        assert_eq!(b.get("warm"), Some("7"));
        // the shared BOOL_FLAGS set applies even with an empty extra set
        let c = Args::parse_with_flags(argv("bench --verbose 7"), &[]);
        assert!(c.flag("verbose"));
        assert_eq!(c.positional, vec!["bench", "7"]);
    }

    #[test]
    fn list_parsing() {
        let a = parse("--methods rsq,gptq , rtn");
        assert_eq!(a.list_or("methods", &[]), vec!["rsq", "gptq"]);
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        parse("--seeds abc").usize_or("seeds", 1);
    }

    #[test]
    fn jobs_parsing() {
        assert_eq!(parse("quantize").jobs(), 1, "serial by default");
        assert_eq!(parse("--jobs 4").jobs(), 4);
        assert_eq!(parse("--jobs=2").jobs(), 2);
        assert!(parse("--jobs auto").jobs() >= 1);
        assert!(parse("--jobs 0").jobs() >= 1, "0 = one per hardware thread");
    }

    #[test]
    #[should_panic]
    fn bad_jobs_panics() {
        parse("--jobs many").jobs();
    }

    #[test]
    fn sched_parsing() {
        assert_eq!(parse("quantize").sched(), "pipelined", "pipelined by default");
        assert_eq!(parse("--sched staged").sched(), "staged");
        assert_eq!(parse("--sched=pipelined").sched(), "pipelined");
    }

    #[test]
    fn kv_bits_parsing() {
        assert_eq!(parse("generate").kv_bits(), 32, "exact f32 path by default");
        assert_eq!(parse("--kv-bits 8").kv_bits(), 8);
        assert_eq!(parse("--kv-bits=2").kv_bits(), 2);
    }

    #[test]
    fn backend_parsing() {
        assert_eq!(parse("quantize").backend(), "reference", "bit-exact path by default");
        assert_eq!(parse("--backend simd").backend(), "simd");
        assert_eq!(parse("--backend=auto").backend(), "auto");
    }

    #[test]
    fn hess_cache_parsing() {
        assert_eq!(
            parse("quantize").hess_cache(),
            Some(std::path::PathBuf::from("cache/hessians")),
            "caching defaults to auto for CLI runs"
        );
        assert_eq!(parse("--hess-cache off").hess_cache(), None);
        assert_eq!(parse("--hess-cache none").hess_cache(), None);
        assert_eq!(
            parse("--hess-cache /tmp/h").hess_cache(),
            Some(std::path::PathBuf::from("/tmp/h"))
        );
    }

    #[test]
    fn unknown_keys_catches_typos() {
        let a = parse("generate --artifact out --max-mew 9 --verbos");
        assert_eq!(
            a.unknown_keys(&["artifact", "max-new", "verbose"]),
            vec!["max-mew".to_string(), "verbos".to_string()]
        );
        assert!(a.unknown_keys(&["artifact", "max-mew", "verbos"]).is_empty());
        // positionals are not flags
        assert!(parse("generate").unknown_keys(&[]).is_empty());
    }

    #[test]
    fn missing_values_catches_valueless_value_options() {
        // `--prompt --max-new 4` parses "prompt" as a bare flag: a known
        // name, so unknown_keys accepts it — missing_values must not
        let a = parse("generate --artifact d --prompt --max-new 4");
        assert!(a.unknown_keys(&["artifact", "prompt", "max-new"]).is_empty());
        assert_eq!(a.missing_values(&["artifact", "prompt", "max-new"]), vec!["prompt"]);
        // trailing value option with no value
        let b = parse("generate --artifact d --max-new");
        assert_eq!(b.missing_values(&["artifact", "max-new"]), vec!["max-new"]);
        // boolean flags are not value options and stay fine
        let c = parse("generate --artifact d --verbose");
        assert!(c.missing_values(&["artifact", "max-new"]).is_empty());
    }

    #[test]
    fn durations_parse() {
        assert_eq!(parse_duration_s("90"), Ok(90.0));
        assert_eq!(parse_duration_s("45m"), Ok(2700.0));
        assert_eq!(parse_duration_s("12h"), Ok(43200.0));
        assert_eq!(parse_duration_s("30d"), Ok(2_592_000.0));
        assert_eq!(parse_duration_s("30D"), Ok(2_592_000.0), "suffix case like parse_bytes");
        assert_eq!(parse_duration_s("1.5h"), Ok(5400.0));
        assert!(parse_duration_s("soon").is_err());
        assert!(parse_duration_s("-5m").is_err());
        assert!(parse_duration_s("").is_err());
    }

    #[test]
    fn byte_sizes_parse() {
        assert_eq!(parse_bytes("1048576"), Ok(1 << 20));
        assert_eq!(parse_bytes("500m"), Ok(500 << 20));
        assert_eq!(parse_bytes("2G"), Ok(2 << 30));
        assert_eq!(parse_bytes("3k"), Ok(3 << 10));
        assert!(parse_bytes("lots").is_err());
        assert!(parse_bytes("99999999999g").is_err(), "overflow is an error");
    }

    #[test]
    fn conflicting_options_rejected() {
        let a = parse("eval --artifact out --model ckpt.bin");
        let err = a.conflict("artifact", "model").unwrap_err();
        assert!(err.contains("--artifact"), "{err}");
        assert!(err.contains("mutually exclusive"), "{err}");
        // either alone is fine, and so is neither
        assert!(parse("eval --artifact out").conflict("artifact", "model").is_ok());
        assert!(parse("eval --model ckpt.bin").conflict("artifact", "model").is_ok());
        assert!(parse("eval").conflict("artifact", "model").is_ok());
    }
}
