//! Criterion-style micro/macro benchmark harness (criterion itself is not
//! in the offline vendor set).
//!
//! Usage (inside a `[[bench]] harness = false` target):
//! ```ignore
//! let mut b = Bench::new("hessian_accum/small");
//! b.iter(|| engine.run("hess_d_t128", &inputs));
//! b.report(); // "hessian_accum/small  time: [12.01 ms 12.34 ms 12.80 ms]"
//! ```
//! Warmup runs are discarded; the report prints min/mean/max plus stddev
//! and throughput when `bytes`/`elements` are set, mirroring criterion's
//! output shape so downstream tooling keeps working.

use std::time::Instant;

pub struct Bench {
    name: String,
    warmup: usize,
    samples: usize,
    bytes: Option<u64>,
    elements: Option<u64>,
    times: Vec<f64>, // seconds
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            warmup: 2,
            samples: 10,
            bytes: None,
            elements: None,
            times: Vec::new(),
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Declare bytes processed per iteration (enables GB/s in the report).
    pub fn throughput_bytes(mut self, bytes: u64) -> Self {
        self.bytes = Some(bytes);
        self
    }

    /// Declare elements processed per iteration (enables Melem/s).
    pub fn throughput_elements(mut self, elements: u64) -> Self {
        self.elements = Some(elements);
        self
    }

    /// Run the closure warmup+samples times, recording sample wall times.
    pub fn iter<T>(&mut self, mut f: impl FnMut() -> T) -> &mut Self {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        self.times.clear();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            self.times.push(t0.elapsed().as_secs_f64());
        }
        self
    }

    pub fn mean_s(&self) -> f64 {
        super::mean(&self.times)
    }

    pub fn min_s(&self) -> f64 {
        self.times.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max_s(&self) -> f64 {
        self.times.iter().cloned().fold(0.0, f64::max)
    }

    fn fmt_time(s: f64) -> String {
        if s < 1e-6 {
            format!("{:.2} ns", s * 1e9)
        } else if s < 1e-3 {
            format!("{:.2} µs", s * 1e6)
        } else if s < 1.0 {
            format!("{:.2} ms", s * 1e3)
        } else {
            format!("{:.2} s", s)
        }
    }

    /// Print a criterion-shaped report line; returns mean seconds.
    pub fn report(&self) -> f64 {
        let mean = self.mean_s();
        let sd = super::stddev(&self.times);
        let mut line = format!(
            "{:<44} time: [{} {} {}]  σ={}",
            self.name,
            Self::fmt_time(self.min_s()),
            Self::fmt_time(mean),
            Self::fmt_time(self.max_s()),
            Self::fmt_time(sd),
        );
        if let Some(b) = self.bytes {
            line += &format!("  thrpt: {:.2} GiB/s", b as f64 / mean / (1u64 << 30) as f64);
        }
        if let Some(e) = self.elements {
            line += &format!("  thrpt: {:.2} Melem/s", e as f64 / mean / 1e6);
        }
        println!("{line}");
        mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_samples() {
        let mut b = Bench::new("t").warmup(1).samples(5);
        let mut n = 0u64;
        b.iter(|| {
            n += 1;
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert_eq!(b.times.len(), 5);
        assert!(b.mean_s() >= 150e-6, "{}", b.mean_s());
        assert!(b.min_s() <= b.mean_s() && b.mean_s() <= b.max_s());
    }

    #[test]
    fn time_formatting() {
        assert!(Bench::fmt_time(2e-9).ends_with("ns"));
        assert!(Bench::fmt_time(2e-6).ends_with("µs"));
        assert!(Bench::fmt_time(2e-3).ends_with("ms"));
        assert!(Bench::fmt_time(2.0).ends_with(" s"));
    }
}
