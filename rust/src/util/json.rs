//! Minimal JSON *writer* (serde is not in the vendor set).
//!
//! Experiment drivers dump machine-readable run records under results/
//! (DESIGN.md §Perf) and the score-visualization driver (paper Figs. 10-14)
//! writes per-layer score series. Only construction + serialization —
//! nothing in this repo parses JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Self {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f32]> for Json {
    fn from(v: &[f32]) -> Self {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_shapes() {
        let j = Json::obj()
            .set("name", "rsq")
            .set("ppl", 9.05_f64)
            .set("ok", true)
            .set("scores", vec![1.0_f64, 2.0, 3.0]);
        assert_eq!(
            j.to_string(),
            r#"{"name":"rsq","ok":true,"ppl":9.05,"scores":[1,2,3]}"#
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
