//! Token-stream generators with controllable structure.
//!
//! Layout of the token space (vocab V, V >= 32):
//!   0           BOS  — document start ("attention sink" position)
//!   1           EOS  — sentence boundary
//!   2..=11      D0..D9 — digit tokens for arithmetic patterns
//!   12          OP   — arithmetic operator
//!   13          EQ   — arithmetic equals
//!   14..V       content tokens, partitioned into `n_topics` topic blocks
//!
//! Per content step the next token is drawn from a mixture of
//!   (a) a deterministic bigram chain within the current topic,
//!   (b) the topic's Zipfian unigram,
//!   (c) the global Zipfian unigram,
//! plus occasional arithmetic sentences (D_a OP D_b EQ D_{(a+b)%10}) and
//! long-range 2-gram repeats (induction-head food). All tables derive from
//! a master seed, so train/eval splits share the distribution while being
//! disjoint streams.

use super::CorpusKind;
use crate::util::Pcg;

pub const BOS: i32 = 0;
pub const EOS: i32 = 1;
pub const D0: i32 = 2; // digits D0..=D9 are tokens 2..=11
pub const OP: i32 = 12;
pub const EQ: i32 = 13;
pub const CONTENT0: usize = 14;

/// Mixture weights and structural rates for one corpus flavor.
#[derive(Clone, Copy, Debug)]
pub struct Profile {
    pub p_bigram: f32,
    pub p_topic: f32,
    pub p_global: f32,
    pub zipf_alpha: f64,
    pub arith_rate: f32,
    pub repeat_rate: f32,
    pub n_topics: usize,
    /// fraction of content tokens actually used (PTB has a small vocab)
    pub vocab_frac: f32,
    /// fraction of documents that are code-like periodic blocks (RedPajama)
    pub code_frac: f32,
}

impl Profile {
    pub fn for_kind(kind: CorpusKind) -> Self {
        match kind {
            CorpusKind::Wiki => Profile {
                p_bigram: 0.50, p_topic: 0.30, p_global: 0.20,
                zipf_alpha: 1.2, arith_rate: 0.03, repeat_rate: 0.05,
                n_topics: 8, vocab_frac: 1.0, code_frac: 0.0,
            },
            CorpusKind::C4 => Profile {
                p_bigram: 0.35, p_topic: 0.30, p_global: 0.35,
                zipf_alpha: 1.05, arith_rate: 0.01, repeat_rate: 0.03,
                n_topics: 8, vocab_frac: 1.0, code_frac: 0.0,
            },
            CorpusKind::Ptb => Profile {
                p_bigram: 0.60, p_topic: 0.25, p_global: 0.15,
                zipf_alpha: 1.4, arith_rate: 0.0, repeat_rate: 0.04,
                n_topics: 4, vocab_frac: 0.5, code_frac: 0.0,
            },
            CorpusKind::RedPajama => Profile {
                p_bigram: 0.45, p_topic: 0.30, p_global: 0.25,
                zipf_alpha: 1.2, arith_rate: 0.02, repeat_rate: 0.05,
                n_topics: 8, vocab_frac: 1.0, code_frac: 0.3,
            },
        }
    }
}

/// Static structure shared by a (vocab, profile, master-seed) triple:
/// topic membership, bigram successor tables, Zipf weights.
pub struct TokenSpace {
    pub vocab: usize,
    pub profile: Profile,
    pub n_content: usize,
    /// topic id for each content token index (0..n_content)
    topic_of: Vec<usize>,
    /// content tokens grouped by topic
    pub topic_tokens: Vec<Vec<i32>>,
    /// deterministic bigram successor per content token
    successor: Vec<i32>,
    /// Zipf weight per content token (global)
    zipf_w: Vec<f32>,
}

impl TokenSpace {
    pub fn new(vocab: usize, profile: Profile, master_seed: u64) -> Self {
        assert!(vocab > CONTENT0 + profile.n_topics * 2, "vocab too small: {vocab}");
        let n_all = vocab - CONTENT0;
        let n_content = ((n_all as f32 * profile.vocab_frac) as usize).max(profile.n_topics * 2);
        let mut rng = Pcg::with_stream(master_seed, 0xC0FFEE);
        let mut topic_of = vec![0usize; n_content];
        let mut topic_tokens = vec![Vec::new(); profile.n_topics];
        for (i, t) in topic_of.iter_mut().enumerate() {
            *t = i % profile.n_topics;
            topic_tokens[*t].push((CONTENT0 + i) as i32);
        }
        // deterministic bigram chain within each topic
        let mut successor = vec![0i32; n_content];
        for (i, s) in successor.iter_mut().enumerate() {
            let peers = &topic_tokens[topic_of[i]];
            *s = peers[rng.below(peers.len())];
        }
        // global Zipf over content tokens in a random frequency order
        let mut order: Vec<usize> = (0..n_content).collect();
        rng.shuffle(&mut order);
        let mut zipf_w = vec![0.0f32; n_content];
        for (rank, &tok) in order.iter().enumerate() {
            zipf_w[tok] = (1.0 / (rank as f64 + 1.0).powf(profile.zipf_alpha)) as f32;
        }
        TokenSpace { vocab, profile, n_content, topic_of, topic_tokens, successor, zipf_w }
    }

    pub fn is_content(&self, tok: i32) -> bool {
        (tok as usize) >= CONTENT0 && ((tok as usize) - CONTENT0) < self.n_content
    }

    pub fn topic_of_token(&self, tok: i32) -> Option<usize> {
        self.is_content(tok).then(|| self.topic_of[tok as usize - CONTENT0])
    }

    pub fn successor_of(&self, tok: i32) -> i32 {
        self.successor[tok as usize - CONTENT0]
    }

    fn sample_zipf(&self, rng: &mut Pcg) -> i32 {
        (CONTENT0 + rng.weighted(&self.zipf_w)) as i32
    }

    fn sample_topic(&self, topic: usize, rng: &mut Pcg) -> i32 {
        // Zipf restricted to the topic's tokens
        let toks = &self.topic_tokens[topic];
        let ws: Vec<f32> = toks.iter().map(|&t| self.zipf_w[t as usize - CONTENT0]).collect();
        toks[rng.weighted(&ws)]
    }
}

/// Streaming token generator over a `TokenSpace`.
pub struct Generator {
    pub space: TokenSpace,
    rng: Pcg,
    topic: usize,
    prev: i32,
    sent_left: usize,
    doc_left: usize,
    history: Vec<i32>,
    code_mode: bool,
    code_pattern: Vec<i32>,
    code_pos: usize,
    /// queued multi-token emissions (arithmetic sentences / repeats)
    pending: Vec<i32>,
}

impl Generator {
    /// `stream` separates train vs eval vs probe draws over one TokenSpace.
    pub fn new(vocab: usize, kind: CorpusKind, master_seed: u64, stream: u64) -> Self {
        let profile = Profile::for_kind(kind);
        let space = TokenSpace::new(vocab, profile, master_seed);
        let mut rng = Pcg::with_stream(master_seed ^ 0x9e37_79b9, stream);
        let topic = rng.below(profile.n_topics);
        Generator {
            space,
            rng,
            topic,
            prev: -1,
            sent_left: 0,
            doc_left: 0,
            history: Vec::new(),
            code_mode: false,
            code_pattern: Vec::new(),
            code_pos: 0,
            pending: Vec::new(),
        }
    }

    fn start_doc(&mut self) -> i32 {
        let p = self.space.profile;
        self.topic = self.rng.below(p.n_topics);
        self.doc_left = 64 + self.rng.below(192);
        self.sent_left = 0;
        self.prev = -1;
        self.code_mode = p.code_frac > 0.0 && self.rng.f32() < p.code_frac;
        if self.code_mode {
            // a short periodic "function body" repeated verbatim
            let len = 4 + self.rng.below(5);
            self.code_pattern = (0..len)
                .map(|_| self.space.sample_topic(self.topic, &mut self.rng))
                .collect();
            self.code_pos = 0;
        }
        BOS
    }

    /// Next token of the infinite stream.
    pub fn next_token(&mut self) -> i32 {
        if self.doc_left == 0 {
            let t = self.start_doc();
            self.push_history(t);
            return t;
        }
        self.doc_left -= 1;

        if self.code_mode {
            let t = self.code_pattern[self.code_pos % self.code_pattern.len()];
            self.code_pos += 1;
            if self.code_pos % (self.code_pattern.len() * 4) == 0 {
                // jump to a fresh pattern occasionally
                self.code_pos = 0;
                let len = 4 + self.rng.below(5);
                self.code_pattern = (0..len)
                    .map(|_| self.space.sample_topic(self.topic, &mut self.rng))
                    .collect();
            }
            self.push_history(t);
            return t;
        }

        if self.sent_left == 0 {
            self.sent_left = 5 + self.rng.below(16);
            if self.prev >= 0 {
                self.push_history(EOS);
                self.sent_left -= 1;
                return EOS;
            }
        }
        self.sent_left -= 1;
        let p = self.space.profile;

        // arithmetic sentence: D_a OP D_b EQ D_{(a+b)%10}
        if self.rng.f32() < p.arith_rate {
            let a = self.rng.below(10) as i32;
            let b = self.rng.below(10) as i32;
            // first token returns now; the remaining four drain via `pending`
            for t in [D0 + a, OP, D0 + b, EQ, D0 + (a + b) % 10] {
                self.push_history(t);
            }
            let n = self.history.len();
            self.pending = self.history[n - 4..].to_vec();
            return self.history[n - 5];
        }

        // long-range repeat: replay a 2-gram seen earlier (induction food)
        if p.repeat_rate > 0.0 && self.history.len() > 16 && self.rng.f32() < p.repeat_rate {
            let i = self.rng.below(self.history.len() - 2);
            let (a, b) = (self.history[i], self.history[i + 1]);
            if self.space.is_content(a) && self.space.is_content(b) {
                self.push_history(a);
                self.pending = vec![b];
                return a;
            }
        }

        let roll = self.rng.f32() * (p.p_bigram + p.p_topic + p.p_global);
        let t = if self.prev >= 0 && self.space.is_content(self.prev) && roll < p.p_bigram {
            self.space.successor_of(self.prev)
        } else if roll < p.p_bigram + p.p_topic {
            self.space.sample_topic(self.topic, &mut self.rng)
        } else {
            self.space.sample_zipf(&mut self.rng)
        };
        self.push_history(t);
        t
    }

    fn push_history(&mut self, t: i32) {
        self.prev = t;
        self.history.push(t);
        if self.history.len() > 4096 {
            self.history.drain(..2048);
        }
    }

    /// Fill a fixed-length sample, draining pending queued tokens first.
    pub fn sample(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        while out.len() < len {
            if let Some(t) = self.pending_pop() {
                out.push(t);
                continue;
            }
            out.push(self.next_token());
        }
        out
    }

    fn pending_pop(&mut self) -> Option<i32> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.pending.remove(0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(kind: CorpusKind) -> Generator {
        Generator::new(256, kind, 42, 1)
    }

    #[test]
    fn deterministic_for_seed() {
        let a = gen(CorpusKind::Wiki).sample(256);
        let b = gen(CorpusKind::Wiki).sample(256);
        assert_eq!(a, b);
    }

    #[test]
    fn streams_differ() {
        let a = Generator::new(256, CorpusKind::Wiki, 42, 1).sample(256);
        let b = Generator::new(256, CorpusKind::Wiki, 42, 2).sample(256);
        assert_ne!(a, b);
    }

    #[test]
    fn tokens_in_range() {
        for kind in CorpusKind::ALL {
            let s = gen(kind).sample(2000);
            assert!(s.iter().all(|&t| t >= 0 && (t as usize) < 256), "{kind:?}");
        }
    }

    #[test]
    fn bigram_structure_learnable() {
        // successor pairs must appear far above chance
        let mut g = gen(CorpusKind::Wiki);
        let s = g.sample(20_000);
        let mut hits = 0usize;
        let mut content_pairs = 0usize;
        for w in s.windows(2) {
            if g.space.is_content(w[0]) && g.space.is_content(w[1]) {
                content_pairs += 1;
                if g.space.successor_of(w[0]) == w[1] {
                    hits += 1;
                }
            }
        }
        let rate = hits as f64 / content_pairs as f64;
        assert!(rate > 0.2, "bigram hit rate {rate}");
    }

    #[test]
    fn zipf_skew() {
        let mut g = gen(CorpusKind::Wiki);
        let s = g.sample(30_000);
        let mut counts = vec![0usize; 256];
        for &t in &s {
            counts[t as usize] += 1;
        }
        let mut c: Vec<usize> = counts[CONTENT0..].iter().cloned().filter(|&x| x > 0).collect();
        c.sort_unstable_by(|a, b| b.cmp(a));
        // top decile of tokens should carry a large share of the mass
        let top = c.iter().take(c.len() / 10).sum::<usize>() as f64;
        let all = c.iter().sum::<usize>() as f64;
        assert!(top / all > 0.25, "top-decile share {}", top / all);
    }

    #[test]
    fn arithmetic_patterns_consistent() {
        let mut g = gen(CorpusKind::Wiki);
        let s = g.sample(50_000);
        let mut seen = 0;
        for w in s.windows(5) {
            if w[1] == OP && w[3] == EQ {
                let a = w[0] - D0;
                let b = w[2] - D0;
                let c = w[4] - D0;
                assert!((0..10).contains(&a) && (0..10).contains(&b));
                assert_eq!(c, (a + b) % 10, "arith pattern broken");
                seen += 1;
            }
        }
        assert!(seen > 20, "too few arithmetic sentences: {seen}");
    }

    #[test]
    fn ptb_uses_fewer_tokens() {
        let sw = gen(CorpusKind::Wiki).sample(20_000);
        let sp = gen(CorpusKind::Ptb).sample(20_000);
        let distinct = |s: &[i32]| {
            let mut set = std::collections::HashSet::new();
            set.extend(s.iter().cloned());
            set.len()
        };
        assert!(distinct(&sp) < distinct(&sw));
    }

    #[test]
    fn redpajama_has_periodic_blocks() {
        let mut g = gen(CorpusKind::RedPajama);
        let s = g.sample(30_000);
        // code-like docs repeat short patterns: count exact (t, t+k) matches
        let mut periodic = 0usize;
        for k in 4..9 {
            for i in 0..(s.len() - k) {
                if s[i] == s[i + k] && g.space.is_content(s[i]) {
                    periodic += 1;
                }
            }
        }
        let base = gen(CorpusKind::Wiki).sample(30_000);
        let mut periodic_base = 0usize;
        for k in 4..9 {
            for i in 0..(base.len() - k) {
                if base[i] == base[i + k] {
                    periodic_base += 1;
                }
            }
        }
        assert!(periodic > periodic_base, "{periodic} <= {periodic_base}");
    }

    #[test]
    fn docs_start_with_bos() {
        let mut g = gen(CorpusKind::Wiki);
        let s = g.sample(5000);
        assert!(s.iter().filter(|&&t| t == BOS).count() > 5);
    }
}
