//! Synthetic corpus machinery — the offline stand-in for WikiText-2 / C4 /
//! PTB / RedPajama (DESIGN.md §Substitutions).
//!
//! The generators produce token streams with *learnable*, non-uniform
//! structure: Zipfian unigrams, deterministic bigram chains, topic
//! clusters, sentence/document boundaries, arithmetic patterns, and
//! occasional long-range repeats. A small transformer trained on this
//! reaches perplexity far below the vocab size, so quantization deltas
//! (the paper's signal) are measurable.

pub mod dataset;
pub mod generator;

pub use dataset::{expand_dataset, CalibSet};
pub use generator::{Generator, Profile, TokenSpace};

/// Which synthetic corpus to draw from (paper Tab. 4 calibration ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CorpusKind {
    /// WikiText-2 stand-in: structured encyclopedic-like text.
    Wiki,
    /// C4 stand-in: noisier web text (flatter unigrams, weaker bigrams).
    C4,
    /// PTB stand-in: small effective vocab, stiff newswire-like bigrams.
    Ptb,
    /// RedPajama stand-in: mixture of wiki-like and code-like documents.
    RedPajama,
}

impl CorpusKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "wiki" | "wikitext" | "wikitext2" => Some(Self::Wiki),
            "c4" => Some(Self::C4),
            "ptb" => Some(Self::Ptb),
            "rp" | "redpajama" => Some(Self::RedPajama),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Wiki => "wiki",
            Self::C4 => "c4",
            Self::Ptb => "ptb",
            Self::RedPajama => "redpajama",
        }
    }

    pub const ALL: [CorpusKind; 4] = [Self::Wiki, Self::C4, Self::Ptb, Self::RedPajama];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_round_trip() {
        for k in CorpusKind::ALL {
            assert_eq!(CorpusKind::parse(k.name()), Some(k));
        }
        assert_eq!(CorpusKind::parse("wikitext2"), Some(CorpusKind::Wiki));
        assert_eq!(CorpusKind::parse("nope"), None);
    }
}
