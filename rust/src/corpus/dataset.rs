//! Calibration/eval sets + dataset expansion (paper Sec. 4.4).

use super::{CorpusKind, Generator};

/// A set of fixed-length token samples (calibration or evaluation).
#[derive(Clone, Debug)]
pub struct CalibSet {
    pub samples: Vec<Vec<i32>>,
    pub seq_len: usize,
    pub kind: CorpusKind,
}

impl CalibSet {
    /// Draw `n` samples of `seq_len` tokens. `stream` decorrelates calib
    /// (stream 1) from eval (stream 2) from probes (stream 3+) over the
    /// same token distribution.
    pub fn generate(
        vocab: usize,
        kind: CorpusKind,
        n: usize,
        seq_len: usize,
        master_seed: u64,
        stream: u64,
    ) -> Self {
        let mut g = Generator::new(vocab, kind, master_seed, stream);
        let samples = (0..n).map(|_| g.sample(seq_len)).collect();
        CalibSet { samples, seq_len, kind }
    }

    pub fn total_tokens(&self) -> usize {
        self.samples.len() * self.seq_len
    }

    /// Occurrence counts over the set — feeds the TokenFreq strategy
    /// (paper Sec. 4.3: rarer tokens are more important).
    pub fn token_frequencies(&self, vocab: usize) -> Vec<u32> {
        let mut counts = vec![0u32; vocab];
        for s in &self.samples {
            for &t in s {
                counts[t as usize] += 1;
            }
        }
        counts
    }

    /// Pad (cycling) the sample list so it is a multiple of `batch`.
    pub fn pad_to_batch(&mut self, batch: usize) {
        let mut i = 0;
        while self.samples.len() % batch != 0 {
            let s = self.samples[i % self.samples.len()].clone();
            self.samples.push(s);
            i += 1;
        }
    }
}

/// Dataset expansion (paper Sec. 4.4): for factor M, append M-1 rotated
/// copies of each sample, shifted forward by k*T/M with the overflow
/// re-inserted at the beginning. This moves every token through the
/// "important" (initial/final) positions that AttnCon favors.
pub fn expand_dataset(set: &CalibSet, m: usize) -> CalibSet {
    assert!(m >= 1);
    let t = set.seq_len;
    let mut samples = Vec::with_capacity(set.samples.len() * m);
    for s in &set.samples {
        samples.push(s.clone());
        for k in 1..m {
            let off = k * t / m;
            // shift forward by `off`: the last `off` tokens wrap to the front
            let mut rot = Vec::with_capacity(t);
            rot.extend_from_slice(&s[t - off..]);
            rot.extend_from_slice(&s[..t - off]);
            samples.push(rot);
        }
    }
    CalibSet { samples, seq_len: t, kind: set.kind }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set() -> CalibSet {
        CalibSet::generate(256, CorpusKind::Wiki, 4, 64, 7, 1)
    }

    #[test]
    fn generate_shapes() {
        let s = set();
        assert_eq!(s.samples.len(), 4);
        assert!(s.samples.iter().all(|x| x.len() == 64));
        assert_eq!(s.total_tokens(), 256);
    }

    #[test]
    fn calib_and_eval_streams_disjoint() {
        let a = CalibSet::generate(256, CorpusKind::Wiki, 2, 64, 7, 1);
        let b = CalibSet::generate(256, CorpusKind::Wiki, 2, 64, 7, 2);
        assert_ne!(a.samples, b.samples);
    }

    #[test]
    fn frequencies_sum_to_tokens() {
        let s = set();
        let f = s.token_frequencies(256);
        assert_eq!(f.iter().sum::<u32>() as usize, s.total_tokens());
    }

    #[test]
    fn expansion_count_and_multiset() {
        let s = set();
        let e = expand_dataset(&s, 8);
        assert_eq!(e.samples.len(), 32);
        // each rotation preserves the token multiset of its source
        for (i, orig) in s.samples.iter().enumerate() {
            for k in 0..8 {
                let mut a = orig.clone();
                let mut b = e.samples[i * 8 + k].clone();
                a.sort_unstable();
                b.sort_unstable();
                assert_eq!(a, b, "sample {i} rotation {k}");
            }
        }
    }

    #[test]
    fn expansion_shift_offsets() {
        let s = CalibSet {
            samples: vec![(0..8).collect()],
            seq_len: 8,
            kind: CorpusKind::Wiki,
        };
        let e = expand_dataset(&s, 4);
        assert_eq!(e.samples[0], vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(e.samples[1], vec![6, 7, 0, 1, 2, 3, 4, 5]);
        assert_eq!(e.samples[2], vec![4, 5, 6, 7, 0, 1, 2, 3]);
        assert_eq!(e.samples[3], vec![2, 3, 4, 5, 6, 7, 0, 1]);
    }

    #[test]
    fn expansion_factor_one_is_identity() {
        let s = set();
        let e = expand_dataset(&s, 1);
        assert_eq!(e.samples, s.samples);
    }

    #[test]
    fn pad_to_batch_cycles() {
        let mut s = set();
        s.samples.truncate(3);
        s.pad_to_batch(4);
        assert_eq!(s.samples.len(), 4);
        assert_eq!(s.samples[3], s.samples[0]);
    }
}
