//! The PJRT execution engine: compile-on-first-use cache over the AOT
//! artifact set of one model config.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Every module is compiled at most once per
//! process; executions validate input arity/shape against the manifest
//! before hitting PJRT so shape bugs fail with a readable error.
//!
//! The engine is **shared across the scheduler's worker threads** (see
//! DESIGN.md §5): the compile cache and the per-module stats live behind
//! `Mutex`es, compiled executables are handed out as `Arc` clones, and
//! `exec_ref` holds no lock while PJRT executes — concurrent executions of
//! the same (or different) modules proceed in parallel.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// Compile cache + execute front-end for one artifact directory.
///
/// One `Engine` is created per model config and shared by reference across
/// the whole process, including `quant::pipeline`'s worker threads.
/// One compile-cache entry: a per-module lock so a slow first-use compile
/// only blocks callers of the *same* module, never unrelated cache hits.
type CacheSlot = Arc<Mutex<Option<Arc<xla::PjRtLoadedExecutable>>>>;

pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Parsed `manifest.txt` of the artifact set (module + param specs).
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, CacheSlot>>,
    /// serializes `client.compile` calls: compilation is the one path that
    /// hands out new wrappers around the client handle, so it must not
    /// race itself (see the thread-safety contract below)
    compile_lock: Mutex<()>,
    /// cumulative (calls, seconds) per module — feeds the perf report
    stats: Mutex<HashMap<String, (u64, f64)>>,
}

// SAFETY — the thread-safety contract (DESIGN.md §5). Sharing the engine
// across threads rests on:
//
// 1. The PJRT C API requires implementations to support concurrent calls
//    (the CPU plugin is internally synchronized), so `compile` and
//    `execute` may run from any thread; the `xla` crate merely does not
//    declare this.
// 2. All rust-side mutable state (`cache`, `stats`) is behind `Mutex`es.
// 3. The client handle is never cloned by this module, and `compile` —
//    the one crate API that mints new wrappers around the client handle —
//    is serialized by `compile_lock`, so a rust-side non-atomic refcount
//    inside the client wrapper is never mutated concurrently by us.
// 4. Cached executables are retained by the cache for the engine's whole
//    lifetime, so worker threads only ever drop `Arc` clones (atomic),
//    never the underlying executable.
//
// AUDIT REQUIREMENT on the vendored `xla` crate: `execute` and the
// literal/buffer paths used in `exec_ref` must not clone/drop a
// non-atomic shared handle internally. If a vendored crate bump violates
// this, run with `--jobs 1` (the default) until it is fixed.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load the artifact set for `config` (e.g. "tiny") from
    /// `artifacts/<config>/`, honoring `RSQ_ARTIFACTS`.
    pub fn load(config: &str) -> Result<Engine> {
        let dir = crate::artifacts_dir(config);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_lock: Mutex::new(()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// The model config baked into this artifact set.
    pub fn config(&self) -> &crate::model::ModelConfig {
        &self.manifest.config
    }

    /// Compile (or fetch cached) one module.
    ///
    /// The global map lock is held only to fetch the module's cache slot;
    /// the compile itself runs under that slot's own lock. A module is
    /// still compiled at most once (concurrent first-use requests queue on
    /// the slot), but a slow compile never blocks cache hits — or first
    /// compiles — of other modules. A failed compile leaves the slot
    /// empty, so a later call retries.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let slot: CacheSlot = {
            let mut cache = self.cache.lock().unwrap();
            cache.entry(name.to_string()).or_default().clone()
        };
        let mut slot = slot.lock().unwrap();
        if let Some(e) = slot.as_ref() {
            return Ok(e.clone());
        }
        let spec = self.manifest.module(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = {
            let _serialize = self.compile_lock.lock().unwrap();
            self.client
                .compile(&comp)
                .with_context(|| format!("compile module {name}"))?
        };
        let exe = Arc::new(exe);
        *slot = Some(exe.clone());
        let dt = t0.elapsed().as_secs_f64();
        if std::env::var_os("RSQ_VERBOSE").is_some() {
            eprintln!("[engine] compiled {name} in {dt:.2}s");
        }
        Ok(exe)
    }

    /// Execute a module with literal inputs; returns the decomposed output
    /// tuple (modules are lowered with return_tuple=True).
    pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.exec_ref(name, &refs)
    }

    /// Borrowed-input variant of [`Engine::exec`]: avoids the deep C-side
    /// `Literal::clone` per argument that the owned API forces on callers
    /// reusing inputs across calls (the pipeline's layer params and hidden
    /// states). ~1.5-2x end-to-end quantization speedup — DESIGN.md §7.
    pub fn exec_ref(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.module(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "module {name}: got {} inputs, manifest expects {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (lit, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            if dims != ispec.shape {
                bail!("module {name} input {i}: shape {dims:?}, expected {:?}", ispec.shape);
            }
        }
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe.execute::<&xla::Literal>(inputs)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            let e = stats.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        if outs.len() != spec.nout {
            bail!("module {name}: {} outputs, manifest expects {}", outs.len(), spec.nout);
        }
        Ok(outs)
    }

    /// Per-module cumulative (calls, total seconds), sorted by total time,
    /// aggregated across every thread that executed through this engine
    /// (the perf report; DESIGN.md §7).
    pub fn stats(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .stats
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &(n, s))| (k.clone(), n, s))
            .collect();
        v.sort_by(|a, b| b.2.total_cmp(&a.2));
        v
    }

    /// Print [`Engine::stats`] as the human-readable perf table.
    pub fn print_stats(&self) {
        println!("--- engine module stats (by total time) ---");
        for (name, n, s) in self.stats() {
            println!("{name:<24} calls={n:<6} total={s:>8.3}s mean={:>8.4}s", s / n as f64);
        }
    }
}
