//! The PJRT execution engine: compile-on-first-use cache over the AOT
//! artifact set of one model config.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Every module is compiled at most once per
//! process; executions validate input arity/shape against the manifest
//! before hitting PJRT so shape bugs fail with a readable error.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative (calls, seconds) per module — feeds the perf report
    stats: RefCell<HashMap<String, (u64, f64)>>,
}

impl Engine {
    /// Load the artifact set for `config` (e.g. "tiny") from
    /// `artifacts/<config>/`, honoring RSQ_ARTIFACTS.
    pub fn load(config: &str) -> Result<Engine> {
        let dir = crate::artifacts_dir(config);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    pub fn config(&self) -> &crate::model::ModelConfig {
        &self.manifest.config
    }

    /// Compile (or fetch cached) one module.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.module(name)?;
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile module {name}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        let dt = t0.elapsed().as_secs_f64();
        if std::env::var_os("RSQ_VERBOSE").is_some() {
            eprintln!("[engine] compiled {name} in {dt:.2}s");
        }
        Ok(exe)
    }

    /// Execute a module with literal inputs; returns the decomposed output
    /// tuple (modules are lowered with return_tuple=True).
    pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.exec_ref(name, &refs)
    }

    /// Borrowed-input variant of [`Engine::exec`]: avoids the deep C-side
    /// `Literal::clone` per argument that the owned API forces on callers
    /// reusing inputs across calls (the pipeline's layer params and hidden
    /// states). ~1.5-2x end-to-end quantization speedup — EXPERIMENTS §Perf.
    pub fn exec_ref(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.module(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "module {name}: got {} inputs, manifest expects {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (i, (lit, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            if dims != ispec.shape {
                bail!("module {name} input {i}: shape {dims:?}, expected {:?}", ispec.shape);
            }
        }
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let result = exe.execute::<&xla::Literal>(inputs)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.decompose_tuple()?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.borrow_mut();
            let e = stats.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        if outs.len() != spec.nout {
            bail!("module {name}: {} outputs, manifest expects {}", outs.len(), spec.nout);
        }
        Ok(outs)
    }

    /// Per-module cumulative call counts/time (perf report; EXPERIMENTS §Perf).
    pub fn stats(&self) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64, f64)> = self
            .stats
            .borrow()
            .iter()
            .map(|(k, &(n, s))| (k.clone(), n, s))
            .collect();
        v.sort_by(|a, b| b.2.total_cmp(&a.2));
        v
    }

    pub fn print_stats(&self) {
        println!("--- engine module stats (by total time) ---");
        for (name, n, s) in self.stats() {
            println!("{name:<24} calls={n:<6} total={s:>8.3}s mean={:>8.4}s", s / n as f64);
        }
    }
}
