//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute many.
//!
//! This is the only boundary between the rust coordinator and the XLA
//! compute stack. Python is never involved: `make artifacts` has already
//! lowered every module; here we parse the manifest, compile each module on
//! the PJRT CPU client (cached), and expose typed execute helpers.
//!
//! Threading (DESIGN.md §5): [`Engine`] is `Sync` — its compile cache and
//! stats are mutex-guarded and the PJRT C API is thread-safe — so the
//! quantization scheduler's worker threads (`util::Pool`) all execute
//! through one shared engine. Literals that workers must *share* (hidden
//! states, layer params) travel as [`SharedLiteral`]; literals a worker
//! creates and consumes itself stay plain `xla::Literal`.

pub mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{Manifest, ModuleSpec};

use crate::tensor::Tensor;
use anyhow::Result;

/// An [`xla::Literal`] wrapped for sharing across the scheduler's worker
/// threads.
///
/// The `xla` crate declares no `Send`/`Sync` on `Literal`, but a literal is
/// an owned, immutable host buffer: nothing in this crate mutates one after
/// construction, and PJRT only *reads* argument literals during execute.
/// This wrapper scopes that assertion to the places that actually share
/// literals, instead of blanket-unsafe-impl'ing the foreign type.
pub struct SharedLiteral(xla::Literal);

// SAFETY: see the type-level comment — the wrapped literal is treated as
// immutable for the wrapper's whole lifetime, and the underlying buffer is
// a plain host allocation with no thread affinity.
unsafe impl Send for SharedLiteral {}
unsafe impl Sync for SharedLiteral {}

impl SharedLiteral {
    /// Borrow the underlying literal for an engine call.
    pub fn get(&self) -> &xla::Literal {
        &self.0
    }

    /// Unwrap back into the owned literal.
    pub fn into_inner(self) -> xla::Literal {
        self.0
    }
}

impl From<xla::Literal> for SharedLiteral {
    fn from(lit: xla::Literal) -> Self {
        SharedLiteral(lit)
    }
}

impl std::ops::Deref for SharedLiteral {
    type Target = xla::Literal;
    fn deref(&self) -> &xla::Literal {
        &self.0
    }
}

/// f32 tensor -> XLA literal with the same shape.
pub fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

/// f32 tensor -> literal already wrapped for cross-thread sharing.
pub fn shared_literal(t: &Tensor) -> Result<SharedLiteral> {
    Ok(tensor_literal(t)?.into())
}

/// i32 token matrix [rows, cols] -> XLA literal.
pub fn tokens_literal(tokens: &[Vec<i32>], cols: usize) -> Result<xla::Literal> {
    let mut flat = Vec::with_capacity(tokens.len() * cols);
    for row in tokens {
        assert_eq!(row.len(), cols, "ragged token batch");
        flat.extend_from_slice(row);
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[tokens.len() as i64, cols as i64])?)
}

/// f32 scalar literal.
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// XLA literal -> f32 tensor (shape recovered from the literal).
pub fn literal_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// XLA literal -> flat f32 vec.
pub fn literal_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// XLA literal -> f32 scalar.
pub fn literal_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
