//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute many.
//!
//! This is the only boundary between the rust coordinator and the XLA
//! compute stack. Python is never involved: `make artifacts` has already
//! lowered every module; here we parse the manifest, compile each module on
//! the PJRT CPU client (cached), and expose typed execute helpers.
//!
//! Threading note: the `xla` crate's `PjRtClient` is `Rc`-based (not Send),
//! so all PJRT calls happen on the coordinator thread; pipeline worker
//! threads (quant::pipeline) handle host-side stages only. On this 1-core
//! box that costs nothing; DESIGN.md §Substitutions records it.

pub mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{Manifest, ModuleSpec};

use crate::tensor::Tensor;
use anyhow::Result;

/// f32 tensor -> XLA literal with the same shape.
pub fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

/// i32 token matrix [rows, cols] -> XLA literal.
pub fn tokens_literal(tokens: &[Vec<i32>], cols: usize) -> Result<xla::Literal> {
    let mut flat = Vec::with_capacity(tokens.len() * cols);
    for row in tokens {
        assert_eq!(row.len(), cols, "ragged token batch");
        flat.extend_from_slice(row);
    }
    Ok(xla::Literal::vec1(&flat).reshape(&[tokens.len() as i64, cols as i64])?)
}

/// f32 scalar literal.
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// XLA literal -> f32 tensor (shape recovered from the literal).
pub fn literal_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// XLA literal -> flat f32 vec.
pub fn literal_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// XLA literal -> f32 scalar.
pub fn literal_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
