//! Parser for the AOT manifest (written by python/compile/aot.py).
//!
//! Line-oriented `key=value` format:
//!   config=tiny / d=64 / layers=2 / ... / seq_lens=32,64
//!   param=<name>|shape=<d0>x<d1>
//!   module=<name>|file=<f>|in=<dtype>:<shape>;...|nout=<n>|note=<text>

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;

/// Declared dtype + shape of one module input (execute-time validation).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InputSpec {
    /// "float32" | "int32"
    pub dtype: String,
    /// dims; empty = scalar
    pub shape: Vec<usize>,
}

/// One AOT-compiled module as recorded by python/compile/aot.py.
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    /// manifest key, e.g. `layer_fwd_t64`
    pub name: String,
    /// HLO text file, relative to the artifact directory
    pub file: String,
    /// input specs in call order
    pub inputs: Vec<InputSpec>,
    /// outputs in the module's return tuple
    pub nout: usize,
    /// free-form note from the lowering side (DESIGN.md §Hardware-Adaptation)
    pub note: String,
}

/// Parsed `manifest.txt`: the contract between the L2 compiler and the
/// L3 coordinator for one model config.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// model hyper-parameters baked into the artifact set
    pub config: ModelConfig,
    /// (name, shape) of every parameter, in python's flat order
    pub params: Vec<(String, Vec<usize>)>,
    /// module name -> spec
    pub modules: BTreeMap<String, ModuleSpec>,
}

/// Parse a `d0xd1x…` (or `scalar`) shape spec. Shared with the
/// quantized-artifact manifest (`quant::artifact::format`), and fallible:
/// a malformed dim is a parse error, never a panic.
pub fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse().with_context(|| format!("bad shape dim {d:?} in {s:?}")))
        .collect()
}

/// Build a [`ModelConfig`] from `key=value` pairs — the config block shared
/// by the AOT manifest and the quantized-artifact manifest (DESIGN.md §9).
pub fn config_from_kv(kv: &BTreeMap<String, String>) -> Result<ModelConfig> {
    let get = |k: &str| -> Result<String> {
        kv.get(k).cloned().with_context(|| format!("manifest missing key {k}"))
    };
    let geti = |k: &str| -> Result<usize> {
        get(k)?.parse().with_context(|| format!("manifest key {k} not an int"))
    };
    Ok(ModelConfig {
        name: get("config")?,
        d: geti("d")?,
        layers: geti("layers")?,
        heads: geti("heads")?,
        ff: geti("ff")?,
        vocab: geti("vocab")?,
        max_seq: geti("max_seq")?,
        batch: geti("batch")?,
        seq_lens: get("seq_lens")?
            .split(',')
            .map(|t| t.parse().context("bad seq_len"))
            .collect::<Result<_>>()?,
        ldlq_k: geti("ldlq_k")?,
        ldlq_g: geti("ldlq_g")?,
    })
}

/// Render a [`ModelConfig`] back to the `key=value` block `config_from_kv`
/// parses (the artifact writer uses this; round-trip tested below).
pub fn config_to_kv(cfg: &ModelConfig) -> String {
    let seq: Vec<String> = cfg.seq_lens.iter().map(|t| t.to_string()).collect();
    format!(
        "config={}\nd={}\nlayers={}\nheads={}\nff={}\nvocab={}\nmax_seq={}\nbatch={}\nseq_lens={}\nldlq_k={}\nldlq_g={}\n",
        cfg.name, cfg.d, cfg.layers, cfg.heads, cfg.ff, cfg.vocab,
        cfg.max_seq, cfg.batch, seq.join(","), cfg.ldlq_k, cfg.ldlq_g,
    )
}

impl Manifest {
    /// Parse manifest text (see the module docs for the line format) and
    /// cross-validate the parameter contract.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut kv = BTreeMap::new();
        let mut params = Vec::new();
        let mut modules = BTreeMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("param=") {
                let mut name = String::new();
                let mut shape = Vec::new();
                for part in rest.split('|') {
                    if let Some(v) = part.strip_prefix("shape=") {
                        shape = parse_shape(v)?;
                    } else {
                        name = part.to_string();
                    }
                }
                params.push((name, shape));
            } else if let Some(rest) = line.strip_prefix("module=") {
                let mut parts = rest.split('|');
                let name = parts.next().unwrap_or_default().to_string();
                let mut spec = ModuleSpec {
                    name: name.clone(),
                    file: String::new(),
                    inputs: Vec::new(),
                    nout: 0,
                    note: String::new(),
                };
                for part in parts {
                    if let Some(v) = part.strip_prefix("file=") {
                        spec.file = v.to_string();
                    } else if let Some(v) = part.strip_prefix("in=") {
                        spec.inputs = v
                            .split(';')
                            .map(|one| {
                                let (dt, sh) = one.split_once(':').unwrap_or(("float32", one));
                                Ok(InputSpec { dtype: dt.to_string(), shape: parse_shape(sh)? })
                            })
                            .collect::<Result<_>>()?;
                    } else if let Some(v) = part.strip_prefix("nout=") {
                        spec.nout = v.parse().context("bad nout")?;
                    } else if let Some(v) = part.strip_prefix("note=") {
                        spec.note = v.to_string();
                    }
                }
                modules.insert(name, spec);
            } else if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.to_string(), v.to_string());
            }
        }

        let config = config_from_kv(&kv)?;
        let m = Manifest { config, params, modules };
        m.check_params()?;
        Ok(m)
    }

    /// Read + parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    /// Cross-validate the python-side parameter list against the rust
    /// ModelConfig contract — a drift here corrupts every execution.
    pub fn check_params(&self) -> Result<()> {
        let names = self.config.param_names();
        if names.len() != self.params.len() {
            bail!(
                "param count mismatch: manifest {} vs config {}",
                self.params.len(),
                names.len()
            );
        }
        for (want, (got, shape)) in names.iter().zip(&self.params) {
            if want != got {
                bail!("param order mismatch: expected {want}, manifest has {got}");
            }
            let want_shape = self.config.param_shape(want);
            if &want_shape != shape {
                bail!("param {want}: shape {shape:?} vs config {want_shape:?}");
            }
        }
        Ok(())
    }

    /// Spec for one module, with a listing of known names on miss.
    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules.get(name).with_context(|| {
            format!(
                "module {name:?} not in manifest for config {} (have: {:?})",
                self.config.name,
                self.modules.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
config=tiny
d=64
layers=1
heads=2
ff=128
vocab=256
max_seq=64
batch=4
seq_lens=32,64
ldlq_k=1024
ldlq_g=8
param=emb|shape=256x64
param=pos|shape=64x64
param=l0.g1|shape=64
param=l0.wq|shape=64x64
param=l0.wk|shape=64x64
param=l0.wv|shape=64x64
param=l0.wo|shape=64x64
param=l0.g2|shape=64
param=l0.wup|shape=128x64
param=l0.wgate|shape=128x64
param=l0.wdown|shape=64x128
param=gf|shape=64
param=head|shape=256x64
module=embed_t32|file=embed_t32.hlo.txt|in=int32:4x32;float32:256x64;float32:64x64|nout=1|note=tokens->Z0
module=gptq_64x64|file=gptq_64x64.hlo.txt|in=float32:64x64;float32:64x64;float32:scalar;float32:scalar|nout=2|note=
";

    #[test]
    fn parses_config_and_params() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.d, 64);
        assert_eq!(m.config.seq_lens, vec![32, 64]);
        assert_eq!(m.params.len(), 13);
        assert_eq!(m.params[0].0, "emb");
    }

    #[test]
    fn parses_modules() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = m.module("embed_t32").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].dtype, "int32");
        assert_eq!(e.inputs[0].shape, vec![4, 32]);
        let g = m.module("gptq_64x64").unwrap();
        assert_eq!(g.nout, 2);
        assert_eq!(g.inputs[2].shape, Vec::<usize>::new());
        assert!(m.module("nope").is_err());
    }

    #[test]
    fn config_kv_round_trip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let rendered = config_to_kv(&m.config);
        let mut kv = BTreeMap::new();
        for line in rendered.lines() {
            let (k, v) = line.split_once('=').unwrap();
            kv.insert(k.to_string(), v.to_string());
        }
        assert_eq!(config_from_kv(&kv).unwrap(), m.config);
    }

    #[test]
    fn malformed_shape_is_an_error_not_a_panic() {
        let broken = SAMPLE.replace("shape=256x64", "shape=256xcat");
        assert!(Manifest::parse(&broken).is_err());
        assert!(parse_shape("4xx8").is_err());
        assert_eq!(parse_shape("scalar").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_shape("3x5").unwrap(), vec![3, 5]);
    }

    #[test]
    fn rejects_param_drift() {
        let broken = SAMPLE.replace("param=l0.wq", "param=l0.xx");
        assert!(Manifest::parse(&broken).is_err());
        let broken2 = SAMPLE.replace("param=gf|shape=64\n", "");
        assert!(Manifest::parse(&broken2).is_err());
    }

    #[test]
    fn real_artifacts_parse_if_present() {
        let dir = crate::artifacts_dir("tiny");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.config.name, "tiny");
            assert!(m.modules.contains_key("train_step"));
        }
    }
}
