//! The training driver: stream corpus batches through `train_step`.

use anyhow::Result;

use crate::corpus::{CorpusKind, Generator};
use crate::model::ParamSet;
use crate::runtime::{self, Engine};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub steps: usize,
    pub corpus: CorpusKind,
    pub seed: u64,
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 200,
            corpus: CorpusKind::Wiki,
            seed: 7,
            log_every: 20,
            verbose: false,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    /// (step, loss) samples at `log_every` cadence plus the final step
    pub loss_curve: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub wall_seconds: f64,
}

/// Train `params` in place for `opts.steps` Adam steps on fresh corpus
/// batches (train stream). The train_step artifact bakes lr/betas (L2 side).
pub fn train(
    engine: &Engine,
    params: &mut ParamSet,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    let t0 = std::time::Instant::now();
    let cfg = engine.config().clone();
    let t = *cfg.seq_lens.iter().max().unwrap();
    let n = params.tensors.len();
    let mut gen = Generator::new(cfg.vocab, opts.corpus, opts.seed, 1);

    // device-side state: params + adam moments as literals
    let mut state: Vec<xla::Literal> = Vec::with_capacity(3 * n);
    for tns in &params.tensors {
        state.push(runtime::tensor_literal(tns)?);
    }
    for tns in &params.tensors {
        state.push(runtime::tensor_literal(&Tensor::zeros(&tns.shape))?);
    }
    for tns in &params.tensors {
        state.push(runtime::tensor_literal(&Tensor::zeros(&tns.shape))?);
    }

    let mut report = TrainReport::default();
    for step in 0..opts.steps {
        let batch: Vec<Vec<i32>> = (0..cfg.batch).map(|_| gen.sample(t)).collect();
        let tok_lit = runtime::tokens_literal(&batch, t)?;
        let step_lit = runtime::scalar_literal(step as f32);
        // borrowed inputs: no deep Literal clones of the full 3n state/step
        let mut ins: Vec<&xla::Literal> = state.iter().collect();
        ins.push(&tok_lit);
        ins.push(&step_lit);
        let outs = engine.exec_ref("train_step", &ins)?;
        let loss = runtime::literal_scalar(&outs[3 * n])?;
        state = outs.into_iter().take(3 * n).collect();
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            report.loss_curve.push((step, loss));
            if opts.verbose {
                eprintln!("[train] step {step:>5}  loss {loss:.4}");
            }
        }
        report.final_loss = loss;
    }

    // materialize trained params back into the ParamSet
    for (i, tns) in params.tensors.iter_mut().enumerate() {
        *tns = runtime::literal_tensor(&state[i])?;
    }
    report.wall_seconds = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Train-or-load helper: checkpoints trained weights under artifacts/ so
/// repeated drivers skip retraining (delete the file to force a retrain).
pub fn train_or_load(
    engine: &Engine,
    seed: u64,
    steps: usize,
    verbose: bool,
) -> Result<(ParamSet, Option<TrainReport>)> {
    let cfg = engine.config().clone();
    let path = crate::artifacts_dir(&cfg.name).join(format!("trained_s{seed}_n{steps}.bin"));
    if path.exists() {
        if let Ok(p) = ParamSet::load(&cfg, &path) {
            return Ok((p, None));
        }
    }
    let mut p = ParamSet::init(&cfg, seed);
    let report = train(
        engine,
        &mut p,
        &TrainOptions { steps, seed, verbose, ..Default::default() },
    )?;
    let _ = p.save(&path);
    Ok((p, Some(report)))
}
