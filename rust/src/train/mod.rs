//! Adam training loop over the `train_step` artifact.
//!
//! Used by the end-to-end example (train a small LM on SynthWiki, then
//! quantize it) and by the table drivers to produce *trained* checkpoints —
//! a randomly-initialized model has no attention structure for AttnCon to
//! exploit, so all quantization experiments run on trained weights.
//!
//! Parameters, Adam moments and outputs stay as XLA literals between steps;
//! tensors only materialize host-side at the end (or for checkpoints).

pub mod trainer;

pub use trainer::{train, train_or_load, TrainOptions, TrainReport};
