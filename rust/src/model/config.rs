//! Model hyper-parameters + the canonical parameter-ordering contract.
//!
//! Mirrors python/compile/configs.py: the flat parameter list is
//!   [emb, pos] + [g1, wq, wk, wv, wo, g2, wup, wgate, wdown] * layers
//!             + [gf, head]
//! and any change must be made on both sides (the AOT manifest records the
//! python view; `runtime::Manifest::check_params` cross-validates).

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub seq_lens: Vec<usize>,
    pub ldlq_k: usize,
    pub ldlq_g: usize,
}

/// Identifier of one transformer weight inside a layer (paper Fig. 7
/// ablates RSQ per-module over exactly these seven).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Module {
    Wq,
    Wk,
    Wv,
    Wo,
    Wup,
    Wgate,
    Wdown,
}

impl Module {
    pub const ALL: [Module; 7] = [
        Module::Wq, Module::Wk, Module::Wv, Module::Wo,
        Module::Wup, Module::Wgate, Module::Wdown,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Module::Wq => "wq",
            Module::Wk => "wk",
            Module::Wv => "wv",
            Module::Wo => "wo",
            Module::Wup => "wup",
            Module::Wgate => "wgate",
            Module::Wdown => "wdown",
        }
    }

    pub fn parse(s: &str) -> Option<Module> {
        Module::ALL.into_iter().find(|m| m.name() == s)
    }

    /// Offset of this weight inside a layer's 9-tensor block.
    pub fn layer_offset(&self) -> usize {
        match self {
            Module::Wq => 1,
            Module::Wk => 2,
            Module::Wv => 3,
            Module::Wo => 4,
            Module::Wup => 6,
            Module::Wgate => 7,
            Module::Wdown => 8,
        }
    }

    /// Which captured input stream feeds this weight
    /// (layer_fwd outputs: Xa -> q/k/v, Xo -> o, Xf -> up/gate, Xd -> down).
    pub fn input_stream(&self) -> InputStream {
        match self {
            Module::Wq | Module::Wk | Module::Wv => InputStream::Xa,
            Module::Wo => InputStream::Xo,
            Module::Wup | Module::Wgate => InputStream::Xf,
            Module::Wdown => InputStream::Xd,
        }
    }
}

/// The four capture streams a layer forward emits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum InputStream {
    Xa,
    Xo,
    Xf,
    Xd,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }

    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["emb".to_string(), "pos".to_string()];
        for l in 0..self.layers {
            for w in ["g1", "wq", "wk", "wv", "wo", "g2", "wup", "wgate", "wdown"] {
                names.push(format!("l{l}.{w}"));
            }
        }
        names.push("gf".to_string());
        names.push("head".to_string());
        names
    }

    pub fn param_shape(&self, name: &str) -> Vec<usize> {
        let (d, ff, v) = (self.d, self.ff, self.vocab);
        match name {
            "emb" | "head" => vec![v, d],
            "pos" => vec![self.max_seq, d],
            "gf" => vec![d],
            _ => {
                let key = name.split('.').nth(1).unwrap_or(name);
                match key {
                    "g1" | "g2" => vec![d],
                    "wq" | "wk" | "wv" | "wo" => vec![d, d],
                    "wup" | "wgate" => vec![ff, d],
                    "wdown" => vec![d, ff],
                    other => panic!("unknown param {other:?}"),
                }
            }
        }
    }

    pub fn num_params(&self) -> usize {
        self.param_names()
            .iter()
            .map(|n| self.param_shape(n).iter().product::<usize>())
            .sum()
    }

    /// Flat index of a layer weight in the parameter list.
    pub fn param_index(&self, layer: usize, module: Module) -> usize {
        assert!(layer < self.layers);
        2 + layer * 9 + module.layer_offset()
    }

    /// (out, in) shape of a layer weight.
    pub fn weight_shape(&self, module: Module) -> (usize, usize) {
        match module {
            Module::Wq | Module::Wk | Module::Wv | Module::Wo => (self.d, self.d),
            Module::Wup | Module::Wgate => (self.ff, self.d),
            Module::Wdown => (self.d, self.ff),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d: 64,
            layers: 2,
            heads: 2,
            ff: 128,
            vocab: 256,
            max_seq: 64,
            batch: 4,
            seq_lens: vec![32, 64],
            ldlq_k: 1024,
            ldlq_g: 8,
        }
    }

    #[test]
    fn param_ordering_matches_python() {
        let c = cfg();
        let names = c.param_names();
        assert_eq!(names.len(), 2 + 9 * 2 + 2);
        assert_eq!(names[0], "emb");
        assert_eq!(names[2], "l0.g1");
        assert_eq!(names[10], "l0.wdown");
        assert_eq!(names[names.len() - 1], "head");
    }

    #[test]
    fn shapes() {
        let c = cfg();
        assert_eq!(c.param_shape("emb"), vec![256, 64]);
        assert_eq!(c.param_shape("l1.wup"), vec![128, 64]);
        assert_eq!(c.param_shape("l1.wdown"), vec![64, 128]);
        assert_eq!(c.param_shape("gf"), vec![64]);
    }

    #[test]
    fn param_index_contract() {
        let c = cfg();
        let names = c.param_names();
        assert_eq!(names[c.param_index(0, Module::Wq)], "l0.wq");
        assert_eq!(names[c.param_index(1, Module::Wdown)], "l1.wdown");
    }

    #[test]
    fn module_round_trip() {
        for m in Module::ALL {
            assert_eq!(Module::parse(m.name()), Some(m));
        }
        assert_eq!(Module::parse("nope"), None);
    }

    #[test]
    fn num_params_counts() {
        let c = cfg();
        // emb+head: 2*256*64, pos: 64*64, per layer: 2*64 + 4*64*64 + 2*128*64 + 64*128, gf: 64
        let per_layer = 2 * 64 + 4 * 64 * 64 + 2 * 128 * 64 + 64 * 128;
        let want = 2 * 256 * 64 + 64 * 64 + 2 * per_layer + 64;
        assert_eq!(c.num_params(), want);
    }
}
