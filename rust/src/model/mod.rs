//! Model substrate: configs, the parameter store, RMSNorm-gain fusion, the
//! Rotate step (paper Sec. 3.2 / 4.2), and outlier injection.

pub mod config;
pub mod fuse;
pub mod outliers;
pub mod params;
pub mod rotate;

pub use config::ModelConfig;
pub use params::ParamSet;
