//! The parameter store: the flat, ordered tensor list shared with every
//! AOT module, plus init and a simple binary save/load format so trained
//! checkpoints (examples/e2e_train_quantize.rs) can be reused by drivers.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::config::{ModelConfig, Module};
use crate::tensor::Tensor;
use crate::util::Pcg;

#[derive(Clone, Debug)]
pub struct ParamSet {
    pub cfg: ModelConfig,
    pub tensors: Vec<Tensor>,
}

const MAGIC: &[u8; 8] = b"RSQPRMS1";

impl ParamSet {
    /// Gaussian init matching the L2 reference initializer: gains = 1,
    /// weights ~ N(0, (0.4/sqrt(fan_in))^2).
    pub fn init(cfg: &ModelConfig, seed: u64) -> Self {
        let mut rng = Pcg::with_stream(seed, 0x1217);
        let tensors = cfg
            .param_names()
            .iter()
            .map(|name| {
                let shape = cfg.param_shape(name);
                if shape.len() == 1 {
                    Tensor::ones(&shape)
                } else {
                    let scale = 0.4 / (shape[1] as f32).sqrt();
                    Tensor::randn(&shape, scale, &mut rng)
                }
            })
            .collect();
        ParamSet { cfg: cfg.clone(), tensors }
    }

    pub fn weight(&self, layer: usize, module: Module) -> &Tensor {
        &self.tensors[self.cfg.param_index(layer, module)]
    }

    pub fn weight_mut(&mut self, layer: usize, module: Module) -> &mut Tensor {
        let idx = self.cfg.param_index(layer, module);
        &mut self.tensors[idx]
    }

    pub fn set_weight(&mut self, layer: usize, module: Module, t: Tensor) {
        let idx = self.cfg.param_index(layer, module);
        assert_eq!(self.tensors[idx].shape, t.shape, "weight shape mismatch");
        self.tensors[idx] = t;
    }

    /// Save as a small binary: magic, count, then per tensor
    /// (ndim, dims..., f32 data), all little-endian.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {path:?}"))?;
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(cfg: &ModelConfig, path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not an RSQ parameter file");
        }
        let mut u32buf = [0u8; 4];
        f.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf) as usize;
        let names = cfg.param_names();
        if count != names.len() {
            bail!("{path:?} has {count} tensors, config {} expects {}", cfg.name, names.len());
        }
        let mut tensors = Vec::with_capacity(count);
        for name in &names {
            f.read_exact(&mut u32buf)?;
            let ndim = u32::from_le_bytes(u32buf) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                f.read_exact(&mut u32buf)?;
                shape.push(u32::from_le_bytes(u32buf) as usize);
            }
            if shape != cfg.param_shape(name) {
                bail!("tensor {name}: shape {shape:?} != config {:?}", cfg.param_shape(name));
            }
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(Tensor::from_vec(&shape, data));
        }
        Ok(ParamSet { cfg: cfg.clone(), tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d: 64, layers: 2, heads: 2, ff: 128, vocab: 256,
            max_seq: 64, batch: 4, seq_lens: vec![32, 64],
            ldlq_k: 1024, ldlq_g: 8,
        }
    }

    #[test]
    fn init_shapes_and_gains() {
        let p = ParamSet::init(&cfg(), 0);
        assert_eq!(p.tensors.len(), 22);
        // gains are all ones
        assert!(p.tensors[2].data.iter().all(|&v| v == 1.0));
        // weights have roughly the right scale
        let w = p.weight(0, Module::Wq);
        let rms = (w.data.iter().map(|v| v * v).sum::<f32>() / w.numel() as f32).sqrt();
        assert!((rms - 0.05).abs() < 0.01, "{rms}");
    }

    #[test]
    fn init_deterministic() {
        let a = ParamSet::init(&cfg(), 3);
        let b = ParamSet::init(&cfg(), 3);
        assert_eq!(a.tensors[3].data, b.tensors[3].data);
        let c = ParamSet::init(&cfg(), 4);
        assert_ne!(a.tensors[3].data, c.tensors[3].data);
    }

    #[test]
    fn save_load_round_trip() {
        let p = ParamSet::init(&cfg(), 7);
        let dir = std::env::temp_dir().join("rsq_param_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.bin");
        p.save(&path).unwrap();
        let q = ParamSet::load(&cfg(), &path).unwrap();
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("rsq_param_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"not a param file").unwrap();
        assert!(ParamSet::load(&cfg(), &path).is_err());
    }

    #[test]
    fn weight_accessors() {
        let mut p = ParamSet::init(&cfg(), 1);
        let w = p.weight(1, Module::Wdown).clone();
        assert_eq!(w.shape, vec![64, 128]);
        let mut w2 = w.clone();
        w2.scale_in_place(2.0);
        p.set_weight(1, Module::Wdown, w2);
        assert!((p.weight(1, Module::Wdown).data[0] - 2.0 * w.data[0]).abs() < 1e-6);
    }
}
