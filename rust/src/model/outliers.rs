//! Weight-outlier injection (DESIGN.md §Substitutions).
//!
//! Pre-trained LLMs carry sparse, large-magnitude weights (SpQR, SqueezeLLM,
//! "super weights"); a briefly-trained toy model does not develop them. To
//! give the Rotate step the phenomenon it exists to fix, we inject sparse
//! high-kurtosis perturbations into the transformer weights after training:
//! a small fraction of entries per weight gets `magnitude × row_rms` added
//! with random sign. The injected model *is* the model under study — all
//! quantization methods see the same weights and the "Full Model" rows in
//! every table are evaluated post-injection.

use super::config::Module;
use super::params::ParamSet;
use crate::util::Pcg;

#[derive(Clone, Copy, Debug)]
pub struct OutlierSpec {
    /// fraction of entries perturbed per weight matrix (e.g. 0.003)
    pub fraction: f32,
    /// perturbation magnitude in units of the row RMS (e.g. 6.0)
    pub magnitude: f32,
}

impl Default for OutlierSpec {
    fn default() -> Self {
        OutlierSpec { fraction: 0.003, magnitude: 6.0 }
    }
}

/// Inject outliers into all seven transformer weights of every layer.
pub fn inject_outliers(p: &mut ParamSet, spec: OutlierSpec, seed: u64) {
    let mut rng = Pcg::with_stream(seed, 0x0071);
    for l in 0..p.cfg.layers {
        for m in Module::ALL {
            let w = p.weight_mut(l, m);
            let (rows, cols) = (w.rows(), w.cols());
            let n_hits = ((rows * cols) as f32 * spec.fraction).ceil() as usize;
            for _ in 0..n_hits {
                let i = rng.below(rows);
                let j = rng.below(cols);
                let row = &w.data[i * cols..(i + 1) * cols];
                let rms = (row.iter().map(|v| v * v).sum::<f32>() / cols as f32)
                    .sqrt()
                    .max(1e-6);
                w.data[i * cols + j] += spec.magnitude * rms * rng.sign();
            }
        }
    }
}

/// Mean per-row max/rms ratio over the layer weights — the "outlier-ness"
/// metric that rotation is supposed to shrink (reported by `rsq scores`).
pub fn kurtosis_ratio(p: &ParamSet) -> f32 {
    let mut total = 0.0f32;
    let mut count = 0usize;
    for l in 0..p.cfg.layers {
        for m in Module::ALL {
            let w = p.weight(l, m);
            let cols = w.cols();
            for i in 0..w.rows() {
                let row = w.row(i);
                let mx = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                let rms = (row.iter().map(|v| v * v).sum::<f32>() / cols as f32)
                    .sqrt()
                    .max(1e-9);
                total += mx / rms;
                count += 1;
            }
        }
    }
    total / count as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::fuse::fuse_gains;
    use crate::model::rotate::{rotate_params, rotation_matrix};

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d: 64, layers: 2, heads: 2, ff: 128, vocab: 256,
            max_seq: 64, batch: 4, seq_lens: vec![32, 64],
            ldlq_k: 1024, ldlq_g: 8,
        }
    }

    #[test]
    fn injection_raises_kurtosis() {
        let mut p = ParamSet::init(&cfg(), 0);
        let before = kurtosis_ratio(&p);
        inject_outliers(&mut p, OutlierSpec::default(), 1);
        let after = kurtosis_ratio(&p);
        assert!(after > before * 1.1, "{before} -> {after}");
    }

    #[test]
    fn rotation_shrinks_injected_kurtosis() {
        // the end-to-end mechanism the paper's Rotate step relies on
        let mut p = ParamSet::init(&cfg(), 0);
        inject_outliers(&mut p, OutlierSpec::default(), 1);
        fuse_gains(&mut p);
        let before = kurtosis_ratio(&p);
        let q = rotation_matrix(64, 2);
        rotate_params(&mut p, &q, &crate::util::Pool::new(1));
        let after = kurtosis_ratio(&p);
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn injection_deterministic() {
        let mut a = ParamSet::init(&cfg(), 0);
        let mut b = ParamSet::init(&cfg(), 0);
        inject_outliers(&mut a, OutlierSpec::default(), 9);
        inject_outliers(&mut b, OutlierSpec::default(), 9);
        assert_eq!(a.tensors[3].data, b.tensors[3].data);
    }

    #[test]
    fn injection_is_sparse() {
        let mut p = ParamSet::init(&cfg(), 0);
        let orig = p.weight(0, Module::Wq).clone();
        inject_outliers(&mut p, OutlierSpec { fraction: 0.001, magnitude: 6.0 }, 3);
        let w = p.weight(0, Module::Wq);
        let changed = w.data.iter().zip(&orig.data).filter(|(a, b)| a != b).count();
        assert!(changed <= 16, "{changed} entries changed");
        assert!(changed >= 1);
    }
}
