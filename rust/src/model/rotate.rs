//! The Rotate step (paper Sec. 3.2 / 4.2): apply a randomized-Hadamard
//! orthogonal transform Q to the residual stream.
//!
//! Conventions (mirroring python/compile/model.py, where
//! tests/test_model.py::test_rotation_invariance proves function
//! preservation): with the residual stream mapped z -> zQ,
//!   in-dim  weights  W' = W·Q    (wq, wk, wv, wup, wgate, head)
//!   out-dim weights  W' = Qᵀ·W   (wo, wdown)
//!   tables           emb' = emb·Q, pos' = pos·Q
//! Gains must already be fused (`fuse::gains_fused`).
//!
//! Every product runs on the pool-parallel `tensor::kernels` layer: the
//! out-dim weights go through the fused-transpose `gemm_at`, so Qᵀ is
//! never materialized, and the scheduler's `--jobs` pool parallelizes the
//! per-weight GEMMs over row blocks without changing a bit of output
//! (DESIGN.md §10).

use crate::tensor::kernels::{self, Backend};
use crate::tensor::{randomized_hadamard, Tensor};
use crate::util::{Pcg, Pool};

use super::fuse::gains_fused;
use super::params::ParamSet;

/// Build the rotation matrix for a config (seeded -> reproducible runs).
pub fn rotation_matrix(d: usize, seed: u64) -> Tensor {
    let mut rng = Pcg::with_stream(seed, 0x40_7A7E);
    randomized_hadamard(d, &mut rng)
}

/// Rotate all parameters in place. Panics if gains are not fused.
/// Runs on the bit-exact `reference` backend; quantize-pipeline call
/// sites that honor `--backend` go through [`rotate_params_with`].
pub fn rotate_params(p: &mut ParamSet, q: &Tensor, pool: &Pool) {
    rotate_params_with(p, q, pool, Backend::Reference)
}

/// [`rotate_params`] on an explicit kernel backend (DESIGN.md §13).
/// `Backend::Reference` is bit-identical to the historical path at every
/// jobs count; `Backend::Simd` is tolerance-pinned against it.
pub fn rotate_params_with(p: &mut ParamSet, q: &Tensor, pool: &Pool, backend: Backend) {
    assert!(gains_fused(p), "fuse_gains must run before rotation");
    assert_eq!(q.rows(), p.cfg.d);
    let pool = Some(pool);
    let layers = p.cfg.layers;
    p.tensors[0] = backend.gemm(&p.tensors[0], q, pool); // emb
    p.tensors[1] = backend.gemm(&p.tensors[1], q, pool); // pos
    for l in 0..layers {
        let base = 2 + l * 9;
        for off in [1, 2, 3] {
            // wq wk wv: in-dim
            p.tensors[base + off] = backend.gemm(&p.tensors[base + off], q, pool);
        }
        p.tensors[base + 4] = backend.gemm_at(q, &p.tensors[base + 4], pool); // wo: out-dim
        for off in [6, 7] {
            // wup wgate: in-dim
            p.tensors[base + off] = backend.gemm(&p.tensors[base + off], q, pool);
        }
        p.tensors[base + 8] = backend.gemm_at(q, &p.tensors[base + 8], pool); // wdown: out-dim
    }
    let n = p.tensors.len();
    p.tensors[n - 1] = backend.gemm(&p.tensors[n - 1], q, pool); // head: in-dim
}

/// Apply the inverse rotation (Qᵀ for orthogonal Q) in place — the exact
/// mirror of [`rotate_params`], with the transposes fused into
/// `gemm_bt`/`gemm` so Qᵀ is never materialized either. (Calling
/// `rotate_params` with a materialized `q.transpose2()` would compute the
/// same bits; this exists so no call site builds that copy — the §10
/// no-materialized-transpose contract — and as the de-rotation entry
/// point for future artifact tooling.)
pub fn unrotate_params(p: &mut ParamSet, q: &Tensor, pool: &Pool) {
    assert!(gains_fused(p), "fuse_gains must run before rotation");
    assert_eq!(q.rows(), p.cfg.d);
    let pool = Some(pool);
    let layers = p.cfg.layers;
    p.tensors[0] = kernels::gemm_bt(&p.tensors[0], q, pool); // emb: W·Qᵀ
    p.tensors[1] = kernels::gemm_bt(&p.tensors[1], q, pool);
    for l in 0..layers {
        let base = 2 + l * 9;
        for off in [1, 2, 3] {
            p.tensors[base + off] = kernels::gemm_bt(&p.tensors[base + off], q, pool);
        }
        p.tensors[base + 4] = kernels::gemm(q, &p.tensors[base + 4], pool); // (Qᵀ)ᵀ·W = Q·W
        for off in [6, 7] {
            p.tensors[base + off] = kernels::gemm_bt(&p.tensors[base + off], q, pool);
        }
        p.tensors[base + 8] = kernels::gemm(q, &p.tensors[base + 8], pool);
    }
    let n = p.tensors.len();
    p.tensors[n - 1] = kernels::gemm_bt(&p.tensors[n - 1], q, pool);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{ModelConfig, Module};
    use crate::model::fuse::fuse_gains;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d: 64, layers: 2, heads: 2, ff: 128, vocab: 256,
            max_seq: 64, batch: 4, seq_lens: vec![32, 64],
            ldlq_k: 1024, ldlq_g: 8,
        }
    }

    #[test]
    fn rotation_matrix_orthogonal_and_seeded() {
        let q1 = rotation_matrix(64, 5);
        let q2 = rotation_matrix(64, 5);
        assert_eq!(q1.data, q2.data);
        let qtq = kernels::syrk_t(&q1, None);
        for i in 0..64 {
            assert!((qtq.at2(i, i) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn rotate_preserves_shapes() {
        let mut p = ParamSet::init(&cfg(), 0);
        fuse_gains(&mut p);
        let shapes: Vec<Vec<usize>> = p.tensors.iter().map(|t| t.shape.clone()).collect();
        rotate_params(&mut p, &rotation_matrix(64, 1), &Pool::new(1));
        for (t, s) in p.tensors.iter().zip(&shapes) {
            assert_eq!(&t.shape, s);
        }
    }

    #[test]
    fn rotate_then_unrotate_is_identity() {
        let mut p = ParamSet::init(&cfg(), 2);
        fuse_gains(&mut p);
        let orig = p.clone();
        let q = rotation_matrix(64, 3);
        let pool = Pool::new(1);
        rotate_params(&mut p, &q, &pool);
        // some weight actually changed
        assert!(!p.weight(0, Module::Wq).allclose(orig.weight(0, Module::Wq), 1e-4));
        unrotate_params(&mut p, &q, &pool);
        for (a, b) in p.tensors.iter().zip(&orig.tensors) {
            assert!(a.allclose(b, 1e-3), "round trip drifted");
        }
    }

    #[test]
    fn rotation_bit_identical_across_jobs() {
        // the §10 determinism contract on the rotate hot path itself:
        // a 4-worker pool rotation matches the serial one bit for bit
        let mut serial = ParamSet::init(&cfg(), 7);
        fuse_gains(&mut serial);
        let mut pooled = serial.clone();
        let q = rotation_matrix(64, 11);
        rotate_params(&mut serial, &q, &Pool::new(1));
        rotate_params(&mut pooled, &q, &Pool::new(4));
        for (a, b) in serial.tensors.iter().zip(&pooled.tensors) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn rotate_with_simd_backend_stays_close_to_reference() {
        // Backend::Simd resolves to the scalar reference path on hosts
        // without AVX2+FMA, so this holds everywhere; on AVX2 hosts it
        // pins the §13 tolerance contract on the rotate call sites.
        let mut reference = ParamSet::init(&cfg(), 21);
        fuse_gains(&mut reference);
        let mut simd = reference.clone();
        let q = rotation_matrix(64, 13);
        let pool = Pool::new(2);
        rotate_params(&mut reference, &q, &pool);
        rotate_params_with(&mut simd, &q, &pool, Backend::Simd);
        for (a, b) in reference.tensors.iter().zip(&simd.tensors) {
            assert!(a.allclose(b, 1e-3), "simd rotation drifted");
        }
    }

    #[test]
    #[should_panic(expected = "fuse_gains")]
    fn rotate_unfused_panics() {
        let mut p = ParamSet::init(&cfg(), 0);
        p.tensors[2].data[0] = 1.5; // perturb a gain
        let q = rotation_matrix(64, 1);
        rotate_params(&mut p, &q, &Pool::new(1));
    }

    #[test]
    fn rotate_preserves_qk_products() {
        // q·kᵀ per token is invariant: (x Q)(Wq Q)ᵀ(Wk Q)(x Q)ᵀ = x Wqᵀ Wk xᵀ
        let mut p = ParamSet::init(&cfg(), 4);
        fuse_gains(&mut p);
        let wq = p.weight(0, Module::Wq).clone();
        let wk = p.weight(0, Module::Wk).clone();
        let m_before = kernels::gemm_bt(&wq, &wk, None);
        let q = rotation_matrix(64, 9);
        rotate_params(&mut p, &q, &Pool::new(2));
        let wq2 = p.weight(0, Module::Wq);
        let wk2 = p.weight(0, Module::Wk);
        let m_after = kernels::gemm_bt(wq2, wk2, None);
        assert!(m_before.allclose(&m_after, 1e-4));
    }
}
