//! RMSNorm-gain fusion (paper Sec. 4.2 "Rotate", following SliceGPT).
//!
//! Rotation is only function-preserving for the gain-free RMSNorm, so the
//! per-channel gains are folded into the adjacent in-dim weights first:
//! g1 -> wq/wk/wv columns, g2 -> wup/wgate columns, gf -> head columns.
//! Mirrors python/compile/model.py::fuse_gains (pytest proves the python
//! version function-preserving; the rust unit test proves both agree).

use super::params::ParamSet;

/// Fold all norm gains into adjacent weights in place; gains become 1.
pub fn fuse_gains(p: &mut ParamSet) {
    let layers = p.cfg.layers;
    for l in 0..layers {
        let base = 2 + l * 9;
        // g1 -> wq, wk, wv (scale input columns)
        let g1 = p.tensors[base].data.clone();
        for off in 1..=3 {
            scale_columns(&mut p.tensors[base + off], &g1);
        }
        p.tensors[base].data.iter_mut().for_each(|v| *v = 1.0);
        // g2 -> wup, wgate
        let g2 = p.tensors[base + 5].data.clone();
        for off in 6..=7 {
            scale_columns(&mut p.tensors[base + off], &g2);
        }
        p.tensors[base + 5].data.iter_mut().for_each(|v| *v = 1.0);
    }
    // gf -> head
    let n = p.tensors.len();
    let gf = p.tensors[n - 2].data.clone();
    scale_columns(&mut p.tensors[n - 1], &gf);
    p.tensors[n - 2].data.iter_mut().for_each(|v| *v = 1.0);
}

/// Whether all gains are 1 (the precondition for `rotate::rotate_params`).
pub fn gains_fused(p: &ParamSet) -> bool {
    let mut idxs = vec![p.tensors.len() - 2];
    for l in 0..p.cfg.layers {
        idxs.push(2 + l * 9);
        idxs.push(2 + l * 9 + 5);
    }
    idxs.iter().all(|&i| p.tensors[i].data.iter().all(|&v| v == 1.0))
}

fn scale_columns(w: &mut crate::tensor::Tensor, g: &[f32]) {
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(cols, g.len(), "gain length mismatch");
    for i in 0..rows {
        let row = &mut w.data[i * cols..(i + 1) * cols];
        for (v, &s) in row.iter_mut().zip(g) {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::Pcg;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d: 64, layers: 2, heads: 2, ff: 128, vocab: 256,
            max_seq: 64, batch: 4, seq_lens: vec![32, 64],
            ldlq_k: 1024, ldlq_g: 8,
        }
    }

    #[test]
    fn fuse_sets_gains_to_one() {
        let mut p = ParamSet::init(&cfg(), 0);
        // perturb gains
        let mut rng = Pcg::new(1);
        for l in 0..2 {
            for idx in [2 + l * 9, 2 + l * 9 + 5] {
                for v in &mut p.tensors[idx].data {
                    *v = 1.0 + 0.1 * rng.normal();
                }
            }
        }
        assert!(!gains_fused(&p));
        fuse_gains(&mut p);
        assert!(gains_fused(&p));
    }

    #[test]
    fn fuse_scales_expected_columns() {
        let mut p = ParamSet::init(&cfg(), 2);
        let wq_before = p.tensors[3].clone();
        for (c, v) in p.tensors[2].data.iter_mut().enumerate() {
            *v = 1.0 + c as f32 * 0.01;
        }
        let g = p.tensors[2].data.clone();
        fuse_gains(&mut p);
        let wq_after = &p.tensors[3];
        for i in 0..64 {
            for j in 0..64 {
                let want = wq_before.at2(i, j) * g[j];
                assert!((wq_after.at2(i, j) - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn fuse_is_idempotent() {
        let mut p = ParamSet::init(&cfg(), 3);
        fuse_gains(&mut p);
        let snapshot: Vec<Vec<f32>> = p.tensors.iter().map(|t| t.data.clone()).collect();
        fuse_gains(&mut p);
        for (a, t) in snapshot.iter().zip(&p.tensors) {
            assert_eq!(a, &t.data);
        }
    }
}
