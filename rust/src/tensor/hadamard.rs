//! Randomized Hadamard orthogonal matrices (paper Sec. 3.2 / 4.2 "Rotate").
//!
//! Q = (1/√d) · H_d · diag(s), with H_d the Sylvester-construction Hadamard
//! matrix and s a random ±1 vector. QᵀQ = diag(s)·HᵀH·diag(s)/d = I because
//! HᵀH = d·I. Multiplying weights by Q "gaussianizes" rows (QuIP's
//! incoherence), which is what lets low-bit grids fit outlier-ridden
//! weights. `d` must be a power of two (all configs guarantee this).

use super::Tensor;
use crate::util::Pcg;

/// Plain (unnormalized) Sylvester Hadamard matrix of size d (power of 2).
pub fn sylvester(d: usize) -> Tensor {
    assert!(d.is_power_of_two(), "Hadamard size must be a power of two, got {d}");
    let mut h = Tensor::from_vec(&[1, 1], vec![1.0]);
    let mut n = 1;
    while n < d {
        let mut next = Tensor::zeros(&[2 * n, 2 * n]);
        for i in 0..n {
            for j in 0..n {
                let v = h.at2(i, j);
                next.set2(i, j, v);
                next.set2(i, j + n, v);
                next.set2(i + n, j, v);
                next.set2(i + n, j + n, -v);
            }
        }
        h = next;
        n *= 2;
    }
    h
}

/// Randomized Hadamard rotation Q = H_d · diag(s) / √d (orthogonal).
pub fn randomized_hadamard(d: usize, rng: &mut Pcg) -> Tensor {
    let mut h = sylvester(d);
    let signs: Vec<f32> = (0..d).map(|_| rng.sign()).collect();
    let inv_sqrt = 1.0 / (d as f32).sqrt();
    for i in 0..d {
        for j in 0..d {
            let v = h.at2(i, j) * signs[j] * inv_sqrt;
            h.set2(i, j, v);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sylvester_entries_pm_one() {
        let h = sylvester(8);
        assert!(h.data.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn sylvester_rows_orthogonal() {
        let h = sylvester(16);
        for i in 0..16 {
            for j in 0..16 {
                let dot: f32 = (0..16).map(|k| h.at2(i, k) * h.at2(j, k)).sum();
                let want = if i == j { 16.0 } else { 0.0 };
                assert_eq!(dot, want, "rows {i},{j}");
            }
        }
    }

    #[test]
    fn randomized_is_orthogonal() {
        let mut rng = Pcg::new(11);
        let q = randomized_hadamard(64, &mut rng);
        let qtq = crate::tensor::kernels::syrk_t(&q, None);
        for i in 0..64 {
            for j in 0..64 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.at2(i, j) - want).abs() < 1e-4, "({i},{j})={}", qtq.at2(i, j));
            }
        }
    }

    #[test]
    fn rotation_reduces_outlier_ratio() {
        // the mechanism behind the paper's Rotate step: per-row max/rms drops
        let mut rng = Pcg::new(5);
        let d = 64;
        let mut w = Tensor::randn(&[d, d], 1.0, &mut rng);
        for _ in 0..20 {
            let idx = rng.below(d * d);
            w.data[idx] += 8.0 * rng.sign();
        }
        let q = randomized_hadamard(d, &mut rng);
        let wr = crate::tensor::kernels::gemm(&w, &q, None);
        let ratio = |m: &Tensor| -> f32 {
            (0..d)
                .map(|i| {
                    let row = m.row(i);
                    let mx = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                    let rms = (row.iter().map(|v| v * v).sum::<f32>() / d as f32).sqrt();
                    mx / rms
                })
                .sum::<f32>()
                / d as f32
        };
        assert!(ratio(&wr) < ratio(&w), "{} !< {}", ratio(&wr), ratio(&w));
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_panics() {
        sylvester(12);
    }
}
