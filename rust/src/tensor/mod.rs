//! Minimal row-major f32 tensor used host-side by the coordinator.
//!
//! All *hot* math runs in AOT HLO on the PJRT client; this type covers the
//! host-side paths: parameter init/fusion/rotation, Hessian assembly
//! checks, the pure-rust reference quantizer, and test assertions. Keep it
//! simple — no broadcasting, no views; shapes are explicit. Dense products
//! and factorizations route through the pool-parallel [`kernels`] layer
//! (DESIGN.md §10); [`Tensor::matmul`] survives only as the serial
//! reference kernel those kernels are equivalence-tested against.

pub mod hadamard;
pub mod kernels;
pub mod linalg;
pub mod pack;

pub use hadamard::randomized_hadamard;
pub use pack::{PackedRows, RowGrid};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![1.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Gaussian init, scaled like the L2 initializer (0.4/sqrt(fan_in)).
    pub fn randn(shape: &[usize], scale: f32, rng: &mut crate::util::Pcg) -> Self {
        let n = shape.iter().product();
        let data = (0..n).map(|_| scale * rng.normal()).collect();
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols() + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let c = self.cols();
        self.data[i * c + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Materialized transpose — a layout transform, not a product input:
    /// products against a transposed operand go through the fused
    /// [`kernels::gemm_at`]/[`kernels::gemm_bt`] variants instead, which
    /// read the operand in place (DESIGN.md §10).
    pub fn transpose2(&self) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Naive serial matmul: self [m,k] @ other [k,n]. **Reference kernel
    /// only** — production host paths call the pool-parallel tiled
    /// [`kernels`] family (`gemm`/`gemm_at`/`gemm_bt`/`syrk`), which is
    /// bit-identical to this loop (`tests/prop_kernels.rs` asserts exact
    /// equality, including the `a == 0.0` zero-skip contract on
    /// non-finite input; DESIGN.md §10). Do not add call sites outside
    /// `tensor/` and the equivalence tests.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        const BK: usize = 64;
        for i in 0..m {
            let a_row = self.row(i);
            let o_row = &mut out.data[i * n..(i + 1) * n];
            let mut k0 = 0;
            while k0 < k {
                let kend = (k0 + BK).min(k);
                for kk in k0..kend {
                    let a = a_row[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let b_row = &other.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        o_row[j] += a * b_row[j];
                    }
                }
                k0 = kend;
            }
        }
        out
    }

    pub fn scale_in_place(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_in_place(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg::new(0);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[7, 7]);
        for i in 0..7 {
            eye.set2(i, i, 1.0);
        }
        assert!(a.matmul(&eye).allclose(&a, 1e-6));
    }

    #[test]
    fn matmul_hand_values() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Pcg::new(1);
        let a = Tensor::randn(&[3, 9], 1.0, &mut rng);
        assert!(a.transpose2().transpose2().allclose(&a, 0.0));
    }

    #[test]
    fn matmul_matches_transposed_form() {
        let mut rng = Pcg::new(2);
        let a = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let b = Tensor::randn(&[6, 3], 1.0, &mut rng);
        let c1 = a.matmul(&b);
        let c2 = b.transpose2().matmul(&a.transpose2()).transpose2();
        assert!(c1.allclose(&c2, 1e-4));
    }

    #[test]
    #[should_panic(expected = "matmul inner dim")]
    fn matmul_dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        a.matmul(&b);
    }

    #[test]
    fn norms() {
        let t = Tensor::from_vec(&[1, 2], vec![3.0, -4.0]);
        assert_eq!(t.frob_norm(), 5.0);
        assert_eq!(t.abs_max(), 4.0);
    }
}
