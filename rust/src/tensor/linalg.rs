//! Host-side linear algebra: the damped-Hessian inverse-factor chain the
//! GPTQ recurrence consumes, plus the unblocked Cholesky/tri-inv
//! *reference* loops the blocked [`kernels`](super::kernels) variants are
//! equivalence-tested against (bit-identical — DESIGN.md §10).
//!
//! Mirrors python/compile/quantizer.py — these back the pure-rust
//! reference GPTQ in `quantref`, which property-tests the HLO solver.

use super::kernels;
use super::Tensor;
use crate::util::Pool;

/// Lower Cholesky of an SPD matrix — the unblocked reference loop. Panics
/// on non-square input; clamps tiny negative pivots (fp noise on
/// near-singular H) to keep factors finite. Production call sites use the
/// blocked, pool-parallel `kernels::cholesky_lower`, which is
/// bit-identical to this (`tests/prop_kernels.rs`).
pub fn cholesky_lower(a: &Tensor) -> Tensor {
    let d = a.rows();
    assert_eq!(d, a.cols(), "cholesky needs a square matrix");
    let mut l = Tensor::zeros(&[d, d]);
    for j in 0..d {
        let mut diag = a.at2(j, j);
        for k in 0..j {
            diag -= l.at2(j, k) * l.at2(j, k);
        }
        let ljj = diag.max(1e-12).sqrt();
        l.set2(j, j, ljj);
        for i in (j + 1)..d {
            let mut v = a.at2(i, j);
            for k in 0..j {
                v -= l.at2(i, k) * l.at2(j, k);
            }
            l.set2(i, j, v / ljj);
        }
    }
    l
}

/// Inverse of a lower-triangular matrix by forward substitution — the
/// unblocked reference for the column-parallel `kernels::tri_inv_lower`.
pub fn tri_inv_lower(l: &Tensor) -> Tensor {
    let d = l.rows();
    let mut x = Tensor::zeros(&[d, d]);
    for i in 0..d {
        let lii = l.at2(i, i);
        for j in 0..=i {
            let mut s = if i == j { 1.0 } else { 0.0 };
            for k in j..i {
                s -= l.at2(i, k) * x.at2(k, j);
            }
            x.set2(i, j, s / lii);
        }
    }
    x
}

/// Upper-triangular U with UᵀU = (H + damp·mean(diag)·I)⁻¹ — the factor the
/// GPTQ recurrence consumes (same contract as quantizer.hinv_cholesky_upper).
///
/// The whole chain — Cholesky, triangular inverse, the LᵀL Gram product,
/// and the final re-factor — runs on the blocked `tensor::kernels` layer.
/// `pool` parallelizes each step over row/column blocks without changing
/// a single output bit (DESIGN.md §10); the `quantref` oracle passes
/// `None` on purpose, keeping the reference GPTQ serial.
pub fn hinv_cholesky_upper(h: &Tensor, damp: f32, pool: Option<&Pool>) -> Tensor {
    let d = h.rows();
    let dmean = (0..d).map(|i| h.at2(i, i)).sum::<f32>() / d as f32;
    let dmean = dmean.max(1e-8);
    let mut hd = h.clone();
    for i in 0..d {
        let v = hd.at2(i, i) + damp * dmean;
        hd.set2(i, i, v);
    }
    let l = kernels::cholesky_lower(&hd, pool);
    let linv = kernels::tri_inv_lower(&l, pool);
    let hinv = kernels::syrk_t(&linv, pool);
    // transpose2 here is a layout transform of the returned factor, not a
    // materialized product operand
    kernels::cholesky_lower(&hinv, pool).transpose2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    fn spd(d: usize, seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed);
        let a = Tensor::randn(&[d, d], 1.0, &mut rng);
        let mut h = kernels::syrk(&a, None);
        for i in 0..d {
            let v = h.at2(i, i) + d as f32;
            h.set2(i, i, v);
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(16, 0);
        let l = cholesky_lower(&a);
        assert!(kernels::syrk(&l, None).allclose(&a, 1e-3));
        // strictly lower
        for i in 0..16 {
            for j in (i + 1)..16 {
                assert_eq!(l.at2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn tri_inv_inverts() {
        let a = spd(12, 1);
        let l = cholesky_lower(&a);
        let li = tri_inv_lower(&l);
        let eye = kernels::gemm(&li, &l, None);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((eye.at2(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn hinv_factor_contract() {
        let h = spd(10, 2);
        let u = hinv_cholesky_upper(&h, 0.01, None);
        // UᵀU (H + damp·mean·I) = I
        let dmean = (0..10).map(|i| h.at2(i, i)).sum::<f32>() / 10.0;
        let mut hd = h.clone();
        for i in 0..10 {
            let v = hd.at2(i, i) + 0.01 * dmean;
            hd.set2(i, i, v);
        }
        let utu = kernels::syrk_t(&u, None);
        let prod = kernels::gemm(&utu, &hd, None);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at2(i, j) - want).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn hinv_chain_jobs_invariant() {
        // the factor chain under a 4-worker pool is bit-identical to the
        // serial path — the §10 determinism contract, end to end
        let h = spd(48, 3);
        let serial = hinv_cholesky_upper(&h, 0.01, None);
        let pooled = hinv_cholesky_upper(&h, 0.01, Some(&Pool::new(4)));
        assert_eq!(serial.data, pooled.data);
    }

    #[test]
    fn degenerate_hessian_finite() {
        let h = Tensor::zeros(&[8, 8]);
        let u = hinv_cholesky_upper(&h, 0.01, None);
        assert!(u.data.iter().all(|v| v.is_finite()));
    }
}
