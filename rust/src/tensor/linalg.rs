//! Host-side reference linear algebra (Cholesky, triangular inverse).
//!
//! Mirrors python/compile/quantizer.py — these back the pure-rust reference
//! GPTQ in `quantref`, which property-tests the HLO solver. Cold path only.

use super::Tensor;

/// Lower Cholesky of an SPD matrix. Panics on non-square input; clamps tiny
/// negative pivots (fp noise on near-singular H) to keep factors finite.
pub fn cholesky_lower(a: &Tensor) -> Tensor {
    let d = a.rows();
    assert_eq!(d, a.cols(), "cholesky needs a square matrix");
    let mut l = Tensor::zeros(&[d, d]);
    for j in 0..d {
        let mut diag = a.at2(j, j);
        for k in 0..j {
            diag -= l.at2(j, k) * l.at2(j, k);
        }
        let ljj = diag.max(1e-12).sqrt();
        l.set2(j, j, ljj);
        for i in (j + 1)..d {
            let mut v = a.at2(i, j);
            for k in 0..j {
                v -= l.at2(i, k) * l.at2(j, k);
            }
            l.set2(i, j, v / ljj);
        }
    }
    l
}

/// Inverse of a lower-triangular matrix by forward substitution.
pub fn tri_inv_lower(l: &Tensor) -> Tensor {
    let d = l.rows();
    let mut x = Tensor::zeros(&[d, d]);
    for i in 0..d {
        let lii = l.at2(i, i);
        for j in 0..=i {
            let mut s = if i == j { 1.0 } else { 0.0 };
            for k in j..i {
                s -= l.at2(i, k) * x.at2(k, j);
            }
            x.set2(i, j, s / lii);
        }
    }
    x
}

/// Upper-triangular U with UᵀU = (H + damp·mean(diag)·I)⁻¹ — the factor the
/// GPTQ recurrence consumes (same contract as quantizer.hinv_cholesky_upper).
pub fn hinv_cholesky_upper(h: &Tensor, damp: f32) -> Tensor {
    let d = h.rows();
    let dmean = (0..d).map(|i| h.at2(i, i)).sum::<f32>() / d as f32;
    let dmean = dmean.max(1e-8);
    let mut hd = h.clone();
    for i in 0..d {
        let v = hd.at2(i, i) + damp * dmean;
        hd.set2(i, i, v);
    }
    let l = cholesky_lower(&hd);
    let linv = tri_inv_lower(&l);
    let hinv = linv.transpose2().matmul(&linv);
    cholesky_lower(&hinv).transpose2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    fn spd(d: usize, seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed);
        let a = Tensor::randn(&[d, d], 1.0, &mut rng);
        let mut h = a.matmul(&a.transpose2());
        for i in 0..d {
            let v = h.at2(i, i) + d as f32;
            h.set2(i, i, v);
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = spd(16, 0);
        let l = cholesky_lower(&a);
        assert!(l.matmul(&l.transpose2()).allclose(&a, 1e-3));
        // strictly lower
        for i in 0..16 {
            for j in (i + 1)..16 {
                assert_eq!(l.at2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn tri_inv_inverts() {
        let a = spd(12, 1);
        let l = cholesky_lower(&a);
        let li = tri_inv_lower(&l);
        let eye = li.matmul(&l);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((eye.at2(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn hinv_factor_contract() {
        let h = spd(10, 2);
        let u = hinv_cholesky_upper(&h, 0.01);
        // UᵀU (H + damp·mean·I) = I
        let dmean = (0..10).map(|i| h.at2(i, i)).sum::<f32>() / 10.0;
        let mut hd = h.clone();
        for i in 0..10 {
            let v = hd.at2(i, i) + 0.01 * dmean;
            hd.set2(i, i, v);
        }
        let utu = u.transpose2().matmul(&u);
        let prod = utu.matmul(&hd);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at2(i, j) - want).abs() < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn degenerate_hessian_finite() {
        let h = Tensor::zeros(&[8, 8]);
        let u = hinv_cholesky_upper(&h, 0.01);
        assert!(u.data.iter().all(|v| v.is_finite()));
    }
}
