//! Bit-packing codec for quantized weight rows (DESIGN.md §9).
//!
//! A GPTQ/RTN-quantized weight holds at most `2^bits` distinct values per
//! row, all on the row's affine grid `v = scale · (code − zero)` with
//! integer codes in `[0, 2^bits − 1]`. This module stores the codes at
//! `bits` bits each plus the per-row grid (`scale`, `zero` as f32), cutting
//! a 3-bit weight to ~3/32 of its f32 size on disk.
//!
//! The contract is **exactness, verified at pack time**: [`PackedRows::pack`]
//! recovers every element's code from the dequantized tensor and checks
//! that `scale * (code as f32 - zero)` reproduces the input *bit-for-bit*
//! (`f32::to_bits`, so even `-0.0` vs `0.0` drift is caught). Any element
//! that is not exactly representable fails the pack — callers fall back to
//! raw f32 storage (the VQ codebook methods always do). [`PackedRows::unpack`]
//! evaluates the identical expression, so `unpack(pack(t)) == t` bitwise
//! whenever `pack` succeeds; rust/tests/prop_artifact.rs property-tests
//! this across bit widths, ragged row widths, and degenerate rows.
//!
//! Bitstream layout: codes are packed LSB-first within each byte, and every
//! row starts on a fresh byte boundary (`row_bytes` bytes per row), so rows
//! are independently addressable and ragged widths need no global padding
//! logic.

use super::Tensor;
use crate::util::Pool;

/// Bit widths the codec supports (the paper's sweep range plus 8-bit).
pub const PACK_BITS: [u32; 4] = [2, 3, 4, 8];

/// Per-row affine quantization grid: `v = scale[r] * (code - zero[r])`.
/// `zero` is integer-valued but stored as f32 because the dequantization
/// arithmetic is f32 (see `quantref::row_grid`).
#[derive(Clone, Debug, PartialEq)]
pub struct RowGrid {
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
}

/// Why a tensor could not be packed. Callers treat any of these as "store
/// raw f32 instead" except where a test asserts the specific cause.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PackError {
    /// bits not one of [`PACK_BITS`]
    UnsupportedBits(u32),
    /// tensor is not a 2-D matrix
    NotMatrix,
    /// scale/zero length differs from the row count
    GridLenMismatch,
    /// NaN/inf scale or zero, or scale ≤ 0 — such a grid cannot be
    /// inverted, and silently packing it would decode to garbage
    NonFiniteGrid { row: usize },
    /// element not exactly representable as `scale*(code-zero)`
    OffGrid { row: usize, col: usize },
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PackError::UnsupportedBits(b) => {
                write!(f, "unsupported pack width {b} bits (supported: {PACK_BITS:?})")
            }
            PackError::NotMatrix => write!(f, "only 2-D tensors can be bit-packed"),
            PackError::GridLenMismatch => write!(f, "grid scale/zero length != row count"),
            PackError::NonFiniteGrid { row } => {
                write!(f, "row {row}: non-finite or non-positive grid scale/zero")
            }
            PackError::OffGrid { row, col } => {
                write!(f, "element ({row},{col}) is not exactly on its row grid")
            }
        }
    }
}

impl std::error::Error for PackError {}

/// Bytes one packed row of `cols` codes occupies (rows are byte-aligned).
pub fn row_bytes(cols: usize, bits: u32) -> usize {
    (cols * bits as usize + 7) / 8
}

/// A bit-packed 2-D tensor: integer codes + per-row grid.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedRows {
    pub bits: u32,
    pub rows: usize,
    pub cols: usize,
    pub grid: RowGrid,
    /// `rows * row_bytes(cols, bits)` bytes, codes LSB-first per byte
    pub data: Vec<u8>,
}

impl PackedRows {
    /// Pack `t` against the given per-row grid, verifying that every
    /// element decodes back bit-identically. O(rows·cols).
    pub fn pack(t: &Tensor, bits: u32, grid: &RowGrid) -> Result<PackedRows, PackError> {
        if !PACK_BITS.contains(&bits) {
            return Err(PackError::UnsupportedBits(bits));
        }
        if t.shape.len() != 2 {
            return Err(PackError::NotMatrix);
        }
        let (rows, cols) = (t.shape[0], t.shape[1]);
        if grid.scale.len() != rows || grid.zero.len() != rows {
            return Err(PackError::GridLenMismatch);
        }
        let maxq = ((1u64 << bits) - 1) as f32;
        let rb = row_bytes(cols, bits);
        let mut data = vec![0u8; rows * rb];
        for r in 0..rows {
            let (s, z) = (grid.scale[r], grid.zero[r]);
            if !s.is_finite() || !z.is_finite() || s <= 0.0 {
                return Err(PackError::NonFiniteGrid { row: r });
            }
            for (c, &v) in t.row(r).iter().enumerate() {
                let code = (v / s + z).round();
                if !(code >= 0.0 && code <= maxq) {
                    return Err(PackError::OffGrid { row: r, col: c });
                }
                let code = code as u32;
                // the decoder's exact expression — bit-compare against v
                if (s * (code as f32 - z)).to_bits() != v.to_bits() {
                    return Err(PackError::OffGrid { row: r, col: c });
                }
                write_code(&mut data[r * rb..(r + 1) * rb], c, bits, code);
            }
        }
        Ok(PackedRows { bits, rows, cols, grid: grid.clone(), data })
    }

    /// Decode back to the exact tensor `pack` consumed, optionally
    /// pool-parallel over row blocks. Rows decode independently through
    /// the identical per-element expression, so the pool cannot change a
    /// single bit — `unpack(Some(pool))` equals `unpack(None)` exactly
    /// (rust/tests/prop_serve.rs pins it). Dispatch rides the kernel
    /// layer's write-into spine (`par_rows_into`, DESIGN.md §13): the
    /// serial path writes straight into the output buffer, the pooled
    /// path allocates per row *block*, and tensors under the kernel
    /// minimum-work threshold decode serially in the calling thread.
    pub fn unpack(&self, pool: Option<&Pool>) -> Tensor {
        use crate::tensor::kernels::par_rows_into;
        let (rows, cols) = (self.rows, self.cols);
        let mut out = Tensor::zeros(&[rows, cols]);
        if rows * cols == 0 {
            return out;
        }
        // decode (bit extraction + affine) is markedly heavier per
        // element than a fused multiply-add; weight the work estimate up
        let work = rows * cols * 4;
        par_rows_into(pool, rows, work, &mut out.data, |r| r * cols..(r + 1) * cols, |r, row| {
            self.decode_row_into(r, 0, row)
        });
        out
    }

    /// Dequantize codes `[k0, k0 + out.len())` of row `r` into `out` —
    /// the per-tile decode primitive shared by [`PackedRows::unpack`] and
    /// the fused serving kernels (`tensor::kernels::gemv`, DESIGN.md
    /// §11). Evaluates exactly `scale * (code - zero)` per element, the
    /// expression `pack` verified against the input bit-for-bit.
    pub fn decode_row_into(&self, r: usize, k0: usize, out: &mut [f32]) {
        let rb = row_bytes(self.cols, self.bits);
        let row_data = &self.data[r * rb..(r + 1) * rb];
        let (s, z) = (self.grid.scale[r], self.grid.zero[r]);
        for (t, o) in out.iter_mut().enumerate() {
            *o = s * (read_code(row_data, k0 + t, self.bits) as f32 - z);
        }
    }

    /// Integer code of one element (tests + debugging).
    pub fn code(&self, r: usize, c: usize) -> u32 {
        let rb = row_bytes(self.cols, self.bits);
        read_code(&self.data[r * rb..(r + 1) * rb], c, self.bits)
    }
}

/// Write element `col`'s `bits`-wide code into a zeroed row buffer,
/// LSB-first within each byte — the single bitstream layout shared by
/// [`PackedRows`] and the serving layer's KV-page codecs
/// (`serve::kvq`, DESIGN.md §12). Only ORs bits in: callers re-encoding
/// a slot must clear its bytes first.
pub fn write_code(row: &mut [u8], col: usize, bits: u32, code: u32) {
    let start = col * bits as usize;
    for k in 0..bits as usize {
        let bit = start + k;
        if (code >> k) & 1 == 1 {
            row[bit / 8] |= 1 << (bit % 8);
        }
    }
}

/// Read element `col`'s `bits`-wide code back — the exact inverse of
/// [`write_code`] over the same LSB-first layout.
pub fn read_code(row: &[u8], col: usize, bits: u32) -> u32 {
    let start = col * bits as usize;
    let mut code = 0u32;
    for k in 0..bits as usize {
        let bit = start + k;
        code |= (((row[bit / 8] >> (bit % 8)) & 1) as u32) << k;
    }
    code
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an exactly-representable tensor from explicit codes.
    fn from_codes(codes: &[&[u32]], s: f32, z: f32) -> (Tensor, RowGrid) {
        let rows = codes.len();
        let cols = codes[0].len();
        let data = codes
            .iter()
            .flat_map(|row| row.iter().map(|&c| s * (c as f32 - z)))
            .collect();
        let grid = RowGrid { scale: vec![s; rows], zero: vec![z; rows] };
        (Tensor::from_vec(&[rows, cols], data), grid)
    }

    #[test]
    fn roundtrip_hand_values() {
        let (t, grid) = from_codes(&[&[0, 1, 2, 3, 7], &[7, 6, 5, 0, 1]], 0.5, 2.0);
        let p = PackedRows::pack(&t, 3, &grid).unwrap();
        assert_eq!(p.code(0, 4), 7);
        assert_eq!(p.code(1, 3), 0);
        let u = p.unpack(None);
        assert_eq!(u.data, t.data);
        // bit-exactness, not just value equality
        for (a, b) in u.data.iter().zip(&t.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn unpack_pool_parallel_is_bit_identical() {
        use crate::quantref;
        use crate::util::Pcg;
        let mut rng = Pcg::new(23);
        // ragged widths so row blocks straddle byte boundaries
        for (rows, cols) in [(1usize, 1usize), (5, 7), (37, 19), (64, 33)] {
            let w = Tensor::randn(&[rows, cols], 1.0, &mut rng);
            for bits in PACK_BITS {
                let maxq = ((1u64 << bits) - 1) as f32;
                let q = quantref::rtn(&w, maxq);
                let (scale, zero) = quantref::row_grid(&w, maxq);
                let p = PackedRows::pack(&q, bits, &RowGrid { scale, zero }).unwrap();
                let serial = p.unpack(None);
                for jobs in [1usize, 4] {
                    let pool = Pool::new(jobs);
                    let par = p.unpack(Some(&pool));
                    assert_eq!(par.shape, serial.shape);
                    for (a, b) in par.data.iter().zip(&serial.data) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{rows}x{cols} bits={bits}");
                    }
                }
            }
        }
    }

    #[test]
    fn decode_row_into_tiles_match_full_row() {
        let (t, grid) =
            from_codes(&[&[0, 1, 2, 3, 7, 5, 4, 6, 2], &[7, 6, 5, 0, 1, 2, 3, 4, 5]], 0.25, 3.0);
        let p = PackedRows::pack(&t, 3, &grid).unwrap();
        for r in 0..2 {
            let mut full = vec![0.0f32; 9];
            p.decode_row_into(r, 0, &mut full);
            assert_eq!(full, t.row(r));
            // tiled decode at an interior offset reads the same codes
            let mut tile = vec![0.0f32; 4];
            p.decode_row_into(r, 3, &mut tile);
            assert_eq!(tile, &t.row(r)[3..7]);
        }
    }

    #[test]
    fn row_bytes_ragged() {
        assert_eq!(row_bytes(5, 3), 2); // 15 bits
        assert_eq!(row_bytes(8, 3), 3); // 24 bits
        assert_eq!(row_bytes(1, 2), 1);
        assert_eq!(row_bytes(7, 8), 7);
    }

    #[test]
    fn rejects_unsupported_bits() {
        let (t, grid) = from_codes(&[&[0, 1]], 1.0, 0.0);
        assert_eq!(PackedRows::pack(&t, 5, &grid), Err(PackError::UnsupportedBits(5)));
        assert_eq!(PackedRows::pack(&t, 0, &grid), Err(PackError::UnsupportedBits(0)));
    }

    #[test]
    fn rejects_non_finite_grid() {
        let (t, mut grid) = from_codes(&[&[0, 1], &[2, 3]], 1.0, 0.0);
        grid.scale[1] = f32::NAN;
        assert_eq!(PackedRows::pack(&t, 2, &grid), Err(PackError::NonFiniteGrid { row: 1 }));
        grid.scale[1] = f32::INFINITY;
        assert_eq!(PackedRows::pack(&t, 2, &grid), Err(PackError::NonFiniteGrid { row: 1 }));
        grid.scale[1] = 0.0;
        assert_eq!(PackedRows::pack(&t, 2, &grid), Err(PackError::NonFiniteGrid { row: 1 }));
    }

    #[test]
    fn rejects_off_grid_values() {
        let (mut t, grid) = from_codes(&[&[0, 1, 2]], 0.25, 1.0);
        t.data[1] += 0.01;
        assert_eq!(PackedRows::pack(&t, 2, &grid), Err(PackError::OffGrid { row: 0, col: 1 }));
    }

    #[test]
    fn rejects_out_of_range_codes() {
        // value corresponds to code 9 on a 3-bit (maxq=7) grid
        let t = Tensor::from_vec(&[1, 1], vec![9.0]);
        let grid = RowGrid { scale: vec![1.0], zero: vec![0.0] };
        assert!(matches!(PackedRows::pack(&t, 3, &grid), Err(PackError::OffGrid { .. })));
    }

    #[test]
    fn all_zero_and_all_max_rows() {
        for bits in PACK_BITS {
            let maxq = (1u32 << bits) - 1;
            let zeros: Vec<u32> = vec![0; 11];
            let maxs: Vec<u32> = vec![maxq; 11];
            let (t, grid) = from_codes(&[&zeros, &maxs], 0.125, 3.0);
            let p = PackedRows::pack(&t, bits, &grid).unwrap();
            assert_eq!(p.unpack(None).data, t.data, "bits={bits}");
            assert_eq!(p.code(1, 10), maxq);
        }
    }

    #[test]
    fn rtn_output_packs_exactly() {
        use crate::quantref;
        use crate::util::Pcg;
        let mut rng = Pcg::new(11);
        let w = Tensor::randn(&[6, 37], 1.0, &mut rng);
        for bits in PACK_BITS {
            let maxq = ((1u64 << bits) - 1) as f32;
            let q = quantref::rtn(&w, maxq);
            let (scale, zero) = quantref::row_grid(&w, maxq);
            let grid = RowGrid { scale, zero };
            let p = PackedRows::pack(&q, bits, &grid)
                .unwrap_or_else(|e| panic!("bits={bits}: {e}"));
            let u = p.unpack(None);
            for (a, b) in u.data.iter().zip(&q.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "bits={bits}");
            }
        }
    }
}
