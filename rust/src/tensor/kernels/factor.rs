//! Blocked triangular factorizations: right-looking Cholesky and
//! column-block-parallel triangular inversion (DESIGN.md §10).
//!
//! Both are **bit-identical** to the unblocked references in
//! `tensor::linalg`: the blocked schedules regroup *which loop* performs
//! each subtraction, but every matrix element still absorbs its
//! `l[i][k]·l[j][k]` (resp. `l[i][k]·x[k][j]`) terms one at a time, in
//! strictly increasing k — the identical floating-point operation
//! sequence, so no tolerance is needed in the equivalence tests. Pool
//! parallelism splits the panel solve and trailing update over row blocks
//! (columns for `tri_inv_lower`), which are data-independent, so `jobs=N`
//! is bit-identical to `jobs=1` as well.

use crate::tensor::Tensor;
use crate::util::Pool;

use super::par_rows;

/// Factor block width (the panel size of the right-looking sweep). A
/// matrix with `d <= NB` degenerates to the plain unblocked loop.
const NB: usize = 32;

/// Lower Cholesky of an SPD matrix, blocked right-looking: factor the
/// diagonal block, forward-substitute the panel below it (row-parallel),
/// subtract the panel's outer product from the trailing matrix
/// (row-parallel), repeat. Tiny negative pivots are clamped exactly like
/// the unblocked reference (`linalg::cholesky_lower`), to which this is
/// bit-identical at every jobs count. Panics on non-square input.
pub fn cholesky_lower(a: &Tensor, pool: Option<&Pool>) -> Tensor {
    let d = a.rows();
    assert_eq!(d, a.cols(), "cholesky needs a square matrix");
    // trailing matrix; only its lower triangle is maintained
    let mut w = a.clone();
    let mut l = Tensor::zeros(&[d, d]);
    let mut p0 = 0;
    while p0 < d {
        let p1 = (p0 + NB).min(d);

        // diagonal block [p0,p1)²: unblocked factor. Contributions from
        // k < p0 were already subtracted into `w` by earlier trailing
        // updates, so only the within-block k range remains.
        for j in p0..p1 {
            let mut diag = w.at2(j, j);
            for k in p0..j {
                diag -= l.at2(j, k) * l.at2(j, k);
            }
            let ljj = diag.max(1e-12).sqrt();
            l.set2(j, j, ljj);
            for i in (j + 1)..p1 {
                let mut v = w.at2(i, j);
                for k in p0..j {
                    v -= l.at2(i, k) * l.at2(j, k);
                }
                l.set2(i, j, v / ljj);
            }
        }
        if p1 == d {
            break;
        }

        // panel solve: each row i >= p1 forward-substitutes against the
        // diagonal block independently — row-parallel, coordinator writes
        // the rows back in index order.
        let bw = p1 - p0;
        let panel = par_rows(pool, d - p1, (d - p1) * bw * bw, |ri| {
            let i = p1 + ri;
            let mut row = vec![0.0f32; bw];
            for j in p0..p1 {
                let mut v = w.at2(i, j);
                for k in p0..j {
                    v -= row[k - p0] * l.at2(j, k);
                }
                row[j - p0] = v / l.at2(j, j);
            }
            row
        });
        for (ri, row) in panel.into_iter().enumerate() {
            let i = p1 + ri;
            l.data[i * d + p0..i * d + p1].copy_from_slice(&row);
        }

        // trailing update: w[i][j] -= Σ_{k∈panel} l[i][k]·l[j][k], one
        // term at a time in k order (the reference's exact sequence),
        // lower triangle only — row-parallel.
        let upd = par_rows(pool, d - p1, (d - p1) * (d - p1) * bw / 2, |ri| {
            let i = p1 + ri;
            let li = &l.data[i * d + p0..i * d + p1];
            let mut row = Vec::with_capacity(i - p1 + 1);
            for j in p1..=i {
                let lj = &l.data[j * d + p0..j * d + p1];
                let mut v = w.at2(i, j);
                for (&x, &y) in li.iter().zip(lj) {
                    v -= x * y;
                }
                row.push(v);
            }
            row
        });
        for (ri, row) in upd.into_iter().enumerate() {
            let i = p1 + ri;
            w.data[i * d + p1..i * d + i + 1].copy_from_slice(&row);
        }
        p0 = p1;
    }
    l
}

/// Inverse of a lower-triangular matrix. Each output column is an
/// independent forward substitution, so columns fan out over the pool in
/// blocks while the within-column arithmetic stays the unblocked
/// reference's (`linalg::tri_inv_lower`) — bit-identical to it at every
/// jobs count. Panics on non-square input.
pub fn tri_inv_lower(l: &Tensor, pool: Option<&Pool>) -> Tensor {
    let d = l.rows();
    assert_eq!(d, l.cols(), "tri_inv needs a square matrix");
    // column j's task returns x[j..d][j]; early columns are the longest,
    // which the pool's atomic task claim load-balances.
    let cols = par_rows(pool, d, d * d * d / 6, |j| {
        let mut col = vec![0.0f32; d - j];
        for i in j..d {
            let mut s = if i == j { 1.0 } else { 0.0 };
            for k in j..i {
                s -= l.at2(i, k) * col[k - j];
            }
            col[i - j] = s / l.at2(i, i);
        }
        col
    });
    let mut x = Tensor::zeros(&[d, d]);
    for (j, col) in cols.into_iter().enumerate() {
        for (ri, v) in col.into_iter().enumerate() {
            x.set2(j + ri, j, v);
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::kernels::syrk;
    use crate::tensor::linalg;
    use crate::util::Pcg;

    fn spd(d: usize, seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed);
        let a = Tensor::randn(&[d, d + 2], 1.0, &mut rng);
        let mut h = syrk(&a, None);
        for i in 0..d {
            let v = h.at2(i, i) + d as f32;
            h.set2(i, i, v);
        }
        h
    }

    #[test]
    fn blocked_cholesky_bit_identical_to_unblocked() {
        // sizes below, at, and across the NB=32 block boundary
        for (d, seed) in [(1, 0), (7, 1), (32, 2), (33, 3), (50, 4), (96, 5)] {
            let h = spd(d, seed);
            let reference = linalg::cholesky_lower(&h);
            for pool in [None, Some(Pool::new(1)), Some(Pool::new(4))] {
                let got = cholesky_lower(&h, pool.as_ref());
                assert_eq!(got.data, reference.data, "d={d} pool={:?}", pool);
            }
        }
    }

    #[test]
    fn column_parallel_tri_inv_bit_identical_to_unblocked() {
        for (d, seed) in [(1, 6), (13, 7), (48, 8), (80, 9)] {
            let l = linalg::cholesky_lower(&spd(d, seed));
            let reference = linalg::tri_inv_lower(&l);
            for pool in [None, Some(Pool::new(4))] {
                let got = tri_inv_lower(&l, pool.as_ref());
                assert_eq!(got.data, reference.data, "d={d}");
            }
        }
    }

    #[test]
    fn degenerate_zero_matrix_stays_finite() {
        let l = cholesky_lower(&Tensor::zeros(&[40, 40]), Some(&Pool::new(2)));
        assert!(l.data.iter().all(|v| v.is_finite()));
        assert_eq!(l.data, linalg::cholesky_lower(&Tensor::zeros(&[40, 40])).data);
    }

    #[test]
    #[should_panic(expected = "square")]
    fn non_square_panics() {
        cholesky_lower(&Tensor::zeros(&[3, 4]), None);
    }
}
