//! The GEMM/SYRK family: pool-parallel over row blocks, cache-tiled over
//! output columns, bit-identical to the naive reference kernel
//! (`Tensor::matmul` + materialized `transpose2()`) — see the module docs
//! in [`super`] for the determinism and zero-skip contracts. These free
//! functions are the `reference` backend (DESIGN.md §13); rows write
//! through [`par_rows_into`] straight into the output buffer, so the
//! dispatch spine allocates per row *block* at most, never per row.

use crate::obs::trace;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::Pool;

use super::par_rows_into;

/// Output-column tile: one out-row segment plus one B-row segment stay
/// L1-resident across the k sweep. Tiling over j never touches the
/// per-element accumulation order (k stays innermost-increasing), so it
/// cannot perturb a single output bit.
const BJ: usize = 256;

/// One output row of A·B or Aᵀ·B into a zeroed `out` slice: `coeff(kk)`
/// yields the row's A coefficient for inner index `kk` (contiguous for
/// `gemm`, strided for `gemm_at`); B rows are read in place. Zero
/// coefficients are skipped — the reference kernel's contract (see
/// [`super`]).
fn row_ab(coeff: impl Fn(usize) -> f32, b: &Tensor, k: usize, out: &mut [f32]) {
    let n = out.len();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + BJ).min(n);
        for kk in 0..k {
            let av = coeff(kk);
            if av == 0.0 {
                continue;
            }
            let b_seg = &b.data[kk * n + j0..kk * n + j1];
            for (o, &bv) in out[j0..j1].iter_mut().zip(b_seg) {
                *o += av * bv;
            }
        }
        j0 = j1;
    }
}

/// A [m,k] · B [k,n] → [m,n]. Pool-parallel over row blocks; bit-identical
/// to `a.matmul(&b)` at every jobs count.
pub fn gemm(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm inner dim: {k} vs {k2}");
    let _sp = trace::span_with("kernel", "kernel.gemm", || {
        Json::obj().set("m", m).set("k", k).set("n", n).set("backend", "reference")
    });
    let mut out = Tensor::zeros(&[m, n]);
    let span = |i: usize| i * n..(i + 1) * n;
    par_rows_into(pool, m, m * k * n, &mut out.data, span, |i, row| {
        let a_row = a.row(i);
        row_ab(|kk| a_row[kk], b, k, row)
    });
    out
}

/// Aᵀ·B for A [k,m], B [k,n] → [m,n], reading A's columns in place — the
/// fused-transpose replacement for `a.transpose2().matmul(&b)`,
/// bit-identical to it without the materialized copy.
pub fn gemm_at(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm_at inner dim: {k} vs {k2}");
    let _sp = trace::span_with("kernel", "kernel.gemm_at", || {
        Json::obj().set("m", m).set("k", k).set("n", n).set("backend", "reference")
    });
    let mut out = Tensor::zeros(&[m, n]);
    let span = |i: usize| i * n..(i + 1) * n;
    par_rows_into(pool, m, m * k * n, &mut out.data, span, |i, row| {
        row_ab(|kk| a.data[kk * m + i], b, k, row)
    });
    out
}

/// Dot products of `a_row` against `bj(j)` rows into a zeroed `out`
/// slice, k ascending, zero coefficients of `a_row` skipped — the
/// element-wise operation sequence of the reference
/// `a.matmul(&b.transpose2())`.
fn row_dots<'t>(a_row: &[f32], bj: impl Fn(usize) -> &'t [f32], out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        let b_row = bj(j);
        let mut acc = 0.0f32;
        for (&av, &bv) in a_row.iter().zip(b_row) {
            if av == 0.0 {
                continue;
            }
            acc += av * bv;
        }
        *o = acc;
    }
}

/// A·Bᵀ for A [m,k], B [n,k] → [m,n]: both operands are walked along
/// contiguous rows (dot-product form) — the fused-transpose replacement
/// for `a.matmul(&b.transpose2())`, bit-identical to it.
pub fn gemm_bt(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "gemm_bt inner dim: {k} vs {k2}");
    let _sp = trace::span_with("kernel", "kernel.gemm_bt", || {
        Json::obj().set("m", m).set("k", k).set("n", n).set("backend", "reference")
    });
    let mut out = Tensor::zeros(&[m, n]);
    let span = |i: usize| i * n..(i + 1) * n;
    par_rows_into(pool, m, m * k * n, &mut out.data, span, |i, row| {
        row_dots(a.row(i), |j| b.row(j), row)
    });
    out
}

/// Mirror the computed lower triangle onto the upper one — shared by the
/// reference and simd `syrk`/`syrk_t` (the simd backend reuses it, so
/// the symmetric-output convention cannot drift between backends).
pub(super) fn mirror_upper(t: &mut Tensor) {
    let m = t.rows();
    for i in 0..m {
        for j in (i + 1)..m {
            t.data[i * m + j] = t.data[j * m + i];
        }
    }
}

/// Symmetric rank-k product A·Aᵀ for A [m,k] → [m,m]: only the lower
/// triangle is computed (ragged rows load-balance through the pool's
/// atomic task claim), the upper is mirrored. Requires finite input —
/// with finite data the mirror equals the reference product bit-for-bit
/// (products commute exactly; a skipped 0·x term contributes an exact
/// ±0.0 that cannot move a +0.0-seeded accumulator).
pub fn syrk(a: &Tensor, pool: Option<&Pool>) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let _sp = trace::span_with("kernel", "kernel.syrk", || {
        Json::obj().set("m", m).set("k", k).set("backend", "reference")
    });
    let mut out = Tensor::zeros(&[m, m]);
    let span = |i: usize| i * m..i * m + i + 1;
    par_rows_into(pool, m, m * m * k / 2, &mut out.data, span, |i, row| {
        row_dots(a.row(i), |j| a.row(j), row)
    });
    mirror_upper(&mut out);
    out
}

/// Symmetric Gram product Aᵀ·A for A [k,m] → [m,m] (the Hessian/`UᵀU`
/// shape), columns read in place: the fused-transpose replacement for
/// `a.transpose2().matmul(&a)`. Lower triangle + mirror, same finite-input
/// contract as [`syrk`].
pub fn syrk_t(a: &Tensor, pool: Option<&Pool>) -> Tensor {
    let (k, m) = (a.rows(), a.cols());
    let _sp = trace::span_with("kernel", "kernel.syrk_t", || {
        Json::obj().set("m", m).set("k", k).set("backend", "reference")
    });
    let mut out = Tensor::zeros(&[m, m]);
    let span = |i: usize| i * m..i * m + i + 1;
    par_rows_into(pool, m, m * m * k / 2, &mut out.data, span, |i, row| {
        for kk in 0..k {
            let av = a.data[kk * m + i];
            if av == 0.0 {
                continue;
            }
            let a_row = &a.data[kk * m..kk * m + i + 1];
            for (o, &bv) in row.iter_mut().zip(a_row) {
                *o += av * bv;
            }
        }
    });
    mirror_upper(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    fn randm(r: usize, c: usize, rng: &mut Pcg) -> Tensor {
        // exact zeros sprinkled in so the zero-skip path is always live
        let data = (0..r * c)
            .map(|_| if rng.f32() < 0.2 { 0.0 } else { rng.normal() })
            .collect();
        Tensor::from_vec(&[r, c], data)
    }

    #[test]
    fn gemm_family_matches_reference_bitwise() {
        let mut rng = Pcg::new(3);
        for (m, k, n) in [(5, 7, 6), (1, 9, 4), (17, 3, 33), (8, 64, 8)] {
            let a = randm(m, k, &mut rng);
            let b = randm(k, n, &mut rng);
            let at = a.transpose2();
            let bt = b.transpose2();
            for pool in [None, Some(Pool::new(4))] {
                let pool = pool.as_ref();
                assert_eq!(gemm(&a, &b, pool).data, a.matmul(&b).data, "gemm {m}x{k}x{n}");
                assert_eq!(gemm_at(&at, &b, pool).data, a.matmul(&b).data, "gemm_at");
                assert_eq!(gemm_bt(&a, &bt, pool).data, a.matmul(&b).data, "gemm_bt");
                assert_eq!(syrk(&a, pool).data, a.matmul(&at).data, "syrk");
                assert_eq!(syrk_t(&a, pool).data, at.matmul(&a).data, "syrk_t");
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        let a = Tensor::zeros(&[0, 3]);
        let b = Tensor::zeros(&[3, 2]);
        assert_eq!(gemm(&a, &b, None).shape, vec![0, 2]);
        let a = Tensor::zeros(&[2, 0]);
        let b = Tensor::zeros(&[0, 5]);
        assert_eq!(gemm(&a, &b, None).data, vec![0.0; 10], "k=0 sums nothing");
        let a = Tensor::from_vec(&[1, 1], vec![3.0]);
        assert_eq!(gemm_bt(&a, &a, None).data, vec![9.0]);
        assert_eq!(syrk(&Tensor::zeros(&[0, 4]), None).shape, vec![0, 0]);
    }

    #[test]
    #[should_panic(expected = "gemm inner dim")]
    fn gemm_dim_mismatch_panics() {
        gemm(&Tensor::zeros(&[2, 3]), &Tensor::zeros(&[4, 2]), None);
    }

    #[test]
    fn zero_skip_is_contractual_on_non_finite_input() {
        // An exact 0.0 (either sign) in A skips its term entirely, which
        // suppresses NaN/∞ from the B row it would have met — exactly like
        // the reference kernel. This is the pinned contract of DESIGN.md
        // §10, not an accident of the implementation.
        let a = Tensor::from_vec(&[1, 3], vec![0.0, -0.0, 2.0]);
        let b = Tensor::from_vec(
            &[3, 2],
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 1.0, 2.0],
        );
        let want = vec![2.0, 4.0];
        assert_eq!(a.matmul(&b).data, want, "reference skips zeros");
        assert_eq!(gemm(&a, &b, None).data, want);
        assert_eq!(gemm_at(&a.transpose2(), &b, None).data, want);
        assert_eq!(gemm_bt(&a, &b.transpose2(), None).data, want);

        // ... while any non-zero coefficient propagates non-finite values
        // in reference and tiled kernels alike.
        let a2 = Tensor::from_vec(&[1, 3], vec![1e-30, 0.0, 2.0]);
        for q in [a2.matmul(&b), gemm(&a2, &b, None), gemm_bt(&a2, &b.transpose2(), None)] {
            assert!(q.data[0].is_nan(), "{:?}", q.data);
            assert!(q.data[1].is_infinite(), "{:?}", q.data);
        }
    }
}
