//! Backend dispatch for the host kernel layer (DESIGN.md §13).
//!
//! Two implementations of the same kernel family sit behind
//! [`KernelBackend`]:
//!
//! - [`ReferenceKernels`] — the free functions of this module tree
//!   (`gemm.rs`, `gemv.rs`), bit-identical to the naive reference at
//!   every jobs count. This backend IS the repo's bit-exact oracle: all
//!   exact-equality tests, golden fixtures, and the quantize/eval/
//!   generate default run through it unchanged.
//! - [`SimdKernels`] — runtime-detected AVX2+FMA paths (`simd.rs`).
//!   SIMD reassociates the k-reductions (eight lanes × multiple
//!   accumulators, FMA contraction), so its outputs are pinned by the
//!   shared tolerance/ULP harness (`tests/common/mod.rs`), never by
//!   exact equality. Deterministic and jobs-invariant all the same: the
//!   lane structure is fixed and the row-block dispatch never splits a
//!   reduction.
//!
//! [`Backend`] is the value call sites thread around (CLI → pipeline →
//! serve). `Backend::parse` maps the `--backend` flag: `reference` is
//! the default, `simd` and `auto` both resolve to [`Backend::Simd`] when
//! the host supports AVX2+FMA (checked once per call via
//! `is_x86_feature_detected!`) and **silently** to
//! [`Backend::Reference`] otherwise — on a non-x86 or pre-AVX2 host
//! every spelling degrades to the oracle, so reports record the
//! *resolved* backend name, never the flag spelling.

use crate::obs::trace;
use crate::tensor::pack::PackedRows;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::Pool;

use super::{gemm, gemv, simd};

/// Shape-tagged span for a simd-dispatched kernel call (the reference
/// free functions carry their own `backend: "reference"` spans; when a
/// simd entry point falls back to them off-AVX2, the nested reference
/// span documents the fallback).
#[inline]
fn simd_span(name: &'static str, m: usize, k: usize, n: usize) -> trace::Span {
    trace::span_with("kernel", name, || {
        Json::obj().set("m", m).set("k", k).set("n", n).set("backend", "simd")
    })
}

/// The kernel entry points a backend must provide: the GEMM family, the
/// fused dequantize kernels, and the dot/AXPY primitives the serving
/// layer's `attn_row` consumes over decoded KV scratch.
pub trait KernelBackend: Sync {
    /// Resolved backend name, as recorded by `QuantReport`/`ServeReport`.
    fn name(&self) -> &'static str;
    /// A\[m,k\] · B\[k,n\] → \[m,n\].
    fn gemm(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor;
    /// Aᵀ·B for A\[k,m\], B\[k,n\] → \[m,n\], reading A columns in place.
    fn gemm_at(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor;
    /// A·Bᵀ for A\[m,k\], B\[n,k\] → \[m,n\].
    fn gemm_bt(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor;
    /// Symmetric A·Aᵀ for A\[m,k\] → \[m,m\] (finite input contract, §10).
    fn syrk(&self, a: &Tensor, pool: Option<&Pool>) -> Tensor;
    /// Symmetric Aᵀ·A for A\[k,m\] → \[m,m\] (finite input contract, §10).
    fn syrk_t(&self, a: &Tensor, pool: Option<&Pool>) -> Tensor;
    /// Fused dequantize A·Wᵀ over bit-packed W (DESIGN.md §11).
    fn deq_gemm_bt(&self, a: &Tensor, w: &PackedRows, pool: Option<&Pool>) -> Tensor;
    /// Fused dequantize GEMV `x · Wᵀ` — the serve decode hot path.
    fn deq_gemv(&self, x: &[f32], w: &PackedRows, pool: Option<&Pool>) -> Vec<f32>;
    /// Plain dot product (no zero-skip) — `attn_row`'s score kernel.
    fn dot(&self, a: &[f32], b: &[f32]) -> f32;
    /// `y += c · x` (caller skips `c == 0`) — `attn_row`'s value kernel.
    fn axpy(&self, c: f32, x: &[f32], y: &mut [f32]);
}

/// The scalar dot product `attn_row` historically inlined: k ascending
/// into one accumulator, no zero-skip. [`ReferenceKernels::dot`] must be
/// exactly this loop so the KV attention path stays bit-identical to the
/// pre-backend code.
pub(super) fn scalar_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// The scalar AXPY `attn_row` historically inlined (value accumulation);
/// the `c == 0.0` skip stays at the call site, as before.
pub(super) fn scalar_axpy(c: f32, x: &[f32], y: &mut [f32]) {
    for (o, &v) in y.iter_mut().zip(x) {
        *o += c * v;
    }
}

/// The bit-exact oracle backend: delegates to the reference free
/// functions, so routing a call site through the trait changes nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReferenceKernels;

impl KernelBackend for ReferenceKernels {
    fn name(&self) -> &'static str {
        "reference"
    }
    fn gemm(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        gemm::gemm(a, b, pool)
    }
    fn gemm_at(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        gemm::gemm_at(a, b, pool)
    }
    fn gemm_bt(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        gemm::gemm_bt(a, b, pool)
    }
    fn syrk(&self, a: &Tensor, pool: Option<&Pool>) -> Tensor {
        gemm::syrk(a, pool)
    }
    fn syrk_t(&self, a: &Tensor, pool: Option<&Pool>) -> Tensor {
        gemm::syrk_t(a, pool)
    }
    fn deq_gemm_bt(&self, a: &Tensor, w: &PackedRows, pool: Option<&Pool>) -> Tensor {
        gemv::deq_gemm_bt(a, w, pool)
    }
    fn deq_gemv(&self, x: &[f32], w: &PackedRows, pool: Option<&Pool>) -> Vec<f32> {
        gemv::deq_gemv(x, w, pool)
    }
    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        scalar_dot(a, b)
    }
    fn axpy(&self, c: f32, x: &[f32], y: &mut [f32]) {
        scalar_axpy(c, x, y)
    }
}

/// The AVX2+FMA backend. Every entry point re-checks availability and
/// falls back to the reference implementation when the host lacks the
/// features, so the struct is always safe to construct and call — but
/// call sites normally never see that fallback, because
/// [`Backend::parse`] already resolves `simd`/`auto` to
/// [`Backend::Reference`] on such hosts.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimdKernels;

impl KernelBackend for SimdKernels {
    fn name(&self) -> &'static str {
        "simd"
    }
    fn gemm(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        let _sp = simd_span("kernel.gemm", a.rows(), a.cols(), b.cols());
        simd::gemm(a, b, pool)
    }
    fn gemm_at(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        let _sp = simd_span("kernel.gemm_at", a.cols(), a.rows(), b.cols());
        simd::gemm_at(a, b, pool)
    }
    fn gemm_bt(&self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        let _sp = simd_span("kernel.gemm_bt", a.rows(), a.cols(), b.rows());
        simd::gemm_bt(a, b, pool)
    }
    fn syrk(&self, a: &Tensor, pool: Option<&Pool>) -> Tensor {
        let _sp = simd_span("kernel.syrk", a.rows(), a.cols(), a.rows());
        simd::syrk(a, pool)
    }
    fn syrk_t(&self, a: &Tensor, pool: Option<&Pool>) -> Tensor {
        let _sp = simd_span("kernel.syrk_t", a.cols(), a.rows(), a.cols());
        simd::syrk_t(a, pool)
    }
    fn deq_gemm_bt(&self, a: &Tensor, w: &PackedRows, pool: Option<&Pool>) -> Tensor {
        let _sp = simd_span("kernel.deq_gemm_bt", a.rows(), a.cols(), w.rows);
        simd::deq_gemm_bt(a, w, pool)
    }
    fn deq_gemv(&self, x: &[f32], w: &PackedRows, pool: Option<&Pool>) -> Vec<f32> {
        let _sp = simd_span("kernel.deq_gemv", 1, x.len(), w.rows);
        simd::deq_gemv(x, w, pool)
    }
    fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        simd::dot(a, b)
    }
    fn axpy(&self, c: f32, x: &[f32], y: &mut [f32]) {
        simd::axpy(c, x, y)
    }
}

static REFERENCE: ReferenceKernels = ReferenceKernels;
static SIMD: SimdKernels = SimdKernels;

/// The resolved backend selection call sites thread around — `Copy`, so
/// it rides in options structs and model state without lifetimes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// The bit-exact oracle (default).
    #[default]
    Reference,
    /// AVX2+FMA kernels; tolerance-pinned against the oracle.
    Simd,
}

impl Backend {
    /// Parse a `--backend` spelling. `reference` always maps to the
    /// oracle; `simd` and `auto` resolve to [`Backend::Simd`] when the
    /// host supports AVX2+FMA and silently to [`Backend::Reference`]
    /// otherwise (the §13 degradation contract — non-x86 and pre-AVX2
    /// hosts run every spelling bit-identically to the default). Unknown
    /// spellings return `None` for the caller's fail-fast path.
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "reference" => Some(Backend::Reference),
            "simd" | "auto" => {
                Some(if simd::simd_available() { Backend::Simd } else { Backend::Reference })
            }
            _ => None,
        }
    }

    /// Resolved name, recorded by `QuantReport`/`ServeReport`.
    pub fn name(self) -> &'static str {
        self.ops().name()
    }

    /// Whether this backend's **batched** `gemm_bt`/`deq_gemm_bt` produce
    /// each output row bit-identically to its own single-row
    /// `matvec`/`deq_gemv` path. True for [`Backend::Reference`] by the
    /// `gemv.rs` contract (the batched kernels are per-row-independent
    /// k-ascending reductions, `dot_row == column`'s m = 1 case). False
    /// for [`Backend::Simd`] when AVX2 is actually in use: the AVX
    /// batched kernels reduce column-major (amortized decode) while the
    /// GEMV kernels reduce row-at-a-time, so the same row comes out of
    /// the two paths with different float associativity. The speculative
    /// verify forward (`Decoder::step_many`) keys off this to stay
    /// token-identical to the sequential decode on every backend.
    pub fn fused_rows_exact(self) -> bool {
        match self {
            Backend::Reference => true,
            // off-AVX2 the simd entry points fall back to the reference
            // scalar kernels, which are row-exact
            Backend::Simd => !simd::simd_available(),
        }
    }

    /// The trait object for generic call sites.
    pub fn ops(self) -> &'static dyn KernelBackend {
        match self {
            Backend::Reference => &REFERENCE,
            Backend::Simd => &SIMD,
        }
    }

    /// A·B through the selected backend.
    pub fn gemm(self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        self.ops().gemm(a, b, pool)
    }

    /// Aᵀ·B through the selected backend.
    pub fn gemm_at(self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        self.ops().gemm_at(a, b, pool)
    }

    /// A·Bᵀ through the selected backend.
    pub fn gemm_bt(self, a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        self.ops().gemm_bt(a, b, pool)
    }

    /// A·Aᵀ through the selected backend.
    pub fn syrk(self, a: &Tensor, pool: Option<&Pool>) -> Tensor {
        self.ops().syrk(a, pool)
    }

    /// Aᵀ·A through the selected backend.
    pub fn syrk_t(self, a: &Tensor, pool: Option<&Pool>) -> Tensor {
        self.ops().syrk_t(a, pool)
    }

    /// Fused dequantize A·Wᵀ through the selected backend.
    pub fn deq_gemm_bt(self, a: &Tensor, w: &PackedRows, pool: Option<&Pool>) -> Tensor {
        self.ops().deq_gemm_bt(a, w, pool)
    }

    /// Fused dequantize GEMV through the selected backend.
    pub fn deq_gemv(self, x: &[f32], w: &PackedRows, pool: Option<&Pool>) -> Vec<f32> {
        self.ops().deq_gemv(x, w, pool)
    }

    /// Dot product through the selected backend — matched inline (no
    /// vtable) because `attn_row` calls it once per head per position.
    #[inline]
    pub fn dot(self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Backend::Reference => scalar_dot(a, b),
            Backend::Simd => simd::dot(a, b),
        }
    }

    /// AXPY through the selected backend (same inlining rationale).
    #[inline]
    pub fn axpy(self, c: f32, x: &[f32], y: &mut [f32]) {
        match self {
            Backend::Reference => scalar_axpy(c, x, y),
            Backend::Simd => simd::axpy(c, x, y),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_spellings() {
        assert_eq!(Backend::parse("reference"), Some(Backend::Reference));
        // `auto` and `simd` resolve identically: Simd where AVX2+FMA is
        // detected, Reference otherwise — never an error.
        assert_eq!(Backend::parse("simd"), Backend::parse("auto"));
        let resolved = Backend::parse("auto").unwrap();
        if simd::simd_available() {
            assert_eq!(resolved, Backend::Simd);
        } else {
            assert_eq!(resolved, Backend::Reference);
        }
        for bad in ["", "avx2", "Reference", "SIMD", "fastest"] {
            assert_eq!(Backend::parse(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn names_and_default() {
        assert_eq!(Backend::default(), Backend::Reference);
        assert_eq!(Backend::Reference.name(), "reference");
        assert_eq!(Backend::Simd.name(), "simd");
        assert_eq!(ReferenceKernels.name(), "reference");
        assert_eq!(SimdKernels.name(), "simd");
    }

    #[test]
    fn fused_rows_exact_tracks_the_dispatch() {
        assert!(Backend::Reference.fused_rows_exact(), "reference is the row-exact oracle");
        // Simd is row-exact exactly when it degrades to the reference
        // scalar kernels (no AVX2+FMA on this host)
        assert_eq!(Backend::Simd.fused_rows_exact(), !simd::simd_available());
    }

    #[test]
    fn reference_primitives_match_the_inlined_loops() {
        let a = [1.5f32, -2.0, 0.0, 3.25, 0.5];
        let b = [0.5f32, 1.0, f32::NAN, -1.0, 2.0];
        // dot has NO zero-skip: the NaN term is 0.0 * NaN = NaN
        assert!(Backend::Reference.dot(&a, &b).is_nan());
        let mut want = 0.0f32;
        let bf = [0.5f32, 1.0, 4.0, -1.0, 2.0];
        for (&x, &y) in a.iter().zip(&bf) {
            want += x * y;
        }
        assert_eq!(Backend::Reference.dot(&a, &bf).to_bits(), want.to_bits());
        let mut y = [1.0f32, 2.0, 3.0];
        Backend::Reference.axpy(2.0, &[0.5, -1.0, 0.25], &mut y);
        assert_eq!(y, [2.0, 0.0, 3.5]);
    }

    #[test]
    fn reference_trait_is_the_free_functions() {
        use crate::util::Pcg;
        let mut rng = Pcg::new(9);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 4], 1.0, &mut rng);
        let via_trait = Backend::Reference.gemm(&a, &b, None);
        assert_eq!(via_trait.data, gemm::gemm(&a, &b, None).data);
        assert_eq!(via_trait.data, a.matmul(&b).data);
    }
}
