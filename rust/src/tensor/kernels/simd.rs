//! AVX2+FMA kernel implementations, runtime-detected (DESIGN.md §13).
//!
//! Every public function here is a *dispatcher*: it runs the
//! `core::arch` x86-64 path when [`simd_available`] holds and falls back
//! to the reference implementation otherwise, so this module compiles
//! and behaves correctly on every architecture — off x86-64 the inner
//! `avx` module does not exist at all and the dispatchers are plain
//! delegation.
//!
//! **What changes vs the oracle.** The dot-form kernels (`gemm_bt`,
//! `syrk`, the fused dequantize dots, `dot`) reassociate the k-reduction
//! into eight lanes × multiple accumulators with FMA contraction —
//! tolerance-pinned, never exact (`tests/common/mod.rs` holds the
//! bounds). They also drop the per-element `a == 0.0` zero-skip (a lane
//! test would cost more than it saves), so they require finite input —
//! the same contract `syrk`/`syrk_t` already had in §10. The AXPY-form
//! kernels (`gemm`, `gemm_at`, `syrk_t`, `axpy`) keep the zero-skip: it
//! is a scalar coefficient test there, outside the vector loop.
//!
//! **What does not change.** The per-element dequantize expression is
//! `scale * (code - zero)` evaluated as the exact same two rounded f32
//! ops as `PackedRows::decode_row_into`, so weight/KV decode is
//! bit-identical — only the dots over decoded values differ. The row
//! codes themselves are recovered by a windowed two-byte read instead of
//! `read_code`'s per-bit loop (every `PACK_BITS` width fits a 16-bit
//! window), recovering identical integers. And dispatch rides the same
//! row-block spine as the reference, so simd output is deterministic and
//! jobs-invariant.

use crate::tensor::pack::PackedRows;
use crate::tensor::Tensor;
use crate::util::Pool;

use super::backend::{scalar_axpy, scalar_dot};

/// True when the running CPU supports the AVX2+FMA kernel set; always
/// false off x86-64. `--backend simd|auto` resolves to `reference`
/// silently when this is false (DESIGN.md §13), and every dispatcher
/// below re-checks it, so the simd paths can never execute unsupported
/// instructions.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// A·B, simd when available (see module docs for the numeric contract).
pub fn gemm(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::gemm(a, b, pool) };
    }
    super::gemm::gemm(a, b, pool)
}

/// Aᵀ·B, simd when available.
pub fn gemm_at(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::gemm_at(a, b, pool) };
    }
    super::gemm::gemm_at(a, b, pool)
}

/// A·Bᵀ, simd when available (finite input: no zero-skip in the dots).
pub fn gemm_bt(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::gemm_bt(a, b, pool) };
    }
    super::gemm::gemm_bt(a, b, pool)
}

/// A·Aᵀ, simd when available (finite input contract as in §10).
pub fn syrk(a: &Tensor, pool: Option<&Pool>) -> Tensor {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::syrk(a, pool) };
    }
    super::gemm::syrk(a, pool)
}

/// Aᵀ·A, simd when available (finite input contract as in §10).
pub fn syrk_t(a: &Tensor, pool: Option<&Pool>) -> Tensor {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::syrk_t(a, pool) };
    }
    super::gemm::syrk_t(a, pool)
}

/// Fused dequantize A·Wᵀ, simd when available.
pub fn deq_gemm_bt(a: &Tensor, w: &PackedRows, pool: Option<&Pool>) -> Tensor {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::deq_gemm_bt(a, w, pool) };
    }
    super::gemv::deq_gemm_bt(a, w, pool)
}

/// Fused dequantize GEMV, simd when available.
pub fn deq_gemv(x: &[f32], w: &PackedRows, pool: Option<&Pool>) -> Vec<f32> {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::deq_gemv(x, w, pool) };
    }
    super::gemv::deq_gemv(x, w, pool)
}

/// Dot product, simd when available.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::dot(a, b) };
    }
    scalar_dot(a, b)
}

/// `y += c · x`, simd when available.
#[inline]
pub fn axpy(c: f32, x: &[f32], y: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence just checked.
        return unsafe { avx::axpy(c, x, y) };
    }
    scalar_axpy(c, x, y)
}

/// The actual AVX2+FMA kernels. Every function is `unsafe` with the
/// same precondition: the caller has verified [`simd_available`].
#[cfg(target_arch = "x86_64")]
mod avx {
    use core::arch::x86_64::*;

    use crate::tensor::pack::{row_bytes, PackedRows};
    use crate::tensor::Tensor;
    use crate::util::Pool;

    use super::super::gemm::mirror_upper;
    use super::super::{par_rows, par_rows_into, pooled, ROW_BLOCK};

    /// Decoded f32s per dequantize tile — same L1 budget as the
    /// reference `gemv.rs` tile.
    const DEQ_TILE: usize = 256;

    /// Horizontal sum of one 8-lane register (final reassociation step
    /// of every dot).
    ///
    /// # Safety
    /// Requires AVX2.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let q = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let q = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let q = _mm_add_ss(q, _mm_shuffle_ps(q, q, 1));
        _mm_cvtss_f32(q)
    }

    /// One fused multiply-add over 8 lanes loaded from `a`/`b`.
    ///
    /// # Safety
    /// Requires AVX2+FMA; `a` and `b` must be readable for 8 f32s.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn fm(a: *const f32, b: *const f32, acc: __m256) -> __m256 {
        _mm256_fmadd_ps(_mm256_loadu_ps(a), _mm256_loadu_ps(b), acc)
    }

    /// AVX2+FMA dot product: four 8-lane accumulators over the main
    /// body, one over the 8-wide remainder, scalar tail — the
    /// reassociation the tolerance harness pins.
    ///
    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut i = 0usize;
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        while i + 32 <= n {
            acc0 = fm(pa.add(i), pb.add(i), acc0);
            acc1 = fm(pa.add(i + 8), pb.add(i + 8), acc1);
            acc2 = fm(pa.add(i + 16), pb.add(i + 16), acc2);
            acc3 = fm(pa.add(i + 24), pb.add(i + 24), acc3);
            i += 32;
        }
        let mut acc = _mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3));
        while i + 8 <= n {
            acc = fm(pa.add(i), pb.add(i), acc);
            i += 8;
        }
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// AVX2+FMA `y += c · x`: per-element rounding differs from the
    /// scalar loop only by FMA contraction (no reassociation — each
    /// output element still absorbs its terms in k order).
    ///
    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn axpy(c: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let cv = _mm256_set1_ps(c);
        let (px, py) = (x.as_ptr(), y.as_mut_ptr());
        let mut i = 0usize;
        while i + 8 <= n {
            let r = _mm256_fmadd_ps(cv, _mm256_loadu_ps(px.add(i)), _mm256_loadu_ps(py.add(i)));
            _mm256_storeu_ps(py.add(i), r);
            i += 8;
        }
        while i < n {
            y[i] += c * x[i];
            i += 1;
        }
    }

    /// Windowed LSB-first code read: every `PACK_BITS` width (≤ 8 bits)
    /// starts at a bit shift ≤ 7, so the code always fits the 16-bit
    /// window `row[byte] | row[byte+1] << 8` — two byte loads replace
    /// `read_code`'s per-bit loop, recovering the identical integer.
    #[inline]
    fn read_window(row: &[u8], idx: usize, bits: usize, mask: u32) -> u32 {
        let bit = idx * bits;
        let byte = bit >> 3;
        let sh = bit & 7;
        let b0 = row[byte] as u32;
        let b1 = if byte + 1 < row.len() { row[byte + 1] as u32 } else { 0 };
        ((b0 | (b1 << 8)) >> sh) & mask
    }

    /// Decode codes `[k0, k0 + out.len())` of packed row `r` — the simd
    /// counterpart of `PackedRows::decode_row_into`. The dequant
    /// `scale * (code - zero)` runs as the exact same two rounded f32
    /// ops per element (cvt/sub/mul in lanes), so decode output is
    /// bit-identical to the reference decode.
    ///
    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn decode_row(w: &PackedRows, r: usize, k0: usize, out: &mut [f32]) {
        let bits = w.bits as usize;
        let mask = (1u32 << bits) - 1;
        let rb = row_bytes(w.cols, w.bits);
        let row = &w.data[r * rb..(r + 1) * rb];
        let (s, z) = (w.grid.scale[r], w.grid.zero[r]);
        let sv = _mm256_set1_ps(s);
        let zv = _mm256_set1_ps(z);
        let n = out.len();
        let mut codes = [0i32; 8];
        let mut t = 0usize;
        while t + 8 <= n {
            for (u, c) in codes.iter_mut().enumerate() {
                *c = read_window(row, k0 + t + u, bits, mask) as i32;
            }
            let cv = _mm256_cvtepi32_ps(_mm256_loadu_si256(codes.as_ptr() as *const __m256i));
            let dv = _mm256_mul_ps(sv, _mm256_sub_ps(cv, zv));
            _mm256_storeu_ps(out.as_mut_ptr().add(t), dv);
            t += 8;
        }
        while t < n {
            out[t] = s * (read_window(row, k0 + t, bits, mask) as f32 - z);
            t += 1;
        }
    }

    /// One output row of A·B / Aᵀ·B: `coeffs` strides over the row's A
    /// coefficients (stride 1 for `gemm`, the column stride for
    /// `gemm_at`); zero coefficients are skipped (a scalar test — the
    /// §10 contract survives in the AXPY form), non-zero ones AXPY the
    /// B row into `out`.
    ///
    /// # Safety
    /// Requires AVX2+FMA; `coeffs` must be readable at
    /// `coeffs + kk * stride` for `kk < k`.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn row_ab(coeffs: *const f32, stride: usize, k: usize, b: &Tensor, out: &mut [f32]) {
        let n = out.len();
        for kk in 0..k {
            let av = *coeffs.add(kk * stride);
            if av == 0.0 {
                continue;
            }
            axpy(av, &b.data[kk * n..(kk + 1) * n], out);
        }
    }

    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    pub(super) unsafe fn gemm(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "gemm inner dim: {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        if k == 0 {
            return out;
        }
        let span = |i: usize| i * n..(i + 1) * n;
        par_rows_into(pool, m, m * k * n, &mut out.data, span, |i, row| {
            // SAFETY: module precondition; row i of A is k coefficients.
            unsafe { row_ab(a.data.as_ptr().add(i * k), 1, k, b, row) }
        });
        out
    }

    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    pub(super) unsafe fn gemm_at(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        let (k, m) = (a.rows(), a.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "gemm_at inner dim: {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        if k == 0 {
            return out;
        }
        let span = |i: usize| i * n..(i + 1) * n;
        par_rows_into(pool, m, m * k * n, &mut out.data, span, |i, row| {
            // SAFETY: module precondition; column i of A strides by m.
            unsafe { row_ab(a.data.as_ptr().add(i), m, k, b, row) }
        });
        out
    }

    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    pub(super) unsafe fn gemm_bt(a: &Tensor, b: &Tensor, pool: Option<&Pool>) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let (n, k2) = (b.rows(), b.cols());
        assert_eq!(k, k2, "gemm_bt inner dim: {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        let span = |i: usize| i * n..(i + 1) * n;
        par_rows_into(pool, m, m * k * n, &mut out.data, span, |i, row| {
            let a_row = a.row(i);
            for (j, o) in row.iter_mut().enumerate() {
                // SAFETY: module precondition.
                *o = unsafe { dot(a_row, b.row(j)) };
            }
        });
        out
    }

    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    pub(super) unsafe fn syrk(a: &Tensor, pool: Option<&Pool>) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let mut out = Tensor::zeros(&[m, m]);
        let span = |i: usize| i * m..i * m + i + 1;
        par_rows_into(pool, m, m * m * k / 2, &mut out.data, span, |i, row| {
            let a_row = a.row(i);
            for (j, o) in row.iter_mut().enumerate() {
                // SAFETY: module precondition.
                *o = unsafe { dot(a_row, a.row(j)) };
            }
        });
        mirror_upper(&mut out);
        out
    }

    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    pub(super) unsafe fn syrk_t(a: &Tensor, pool: Option<&Pool>) -> Tensor {
        let (k, m) = (a.rows(), a.cols());
        let mut out = Tensor::zeros(&[m, m]);
        let span = |i: usize| i * m..i * m + i + 1;
        par_rows_into(pool, m, m * m * k / 2, &mut out.data, span, |i, row| {
            for kk in 0..k {
                let av = a.data[kk * m + i];
                if av == 0.0 {
                    continue;
                }
                // SAFETY: module precondition.
                unsafe { axpy(av, &a.data[kk * m..kk * m + i + 1], row) }
            }
        });
        mirror_upper(&mut out);
        out
    }

    /// One scalar dot of `x` against packed row `j`, tile-decoded
    /// through `buf`; per-tile partial dots accumulate in k order.
    ///
    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn deq_dot_row(x: &[f32], w: &PackedRows, j: usize, buf: &mut [f32; DEQ_TILE]) -> f32 {
        let k = x.len();
        let mut acc = 0.0f32;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + DEQ_TILE).min(k);
            let tile = &mut buf[..k1 - k0];
            decode_row(w, j, k0, tile);
            acc += dot(&x[k0..k1], tile);
            k0 = k1;
        }
        acc
    }

    /// Output column j of A·Wᵀ (all m rows of `a` against packed row j),
    /// tile-decoded once per tile.
    ///
    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn deq_column(a: &[f32], m: usize, k: usize, w: &PackedRows, j: usize) -> Vec<f32> {
        let mut acc = vec![0.0f32; m];
        let mut buf = [0.0f32; DEQ_TILE];
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + DEQ_TILE).min(k);
            let tile = &mut buf[..k1 - k0];
            decode_row(w, j, k0, tile);
            for (i, acc_i) in acc.iter_mut().enumerate() {
                *acc_i += dot(&a[i * k + k0..i * k + k1], tile);
            }
            k0 = k1;
        }
        acc
    }

    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    pub(super) unsafe fn deq_gemm_bt(a: &Tensor, w: &PackedRows, pool: Option<&Pool>) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        assert_eq!(w.cols, k, "deq_gemm_bt inner dim: {k} vs {}", w.cols);
        let n = w.rows;
        // SAFETY: module precondition.
        let cols = par_rows(pool, n, m * k * n, |j| unsafe { deq_column(&a.data, m, k, w, j) });
        let mut out = Tensor::zeros(&[m, n]);
        for (j, col) in cols.into_iter().enumerate() {
            for (i, v) in col.into_iter().enumerate() {
                out.data[i * n + j] = v;
            }
        }
        out
    }

    /// # Safety
    /// Requires AVX2+FMA ([`super::simd_available`]).
    pub(super) unsafe fn deq_gemv(x: &[f32], w: &PackedRows, pool: Option<&Pool>) -> Vec<f32> {
        assert_eq!(x.len(), w.cols, "deq_gemv inner dim: {} vs {}", x.len(), w.cols);
        let n = w.rows;
        let block = |lo: usize, hi: usize| -> Vec<f32> {
            let mut out = Vec::with_capacity(hi - lo);
            let mut buf = [0.0f32; DEQ_TILE];
            for j in lo..hi {
                // SAFETY: module precondition.
                out.push(unsafe { deq_dot_row(x, w, j, &mut buf) });
            }
            out
        };
        let starts: Vec<usize> = (0..n).step_by(ROW_BLOCK).collect();
        match pooled(pool, starts.len(), n * w.cols) {
            Some(p) => p
                .run(starts.len(), |bi| {
                    let lo = starts[bi];
                    block(lo, (lo + ROW_BLOCK).min(n))
                })
                .into_iter()
                .flatten()
                .collect(),
            None => block(0, n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg;

    #[test]
    fn dispatchers_fall_back_cleanly() {
        // Whatever the host: the dispatcher output must match the
        // selected implementation. On non-AVX2 hosts that is exact
        // equality with the reference; on AVX2 hosts this is a smoke
        // check that the simd path produces finite, same-shape output
        // (tolerance bounds live in tests/prop_kernels.rs).
        let mut rng = Pcg::new(17);
        let a = Tensor::randn(&[9, 40], 1.0, &mut rng);
        let b = Tensor::randn(&[40, 7], 1.0, &mut rng);
        let got = gemm(&a, &b, None);
        let want = super::super::gemm::gemm(&a, &b, None);
        assert_eq!(got.shape, want.shape);
        if !simd_available() {
            assert_eq!(got.data, want.data, "fallback must be the reference bit-for-bit");
        } else {
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
            }
        }
    }

    #[test]
    fn dot_axpy_tails_cover_all_lengths() {
        // every length from empty through past the 32-lane unroll
        for n in 0..70usize {
            let a: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).cos()).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0), "n={n}: {got} vs {want}");
            let mut y = vec![1.0f32; n];
            axpy(0.5, &a, &mut y);
            for (i, v) in y.iter().enumerate() {
                let w = 1.0 + 0.5 * a[i];
                assert!((v - w).abs() <= 1e-6, "n={n} i={i}");
            }
        }
    }
}
