//! Fused dequantize-GEMV/GEMM over bit-packed weights (DESIGN.md §11).
//!
//! The serving layer decodes **directly from packed artifacts**: a
//! projection `y = x · Wᵀ` against a [`PackedRows`] weight never
//! materializes the dequantized W. Each pool task walks a block of packed
//! rows; within a row the codes are dequantized in tiles of `DEQ_TILE`
//! f32s (one L1-resident scratch buffer per worker) and consumed by the
//! dot products immediately, so the resident working set stays the packed
//! bytes plus one tile — the packed-vs-f32 memory win survives decode
//! time, not just disk (`benches/bench_serve.rs` measures the ratio).
//!
//! **Determinism.** The dequant expression is exactly `unpack`'s
//! (`scale · (code − zero)`, via [`PackedRows::decode_row_into`]), every
//! accumulator consumes the inner index k in increasing order, and zero
//! activation coefficients are skipped — the §10 zero-skip contract. The
//! pool fans out over *packed-row* blocks, i.e. disjoint output columns,
//! so no reduction crosses a task boundary: [`deq_gemm_bt`] is
//! bit-identical to `gemm_bt(a, &w.unpack(None), pool)` at every jobs
//! count. `tests/prop_serve.rs` asserts exact equality, not tolerance,
//! across bit widths, ragged shapes, and jobs ∈ {1, 4}.

use crate::obs::trace;
use crate::tensor::pack::PackedRows;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::Pool;

use super::{par_rows, pooled, ROW_BLOCK};

/// Codes dequantized per tile: 256 f32s (1 KiB) of stack scratch per
/// worker. Tiling never touches the per-element accumulation order (k
/// stays ascending into the same accumulator), so it cannot perturb a bit.
const DEQ_TILE: usize = 256;

/// Dot the `m` rows of `a` (row stride `k`) against packed row `j`,
/// tile-decoded on the fly; returns output column j of length `m`.
fn column(a: &[f32], m: usize, k: usize, w: &PackedRows, j: usize) -> Vec<f32> {
    let mut acc = vec![0.0f32; m];
    let mut buf = [0.0f32; DEQ_TILE];
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + DEQ_TILE).min(k);
        let tile = &mut buf[..k1 - k0];
        w.decode_row_into(j, k0, tile);
        for (i, acc_i) in acc.iter_mut().enumerate() {
            let a_seg = &a[i * k + k0..i * k + k1];
            for (&av, &wv) in a_seg.iter().zip(tile.iter()) {
                if av == 0.0 {
                    continue;
                }
                *acc_i += av * wv;
            }
        }
        k0 = k1;
    }
    acc
}

/// A·Wᵀ for A [m,k] and packed W [n,k] → [m,n] with on-the-fly
/// dequantization — the packed-domain replacement for
/// `gemm_bt(a, &w.unpack(None), pool)`, bit-identical to it at every
/// jobs count. Pool tasks cover disjoint packed-row blocks — the large
/// dimension at decode time — so a batch-1 GEMV still parallelizes.
pub fn deq_gemm_bt(a: &Tensor, w: &PackedRows, pool: Option<&Pool>) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    assert_eq!(w.cols, k, "deq_gemm_bt inner dim: {k} vs {}", w.cols);
    let n = w.rows;
    let _sp = trace::span_with("kernel", "kernel.deq_gemm_bt", || {
        Json::obj().set("m", m).set("k", k).set("n", n).set("backend", "reference")
    });
    let cols = par_rows(pool, n, m * k * n, |j| column(&a.data, m, k, w, j));
    let mut out = Tensor::zeros(&[m, n]);
    for (j, col) in cols.into_iter().enumerate() {
        for (i, v) in col.into_iter().enumerate() {
            out.data[i * n + j] = v;
        }
    }
    out
}

/// One scalar dot of `x` against packed row `j`, tile-decoded through
/// `buf` — per-element identical to [`column`]'s m = 1 case (k ascends,
/// `x == 0.0` skips) without its per-row accumulator allocation.
fn dot_row(x: &[f32], w: &PackedRows, j: usize, buf: &mut [f32; DEQ_TILE]) -> f32 {
    let k = x.len();
    let mut acc = 0.0f32;
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + DEQ_TILE).min(k);
        let tile = &mut buf[..k1 - k0];
        w.decode_row_into(j, k0, tile);
        for (&av, &wv) in x[k0..k1].iter().zip(tile.iter()) {
            if av == 0.0 {
                continue;
            }
            acc += av * wv;
        }
        k0 = k1;
    }
    acc
}

/// Fused dequantize-GEMV: `y = x · Wᵀ` for `x` of length `w.cols` — the
/// m = 1 row of [`deq_gemm_bt`] without the `Tensor` wrapper. This is
/// the serve decode hot path (one call per projection per token), so it
/// dispatches `ROW_BLOCK`-sized packed-row blocks that each write their
/// outputs into one buffer — no per-output-element allocation — while
/// keeping the exact per-element operation sequence of the reference.
/// Shapes under [`super::POOL_MIN_WORK`] (`n·k` here — the batch-1
/// decode GEMVs of a tiny model) skip the pool entirely: the task-claim
/// round trip would cost more than the arithmetic, and the serial path
/// is bit-identical anyway.
pub fn deq_gemv(x: &[f32], w: &PackedRows, pool: Option<&Pool>) -> Vec<f32> {
    assert_eq!(x.len(), w.cols, "deq_gemv inner dim: {} vs {}", x.len(), w.cols);
    let n = w.rows;
    let _sp = trace::span_with("kernel", "kernel.deq_gemv", || {
        Json::obj().set("k", w.cols).set("n", n).set("backend", "reference")
    });
    let block = |lo: usize, hi: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(hi - lo);
        let mut buf = [0.0f32; DEQ_TILE];
        for j in lo..hi {
            out.push(dot_row(x, w, j, &mut buf));
        }
        out
    };
    let starts: Vec<usize> = (0..n).step_by(ROW_BLOCK).collect();
    match pooled(pool, starts.len(), n * w.cols) {
        Some(p) => p
            .run(starts.len(), |bi| {
                let lo = starts[bi];
                block(lo, (lo + ROW_BLOCK).min(n))
            })
            .into_iter()
            .flatten()
            .collect(),
        None => block(0, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantref;
    use crate::tensor::kernels::gemm_bt;
    use crate::tensor::pack::RowGrid;
    use crate::util::Pcg;

    /// RTN-quantize a random matrix so it packs exactly.
    fn packed(rows: usize, cols: usize, bits: u32, rng: &mut Pcg) -> PackedRows {
        let w = Tensor::randn(&[rows, cols], 1.0, rng);
        let maxq = ((1u64 << bits) - 1) as f32;
        let q = quantref::rtn(&w, maxq);
        let (scale, zero) = quantref::row_grid(&w, maxq);
        PackedRows::pack(&q, bits, &RowGrid { scale, zero }).unwrap()
    }

    #[test]
    fn matches_unpack_then_gemm_bitwise() {
        let mut rng = Pcg::new(5);
        for (m, k, n) in [(1usize, 7usize, 5usize), (3, 33, 17), (4, 300, 9)] {
            // zeros sprinkled in so the zero-skip path is always live
            let a_data: Vec<f32> = (0..m * k)
                .map(|_| if rng.f32() < 0.2 { 0.0 } else { rng.normal() })
                .collect();
            let a = Tensor::from_vec(&[m, k], a_data);
            for bits in [2u32, 4] {
                let w = packed(n, k, bits, &mut rng);
                let want = gemm_bt(&a, &w.unpack(None), None);
                for pool in [None, Some(Pool::new(4))] {
                    let got = deq_gemm_bt(&a, &w, pool.as_ref());
                    assert_eq!(got.shape, want.shape);
                    for (x, y) in got.data.iter().zip(&want.data) {
                        assert_eq!(x.to_bits(), y.to_bits(), "m={m} k={k} n={n} bits={bits}");
                    }
                    let gv = deq_gemv(a.row(0), &w, pool.as_ref());
                    assert_eq!(gv, want.row(0), "gemv row");
                }
            }
        }
    }

    #[test]
    fn degenerate_shapes() {
        let mut rng = Pcg::new(6);
        let w = packed(4, 3, 2, &mut rng);
        let empty = Tensor::zeros(&[0, 3]);
        assert_eq!(deq_gemm_bt(&empty, &w, None).shape, vec![0, 4]);
        let one = packed(1, 1, 8, &mut rng);
        let x = Tensor::from_vec(&[1, 1], vec![2.0]);
        assert_eq!(deq_gemm_bt(&x, &one, None).data, vec![2.0 * one.unpack(None).data[0]]);
    }

    #[test]
    #[should_panic(expected = "deq_gemv inner dim")]
    fn gemv_dim_mismatch_panics() {
        let mut rng = Pcg::new(7);
        let w = packed(2, 5, 2, &mut rng);
        deq_gemv(&[1.0; 4], &w, None);
    }
}
