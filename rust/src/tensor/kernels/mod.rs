//! Host kernel layer: pool-parallel, cache-tiled dense kernels for every
//! host-side hot path (DESIGN.md §10).
//!
//! The naive [`Tensor::matmul`] forced two costs on the rotate/solve hot
//! paths: it is single-threaded, and every transposed operand had to be
//! materialized through `transpose2()` first. This subsystem replaces it
//! with a small BLAS-shaped family:
//!
//! - [`gemm`] / [`gemm_at`] / [`gemm_bt`] — A·B, Aᵀ·B, A·Bᵀ; the fused-
//!   transpose variants read the transposed operand in place, so no call
//!   site materializes a transpose copy for a product anymore;
//! - [`syrk`] / [`syrk_t`] — the symmetric products A·Aᵀ and Aᵀ·A
//!   (Hessian/Gram shapes): only the lower triangle is computed, the upper
//!   is mirrored;
//! - [`cholesky_lower`] / [`tri_inv_lower`] — blocked right-looking
//!   Cholesky and column-block-parallel triangular inversion, the factor
//!   chain behind `linalg::hinv_cholesky_upper`;
//! - [`deq_gemm_bt`] / [`deq_gemv`] — the serving layer's fused
//!   dequantize products over bit-packed weights (`tensor::pack`), which
//!   never materialize the dequantized operand (DESIGN.md §11).
//!
//! **Determinism (DESIGN.md §5, §10).** Every kernel takes an optional
//! [`Pool`] and parallelizes over *row blocks* (column blocks for
//! `tri_inv_lower`): workers compute disjoint output rows with the exact
//! per-row code the serial path runs, and the coordinator stitches the
//! blocks back in index order. No floating-point reduction ever crosses a
//! task boundary, so `jobs=N` is bit-identical to `jobs=1` — and, because
//! the tiling never reassociates a per-element accumulation (k is always
//! visited in increasing order into the same accumulator), the kernels are
//! bit-identical to the naive reference kernel itself. The equivalence
//! tests (`tests/prop_kernels.rs`) assert exact equality, not tolerance.
//!
//! **Zero-skip contract.** The reference kernel skips `a == 0.0`
//! coefficients (both signs), which also suppresses NaN/∞ propagation from
//! the other operand's row. The tiled kernels keep exactly that semantic —
//! contractually, not accidentally: `gemm::tests` pins the behavior on
//! non-finite inputs against the reference. `syrk`/`syrk_t` additionally
//! assume finite input (the mirrored triangle equals the reference only
//! when 0·x cannot produce NaN); every call site feeds finite data.
//!
//! [`Tensor::matmul`]: crate::tensor::Tensor::matmul
//! [`Pool`]: crate::util::Pool

pub mod factor;
pub mod gemm;
pub mod gemv;

pub use factor::{cholesky_lower, tri_inv_lower};
pub use gemm::{gemm, gemm_at, gemm_bt, syrk, syrk_t};
pub use gemv::{deq_gemm_bt, deq_gemv};

use crate::util::Pool;

/// Output rows (or columns) dispatched per pool task: small enough to
/// load-balance ragged work (`syrk` rows grow with the index), large
/// enough that the atomic task claim is amortized.
pub(crate) const ROW_BLOCK: usize = 16;

/// Run `f(0), …, f(n-1)` — one call per output row — and return the
/// results in row order. With a multi-worker pool the rows are dispatched
/// in blocks of [`ROW_BLOCK`] over [`Pool::run`]; rows are computed by the
/// same closure either way, so the parallel path is bit-identical to the
/// serial one (the determinism contract of the module docs).
pub(crate) fn par_rows<F>(pool: Option<&Pool>, n: usize, f: F) -> Vec<Vec<f32>>
where
    F: Fn(usize) -> Vec<f32> + Sync,
{
    let starts: Vec<usize> = (0..n).step_by(ROW_BLOCK).collect();
    match pool {
        Some(p) if p.jobs() > 1 && starts.len() > 1 => p
            .run(starts.len(), |bi| {
                let lo = starts[bi];
                let hi = (lo + ROW_BLOCK).min(n);
                (lo..hi).map(&f).collect::<Vec<Vec<f32>>>()
            })
            .into_iter()
            .flatten()
            .collect(),
        _ => (0..n).map(f).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_rows_orders_and_matches_serial() {
        let f = |i: usize| vec![i as f32, (i * i) as f32];
        let serial = par_rows(None, 67, f);
        for jobs in [1, 2, 4] {
            let pool = Pool::new(jobs);
            assert_eq!(par_rows(Some(&pool), 67, f), serial, "jobs={jobs}");
        }
        assert_eq!(par_rows(Some(&Pool::new(4)), 0, f), Vec::<Vec<f32>>::new());
    }
}
