//! Host kernel layer: pool-parallel, cache-tiled dense kernels for every
//! host-side hot path (DESIGN.md §10), dispatched per backend (§13).
//!
//! The naive [`Tensor::matmul`] forced two costs on the rotate/solve hot
//! paths: it is single-threaded, and every transposed operand had to be
//! materialized through `transpose2()` first. This subsystem replaces it
//! with a small BLAS-shaped family:
//!
//! - [`gemm`] / [`gemm_at`] / [`gemm_bt`] — A·B, Aᵀ·B, A·Bᵀ; the fused-
//!   transpose variants read the transposed operand in place, so no call
//!   site materializes a transpose copy for a product anymore;
//! - [`syrk`] / [`syrk_t`] — the symmetric products A·Aᵀ and Aᵀ·A
//!   (Hessian/Gram shapes): only the lower triangle is computed, the upper
//!   is mirrored;
//! - [`cholesky_lower`] / [`tri_inv_lower`] — blocked right-looking
//!   Cholesky and column-block-parallel triangular inversion, the factor
//!   chain behind `linalg::hinv_cholesky_upper`;
//! - [`deq_gemm_bt`] / [`deq_gemv`] — the serving layer's fused
//!   dequantize products over bit-packed weights (`tensor::pack`), which
//!   never materialize the dequantized operand (DESIGN.md §11).
//!
//! **Backends (DESIGN.md §13).** The free functions above are the
//! `reference` backend — the bit-exact oracle every equivalence test pins
//! against. [`Backend`] selects between them and the runtime-detected
//! AVX2+FMA implementations in [`simd`] (`--backend reference|simd|auto`):
//! [`KernelBackend`] is the dispatch trait, [`Backend::parse`] resolves
//! `simd`/`auto` to `reference` silently when the host lacks AVX2+FMA, and
//! the simd kernels are tolerance-pinned (they reassociate reductions),
//! never exact-pinned — see `backend.rs` and `tests/common/mod.rs`.
//!
//! **Determinism (DESIGN.md §5, §10).** Every kernel takes an optional
//! [`Pool`] and parallelizes over *row blocks* (column blocks for
//! `tri_inv_lower`): workers compute disjoint output rows with the exact
//! per-row code the serial path runs, and the coordinator stitches the
//! blocks back in index order. No floating-point reduction ever crosses a
//! task boundary, so `jobs=N` is bit-identical to `jobs=1` — and, because
//! the tiling never reassociates a per-element accumulation (k is always
//! visited in increasing order into the same accumulator), the kernels are
//! bit-identical to the naive reference kernel itself. The equivalence
//! tests (`tests/prop_kernels.rs`) assert exact equality, not tolerance.
//! The same row-block dispatch carries the simd backend, so simd output is
//! equally jobs-invariant — it only differs from reference by the
//! documented in-row reassociation.
//!
//! **Zero-skip contract.** The reference kernel skips `a == 0.0`
//! coefficients (both signs), which also suppresses NaN/∞ propagation from
//! the other operand's row. The tiled kernels keep exactly that semantic —
//! contractually, not accidentally: `gemm::tests` pins the behavior on
//! non-finite inputs against the reference. `syrk`/`syrk_t` additionally
//! assume finite input (the mirrored triangle equals the reference only
//! when 0·x cannot produce NaN); every call site feeds finite data. The
//! simd backend keeps the skip only where it is a scalar coefficient test
//! (the AXPY-form kernels); see §13 for the caveat on the dot-form ones.
//!
//! [`Tensor::matmul`]: crate::tensor::Tensor::matmul
//! [`Pool`]: crate::util::Pool

pub mod backend;
pub mod factor;
pub mod gemm;
pub mod gemv;
pub mod simd;

pub use backend::{Backend, KernelBackend, ReferenceKernels, SimdKernels};
pub use factor::{cholesky_lower, tri_inv_lower};
pub use gemm::{gemm, gemm_at, gemm_bt, syrk, syrk_t};
pub use gemv::{deq_gemm_bt, deq_gemv};
pub use simd::simd_available;

use crate::util::Pool;
use std::ops::Range;

/// Output rows (or columns) dispatched per pool task: small enough to
/// load-balance ragged work (`syrk` rows grow with the index), large
/// enough that the atomic task claim is amortized.
pub(crate) const ROW_BLOCK: usize = 16;

/// Minimum per-call work (fused multiply-add count, estimated by each
/// kernel as the product of its loop extents) below which pool dispatch
/// is skipped entirely and the serial path runs in the calling thread.
/// Tiny shapes — the batch-1 decode GEMVs of `serve/` above all — would
/// otherwise pay the atomic task-claim round trip for microseconds of
/// arithmetic. Dispatch is bit-identical either way (the serial path IS
/// the per-row code the workers run), so the threshold is pure policy;
/// `benches/bench_kernels.rs` prints it next to the shapes it gates.
pub const POOL_MIN_WORK: usize = 1 << 12;

/// Whether `pool` should be used for `starts.len()` row blocks of
/// estimated total `work`: multi-worker, more than one block, and enough
/// arithmetic to amortize the task claims ([`POOL_MIN_WORK`]).
fn pooled(pool: Option<&Pool>, blocks: usize, work: usize) -> Option<&Pool> {
    match pool {
        Some(p) if p.jobs() > 1 && blocks > 1 && work >= POOL_MIN_WORK => Some(p),
        _ => None,
    }
}

/// Run `f(0), …, f(n-1)` — one call per output row — and return the
/// results in row order. With a multi-worker pool (and at least
/// [`POOL_MIN_WORK`] estimated work) the rows are dispatched in blocks of
/// [`ROW_BLOCK`] over [`Pool::run`]; rows are computed by the same closure
/// either way, so the parallel path is bit-identical to the serial one
/// (the determinism contract of the module docs).
pub(crate) fn par_rows<F>(pool: Option<&Pool>, n: usize, work: usize, f: F) -> Vec<Vec<f32>>
where
    F: Fn(usize) -> Vec<f32> + Sync,
{
    let starts: Vec<usize> = (0..n).step_by(ROW_BLOCK).collect();
    match pooled(pool, starts.len(), work) {
        Some(p) => p
            .run(starts.len(), |bi| {
                let lo = starts[bi];
                let hi = (lo + ROW_BLOCK).min(n);
                (lo..hi).map(&f).collect::<Vec<Vec<f32>>>()
            })
            .into_iter()
            .flatten()
            .collect(),
        None => (0..n).map(f).collect(),
    }
}

/// The allocation-free spine: run `f(i, row)` for each row `i`, where
/// `row` is `out[span(i)]` — zero-initialized on entry — instead of a
/// freshly allocated `Vec` per row ([`par_rows`]'s cost). The serial path
/// writes straight into `out`; the pooled path allocates one buffer per
/// [`ROW_BLOCK`] block covering `span(lo).start..span(hi-1).end` and the
/// coordinator copies blocks back in index order, so the parallel path
/// stays bit-identical to the serial one.
///
/// Contract: `span` must be non-decreasing (row i+1 starts at or after
/// row i), every row slice arrives zeroed, and positions of `out` that
/// fall inside a block's covering range but in no row's span (the gaps of
/// ragged triangular outputs) are written as `0.0` by the pooled path —
/// callers pass freshly zeroed outputs, or overwrite the gaps afterwards
/// (`syrk`'s upper-triangle mirror does the latter).
pub(crate) fn par_rows_into<S, F>(
    pool: Option<&Pool>,
    n: usize,
    work: usize,
    out: &mut [f32],
    span: S,
    f: F,
) where
    S: Fn(usize) -> Range<usize> + Sync,
    F: Fn(usize, &mut [f32]) + Sync,
{
    let starts: Vec<usize> = (0..n).step_by(ROW_BLOCK).collect();
    match pooled(pool, starts.len(), work) {
        Some(p) => {
            let blocks = p.run(starts.len(), |bi| {
                let lo = starts[bi];
                let hi = (lo + ROW_BLOCK).min(n);
                let base = span(lo).start;
                let mut buf = vec![0.0f32; span(hi - 1).end - base];
                for i in lo..hi {
                    let r = span(i);
                    f(i, &mut buf[r.start - base..r.end - base]);
                }
                (base, buf)
            });
            for (base, buf) in blocks {
                out[base..base + buf.len()].copy_from_slice(&buf);
            }
        }
        None => {
            for i in 0..n {
                f(i, &mut out[span(i)]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_rows_orders_and_matches_serial() {
        let f = |i: usize| vec![i as f32, (i * i) as f32];
        let serial = par_rows(None, 67, POOL_MIN_WORK, f);
        for jobs in [1, 2, 4] {
            let pool = Pool::new(jobs);
            assert_eq!(par_rows(Some(&pool), 67, POOL_MIN_WORK, f), serial, "jobs={jobs}");
        }
        assert_eq!(par_rows(Some(&Pool::new(4)), 0, POOL_MIN_WORK, f), Vec::<Vec<f32>>::new());
    }

    #[test]
    fn par_rows_below_min_work_matches_pooled() {
        // under the threshold the pool is bypassed; output is identical
        let f = |i: usize| vec![i as f32; 3];
        let pool = Pool::new(4);
        assert_eq!(
            par_rows(Some(&pool), 67, POOL_MIN_WORK - 1, f),
            par_rows(Some(&pool), 67, POOL_MIN_WORK, f),
        );
    }

    #[test]
    fn par_rows_into_matches_par_rows_contiguous_and_ragged() {
        let n = 67usize;
        // contiguous rows of width 3 (the gemm shape)
        let f = |i: usize| vec![i as f32, (i * 2) as f32, (i * i) as f32];
        let want: Vec<f32> = par_rows(None, n, POOL_MIN_WORK, f).into_iter().flatten().collect();
        for pool in [None, Some(Pool::new(1)), Some(Pool::new(4))] {
            let mut out = vec![0.0f32; n * 3];
            let span = |i: usize| i * 3..(i + 1) * 3;
            par_rows_into(pool.as_ref(), n, POOL_MIN_WORK, &mut out, span, |i, row| {
                row[0] = i as f32;
                row[1] = (i * 2) as f32;
                row[2] = (i * i) as f32;
            });
            assert_eq!(out, want, "contiguous pool={:?}", pool.as_ref().map(|p| p.jobs()));
        }
        // ragged triangular rows (the syrk shape): row i spans i*n..i*n+i+1
        for pool in [None, Some(Pool::new(4))] {
            let mut out = vec![0.0f32; n * n];
            let span = |i: usize| i * n..i * n + i + 1;
            par_rows_into(pool.as_ref(), n, POOL_MIN_WORK, &mut out, span, |i, row| {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = (i * n + j) as f32 + 1.0;
                }
            });
            for i in 0..n {
                for j in 0..n {
                    let want = if j <= i { (i * n + j) as f32 + 1.0 } else { 0.0 };
                    assert_eq!(out[i * n + j], want, "ragged ({i},{j})");
                }
            }
        }
    }
}
