//! Property tests (hand-rolled harness, util::prop) over the artifact
//! layer: pack→unpack exactness across bit widths / ragged widths /
//! degenerate rows, rejection of malformed grids, and the Hessian cache
//! key's invariance contract (jobs/sched-invariant, everything-else-
//! sensitive). All host-side — no compiled artifacts needed.

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::model::config::{ModelConfig, Module};
use rsq::model::ParamSet;
use rsq::quant::artifact::cache::cache_key;
use rsq::quant::{Method, QuantOptions, SchedMode, Strategy};
use rsq::quantref;
use rsq::tensor::pack::{PackedRows, RowGrid, PACK_BITS};
use rsq::tensor::Tensor;
use rsq::util::prop::{check, Config};
use rsq::util::Pcg;

fn random_grid(rows: usize, rng: &mut Pcg) -> RowGrid {
    RowGrid {
        // powers of two keep the values exactly representable without
        // relying on rounding luck — exactness is what's under test
        scale: (0..rows).map(|_| [0.25f32, 0.5, 0.125, 1.0][rng.below(4)]).collect(),
        zero: (0..rows).map(|_| rng.below(4) as f32).collect(),
    }
}

fn tensor_from_codes(rows: usize, cols: usize, bits: u32, grid: &RowGrid, rng: &mut Pcg) -> Tensor {
    let maxq = (1usize << bits) - 1;
    let mut t = Tensor::zeros(&[rows, cols]);
    for r in 0..rows {
        for c in 0..cols {
            let code = rng.below(maxq + 1) as f32;
            t.set2(r, c, grid.scale[r] * (code - grid.zero[r]));
        }
    }
    t
}

#[test]
fn prop_pack_roundtrip_exact_all_bit_widths() {
    // ragged widths: `size` drives cols, rows varies independently
    for bits in PACK_BITS {
        check(
            Config { cases: 24, min_size: 1, max_size: 70, ..Default::default() },
            &format!("pack_roundtrip_{bits}bit"),
            |rng, size| {
                let rows = 1 + rng.below(6);
                let grid = random_grid(rows, rng);
                let t = tensor_from_codes(rows, size, bits, &grid, rng);
                let p = match PackedRows::pack(&t, bits, &grid) {
                    Ok(p) => p,
                    Err(_) => return false,
                };
                let u = p.unpack(None);
                u.shape == t.shape
                    && u.data.iter().zip(&t.data).all(|(a, b)| a.to_bits() == b.to_bits())
            },
        );
    }
}

#[test]
fn prop_pack_roundtrip_rtn_grids() {
    // the real producer: quantref::rtn output on its own row grid, i.e.
    // grids that are NOT powers of two
    for bits in PACK_BITS {
        let maxq = ((1u64 << bits) - 1) as f32;
        check(
            Config { cases: 16, min_size: 2, max_size: 48, ..Default::default() },
            &format!("pack_rtn_{bits}bit"),
            |rng, size| {
                let w = Tensor::randn(&[5, size], 1.0, rng);
                let q = quantref::rtn(&w, maxq);
                let (scale, zero) = quantref::row_grid(&w, maxq);
                let grid = RowGrid { scale, zero };
                match PackedRows::pack(&q, bits, &grid) {
                    Ok(p) => {
                        let u = p.unpack(None);
                        u.data.iter().zip(&q.data).all(|(a, b)| a.to_bits() == b.to_bits())
                    }
                    Err(_) => false,
                }
            },
        );
    }
}

#[test]
fn prop_degenerate_rows_roundtrip() {
    // all-zero-code and all-max-code rows at every width
    check(Config { cases: 16, min_size: 1, max_size: 64, ..Default::default() },
        "degenerate_rows",
        |rng, size| {
            PACK_BITS.into_iter().all(|bits| {
                let maxq = (1u32 << bits) - 1;
                let grid = random_grid(2, rng);
                let mut t = Tensor::zeros(&[2, size]);
                for c in 0..size {
                    t.set2(0, c, grid.scale[0] * (0.0 - grid.zero[0]));
                    t.set2(1, c, grid.scale[1] * (maxq as f32 - grid.zero[1]));
                }
                let p = PackedRows::pack(&t, bits, &grid).unwrap();
                let u = p.unpack(None);
                (0..size).all(|c| p.code(0, c) == 0 && p.code(1, c) == maxq)
                    && u.data.iter().zip(&t.data).all(|(a, b)| a.to_bits() == b.to_bits())
            })
        });
}

#[test]
fn prop_non_finite_scale_rejected() {
    check(
        Config { cases: 16, min_size: 1, max_size: 32, ..Default::default() },
        "non_finite_scale",
        |rng, size| {
            let rows = 1 + rng.below(4);
            let grid = random_grid(rows, rng);
            let t = tensor_from_codes(rows, size, 4, &grid, rng);
            let poison = rng.below(rows);
            let bad = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -1.0][rng.below(5)];
            let mut g2 = grid.clone();
            g2.scale[poison] = bad;
            PackedRows::pack(&t, 4, &g2).is_err()
        },
    );
}

// ---------------------------------------------------------------------------
// cache-key invariance
// ---------------------------------------------------------------------------

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d: 64,
        layers: 2,
        heads: 2,
        ff: 128,
        vocab: 256,
        max_seq: 64,
        batch: 4,
        seq_lens: vec![32, 64],
        ldlq_k: 1024,
        ldlq_g: 8,
    }
}

fn base_setup() -> (ModelConfig, ParamSet, CalibSet, QuantOptions) {
    let c = cfg();
    let p = ParamSet::init(&c, 7);
    let calib = CalibSet::generate(c.vocab, CorpusKind::Wiki, 8, 64, 7, 1);
    let opts = QuantOptions::new(Method::Rsq, 3, 64);
    (c, p, calib, opts)
}

#[test]
fn cache_key_invariant_under_jobs_and_sched() {
    let (c, p, calib, mut opts) = base_setup();
    let base = cache_key(&c, &p, &calib, &opts);
    for jobs in [1usize, 2, 4, 16] {
        for sched in [SchedMode::Staged, SchedMode::Pipelined] {
            opts.jobs = jobs;
            opts.sched = sched;
            opts.verbose = !opts.verbose;
            opts.hess_cache = Some(std::path::PathBuf::from(format!("/tmp/x{jobs}")));
            assert_eq!(
                cache_key(&c, &p, &calib, &opts),
                base,
                "key must not see jobs={jobs} sched={sched:?}"
            );
        }
    }
}

#[test]
fn cache_key_sensitive_to_every_determining_field() {
    let (c, p, calib, opts) = base_setup();
    let base = cache_key(&c, &p, &calib, &opts);

    // corpus: different kind, different content, different seq_len
    let calib_c4 = CalibSet::generate(c.vocab, CorpusKind::C4, 8, 64, 7, 1);
    assert_ne!(cache_key(&c, &p, &calib_c4, &opts), base, "corpus kind");
    let calib_seed = CalibSet::generate(c.vocab, CorpusKind::Wiki, 8, 64, 8, 1);
    assert_ne!(cache_key(&c, &p, &calib_seed, &opts), base, "corpus content");
    let calib_short = CalibSet::generate(c.vocab, CorpusKind::Wiki, 8, 32, 7, 1);
    assert_ne!(cache_key(&c, &p, &calib_short, &opts), base, "corpus seq_len");

    // rotation seed
    let mut o = opts.clone();
    o.rot_seed += 1;
    assert_ne!(cache_key(&c, &p, &calib, &o), base, "rot_seed");

    // strategy (kind and r_min both)
    let mut o = opts.clone();
    o.strategy = Strategy::ActNorm { r_min: 0.05 };
    assert_ne!(cache_key(&c, &p, &calib, &o), base, "strategy kind");
    let mut o = opts.clone();
    o.strategy = Strategy::AttnCon { r_min: 0.01 };
    assert_ne!(cache_key(&c, &p, &calib, &o), base, "strategy r_min");

    // solve config reaches layer>0 Hessians through quantized pass B
    for (label, o) in [
        ("bits", {
            let mut o = opts.clone();
            o.bits = 2;
            o
        }),
        ("damp", {
            let mut o = opts.clone();
            o.damp = 0.02;
            o
        }),
        ("method", {
            let mut o = opts.clone();
            o.method = Method::QuaRot;
            o
        }),
        ("expansion", {
            let mut o = opts.clone();
            o.expansion = 2;
            o
        }),
        ("module_mask", {
            let mut o = opts.clone();
            o.module_mask = Some([Module::Wq, Module::Wv].into_iter().collect());
            o
        }),
    ] {
        assert_ne!(cache_key(&c, &p, &calib, &o), base, "{label}");
    }

    // model params
    let mut p2 = p.clone();
    p2.tensors[3].data[0] += 1e-3;
    assert_ne!(cache_key(&c, &p2, &calib, &opts), base, "params");
}

#[test]
fn cache_key_is_stable_across_calls() {
    let (c, p, calib, opts) = base_setup();
    assert_eq!(cache_key(&c, &p, &calib, &opts), cache_key(&c, &p, &calib, &opts));
    // and hex renders 32 chars of lowercase hex
    let hex = cache_key(&c, &p, &calib, &opts).hex();
    assert_eq!(hex.len(), 32);
    assert!(hex.chars().all(|ch| ch.is_ascii_hexdigit() && !ch.is_ascii_uppercase()));
}
