//! Shared tolerance harness for cross-backend equivalence tests
//! (DESIGN.md §13). The simd backend reassociates its dot reductions
//! (8 lanes × 4 accumulators + FMA), so simd-vs-reference comparisons
//! are pinned by ULP distance + relative error, never bit-equality —
//! bit-equality remains reserved for the reference backend's own tests.
//!
//! Bounds are deliberately generous: a k-term reassociated f32 sum
//! differs from the sequential one by O(k·eps·Σ|terms|), which for the
//! shapes under test stays far inside MAX_ULP/MAX_REL. Tightening them
//! is safe only with an error analysis in hand.

// not every test binary that mounts `mod common;` uses every helper
#![allow(dead_code)]

/// Maximum units-in-last-place distance accepted between a simd result
/// and its reference counterpart.
pub const MAX_ULP: u32 = 128;

/// Maximum relative error accepted when the ULP bound is exceeded near
/// zero crossings (catastrophic cancellation makes ULP meaningless at
/// magnitudes far below the summands).
pub const MAX_REL: f32 = 1e-4;

/// Absolute floor under which any difference is accepted: results this
/// close to zero are dominated by cancellation noise in both backends.
pub const MAX_ABS: f32 = 1e-5;

/// ULP distance between two finite f32s via the ordered-integer map
/// (sign-magnitude → two's-complement-like monotone ordering). Equal
/// values — including `0.0` vs `-0.0` — map to 0; NaN/∞ anywhere maps
/// to `u32::MAX` so they always fail the bound.
pub fn ulp_diff(a: f32, b: f32) -> u32 {
    if !a.is_finite() || !b.is_finite() {
        return if a == b || (a.is_nan() && b.is_nan()) { 0 } else { u32::MAX };
    }
    let ord = |x: f32| -> i64 {
        let bits = x.to_bits() as i32;
        // flip negative floats so the integer line is monotone in value;
        // bits < 0 keeps i32::MIN - bits inside [i32::MIN + 1, 0]
        i64::from(if bits < 0 { i32::MIN - bits } else { bits })
    };
    let d = (ord(a) - ord(b)).unsigned_abs();
    u32::try_from(d).unwrap_or(u32::MAX)
}

/// True when `got` is within the documented harness bounds of `want`:
/// ULP ≤ [`MAX_ULP`], or relative error ≤ [`MAX_REL`], or absolute
/// difference ≤ [`MAX_ABS`].
pub fn within_tolerance(want: f32, got: f32) -> bool {
    let u = ulp_diff(want, got);
    if u == 0 {
        return true;
    }
    if !want.is_finite() || !got.is_finite() {
        return false; // an Inf/NaN mismatch is never reassociation noise
    }
    if u <= MAX_ULP {
        return true;
    }
    let diff = (want - got).abs();
    diff <= MAX_ABS || diff <= MAX_REL * want.abs().max(got.abs())
}

/// Assert two slices agree elementwise within the harness bounds,
/// reporting the worst offender (index, values, ULP distance) on
/// failure. `label` names the kernel/shape under test.
pub fn assert_close(label: &str, want: &[f32], got: &[f32]) {
    assert_eq!(want.len(), got.len(), "{label}: length mismatch");
    let mut worst: Option<(usize, u32)> = None;
    for (i, (&w, &g)) in want.iter().zip(got.iter()).enumerate() {
        if !within_tolerance(w, g) {
            let u = ulp_diff(w, g);
            let better = match worst {
                None => true,
                Some((_, wu)) => u > wu,
            };
            if better {
                worst = Some((i, u));
            }
        }
    }
    if let Some((i, u)) = worst {
        panic!(
            "{label}: out of tolerance at [{i}]: want {:?} got {:?} \
             (ulp {u}, rel {:e}, bounds: ulp<={MAX_ULP} rel<={MAX_REL:e} abs<={MAX_ABS:e})",
            want[i],
            got[i],
            (want[i] - got[i]).abs() / want[i].abs().max(got[i].abs()).max(f32::MIN_POSITIVE),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ulp_of_equal_and_signed_zero_is_zero() {
        assert_eq!(ulp_diff(1.5, 1.5), 0);
        assert_eq!(ulp_diff(0.0, -0.0), 0);
    }

    #[test]
    fn ulp_counts_representable_steps() {
        let a = 1.0f32;
        let b = f32::from_bits(a.to_bits() + 3);
        assert_eq!(ulp_diff(a, b), 3);
        assert_eq!(ulp_diff(b, a), 3);
        // across the zero crossing: -min_sub to +min_sub is two steps
        let sub = f32::from_bits(1);
        assert_eq!(ulp_diff(-sub, sub), 2);
    }

    #[test]
    fn nan_and_inf_never_pass() {
        assert_eq!(ulp_diff(f32::NAN, 1.0), u32::MAX);
        assert_eq!(ulp_diff(f32::INFINITY, f32::MAX), u32::MAX);
        assert!(!within_tolerance(f32::NAN, 1.0));
        assert!(!within_tolerance(1.0, f32::INFINITY));
    }

    #[test]
    fn tolerance_accepts_reassociation_noise_rejects_real_drift() {
        assert!(within_tolerance(100.0, 100.0 + 100.0 * 0.5 * MAX_REL));
        assert!(within_tolerance(0.0, 0.5 * MAX_ABS));
        assert!(!within_tolerance(100.0, 101.0));
        assert!(!within_tolerance(1.0, -1.0));
    }
}
