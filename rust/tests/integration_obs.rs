//! Observability integration (DESIGN.md §16): the binding contract that
//! turning the tracer and metrics registry on never changes one bit of
//! quantization output, at every worker count and scheduler mode the
//! quantizer supports. Requires `make artifacts` (same gate as
//! integration_pipeline.rs).

use rsq::corpus::{CalibSet, CorpusKind};
use rsq::model::outliers::{inject_outliers, OutlierSpec};
use rsq::model::ParamSet;
use rsq::obs::{metrics, trace};
use rsq::quant::{quantize, Method, QuantOptions, SchedMode};
use rsq::runtime::Engine;
use rsq::train::train_or_load;

fn setup() -> (Engine, ParamSet, CalibSet) {
    let eng = Engine::load("tiny").expect("run `make artifacts` first");
    let cfg = eng.config().clone();
    let (mut p, _) = train_or_load(&eng, 7, 150, false).unwrap();
    inject_outliers(&mut p, OutlierSpec::default(), 7);
    let calib = CalibSet::generate(cfg.vocab, CorpusKind::Wiki, 8, 64, 7, 1);
    (eng, p, calib)
}

/// One full RSQ quantization, reduced to the exact bit patterns of every
/// output tensor plus the per-layer reconstruction errors — `to_bits` so
/// the comparison is bit-equality, not float equality.
fn run(
    eng: &Engine,
    p: &ParamSet,
    calib: &CalibSet,
    jobs: usize,
    sched: SchedMode,
) -> (Vec<Vec<u32>>, Vec<u32>) {
    let mut opts = QuantOptions::new(Method::Rsq, 3, 64);
    opts.jobs = jobs;
    opts.sched = sched;
    let (q, report) = quantize(eng, p, calib, &opts).unwrap();
    (
        q.tensors.iter().map(|t| t.data.iter().map(|v| v.to_bits()).collect()).collect(),
        report.layer_err.iter().map(|e| e.to_bits()).collect(),
    )
}

#[test]
fn tracing_on_never_changes_quantization_bits() {
    let (eng, p, calib) = setup();
    let combos = [
        (1usize, SchedMode::Staged),
        (4, SchedMode::Staged),
        (1, SchedMode::Pipelined),
        (4, SchedMode::Pipelined),
    ];
    // baseline first: these runs record nothing unless another test in
    // the process already enabled the globals — in which case they are
    // traced too and the contract below is tested all the same
    let baseline: Vec<_> = combos.iter().map(|&(j, s)| run(&eng, &p, &calib, j, s)).collect();
    trace::enable();
    metrics::enable();
    for (&(j, s), want) in combos.iter().zip(&baseline) {
        let got = run(&eng, &p, &calib, j, s);
        assert_eq!(&got, want, "jobs={j} sched={s:?}: tracing flipped an output bit");
    }
    // the traced runs must actually have recorded the scheduler spans —
    // otherwise this test would pass vacuously with dead instrumentation
    let evs = trace::take_events();
    for name in ["sched.solve_module", "quant.rotate"] {
        assert!(evs.iter().any(|e| e.name == name), "no {name} span recorded");
    }
    let snap = metrics::snapshot();
    assert!(
        snap.gauges.keys().any(|k| k.starts_with("quant.layer_err.")),
        "no per-layer error gauges recorded"
    );
}
