//! Property tests (hand-rolled harness, util::prop) over the quantization
//! stack: HLO-vs-rust agreement on random instances, grid invariants,
//! Eq. 4 bounds, and scheduler/dataset invariants.

use rsq::corpus::{expand_dataset, CalibSet, CorpusKind};
use rsq::quant::strategy::normalize_eq4;
use rsq::quantref;
use rsq::runtime::{self, Engine};
use rsq::tensor::{kernels, linalg, Tensor};
use rsq::util::prop::{check, Config};
use rsq::util::Pcg;

fn rand_hessian(din: usize, rng: &mut Pcg) -> Tensor {
    let n = din * 3;
    let x: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..din).map(|_| rng.normal()).collect())
        .collect();
    let r: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    quantref::hessian_scaled(&x, &r)
}

#[test]
fn prop_rtn_idempotent() {
    check(Config { cases: 16, max_size: 48, ..Default::default() }, "rtn_idempotent", |rng, size| {
        let w = Tensor::randn(&[8, size.max(2)], 1.0, rng);
        let q1 = quantref::rtn(&w, 7.0);
        let q2 = quantref::rtn(&q1, 7.0);
        q1.allclose(&q2, 1e-5)
    });
}

#[test]
fn prop_gptq_beats_rtn_in_aggregate() {
    // GPTQ's greedy feedback with a grid fixed from the original W is not
    // pointwise-dominant over RTN (feedback can push values off-grid), but
    // it must win in aggregate and never lose catastrophically.
    let mut wins = 0usize;
    let cases = 24usize;
    check(Config { cases, min_size: 4, max_size: 24, ..Default::default() }, "gptq_vs_rtn", |rng, size| {
        let din = size.max(4);
        let w = Tensor::randn(&[6, din], 1.0, rng);
        let h = rand_hessian(din, rng);
        let (_, egptq) = quantref::gptq(&w, &h, 7.0, 0.01);
        let qrtn = quantref::rtn(&w, 7.0);
        let ertn = quantref::hessian_weighted_err(&w, &qrtn, &h);
        if egptq <= ertn * 1.001 + 1e-4 {
            wins += 1;
        }
        egptq <= ertn * 2.0 + 1e-3 // never catastrophically worse
    });
    assert!(wins * 4 >= cases * 3, "GPTQ won only {wins}/{cases} instances");
}

#[test]
fn prop_cholesky_factor_reconstructs() {
    check(Config { cases: 16, min_size: 2, max_size: 32, ..Default::default() }, "chol", |rng, size| {
        let d = size.max(2);
        let a = Tensor::randn(&[d, d], 1.0, rng);
        let mut h = kernels::syrk(&a, None);
        for i in 0..d {
            let v = h.at2(i, i) + d as f32;
            h.set2(i, i, v);
        }
        let l = linalg::cholesky_lower(&h);
        kernels::syrk(&l, None).allclose(&h, 1e-2 * d as f32)
    });
}

#[test]
fn prop_eq4_bounds_and_monotonicity() {
    check(Config { cases: 24, min_size: 2, max_size: 64, ..Default::default() }, "eq4", |rng, size| {
        let raw: Vec<f32> = (0..size.max(2)).map(|_| rng.normal() * 10.0).collect();
        let r = normalize_eq4(&raw, 0.01);
        let bounds = r.iter().all(|&v| (0.0099..=1.0001).contains(&v));
        // order-preserving
        let mono = raw
            .iter()
            .zip(raw.iter().skip(1))
            .zip(r.iter().zip(r.iter().skip(1)))
            .all(|((a, b), (ra, rb))| (a <= b) == (ra <= rb) || (a - b).abs() < 1e-9);
        bounds && mono
    });
}

#[test]
fn prop_expansion_preserves_token_multiset() {
    check(Config { cases: 12, min_size: 2, max_size: 8, ..Default::default() }, "expansion", |rng, size| {
        let m = size.max(2);
        let set = CalibSet::generate(256, CorpusKind::Wiki, 2, 64, rng.next_u64(), 1);
        let e = expand_dataset(&set, m);
        if e.samples.len() != set.samples.len() * m {
            return false;
        }
        let hist = |samples: &[Vec<i32>]| {
            let mut h = vec![0u32; 256];
            for s in samples {
                for &t in s {
                    h[t as usize] += 1;
                }
            }
            h
        };
        let h0 = hist(&set.samples);
        let he = hist(&e.samples);
        h0.iter().zip(&he).all(|(a, b)| *b == a * m as u32)
    });
}

#[test]
fn prop_hlo_gptq_matches_rust_reference() {
    // the big one: the AOT solver and the independent rust solver agree on
    // random (W, H, bits) instances — shapes fixed by the tiny artifacts
    let eng = Engine::load("tiny").expect("run `make artifacts` first");
    check(Config { cases: 6, max_size: 1000, ..Default::default() }, "hlo_gptq", |rng, _| {
        let w = Tensor::randn(&[64, 64], 0.5, rng);
        let h = rand_hessian(64, rng);
        let bits = [3.0f32, 7.0, 15.0][rng.below(3)];
        let outs = eng
            .exec(
                "gptq_64x64",
                &[
                    runtime::tensor_literal(&w).unwrap(),
                    runtime::tensor_literal(&h).unwrap(),
                    runtime::scalar_literal(bits),
                    runtime::scalar_literal(0.01),
                ],
            )
            .unwrap();
        let q_hlo = runtime::literal_tensor(&outs[0]).unwrap();
        let (q_ref, _) = quantref::gptq(&w, &h, bits, 0.01);
        q_hlo.sub(&q_ref).abs_max() < 1e-3
    });
}

#[test]
fn prop_hlo_rtn_matches_rust_reference() {
    let eng = Engine::load("tiny").expect("run `make artifacts` first");
    check(Config { cases: 8, max_size: 1000, ..Default::default() }, "hlo_rtn", |rng, _| {
        let w = Tensor::randn(&[128, 64], 1.0, rng);
        let maxq = [3.0f32, 7.0, 15.0, 255.0][rng.below(4)];
        let outs = eng
            .exec(
                "rtn_128x64",
                &[runtime::tensor_literal(&w).unwrap(), runtime::scalar_literal(maxq)],
            )
            .unwrap();
        let q = runtime::literal_tensor(&outs[0]).unwrap();
        q.sub(&quantref::rtn(&w, maxq)).abs_max() < 1e-5
    });
}
